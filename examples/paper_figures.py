"""Reproduce the paper's figures at laptop scale (quick mode) and print the
claims being validated.  Full-scale numbers: python -m benchmarks.run --full

Run:  PYTHONPATH=src:. python examples/paper_figures.py
"""
from benchmarks import bench_zipf, bench_traces, bench_window

rows = bench_zipf.run(quick=True)
tl = {r["policy"]: r["hit_ratio"] for r in rows
      if r["trace"] == "zipf0.9" and r["cache_size"] == 2000}
print("\nFig 6 (zipf0.9, C=2000):")
for k in ["LRU", "TLRU", "TRandom", "TLFU", "WLFU", "PLFU", "W-TinyLFU"]:
    print(f"  {k:12s} {tl.get(k, float('nan')):.4f}")
print("claim: TLRU/TRandom/TLFU cluster near WLFU, far above LRU")

rows = bench_window.run(quick=True)
oltp = [(r["policy"], r["hit_ratio"]) for r in rows if r["trace"] == "oltp-like"]
print("\nFig 21 (oltp-like window sweep):", *oltp, sep="\n  ")
print("claim: 20-40% window beats 1% on OLTP-family traces")
