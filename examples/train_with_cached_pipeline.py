"""Training driver: a reduced-config model trained for real steps with the
fault-tolerant loop — deterministic resumable pipeline (W-TinyLFU shard
cache), async checkpointing, preemption-safe.

Run:  PYTHONPATH=src python examples/train_with_cached_pipeline.py
"""
import json
import shutil

from repro.train.driver import train

shutil.rmtree("/tmp/repro_example_run", ignore_errors=True)
out = train("minicpm-2b", smoke=True, steps=30, out_dir="/tmp/repro_example_run",
            global_batch=8, seq_len=64, ckpt_every=10, optimizer="adamw")
print(json.dumps(out, indent=1))
print("loss curve in /tmp/repro_example_run/metrics.jsonl")
