"""Quickstart: the paper's data structure in 40 lines.

Builds a TinyLFU sketch, streams a skewed workload through it, and shows the
admission decision (paper Fig 1) protecting a hot working set — then the same
thing through the TPU-kernel path (Pallas, interpret mode on CPU).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import tinylfu_cache, Cache, LRUEviction, WTinyLFU, run_trace
from repro.traces import zipf_trace
from repro.kernels import DeviceTinyLFU

# --- 1. hit-ratio boost from admission (the paper's headline) --------------
trace = zipf_trace(200_000, n_items=200_000, alpha=0.9, seed=0)
C = 1000
lru = run_trace(Cache(LRUEviction(C)), trace, warmup=40_000)
tlru = run_trace(tinylfu_cache(C, "lru", sample_factor=16), trace,
                 warmup=40_000)
wtlfu = run_trace(WTinyLFU(C, sample_factor=16), trace, warmup=40_000)
print(f"LRU        hit-ratio: {lru.hit_ratio:.4f}")
print(f"TinyLFU+LRU           {tlru.hit_ratio:.4f}   (admission only)")
print(f"W-TinyLFU             {wtlfu.hit_ratio:.4f}   (window + SLRU)")

# --- 2. the same sketch as TPU kernels (Pallas; interpret=True on CPU) -----
t = DeviceTinyLFU(num_blocks=1024, sample_factor=8)
hot = np.arange(0, 64, dtype=np.uint64)
rng = np.random.default_rng(0)
t.record(np.repeat(hot, 20))                    # hot keys seen 20x
cold = rng.integers(1 << 20, 1 << 21, size=64, dtype=np.uint64)
print("\nadmit cold-over-hot :", int(t.admit(cold, hot).sum()), "/ 64")
print("admit hot-over-cold :", int(t.admit(hot, cold).sum()), "/ 64")
