"""End-to-end serving driver (the paper's kind of system): a small LM served
with continuous batching; the HBM prefix pool is managed by the paper's
admission policy.  Compares retention policies on a multi-tenant workload.

Run:  PYTHONPATH=src python examples/serve_prefix_cache.py
"""
from repro.serve.driver import serve

for policy in ["lru", "tinylfu", "wtinylfu"]:
    stats = serve("qwen3-4b", n_requests=48, policy=policy, pool_slots=24)
    print(f"{policy:10s} block-hit={stats['prefix_hit_ratio']:.3f} "
          f"reuse={stats['reuse_frac']:.3f} "
          f"admitted={stats['admitted']} rejected={stats['rejected']} "
          f"pool={stats['pool_used']}")
