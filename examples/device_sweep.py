"""Device-resident trace simulation: host loop vs one compiled program.

Simulates the same Zipf trace through W-TinyLFU three ways:

1. host engine  — `run_trace` driving the pure-Python policy objects;
2. device scan  — `device_simulate.simulate_trace`: the whole trace is one
   `jax.lax.scan` over the fused per-access step, state never leaves the
   device (interpret/jit stand-in on CPU);
3. device sweep — a (cache size × window fraction) Cartesian grid through
   `simulate_sweep`: the `run_matrix` experiment as one compiled program.

Host and device agree to a few 1e-4 of hit ratio; the only difference is the
hash family (64-bit splitmix on host, 32-bit-lane mixers on device).

Run:  PYTHONPATH=src python examples/device_sweep.py
"""
from repro.core import WTinyLFU, run_trace
from repro.core.device_simulate import simulate_trace, simulate_sweep
from repro.traces import zipf_trace

trace = zipf_trace(60_000, n_items=50_000, alpha=0.9, seed=7)
C, warm = 500, 12_000

host = run_trace(WTinyLFU(C, sample_factor=8), trace, warmup=warm,
                 trace_name="zipf0.9")
dev = simulate_trace(trace, C, warmup=warm, trace_name="zipf0.9")
print(f"host   W-TinyLFU hit-ratio: {host.hit_ratio:.4f}  "
      f"({host.accesses / host.wall_s:,.0f} acc/s)")
print(f"device W-TinyLFU hit-ratio: {dev.hit_ratio:.4f}  "
      f"({dev.accesses / dev.wall_s:,.0f} acc/s, backend={dev.extra['backend']})")

adev = simulate_trace(trace, C, warmup=warm, trace_name="zipf0.9", assoc=8)
print(f"device set-assoc(w=8)  ratio: {adev.hit_ratio:.4f}  "
      f"({adev.accesses / adev.wall_s:,.0f} acc/s — O(ways) per access, "
      f"capacity-free; the engine for production-scale C)")

print("\nCartesian sweep (sizes x window fractions), one program:")
simulate_sweep(trace, [250, 500, 1000], window_fracs=[0.01, 0.2],
               warmup=warm, trace_name="zipf0.9", verbose=True)
