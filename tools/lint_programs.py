#!/usr/bin/env python
"""Compiled-program lint: enforce the in-place discipline statically.

Lowers the engine across the configuration matrix (flat/assoc x
static/adaptive x shards x streams x policy x mesh chunk/stale), lints
the post-optimization HLO against rules R0-R6, and verifies the R7
byte-identity fingerprint registry.  Lowering + compilation only — no
program executes, so the whole run is CPU-cheap (~30 s here; see the CI
step for the budget).

Exit codes: 0 clean (waived findings allowed), 1 violations, 2 internal
error.

    python tools/lint_programs.py                 # full matrix + R7
    python tools/lint_programs.py --configs mesh  # label substring
    python tools/lint_programs.py --update        # re-pin fingerprints
    python tools/lint_programs.py --report lint_report.json
    python tools/lint_programs.py --list-rules
"""
import argparse
import json
import os
import sys
from pathlib import Path

# environment must be fixed BEFORE jax imports: the mesh entries need two
# forced host devices, and the lint contract is the CPU backend
os.environ.setdefault("JAX_PLATFORMS", "cpu")
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=2").strip()

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="static lint of lowered engine programs (R1-R7)")
    ap.add_argument("--configs", metavar="SUBSTR", default=None,
                    help="only lint matrix entries whose label contains "
                         "SUBSTR")
    ap.add_argument("--update", action="store_true",
                    help="re-pin the R7 fingerprint registry for this "
                         "environment (after an intentional lowering "
                         "change)")
    ap.add_argument("--report", metavar="PATH", default=None,
                    help="write a JSON report to PATH")
    ap.add_argument("--skip-fingerprints", action="store_true",
                    help="matrix rules only (R0-R6)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    from repro.analysis.program_lint import (RULES, check_fingerprints,
                                             env_key, run_matrix)
    if args.list_rules:
        for rid, desc in RULES.items():
            print(f"{rid}  {desc}")
        return 0

    import jax
    print(f"program lint [{env_key()}], "
          f"{jax.device_count()} device(s)")

    violations, rows = run_matrix(configs=args.configs)
    for r in rows:
        mark = {"ok": "ok", "fail": "FAIL", "skipped": "skip",
                "waived": "ok (waived)"}[r["status"]]
        extra = r.get("reason", "") or (
            f"{r.get('seconds', 0):.1f}s" if "seconds" in r else "")
        print(f"  {r['label']:<26} {mark:<12} {extra}")
        for rule, reason in dict(
                (w["rule"], w["reason"])
                for w in r.get("waived", [])).items():
            n = sum(1 for w in r["waived"] if w["rule"] == rule)
            print(f"      waived [{rule}] x{n}: {reason}")

    fp_violations, notes = [], []
    if not args.skip_fingerprints and not args.configs:
        fp_violations, notes = check_fingerprints(update=args.update)
        for n in notes:
            print(f"  fingerprints: {n}")
        if not fp_violations and not args.update:
            print("  fingerprints: R7 ok "
                  "(pair equality + registry digests)")

    all_v = violations + fp_violations
    for v in all_v:
        print(f"  {v}")

    if args.report:
        Path(args.report).write_text(json.dumps({
            "env": env_key(),
            "configs": rows,
            "fingerprints": {
                "violations": [v.to_dict() for v in fp_violations],
                "notes": notes,
            },
            "ok": not all_v,
        }, indent=2) + "\n")
        print(f"  report -> {args.report}")

    if all_v:
        print(f"FAIL: {len(all_v)} violation(s)")
        return 1
    print("clean")
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except Exception as exc:                      # noqa: BLE001
        print(f"internal error: {exc}", file=sys.stderr)
        raise SystemExit(2)
