#!/usr/bin/env python
"""Python-AST lint for the kernel source: the write-discipline at the
source level, complementing the compiled-program lint
(``tools/lint_programs.py``) which checks what XLA actually emitted.

Rules:

``S1``  raw ``.at[...]`` indexed-update chains in ``src/repro/kernels/``
        are banned outside the approved write helpers (``_lset*``) and
        the epoch-boundary / init / host-reference scopes listed in
        ``ALLOWED_AT_SCOPES``.  Per-access writes must go through the
        helpers — they are what keeps lane batching scatter-free and the
        single-word DUS discipline honest (lint rule R1's source-level
        twin).
``S2``  computed-index subscript loads (``tab[h % N]``-style inline
        gathers, ``jnp.take``) in ``src/repro/kernels/`` outside the
        approved gather helpers: reads of dynamic positions must go
        through ``_ds_gather`` / reviewed helper scopes so the
        ``_big_operand`` width-cliff discipline applies (R-series
        symptom: the 2^18 gather-partitioning cliff).
``S3``  module-level memo dicts (``_x_cache = {}``) anywhere in
        ``src/repro/`` must be bounded: the file must apply the
        clear-on-full pattern (``if len(cache) >= LIMIT: cache.clear()``)
        — the ``_mesh_cache``/``_vmap_cache``/``_pallas_cache`` leak
        class fixed reactively in PRs 6 and 8, now enforced statically.

Exit codes: 0 clean, 1 findings.
"""
import argparse
import ast
import sys
from pathlib import Path

# S1: functions whose whole body may use raw .at[] updates.
#   - the approved write helpers themselves (their implementation IS the
#     discipline: off-lane they emit the plain .at[].set)
#   - epoch-boundary scopes (rebalance/merge run once per epoch, not per
#     access; their gather/scatter cost is amortized by design)
#   - init-time and pallas-kernel scopes (not part of the traced scan)
ALLOWED_AT_SCOPES = {
    "_lset", "_lset_row", "_lset_col",            # the write helpers
    "_rebalance_flat", "_rebalance_set",          # epoch boundary
    "compact",                                    # epoch boundary
    "init_step_state",                            # init time
    "_step_kernel",                               # pallas body (Ref ops)
}
# S1/S2: whole files outside the fused-scan discipline: the O(capacity)
# host-reference kernel, the epoch-boundary merge fold, and the pallas
# batched-admission kernel (Ref indexing, not traced gathers)
ALLOWED_FILES = {"ref.py", "sketch_merge.py", "sketch_update.py"}

# S2: scopes that may read computed indices directly — each one either
# implements the width-cliff discipline or carries the _big_operand
# guard internally (the small-width fused-gather branch is the approved
# fast path there)
ALLOWED_GATHER_SCOPES = {
    "_ds_gather",                                  # the gather helper
    "_estimate_pair", "_estimate_block",           # _big_operand-guarded
    "_one_access_set_arc",                         # _big_operand-guarded
    "bit_get",                                     # packed-bitset helper
    "probe_index", "dk_probe_index",               # python const tables
    "set_table",                                   # init-time numpy
} | ALLOWED_AT_SCOPES


def _enclosing_functions(tree):
    """Map every node -> tuple of enclosing function names, outermost
    first (an inner ``body`` closure inherits its parent's approval)."""
    owner = {}

    def walk(node, chain):
        for child in ast.iter_child_nodes(node):
            nchain = chain
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                nchain = chain + (child.name,)
            owner[child] = nchain
            walk(child, nchain)
    walk(tree, ())
    return owner


def _is_at_chain(node: ast.Subscript) -> bool:
    """``<expr>.at[...]`` — the jax indexed-update property."""
    return (isinstance(node.value, ast.Attribute)
            and node.value.attr == "at")


def _computed_index(node: ast.expr) -> bool:
    """An index expression with arithmetic or calls in it — the inline
    hash-derived gather S2 bans.  Plain names/constants/slices pass (a
    static type can't tell a python int from a traced array, so a
    deliberate variable assignment is the reviewable unit)."""
    if isinstance(node, ast.Tuple):
        return any(_computed_index(e) for e in node.elts)
    if isinstance(node, ast.Slice):
        return False
    return any(isinstance(n, (ast.BinOp, ast.Call))
               for n in ast.walk(node))


def lint_kernels_file(path: Path) -> list:
    findings = []
    tree = ast.parse(path.read_text())
    owner = _enclosing_functions(tree)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Subscript):
            continue
        chain = owner.get(node, ())
        label = chain[-1] if chain else "<module>"
        if _is_at_chain(node):
            if not any(fn in ALLOWED_AT_SCOPES for fn in chain):
                findings.append(
                    ("S1", path, node.lineno,
                     f"raw .at[] update in {label}() — use the "
                     "_lset*/_ldus* write helpers (or add the scope "
                     "to ALLOWED_AT_SCOPES with a reason)"))
        elif isinstance(node.ctx, ast.Load) and \
                _computed_index(node.slice):
            if not any(fn in ALLOWED_GATHER_SCOPES for fn in chain):
                findings.append(
                    ("S2", path, node.lineno,
                     f"computed-index gather in {label}() — read "
                     "through _ds_gather (width-cliff discipline) "
                     "or an approved helper scope"))
    return findings


def lint_memo_dicts(path: Path) -> list:
    """S3: every module-level ``NAME = {}`` must be bounded in-file."""
    findings = []
    src = path.read_text()
    tree = ast.parse(src)
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            value, targets = node.value, node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            value, targets = node.value, [node.target]
        else:
            continue
        if not (isinstance(value, ast.Dict) and not value.keys):
            continue
        for t in targets:
            if not isinstance(t, ast.Name):
                continue
            name = t.id
            if f"len({name})" not in src and f"{name}.clear()" not in src:
                findings.append(
                    ("S3", path, node.lineno,
                     f"module-level memo dict {name!r} has no bound — "
                     "apply the clear-on-full pattern "
                     f"(if len({name}) >= LIMIT: {name}.clear())"))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="AST lint: raw .at[] / inline gathers / unbounded "
                    "memo dicts")
    ap.add_argument("--root", default=str(
        Path(__file__).resolve().parents[1]))
    args = ap.parse_args(argv)
    root = Path(args.root)

    findings = []
    for path in sorted((root / "src" / "repro" / "kernels").glob("*.py")):
        if path.name in ALLOWED_FILES:
            continue
        findings += lint_kernels_file(path)
    for path in sorted((root / "src" / "repro").rglob("*.py")):
        findings += lint_memo_dicts(path)

    for rule, path, line, msg in findings:
        print(f"FAIL [{rule}] {path.relative_to(root)}:{line}: {msg}")
    if findings:
        print(f"{len(findings)} finding(s)")
        return 1
    print("source lint clean (S1-S3)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
