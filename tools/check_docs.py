"""Docs reference checker (ISSUE 4): fail CI when a doc references a file
or symbol that no longer exists.

Scans ``docs/*.md`` and ``README.md`` for backtick-quoted references and
verifies two kinds:

* **paths** — tokens that look like file paths (contain a known extension,
  e.g. ``kernels/sketch_step.py`` or ``BENCH_device.json``).  Resolved
  against the repo root, ``src/``, and ``src/repro/`` (docs conventionally
  drop the ``src/repro/`` prefix for in-package files).  A trailing
  ``:<line>`` or anchor is stripped.
* **dotted symbols** — tokens starting with ``repro.`` (e.g.
  ``repro.core.device_simulate.simulate_trace``).  The longest module
  prefix must resolve to a ``.py`` file (or package ``__init__.py``) under
  ``src/``, and any remaining attribute must appear in that file as a
  ``def``/``class`` definition or assignment target (grep-based — simple
  on purpose; it catches renames and deletions, not signature drift).

Anything else inside backticks (shell commands, inline code, field names)
is ignored.  Keep doc references in one of the two checkable forms so this
gate keeps meaning something.

Usage: ``python tools/check_docs.py [--root REPO_ROOT]`` — exits 1 with a
list of stale references on failure.
"""
from __future__ import annotations

import argparse
import glob
import os
import re
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_BACKTICK = re.compile(r"`([^`\n]+)`")
_PATHLIKE = re.compile(
    r"^[A-Za-z0-9_.{/\\-]*\.(py|md|json|yml|yaml|toml|txt)(:\d+)?(#[\w-]*)?$")
_DOTTED = re.compile(r"^repro(\.[A-Za-z_]\w*)+$")


def _iter_refs(text: str):
    for m in _BACKTICK.finditer(text):
        tok = m.group(1).strip()
        # strip decorations that commonly wrap a reference
        tok = tok.strip("*,;:()[]")
        if not tok or " " in tok or "*" in tok or "{" in tok:
            continue                      # commands, globs, templates
        yield tok


def _check_path(tok: str, root: str) -> bool:
    tok = tok.split("#")[0]
    tok = re.sub(r":\d+$", "", tok)
    for base in ("", "src", os.path.join("src", "repro")):
        if os.path.exists(os.path.join(root, base, tok)):
            return True
    return False


def _check_symbol(tok: str, root: str) -> bool:
    parts = tok.split(".")
    # longest module prefix that is a real file / package
    for cut in range(len(parts), 0, -1):
        mod = os.path.join(root, "src", *parts[:cut])
        for cand in (mod + ".py", os.path.join(mod, "__init__.py")):
            if os.path.exists(cand):
                rest = parts[cut:]
                if not rest:
                    return True
                # only the first attribute is greppable (module-level name)
                name = re.escape(rest[0])
                pat = re.compile(
                    rf"^\s*(def\s+{name}\b|class\s+{name}\b|{name}\s*[:=])",
                    re.M)
                with open(cand) as f:
                    return bool(pat.search(f.read()))
    return False


def check_file(path: str, root: str) -> list[str]:
    with open(path) as f:
        text = f.read()
    rel = os.path.relpath(path, root)
    stale = []
    for tok in _iter_refs(text):
        if _DOTTED.match(tok):
            if not _check_symbol(tok, root):
                stale.append(f"{rel}: stale symbol reference `{tok}`")
        elif _PATHLIKE.match(tok):
            if not _check_path(tok, root):
                stale.append(f"{rel}: stale path reference `{tok}`")
    return stale


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=_REPO_ROOT)
    args = ap.parse_args(argv)

    targets = sorted(glob.glob(os.path.join(args.root, "docs", "*.md")))
    readme = os.path.join(args.root, "README.md")
    if os.path.exists(readme):
        targets.append(readme)
    if not targets:
        print("check_docs: nothing to check (no docs/*.md or README.md)")
        return 1

    failures = []
    n_refs = 0
    for path in targets:
        with open(path) as f:
            n_refs += sum(1 for t in _iter_refs(f.read())
                          if _DOTTED.match(t) or _PATHLIKE.match(t))
        failures.extend(check_file(path, args.root))
    for msg in failures:
        print("FAIL:", msg, flush=True)
    if not failures:
        print(f"docs OK: {n_refs} path/symbol references across "
              f"{len(targets)} files all resolve", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
