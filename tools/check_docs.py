"""Docs reference checker (ISSUE 4): fail CI when a doc references a file
or symbol that no longer exists.

Scans ``docs/*.md`` and ``README.md`` for backtick-quoted references and
verifies two kinds:

* **paths** — tokens that look like file paths (contain a known extension,
  e.g. ``kernels/sketch_step.py`` or ``BENCH_device.json``).  Resolved
  against the repo root, ``src/``, and ``src/repro/`` (docs conventionally
  drop the ``src/repro/`` prefix for in-package files).  A trailing
  ``:<line>`` or anchor is stripped.
* **dotted symbols** — tokens starting with ``repro.`` (e.g.
  ``repro.core.device_simulate.simulate_trace``).  The longest module
  prefix must resolve to a ``.py`` file (or package ``__init__.py``) under
  ``src/``, and any remaining attribute must appear in that file as a
  ``def``/``class`` definition or assignment target (grep-based — simple
  on purpose; it catches renames and deletions, not signature drift).

Anything else inside backticks (shell commands, inline code, field names)
is ignored.  Keep doc references in one of the two checkable forms so this
gate keeps meaning something.

It additionally cross-checks the benchmark-snapshot field contract
(``check_bench_fields``): every field documented in the
"## ``BENCH_device.json`` fields" table of ``docs/BENCHMARKS.md`` and
every field literal the gate reads in ``benchmarks/check_bench.py``
(including f-string templates like ``policy_acc_per_s_{pol}``, matched as
wildcards) must exist in the committed ``BENCH_device.json`` — and every
snapshot field must be documented in the table.  A renamed bench field
now fails CI instead of silently un-gating an arm.

Usage: ``python tools/check_docs.py [--root REPO_ROOT]`` — exits 1 with a
list of stale references on failure.
"""
from __future__ import annotations

import argparse
import ast
import glob
import json
import os
import re
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_BACKTICK = re.compile(r"`([^`\n]+)`")
_PATHLIKE = re.compile(
    r"^[A-Za-z0-9_.{/\\-]*\.(py|md|json|yml|yaml|toml|txt)(:\d+)?(#[\w-]*)?$")
_DOTTED = re.compile(r"^repro(\.[A-Za-z_]\w*)+$")


def _iter_refs(text: str):
    for m in _BACKTICK.finditer(text):
        tok = m.group(1).strip()
        # strip decorations that commonly wrap a reference
        tok = tok.strip("*,;:()[]")
        if not tok or " " in tok or "*" in tok or "{" in tok:
            continue                      # commands, globs, templates
        yield tok


def _check_path(tok: str, root: str) -> bool:
    tok = tok.split("#")[0]
    tok = re.sub(r":\d+$", "", tok)
    for base in ("", "src", os.path.join("src", "repro")):
        if os.path.exists(os.path.join(root, base, tok)):
            return True
    return False


def _check_symbol(tok: str, root: str) -> bool:
    parts = tok.split(".")
    # longest module prefix that is a real file / package
    for cut in range(len(parts), 0, -1):
        mod = os.path.join(root, "src", *parts[:cut])
        for cand in (mod + ".py", os.path.join(mod, "__init__.py")):
            if os.path.exists(cand):
                rest = parts[cut:]
                if not rest:
                    return True
                # only the first attribute is greppable (module-level name)
                name = re.escape(rest[0])
                pat = re.compile(
                    rf"^\s*(def\s+{name}\b|class\s+{name}\b|{name}\s*[:=])",
                    re.M)
                with open(cand) as f:
                    return bool(pat.search(f.read()))
    return False


def check_file(path: str, root: str) -> list[str]:
    with open(path) as f:
        text = f.read()
    rel = os.path.relpath(path, root)
    stale = []
    for tok in _iter_refs(text):
        if _DOTTED.match(tok):
            if not _check_symbol(tok, root):
                stale.append(f"{rel}: stale symbol reference `{tok}`")
        elif _PATHLIKE.match(tok):
            if not _check_path(tok, root):
                stale.append(f"{rel}: stale path reference `{tok}`")
    return stale


# ---------------------------------------------------------------------------
# BENCH_device.json field contract
# ---------------------------------------------------------------------------

# a snapshot-field-shaped token: lowercase start, >= 1 underscore segment,
# no dots/dashes/spaces.  {} marks an f-string hole (wildcard).
_FIELDLIKE = re.compile(r"^[a-z][A-Za-z0-9]*(?:_[A-Za-z0-9{}]+)+$")
_FIELDS_HEADING = "fields"


def _doc_bench_fields(md_text: str) -> list[str]:
    """Field names from the "## `BENCH_device.json` fields" table."""
    fields, in_section = [], False
    for line in md_text.splitlines():
        if line.startswith("## "):
            in_section = ("BENCH_device.json" in line
                          and _FIELDS_HEADING in line)
            continue
        if in_section:
            m = re.match(r"\|\s*`([^`]+)`\s*\|", line)
            if m:
                fields.append(m.group(1))
    return fields


def _gate_bench_fields(py_text: str) -> list[str]:
    """Snapshot-field string literals read by check_bench.py: arguments
    of ``.get(...)`` calls and elements of tuple/list constants (the
    iterated key collections).  F-string holes become ``{}`` and are
    matched as wildcards — prose, argparse strings etc. never appear in
    those positions."""
    fields = set()

    def consider(node):
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            s = node.value
        elif isinstance(node, ast.JoinedStr):
            s = "".join(
                v.value if isinstance(v, ast.Constant) else "{}"
                for v in node.values
                if isinstance(v, (ast.Constant, ast.FormattedValue)))
        else:
            return
        if _FIELDLIKE.match(s):
            fields.add(s)

    for node in ast.walk(ast.parse(py_text)):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "get":
            for arg in node.args:
                consider(arg)
        elif isinstance(node, (ast.Tuple, ast.List)):
            for el in node.elts:
                consider(el)
    return sorted(fields)


def check_bench_fields(root: str) -> list[str]:
    """Cross-check docs/BENCHMARKS.md + benchmarks/check_bench.py against
    the committed BENCH_device.json.  Missing inputs are skipped quietly
    (a checkout without the snapshot still lints its docs)."""
    snap_path = os.path.join(root, "BENCH_device.json")
    md_path = os.path.join(root, "docs", "BENCHMARKS.md")
    gate_path = os.path.join(root, "benchmarks", "check_bench.py")
    if not (os.path.exists(snap_path) and os.path.exists(md_path)):
        return []
    with open(snap_path) as f:
        keys = set(json.load(f))
    failures = []

    with open(md_path) as f:
        documented = _doc_bench_fields(f.read())
    if not documented:
        failures.append("docs/BENCHMARKS.md: BENCH_device.json fields "
                        "table not found (heading or format changed?)")
    for field in documented:
        if field not in keys:
            failures.append(
                f"docs/BENCHMARKS.md: documented field `{field}` missing "
                "from the committed BENCH_device.json")
    for key in sorted(keys - set(documented)):
        failures.append(
            f"BENCH_device.json: field `{key}` undocumented in the "
            "docs/BENCHMARKS.md fields table")

    if os.path.exists(gate_path):
        with open(gate_path) as f:
            gate_fields = _gate_bench_fields(f.read())
        for field in gate_fields:
            if "{}" in field:
                pat = re.compile(
                    "^" + re.escape(field).replace(r"\{\}",
                                                   "[A-Za-z0-9_]+") + "$")
                if not any(pat.match(k) for k in keys):
                    failures.append(
                        f"benchmarks/check_bench.py: no snapshot field "
                        f"matches gate template `{field}`")
            elif field not in keys:
                failures.append(
                    f"benchmarks/check_bench.py: gate reads field "
                    f"`{field}` missing from BENCH_device.json")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=_REPO_ROOT)
    args = ap.parse_args(argv)

    targets = sorted(glob.glob(os.path.join(args.root, "docs", "*.md")))
    readme = os.path.join(args.root, "README.md")
    if os.path.exists(readme):
        targets.append(readme)
    if not targets:
        print("check_docs: nothing to check (no docs/*.md or README.md)")
        return 1

    failures = []
    n_refs = 0
    for path in targets:
        with open(path) as f:
            n_refs += sum(1 for t in _iter_refs(f.read())
                          if _DOTTED.match(t) or _PATHLIKE.match(t))
        failures.extend(check_file(path, args.root))
    bench_failures = check_bench_fields(args.root)
    failures.extend(bench_failures)
    for msg in failures:
        print("FAIL:", msg, flush=True)
    if not failures:
        print(f"docs OK: {n_refs} path/symbol references across "
              f"{len(targets)} files all resolve; bench field contract "
              "consistent", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
