"""Window-adaptation driver: run the device hill-climbed W-TinyLFU engine
against a trace, optionally next to the static-window sweep it must beat,
and record the per-epoch (quota, hits) trajectory.

This is the operational face of ISSUE 3's runtime-adaptive window sizing:
one command answers "what window does this workload want, and does the
climber find it?" — the whole simulation (epoch scan + climb + rebalance)
is a single compiled program per configuration.

  PYTHONPATH=src python -m repro.launch.hillclimb --trace phase \\
      --capacity 1000 --length 200000 --assoc 8 --static-sweep

Trajectory JSON lands in experiments/adaptive/<trace>_C<capacity>.json and
feeds ``python -m repro.analysis.report --what adaptive``.
"""
from __future__ import annotations

import argparse
import json
import os
from dataclasses import asdict

import numpy as np

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../..",
                       "experiments", "adaptive")

STATIC_WFS = (0.01, 0.05, 0.10, 0.20, 0.40)


def make_trace(name: str, length: int, seed: int) -> np.ndarray:
    from repro import traces as T
    gens = {
        "zipf": lambda: T.zipf_trace(length, n_items=max(1000, length // 4),
                                     alpha=0.9, seed=seed),
        "fickle": lambda: T.fickle_churn_trace(length, seed=seed),
        "phase": lambda: T.phase_shift_trace(length, seed=seed),
        "youtube": lambda: T.youtube_dynamic_trace(length, seed=seed),
        "wiki": lambda: T.wiki_drift_trace(length, seed=seed),
        "oltp": lambda: T.oltp_like_trace(length, seed=seed),
        "spc1": lambda: T.spc1_like_trace(length, seed=seed),
        "glimpse": lambda: T.glimpse_trace(length, seed=seed),
    }
    if name not in gens:
        raise SystemExit(f"unknown trace {name!r}; one of {sorted(gens)}")
    return gens[name]()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="phase",
                    help="zipf|fickle|phase|youtube|wiki|oltp|spc1|glimpse")
    ap.add_argument("--capacity", type=int, default=1000)
    ap.add_argument("--length", type=int, default=200_000)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--assoc", type=int, default=8,
                    help="ways per set; 0 = exact flat tables")
    ap.add_argument("--epoch-len", type=int, default=4096)
    ap.add_argument("--window-frac", type=float, default=0.01,
                    help="initial window quota")
    ap.add_argument("--static-sweep", action="store_true",
                    help="also run the static windows the climber must beat")
    ap.add_argument("--out", default=None, help="output JSON path")
    args = ap.parse_args()

    from repro.core.device_simulate import (simulate_trace, simulate_sweep,
                                            ClimbSpec)

    tr = make_trace(args.trace, args.length, args.seed)
    assoc = args.assoc or None
    climb = ClimbSpec(epoch_len=args.epoch_len)
    rows = []

    a = simulate_trace(tr, args.capacity, adaptive=True, assoc=assoc,
                       window_frac=args.window_frac, climb=climb,
                       trace_name=args.trace)
    print(f"adaptive: hit {a.hit_ratio:.4f}  final quota "
          f"{a.extra['final_quota']} "
          f"({a.extra['final_quota'] / args.capacity:.1%} of C)", flush=True)
    tj = a.extra.get("trajectory")
    if tj is None:
        print(f"  (trace shorter than one epoch of {args.epoch_len} — "
              "no climb ran; lower --epoch-len)", flush=True)
    else:
        E = tj["epoch_len"]
        print("  epoch  quota  hit-rate")
        for i, (q, e) in enumerate(zip(tj["quota"], tj["epoch_hits"])):
            print(f"  {i:5d}  {q:5d}  {e / E:.3f}")
    rows.append(asdict(a))

    if args.static_sweep:
        stat = simulate_sweep(tr, [args.capacity], window_fracs=STATIC_WFS,
                              mode="sequential", assoc=assoc,
                              trace_name=args.trace)
        best = max(r.hit_ratio for r in stat)
        for r in stat:
            print(f"static wf={r.extra['window_frac']:.2f}: "
                  f"hit {r.hit_ratio:.4f}", flush=True)
            rows.append(asdict(r))
        print(f"best static {best:.4f} vs adaptive {a.hit_ratio:.4f} "
              f"({a.hit_ratio - best:+.4f})", flush=True)

    out = args.out or os.path.join(
        OUT_DIR, f"{args.trace}_C{args.capacity}.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print("wrote", os.path.normpath(out), flush=True)


if __name__ == "__main__":
    main()
