import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: run tagged optimization iterations on the three
chosen cells and print before/after roofline terms + byte breakdowns.

  PYTHONPATH=src python -m repro.launch.hillclimb --iter 1
"""
import argparse
import json

from repro.launch.dryrun import run_cell

CELLS = [
    # (arch, shape, why chosen)
    ("zamba2-1.2b", "prefill_32k", "worst roofline fraction (0.0022)"),
    ("llama4-maverick-400b-a17b", "train_4k",
     "most collective-bound (t_coll 19.9s)"),
    ("qwen3-4b", "decode_32k", "paper-representative serve_step"),
]

# iteration -> per-cell cfg overrides (None = skip cell this iteration)
ITERS = {
    # it1: buffer donation (in-place cache/state) + bf16 param gathers
    # (cast-before-gather). Code-level changes; cfg stays default.
    1: {c[0] + "/" + c[1]: {} for c in CELLS},
    # it2: per-cell targeted knobs
    2: {
        "zamba2-1.2b/prefill_32k": {"ssm_chunk": 128},
        "llama4-maverick-400b-a17b/train_4k": {
            "causal_skip": True, "attn_scores_bf16": True},
        "qwen3-4b/decode_32k": None,      # breakdown-driven; see it3
    },
    3: {
        "zamba2-1.2b/prefill_32k": {"ssm_chunk": 64},
        "llama4-maverick-400b-a17b/train_4k": None,
        "qwen3-4b/decode_32k": None,
    },
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iter", type=int, required=True)
    ap.add_argument("--cell", type=str, default=None)
    ap.add_argument("--override", type=str, default=None,
                    help="JSON cfg overrides (ad-hoc iteration)")
    ap.add_argument("--policy", type=str, default=None,
                    help="JSON ShardingPolicy overrides")
    args = ap.parse_args()

    pol = json.loads(args.policy) if args.policy else None
    for arch, shape, why in CELLS:
        key = f"{arch}/{shape}"
        if args.cell and args.cell != key:
            continue
        ov = (json.loads(args.override) if args.override
              else ITERS.get(args.iter, {}).get(key))
        if ov is None:
            continue
        tag = f"_it{args.iter}"
        r = run_cell(arch, shape, multi_pod=False, cfg_overrides=ov,
                     policy_overrides=pol, tag=tag)
        if r["status"] == "ok":
            bb = r.get("bytes_by_kind", {})
            top = sorted(bb.items(), key=lambda x: -x[1])[:4]
            print("  bytes_by_kind:",
                  {k: f"{v:.2e}" for k, v in top}, flush=True)


if __name__ == "__main__":
    main()
