import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
against the production meshes (16x16 single pod, 2x16x16 two pods) with 512
placeholder host devices, print memory/cost analysis, and emit the roofline
terms (analysis/roofline.py) to experiments/dryrun/*.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import build_model, SHAPES, is_subquadratic
from repro.models.common import param_count
from repro.optim import make_optimizer, wsd
from repro.train import make_train_state, build_train_step
from repro.launch.mesh import make_production_mesh
from repro.distributed.shardings import ShardingPolicy
from repro.analysis.roofline import Roofline, SimpleColl, model_flops
from repro.analysis.hlo_cost import analyze_hlo

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../..",
                       "experiments", "dryrun")

# archs where AdamW's fp32 m+v cannot fit a single pod (DESIGN.md §4)
ADAFACTOR_ARCHS = {"llama4-maverick-400b-a17b"}

# loss chunking keeps fp32 logits bounded; larger vocab -> smaller chunk
def _loss_chunk(cfg):
    return 128 if cfg.vocab_size >= 100_000 else 256


def should_skip(arch_cfg, shape_kind: str) -> str | None:
    if shape_kind == "long_500k" and not is_subquadratic(arch_cfg):
        return ("pure full-attention arch: long_500k requires sub-quadratic "
                "attention (DESIGN.md §5)")
    return None


def build_cell(model, shape_kind: str, policy: ShardingPolicy):
    """Returns (fn, args, in_shardings, tokens_for_model_flops, kind)."""
    cfg = model.cfg
    sh = SHAPES[shape_kind]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    specs = model.input_specs(shape_kind)

    if kind == "train":
        opt = make_optimizer(
            "adafactor" if cfg.name in ADAFACTOR_ARCHS else "adamw",
            wsd(3e-4, 2000, 100_000, 20_000))
        state_shapes = jax.eval_shape(
            lambda k: make_train_state(model, opt, k),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        step = build_train_step(model, opt, policy=policy,
                                loss_chunk=_loss_chunk(cfg))
        batch = {k: v for k, v in specs.items()}
        in_sh = (policy.shardings(state_shapes), policy.batch_specs(batch))
        return step, (state_shapes, batch), in_sh, B * S, kind

    params_shapes = jax.eval_shape(
        lambda k: model.init(k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    param_sh = policy.shardings(params_shapes)

    if kind == "prefill":
        S_cache = S + (cfg.n_vis_tokens or 0)
        cache_shapes = jax.eval_shape(lambda: model.init_cache(B, S_cache))
        cache_sh = policy.cache_specs(cache_shapes, B)
        batch = dict(specs)

        def prefill_step(params, batch, cache):
            cache, last_h = model.prefill(params, batch, cache,
                                          policy=policy)
            return cache, model.lm_head(params, last_h, policy=policy)

        in_sh = (param_sh, policy.batch_specs(batch), cache_sh)
        return prefill_step, (params_shapes, batch, cache_shapes), in_sh, \
            B * S, kind

    # decode: one new token against a seq_len-deep cache
    cache_shapes = specs["cache"]
    cache_sh = policy.cache_specs(cache_shapes, B)
    tokens = specs["tokens"]

    def serve_step(params, tokens, cache):
        return model.decode(params, tokens, cache, policy=policy)

    in_sh = (param_sh, policy.batch_specs({"t": tokens})["t"], cache_sh)
    return serve_step, (params_shapes, tokens, cache_shapes), in_sh, B, kind


def run_cell(arch: str, shape_kind: str, multi_pod: bool,
             policy_overrides: dict | None = None,
             cfg_overrides: dict | None = None, tag: str = "",
             save: bool = True, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    t0 = time.time()
    skip = should_skip(cfg, shape_kind)
    mesh_name = "multi" if multi_pod else "single"
    result = {"arch": cfg.name, "shape": shape_kind, "mesh": mesh_name,
              "status": "skip", "reason": skip, "tag": tag,
              "cfg_overrides": {k: str(v) for k, v in
                                (cfg_overrides or {}).items()}}
    if skip:
        if verbose:
            print(f"[dryrun] {cfg.name} x {shape_kind} x {mesh_name}: "
                  f"SKIP ({skip})", flush=True)
        if save:
            _save(result)
        return result

    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    policy = ShardingPolicy(mesh, **(policy_overrides or {}))
    fn, args, in_sh, tokens, kind = build_cell(model, shape_kind, policy)

    donate = {"train": (0,), "prefill": (2,), "decode": (2,)}[kind]
    jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "arg_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:                                    # pragma: no cover
        mem_d = {"error": str(e)}

    # trip-count-aware cost over the partitioned (per-device) module;
    # XLA's cost_analysis counts while bodies once (kept raw for reference)
    hlo = compiled.as_text()
    try:
        import gzip
        hlo_dir = os.path.join(OUT_DIR, "..", "hlo")
        os.makedirs(hlo_dir, exist_ok=True)
        tag_ = tag or ""
        with gzip.open(os.path.join(
                hlo_dir, f"{cfg.name}_{shape_kind}_{mesh_name}{tag_}.hlo.gz"),
                "wt") as f:
            f.write(hlo)
    except Exception:
        pass
    hc = analyze_hlo(hlo)
    coll = SimpleColl(counts=dict(hc.coll_counts),
                      out_bytes=dict(hc.coll_bytes),
                      wire_bytes=hc.coll_wire_bytes)

    params_shapes = jax.eval_shape(
        lambda k: model.init(k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    n_params = param_count(params_shapes)

    rl = Roofline(chips=chips, hlo_flops=hc.flops * chips,
                  hlo_bytes=hc.bytes * chips, coll=coll,
                  model_flops=model_flops(cfg, n_params, tokens, kind))

    result.update({
        "status": "ok", "reason": None,
        "chips": chips, "kind": kind, "n_params": n_params,
        "compile_s": round(t_compile, 1),
        "xla_cost_flops_loop_once": float(cost.get("flops", 0.0)),
        "hlo_flops_per_device": hc.flops,
        "hlo_bytes_per_device": hc.bytes,
        "hlo_warnings": hc.warnings[:10],
        "bytes_by_kind": {k: v for k, v in hc.bytes_by_kind.items()},
        "top_collectives": dict(sorted(hc.coll_ops.items(),
                                       key=lambda x: -x[1])[:12]),
        "top_fusions": dict(sorted(hc.fusion_ops.items(),
                                   key=lambda x: -x[1])[:12]),
        "memory": mem_d,
        "roofline": rl.as_dict(),
    })
    if verbose:
        r = rl.as_dict()
        print(f"[dryrun] {cfg.name} x {shape_kind} x {mesh_name}: OK "
              f"compile={t_compile:.0f}s "
              f"tc={r['t_compute_s']:.4f} tm={r['t_memory_s']:.4f} "
              f"tcoll={r['t_collective_s']:.4f} "
              f"bound={r['bottleneck']} frac={r['roofline_frac']:.3f} "
              f"useful={r['useful_flops_frac']:.2f}", flush=True)
    if save:
        _save(result)
    return result


def _save(result: dict) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    tag = result.get("tag") or ""
    name = f"{result['arch']}_{result['shape']}_{result['mesh']}{tag}.json"
    with open(os.path.join(OUT_DIR, name), "w") as f:
        json.dump(result, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", type=str, default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    overrides = {"fsdp": not args.no_fsdp}

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, mp, policy_overrides=overrides)
                except Exception as e:
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[dryrun] {arch} x {shape} x "
                          f"{'multi' if mp else 'single'}: FAIL {e}",
                          flush=True)
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: "
                         + "; ".join(f"{a}/{s}" for a, s, _, _ in failures))
    print("[dryrun] all requested cells passed")


if __name__ == "__main__":
    main()
