"""Prefix cache with TinyLFU admission — the paper's technique as a serving
feature.

The cache maps *chained block hashes* (content-defined keys over token blocks,
vLLM/SGLang-style) to payload slots holding either KV blocks (attention
families) or recurrent-state snapshots (SSM families).  Retention is governed
by exactly the paper's architecture (Fig 1 / Fig 5):

  * eviction policy over cached blocks: LRU, or SLRU+window (W-TinyLFU),
  * admission policy: TinyLFU frequency sketch (host sketch by default, the
    Pallas DeviceTinyLFU on TPU) — a candidate block displaces the eviction
    victim only if its recent access frequency is higher.

Every lookup records the touched block hashes into the sketch in one batch
(the batched-tick adaptation of the paper's per-access Add, DESIGN.md §2).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.sketch import default_sketch
from repro.core.policies import SLRUEviction, LRUEviction
from repro.kernels.ops import DeviceTinyLFU

_MASK64 = (1 << 64) - 1
_GAMMA = 0x9E3779B97F4A7C15


def _mix(x: int) -> int:
    x = (x + _GAMMA) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return x ^ (x >> 31)


def block_hashes(tokens, block_size: int) -> list[int]:
    """Chained content hashes: block i's key depends on blocks 0..i."""
    out = []
    h = 0x51CE_B00C
    n_full = len(tokens) // block_size
    for b in range(n_full):
        for t in tokens[b * block_size:(b + 1) * block_size]:
            h = _mix(h ^ _mix(int(t)))
        out.append(h)
    return out


# ---------------------------------------------------------------------------
# payload pool: device-array slots for KV blocks / state snapshots
# ---------------------------------------------------------------------------

class PayloadPool:
    """Fixed-slot pool over an arbitrary pytree template.  store/load/free.
    On TPU the leaves live in HBM and store/load are gather/scatter DMAs."""

    def __init__(self, template, n_slots: int):
        self.n_slots = n_slots
        self.pool = jax.tree_util.tree_map(
            lambda a: jnp.zeros((n_slots,) + a.shape, a.dtype), template)
        self.free_list = list(range(n_slots))

    def store(self, tree) -> Optional[int]:
        if not self.free_list:
            return None
        slot = self.free_list.pop()
        self.pool = jax.tree_util.tree_map(
            lambda pool, a: pool.at[slot].set(a), self.pool, tree)
        return slot

    def load(self, slot: int):
        return jax.tree_util.tree_map(lambda pool: pool[slot], self.pool)

    def load_many(self, slots: list[int]):
        idx = jnp.asarray(slots, jnp.int32)
        return jax.tree_util.tree_map(lambda pool: pool[idx], self.pool)

    def free(self, slot: int) -> None:
        self.free_list.append(slot)

    @property
    def used(self) -> int:
        return self.n_slots - len(self.free_list)


# ---------------------------------------------------------------------------
# admission backends
# ---------------------------------------------------------------------------

class HostAdmission:
    def __init__(self, capacity: int, sample_factor: int = 8, seed: int = 0):
        self.sketch = default_sketch(capacity, sample_factor=sample_factor,
                                     seed=seed)

    def record_batch(self, keys) -> None:
        for k in keys:
            self.sketch.add(int(k) & _MASK64)

    def admit(self, cand: int, victim: int) -> bool:
        return (self.sketch.estimate(int(cand) & _MASK64)
                > self.sketch.estimate(int(victim) & _MASK64))


class DeviceAdmission:
    """Batched admission through the Pallas kernels."""

    def __init__(self, capacity: int, sample_factor: int = 8,
                 use_pallas: bool = True):
        self.t = DeviceTinyLFU(capacity, sample_factor=sample_factor,
                               use_pallas=use_pallas)

    def record_batch(self, keys) -> None:
        if len(keys):
            self.t.record(np.asarray(keys, np.uint64))

    def admit(self, cand: int, victim: int) -> bool:
        return bool(self.t.admit(np.asarray([cand], np.uint64),
                                 np.asarray([victim], np.uint64))[0])


# ---------------------------------------------------------------------------
# the cache itself
# ---------------------------------------------------------------------------

@dataclass
class PrefixCacheStats:
    lookups: int = 0
    block_hits: int = 0
    block_misses: int = 0
    inserts: int = 0
    admitted: int = 0
    rejected: int = 0
    evicted: int = 0

    @property
    def hit_ratio(self) -> float:
        n = self.block_hits + self.block_misses
        return self.block_hits / n if n else 0.0


class PrefixCache:
    """hash -> payload-slot cache with pluggable retention policy.

    policy: "lru" (no admission), "tinylfu" (LRU eviction + admission),
    "wtinylfu" (1% LRU window + SLRU main + admission).
    """

    def __init__(self, capacity: int, policy: str = "wtinylfu",
                 sample_factor: int = 8, window_frac: float = 0.01,
                 device_sketch: bool = False, seed: int = 0):
        assert policy in ("lru", "tinylfu", "wtinylfu")
        self.policy = policy
        self.capacity = capacity
        self.slots: dict[int, int] = {}           # hash -> payload slot
        self.stats = PrefixCacheStats()
        self.admission = None
        if policy != "lru":
            self.admission = (DeviceAdmission(capacity, sample_factor)
                              if device_sketch else
                              HostAdmission(capacity, sample_factor, seed))
        if policy == "wtinylfu":
            self.window_cap = max(1, int(round(capacity * window_frac)))
            self.main_cap = capacity - self.window_cap
            self.window: OrderedDict = OrderedDict()
            self.main = SLRUEviction(self.main_cap)
        else:
            self.main = LRUEviction(capacity)

    # -- helpers ---------------------------------------------------------------
    def __contains__(self, h):
        if self.policy == "wtinylfu" and h in self.window:
            return True
        return h in self.main

    def __len__(self):
        n = len(self.main)
        if self.policy == "wtinylfu":
            n += len(self.window)
        return n

    def _touch(self, h):
        if self.policy == "wtinylfu" and h in self.window:
            self.window.move_to_end(h)
        else:
            self.main.on_hit(h)

    # -- api ---------------------------------------------------------------------
    def lookup(self, hashes: list[int]) -> list[int]:
        """Longest cached prefix: returns payload slots for the leading run of
        hits.  Records ALL requested hashes in the sketch (they were accessed,
        whether or not they hit — the paper's frequency stream)."""
        self.stats.lookups += 1
        if self.admission is not None:
            self.admission.record_batch(hashes)
        out = []
        for h in hashes:
            if h in self:
                self._touch(h)
                out.append(self.slots[h])
            else:
                break
        self.stats.block_hits += len(out)
        self.stats.block_misses += len(hashes) - len(out)
        return out

    def lookup_snapshots(self, hashes: list[int], every: int) -> tuple[int, Optional[int]]:
        """SSM-family lookup: snapshots exist only at block indices
        every-1, 2*every-1, ...  Returns (n_blocks_covered, payload_slot) for
        the DEEPEST cached snapshot (or (0, None)).  Records all hashes."""
        self.stats.lookups += 1
        if self.admission is not None:
            self.admission.record_batch(hashes)
        best = (0, None)
        boundaries = list(range(every - 1, len(hashes), every))
        for i in boundaries:
            h = hashes[i]
            if h in self:
                self._touch(h)
                best = (i + 1, self.slots[h])
        hits = best[0] // every
        self.stats.block_hits += hits
        self.stats.block_misses += len(boundaries) - hits
        return best

    def insert(self, h: int, slot: int) -> list[int]:
        """Offer one block.  Returns payload slots freed by eviction/rejection
        (caller returns them to the pool).  The offered slot itself is freed
        (returned) if the block is rejected or already cached."""
        self.stats.inserts += 1
        if h in self:
            return [slot]
        freed: list[int] = []
        if self.policy == "wtinylfu":
            self.window[h] = None
            self.slots[h] = slot
            if len(self.window) <= self.window_cap:
                return freed
            cand, _ = self.window.popitem(last=False)
            freed += self._offer_main(cand)
            return freed
        return self._offer_main_direct(h, slot)

    def _offer_main(self, cand: int) -> list[int]:
        """W-TinyLFU window victim asks for main admission."""
        freed = []
        if len(self.main) < self.main.capacity:
            self.main.add(cand)
            return freed
        victim = self.main.peek_victim()
        if self.admission is None or self.admission.admit(cand, victim):
            self.stats.admitted += 1
            self.main.remove(victim)
            freed.append(self.slots.pop(victim))
            self.stats.evicted += 1
            self.main.add(cand)
        else:
            self.stats.rejected += 1
            freed.append(self.slots.pop(cand))
        return freed

    def _offer_main_direct(self, h: int, slot: int) -> list[int]:
        freed = []
        if len(self.main) < self.main.capacity:
            self.main.add(h)
            self.slots[h] = slot
            return freed
        victim = self.main.peek_victim()
        if self.admission is None or self.admission.admit(h, victim):
            self.stats.admitted += 1
            self.main.remove(victim)
            freed.append(self.slots.pop(victim))
            self.stats.evicted += 1
            self.main.add(h)
            self.slots[h] = slot
        else:
            self.stats.rejected += 1
            freed.append(slot)
        return freed
