from .prefix_cache import PrefixCache, PayloadPool, block_hashes, \
    PrefixCacheStats
from .engine import ServeEngine, Request
from .extend import extend

__all__ = ["PrefixCache", "PayloadPool", "block_hashes", "PrefixCacheStats",
           "ServeEngine", "Request", "extend"]
