"""Continue-prefill ("extend"): run a token segment on top of an existing
cache — the primitive behind prefix-cache reuse.  A prefix hit restores KV
blocks (attention families) or a state snapshot (SSM families) and the engine
extends only the un-cached suffix, saving the corresponding prefill FLOPs.

``start`` is a static python int (the engine works at block granularity, so
the trace count is bounded by max_len / block_size).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, NULL_POLICY
from repro.models.layers import rmsnorm, flash_attention
from repro.models import transformer as T
from repro.models import zamba as Z
from repro.models import xlstm as X
from repro.models.mamba2 import mamba2_forward
from repro.models.mlstm import mlstm_forward, slstm_forward


def _attn_extend(p, x, cfg: ModelConfig, start: int, k_cache, v_cache,
                 policy):
    """x (B,S,M); caches (B,Smax,Hkv,hd) valid to ``start``.  Returns
    (x_out, k_cache, v_cache) with the new segment written at [start:]."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(start + jnp.arange(S), (B, S))
    q, k, v = T._qkv(p, x, cfg, positions, policy)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, start, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, start, 0, 0))
    kv = T.repeat_kv(k_cache[:, :start + S].astype(x.dtype), cfg.q_groups)
    vv = T.repeat_kv(v_cache[:, :start + S].astype(x.dtype), cfg.q_groups)
    o = flash_attention(q, kv, vv, causal=True, q_block=cfg.q_block,
                        kv_block=cfg.kv_block, q_offset=start,
                        softcap=cfg.attn_logit_softcap, policy=policy)
    o = o.reshape(B, S, -1) @ p["wo"].astype(x.dtype)
    return x + o * cfg.residual_scale, k_cache, v_cache


# ---------------------------------------------------------------------------
# transformer family
# ---------------------------------------------------------------------------

def transformer_extend(params, tokens, cfg: ModelConfig, cache: dict,
                       start: int, *, vision_embeds=None,
                       policy=NULL_POLICY):
    x = T.embed_tokens(params, tokens, cfg,
                       vision_embeds if start == 0 else None)
    B, S, _ = x.shape
    n_attn = cfg.moe_every if cfg.n_experts else 1
    n_super = cfg.n_layers // n_attn
    kc = cache["k"].reshape(n_super, n_attn, *cache["k"].shape[1:])
    vc = cache["v"].reshape(n_super, n_attn, *cache["v"].shape[1:])

    def superblock(x, inp):
        block, k_l, v_l = inp
        nk, nv = [], []
        for j in range(n_attn):
            x, k_new, v_new = _attn_extend(block[f"attn{j}"], x, cfg, start,
                                           k_l[j], v_l[j], policy)
            x, _ = T.ffn_or_moe(block, j, x, cfg, None, policy)
            nk.append(k_new)
            nv.append(v_new)
        return x, (jnp.stack(nk), jnp.stack(nv))

    x, (nk, nv) = jax.lax.scan(superblock, x, (params["layers"], kc, vc))
    cache = dict(cache)
    cache["k"] = nk.reshape(cache["k"].shape)
    cache["v"] = nv.reshape(cache["v"].shape)
    cache["pos"] = jnp.full((B,), start + S, jnp.int32)
    return cache, x[:, -1:]


# ---------------------------------------------------------------------------
# zamba (hybrid): mamba initial states + shared-attn KV
# ---------------------------------------------------------------------------

def zamba_extend(params, tokens, cfg: ModelConfig, cache: dict, start: int,
                 *, vision_embeds=None, policy=NULL_POLICY):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    B, S, _ = x.shape
    groups, tail = Z._split(cfg)
    new_m, new_k, new_v = [], [], []

    def mamba_seq(x, stacked, offset, n):
        for i in range(n):
            p = jax.tree_util.tree_map(lambda a: a[i], stacked)
            st = jax.tree_util.tree_map(lambda a: a[offset + i],
                                        cache["mamba"])
            h = rmsnorm(x, p["norm"], cfg.norm_eps)
            out, fin = mamba2_forward(p["mamba"], h, cfg, initial_state=st,
                                      policy=policy)
            x = x + out
            new_m.append(fin)
        return x

    off = 0
    for g in range(groups):
        gp = jax.tree_util.tree_map(lambda a: a[g], params["groups"])
        x = mamba_seq(x, gp, off, cfg.attn_every)
        off += cfg.attn_every
        x, k_new, v_new = _attn_extend(params["shared_attn"], x, cfg, start,
                                       cache["k"][g], cache["v"][g], policy)
        x = Z.mlp_block(params["shared_mlp"], x, cfg, policy)
        new_k.append(k_new)
        new_v.append(v_new)
    if tail:
        x = mamba_seq(x, params["tail"], off, tail)

    cache = dict(cache)
    from repro.models.common import stack_layer_params
    cache["mamba"] = stack_layer_params(new_m)
    cache["k"] = jnp.stack(new_k)
    cache["v"] = jnp.stack(new_v)
    cache["pos"] = jnp.full((B,), start + S, jnp.int32)
    return cache, x[:, -1:]


# ---------------------------------------------------------------------------
# xlstm: pure state continuation
# ---------------------------------------------------------------------------

def xlstm_extend(params, tokens, cfg: ModelConfig, cache: dict, start: int,
                 *, vision_embeds=None, policy=NULL_POLICY):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    B, S, _ = x.shape
    n_super, n_ml = X._split(cfg)
    new_ml, new_sl = [], []
    for s in range(n_super):
        blk = jax.tree_util.tree_map(lambda a: a[s], params["supers"])
        row = []
        for i in range(n_ml):
            p = jax.tree_util.tree_map(lambda a: a[i], blk["mlstm"])
            h = rmsnorm(x, p["norm"], cfg.norm_eps)
            out, st = mlstm_forward(p["p"], h, cfg,
                                    initial_state=cache["mlstm"][s, i],
                                    policy=policy)
            x = x + out
            row.append(st)
        new_ml.append(jnp.stack(row))
        sl_st = jax.tree_util.tree_map(lambda a: a[s], cache["slstm"])
        h = rmsnorm(x, blk["slstm"]["norm"], cfg.norm_eps)
        out, sl_st = slstm_forward(blk["slstm"]["p"], h, cfg,
                                   initial_state=sl_st, policy=policy)
        x = x + out
        new_sl.append(sl_st)
    from repro.models.common import stack_layer_params
    cache = dict(cache)
    cache["mlstm"] = jnp.stack(new_ml)
    cache["slstm"] = stack_layer_params(new_sl)
    cache["pos"] = cache["pos"] * 0 + (start + S)
    return cache, x[:, -1:]


def extend(model, params, tokens, cache, start: int, *, vision_embeds=None,
           policy=NULL_POLICY):
    cfg = model.cfg
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        return transformer_extend(params, tokens, cfg, cache, start,
                                  vision_embeds=vision_embeds, policy=policy)
    if cfg.family == "hybrid_ssm":
        return zamba_extend(params, tokens, cfg, cache, start,
                            policy=policy)
    if cfg.family == "xlstm":
        return xlstm_extend(params, tokens, cfg, cache, start, policy=policy)
    raise ValueError(cfg.family)
