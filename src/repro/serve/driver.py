"""Serving driver: spin up a ServeEngine for an arch (smoke config on CPU;
full config on a real slice) and replay a multi-tenant workload, reporting
prefix-cache hit-ratio / reuse / admission stats per retention policy.

  PYTHONPATH=src python -m repro.serve.driver --arch qwen3-4b \
      --requests 40 --policy wtinylfu
"""
from __future__ import annotations

import argparse
import json

import numpy as np
import jax

from repro.configs import get_config
from repro.models import build_model
from .engine import ServeEngine


def make_workload(cfg, n_requests: int, n_tenants: int = 12,
                  prefix_len: int = 24, suffix_len: int = 9, seed: int = 0):
    """Zipf-popular tenants sharing per-tenant prompt prefixes."""
    rng = np.random.default_rng(seed)
    prefixes = [list(rng.integers(0, cfg.vocab_size, prefix_len))
                for _ in range(n_tenants)]
    ranks = np.arange(1, n_tenants + 1, dtype=np.float64) ** -1.0
    p = ranks / ranks.sum()
    out = []
    for _ in range(n_requests):
        t = rng.choice(n_tenants, p=p)
        out.append(prefixes[t] + list(rng.integers(0, cfg.vocab_size,
                                                   suffix_len)))
    return out


def serve(arch: str, *, smoke: bool = True, n_requests: int = 40,
          policy: str = "wtinylfu", max_new_tokens: int = 4,
          pool_slots: int = 48, device_sketch: bool = False,
          seed: int = 0) -> dict:
    cfg = get_config(arch, smoke=smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    eng = ServeEngine(model, params, max_batch=4, max_len=128, block_size=8,
                      pool_slots=pool_slots, prefix_policy=policy,
                      device_sketch=device_sketch, seed=seed)
    for prompt in make_workload(cfg, n_requests, seed=seed):
        eng.submit(prompt, max_new_tokens)
    results = eng.run()
    stats = dict(eng.stats)
    stats["completed"] = len(results)
    return stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--policy", default="wtinylfu",
                    choices=["lru", "tinylfu", "wtinylfu"])
    ap.add_argument("--device-sketch", action="store_true")
    args = ap.parse_args()
    out = serve(args.arch, n_requests=args.requests, policy=args.policy,
                device_sketch=args.device_sketch)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
