"""Continuous-batching serving engine with TinyLFU-guarded prefix caching.

Architecture (host control plane, device data plane — the standard TPU
serving split):

  * per-request prefill at block granularity: block hashes -> PrefixCache
    lookup -> payload slots gathered from the PayloadPool into the request's
    batch slot -> ``extend`` runs only the uncached suffix;
  * batched decode over all active slots (one serve_step per tick);
  * attention families offer each completed KV block to the prefix cache;
    SSM families capture state snapshots at snapshot boundaries during
    prefill — TinyLFU admission decides which blocks are worth the HBM
    (paper Fig 1), with W-TinyLFU's window absorbing bursty one-off prefixes
    (paper §4);
  * greedy sampling for determinism.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.api import Model
from .extend import extend
from .prefix_cache import PrefixCache, PayloadPool, block_hashes


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    out_tokens: list = field(default_factory=list)
    slot: int = -1
    prefix_blocks_reused: int = 0
    done: bool = False


def _is_attn_family(cfg) -> bool:
    return cfg.family in ("dense", "moe", "vlm", "audio")


class ServeEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 4,
                 max_len: int = 256, block_size: int = 16,
                 pool_slots: int = 64, prefix_policy: str = "wtinylfu",
                 sample_factor: int = 8, device_sketch: bool = False,
                 snapshot_every: int = 2, seed: int = 0):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.block_size = block_size
        self.snapshot_every = snapshot_every          # blocks per snapshot
        self.cache = model.init_cache(max_batch, max_len)
        self.prefix_cache = PrefixCache(pool_slots, policy=prefix_policy,
                                        sample_factor=sample_factor,
                                        device_sketch=device_sketch,
                                        seed=seed)
        self.pool = PayloadPool(self._payload_template(), pool_slots)
        self.free_slots = list(range(max_batch))
        self.active: dict[int, Request] = {}
        self.queue: list[Request] = []
        self._next_rid = 0
        self._decode_fn = jax.jit(lambda p, t, c: model.decode(p, t, c))
        self.tokens_prefilled = 0
        self.tokens_reused = 0

    # ------------------------------------------------------------------ payload
    def _payload_template(self):
        cfg = self.cfg
        if _is_attn_family(cfg):
            shp = (cfg.n_layers, self.block_size, cfg.n_kv_heads, cfg.hd)
            return {"k": jnp.zeros(shp, jnp.bfloat16),
                    "v": jnp.zeros(shp, jnp.bfloat16)}
        one = self.model.init_cache(1, self.max_len)
        return self._state_snapshot_of(one, 0)

    def _state_snapshot_of(self, cache, b: int):
        """State snapshot payload for batch slot b (SSM families)."""
        cfg = self.cfg
        if cfg.family == "hybrid_ssm":
            return {
                "mamba": jax.tree_util.tree_map(lambda a: a[:, b],
                                                cache["mamba"]),
                "k": cache["k"][:, b], "v": cache["v"][:, b],
            }
        if cfg.family == "xlstm":
            return {
                "mlstm": cache["mlstm"][:, :, b],
                "slstm": jax.tree_util.tree_map(lambda a: a[:, b],
                                                cache["slstm"]),
            }
        raise ValueError(cfg.family)

    def _restore_snapshot(self, b: int, state) -> None:
        cfg = self.cfg
        c = self.cache
        if cfg.family == "hybrid_ssm":
            c["mamba"] = jax.tree_util.tree_map(
                lambda full, s: full.at[:, b].set(s), c["mamba"],
                state["mamba"])
            c["k"] = c["k"].at[:, b].set(state["k"])
            c["v"] = c["v"].at[:, b].set(state["v"])
        else:
            c["mlstm"] = c["mlstm"].at[:, :, b].set(state["mlstm"])
            c["slstm"] = jax.tree_util.tree_map(
                lambda full, s: full.at[:, b].set(s), c["slstm"],
                state["slstm"])

    # ------------------------------------------------------------------ plumbing
    def _extract(self, b: int):
        """Batch slot -> batch-1 cache pytree (copy)."""
        cfg = self.cfg
        c = self.cache
        if _is_attn_family(cfg):
            return {"k": c["k"][:, b:b + 1], "v": c["v"][:, b:b + 1],
                    "pos": c["pos"][b:b + 1]}
        if cfg.family == "hybrid_ssm":
            return {"mamba": jax.tree_util.tree_map(lambda a: a[:, b:b + 1],
                                                    c["mamba"]),
                    "k": c["k"][:, b:b + 1], "v": c["v"][:, b:b + 1],
                    "pos": c["pos"][b:b + 1]}
        return {"mlstm": c["mlstm"][:, :, b:b + 1],
                "slstm": jax.tree_util.tree_map(lambda a: a[:, b:b + 1],
                                                c["slstm"]),
                "pos": c["pos"][b:b + 1]}

    def _writeback(self, b: int, sub) -> None:
        cfg = self.cfg
        c = self.cache
        if _is_attn_family(cfg) or cfg.family == "hybrid_ssm":
            c["k"] = c["k"].at[:, b:b + 1].set(sub["k"])
            c["v"] = c["v"].at[:, b:b + 1].set(sub["v"])
        if cfg.family == "hybrid_ssm":
            c["mamba"] = jax.tree_util.tree_map(
                lambda full, s: full.at[:, b:b + 1].set(s), c["mamba"],
                sub["mamba"])
        if cfg.family == "xlstm":
            c["mlstm"] = c["mlstm"].at[:, :, b:b + 1].set(sub["mlstm"])
            c["slstm"] = jax.tree_util.tree_map(
                lambda full, s: full.at[:, b:b + 1].set(s), c["slstm"],
                sub["slstm"])
        c["pos"] = c["pos"].at[b].set(sub["pos"][0])

    def _offer(self, h: int, payload) -> None:
        """Store payload and run the admission pipeline."""
        slot = self.pool.store(payload)
        if slot is None:
            return
        for freed in self.prefix_cache.insert(h, slot):
            self.pool.free(freed)

    def _tokens_arr(self, toks_1d: jnp.ndarray) -> jnp.ndarray:
        if self.cfg.n_codebooks:
            return jnp.broadcast_to(toks_1d[..., None],
                                    toks_1d.shape + (self.cfg.n_codebooks,))
        return toks_1d

    # ------------------------------------------------------------------ prefill
    def _start(self, req: Request) -> None:
        cfg = self.cfg
        b = self.free_slots.pop()
        req.slot = b
        self.active[req.rid] = req
        prompt = req.prompt
        hashes = block_hashes(prompt, self.block_size)
        bs = self.block_size
        snap_blocks = self.snapshot_every

        if _is_attn_family(cfg):
            slots = self.prefix_cache.lookup(hashes)
            n_reuse = len(slots)
            start = n_reuse * bs
            if n_reuse:
                payload = self.pool.load_many(slots)   # leaves (n,L,blk,H,D)
                k = jnp.concatenate(list(payload["k"]), axis=1)  # (L,n*blk,H,D)
                v = jnp.concatenate(list(payload["v"]), axis=1)
                self.cache["k"] = self.cache["k"].at[:, b, :start].set(
                    k.astype(self.cache["k"].dtype))
                self.cache["v"] = self.cache["v"].at[:, b, :start].set(
                    v.astype(self.cache["v"].dtype))
            req.prefix_blocks_reused = n_reuse
            self.tokens_reused += start
            suffix = prompt[start:]
            self.tokens_prefilled += len(suffix)
            sub = self._extract(b)
            toks = self._tokens_arr(jnp.asarray(suffix, jnp.int32)[None])
            sub, last_h = extend(self.model, self.params, toks, sub, start)
            self._writeback(b, sub)
        else:
            # SSM: reuse the deepest cached snapshot
            n_reuse, snap_slot = self.prefix_cache.lookup_snapshots(
                hashes, snap_blocks)
            start = n_reuse * bs
            if snap_slot is not None:
                self._restore_snapshot(b, self.pool.load(snap_slot))
            req.prefix_blocks_reused = n_reuse
            self.tokens_reused += start
            self.tokens_prefilled += len(prompt) - start
            # segmented prefill, capturing snapshots at boundaries
            seg_tokens = snap_blocks * bs
            pos = start
            last_h = None
            while pos < len(prompt):
                nxt = min(pos + seg_tokens, len(prompt))
                sub = self._extract(b)
                toks = self._tokens_arr(
                    jnp.asarray(prompt[pos:nxt], jnp.int32)[None])
                sub, last_h = extend(self.model, self.params, toks, sub, pos)
                self._writeback(b, sub)
                pos = nxt
                n_blocks = pos // bs
                if pos % seg_tokens == 0 and pos % bs == 0:
                    h = hashes[n_blocks - 1] if n_blocks - 1 < len(hashes) \
                        else None
                    if h is not None and h not in self.prefix_cache:
                        self._offer(h, self._state_snapshot_of(self.cache, b))

        logits = self.model.lm_head(self.params, last_h)
        self._emit(req, logits[:, 0])

    # ------------------------------------------------------------------ decode
    def _emit(self, req: Request, logits_row) -> None:
        tok = np.asarray(jnp.argmax(logits_row[0], axis=-1))
        if self.cfg.n_codebooks:
            req.out_tokens.append([int(t) for t in tok])
        else:
            req.out_tokens.append(int(tok))
        if len(req.out_tokens) >= req.max_new_tokens:
            req.done = True

    def _decode_tick(self) -> None:
        toks = np.zeros((self.max_batch, 1), np.int32)
        for req in self.active.values():
            last = req.out_tokens[-1]
            toks[req.slot, 0] = last[0] if isinstance(last, list) else last
        t = self._tokens_arr(jnp.asarray(toks))
        logits, self.cache = self._decode_fn(self.params, t, self.cache)
        for req in self.active.values():
            if not req.done:
                self._emit(req, logits[req.slot:req.slot + 1, 0])

    # ------------------------------------------------------------------ finish
    def _finish(self, req: Request) -> None:
        cfg = self.cfg
        b = req.slot
        if _is_attn_family(cfg):
            hashes = block_hashes(req.prompt, self.block_size)
            for i, h in enumerate(hashes):
                if h in self.prefix_cache:
                    continue
                s0 = i * self.block_size
                payload = {
                    "k": self.cache["k"][:, b, s0:s0 + self.block_size],
                    "v": self.cache["v"][:, b, s0:s0 + self.block_size],
                }
                self._offer(h, payload)
        self.free_slots.append(b)
        self.cache["pos"] = self.cache["pos"].at[b].set(0)

    # ------------------------------------------------------------------ driver
    def submit(self, prompt, max_new_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, list(map(int, prompt)),
                                  max_new_tokens))
        return rid

    def run(self) -> dict[int, list]:
        results = {}
        while self.queue or self.active:
            while self.queue and self.free_slots:
                self._start(self.queue.pop(0))
            if self.active:
                self._decode_tick()
                for rid in [r for r, q in self.active.items() if q.done]:
                    req = self.active.pop(rid)
                    self._finish(req)
                    results[rid] = req.out_tokens
        return results

    @property
    def stats(self) -> dict:
        pc = self.prefix_cache.stats
        return {
            "prefix_hit_ratio": pc.hit_ratio,
            "block_hits": pc.block_hits,
            "block_misses": pc.block_misses,
            "admitted": pc.admitted,
            "rejected": pc.rejected,
            "tokens_prefilled": self.tokens_prefilled,
            "tokens_reused": self.tokens_reused,
            "reuse_frac": self.tokens_reused /
                max(1, self.tokens_reused + self.tokens_prefilled),
            "pool_used": self.pool.used,
        }
