"""Core layers: RMSNorm, RoPE (incl. partial/"2d"), GQA flash-style blocked
attention (train/prefill) + decode attention, SwiGLU MLP.

Attention never materializes the full (S x S) score matrix: it runs an online
-softmax over (q_block x kv_block) tiles via nested lax.scan — the jnp analogue
of FlashAttention, sized so tiles stay within a few hundred MB at 32k context.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, NULL_POLICY

NEG_INF = -1e30


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * w.astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_cos_sin(positions: jnp.ndarray, rot_dim: int, theta: float):
    """positions (...,) int -> cos/sin (..., rot_dim//2) fp32."""
    inv = 1.0 / (theta ** (np.arange(0, rot_dim, 2, dtype=np.float32) / rot_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
               rotary_pct: float = 1.0) -> jnp.ndarray:
    """x (B, S, H, D); cos/sin (B, S, rot//2).  Rotates the first
    ``rotary_pct * D`` dims (half-split convention); chatglm3's 2d-RoPE is the
    rotary_pct=0.5 case (second half carries no positional signal)."""
    d = x.shape[-1]
    rot = int(d * rotary_pct)
    rot -= rot % 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(xr, 2, axis=-1)
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return jnp.concatenate([out, xp], axis=-1) if rot < d else out


# ---------------------------------------------------------------------------
# blocked (flash-style) attention — training & prefill
# ---------------------------------------------------------------------------

def _pad_to(x: jnp.ndarray, axis: int, mult: int):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, x.shape[axis]
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), x.shape[axis]


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True,
                    q_block: int = 512, kv_block: int = 1024,
                    q_offset: int | jnp.ndarray = 0,
                    kv_len: Optional[jnp.ndarray] = None,
                    softcap: float = 0.0,
                    scores_bf16: bool = False,
                    causal_skip: bool = False,
                    policy=NULL_POLICY) -> jnp.ndarray:
    """Online-softmax tiled attention with a single head axis.

    q (B, Sq, H, D); k, v (B, Skv, H, D) — GQA callers repeat KV heads before
    the call so every tensor in the scan shares one head axis (keeps the
    'model'-axis sharding stable across iterations; grouped layouts made
    GSPMD thrash reshardings inside the loop).
    q_offset: global position of q[0] (prefill chunks); kv_len (B,) masks a
    padded KV cache.  Returns (B, Sq, H, D) in q.dtype.
    """
    B, Sq, H, D = q.shape
    _, Skv, _, _ = k.shape
    scale = float(1.0 / np.sqrt(D))

    q, _ = _pad_to(q, 1, q_block)
    k, _ = _pad_to(k, 1, kv_block)
    v, _ = _pad_to(v, 1, kv_block)
    nq, nk = q.shape[1] // q_block, k.shape[1] // kv_block

    cst = (lambda x: policy.act(x, "attn_blk")) if policy else (lambda x: x)
    qb = cst(q.reshape(B, nq, q_block, H, D)).transpose(1, 0, 2, 3, 4)
    kb = cst(k.reshape(B, nk, kv_block, H, D)).transpose(1, 0, 2, 3, 4)
    vb = cst(v.reshape(B, nk, kv_block, H, D)).transpose(1, 0, 2, 3, 4)

    kv_limit = kv_len if kv_len is not None else jnp.full((B,), Skv, jnp.int32)
    score_dtype = jnp.bfloat16 if scores_bf16 else jnp.float32

    def kv_tile(carry, ki, kblk, vblk, qblk, q_pos, *, need_mask: bool):
        m, l, acc = carry
        k_pos = ki * kv_block + jnp.arange(kv_block)
        s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        if need_mask:
            mask = k_pos[None, :] < kv_limit[:, None]          # (B, kb)
            if causal:
                mask = mask[:, None, :] \
                    & (q_pos[:, None] >= k_pos[None, :])[None]
            else:
                mask = jnp.broadcast_to(mask[:, None, :],
                                        (B, q_block, kv_block))
            s = jnp.where(mask[:, None, :, :], s, NEG_INF)
        # optional low-precision materialization of the score tile (halves
        # the dominant HBM traffic of unfused attention; §Perf)
        s = s.astype(score_dtype)
        m_new = jnp.maximum(m, s.max(-1).astype(jnp.float32))
        p = jnp.exp(s.astype(jnp.float32) - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(qblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return m_new, l_new, acc_new

    def init_carry():
        return (jnp.full((B, H, q_block), NEG_INF, jnp.float32),
                jnp.zeros((B, H, q_block), jnp.float32),
                jnp.zeros((B, H, q_block, D), jnp.float32))

    def finish(qi_out):
        m, l, acc = qi_out
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 2, 1, 3).astype(q.dtype)   # (B,qb,H,D)

    static_q_offset = isinstance(q_offset, int)
    if causal_skip and causal and static_q_offset and nq <= 16 \
            and kv_len is None:
        # static triangular tiling: unrolled q loop; each q-block scans only
        # its causal kv prefix; only the diagonal tile needs a mask.
        outs = []
        for qi in range(nq):
            qblk = qb[qi]
            q_pos = q_offset + qi * q_block + jnp.arange(q_block)
            hi = (q_offset + (qi + 1) * q_block + kv_block - 1) // kv_block
            hi = min(hi, nk)
            carry = init_carry()
            if hi > 1:
                def body(c, ki_kv):
                    ki, kblk, vblk = ki_kv
                    return kv_tile(c, ki, kblk, vblk, qblk, q_pos,
                                   need_mask=False), None
                carry, _ = jax.lax.scan(
                    body, carry,
                    (jnp.arange(hi - 1), kb[:hi - 1], vb[:hi - 1]))
            carry = kv_tile(carry, jnp.int32(hi - 1), kb[hi - 1], vb[hi - 1],
                            qblk, q_pos, need_mask=True)
            outs.append(finish(carry))
        ob = jnp.stack(outs)
    else:
        def q_step(_, qi_qblk):
            qi, qblk = qi_qblk                      # qblk (B, qb, H, D)
            q_pos = q_offset + qi * q_block + jnp.arange(q_block)

            def kv_step(carry, ki_kv):
                ki, kblk, vblk = ki_kv
                return kv_tile(carry, ki, kblk, vblk, qblk, q_pos,
                               need_mask=True), None

            carry, _ = jax.lax.scan(kv_step, init_carry(),
                                    (jnp.arange(nk), kb, vb))
            return None, finish(carry)

        _, ob = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    out = ob.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_block, H, D)
    return out[:, :Sq]


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     pos: jnp.ndarray, *, softcap: float = 0.0,
                     policy=NULL_POLICY) -> jnp.ndarray:
    """Single-token attention over a (padded) KV cache.

    q (B, 1, Hq, D); caches (B, Smax, Hkv, D); pos (B,) = #valid cache slots
    (the new token's k/v must already be written at pos-1).
    """
    B, _, Hq, D = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = jnp.arange(Smax)[None, :] < pos[:, None]            # (B, Smax)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(q.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray, policy=NULL_POLICY) -> jnp.ndarray:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    h = policy.act(h, "ffn_hidden")
    return h @ w_down
