"""xLSTM stack (arXiv:2405.04517): superblocks of (slstm_period - 1) mLSTM
blocks followed by 1 sLSTM block — xLSTM[7:1] at 48 layers = 6 superblocks.
d_ff = 0: there is no separate FFN; the mLSTM up/down projection is the only
channel mixing (per the assigned config).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, KeyGen, embed_init, dense_init, \
    stack_layer_params, NULL_POLICY
from .layers import rmsnorm
from .mlstm import (init_mlstm_params, mlstm_forward, mlstm_decode_step,
                    init_mlstm_state, init_slstm_params, slstm_forward,
                    slstm_decode_step, init_slstm_state)
from .transformer import lm_head


def _split(cfg: ModelConfig):
    per = cfg.slstm_period
    assert cfg.n_layers % per == 0
    return cfg.n_layers // per, per - 1      # (n_super, mlstm per super)


def init_params(cfg: ModelConfig, key) -> dict:
    kg = KeyGen(key)
    dt = cfg.param_dtype
    n_super, n_ml = _split(cfg)
    supers = []
    for s in range(n_super):
        blk = {
            "mlstm": stack_layer_params([
                {"p": init_mlstm_params(kg, cfg, dt),
                 "norm": jnp.ones((cfg.d_model,), dt)}
                for _ in range(n_ml)]),
            "slstm": {"p": init_slstm_params(kg, cfg, dt),
                      "norm": jnp.ones((cfg.d_model,), dt)},
        }
        supers.append(blk)
    return {
        "embed": embed_init(kg(), (cfg.padded_vocab, cfg.d_model), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "out_head": dense_init(kg(), (cfg.d_model, cfg.padded_vocab), dt),
        "supers": stack_layer_params(supers),
    }


def forward_train(params, tokens, cfg: ModelConfig, *, vision_embeds=None,
                  policy=NULL_POLICY, remat: bool = True):
    from .transformer import cast_params
    params = cast_params(params, cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = policy.act(x, "residual")

    def super_body(x, blk):
        def ml_body(x, p):
            h = rmsnorm(x, p["norm"], cfg.norm_eps)
            out, _ = mlstm_forward(p["p"], h, cfg, policy=policy)
            return policy.act(x + out, "residual"), None
        ml = jax.checkpoint(ml_body) if remat else ml_body
        x, _ = jax.lax.scan(ml, x, blk["mlstm"])
        h = rmsnorm(x, blk["slstm"]["norm"], cfg.norm_eps)
        out, _ = slstm_forward(blk["slstm"]["p"], h, cfg, policy=policy)
        return policy.act(x + out, "residual"), None

    x, _ = jax.lax.scan(super_body, x, params["supers"])
    return x, jnp.float32(0.0)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    n_super, n_ml = _split(cfg)
    ml = init_mlstm_state(cfg, batch)
    sl = init_slstm_state(cfg, batch)
    tile = lambda a, n: jnp.broadcast_to(a, (n,) + a.shape).copy() \
        if hasattr(a, "shape") else a
    return {
        "mlstm": jax.tree_util.tree_map(
            lambda a: jnp.zeros((n_super, n_ml) + a.shape, a.dtype), ml),
        "slstm": jax.tree_util.tree_map(
            lambda a: jnp.zeros((n_super,) + a.shape, a.dtype), sl),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def forward_prefill(params, tokens, cfg: ModelConfig, cache: dict, *,
                    vision_embeds=None, policy=NULL_POLICY):
    from .transformer import cast_params
    params = cast_params(params, cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    B, S, _ = x.shape
    n_super, n_ml = _split(cfg)
    ml_states, sl_states = [], []
    for s in range(n_super):
        blk = jax.tree_util.tree_map(lambda a: a[s], params["supers"])
        row = []
        for i in range(n_ml):
            p = jax.tree_util.tree_map(lambda a: a[i], blk["mlstm"])
            h = rmsnorm(x, p["norm"], cfg.norm_eps)
            out, st = mlstm_forward(p["p"], h, cfg, policy=policy)
            x = x + out
            row.append(st)
        ml_states.append(jnp.stack(row))
        h = rmsnorm(x, blk["slstm"]["norm"], cfg.norm_eps)
        out, st = slstm_forward(blk["slstm"]["p"], h, cfg, policy=policy)
        x = x + out
        sl_states.append(st)
    cache = dict(cache)
    cache["mlstm"] = jnp.stack(ml_states)
    cache["slstm"] = stack_layer_params(sl_states)
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    return cache, x[:, -1:]


def forward_decode(params, tokens, cfg: ModelConfig, cache: dict, *,
                   vision_embeds=None, policy=NULL_POLICY):
    from .transformer import cast_params
    params = cast_params(params, cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    n_super, n_ml = _split(cfg)
    new_ml, new_sl = [], []
    for s in range(n_super):
        blk = jax.tree_util.tree_map(lambda a: a[s], params["supers"])
        row = []
        for i in range(n_ml):
            p = jax.tree_util.tree_map(lambda a: a[i], blk["mlstm"])
            st = cache["mlstm"][s, i]
            h = rmsnorm(x, p["norm"], cfg.norm_eps)
            out, st = mlstm_decode_step(p["p"], h, st, cfg, policy=policy)
            x = x + out
            row.append(st)
        new_ml.append(jnp.stack(row))
        sl_st = jax.tree_util.tree_map(lambda a: a[s], cache["slstm"])
        h = rmsnorm(x, blk["slstm"]["norm"], cfg.norm_eps)
        out, sl_st = slstm_decode_step(blk["slstm"]["p"], h, sl_st, cfg,
                                       policy=policy)
        x = x + out
        new_sl.append(sl_st)
    cache = dict(cache)
    cache["mlstm"] = jnp.stack(new_ml)
    cache["slstm"] = stack_layer_params(new_sl)
    cache["pos"] = cache["pos"] + 1
    logits = lm_head(params, x, cfg, policy)
    return logits, cache
