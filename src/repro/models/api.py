"""Unified model API over the four family implementations.

Every architecture exposes the same five entry points (the training loop,
serving runtime, and multi-pod dry-run are family-agnostic):

  model.init(key)                         -> params
  model.hidden_train(params, batch, ...)  -> (hidden, aux_loss)   # pre-head
  model.prefill(params, batch, cache)     -> (cache, last_hidden)
  model.decode(params, tokens, cache)     -> (logits, cache)
  model.init_cache(batch, max_len)        -> cache pytree

plus ``input_specs(kind)`` returning jax.ShapeDtypeStruct stand-ins for each
assigned input-shape kind (train_4k / prefill_32k / decode_32k / long_500k) —
the dry-run lowers against these without allocating anything.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig, NULL_POLICY
from . import transformer, zamba, xlstm


SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def _family_mod(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        return transformer
    if cfg.family == "hybrid_ssm":
        return zamba
    if cfg.family == "xlstm":
        return xlstm
    raise ValueError(cfg.family)


def is_subquadratic(cfg: ModelConfig) -> bool:
    """Archs eligible for the long_500k cell (SSM / hybrid / linear-attn)."""
    return cfg.family in ("hybrid_ssm", "xlstm")


@dataclass
class Model:
    cfg: ModelConfig

    def __post_init__(self):
        self._mod = _family_mod(self.cfg)

    # -- parameters -----------------------------------------------------------
    def init(self, key):
        if self._mod is transformer:
            return transformer.init_params(self.cfg, key)
        return self._mod.init_params(self.cfg, key)

    # -- training forward (head applied by train/losses.py, chunked) ----------
    def hidden_train(self, params, batch, policy=NULL_POLICY, remat=True):
        return self._mod.forward_train(
            params, batch["tokens"], self.cfg,
            vision_embeds=batch.get("vision_embeds"), policy=policy,
            remat=remat)

    # -- serving ---------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        if self._mod is transformer:
            return transformer.init_kv_cache(self.cfg, batch, max_len, dtype)
        return self._mod.init_cache(self.cfg, batch, max_len, dtype)

    def prefill(self, params, batch, cache, policy=NULL_POLICY):
        return self._mod.forward_prefill(
            params, batch["tokens"], self.cfg, cache,
            vision_embeds=batch.get("vision_embeds"), policy=policy)

    def decode(self, params, tokens, cache, policy=NULL_POLICY):
        return self._mod.forward_decode(params, tokens, self.cfg, cache,
                                        policy=policy)

    def lm_head(self, params, hidden, policy=NULL_POLICY):
        return transformer.lm_head(params, hidden, self.cfg, policy)

    # -- dry-run input specs -----------------------------------------------------
    def input_specs(self, kind: str) -> dict:
        cfg = self.cfg
        sh = SHAPES[kind]
        B, S = sh["global_batch"], sh["seq_len"]
        tok_shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
        specs: dict[str, Any] = {}
        if sh["kind"] in ("train", "prefill"):
            specs["tokens"] = jax.ShapeDtypeStruct(tok_shape, jnp.int32)
            if cfg.n_vis_tokens:
                specs["vision_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_vis_tokens, cfg.d_model), jnp.bfloat16)
        else:  # decode: one new token against a seq_len-deep cache
            one = (B, 1, cfg.n_codebooks) if cfg.n_codebooks else (B, 1)
            specs["tokens"] = jax.ShapeDtypeStruct(one, jnp.int32)
            specs["cache"] = jax.eval_shape(
                lambda: self.init_cache(B, S))
        return specs


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
