"""Mamba2 (SSD) mixer — chunked parallel scan for train/prefill, O(1)-state
recurrent step for decode (zamba2 hybrid backbone).

Chunked algorithm (Mamba2 paper §6): sequence split into chunks of L;
intra-chunk term is a masked (L x L) "attention" with cumulative decay;
inter-chunk term propagates the (H, P, N) state with a tiny lax.scan over
chunks.  All matmuls in bf16 with fp32 softplus/exp gate math.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, NULL_POLICY, dense_init

NEG_INF = -1e30


def ssm_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    return d_in, H, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba_params(kg, cfg: ModelConfig, dtype):
    d_in, H, P, N = ssm_dims(cfg)
    conv_ch = d_in + 2 * N                       # x + B + C (single group)
    return {
        "in_proj": dense_init(kg(), (cfg.d_model, 2 * d_in + 2 * N + H), dtype),
        "conv_w": dense_init(kg(), (cfg.ssm_conv, conv_ch), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),
        "D": jnp.ones((H,), dtype),
        "norm_w": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(kg(), (d_in, cfg.d_model), dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None):
    """Depthwise causal conv1d.  x (B,S,C); w (K,C).  state (B,K-1,C) holds the
    trailing inputs of the previous segment (decode).  Returns y, new_state."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    return jax.nn.silu(y), xp[:, -(K - 1):]


def mamba2_forward(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                   initial_state=None, policy=NULL_POLICY):
    """x (B,S,M) -> (y (B,S,M), final_state dict(conv, ssm))."""
    B, S, M = x.shape
    d_in, H, P, N = ssm_dims(cfg)
    L = min(cfg.ssm_chunk, S)

    zxbcdt = policy.act(x @ p["in_proj"].astype(x.dtype), "mamba_proj")
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * N], axis=-1)
    conv_state0 = None if initial_state is None else initial_state["conv"]
    xbc, conv_state = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                   p["conv_b"].astype(x.dtype), conv_state0)
    xbc = policy.act(xbc, "mamba_proj")
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (H,)

    # pad the time axis to a chunk multiple; padded steps are inert
    # (dt=0 -> decay=1 and zero input contribution)
    S_orig = S
    pad = (-S) % L
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        S += pad
    nc = S // L
    dlog = dt * A                                                  # log decay, <=0

    # chunked views
    xs_c = (xs * dt.astype(xs.dtype)[..., None]).reshape(B, nc, L, H, P)
    xs_c = policy.act(xs_c, "mamba_chunk")
    B_c = Bm.reshape(B, nc, L, N)
    C_c = Cm.reshape(B, nc, L, N)
    dlog_c = dlog.reshape(B, nc, L, H)
    cum = jnp.cumsum(dlog_c, axis=2)                               # (B,nc,L,H)
    total = cum[:, :, -1]                                          # (B,nc,H)

    # ---- intra-chunk: masked decay attention -------------------------------
    cb = jnp.einsum("bcln,bcsn->bcls", C_c, B_c,
                    preferred_element_type=jnp.float32)            # (B,nc,L,L)
    dmask = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (B,nc,L,L,H)
    causal = jnp.tril(jnp.ones((L, L), bool))
    # mask BEFORE exp: non-causal entries have dmask > 0 and would overflow,
    # poisoning the backward pass (inf * 0 = nan)
    dmask = jnp.where(causal[None, None, :, :, None], dmask, NEG_INF)
    att = (jnp.exp(dmask) * cb[..., None]).astype(x.dtype)         # (B,nc,L,L,H)
    att = policy.act(att, "mamba_att")
    y_intra = jnp.einsum("bclsh,bcshp->bclhp", att, xs_c)

    # ---- chunk states + inter-chunk scan ------------------------------------
    # state contribution of step s within chunk: exp(total - cum_s) * dt x B
    w_end = jnp.exp(total[:, :, None, :] - cum).astype(x.dtype)    # (B,nc,L,H)
    S_c = jnp.einsum("bclhp,bcln,bclh->bchpn", xs_c, B_c, w_end)   # (B,nc,H,P,N)

    ssm0 = (jnp.zeros((B, H, P, N), jnp.float32) if initial_state is None
            else initial_state["ssm"])

    def chunk_step(h, inp):
        s_c, tot = inp                                             # (B,H,P,N),(B,H)
        h_new = h * jnp.exp(tot)[:, :, None, None] + s_c.astype(jnp.float32)
        return h_new, h                                            # emit state BEFORE chunk

    (ssm_final, h_prevs) = jax.lax.scan(
        chunk_step, ssm0,
        (S_c.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)))
    h_prev = h_prevs.transpose(1, 0, 2, 3, 4)                      # (B,nc,H,P,N)

    # ---- inter-chunk output: C_t · exp(cum_t) h_prev -------------------------
    w_in = jnp.exp(cum).astype(x.dtype)                            # (B,nc,L,H)
    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp", C_c, w_in,
                         h_prev.astype(x.dtype))

    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + xs * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_in)[:, :S_orig]

    # gated output norm + projection
    y = y * jax.nn.silu(z)
    from .layers import rmsnorm
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"conv": conv_state, "ssm": ssm_final}


def mamba2_decode_step(p: dict, x: jnp.ndarray, state: dict, cfg: ModelConfig,
                       policy=NULL_POLICY):
    """Single-token recurrent step.  x (B,1,M); state {conv (B,K-1,C),
    ssm (B,H,P,N)} -> (y (B,1,M), new state)."""
    B = x.shape[0]
    d_in, H, P, N = ssm_dims(cfg)
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * N], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                   p["conv_b"].astype(x.dtype), state["conv"])
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    xs = xs.reshape(B, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))[:, 0]   # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                                          # (B,H)
    dx = xs.astype(jnp.float32) * dt[..., None]                      # (B,H,P)
    ssm = state["ssm"] * decay[:, :, None, None] + \
        jnp.einsum("bhp,bn->bhpn", dx, Bm[:, 0].astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", ssm, Cm[:, 0].astype(jnp.float32))
    y = y.astype(x.dtype) + xs * p["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(B, 1, d_in)
    y = y * jax.nn.silu(z)
    from .layers import rmsnorm
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"].astype(x.dtype), {"conv": conv_state, "ssm": ssm}


def init_mamba_state(cfg: ModelConfig, batch: int, dtype):
    d_in, H, P, N = ssm_dims(cfg)
    conv_ch = d_in + 2 * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
    }
