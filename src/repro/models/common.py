"""Model configuration + parameter-init utilities shared by every assigned
architecture.  Pure JAX (no flax): params are plain dict pytrees; layer stacks
are stored with a leading layer axis and executed with ``lax.scan``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid_ssm | xlstm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # attention
    rope_theta: float = 1_000_000.0
    rotary_pct: float = 1.0        # chatglm3: 0.5 ("RoPE 2d")
    qk_norm: bool = False          # qwen3
    attn_logit_softcap: float = 0.0
    # MoE
    n_experts: int = 0
    moe_top_k: int = 1
    moe_every: int = 1             # llama4-maverick: 2 (alternating dense/MoE)
    n_shared_experts: int = 0      # llama4: 1 shared expert
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # hybrid SSM (zamba2)
    ssm_state: int = 0             # Mamba2 d_state
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    attn_every: int = 0            # shared attention block period (zamba2: 6)
    # xLSTM
    slstm_period: int = 0          # 1 sLSTM per this many blocks (xlstm: 8)
    proj_factor: float = 2.0       # mLSTM up-projection
    # audio (musicgen)
    n_codebooks: int = 0
    # vlm (llava-next) — vision frontend is a stub; embeddings arrive as input
    n_vis_tokens: int = 0
    # scaling tricks
    scale_emb: float = 1.0         # minicpm: 12.0
    scale_depth: float = 0.0       # minicpm: 1.4 (residual scaled by this/sqrt(L))
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    # attention blocking (flash-style jnp attention)
    q_block: int = 512
    kv_block: int = 1024
    ssm_chunk: int = 256
    # perf knobs (see EXPERIMENTS.md §Perf — each is one hillclimb hypothesis)
    attn_scores_bf16: bool = False   # materialize score/prob tiles in bf16
    causal_skip: bool = False        # static triangular tiling (skip masked
                                     # kv tiles; unrolled outer q loop)
    cast_params_once: bool = True    # cast fp32 params->bf16 BEFORE layer use
                                     # so FSDP all-gathers move bf16

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/head storage rows: padded to a shardable multiple when
        the published vocab isn't divisible by the TP degree (minicpm's
        122753).  Padded logit columns are masked in the loss and sliced off
        the head — the model is functionally exactly ``vocab_size``."""
        if self.vocab_size % 16 == 0:
            return self.vocab_size
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def q_groups(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    @property
    def residual_scale(self) -> float:
        return (self.scale_depth / float(np.sqrt(self.n_layers))
                if self.scale_depth else 1.0)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (fan-in = shape[-2] unless overridden)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


class KeyGen:
    """Deterministic key splitter: kg = KeyGen(key); kg() -> fresh key."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


def stack_layer_params(per_layer: list[dict]) -> dict:
    """[{name: arr}, ...] -> {name: arr[L, ...]} for lax.scan stacks."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_layer)


def param_count(params) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree_util.tree_leaves(params)))


# ---------------------------------------------------------------------------
# sharding policy hook (distributed/shardings.py provides the real one)
# ---------------------------------------------------------------------------

class NullPolicy:
    """No-op activation-sharding policy (single-device paths, smoke tests)."""

    def act(self, x, kind: str):
        return x


NULL_POLICY = NullPolicy()
