"""xLSTM blocks: chunked-parallel mLSTM (matrix memory) and strictly
recurrent sLSTM (scalar memory with block-diagonal recurrence), per
arXiv:2405.04517, adapted for TPU:

* mLSTM uses the same chunked decay-attention machinery as our Mamba2 SSD —
  the normalizer state n is carried as an extra value column, and the input
  gate is sigmoid (bounded) instead of exp+stabilizer so the chunked form
  stays in bf16-safe range (deviation noted in DESIGN.md §6).
* sLSTM keeps the paper's exponential gating with the m stabilizer state —
  it is inherently sequential (h feeds the block-diagonal recurrence R), so
  training runs a lax.scan over time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, NULL_POLICY, dense_init
from .layers import rmsnorm


def mlstm_dims(cfg: ModelConfig):
    d_in = int(cfg.proj_factor * cfg.d_model)
    H = cfg.n_heads
    return d_in, H, d_in // H


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm_params(kg, cfg: ModelConfig, dtype):
    M = cfg.d_model
    d_in, H, hd = mlstm_dims(cfg)
    return {
        "up_x": dense_init(kg(), (M, d_in), dtype),
        "up_z": dense_init(kg(), (M, d_in), dtype),
        "w_q": dense_init(kg(), (d_in, d_in), dtype),
        "w_k": dense_init(kg(), (d_in, d_in), dtype),
        "w_v": dense_init(kg(), (d_in, d_in), dtype),
        "w_gates": dense_init(kg(), (d_in, 2 * H), dtype),   # i, f per head
        "gate_bias": jnp.concatenate([jnp.zeros((H,)),       # igate bias 0
                                      3.0 + jnp.arange(H) * 0.5]).astype(dtype),
        "norm_w": jnp.ones((d_in,), dtype),
        "down": dense_init(kg(), (d_in, M), dtype),
    }


def _mlstm_core_chunked(q, k, v, lf, li, chunk: int, h0=None):
    """Chunked gated linear attention with normalizer column.

    q,k,v (B,S,H,D); lf (B,S,H) log-forget (<=0); li (B,S,H) log-input (<=0).
    Returns (y (B,S,H,D), final_state (B,H,D,D+1) fp32).
    """
    B, S, H, D = q.shape
    L = min(chunk, S)
    scale = float(1.0 / np.sqrt(D))   # python float: weak type, keeps bf16

    # pad time axis to a chunk multiple; padded steps: forget=1 (lf=0) and
    # input weight exp(li)=0, so states pass through untouched.
    S_orig = S
    pad = (-S) % L
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)),
                     constant_values=-1e30)
        S += pad
    nc = S // L

    vn = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], -1)
    qc = (q * scale).reshape(B, nc, L, H, D)
    kc = k.reshape(B, nc, L, H, D)
    vc = vn.reshape(B, nc, L, H, D + 1)
    lf_c = lf.reshape(B, nc, L, H)
    li_c = li.reshape(B, nc, L, H)
    cum = jnp.cumsum(lf_c, axis=2)                      # (B,nc,L,H)
    total = cum[:, :, -1]

    # intra-chunk: att[t,s] = exp(cum_t - cum_s + li_s) * (q_t . k_s), s<=t
    qk = jnp.einsum("bclhd,bcshd->bclsh", qc, kc,
                    preferred_element_type=jnp.float32)
    dmask = cum[:, :, :, None, :] - cum[:, :, None, :, :] \
        + li_c[:, :, None, :, :]                        # (B,nc,L,L,H)
    causal = jnp.tril(jnp.ones((L, L), bool))
    # mask BEFORE exp (non-causal dmask > 0 would overflow -> nan in bwd)
    dmask = jnp.where(causal[None, None, :, :, None], dmask, -1e30)
    att = (jnp.exp(dmask) * qk).astype(q.dtype)
    y_intra = jnp.einsum("bclsh,bcshd->bclhd", att, vc)

    # chunk states
    w_end = jnp.exp(total[:, :, None, :] - cum + li_c).astype(q.dtype)
    S_c = jnp.einsum("bclhd,bclh,bclhe->bchde", kc, w_end, vc)

    h_init = (jnp.zeros((B, H, D, D + 1), jnp.float32) if h0 is None else h0)

    def chunk_step(h, inp):
        s_c, tot = inp
        return h * jnp.exp(tot)[:, :, None, None] + s_c.astype(jnp.float32), h

    h_final, h_prevs = jax.lax.scan(
        chunk_step, h_init,
        (S_c.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)))
    h_prev = h_prevs.transpose(1, 0, 2, 3, 4)           # (B,nc,H,D,D+1)

    w_in = jnp.exp(cum).astype(q.dtype)
    y_inter = jnp.einsum("bclhd,bclh,bchde->bclhe", qc, w_in,
                         h_prev.astype(q.dtype))
    y = (y_intra + y_inter).reshape(B, S, H, D + 1)[:, :S_orig]
    num, den = y[..., :-1], y[..., -1:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    return y, h_final


def mlstm_forward(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                  initial_state=None, policy=NULL_POLICY):
    B, S, M = x.shape
    d_in, H, hd = mlstm_dims(cfg)
    xin = x @ p["up_x"].astype(x.dtype)
    z = x @ p["up_z"].astype(x.dtype)
    q = (xin @ p["w_q"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (xin @ p["w_k"].astype(x.dtype)).reshape(B, S, H, hd)
    v = (xin @ p["w_v"].astype(x.dtype)).reshape(B, S, H, hd)
    gates = (xin @ p["w_gates"].astype(x.dtype)).astype(jnp.float32) \
        + p["gate_bias"].astype(jnp.float32)
    li = jax.nn.log_sigmoid(gates[..., :H])             # log input gate <= 0
    lf = jax.nn.log_sigmoid(gates[..., H:])             # log forget gate <= 0
    y, state = _mlstm_core_chunked(q, k, v, lf, li, cfg.ssm_chunk,
                                   h0=None if initial_state is None
                                   else initial_state)
    y = y.reshape(B, S, d_in)
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["down"].astype(x.dtype), state


def mlstm_decode_step(p: dict, x: jnp.ndarray, state: jnp.ndarray,
                      cfg: ModelConfig, policy=NULL_POLICY):
    """x (B,1,M); state (B,H,D,D+1) fp32."""
    B = x.shape[0]
    d_in, H, hd = mlstm_dims(cfg)
    xin = x @ p["up_x"].astype(x.dtype)
    z = x @ p["up_z"].astype(x.dtype)
    q = (xin @ p["w_q"].astype(x.dtype)).reshape(B, H, hd)
    k = (xin @ p["w_k"].astype(x.dtype)).reshape(B, H, hd)
    v = (xin @ p["w_v"].astype(x.dtype)).reshape(B, H, hd)
    gates = (xin @ p["w_gates"].astype(x.dtype)).astype(jnp.float32)[:, 0] \
        + p["gate_bias"].astype(jnp.float32)
    i_g = jax.nn.sigmoid(gates[..., :H])
    f_g = jax.nn.sigmoid(gates[..., H:])
    vn = jnp.concatenate([v, jnp.ones((B, H, 1), v.dtype)], -1)
    kv = jnp.einsum("bhd,bhe->bhde", k.astype(jnp.float32),
                    vn.astype(jnp.float32))
    state = state * f_g[:, :, None, None] + kv * i_g[:, :, None, None]
    y = jnp.einsum("bhd,bhde->bhe", (q / np.sqrt(hd)).astype(jnp.float32),
                   state)
    num, den = y[..., :-1], y[..., -1:]
    y = (num / jnp.maximum(jnp.abs(den), 1.0)).astype(x.dtype)
    y = y.reshape(B, 1, d_in)
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["down"].astype(x.dtype), state


def init_mlstm_state(cfg: ModelConfig, batch: int):
    d_in, H, hd = mlstm_dims(cfg)
    return jnp.zeros((batch, H, hd, hd + 1), jnp.float32)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm_params(kg, cfg: ModelConfig, dtype):
    M = cfg.d_model
    H = cfg.n_heads
    hd = M // H
    return {
        "w_x": dense_init(kg(), (M, 4 * M), dtype),
        "r": dense_init(kg(), (H, hd, 4 * hd), dtype, scale=1.0 / np.sqrt(hd)),
        "b": jnp.zeros((4 * M,), dtype),
        "norm_w": jnp.ones((M,), dtype),
        "out": dense_init(kg(), (M, M), dtype),
    }


def _slstm_cell(p, xt, state, cfg: ModelConfig):
    """One timestep.  xt (B, 4M) precomputed x @ w_x + b.  state: dict of
    (B, M) fp32 arrays h, c, n, m."""
    M = cfg.d_model
    H = cfg.n_heads
    hd = M // H
    B = xt.shape[0]
    hr = state["h"].reshape(B, H, hd)
    rec = jnp.einsum("bhd,hde->bhe", hr.astype(xt.dtype),
                     p["r"].astype(xt.dtype)).reshape(B, 4 * M)
    pre = (xt + rec).astype(jnp.float32)
    zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
    # exponential gating with stabilizer (xLSTM eq. 15-17)
    m_new = jnp.maximum(ft + state["m"], it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(ft + state["m"] - m_new)
    c = f_p * state["c"] + i_p * jnp.tanh(zt)
    n = f_p * state["n"] + i_p
    h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
    return {"h": h, "c": c, "n": n, "m": m_new}


def slstm_forward(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                  initial_state=None, policy=NULL_POLICY):
    B, S, M = x.shape
    xw = x @ p["w_x"].astype(x.dtype) + p["b"].astype(x.dtype)
    st = initial_state if initial_state is not None \
        else init_slstm_state(cfg, B)

    def step(state, xt):
        new = _slstm_cell(p, xt, state, cfg)
        return new, new["h"]

    final, hs = jax.lax.scan(step, st, xw.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps)
    return y @ p["out"].astype(x.dtype), final


def slstm_decode_step(p: dict, x: jnp.ndarray, state: dict, cfg: ModelConfig,
                      policy=NULL_POLICY):
    xw = (x @ p["w_x"].astype(x.dtype) + p["b"].astype(x.dtype))[:, 0]
    new = _slstm_cell(p, xw, state, cfg)
    y = rmsnorm(new["h"].astype(x.dtype)[:, None, :], p["norm_w"],
                cfg.norm_eps)
    return y @ p["out"].astype(x.dtype), new


def init_slstm_state(cfg: ModelConfig, batch: int):
    M = cfg.d_model
    z = lambda: jnp.zeros((batch, M), jnp.float32)
    return {"h": z(), "c": z(), "n": z(), "m": z()}
