"""Zamba2-style hybrid backbone: Mamba2 layers with a single *shared*
attention+MLP block applied every ``attn_every`` layers (arXiv:2411.15242).

Simplifications vs the released checkpoints (recorded in DESIGN.md §6): the
shared block consumes the hidden stream directly (no embedding concat) and is
re-applied with identical weights (no per-invocation LoRA).  Parameter count
and dataflow otherwise follow the paper: 38 Mamba2 blocks, shared block every
6, MHA attention (kv=heads), d_ff 8192 in the shared MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, KeyGen, embed_init, dense_init, \
    stack_layer_params, NULL_POLICY
from .layers import rmsnorm
from .mamba2 import (init_mamba_params, mamba2_forward, mamba2_decode_step,
                     init_mamba_state, ssm_dims)
from .transformer import (_init_attn, _init_mlp, attn_block_train,
                          attn_block_decode, mlp_block, lm_head)


def _split(cfg: ModelConfig):
    groups = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers % cfg.attn_every
    return groups, tail


def init_params(cfg: ModelConfig, key) -> dict:
    kg = KeyGen(key)
    dt = cfg.param_dtype
    groups, tail = _split(cfg)
    params = {
        "embed": embed_init(kg(), (cfg.padded_vocab, cfg.d_model), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "out_head": dense_init(kg(), (cfg.d_model, cfg.padded_vocab), dt),
        "shared_attn": _init_attn(kg, cfg, dt),
        "shared_mlp": _init_mlp(kg, cfg, dt),
        "groups": stack_layer_params([
            stack_layer_params([
                {"mamba": init_mamba_params(kg, cfg, dt),
                 "norm": jnp.ones((cfg.d_model,), dt)}
                for _ in range(cfg.attn_every)])
            for _ in range(groups)]),
    }
    if tail:
        params["tail"] = stack_layer_params([
            {"mamba": init_mamba_params(kg, cfg, dt),
             "norm": jnp.ones((cfg.d_model,), dt)}
            for _ in range(tail)])
    return params


def _mamba_block(p, x, cfg, state, policy):
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    if state is None:
        out, fin = mamba2_forward(p["mamba"], h, cfg, policy=policy)
    else:
        out, fin = mamba2_forward(p["mamba"], h, cfg, initial_state=state,
                                  policy=policy)
    return x + out, fin


def forward_train(params, tokens, cfg: ModelConfig, *, vision_embeds=None,
                  policy=NULL_POLICY, remat: bool = True):
    from .transformer import cast_params
    params = cast_params(params, cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = policy.act(x, "residual")
    groups, tail = _split(cfg)

    def mamba_scan(x, stacked):
        def body(x, p):
            x, _ = _mamba_block(p, x, cfg, None, policy)
            return policy.act(x, "residual"), None
        body = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(body, x, stacked)
        return x

    def group_body(x, gp):
        x = mamba_scan(x, gp)
        x, _ = attn_block_train(params["shared_attn"], x, cfg, positions,
                                policy)
        x = mlp_block(params["shared_mlp"], x, cfg, policy)
        return policy.act(x, "residual"), None

    x, _ = jax.lax.scan(group_body, x, params["groups"])
    if tail:
        x = mamba_scan(x, params["tail"])
    return x, jnp.float32(0.0)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    groups, tail = _split(cfg)
    d_in, H, P, N = ssm_dims(cfg)
    conv_ch = d_in + 2 * N
    mk = lambda n: {
        "conv": jnp.zeros((n, batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((n, batch, H, P, N), jnp.float32),
    }
    cache = {
        "mamba": mk(groups * cfg.attn_every + tail),
        "k": jnp.zeros((groups, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((groups, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    return cache


def forward_prefill(params, tokens, cfg: ModelConfig, cache: dict, *,
                    vision_embeds=None, policy=NULL_POLICY):
    from .transformer import cast_params
    params = cast_params(params, cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    groups, tail = _split(cfg)
    mamba_states, ks, vs = [], [], []

    def mamba_seq(x, stacked, n):
        sts = []
        for i in range(n):                  # unrolled: n <= attn_every (6)
            p = jax.tree_util.tree_map(lambda a: a[i], stacked)
            x, st = _mamba_block(p, x, cfg, None, policy)
            sts.append(st)
        return x, sts

    for g in range(groups):
        gp = jax.tree_util.tree_map(lambda a: a[g], params["groups"])
        x, sts = mamba_seq(x, gp, cfg.attn_every)
        mamba_states += sts
        x, (k, v) = attn_block_train(params["shared_attn"], x, cfg,
                                     positions, policy)
        x = mlp_block(params["shared_mlp"], x, cfg, policy)
        ks.append(k)
        vs.append(v)
    if tail:
        x, sts = mamba_seq(x, params["tail"], tail)
        mamba_states += sts

    cache = dict(cache)
    cache["mamba"] = stack_layer_params(mamba_states)
    kpad = jnp.stack(ks).astype(cache["k"].dtype)
    vpad = jnp.stack(vs).astype(cache["v"].dtype)
    cache["k"] = jax.lax.dynamic_update_slice(cache["k"], kpad,
                                              (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(cache["v"], vpad,
                                              (0, 0, 0, 0, 0))
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    return cache, x[:, -1:]


def forward_decode(params, tokens, cfg: ModelConfig, cache: dict, *,
                   vision_embeds=None, policy=NULL_POLICY):
    from .transformer import cast_params
    params = cast_params(params, cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    pos = cache["pos"]
    groups, tail = _split(cfg)
    new_m, new_k, new_v = [], [], []

    def mamba_seq(x, stacked, states, offset, n):
        for i in range(n):
            p = jax.tree_util.tree_map(lambda a: a[i], stacked)
            st = jax.tree_util.tree_map(lambda a: a[offset + i], states)
            h = rmsnorm(x, p["norm"], cfg.norm_eps)
            out, fin = mamba2_decode_step(p["mamba"], h, st, cfg,
                                          policy=policy)
            x = x + out
            new_m.append(fin)
        return x

    off = 0
    for g in range(groups):
        gp = jax.tree_util.tree_map(lambda a: a[g], params["groups"])
        x = mamba_seq(x, gp, cache["mamba"], off, cfg.attn_every)
        off += cfg.attn_every
        x, k_new, v_new = attn_block_decode(
            params["shared_attn"], x, cfg, pos, cache["k"][g], cache["v"][g],
            policy)
        x = mlp_block(params["shared_mlp"], x, cfg, policy)
        new_k.append(k_new)
        new_v.append(v_new)
    if tail:
        x = mamba_seq(x, params["tail"], cache["mamba"], off, tail)

    cache = dict(cache)
    cache["mamba"] = stack_layer_params(new_m)
    cache["k"] = jnp.stack(new_k)
    cache["v"] = jnp.stack(new_v)
    cache["pos"] = pos + 1
    logits = lm_head(params, x, cfg, policy)
    return logits, cache
