from .common import ModelConfig, NULL_POLICY, param_count
from .api import Model, build_model, SHAPES, is_subquadratic

__all__ = ["ModelConfig", "NULL_POLICY", "param_count", "Model",
           "build_model", "SHAPES", "is_subquadratic"]
