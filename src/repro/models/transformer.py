"""Attention-based model families: dense / moe / vlm / audio.

One parameterized decoder-only stack covers:
  dense  — mistral-nemo, chatglm3, minicpm, qwen3 (GQA, partial RoPE, qk-norm,
           scaled residuals)
  moe    — llama4-scout (all-MoE), llama4-maverick (alternating dense/MoE),
           shared expert + top-1 routed experts
  vlm    — llava-next: precomputed vision patch embeddings (frontend stub)
           are prepended to the token sequence
  audio  — musicgen: K codebooks summed at the input, K output heads

Layers execute under lax.scan with stacked parameters (homogeneous stacks; MoE
interleaving scans over superblocks of ``moe_every`` layers).  Attention is
the blocked online-softmax implementation in layers.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .common import (ModelConfig, KeyGen, dense_init, embed_init,
                     stack_layer_params, NULL_POLICY)
from .layers import (rmsnorm, rope_cos_sin, apply_rope, flash_attention,
                     decode_attention, swiglu)
from .moe import init_moe_params, moe_layer


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _init_attn(kg, cfg: ModelConfig, dtype):
    M, hd = cfg.d_model, cfg.hd
    p = {
        "norm": jnp.ones((M,), dtype),
        "wq": dense_init(kg(), (M, cfg.n_heads * hd), dtype),
        "wk": dense_init(kg(), (M, cfg.n_kv_heads * hd), dtype),
        "wv": dense_init(kg(), (M, cfg.n_kv_heads * hd), dtype),
        "wo": dense_init(kg(), (cfg.n_heads * hd, M), dtype,
                         scale=1.0 / np.sqrt(cfg.n_heads * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _init_mlp(kg, cfg: ModelConfig, dtype):
    M, F = cfg.d_model, cfg.d_ff
    return {
        "norm": jnp.ones((M,), dtype),
        "w_gate": dense_init(kg(), (M, F), dtype),
        "w_up": dense_init(kg(), (M, F), dtype),
        "w_down": dense_init(kg(), (F, M), dtype, scale=1.0 / np.sqrt(F)),
    }


def _is_moe_layer(cfg: ModelConfig, layer_idx: int) -> bool:
    """MoE sits on the last slot of each ``moe_every`` superblock."""
    return cfg.n_experts > 0 and (layer_idx % cfg.moe_every == cfg.moe_every - 1)


def init_params(cfg: ModelConfig, key) -> dict:
    kg = KeyGen(key)
    dt = cfg.param_dtype
    V = cfg.padded_vocab
    params: dict = {
        "embed": embed_init(kg(), (V, cfg.d_model), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if cfg.n_codebooks:          # musicgen: per-codebook embeddings + heads
        params["embed"] = embed_init(
            kg(), (cfg.n_codebooks, V, cfg.d_model), dt)
        params["out_head"] = dense_init(
            kg(), (cfg.n_codebooks, cfg.d_model, V), dt)
    elif not cfg.tie_embeddings:
        params["out_head"] = dense_init(kg(), (cfg.d_model, V), dt)

    n_super = cfg.n_layers // cfg.moe_every if cfg.n_experts else cfg.n_layers
    per = []
    for s in range(n_super):
        block = {}
        if cfg.n_experts:
            for j in range(cfg.moe_every):
                li = s * cfg.moe_every + j
                block[f"attn{j}"] = _init_attn(kg, cfg, dt)
                if _is_moe_layer(cfg, li):
                    block[f"moe{j}"] = init_moe_params(kg, cfg, dt)
                    block[f"moe{j}_norm"] = jnp.ones((cfg.d_model,), dt)
                else:
                    block[f"mlp{j}"] = _init_mlp(kg, cfg, dt)
        else:
            block["attn0"] = _init_attn(kg, cfg, dt)
            block["mlp0"] = _init_mlp(kg, cfg, dt)
        per.append(block)
    params["layers"] = stack_layer_params(per)
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _qkv(p, x, cfg: ModelConfig, positions, policy):
    B, S, _ = x.shape
    hd = cfg.hd
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q = (h @ p["wq"].astype(x.dtype)).reshape(B, S, cfg.n_heads, hd)
    k = (h @ p["wk"].astype(x.dtype)).reshape(B, S, cfg.n_kv_heads, hd)
    v = (h @ p["wv"].astype(x.dtype)).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    rot = int(hd * cfg.rotary_pct)
    cos, sin = rope_cos_sin(positions, rot - rot % 2, cfg.rope_theta)
    q = apply_rope(q, cos, sin, cfg.rotary_pct)
    k = apply_rope(k, cos, sin, cfg.rotary_pct)
    q = policy.act(q, "attn_q")
    return q, k, v


def repeat_kv(x: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B,S,Hkv,D) -> (B,S,Hq,D): single head axis keeps TP sharding stable
    through the flash scans (grouped layouts reshard every iteration)."""
    if groups == 1:
        return x
    B, S, Hkv, D = x.shape
    return jnp.repeat(x, groups, axis=2)


def attn_block_train(p, x, cfg: ModelConfig, positions, policy):
    q, k, v = _qkv(p, x, cfg, positions, policy)
    kr = policy.act(repeat_kv(k, cfg.q_groups), "attn_q")
    vr = policy.act(repeat_kv(v, cfg.q_groups), "attn_q")
    o = flash_attention(q, kr, vr, causal=True, q_block=cfg.q_block,
                        kv_block=cfg.kv_block,
                        softcap=cfg.attn_logit_softcap,
                        scores_bf16=cfg.attn_scores_bf16,
                        causal_skip=cfg.causal_skip, policy=policy)
    o = o.reshape(x.shape[0], x.shape[1], -1) @ p["wo"].astype(x.dtype)
    return x + o * cfg.residual_scale, (k, v)


def attn_block_decode(p, x, cfg: ModelConfig, pos, k_cache, v_cache, policy):
    """x (B,1,M); pos (B,) index of the new token; caches (B,Smax,Hkv,hd)."""
    q, k, v = _qkv(p, x, cfg, pos[:, None], policy)
    k_cache = jax.vmap(
        lambda c, upd, i: jax.lax.dynamic_update_slice_in_dim(c, upd, i, 0)
    )(k_cache, k[:, 0:1].astype(k_cache.dtype), pos)
    v_cache = jax.vmap(
        lambda c, upd, i: jax.lax.dynamic_update_slice_in_dim(c, upd, i, 0)
    )(v_cache, v[:, 0:1].astype(v_cache.dtype), pos)
    o = decode_attention(q, k_cache.astype(x.dtype), v_cache.astype(x.dtype),
                         pos + 1, softcap=cfg.attn_logit_softcap,
                         policy=policy)
    o = o.reshape(x.shape[0], 1, -1) @ p["wo"].astype(x.dtype)
    return x + o * cfg.residual_scale, k_cache, v_cache


def mlp_block(p, x, cfg: ModelConfig, policy):
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    return x + swiglu(h, p["w_gate"].astype(x.dtype),
                      p["w_up"].astype(x.dtype),
                      p["w_down"].astype(x.dtype),
                      policy=policy) * cfg.residual_scale


def ffn_or_moe(block, j, x, cfg: ModelConfig, layer_idx, policy):
    """Returns (x, aux_loss)."""
    if f"moe{j}" in block:
        h = rmsnorm(x, block[f"moe{j}_norm"], cfg.norm_eps)
        out, aux = moe_layer(block[f"moe{j}"], h, cfg, policy=policy)
        return x + out * cfg.residual_scale, aux
    return mlp_block(block[f"mlp{j}"], x, cfg, policy), 0.0


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ModelConfig, vision_embeds=None):
    """tokens (B,S) or (B,S,K) -> (B,S',M) with optional vision prefix."""
    emb = params["embed"]
    if cfg.n_codebooks:
        x = sum(jnp.take(emb[k], tokens[..., k], axis=0)
                for k in range(cfg.n_codebooks))
    else:
        x = jnp.take(emb, tokens, axis=0)
    x = x.astype(cfg.compute_dtype) * cfg.scale_emb
    if cfg.n_vis_tokens and vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
    return x


def lm_head(params, x, cfg: ModelConfig, policy=NULL_POLICY):
    """x (B,S,M) -> logits (B,S,V) or (B,S,K,V) fp32."""
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.n_codebooks:
        w = params["out_head"].astype(h.dtype)          # (K,M,V)
        logits = jnp.einsum("bsm,kmv->bskv", h, w)
    elif cfg.tie_embeddings:
        logits = h @ params["embed"].astype(h.dtype).T
    else:
        logits = h @ params["out_head"].astype(h.dtype)
    logits = policy.act(logits.astype(jnp.float32), "logits")
    if cfg.padded_vocab != cfg.vocab_size:
        logits = logits[..., :cfg.vocab_size]
    return logits


# ---------------------------------------------------------------------------
# full forward passes
# ---------------------------------------------------------------------------

def cast_params(params, cfg: ModelConfig):
    """fp32 -> compute-dtype cast at the sharded source, so FSDP all-gathers
    move bf16 instead of fp32 (cfg.cast_params_once; §Perf)."""
    if not cfg.cast_params_once:
        return params
    import jax as _jax
    return _jax.tree_util.tree_map(
        lambda p: p.astype(cfg.compute_dtype)
        if p.ndim >= 2 and p.dtype == jnp.float32 else p, params)


def forward_train(params, tokens, cfg: ModelConfig, *, vision_embeds=None,
                  policy=NULL_POLICY, remat: bool = True):
    """Returns (hidden (B,S',M), aux_loss).  Head/loss applied by the caller
    (train/losses.py chunks the vocab projection)."""
    params = cast_params(params, cfg)
    x = embed_tokens(params, tokens, cfg, vision_embeds)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = policy.act(x, "residual")

    def superblock(carry, block):
        x, aux = carry
        for j in range(cfg.moe_every if cfg.n_experts else 1):
            x, (k, v) = attn_block_train(block[f"attn{j}"], x, cfg,
                                         positions, policy)
            x = policy.act(x, "residual")
            x, a = ffn_or_moe(block, j, x, cfg, None, policy)
            x = policy.act(x, "residual")
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(superblock) if remat else superblock
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["layers"])
    return x, aux


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> dict:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def forward_prefill(params, tokens, cfg: ModelConfig, cache: dict, *,
                    vision_embeds=None, policy=NULL_POLICY):
    """Run the prompt, fill the KV cache, return (cache, last-token hidden)."""
    params = cast_params(params, cfg)
    x = embed_tokens(params, tokens, cfg, vision_embeds)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = policy.act(x, "residual")
    n_attn = cfg.moe_every if cfg.n_experts else 1

    def superblock(carry, block):
        x = carry
        ks, vs = [], []
        for j in range(n_attn):
            x, (k, v) = attn_block_train(block[f"attn{j}"], x, cfg,
                                         positions, policy)
            x, _ = ffn_or_moe(block, j, x, cfg, None, policy)
            x = policy.act(x, "residual")
            ks.append(k)
            vs.append(v)
        return x, (jnp.stack(ks), jnp.stack(vs))

    x, (ks, vs) = jax.lax.scan(superblock, x, params["layers"])
    # ks: (n_super, n_attn, B, S, Hkv, hd) -> (L, B, S, Hkv, hd)
    L = cfg.n_layers
    ks = ks.reshape(L, B, S, cfg.n_kv_heads, cfg.hd).astype(cache["k"].dtype)
    vs = vs.reshape(L, B, S, cfg.n_kv_heads, cfg.hd).astype(cache["v"].dtype)
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], ks, (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], vs, (0, 0, 0, 0, 0))
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    return cache, x[:, -1:]


def forward_decode(params, tokens, cfg: ModelConfig, cache: dict, *,
                   vision_embeds=None, policy=NULL_POLICY):
    """One decode step.  tokens (B,1)[,K] -> (logits (B,1,V)[,K,V], cache).

    The stacked KV cache rides the layer scan as a CARRY with per-layer
    dynamic-update-slice, so XLA updates the buffer in place.  (Emitting
    per-layer caches as scan ys restacks the whole cache every token —
    ~150x the minimal decode HBM traffic; §Perf qwen3 decode log.)"""
    params = cast_params(params, cfg)
    x = embed_tokens(params, tokens, cfg, None)
    pos = cache["pos"]
    x = policy.act(x, "residual")
    n_attn = cfg.moe_every if cfg.n_experts else 1

    def superblock(carry, block):
        x, kc, vc, li = carry                  # kc/vc: full (L,B,S,Hkv,hd)
        for j in range(n_attn):
            k_l = jax.lax.dynamic_index_in_dim(kc, li, 0, keepdims=False)
            v_l = jax.lax.dynamic_index_in_dim(vc, li, 0, keepdims=False)
            x, k_new, v_new = attn_block_decode(
                block[f"attn{j}"], x, cfg, pos, k_l, v_l, policy)
            kc = jax.lax.dynamic_update_index_in_dim(
                kc, k_new.astype(kc.dtype), li, 0)
            vc = jax.lax.dynamic_update_index_in_dim(
                vc, v_new.astype(vc.dtype), li, 0)
            x, _ = ffn_or_moe(block, j, x, cfg, None, policy)
            li = li + 1
        return (x, kc, vc, li), None

    (x, kc, vc, _), _ = jax.lax.scan(
        superblock, (x, cache["k"], cache["v"], jnp.int32(0)),
        params["layers"])
    cache = dict(cache)
    cache["k"] = kc
    cache["v"] = vc
    cache["pos"] = pos + 1
    logits = lm_head(params, x, cfg, policy)
    return logits, cache
