"""Switch-style top-1 MoE with capacity-bounded scatter dispatch + optional
shared expert (llama4 family).

Dispatch strategy (DESIGN.md §4): groups = batch elements.  Each batch row
scatters its tokens into (E, C) slots (C = S/E * capacity_factor); the
dispatched tensor (B, E, C, M) carries a sharding hint P(data, model, ...) so
GSPMD materializes the expert-parallel all-to-all; expert FFNs run as stacked
einsums over the expert axis; tokens gather back and the inverse all-to-all
emerges.  Over-capacity tokens are dropped (their residual passes through),
standard Switch semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, NULL_POLICY


def moe_capacity(cfg: ModelConfig, seq: int) -> int:
    c = int(np.ceil(seq / cfg.n_experts * cfg.capacity_factor))
    return max(8, ((c + 7) // 8) * 8)      # pad to lane-friendly size


def init_moe_params(kg, cfg: ModelConfig, dtype):
    from .common import dense_init
    M, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": dense_init(kg(), (M, E), dtype),
        "w_gate": dense_init(kg(), (E, M, F), dtype),
        "w_up": dense_init(kg(), (E, M, F), dtype),
        "w_down": dense_init(kg(), (E, F, M), dtype, scale=1.0 / np.sqrt(F)),
    }
    if cfg.n_shared_experts:
        p["shared_gate"] = dense_init(kg(), (M, F * cfg.n_shared_experts), dtype)
        p["shared_up"] = dense_init(kg(), (M, F * cfg.n_shared_experts), dtype)
        p["shared_down"] = dense_init(kg(), (F * cfg.n_shared_experts, M), dtype,
                                      scale=1.0 / np.sqrt(F))
    return p


def moe_layer(p: dict, x: jnp.ndarray, cfg: ModelConfig,
              policy=NULL_POLICY):
    """x (B, S, M) -> (out (B, S, M), aux_loss scalar)."""
    B, S, M = x.shape
    E = cfg.n_experts
    C = moe_capacity(cfg, S)

    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, e_idx = jnp.max(probs, -1), jnp.argmax(probs, -1)         # (B,S)

    # ---- load-balancing aux loss (Switch eq. 4-6) --------------------------
    onehot = jax.nn.one_hot(e_idx, E, dtype=jnp.float32)            # (B,S,E)
    density = onehot.mean(axis=1)                                   # (B,E)
    density_proxy = probs.mean(axis=1)
    aux = (density * density_proxy).sum(-1).mean() * E * cfg.router_aux_coef

    # ---- capacity assignment: position within expert, per batch row --------
    pos_in_e = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1.0  # (B,S)
    pos_in_e = pos_in_e.astype(jnp.int32)
    keep = pos_in_e < C                                             # (B,S)
    slot = e_idx * C + jnp.where(keep, pos_in_e, 0)                 # (B,S)

    # ---- scatter dispatch: (B, S, M) -> (B, E*C, M) -------------------------
    def scatter_row(slots, val, kp):
        buf = jnp.zeros((E * C, M), x.dtype)
        return buf.at[slots].add(val * kp[:, None].astype(x.dtype))

    dispatched = jax.vmap(scatter_row)(slot, x, keep)               # (B,E*C,M)
    dispatched = dispatched.reshape(B, E, C, M)
    dispatched = policy.act(dispatched, "moe_dispatch")             # all-to-all

    # ---- expert FFNs (E sharded over 'model') -------------------------------
    wg = p["w_gate"].astype(x.dtype)
    wu = p["w_up"].astype(x.dtype)
    wd = p["w_down"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("becm,emf->becf", dispatched, wg)) \
        * jnp.einsum("becm,emf->becf", dispatched, wu)
    h = policy.act(h, "moe_hidden")
    eout = jnp.einsum("becf,efm->becm", h, wd)                      # (B,E,C,M)
    eout = policy.act(eout, "moe_combine")                          # a2a back

    # ---- gather combine ------------------------------------------------------
    flat = eout.reshape(B, E * C, M)
    out = jax.vmap(lambda f, s: f[s])(flat, slot)                   # (B,S,M)
    out = out * (gate * keep.astype(gate.dtype))[..., None].astype(x.dtype)

    # ---- shared expert (always-on dense path) --------------------------------
    if cfg.n_shared_experts:
        sh = jax.nn.silu(x @ p["shared_gate"].astype(x.dtype)) \
            * (x @ p["shared_up"].astype(x.dtype))
        out = out + sh @ p["shared_down"].astype(x.dtype)
    return out, aux
