"""llama4-scout-17b-a16e [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 routed experts top-1 + 1 shared expert on every layer
(early-fusion multimodal frontend stubbed out — text backbone only).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=202048,
        rope_theta=500_000.0,
        n_experts=16, moe_top_k=1, moe_every=1, n_shared_experts=1,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        n_experts=4, moe_top_k=1, moe_every=1, n_shared_experts=1,
        q_block=16, kv_block=32,
    )
