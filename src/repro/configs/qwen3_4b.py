"""qwen3-4b [dense]: 36L d=2560 32H (GQA kv=8) head_dim=128 d_ff=9728
vocab=151936 — qk-norm on per-head q/k.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b", family="dense",
        n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=9728, vocab_size=151936,
        rope_theta=1_000_000.0, qk_norm=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=512, qk_norm=True, q_block=16, kv_block=32,
    )
