"""zamba2-1.2b [hybrid]: 38 Mamba2 layers (d_state=64) + ONE shared
attention+MLP block (32H MHA, d_ff=8192) applied every 6 layers, d=2048,
vocab=32000.  [arXiv:2411.15242; hf]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid_ssm",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, vocab_size=32000,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
        attn_every=6, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid_ssm",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4,
        attn_every=2, tie_embeddings=True, ssm_chunk=16,
        q_block=16, kv_block=32,
    )
