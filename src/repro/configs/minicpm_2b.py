"""minicpm-2b [dense]: 40L d=2304 36H (MHA kv=36) d_ff=5760 vocab=122753.
Llama-like arch with mup-style scaling (scale_emb=12, scale_depth=1.4) and
the WSD learning-rate schedule (optim/schedules.py).  [arXiv:2404.06395; hf]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b", family="dense",
        n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, head_dim=64,
        d_ff=5760, vocab_size=122753,
        rope_theta=10_000.0, scale_emb=12.0, scale_depth=1.4,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=96, vocab_size=511, scale_emb=12.0, scale_depth=1.4,
        tie_embeddings=True, q_block=16, kv_block=32,
    )
