"""mistral-nemo-12b [dense]: 40L d=5120 32H (GQA kv=8) head_dim=128
d_ff=14336 vocab=131072 — 128k context (rope theta 1M).
[hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=131072,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=160, vocab_size=512, q_block=16, kv_block=32,
    )
