"""Assigned-architecture registry: one module per architecture, each exposing
``config()`` (the exact published dimensions) and ``smoke_config()`` (a
reduced same-family config for CPU smoke tests)."""
from __future__ import annotations

import importlib

ARCHS = [
    "llava_next_34b",
    "llama4_scout_17b_a16e",
    "llama4_maverick_400b_a17b",
    "mistral_nemo_12b",
    "chatglm3_6b",
    "minicpm_2b",
    "qwen3_4b",
    "zamba2_1p2b",
    "musicgen_medium",
    "xlstm_1p3b",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}
_ALIAS.update({
    "llava-next-34b": "llava_next_34b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "chatglm3-6b": "chatglm3_6b",
    "minicpm-2b": "minicpm_2b",
    "qwen3-4b": "qwen3_4b",
    "zamba2-1.2b": "zamba2_1p2b",
    "musicgen-medium": "musicgen_medium",
    "xlstm-1.3b": "xlstm_1p3b",
})


def _module(name: str):
    mod_name = _ALIAS.get(name, name)
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(name: str, smoke: bool = False):
    m = _module(name)
    return m.smoke_config() if smoke else m.config()


def list_archs():
    return list(ARCHS)
