"""llava-next-34b [vlm]: 60L d=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
AnyRes vision tiling is a frontend STUB: input_specs supplies precomputed
patch embeddings (n_vis_tokens per image, prepended to the text sequence).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
        d_ff=20480, vocab_size=64000,
        rope_theta=5_000_000.0, n_vis_tokens=576,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, n_vis_tokens=8,
        q_block=16, kv_block=32,
    )
