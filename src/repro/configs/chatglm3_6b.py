"""chatglm3-6b [dense]: 28L d=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
"RoPE 2d": rotary applied to half the head dims (rotary_pct=0.5).
[arXiv:2406.12793; hf]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b", family="dense",
        n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
        d_ff=13696, vocab_size=65024,
        rope_theta=10_000.0, rotary_pct=0.5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=512, rotary_pct=0.5, q_block=16, kv_block=32,
    )
