"""musicgen-medium [audio]: 48L d=1536 24H (MHA kv=24) d_ff=6144 vocab=2048.
Decoder-only LM over EnCodec tokens: 4 codebooks summed at the input, 4
output heads (the EnCodec encoder/decoder is the frontend stub — tokens are
the model inputs).  [arXiv:2306.05284; hf]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
        d_ff=6144, vocab_size=2048,
        rope_theta=10_000.0, n_codebooks=4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128, n_codebooks=4, q_block=16, kv_block=32,
    )
