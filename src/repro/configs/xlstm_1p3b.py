"""xlstm-1.3b [ssm]: 48 blocks d=2048, 4 heads, d_ff=0 (no separate FFN),
vocab=50304 — xLSTM[7:1]: superblocks of 7 mLSTM + 1 sLSTM.
[arXiv:2405.04517; unverified]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="xlstm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
        d_ff=0, vocab_size=50304,
        slstm_period=8, proj_factor=2.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="xlstm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=0, vocab_size=512,
        slstm_period=2, proj_factor=2.0, ssm_chunk=16,
        q_block=16, kv_block=32,
    )
