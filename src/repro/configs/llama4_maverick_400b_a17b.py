"""llama4-maverick-400b-a17b [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 routed experts top-1 + shared expert on every SECOND
layer (alternating dense/MoE, matching the released interleave and the ~400B
total / 17B active budget).  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=202048,
        rope_theta=500_000.0,
        n_experts=128, moe_top_k=1, moe_every=2, n_shared_experts=1,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-smoke", family="moe",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        n_experts=8, moe_top_k=1, moe_every=2, n_shared_experts=1,
        q_block=16, kv_block=32,
    )
