"""Pure-JAX optimizers (no optax in the container): AdamW and Adafactor.

Functional API:
    opt = adamw(lr_schedule, ...)
    state = opt.init(params)
    params, state = opt.apply(params, grads, state)

Optimizer state mirrors the parameter pytree, so pjit shards it exactly like
the parameters (ZeRO-style by construction — see distributed/shardings.py).
Adafactor (factored second moments, no first moment by default) is the
default for llama4-maverick: 400B parameters with AdamW fp32 m+v would not
fit 256 x 16 GiB (DESIGN.md §4 memory budget).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    apply: Callable        # (params, grads, state) -> (params, state, metrics)
    name: str = "opt"


def adamw(lr_fn: Callable, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          max_grad_norm: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def apply(params, grads, state):
        step = state["step"] + 1
        lr = lr_fn(step)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            wd = weight_decay if p.ndim >= 2 else 0.0
            new_p = p.astype(jnp.float32) - lr * (u + wd * p.astype(jnp.float32))
            return new_p.astype(p.dtype), m, v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}, \
            {"grad_norm": gnorm, "lr": lr}

    return Optimizer(init=init, apply=apply, name="adamw")


def adafactor(lr_fn: Callable, eps: float = 1e-30, clip_threshold: float = 1.0,
              decay_rate: float = 0.8, weight_decay: float = 0.0,
              max_grad_norm: float = 1.0) -> Optimizer:
    """Factored second moments over the last two dims of >=2D params; O(n+m)
    state instead of O(n*m) — the difference between maverick fitting on a
    single pod or not."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def per(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"f": jax.tree_util.tree_map(per, params),
                "step": jnp.zeros((), jnp.int32)}

    def apply(params, grads, state):
        step = state["step"] + 1
        lr = lr_fn(step)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        beta = 1.0 - step.astype(jnp.float32) ** (-decay_rate)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr / jnp.maximum(vr.mean(-1, keepdims=True), eps)
                         )[..., None] * vc[..., None, :]
                u = g * jax.lax.rsqrt(denom + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            # update clipping (Adafactor's RMS-1 rule)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            wd = weight_decay if p.ndim >= 2 else 0.0
            new_p = p.astype(jnp.float32) - lr * u - lr * wd * p.astype(jnp.float32)
            return new_p.astype(p.dtype), new_s

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["f"])
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_f = treedef.unflatten([o[1] for o in out])
        return new_p, {"f": new_f, "step": step}, {"grad_norm": gnorm, "lr": lr}

    return Optimizer(init=init, apply=apply, name="adafactor")


def make_optimizer(name: str, lr_fn: Callable, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr_fn, **kw)
    if name == "adafactor":
        return adafactor(lr_fn, **kw)
    raise ValueError(name)
