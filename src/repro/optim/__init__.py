from .optimizers import (Optimizer, adamw, adafactor, make_optimizer,
                         clip_by_global_norm, global_norm)
from .schedules import wsd, cosine, constant

__all__ = ["Optimizer", "adamw", "adafactor", "make_optimizer",
           "clip_by_global_norm", "global_norm", "wsd", "cosine", "constant"]
