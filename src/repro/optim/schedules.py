"""Learning-rate schedules.  WSD (Warmup-Stable-Decay) is first-class because
minicpm-2b trains with it (arXiv:2404.06395 §4)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup):
    return jnp.minimum(1.0, (step + 1) / max(1, warmup))


def wsd(peak_lr: float, warmup: int, stable: int, decay: int,
        floor_frac: float = 0.1):
    """Warmup -> constant plateau -> exponential-ish decay to floor."""
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = linear_warmup(step, warmup)
        in_decay = jnp.clip((step - warmup - stable) / max(1, decay), 0.0, 1.0)
        decay_mult = (1.0 - in_decay) + in_decay * floor_frac
        return peak_lr * warm * decay_mult
    return f


def cosine(peak_lr: float, warmup: int, total: int, floor_frac: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = linear_warmup(step, warmup)
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return peak_lr * warm * (floor_frac + (1 - floor_frac) * cos)
    return f


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)
