from .losses import chunked_cross_entropy
from .train_step import TrainState, make_train_state, build_train_step, \
    build_loss_fn

__all__ = ["chunked_cross_entropy", "TrainState", "make_train_state",
           "build_train_step", "build_loss_fn"]
