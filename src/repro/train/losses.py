"""Chunked cross-entropy: the vocab projection + softmax run per sequence
chunk under jax.checkpoint, so the full (B, S, V) fp32 logits tensor never
materializes (llama4's 202k vocab x 1M tokens would be ~800 GB fp32).

Handles all model families: plain LM head, tied embeddings, multi-codebook
audio heads, and the vlm vision-prefix offset (no loss on image positions).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, NULL_POLICY
from repro.models.layers import rmsnorm


def _head_weights(params, cfg: ModelConfig):
    if cfg.n_codebooks:
        return params["out_head"]                    # (K, M, V)
    if cfg.tie_embeddings:
        return params["embed"].T                     # (M, V)
    return params["out_head"]


def _chunk_logits(h, w, cfg: ModelConfig, policy):
    """h (B, c, M) -> fp32 logits (B, c, V) or (B, c, K, V), vocab-sharded."""
    if cfg.n_codebooks:
        logits = jnp.einsum("bcm,kmv->bckv", h, w.astype(h.dtype))
    else:
        logits = h @ w.astype(h.dtype)
    logits = policy.act(logits.astype(jnp.float32), "logits")
    if cfg.padded_vocab != cfg.vocab_size:
        # mask storage-padding columns so softmax is over the true vocab
        col = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits


def chunked_cross_entropy(params, hidden, tokens, cfg: ModelConfig, *,
                          chunk: int = 256, policy=NULL_POLICY):
    """hidden (B, S', M) raw (pre-final-norm applied here); tokens (B, S)[,K].
    Returns (mean_nll, metrics).  Next-token loss: position t predicts token
    t+1; vlm vision prefix positions are excluded."""
    B = hidden.shape[0]
    off = cfg.n_vis_tokens if cfg.family == "vlm" else 0
    # positions off..off+S-2 predict tokens 1..S-1
    h = hidden[:, off:hidden.shape[1] - 1]
    labels = tokens[:, 1:]
    T = h.shape[1]
    w = _head_weights(params, cfg)

    pad = (-T) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        lab_pad = [(0, 0), (0, pad)] + ([(0, 0)] if cfg.n_codebooks else [])
        labels = jnp.pad(labels, lab_pad)
    mask = (jnp.arange(h.shape[1]) < T).astype(jnp.float32)[None, :]  # (1,Tp)
    nchunk = h.shape[1] // chunk

    hc = h.reshape(B, nchunk, chunk, -1).transpose(1, 0, 2, 3)
    if cfg.n_codebooks:
        lc = labels.reshape(B, nchunk, chunk, cfg.n_codebooks).transpose(1, 0, 2, 3)
    else:
        lc = labels.reshape(B, nchunk, chunk).transpose(1, 0, 2)
    mc = mask.reshape(1, nchunk, chunk).transpose(1, 0, 2)    # (nchunk,1,chunk)

    @jax.checkpoint
    def one_chunk(carry, xs):
        loss_sum, count = carry
        h_c, l_c, m_c = xs
        h_c = rmsnorm(h_c, params["final_norm"], cfg.norm_eps)
        logits = _chunk_logits(h_c, w, cfg, policy)         # fp32
        lse = jax.nn.logsumexp(logits, axis=-1)
        true = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        nll = lse - true                                    # (B,c)[,K]
        if cfg.n_codebooks:
            nll = nll.mean(-1)
        mm = jnp.broadcast_to(m_c, nll.shape)
        return (loss_sum + (nll * mm).sum(), count + mm.sum()), None

    (loss_sum, count), _ = jax.lax.scan(one_chunk, (jnp.float32(0.0),
                                                    jnp.float32(0.0)),
                                        (hc, lc, mc))
    loss = loss_sum / jnp.maximum(count, 1.0)
    return loss, {"nll": loss, "tokens": count}
