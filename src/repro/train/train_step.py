"""Train-step builder: loss = chunked xent + MoE aux, microbatch gradient
accumulation (lax.scan), optimizer apply.  Family-agnostic via models.api.

The returned ``step(state, batch)`` is a pure function ready for jax.jit with
in/out shardings (train/driver.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.models.common import NULL_POLICY
from repro.optim.optimizers import Optimizer
from .losses import chunked_cross_entropy


@dataclass
class TrainState:
    params: Any
    opt: Any
    step: jnp.ndarray


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt, s.step), None),
    lambda aux, ch: TrainState(*ch))


def make_train_state(model: Model, optimizer: Optimizer, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def build_loss_fn(model: Model, policy=NULL_POLICY, remat: bool = True,
                  loss_chunk: int = 256):
    def loss_fn(params, batch):
        hidden, aux = model.hidden_train(params, batch, policy=policy,
                                         remat=remat)
        nll, metrics = chunked_cross_entropy(params, hidden, batch["tokens"],
                                             model.cfg, chunk=loss_chunk,
                                             policy=policy)
        metrics["aux_loss"] = aux
        return nll + aux, metrics
    return loss_fn


def build_train_step(model: Model, optimizer: Optimizer, *,
                     policy=NULL_POLICY, microbatches: int = 1,
                     remat: bool = True, loss_chunk: int = 256,
                     donate: bool = True) -> Callable:
    loss_fn = build_loss_fn(model, policy, remat, loss_chunk)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            mb = jax.tree_util.tree_map(split, batch)

            def accum(carry, mbatch):
                gsum, lsum = carry
                (l, _), g = grad_fn(state.params, mbatch)
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), _ = jax.lax.scan(accum, (zeros, jnp.float32(0.0)),
                                           mb)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {}
        new_params, new_opt, opt_metrics = optimizer.apply(
            state.params, grads, state.opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        new_state = TrainState(params=new_params, opt=new_opt,
                               step=state.step + 1)
        return new_state, metrics

    return step
