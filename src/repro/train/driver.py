"""End-to-end training driver: config -> mesh -> sharded train loop with
fault tolerance.

Features exercised even on this single-host CPU container (and wired for real
clusters):
  * optional jax.distributed.initialize from env (COORDINATOR/NUM_PROC/RANK);
  * deterministic resumable data pipeline (step-keyed sampling);
  * async sharded checkpointing + atomic rename; restores are **elastic** —
    the mesh may change between runs (checkpoint stores global arrays);
  * SIGTERM/SIGINT preemption handler: checkpoint-then-exit (standard TPU
    preemption notice flow);
  * metrics log (jsonl) with loss/grad-norm/lr/throughput.

Example (CPU smoke):
  PYTHONPATH=src python -m repro.train.driver --arch qwen3-4b --smoke \
      --steps 20 --out /tmp/run1
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.optim import make_optimizer, wsd
from .train_step import make_train_state, build_train_step
from repro.data.pipeline import (ShardSpec, SyntheticShardStore,
                                 CachedShardReader, TokenPipeline)
from repro.checkpoint.store import (AsyncCheckpointer, latest_step,
                                    restore_checkpoint)
from repro.models.common import NULL_POLICY


def maybe_init_distributed() -> None:
    coord = os.environ.get("REPRO_COORDINATOR")
    if coord:
        jax.distributed.initialize(
            coordinator_address=coord,
            num_processes=int(os.environ["REPRO_NUM_PROCESSES"]),
            process_id=int(os.environ["REPRO_PROCESS_ID"]))


def train(arch: str, *, smoke: bool = True, steps: int = 20,
          out_dir: str = "/tmp/repro_run", global_batch: int = 8,
          seq_len: int = 64, ckpt_every: int = 5, microbatches: int = 1,
          mesh=None, policy=None, seed: int = 0,
          lr: float = 1e-3, resume: bool = True,
          optimizer: str = "adamw") -> dict:
    cfg = get_config(arch, smoke=smoke)
    model = build_model(cfg)
    opt = make_optimizer(optimizer, wsd(lr, max(1, steps // 10), steps, steps))
    policy = policy or NULL_POLICY

    spec = ShardSpec(n_shards=64, tokens_per_shard=4096,
                     vocab_size=cfg.vocab_size, seed=seed)
    pipeline = TokenPipeline(CachedShardReader(SyntheticShardStore(spec),
                                               capacity_shards=8, seed=seed),
                             seq_len=seq_len, global_batch=global_batch,
                             seed=seed)

    state = make_train_state(model, opt, jax.random.PRNGKey(seed))
    ckpt_dir = os.path.join(out_dir, "ckpt")
    ckpt = AsyncCheckpointer(ckpt_dir)
    start_step = 0
    last = latest_step(ckpt_dir) if resume else None
    if last is not None:
        shardings = None
        if mesh is not None and hasattr(policy, "shardings"):
            shardings = policy.shardings(state)
        payload = restore_checkpoint(
            ckpt_dir, last, {"state": state, "data": pipeline.state_dict()},
            {"state": shardings, "data": None} if shardings else None)
        state = payload["state"]
        pipeline.load_state_dict(payload["data"])
        start_step = int(state.step)
        print(f"[train] resumed from step {start_step}", flush=True)

    step_fn = build_train_step(model, opt, policy=policy,
                               microbatches=microbatches, loss_chunk=32)
    if mesh is not None and hasattr(policy, "shardings"):
        step_fn = jax.jit(step_fn,
                          in_shardings=(policy.shardings(state), None))
    else:
        step_fn = jax.jit(step_fn)

    # -- preemption: checkpoint then exit -------------------------------------
    preempted = {"flag": False}

    def _handler(signum, frame):
        preempted["flag"] = True
    old_handlers = {s: signal.signal(s, _handler)
                    for s in (signal.SIGTERM, signal.SIGINT)}

    os.makedirs(out_dir, exist_ok=True)
    log_path = os.path.join(out_dir, "metrics.jsonl")
    metrics_out = {}
    t_start = time.time()
    with open(log_path, "a") as logf:
        for step in range(start_step, steps):
            if cfg.n_codebooks:
                b = pipeline.next_batch()
                b["tokens"] = np.repeat(b["tokens"][..., None],
                                        cfg.n_codebooks, -1)
            else:
                b = pipeline.next_batch()
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            rec = {"step": step + 1, "loss": loss,
                   "grad_norm": float(metrics.get("grad_norm", 0.0)),
                   "lr": float(metrics.get("lr", 0.0)),
                   "tokens_per_s": global_batch * seq_len
                   / max(1e-9, time.time() - t0)}
            rec.update(pipeline.cache_stats)
            logf.write(json.dumps(rec) + "\n")
            logf.flush()
            metrics_out = rec
            if (step + 1) % ckpt_every == 0 or preempted["flag"] \
                    or step + 1 == steps:
                ckpt.save(int(state.step),
                          {"state": state, "data": pipeline.state_dict()})
            if preempted["flag"]:
                print(f"[train] preempted at step {step + 1}; "
                      "checkpoint written", flush=True)
                break
    ckpt.wait()
    for s, h in old_handlers.items():
        signal.signal(s, h)
    metrics_out["wall_s"] = time.time() - t_start
    return metrics_out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--out", default="/tmp/repro_run")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    args = ap.parse_args()
    maybe_init_distributed()
    out = train(args.arch, smoke=args.smoke, steps=args.steps,
                out_dir=args.out, global_batch=args.global_batch,
                seq_len=args.seq_len, microbatches=args.microbatches,
                optimizer=args.optimizer)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
