"""Deterministic, resumable data pipeline with a W-TinyLFU host shard cache.

The paper's technique applied at the data layer: training corpora live as
tokenized shards on (slow, remote) storage; hosts keep a bounded in-RAM page
cache of decoded shards.  Shard popularity is highly skewed under
sequence-packing curricula and multi-epoch sampling, so the page cache uses
W-TinyLFU retention — the same sketch/admission machinery as the serving
prefix pool (core/wtinylfu.py).

Determinism & fault tolerance:
  * the sample stream is a pure function of (seed, step, host_id) — a
    restarted job replays the identical batch sequence from any step;
  * `state_dict()/load_state_dict()` round-trips the cursor through
    checkpoints (train/driver.py saves it alongside the model).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.core.wtinylfu import WTinyLFU


@dataclass
class ShardSpec:
    n_shards: int
    tokens_per_shard: int
    vocab_size: int
    seed: int = 0


class SyntheticShardStore:
    """Stand-in for remote blob storage: shard i is deterministically
    generated (zipf-ish token stream).  ``fetches`` counts cold reads — the
    metric the W-TinyLFU cache exists to minimize."""

    def __init__(self, spec: ShardSpec):
        self.spec = spec
        self.fetches = 0

    def fetch(self, shard_id: int) -> np.ndarray:
        self.fetches += 1
        rng = np.random.default_rng(
            (self.spec.seed << 20) ^ shard_id)
        # cheap zipf-ish marginal: squared uniform concentrates mass
        u = rng.random(self.spec.tokens_per_shard)
        toks = (u * u * self.spec.vocab_size).astype(np.int32)
        return np.minimum(toks, self.spec.vocab_size - 1)


class CachedShardReader:
    """W-TinyLFU-guarded shard cache in host RAM."""

    def __init__(self, store: SyntheticShardStore, capacity_shards: int = 16,
                 seed: int = 0):
        self.store = store
        self.cache_policy = WTinyLFU(capacity_shards, sample_factor=8,
                                     seed=seed)
        self.payloads: dict[int, np.ndarray] = {}
        self.hits = 0
        self.misses = 0

    def read(self, shard_id: int) -> np.ndarray:
        hit = self.cache_policy.access(shard_id)
        if hit and shard_id in self.payloads:
            self.hits += 1
            return self.payloads[shard_id]
        self.misses += 1
        data = self.store.fetch(shard_id)
        if shard_id in self.cache_policy:
            self.payloads[shard_id] = data
            # drop payloads for keys the policy evicted
            live = set(self.payloads) & (
                set(self.cache_policy.window)
                | set(self.cache_policy.main.probation)
                | set(self.cache_policy.main.protected))
            for k in list(self.payloads):
                if k not in live:
                    del self.payloads[k]
        return data


class TokenPipeline:
    """Packs fixed-length sequences from shards; zipf-skewed shard sampling
    (curriculum/dedup reweighting in real corpora)."""

    def __init__(self, reader: CachedShardReader, *, seq_len: int,
                 global_batch: int, host_id: int = 0, n_hosts: int = 1,
                 shard_alpha: float = 1.0, seed: int = 0):
        self.reader = reader
        self.seq_len = seq_len
        self.batch = global_batch // n_hosts
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.seed = seed
        self.step = 0
        n = reader.store.spec.n_shards
        w = np.arange(1, n + 1, dtype=np.float64) ** (-shard_alpha)
        self._probs = w / w.sum()

    # -- determinism ---------------------------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        h = hashlib.sha256(
            f"{self.seed}:{step}:{self.host_id}".encode()).digest()
        return np.random.default_rng(int.from_bytes(h[:8], "little"))

    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, st: dict) -> None:
        self.step = int(st["step"])

    # -- batches ---------------------------------------------------------------
    def next_batch(self) -> dict:
        rng = self._rng(self.step)
        spec = self.reader.store.spec
        toks = np.empty((self.batch, self.seq_len), np.int32)
        cdf = np.cumsum(self._probs)
        for b in range(self.batch):
            sid = int(np.searchsorted(cdf, rng.random()))
            shard = self.reader.read(sid)
            off = int(rng.integers(0, spec.tokens_per_shard - self.seq_len))
            toks[b] = shard[off:off + self.seq_len]
        self.step += 1
        return {"tokens": toks}

    @property
    def cache_stats(self) -> dict:
        r = self.reader
        n = r.hits + r.misses
        return {"shard_cache_hit_ratio": r.hits / n if n else 0.0,
                "cold_fetches": r.store.fetches}
