"""repro: TinyLFU cache-admission (Einziger, Friedman & Manes 2015) built as a
first-class feature of a multi-pod JAX training/serving framework.

Subpackages:
  core/        the paper's contribution: sketches, admission, W-TinyLFU, policies
  traces/      synthetic workload generators (paper §5 trace families)
  kernels/     Pallas TPU kernels for the sketch hot path (+ jnp oracles)
  models/      assigned architecture zoo (dense/MoE/hybrid-SSM/xLSTM/audio/VLM)
  configs/     one config per assigned architecture
  optim/       optimizers + schedules
  train/       train-step builder, losses, remat + end-to-end train driver
  serve/       paged KV cache + TinyLFU prefix-cache admission + scheduler
               + serving driver
  distributed/ sharding rules, pipeline parallelism, compressed collectives
  checkpoint/  sharded fault-tolerant checkpointing
  data/        deterministic resumable data pipeline w/ W-TinyLFU shard cache
  launch/      TinyLFU experiment drivers (window-adaptation hillclimb)
"""
__version__ = "1.0.0"
