from .shardings import ShardingPolicy
from .pipeline import pipeline_apply
from .compression import compressed_allreduce_int8, compressed_tree_allreduce

__all__ = ["ShardingPolicy", "pipeline_apply", "compressed_allreduce_int8",
           "compressed_tree_allreduce"]
