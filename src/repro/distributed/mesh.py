"""Production mesh construction.  A FUNCTION (not a module-level constant) so
importing this module never touches jax device state."""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count or run on "
            "real hardware")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires >= prod(shape) host devices)."""
    n = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


# ---------------------------------------------------------------------------
# sketch-shard placement (StepSpec.shards — see kernels/sketch_merge.py)
# ---------------------------------------------------------------------------

def shard_placement(n_shards: int, devices=None) -> list:
    """Shard -> device placement map for the sharded frequency sketch.

    Shard ``s`` owns the ``width/n_shards`` counter slice ``s`` of the
    sketch buffers' delta halves plus its slice of the replicated global
    estimate; per-access writes are shard-local, and the once-per-epoch
    ``merge_halve`` fold is the only cross-device exchange (an all-gather
    that refreshes every device's global replica).  Round-robin so shard
    counts above the device count still map (multiple shards per device —
    the single-host simulation is the n_devices=1 special case).
    """
    assert n_shards >= 1
    devices = list(jax.devices()) if devices is None else list(devices)
    assert devices, "shard placement needs at least one device"
    return [devices[s % len(devices)] for s in range(n_shards)]


def make_shard_mesh(n_shards: int, devices=None):
    """1-D ``("shard",)`` mesh over ``min(n_shards, available)`` devices —
    the placement the future multi-device sharded-sketch run will shard the
    delta arrays over (``jax.sharding.NamedSharding`` along axis 0)."""
    devices = list(jax.devices()) if devices is None else list(devices)
    n = min(max(1, n_shards), len(devices))
    return jax.make_mesh((n,), ("shard",), devices=devices[:n])
