"""Production mesh construction.  A FUNCTION (not a module-level constant) so
importing this module never touches jax device state."""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count or run on "
            "real hardware")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires >= prod(shape) host devices)."""
    n = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


# ---------------------------------------------------------------------------
# sketch-shard placement (StepSpec.shards — see kernels/sketch_merge.py)
# ---------------------------------------------------------------------------

def _shard_mesh_size(n_shards: int, n_devices: int) -> int:
    """Devices a ``("shard",)`` mesh uses for ``n_shards`` shards: the
    largest DIVISOR of ``n_shards`` that fits the available devices, so the
    shard-major delta arrays partition evenly along the mesh axis (shards
    are a power of two, so this is the largest power of two <= both)."""
    assert n_shards >= 1 and n_devices >= 1
    n = min(n_shards, n_devices)
    while n_shards % n:
        n -= 1
    return n


def shard_placement(n_shards: int, devices=None) -> list:
    """Shard -> device placement map for the sharded frequency sketch.

    Shard ``s`` owns the ``width/n_shards`` counter slice ``s`` of the
    sketch buffers' delta halves plus its slice of the replicated global
    estimate; per-access writes are shard-local, and the once-per-epoch
    ``merge_halve`` fold is the only cross-device state exchange (an
    all-gather that refreshes every device's global replica).

    BLOCK placement: with ``D`` mesh devices (``_shard_mesh_size`` — the
    largest divisor of ``n_shards`` that fits), device ``d`` owns the
    ``n_shards/D`` consecutive shards ``[d*S/D, (d+1)*S/D)``.  This is
    exactly how ``jax.sharding.NamedSharding``/``shard_map`` split axis 0
    of the shard-major delta arrays over :func:`make_shard_mesh`, so this
    map, the mesh runner (``core.device_simulate`` ``DeviceWTinyLFU``
    ``(mesh=)``), and a sharding-visualizer all describe the same
    placement.  (It used to be round-robin, which contradicted the mesh's
    contiguous split whenever ``n_shards > n_devices`` — ISSUE 5.)
    The single-host simulation is the n_devices=1 special case.
    """
    assert n_shards >= 1
    devices = list(jax.devices()) if devices is None else list(devices)
    assert devices, "shard placement needs at least one device"
    n = _shard_mesh_size(n_shards, len(devices))
    per = n_shards // n
    return [devices[s // per] for s in range(n_shards)]


def make_shard_mesh(n_shards: int, devices=None, require: int = 0):
    """1-D ``("shard",)`` mesh for the multi-device sharded-sketch run
    (``core.device_simulate.simulate_trace(..., shards=S, mesh=...)``): the
    delta arrays are partitioned along axis 0 (``NamedSharding``/
    ``shard_map``), so the mesh takes the largest divisor of ``n_shards``
    that the available devices can host — device ``d`` then owns the
    contiguous shard block ``[d*S/D, (d+1)*S/D)``, consistent with
    :func:`shard_placement`.

    ``require=D`` demands a mesh of exactly D devices and raises an eager
    ``ValueError`` when the machine cannot host it — instead of silently
    shrinking to what fits (the default, which is right for portable
    scripts but wrong for placement tests and fault drills that NEED the
    multi-device layout)."""
    devices = list(jax.devices()) if devices is None else list(devices)
    if require:
        if require > len(devices):
            raise ValueError(
                f"make_shard_mesh(require={require}) but only "
                f"{len(devices)} device(s) are available — set "
                "XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{require} (before importing jax) or run on hardware "
                "with enough devices")
        if n_shards % require:
            raise ValueError(
                f"make_shard_mesh(require={require}): {n_shards} shards "
                "do not split evenly (block placement needs "
                "shards % devices == 0)")
        return jax.make_mesh((require,), ("shard",),
                             devices=devices[:require])
    n = _shard_mesh_size(max(1, n_shards), len(devices))
    return jax.make_mesh((n,), ("shard",), devices=devices[:n])


def mesh_state_shardings(mesh, state_keys) -> dict:
    """NamedShardings that place a mesh-layout engine state pytree
    (``core.device_simulate`` keys) onto ``mesh``: the shard-major delta
    arrays split along ``("shard",)`` axis 0, everything else replicated.
    The elastic-restore path (``core.device_simulate.resume_trace``) uses
    this to ``jax.device_put`` a checkpoint restored from a DIFFERENT mesh
    size onto the current one."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    return {k: NamedSharding(
        mesh, P("shard") if k in ("dcounters", "ddoorkeeper") else P())
        for k in state_keys}
