"""Production mesh construction.  A FUNCTION (not a module-level constant) so
importing this module never touches jax device state."""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count or run on "
            "real hardware")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires >= prod(shape) host devices)."""
    n = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
