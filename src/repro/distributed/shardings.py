"""Sharding rules: parameter PartitionSpecs by tree path, activation
constraints by semantic kind, cache/batch specs per shape kind.

Axes: 'data' (+ 'pod' composed in for multi-pod DP) and 'model' (TP/EP).
Strategy (DESIGN.md §4):
  * 2D weight sharding = Megatron TP on 'model' + FSDP on 'data' (GSPMD
    all-gathers the data-axis shards at use; optimizer state inherits the
    spec, giving ZeRO semantics for free).
  * MoE experts on 'model' (expert parallelism; dispatch all-to-all emerges
    from the (B, E, C, M) constraint).
  * decode KV caches are sequence-sharded on 'model' (flash-decoding style:
    every assigned shape divides evenly, unlike head counts) and
    batch-sharded on DP; long_500k (batch=1) shards the sequence across
    every axis.
  * head-count dims not divisible by 16 rely on GSPMD uneven sharding
    (internal padding) — measured, not assumed, in §Roofline.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# parameter rules: (regex on path, spec for the trailing dims)
# ---------------------------------------------------------------------------

def _param_rules(fsdp: str | None):
    d = fsdp           # 'data' or None
    return [
        # embeddings / heads
        (r"embed$",            {3: P(None, "model", d), 2: P("model", d)}),
        (r"out_head$",         {3: P(None, d, "model"), 2: P(d, "model")}),
        # attention
        (r"attn\d*/(wq|wk|wv)$", {2: P(d, "model")}),
        (r"shared_attn/(wq|wk|wv)$", {2: P(d, "model")}),
        (r"wo$",               {2: P("model", d)}),
        # dense mlp
        (r"(w_gate|w_up|shared_gate|shared_up|up_x|up_z)$", {2: P(d, "model")}),
        (r"(w_down|shared_down|down)$", {2: P("model", d)}),
        # moe experts: E on 'model'
        (r"moe\d*/w_gate$",    {3: P("model", d, None)}),
        (r"moe\d*/w_up$",      {3: P("model", d, None)}),
        (r"moe\d*/w_down$",    {3: P("model", None, d)}),
        (r"router$",           {2: P(None, None)}),
        # mamba2
        (r"in_proj$",          {2: P(d, "model")}),
        (r"out_proj$",         {2: P("model", d)}),
        (r"conv_w$",           {2: P(None, "model")}),
        (r"conv_b$",           {1: P("model")}),
        # xlstm
        (r"w_[qkv]$",          {2: P(None, "model")}),
        (r"w_gates$",          {2: P(None, None)}),
        (r"/r$",               {3: P(None, None, "model")}),
        (r"w_x$",              {2: P(d, "model")}),
        (r"/out$",             {2: P("model", d)}),
    ]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


@dataclass
class ShardingPolicy:
    mesh: Mesh
    fsdp: bool = True
    seq_parallel: bool = False   # shard the residual stream's seq dim over
                                 # 'model' (Megatron-SP): norm/residual
                                 # fusions shard TP-ways; TP boundaries turn
                                 # into RS/AG pairs

    def __post_init__(self):
        self.dp = ("pod", "data") if "pod" in self.mesh.axis_names else "data"
        self._rules = _param_rules("data" if self.fsdp else None)

    # -- parameters -----------------------------------------------------------
    def param_spec(self, path: str, ndim: int) -> P:
        for pat, by_rank in self._rules:
            if re.search(pat, path):
                for rank in sorted(by_rank, reverse=True):
                    if ndim >= rank:
                        spec = by_rank[rank]
                        pad = ndim - len(spec)
                        return P(*([None] * pad + list(spec)))
        return P()     # replicate (norm weights, biases, scalars)

    def tree_specs(self, tree) -> Any:
        """PartitionSpec tree for a parameter/TrainState-shaped pytree.
        Optimizer-state wrappers (m/v/f, vr/vc) reuse the parameter rule on
        the cleaned path, with factored dims dropped."""
        def one(path, leaf):
            p = _path_str(path)
            clean = re.sub(r"^(0/)?(params|opt|m|v|f)/", "", p)
            clean = re.sub(r"^(m|v|f)/", "", clean)
            is_vr = clean.endswith("/vr")
            is_vc = clean.endswith("/vc")
            clean = re.sub(r"/(vr|vc|v)$", "", clean)
            nd = leaf.ndim + (1 if is_vr or is_vc else 0)
            spec = self.param_spec(clean, nd)
            names = list(spec) + [None] * (nd - len(spec))
            if is_vr:
                names = names[:-1]            # mean over last dim
            elif is_vc:
                names = names[:-2] + names[-1:]
            return P(*names[:leaf.ndim])
        return jax.tree_util.tree_map_with_path(one, tree)

    def shardings(self, tree) -> Any:
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), self.tree_specs(tree))

    # -- activations -------------------------------------------------------------
    def act(self, x, kind: str):
        spec = self.act_spec(kind, x.ndim, x.shape)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def act_spec(self, kind: str, ndim: int, shape=None) -> Optional[P]:
        dp = self.dp
        if kind == "residual":
            if self.seq_parallel and shape is not None and shape[1] % 16 == 0:
                return P(dp, "model", None)
            return P(dp, None, None)
        if kind == "logits":
            return P(dp, None, "model") if ndim == 3 else P(dp, None, None, "model")
        if kind in ("attn_q", "attn_kv"):
            return P(dp, None, "model", None)
        if kind == "attn_blk":                 # (B, nblk, blk, H, D)
            return P(dp, None, None, "model", None)
        if kind == "ffn_hidden":
            return P(dp, None, "model")
        if kind in ("moe_dispatch", "moe_hidden", "moe_combine"):
            return P(dp, "model", None, None)
        if kind == "mamba_proj":               # (B, S, channels)
            return P(dp, None, "model")
        if kind == "mamba_chunk":              # (B, nc, L, H, P)
            return P(dp, None, None, "model", None)
        if kind == "mamba_att":                # (B, nc, L, L, H)
            return P(dp, None, None, None, "model")
        return None

    # -- batches -------------------------------------------------------------------
    def batch_specs(self, batch_tree) -> Any:
        def one(path, leaf):
            if leaf.shape[0] == 1:                 # long_500k: replicate batch
                return NamedSharding(self.mesh, P())
            return NamedSharding(
                self.mesh, P(self.dp, *([None] * (leaf.ndim - 1))))
        return jax.tree_util.tree_map_with_path(one, batch_tree)

    # -- caches -----------------------------------------------------------------------
    def cache_specs(self, cache_tree, batch: int) -> Any:
        """Decode-cache shardings: sequence-sharded KV (flash-decoding),
        batch over DP; batch=1 shards the sequence over every axis."""
        long_ctx = batch == 1
        all_axes = tuple(self.mesh.axis_names)

        def one(path, leaf):
            p = _path_str(path)
            nd = leaf.ndim
            if p.endswith("pos"):
                return NamedSharding(self.mesh, P())
            if re.search(r"(^|/)(k|v)$", p):       # (L_or_G, B, S, H, D)
                if long_ctx:
                    return NamedSharding(self.mesh,
                                         P(None, None, all_axes, None, None))
                return NamedSharding(self.mesh,
                                     P(None, self.dp, "model", None, None))
            if "mamba" in p or "mlstm" in p:       # states: shard heads/dk
                axes = [None] * nd
                # batch axis = first axis with size == batch
                for i, s in enumerate(leaf.shape):
                    if s == batch and not long_ctx:
                        axes[i] = self.dp
                        break
                # shard the largest remaining dim on 'model'
                cand = [(s, i) for i, s in enumerate(leaf.shape)
                        if axes[i] is None and s % 16 == 0]
                if cand:
                    axes[max(cand)[1]] = "model"
                return NamedSharding(self.mesh, P(*axes))
            if "slstm" in p:
                axes = [None] * nd
                if not long_ctx and nd >= 2:
                    for i, s in enumerate(leaf.shape):
                        if s == batch:
                            axes[i] = self.dp
                            break
                if nd >= 1 and leaf.shape[-1] % 16 == 0:
                    axes[-1] = "model"
                return NamedSharding(self.mesh, P(*axes))
            return NamedSharding(self.mesh, P())
        return jax.tree_util.tree_map_with_path(one, cache_tree)
