"""Gradient compression for the data-parallel axis: int8 quantized
all-reduce with error feedback (1-bit-Adam-style residual correction).

Wire cost: an fp32 ring all-reduce moves ~2x4 bytes/element; quantize->
all_gather(int8)->local dequant-sum moves ~1 byte/element — an ~8x reduction
on the DP axis, at the price of quantization noise that the error-feedback
state re-injects next step (so the *accumulated* gradient is unbiased).

This is the manual-collective path: use inside shard_map over the 'data'
axis (pjit's implicit gradient reductions cannot be intercepted).  See
tests/test_compression.py for the equivalence + convergence checks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize_int8(x: jnp.ndarray):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_allreduce_int8(x: jnp.ndarray, axis_name: str,
                              error: jnp.ndarray | None = None):
    """Mean over ``axis_name`` of per-shard tensors, int8 on the wire.

    Returns (mean, new_error).  Call inside shard_map/pmap with ``x`` the
    local shard's contribution and ``error`` the previous step's residual.
    """
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    q, scale = _quantize_int8(xf)
    new_error = xf - q.astype(jnp.float32) * scale       # feedback residual
    # wire: int8 values + one f32 scale per participant
    qg = jax.lax.all_gather(q, axis_name)                # (G, ...)
    sg = jax.lax.all_gather(scale, axis_name)            # (G,)
    n = qg.shape[0]
    deq = (qg.astype(jnp.float32)
           * sg.reshape((n,) + (1,) * x.ndim)).sum(0) / n
    return deq.astype(x.dtype), new_error


def compressed_tree_allreduce(grads, axis_name: str, error_tree=None):
    """Pytree version; threads per-leaf error feedback."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    errs = (treedef.flatten_up_to(error_tree) if error_tree is not None
            else [None] * len(leaves))
    out, new_err = [], []
    for g, e in zip(leaves, errs):
        m, ne = compressed_allreduce_int8(g, axis_name, e)
        out.append(m)
        new_err.append(ne)
    return treedef.unflatten(out), treedef.unflatten(new_err)
