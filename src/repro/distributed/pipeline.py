"""Pipeline parallelism over a mesh axis via shard_map + collective_permute
(GPipe schedule) — the multi-pod mesh's 'pod' axis can act as a 2-deep
pipeline instead of pure DP (DESIGN.md §4).

The layer stack (L, ...) is split into S contiguous stages; a global batch is
split into M microbatches.  Every step t of the S+M-1 schedule, stage s
processes microbatch (t - s) if live, then activations ppermute to stage
s+1.  Bubble fraction = (S-1)/(S+M-1), amortized by M.

`pipeline_apply` is the forward executor (inference/eval and the building
block for interleaved training); equivalence vs the sequential stack is
checked in tests/test_pipeline.py on a host-device mesh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(mesh: Mesh, stage_axis: str, block_fn, stacked_params,
                   x: jnp.ndarray, n_micro: int):
    """Run ``x`` through the full stacked layer sequence, stages sharded over
    ``stage_axis``.

    block_fn(params_slice, h) -> h applies ONE layer.
    stacked_params: pytree with leading layer axis L (L % n_stages == 0).
    x: (B, ...) global batch (B % n_micro == 0).
    """
    n_stages = mesh.shape[stage_axis]
    L = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]
    assert L % n_stages == 0
    B = x.shape[0]
    assert B % n_micro == 0
    mb = B // n_micro

    def stage_body(params_local, x_all):
        # params_local: (L/S, ...) this stage's layers; x_all: full batch
        # (replicated over the stage axis — microbatches stream through)
        sid = jax.lax.axis_index(stage_axis)
        micros = x_all.reshape(n_micro, mb, *x_all.shape[1:])
        T = n_stages + n_micro - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def layers(h):
            def body(h, p):
                return block_fn(p, h), None
            h, _ = jax.lax.scan(body, h, params_local)
            return h

        def step(carry, t):
            inbuf, outs = carry
            # stage 0 injects microbatch t; others use what arrived
            m_idx = jnp.clip(t, 0, n_micro - 1)
            injected = jnp.where(sid == 0, 1, 0)
            h_in = jnp.where(injected, micros[m_idx], inbuf)
            live = (t - sid >= 0) & (t - sid < n_micro)
            h_out = jnp.where(live, layers(h_in), h_in)
            # last stage collects its finished microbatch
            done_idx = t - (n_stages - 1)
            is_done = (sid == n_stages - 1) & (done_idx >= 0) \
                & (done_idx < n_micro)
            outs = jax.lax.cond(
                is_done,
                lambda o: jax.lax.dynamic_update_slice_in_dim(
                    o, h_out[None], jnp.clip(done_idx, 0, n_micro - 1), 0),
                lambda o: o, outs)
            # forward activations to the next stage
            nxt = jax.lax.ppermute(h_out, stage_axis, perm)
            return (nxt, outs), None

        inbuf0 = jnp.zeros((mb, *x_all.shape[1:]), x_all.dtype)
        outs0 = jnp.zeros((n_micro, mb, *x_all.shape[1:]), x_all.dtype)
        (_, outs), _ = jax.lax.scan(step, (inbuf0, outs0),
                                    jnp.arange(n_stages + n_micro - 1))
        # only the last stage holds real outputs; gather + select them
        outs = jax.lax.all_gather(outs, stage_axis)[n_stages - 1]
        return outs.reshape(B, *x_all.shape[1:])

    params_spec = jax.tree_util.tree_map(
        lambda a: P(stage_axis, *([None] * (a.ndim - 1))), stacked_params)
    fn = shard_map(stage_body, mesh=mesh,
                   in_specs=(params_spec, P()), out_specs=P(),
                   check_rep=False)
    return fn(stacked_params, x)
