"""Fault-tolerant sharded checkpointing with elastic resharding.

Design (DESIGN.md §4):
  * a checkpoint = manifest.json + one .npy blob per leaf per host-shard;
    the manifest records the flattened tree paths, global shapes/dtypes, and
    the PartitionSpec each leaf was saved under;
  * save is topology-aware: each host writes only the shards it owns (on this
    single-host container that's everything, but the addressable-shard loop
    is the real multi-host code path);
  * restore is **elastic**: the target mesh/sharding may differ from the one
    saved — leaves are reassembled to their global shape and re-sharded via
    jax.device_put under the new policy (a restart may change pod count);
  * async: `AsyncCheckpointer` snapshots to host RAM synchronously (cheap)
    and writes to disk on a background thread, overlapping the next step;
  * atomicity: writes go to <dir>.tmp, fsync'd, then os.rename'd into place;
    `latest_step` only ever sees complete checkpoints.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Optional

import numpy as np
import jax


def _flat(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out.append((key, leaf))
    return out, treedef


def _leaf_filename(key: str) -> str:
    return re.sub(r"[^\w\-]", "_", key) + ".npy"


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra_meta: Optional[dict] = None) -> str:
    """Synchronous atomic save.  Returns the checkpoint path."""
    ckpt = os.path.join(directory, f"step_{step:010d}")
    tmp = ckpt + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, _ = _flat(tree)
    manifest = {"step": step, "time": time.time(),
                "extra": extra_meta or {}, "leaves": []}
    for key, leaf in leaves:
        arr = np.asarray(leaf)
        fn = _leaf_filename(key)
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append({
            "key": key, "file": fn, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(ckpt):
        shutil.rmtree(ckpt)
    os.rename(tmp, ckpt)
    return ckpt


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None


def load_meta(directory: str, step: int) -> dict:
    """The ``extra_meta`` dict a checkpoint was saved with (empty if none).

    Readable without touching the leaf blobs — resume paths use it to
    learn the trace cursor and to verify the saved configuration
    fingerprint BEFORE building restore templates."""
    ckpt = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(ckpt, "manifest.json")) as f:
        manifest = json.load(f)
    return manifest.get("extra", {})


def restore_checkpoint(directory: str, step: int, template: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for the TARGET mesh — elastic resharding happens here."""
    ckpt = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(ckpt, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {l["key"]: l for l in manifest["leaves"]}

    leaves, treedef = _flat(template)
    sh_leaves = None
    if shardings is not None:
        sh_flat, _ = _flat(shardings)
        sh_leaves = dict(sh_flat)
    out = []
    for key, leaf in leaves:
        meta = by_key.get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(ckpt, meta["file"]))
        if not hasattr(leaf, "shape"):            # python scalar leaf
            out.append(arr.item())
            continue
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: saved {arr.shape} != wanted {want_shape}")
        sh = sh_leaves.get(key) if sh_leaves is not None else None
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def prune_old(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(int(m.group(1)) for d in os.listdir(directory)
                   if (m := re.match(r"step_(\d+)$", d)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot on the caller thread (device->host copy), persist on a
    background thread.  ``wait()`` joins pending writes (call before exit and
    in tests)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra_meta: Optional[dict] = None):
        self.wait()
        # snapshot NOW: jax arrays are immutable (a host view is a stable
        # snapshot), but mutable numpy leaves must be copied or the caller
        # could race the background writer
        host_tree = jax.tree_util.tree_map(
            lambda x: np.array(x) if isinstance(x, np.ndarray)
            else np.asarray(x), tree)

        def _write():
            try:
                save_checkpoint(self.directory, step, host_tree, extra_meta)
                prune_old(self.directory, self.keep)
                self.last_saved = step
            except BaseException as e:                 # surfaced on wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
