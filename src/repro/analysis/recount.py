"""Recount roofline terms from archived compiled HLO (no recompiles).

  PYTHONPATH=src python -m repro.analysis.recount

Rewrites the cost-derived fields of every experiments/dryrun/*.json that has
a matching experiments/hlo/*.hlo.gz, using the current hlo_cost model.
"""
from __future__ import annotations

import glob
import gzip
import json
import os

from .hlo_cost import analyze_hlo
from .roofline import Roofline, SimpleColl

ROOT = os.path.join(os.path.dirname(__file__), "../../..")
DRY = os.path.join(ROOT, "experiments", "dryrun")
HLO = os.path.join(ROOT, "experiments", "hlo")


def recount_one(json_path: str) -> bool:
    r = json.load(open(json_path))
    if r.get("status") != "ok":
        return False
    tag = r.get("tag") or ""
    hlo_path = os.path.join(
        HLO, f"{r['arch']}_{r['shape']}_{r['mesh']}{tag}.hlo.gz")
    if not os.path.exists(hlo_path):
        return False
    hc = analyze_hlo(gzip.open(hlo_path, "rt").read())
    coll = SimpleColl(counts=dict(hc.coll_counts),
                      out_bytes=dict(hc.coll_bytes),
                      wire_bytes=hc.coll_wire_bytes)
    rl = Roofline(chips=r["chips"], hlo_flops=hc.flops * r["chips"],
                  hlo_bytes=hc.bytes * r["chips"], coll=coll,
                  model_flops=r["roofline"]["model_flops"])
    r["hlo_flops_per_device"] = hc.flops
    r["hlo_bytes_per_device"] = hc.bytes
    r["bytes_by_kind"] = dict(hc.bytes_by_kind)
    r["top_collectives"] = dict(sorted(hc.coll_ops.items(),
                                       key=lambda x: -x[1])[:12])
    r["top_fusions"] = dict(sorted(hc.fusion_ops.items(),
                                   key=lambda x: -x[1])[:12])
    r["roofline"] = rl.as_dict()
    json.dump(r, open(json_path, "w"), indent=1)
    return True


def main():
    n = 0
    for f in sorted(glob.glob(os.path.join(DRY, "*.json"))):
        if recount_one(f):
            n += 1
            r = json.load(open(f))
            rl = r["roofline"]
            print(f"{r['arch']:28s} {r['shape']:12s} {r['mesh']:6s} "
                  f"{r.get('tag') or '':6s} tm={rl['t_memory_s']:.3f} "
                  f"tc={rl['t_compute_s']:.3f} "
                  f"tcoll={rl['t_collective_s']:.3f} "
                  f"frac={rl['roofline_frac']:.4f}", flush=True)
    print(f"recounted {n} cells")


if __name__ == "__main__":
    main()
