"""Static lint of lowered engine programs: the in-place discipline as rules.

Every throughput claim in this repo rests on *compiled-program* properties
— the XLA-CPU in-place discipline of ``docs/ARCHITECTURE.md`` — that the
benchmark gates only catch after the fact, noisily, days late.  This
module enforces them at lowering time: it parses ``compiled.as_text()``
with the call-graph / trip-count machinery of :mod:`repro.analysis.hlo_cost`
and checks structural rules over the access-scan bodies.

Rules (each cross-referenced to the ARCHITECTURE.md symptom table):

``R1``  no ``scatter`` op reachable from an access-scan body.  Symptom:
        per-access fixed ~µs dispatch; the lane-batching regression class
        (scatter-free lane writes are the whole point of ``streams``).
``R2``  per-access write footprint bounded: every ``dynamic-update-slice``
        in the scan body updates O(ways) words, never a table-shaped
        region.  Symptom: flatness collapse proportional to capacity.
``R3``  no table-shaped ``copy`` / non-DUS fusion output in the scan body
        (the chain-split-allocation cliff: a full-buffer materialization
        per access).  Symptom: flatness collapse + overhead ~1 —
        "full-buffer copy (aliasing broke)".
``R4``  no ``outer_dimension_partitions`` thread dispatch on sub-512B
        outputs.  Symptom: flatness collapse + big overhead at one width
        tier — "partitioned body fusion".
``R5``  donation honored: state buffers input/output-aliased, zero
        table-shaped entry-level copies.  Symptom: same as R3, at the
        program boundary instead of inside the scan.
``R6``  collective cadence: zero collectives reachable from any while
        body for ``mesh_exchange="chunk"`` (entry/exit all-gather only),
        none reachable from the access body for ``"stale"`` (per-epoch
        fold only), none at all in single-device programs.  This is the
        62.8x per-access-psum bug of PR 6, expressed statically.
``R7``  byte-identity fingerprints: every "compiles the identical
        program" contract (``policy`` default, ``streams=1``,
        ``shards=1``, ``adaptive=False``, ``integrity=False``) lowers
        byte-identical text, and its digest matches the committed
        registry (``fingerprints.json``, keyed by jax version + backend;
        refresh with ``tools/lint_programs.py --update``).
``R0``  structural sanity: the access scan itself must exist as a
        known-trip-count while (catches a restructure that would silently
        void R1-R3/R6's scoping).

The text analysis (:func:`lint_hlo`) is pure — no jax import — so fixture
HLO and committed repro text lint without lowering anything.  The config
matrix (:func:`default_matrix` / :func:`run_matrix`) lowers the real
engine across flat/assoc x static/adaptive x shards x streams x policy x
mesh chunk/stale; ``tools/lint_programs.py`` is the CLI and CI step.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable

from .hlo_cost import (_COLLECTIVES, _TRIP_COUNT, _nbytes, _nelems,
                       _split_computations, _trip_count)

# ---------------------------------------------------------------------------
# rule table (ids -> one-line contract; rendered by --list-rules and docs)
# ---------------------------------------------------------------------------

RULES = {
    "R0": "access scan exists as a known-trip-count while loop",
    "R1": "no scatter op reachable from an access-scan body",
    "R2": "every DUS in the scan body updates O(ways) words, "
          "never a table-shaped region",
    "R3": "no table-shaped copy / non-DUS fusion output in the scan body "
          "(chain-split allocation cliff)",
    "R4": "no outer_dimension_partitions thread dispatch on sub-512B "
          "outputs",
    "R5": "donation honored: state buffers input/output-aliased, no "
          "table-shaped entry copies",
    "R6": "collective cadence: chunk = entry/exit only, stale = "
          "per-epoch only, single-device = none",
    "R7": "byte-identity fingerprints match the committed registry",
}

# default scan lengths for the lowered matrix — deliberately NOT powers of
# two so trip counts cannot collide with internal geometry loops (set
# counts, ways, rebalance fori bounds are all powers of two)
T_STEP = 96          # plain step programs: accesses per chunk
E_EPOCH = 192        # runner programs: accesses per merge/climb epoch
NE_EPOCHS = 2        # epochs per lowered runner program
T_TAIL = 23          # mesh programs: tail accesses outside the epoch scan
B_LANES = 4          # lane-batched entries


@dataclass(frozen=True)
class LintBounds:
    """Per-program parameters the rules check against.

    ``access_trips`` identifies the access-scan while loops by their
    known trip counts — the linter controls the lowering, so it knows the
    chunk lengths it lowered with.  ``max_update_elems`` is the R2 bound
    (None disables R2 — flat programs write O(capacity) by design).
    ``table_elems_floor`` is the smallest output (elements) R3/R5 call
    "table-shaped".  ``expect_aliases`` arms R5 with the number of state
    leaves that must be input/output-aliased.  ``mesh_exchange`` selects
    the R6 cadence contract (None = single-device, zero collectives).
    """
    access_trips: tuple = ()
    assoc: bool = False
    streams: int = 1
    max_update_elems: int | None = None
    table_elems_floor: int = 1024
    mesh_exchange: str | None = None
    expect_aliases: int | None = None
    partition_floor_bytes: int = 512


@dataclass
class Violation:
    rule: str
    config: str
    where: str
    message: str

    def __str__(self):
        return f"[{self.rule}] {self.config}: {self.message} ({self.where})"

    def to_dict(self):
        return {"rule": self.rule, "config": self.config,
                "where": self.where, "message": self.message}


# ---------------------------------------------------------------------------
# call-graph helpers over _split_computations output
# ---------------------------------------------------------------------------

def _reachable(comps, roots):
    """Names of computations reachable from ``roots`` through any call
    edge (while cond/body, fusion calls, call, conditional branches)."""
    seen: set[str] = set()
    stack = [r for r in roots if r]
    while stack:
        name = stack.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        for op in comps[name].ops.values():
            stack.extend(c for c in op.called if c not in seen)
    return seen


def _find_whiles(comps):
    """All while ops: (comp_name, op, trips_or_None, body_name)."""
    out = []
    for cn, comp in comps.items():
        for op in comp.ops.values():
            if op.kind != "while":
                continue
            called = [c for c in op.called if c in comps]
            cond = called[0] if called else None       # condition=, body=
            body = called[1] if len(called) > 1 else None
            tm = _TRIP_COUNT.search(op.line)
            trips = int(tm.group(1)) if tm else None
            if trips is None and cond:
                t = _trip_count(comps[cond])
                trips = int(t) if t is not None else None
            out.append((cn, op, trips, body))
    return out


def _max_out_elems(op) -> int:
    """Largest tuple element of the op's output, in elements."""
    if not op.out_shapes:
        return 0
    return int(max(_nelems([s]) for s in op.out_shapes))


def _is_collective(kind: str) -> bool:
    return any(kind.startswith(c) for c in _COLLECTIVES) \
        and not kind.endswith("-done")


# ---------------------------------------------------------------------------
# the linter core: pure text analysis
# ---------------------------------------------------------------------------

def lint_hlo(text: str, bounds: LintBounds, config: str = "") -> list:
    """Lint one compiled module's text against ``bounds``.  Pure — usable
    on committed fixture HLO as well as live lowerings."""
    comps, entry = _split_computations(text)
    out: list[Violation] = []
    whiles = _find_whiles(comps)

    # XLA may unroll the scan body (flat programs unroll 4x): a while
    # with trips = T/k for a small integer k is still the access loop
    def _is_access(t):
        return any(t == at or (t and at % t == 0 and 2 <= at // t <= 8)
                   for at in bounds.access_trips)

    access_bodies = [b for _, _, t, b in whiles
                     if b and t is not None and _is_access(t)]
    if bounds.access_trips and not access_bodies:
        out.append(Violation(
            "R0", config, entry or "?",
            f"no while loop with trip count in {bounds.access_trips} — "
            "the access scan is gone or restructured; rule scoping is "
            "void"))
    access_reach = _reachable(comps, access_bodies)
    while_reach = _reachable(comps, [b for _, _, _, b in whiles if b])

    # R1: no scatter reachable from the access scan.  XLA-CPU's scatter
    # expander rewrites every scatter into a sequential while loop with a
    # KNOWN trip count (= number of scatter indices) before the final
    # HLO, so the compiled-text signature is either a literal scatter op
    # (other backends) or a known-trip inner while nested in the access
    # body — healthy inner loops there (the §3.3 reset, the ghost
    # saturation clear) all have where-gated DYNAMIC trip counts.
    access_body_names = set(access_bodies)
    for cn in sorted(access_reach):
        for op in comps[cn].ops.values():
            if op.kind == "scatter":
                out.append(Violation(
                    "R1", config, f"{cn}/{op.name}",
                    "scatter op in the access-scan body — per-access "
                    "dispatch overhead (lane writes must be fused "
                    "one-hot selects, table writes single-word DUS)"))
    for cn, op, trips, body in whiles:
        if cn in access_reach and body not in access_body_names \
                and trips is not None and not _is_access(trips):
            out.append(Violation(
                "R1", config, f"{cn}/{op.name}",
                f"known-trip-count ({trips}) while nested in the "
                "access-scan body — the expanded-scatter signature "
                "(a serialized per-index write loop per access)"))

    # R2: DUS write footprint inside the access scan
    if bounds.max_update_elems is not None:
        for cn in sorted(access_reach):
            comp = comps[cn]
            for op in comp.ops.values():
                if op.kind != "dynamic-update-slice" or \
                        len(op.operands) < 2:
                    continue
                upd = comp.ops.get(op.operands[1])
                if upd is None:
                    continue
                elems = _max_out_elems(upd)
                if elems > bounds.max_update_elems:
                    out.append(Violation(
                        "R2", config, f"{cn}/{op.name}",
                        f"DUS updates {elems} elements per access "
                        f"(bound {bounds.max_update_elems} = O(ways)) — "
                        "a table-shaped write region sinks flatness"))

    # R3: table-shaped copy / non-DUS fusion output in the access scan.
    # Lane programs (streams>1) legitimately materialize full-array
    # one-hot-select fusions; flat programs are O(capacity) by design.
    if bounds.assoc and bounds.streams == 1:
        for cn in sorted(access_reach):
            comp = comps[cn]
            for op in comp.ops.values():
                big = _max_out_elems(op) >= bounds.table_elems_floor
                if not big:
                    continue
                if op.kind == "copy":
                    out.append(Violation(
                        "R3", config, f"{cn}/{op.name}",
                        f"table-shaped copy ({_max_out_elems(op)} elems) "
                        "in the access-scan body — the chain-split "
                        "allocation cliff (aliasing broke)"))
                elif op.kind == "fusion":
                    fused = [comps[c] for c in op.called if c in comps]
                    has_dus = any(
                        o.kind == "dynamic-update-slice"
                        for f in fused for o in f.ops.values())
                    if not has_dus:
                        out.append(Violation(
                            "R3", config, f"{cn}/{op.name}",
                            f"table-shaped fusion output "
                            f"({_max_out_elems(op)} elems) with no DUS "
                            "root in the access-scan body — a "
                            "full-buffer materialization per access"))

    # R4: partitioned thread dispatch on tiny outputs (whole module)
    for cn in sorted(comps):
        for op in comps[cn].ops.values():
            if "outer_dimension_partitions" not in op.line:
                continue
            nb = _nbytes(op.out_shapes)
            if nb < bounds.partition_floor_bytes:
                out.append(Violation(
                    "R4", config, f"{cn}/{op.name}",
                    f"outer_dimension_partitions on a {int(nb)}B output "
                    f"(< {bounds.partition_floor_bytes}B) — thread "
                    "dispatch costs more than the work it splits"))

    # R5: donation honored at the program boundary
    if bounds.expect_aliases is not None:
        header = text.splitlines()[0] if text else ""
        n_alias = header.count("may-alias") + header.count("must-alias")
        if n_alias < bounds.expect_aliases:
            out.append(Violation(
                "R5", config, "entry",
                f"only {n_alias} of {bounds.expect_aliases} state "
                "buffers input/output-aliased — donation is not "
                "reaching the compiled program"))
        if entry and entry in comps:
            for op in comps[entry].ops.values():
                if op.kind == "copy" and \
                        _max_out_elems(op) >= bounds.table_elems_floor:
                    out.append(Violation(
                        "R5", config, f"{entry}/{op.name}",
                        f"table-shaped entry-level copy "
                        f"({_max_out_elems(op)} elems) — a donated "
                        "buffer is being duplicated at the boundary"))

    # R6: collective cadence
    coll = [(cn, op) for cn in comps for op in comps[cn].ops.values()
            if _is_collective(op.kind)]
    if bounds.mesh_exchange is None:
        for cn, op in coll:
            out.append(Violation(
                "R6", config, f"{cn}/{op.name}",
                f"{op.kind} in a single-device program"))
    elif bounds.mesh_exchange == "chunk":
        for cn, op in coll:
            if cn in while_reach:
                out.append(Violation(
                    "R6", config, f"{cn}/{op.name}",
                    f"{op.kind} inside a loop body — chunk mode pays "
                    "its collectives at program entry/exit only (the "
                    "62.8x per-access-psum bug class)"))
    else:                                   # "stale": per-epoch fold only
        for cn, op in coll:
            if cn in access_reach:
                out.append(Violation(
                    "R6", config, f"{cn}/{op.name}",
                    f"{op.kind} inside the access-scan body — stale "
                    "mode's one collective is the per-epoch "
                    "merge_halve_mesh fold"))
    return out


# ---------------------------------------------------------------------------
# R7: byte-identity fingerprint registry
# ---------------------------------------------------------------------------

REGISTRY_PATH = Path(__file__).with_name("fingerprints.json")

# the canonical pin geometry — shared by the historic per-test pins this
# registry replaced (tests/test_sketch_step.py, test_policy_panel.py,
# test_streams.py all lowered this same spec family)
_FP_BASE = dict(width=256, rows=4, dk_bits=1024, window_slots=8,
                main_slots=64, assoc=8)

# contract name -> StepSpec override that must compile the byte-identical
# program to the base spec (the override merely spells out a default)
FINGERPRINT_CONTRACTS = {
    "shards1": {"shards": 1},
    "policy-default": {"policy": "wtinylfu"},
    "streams1": {"streams": 1},
    "adaptive-off": {"adaptive": False},
    "integrity-off": {"integrity": False},
}


def env_key() -> str:
    """HLO text varies across jax versions/backends; digests are only
    comparable within one environment."""
    import jax
    return f"jax-{jax.__version__}-{jax.default_backend()}"


def pin_program_text(**overrides) -> str:
    """Lower the canonical pin program (unoptimized module text).

    Lowers from a cleared trace/lowering cache: jax's auto-numbered
    private helpers (``_where_N``, ``floor_divide_N``...) pick up
    process-history-dependent suffixes — and occasionally an extra
    deduplication-miss copy — when the global lowering caches are warm
    from unrelated programs (e.g. mid-test-suite), which would make the
    R7 digest compare process-order-dependent.  A cold cache lowers the
    byte-identical text every time, in any process.
    """
    import jax
    import numpy as np
    from repro.kernels.sketch_common import keys_to_lanes
    from repro.kernels.sketch_step import (StepSpec, init_step_state,
                                           make_step_params, step_ref)
    jax.clear_caches()
    spec = StepSpec(**{**_FP_BASE, **overrides})
    params = make_step_params(4, 48, 38, 700, 7, 0)
    lo, hi = keys_to_lanes(np.arange(16, dtype=np.uint64))
    return jax.jit(step_ref, static_argnums=0).lower(
        spec, params, init_step_state(spec), lo, hi).as_text()


def _digest(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()


def load_registry(path: Path = REGISTRY_PATH) -> dict:
    if Path(path).exists():
        return json.loads(Path(path).read_text())
    return {}


def check_fingerprints(update: bool = False,
                       registry_path: Path = REGISTRY_PATH,
                       contracts: dict | None = None):
    """Verify every identical-program contract; returns
    ``(violations, notes)``.  Pair equality (base text == variant text)
    is always enforced; the committed digest is only compared when the
    registry has an entry for this environment (``--update`` writes one).
    """
    contracts = FINGERPRINT_CONTRACTS if contracts is None else contracts
    violations: list[Violation] = []
    notes: list[str] = []
    base = pin_program_text()
    key = env_key()
    reg = load_registry(registry_path)
    env = reg.get(key, {})
    digests = {"base": _digest(base)}
    for name, ov in contracts.items():
        var = pin_program_text(**ov)
        digests[name] = _digest(var)
        if var != base:
            violations.append(Violation(
                "R7", name, "lowering",
                f"spelling out the default ({ov}) lowers a DIFFERENT "
                "program — an identical-program contract broke"))
    if update:
        reg[key] = digests
        Path(registry_path).write_text(
            json.dumps(reg, indent=2, sort_keys=True) + "\n")
        notes.append(f"registry updated for {key} "
                     f"({len(digests)} digests)")
        return violations, notes
    if not env:
        notes.append(f"no registry entry for {key} — digest check "
                     "skipped (pair equality still enforced); run "
                     "tools/lint_programs.py --update to pin this "
                     "environment")
        return violations, notes
    for name, dg in digests.items():
        want = env.get(name)
        if want is None:
            notes.append(f"contract {name!r} not in registry for {key}")
        elif want != dg:
            violations.append(Violation(
                "R7", name, key,
                "lowered-program digest drifted from the committed "
                "registry — if the lowering change is intentional, "
                "refresh with tools/lint_programs.py --update"))
    return violations, notes


def assert_identical_program(name: str):
    """Test-facing one-liner for the identical-program pins: lowers the
    base and the ``name`` contract's variant, asserts byte-identity, and
    (when this environment is pinned) the committed digest."""
    ov = FINGERPRINT_CONTRACTS[name]
    base = pin_program_text()
    var = pin_program_text(**ov)
    assert var == base, (
        f"contract {name!r}: spelling out the default {ov} lowered a "
        "different program")
    env = load_registry().get(env_key(), {})
    if env:
        assert _digest(var) == env[name], (
            f"contract {name!r}: program digest drifted from the "
            "committed fingerprints.json — refresh with "
            "tools/lint_programs.py --update if intentional")


# ---------------------------------------------------------------------------
# the configuration matrix: lowered live, linted statically
# ---------------------------------------------------------------------------

class SkipEntry(Exception):
    """Raised by a builder when its environment prerequisite is missing
    (e.g. mesh entries on a single-device host)."""


@dataclass
class MatrixEntry:
    label: str
    build: Callable            # () -> (hlo_text, LintBounds)
    note: str = ""
    # rule id -> reason: known, documented debt.  Waived violations are
    # still reported (status "waived") but do not fail the run; the list
    # of waivers is part of docs/ARCHITECTURE.md's static-analysis
    # section and each one carries a ROADMAP follow-up.
    waive: dict = field(default_factory=dict)


def _bounds_for(spec, access_trips, mesh_exchange=None,
                expect_aliases=None) -> LintBounds:
    ways = spec.assoc or 0
    max_upd = 4 * ways * max(spec.wcols, spec.mcols) if ways else None
    return LintBounds(access_trips=tuple(access_trips), assoc=bool(ways),
                      streams=spec.streams, max_update_elems=max_upd,
                      mesh_exchange=mesh_exchange,
                      expect_aliases=expect_aliases)


def _step_program(cfg_kwargs: dict, donate: bool = False):
    import jax
    import jax.numpy as jnp
    from repro.core.device_simulate import DeviceWTinyLFU
    from repro.kernels.sketch_step import init_step_state, step_ref
    cfg = DeviceWTinyLFU(**cfg_kwargs)
    spec, params = cfg.spec(), cfg.params()
    state = init_step_state(spec, cfg.window_cap, cfg.main_cap)
    shape = (spec.streams, T_STEP) if spec.streams > 1 else (T_STEP,)
    lo = jnp.zeros(shape, jnp.int32)
    jit = jax.jit(step_ref, static_argnums=(0,),
                  donate_argnums=(2,) if donate else ())
    text = jit.lower(spec, params, state, lo, lo).compile().as_text()
    return text, _bounds_for(
        spec, (T_STEP,),
        expect_aliases=len(state) if donate else None)


def _sharded_program(cfg_kwargs: dict):
    import jax.numpy as jnp
    from repro.core.device_simulate import (DeviceWTinyLFU,
                                            _sharded_runner)
    from repro.kernels.sketch_step import init_step_state
    cfg = DeviceWTinyLFU(**cfg_kwargs)
    spec, params = cfg.spec(), cfg.params()
    state = init_step_state(spec, cfg.window_cap, cfg.main_cap)
    los = jnp.zeros((NE_EPOCHS, E_EPOCH), jnp.int32)
    nvalid = jnp.full((NE_EPOCHS,), E_EPOCH, jnp.int32)
    run = _sharded_runner(spec, "jit", False)
    text = run.lower(params, state, los, los,
                     nvalid).compile().as_text()
    return text, _bounds_for(spec, (E_EPOCH,))


def _adaptive_program(cfg_kwargs: dict):
    import jax.numpy as jnp
    from repro.core.device_simulate import (ClimbSpec, DeviceWTinyLFU,
                                            _adaptive_runner,
                                            _climb_carry0)
    from repro.kernels.sketch_step import init_step_state
    cfg = DeviceWTinyLFU(**cfg_kwargs)
    spec, params = cfg.spec(), cfg.params()
    state = init_step_state(spec, cfg.window_cap, cfg.main_cap)
    B = spec.streams
    shape = (NE_EPOCHS, B, E_EPOCH) if B > 1 else (NE_EPOCHS, E_EPOCH)
    los = jnp.zeros(shape, jnp.int32)
    nvalid = jnp.full((NE_EPOCHS,), E_EPOCH, jnp.int32)
    cvec = jnp.asarray(ClimbSpec(epoch_len=E_EPOCH).resolve(cfg))
    carry0 = _climb_carry0(cvec)
    if B > 1:
        carry0 = jnp.broadcast_to(carry0[:, None], (6, B))
    run = _adaptive_runner(spec, "jit", False)
    text = run.lower(params, state, los, los, nvalid, cvec,
                     carry0).compile().as_text()
    return text, _bounds_for(spec, (E_EPOCH,))


def _mesh_program(mode: str):
    import jax
    if jax.device_count() < 2:
        raise SkipEntry(
            "needs >= 2 devices (XLA_FLAGS="
            "--xla_force_host_platform_device_count=2 before jax import)")
    from dataclasses import replace

    import jax.numpy as jnp
    from repro.core.device_simulate import (DeviceWTinyLFU, _mesh_runner,
                                            _to_mesh_state)
    from repro.distributed.mesh import (make_shard_mesh,
                                        mesh_state_shardings)
    from repro.kernels.sketch_step import init_step_state
    cfg = DeviceWTinyLFU(2048, assoc=8, shards=4,
                         mesh=make_shard_mesh(2), mesh_exchange=mode,
                         merge_every=E_EPOCH)
    spec, params = cfg.spec(), cfg.params()
    state = _to_mesh_state(spec, init_step_state(
        replace(spec, mesh_devices=0), cfg.window_cap, cfg.main_cap))
    sh = mesh_state_shardings(cfg.mesh, state.keys())
    state = {k: jax.device_put(v, sh[k]) for k, v in state.items()}
    los = jnp.zeros((NE_EPOCHS, E_EPOCH), jnp.int32)
    tlo = jnp.zeros((T_TAIL,), jnp.int32)
    run = _mesh_runner(spec, cfg.mesh, False)
    text = run.lower(params, state, los, los, tlo,
                     tlo).compile().as_text()
    return text, _bounds_for(spec, (E_EPOCH, T_TAIL),
                             mesh_exchange=mode)


def default_matrix() -> list:
    """The lowered config matrix — flat/assoc x static/adaptive x shards
    x streams x policy x mesh chunk/stale, one representative per axis
    value (the cross product is covered by the per-axis exactness ladder;
    the lint checks structure, which composes)."""
    E = MatrixEntry
    return [
        E("flat-static", lambda: _step_program(dict(capacity=512))),
        E("assoc-static",
          lambda: _step_program(dict(capacity=2048, assoc=8))),
        E("assoc-integrity",
          lambda: _sharded_program(
              dict(capacity=2048, assoc=8, shards=4, integrity=True))),
        E("assoc-donated",
          lambda: _step_program(dict(capacity=2048, assoc=8),
                                donate=True),
          note="R5: state donation must alias every leaf"),
        E("flat-streams4",
          lambda: _step_program(dict(capacity=512, streams=B_LANES))),
        E("assoc-streams4",
          lambda: _step_program(
              dict(capacity=512, assoc=8, streams=B_LANES))),
        E("policy-s3fifo",
          lambda: _step_program(
              dict(capacity=2048, assoc=8, policy="s3fifo"))),
        E("policy-arc",
          lambda: _step_program(
              dict(capacity=2048, assoc=8, policy="arc")),
          waive={"R3": "known debt: XLA inserts whole-mtab/ghost copies "
                       "around the ghost-clear fori carry (competitor "
                       "reference path; perf follow-up in ROADMAP)"}),
        E("policy-lfu",
          lambda: _step_program(
              dict(capacity=2048, assoc=8, policy="lfu"))),
        E("assoc-shards4",
          lambda: _sharded_program(
              dict(capacity=2048, assoc=8, shards=4))),
        E("flat-adaptive",
          lambda: _adaptive_program(
              dict(capacity=512, adaptive=True))),
        E("assoc-adaptive",
          lambda: _adaptive_program(
              dict(capacity=2048, assoc=8, adaptive=True))),
        E("assoc-adaptive-streams4",
          lambda: _adaptive_program(
              dict(capacity=512, assoc=8, adaptive=True,
                   streams=B_LANES))),
        E("mesh-chunk", lambda: _mesh_program("chunk"),
          note="needs 2 forced host devices"),
        E("mesh-stale", lambda: _mesh_program("stale"),
          note="needs 2 forced host devices",
          waive={"R3": "known debt: the device-local delta block is "
                       "copied per access inside the shard_map body "
                       "(aliasing breaks across the spmd partitioner; "
                       "perf follow-up in ROADMAP)"}),
    ]


def run_matrix(matrix=None, configs: str | None = None):
    """Lower + lint every matrix entry; returns ``(violations, rows)``
    where rows are report dicts (label, status, counts, seconds)."""
    import time
    matrix = default_matrix() if matrix is None else matrix
    if configs:
        matrix = [e for e in matrix if configs in e.label]
    violations: list[Violation] = []
    rows = []
    for e in matrix:
        t0 = time.monotonic()
        try:
            text, bounds = e.build()
        except SkipEntry as exc:
            rows.append({"label": e.label, "status": "skipped",
                         "reason": str(exc)})
            continue
        v = lint_hlo(text, bounds, config=e.label)
        active = [x for x in v if x.rule not in e.waive]
        waived = [x for x in v if x.rule in e.waive]
        violations += active
        rows.append({"label": e.label,
                     "status": ("fail" if active
                                else "waived" if waived else "ok"),
                     "violations": [x.to_dict() for x in active],
                     "waived": [dict(x.to_dict(),
                                     reason=e.waive[x.rule])
                                for x in waived],
                     "seconds": round(time.monotonic() - t0, 2)})
    return violations, rows
