"""Deliberately-bad programs, one per lint rule — the committed repros.

Each builder returns ``(hlo_text, LintBounds)`` that must make
:func:`repro.analysis.program_lint.lint_hlo` report its rule — they are
the negative tests of ``tests/test_program_lint.py``, and the R3/R6
builders double as the minimal upstream-issue repros exported under
``experiments/xla_repros/``.  Three of them reproduce historic
regressions of this repo statically:

* :func:`bad_r1_lane_scatter` — the lane-batching scatter class PR 8's
  one-hot-select writes eliminated (a scatter per access = fixed ~µs
  dispatch each).
* :func:`bad_r3_whole_table_copy` — the chain-split allocation cliff /
  width-2^18 whole-table materialization class removed in PR 5 (a
  full-buffer fusion output per access).
* :data:`BAD_R6_PER_ACCESS_PSUM` — PR 6's 62.8x bug: one all-reduce per
  access inside the scan body, here as committed HLO text (mesh
  lowerings need forced multi-device; the text is what the linter sees).

The R4 fixture is also committed text: ``outer_dimension_partitions``
is a cost-model decision XLA only makes on wide outputs, so a live
program cannot deterministically produce it on a tiny buffer.
"""
from __future__ import annotations

from .program_lint import E_EPOCH, LintBounds, T_STEP


def bad_r1_lane_scatter():
    """A scan whose body scatters into the table through fancy indexing
    with duplicate-capable dynamic indices — XLA must keep the scatter
    op (cf. the scatter-free lane-write contract)."""
    import jax
    import jax.numpy as jnp
    N = 4096

    def step(tab, key):
        rows = (key * jnp.arange(1, 5, dtype=jnp.int32)
                * jnp.int32(40503)) % N
        return tab.at[rows].add(1), key

    def prog(tab, keys):
        return jax.lax.scan(step, tab, keys)

    text = jax.jit(prog).lower(
        jnp.zeros((N,), jnp.int32),
        jnp.zeros((T_STEP,), jnp.int32)).compile().as_text()
    return text, LintBounds(access_trips=(T_STEP,))


def bad_r2_table_shaped_write():
    """A DUS per access whose update region is a quarter of the table —
    O(capacity), not O(ways)."""
    import jax
    import jax.numpy as jnp
    N, BLK = 8192, 2048

    def step(tab, i):
        blk = jnp.full((BLK,), i, jnp.int32)
        return jax.lax.dynamic_update_slice(tab, blk, (i % 16,)), i

    def prog(tab, xs):
        return jax.lax.scan(step, tab, xs)

    text = jax.jit(prog).lower(
        jnp.zeros((N,), jnp.int32),
        jnp.zeros((T_STEP,), jnp.int32)).compile().as_text()
    return text, LintBounds(access_trips=(T_STEP,), assoc=True,
                            max_update_elems=384)


def bad_r3_whole_table_copy():
    """A full-table masked select per access — the whole-table-copy /
    chain-split-allocation class: every access materializes a new
    table-shaped buffer even though only one word changes."""
    import jax
    import jax.numpy as jnp
    N = 8192

    def step(tab, i):
        mask = jnp.arange(N, dtype=jnp.int32) == (i % N)
        return jnp.where(mask, tab + 1, tab), i

    def prog(tab, xs):
        return jax.lax.scan(step, tab, xs)

    text = jax.jit(prog).lower(
        jnp.zeros((N,), jnp.int32),
        jnp.zeros((T_STEP,), jnp.int32)).compile().as_text()
    return text, LintBounds(access_trips=(T_STEP,), assoc=True,
                            max_update_elems=384)


def bad_r5_unaliasable_donation():
    """A donated input whose output cannot alias it (shape changes), so
    the compiled program carries zero input/output aliases."""
    import jax
    import jax.numpy as jnp
    import warnings

    def prog(state):
        return jnp.concatenate([state, state])

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")   # jax warns: donation unused
        text = jax.jit(prog, donate_argnums=(0,)).lower(
            jnp.zeros((4096,), jnp.int32)).compile().as_text()
    return text, LintBounds(expect_aliases=1)


# R4: outer_dimension_partitions thread dispatch on a 64-byte output.
# Committed text: the partitioner only fires on wide outputs in practice,
# so the bad case cannot be forced from jax deterministically.
BAD_R4_PARTITIONED_SMALL = """\
HloModule bad_r4_partitioned_small, is_scheduled=true

%tiny (p0: s32[16]) -> s32[16] {
  %p0 = s32[16]{0} parameter(0)
  %one = s32[] constant(1)
  %ones = s32[16]{0} broadcast(s32[] %one), dimensions={}
  ROOT %add = s32[16]{0} add(s32[16]{0} %p0, s32[16]{0} %ones)
}

ENTRY %main (arg: s32[16]) -> s32[16] {
  %arg = s32[16]{0} parameter(0)
  ROOT %out = s32[16]{0} fusion(s32[16]{0} %arg), kind=kLoop, calls=%tiny, outer_dimension_partitions={4}
}
"""


def bad_r4_partitioned_small():
    return BAD_R4_PARTITIONED_SMALL, LintBounds()


# R6: the 62.8x bug — an all-reduce per access inside the scan body.
# Committed text (the real regression needed a >= 2 device mesh; the
# linter only ever sees the module text, which this is).
BAD_R6_PER_ACCESS_PSUM = """\
HloModule bad_r6_per_access_psum, is_scheduled=true

%sum (a: s32[], b: s32[]) -> s32[] {
  %a = s32[] parameter(0)
  %b = s32[] parameter(1)
  ROOT %s = s32[] add(s32[] %a, s32[] %b)
}

%body (p: (s32[], s32[128])) -> (s32[], s32[128]) {
  %p = (s32[], s32[128]) parameter(0)
  %i = s32[] get-tuple-element((s32[], s32[128]) %p), index=0
  %one = s32[] constant(1)
  %ip = s32[] add(s32[] %i, s32[] %one)
  %t = s32[128]{0} get-tuple-element((s32[], s32[128]) %p), index=1
  %psum = s32[128]{0} all-reduce(s32[128]{0} %t), replica_groups={{0,1}}, to_apply=%sum
  ROOT %r = (s32[], s32[128]) tuple(s32[] %ip, s32[128]{0} %psum)
}

%cond (p: (s32[], s32[128])) -> pred[] {
  %p = (s32[], s32[128]) parameter(0)
  %i = s32[] get-tuple-element((s32[], s32[128]) %p), index=0
  %n = s32[] constant(96)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %n), direction=LT
}

ENTRY %main (arg: (s32[], s32[128])) -> (s32[], s32[128]) {
  %arg = (s32[], s32[128]) parameter(0)
  ROOT %w = (s32[], s32[128]) while((s32[], s32[128]) %arg), condition=%cond, body=%body
}
"""


def bad_r6_per_access_psum():
    return BAD_R6_PER_ACCESS_PSUM, LintBounds(access_trips=(96,),
                                              mesh_exchange="chunk")


#: rule id -> fixture builder (R7 is exercised through the registry API
#: in tests/test_program_lint.py — it has no single-module fixture)
FIXTURES = {
    "R1": bad_r1_lane_scatter,
    "R2": bad_r2_table_shaped_write,
    "R3": bad_r3_whole_table_copy,
    "R4": bad_r4_partitioned_small,
    "R5": bad_r5_unaliasable_donation,
    "R6": bad_r6_per_access_psum,
}
