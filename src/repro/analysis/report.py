"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json and
experiments/results/*.json.

  PYTHONPATH=src python -m repro.analysis.report [--baseline-dir DIR]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "../../..")


def load_cells(d):
    out = {}
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(f))
        out[(r["arch"], r["shape"], r["mesh"], r.get("tag") or "")] = r
    return out


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ["B", "KB", "MB", "GB", "TB"]:
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(cells, mesh="single", tag=""):
    lines = ["| arch | shape | chips | params | bytes/dev (peak) | compile |",
             "|---|---|---|---|---|---|"]
    for (a, s, m, t), r in sorted(cells.items()):
        if m != mesh or t != tag:
            continue
        if r["status"] == "skip":
            lines.append(f"| {a} | {s} | - | - | SKIP: {r['reason'][:60]} | - |")
            continue
        mem = r.get("memory", {})
        lines.append(
            f"| {a} | {s} | {r['chips']} | {r['n_params']/1e9:.1f}B "
            f"| {fmt_bytes(mem.get('peak_bytes'))} | {r['compile_s']}s |")
    return "\n".join(lines)


def roofline_table(cells, mesh="single", tag=""):
    lines = ["| arch | shape | t_comp | t_mem | t_coll | bound | "
             "useful/HLO | roofline frac | would move the bound |",
             "|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m, t), r in sorted(cells.items()):
        if m != mesh or t != tag or r["status"] != "ok":
            continue
        rl = r["roofline"]
        hint = {
            "memory": "fuse attention tiles (Pallas flash) / bf16 tiles",
            "collective": "bf16 gathers, reduce-scatter grads, a2a layout",
            "compute": "causal tile skip, drop remat recompute",
        }[rl["bottleneck"]]
        lines.append(
            f"| {a} | {s} | {rl['t_compute_s']:.3f} | {rl['t_memory_s']:.3f} "
            f"| {rl['t_collective_s']:.3f} | {rl['bottleneck']} "
            f"| {rl['useful_flops_frac']:.2f} | {rl['roofline_frac']:.4f} "
            f"| {hint} |")
    return "\n".join(lines)


def collective_summary(cells, mesh="single", tag=""):
    lines = ["| arch | shape | AG | AR | RS | A2A | CP | wire/chip |",
             "|---|---|---|---|---|---|---|---|"]
    for (a, s, m, t), r in sorted(cells.items()):
        if m != mesh or t != tag or r["status"] != "ok":
            continue
        c = r["roofline"]["collective_counts"]
        w = r["roofline"]["collective_wire_bytes_per_chip_total"]
        lines.append(
            f"| {a} | {s} | {int(c.get('all-gather', 0))} "
            f"| {int(c.get('all-reduce', 0))} "
            f"| {int(c.get('reduce-scatter', 0))} "
            f"| {int(c.get('all-to-all', 0))} "
            f"| {int(c.get('collective-permute', 0))} | {fmt_bytes(w)} |")
    return "\n".join(lines)


def adaptive_table(adir):
    """Render launch/hillclimb.py trajectory JSONs: adaptive vs best-static
    hit ratios and where the climber converged."""
    lines = ["| trace | C | adaptive hit | best static | gap | final quota "
             "| epochs |",
             "|---|---|---|---|---|---|---|"]
    for f in sorted(glob.glob(os.path.join(adir, "*.json"))):
        rows = json.load(open(f))
        ad = [r for r in rows if r.get("extra", {}).get("adaptive")]
        stat = [r for r in rows if not r.get("extra", {}).get("adaptive")]
        for r in ad:
            x = r["extra"]
            tj = x.get("trajectory", {})
            best = max((s["hit_ratio"] for s in stat), default=None)
            gap = f"{r['hit_ratio'] - best:+.4f}" if best is not None else "-"
            beststr = f"{best:.4f}" if best is not None else "-"
            lines.append(
                f"| {r['trace']} | {r['cache_size']} | {r['hit_ratio']:.4f} "
                f"| {beststr} | {gap} | {x.get('final_quota', '-')} "
                f"| {len(tj.get('quota', []))} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=None)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    ap.add_argument("--what", default="roofline",
                    choices=["roofline", "dryrun", "collectives", "adaptive"])
    args = ap.parse_args()
    if args.what == "adaptive":
        print(adaptive_table(
            args.dir or os.path.join(ROOT, "experiments/adaptive")))
        return
    cells = load_cells(args.dir or os.path.join(ROOT, "experiments/dryrun"))
    fn = {"roofline": roofline_table, "dryrun": dryrun_table,
          "collectives": collective_summary}[args.what]
    print(fn(cells, mesh=args.mesh, tag=args.tag))


if __name__ == "__main__":
    main()
