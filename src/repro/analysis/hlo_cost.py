"""Trip-count-aware cost analysis over compiled (post-SPMD) HLO text.

XLA's built-in cost_analysis counts while-loop bodies ONCE — useless for
scan-over-layers programs (a 48-layer model reports ~1/48th of its FLOPs).
This module parses ``compiled.as_text()`` into computations, resolves the
call graph (while/fusion/call/conditional) with loop trip counts recovered
from lax.scan's canonical induction structure, and accumulates:

  * flops        — dot_general (from shapes + dnums) + elementwise
  * bytes        — HBM-traffic model identical in spirit to XLA's: at each
                   computation's top level, operand bytes + output bytes per
                   op; fusion internals are free (one kernel = one read of its
                   params + one write of its outputs); gather/dynamic-slice
                   read only what they produce; scatter/DUS write the update
                   region, not the whole buffer
  * collectives  — per kind: count, output bytes, wire bytes (ring formulas),
                   each weighted by its computation's execution multiplier

Trip counts: a while cond of the form ``compare(gte(param), constant(N)),
direction=LT`` with a 0-initialized induction var (lax.scan canonical) gives
N.  Unrecognized conditions get multiplier 1 and are recorded in .warnings.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "token": 0, "opaque": 0,
}

# ops whose "flops" ~ elements of output (XLA counts transcendentals as >1;
# close enough for roofline purposes)
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "logistic", "cosine", "sine", "floor", "ceil", "round-nearest-afz",
    "compare", "select", "and", "or", "xor", "not", "clamp", "atan2",
    "remainder", "sign", "expm1", "log1p", "cbrt", "erf",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "convert", "reduce", "exponential-minus-one",
}

_GATHERISH = {"gather", "dynamic-slice"}
_SCATTERISH = {"scatter", "dynamic-update-slice"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "rng", "partition-id",
         "replica-id", "custom-call", "reduce-window", "while", "fusion",
         "call", "conditional", "sort", "map", "reduce-precision",
         "optimization-barrier", "copy-start", "copy-done", "domain",
         "send", "recv", "infeed", "outfeed"}

# unfused data-movement ops in a scheduled module are real kernels:
# read input, write output (iota/broadcast write-only)
_MATERIALIZE = {"copy", "transpose", "reshape", "concatenate", "slice",
                "pad", "reverse"}
_WRITE_ONLY = {"iota", "broadcast"}

_SHAPE_TOKEN = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_COMMENT = re.compile(r"/\*.*?\*/")
# name = <type> kind(args...   — type is either a (tuple, ...) or one token
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\((.*)$")
_CALLED = re.compile(
    r"(?:condition|body|calls|to_apply|true_computation|"
    r"false_computation|branch_computations)=\{?%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_COUNT = re.compile(r'"known_trip_count":\s*\{\s*"n":\s*"(\d+)"')


def _parse_shape(s: str):
    """'f32[16,512]{1,0}' or tuple '(f32[2], s32[])' -> list[(dtype, dims)]."""
    out = []
    for m in _SHAPE_TOKEN.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(shapes) -> float:
    return sum(_DTYPE_BYTES[dt] * math.prod(sh) for dt, sh in shapes)


def _nelems(shapes) -> float:
    return sum(math.prod(sh) for _, sh in shapes)


@dataclass
class OpInfo:
    name: str
    kind: str
    out_shapes: list
    line: str
    operands: list[str] = field(default_factory=list)
    called: list[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: dict = field(default_factory=dict)       # name -> OpInfo
    order: list = field(default_factory=list)


_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")


def _split_computations(text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if not line.startswith(" ") and "->" in line and \
                stripped.endswith("{"):
            m = _COMP_HEADER.match(stripped)
            if m:
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                continue
        if cur is None:
            continue
        if stripped == "}":
            continue
        m = _OP_LINE.match(_COMMENT.sub("", line))
        if not m:
            continue
        name, shape_s, kind, rest = m.groups()
        info = OpInfo(name=name, kind=kind, out_shapes=_parse_shape(shape_s),
                      line=stripped)
        # operands: up to the closing paren of the op call
        depth = 1
        arg_str = []
        for ch in rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            arg_str.append(ch)
        info.operands = _OPERAND_NAME.findall("".join(arg_str))
        bm = _BRANCHES.search(stripped)
        if bm:
            info.called = _OPERAND_NAME.findall(bm.group(1))
        else:
            info.called = _CALLED.findall(stripped)
        cur.ops[name] = info
        cur.order.append(name)
    return comps, entry


def _dot_flops(info: OpInfo, comp: Computation) -> float:
    out_elems = _nelems(info.out_shapes)
    m = _CONTRACT.search(info.line)
    contract = 1.0
    if m and info.operands:
        lhs = comp.ops.get(info.operands[0])
        if lhs is not None and lhs.out_shapes:
            dims = lhs.out_shapes[0][1]
            for d in m.group(1).split(","):
                if d.strip():
                    i = int(d)
                    if i < len(dims):
                        contract *= dims[i]
    return 2.0 * out_elems * contract


def _trip_count(cond: Computation) -> float | None:
    """lax.scan canonical: compare(gte, constant(N)), direction=LT."""
    consts = {}
    for name in cond.order:
        op = cond.ops[name]
        if op.kind == "constant":
            cm = re.search(r"constant\((-?\d+)\)", op.line)
            if cm:
                consts[name] = int(cm.group(1))
    for name in reversed(cond.order):
        op = cond.ops[name]
        if op.kind == "compare" and "direction=LT" in op.line:
            for o in op.operands:
                if o in consts:
                    return float(consts[o])
    return None


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_counts: dict = field(default_factory=lambda: defaultdict(float))
    coll_bytes: dict = field(default_factory=lambda: defaultdict(float))
    coll_wire_bytes: float = 0.0
    warnings: list = field(default_factory=list)
    bytes_by_kind: dict = field(default_factory=lambda: defaultdict(float))
    coll_ops: dict = field(default_factory=lambda: defaultdict(float))
    fusion_ops: dict = field(default_factory=lambda: defaultdict(float))

    def add_bytes(self, kind: str, b: float):
        self.bytes += b
        self.bytes_by_kind[kind] += b

    def add(self, o: "HloCost", k: float = 1.0):
        self.flops += o.flops * k
        self.bytes += o.bytes * k
        self.coll_wire_bytes += o.coll_wire_bytes * k
        for kk, v in o.coll_counts.items():
            self.coll_counts[kk] += v * k
        for kk, v in o.coll_bytes.items():
            self.coll_bytes[kk] += v * k
        for kk, v in o.bytes_by_kind.items():
            self.bytes_by_kind[kk] += v * k
        for kk, v in o.coll_ops.items():
            self.coll_ops[kk] += v * k
        for kk, v in o.fusion_ops.items():
            self.fusion_ops[kk] += v * k
        self.warnings += o.warnings


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_BRACE.search(line)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip()]))
    return 1


def _op_bytes(info: OpInfo, comp: Computation) -> float:
    out_b = _nbytes(info.out_shapes)
    if info.kind in _GATHERISH:
        return 2 * out_b                      # read what you produce + write
    if info.kind in _SCATTERISH:
        upd = 0.0
        if len(info.operands) >= 2:
            u = comp.ops.get(info.operands[-1]) or comp.ops.get(
                info.operands[1])
            if u is not None:
                upd = _nbytes(u.out_shapes)
        return 2 * upd + 0.0                  # read+write the update region
    opb = 0.0
    for o in info.operands:
        src = comp.ops.get(o)
        if src is not None:
            opb += _nbytes(src.out_shapes)
    return opb + out_b


class HloCostModel:
    def __init__(self, text: str):
        self.comps, self.entry = _split_computations(text)
        self._memo: dict[str, HloCost] = {}
        if self.entry is None:                # fall back: main-ish name
            for n in self.comps:
                if "main" in n:
                    self.entry = n
        assert self.entry, "no ENTRY computation found"

    def cost(self) -> HloCost:
        return self._comp_cost(self.entry)

    def _comp_cost(self, name: str) -> HloCost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = HloCost()
        if comp is None:
            return total
        self._memo[name] = total              # break cycles defensively
        for op_name in comp.order:
            info = comp.ops[op_name]
            k = info.kind
            if k == "while":
                called = [c for c in info.called if c in self.comps]
                cond = called[0] if called else None     # condition=, body=
                body = called[1] if len(called) > 1 else None
                tm = _TRIP_COUNT.search(info.line)       # XLA's annotation
                trips = float(tm.group(1)) if tm else None
                if trips is None and cond:
                    trips = self._trips(cond)
                if trips is None:
                    trips = 1.0
                    total.warnings.append(f"unknown trip count: {op_name}")
                if body:
                    total.add(self._comp_cost(body), trips)
                if cond:
                    total.add(self._comp_cost(cond), trips)
            elif k == "fusion":
                # fusion = one kernel: internal flops/collectives count,
                # internal byte traffic is free (stays in registers/VMEM)
                ccomp = None
                for c in info.called:
                    if c in self.comps:
                        sub = self._comp_cost(c)
                        total.add(sub, 1.0)
                        total.bytes -= sub.bytes          # undo internals
                        for kk, v in sub.bytes_by_kind.items():
                            total.bytes_by_kind[kk] -= v
                        ccomp = ccomp or self.comps[c]
                fb = _fusion_bytes(info, comp, ccomp)
                total.add_bytes("fusion", fb)
                sig = ",".join(f"{dt}[{'x'.join(map(str, sh))}]"
                               for dt, sh in info.out_shapes[:2])
                total.fusion_ops[sig] += fb
            elif k in ("call", "conditional", "map", "sort",
                       "select-and-scatter", "async-start", "custom-call"):
                for c in info.called:
                    if c in self.comps:
                        total.add(self._comp_cost(c), 1.0)
            elif any(k.startswith(c) for c in _COLLECTIVES):
                if k.endswith("-done"):
                    continue
                kind = next(c for c in _COLLECTIVES if k.startswith(c))
                nb = _nbytes(info.out_shapes)
                g = _group_size(info.line)
                total.coll_counts[kind] += 1
                total.coll_bytes[kind] += nb
                total.coll_wire_bytes += _wire_bytes(kind, nb, g)
                total.add_bytes("collective", 2 * nb)
                sig = f"{kind} g{g} " + ",".join(
                    f"{dt}[{'x'.join(map(str, sh))}]"
                    for dt, sh in info.out_shapes[:2])
                total.coll_ops[sig] += _wire_bytes(kind, nb, g)
            elif k == "dot":
                total.flops += _dot_flops(info, comp)
                total.add_bytes("dot", _op_bytes(info, comp))
            elif k == "convolution":
                total.flops += 2 * _nelems(info.out_shapes) * 128  # coarse
                total.add_bytes("conv", _op_bytes(info, comp))
            elif k in ("reduce", "reduce-window"):
                opb = 0.0
                for o in info.operands:
                    src = comp.ops.get(o)
                    if src is not None:
                        opb += _nelems(src.out_shapes)
                total.flops += opb
                total.add_bytes("reduce", _op_bytes(info, comp))
            elif k == "scatter":
                total.add_bytes("scatter", _op_bytes(info, comp))
            elif k in _ELEMENTWISE:
                total.flops += _nelems(info.out_shapes)
                total.add_bytes("elementwise", _op_bytes(info, comp))
            elif k in _GATHERISH:
                total.add_bytes("gather", _op_bytes(info, comp))
            elif k in _MATERIALIZE:
                total.add_bytes("datamove", 2 * _nbytes(info.out_shapes))
            elif k in _WRITE_ONLY:
                total.add_bytes("datamove", _nbytes(info.out_shapes))
            elif k in _FREE:
                continue
            else:
                total.add_bytes("other", _op_bytes(info, comp))
        return total

    def _trips(self, cond_name: str) -> float | None:
        comp = self.comps.get(cond_name)
        return _trip_count(comp) if comp else None


def _wire_bytes(kind: str, nbytes: float, group: int) -> float:
    if group <= 1:
        return 0.0
    g = group
    if kind == "all-reduce":
        return 2 * nbytes * (g - 1) / g
    if kind == "collective-permute":
        return nbytes
    return nbytes * (g - 1) / g


def _op_bytes_fusion(info: OpInfo, comp: Computation) -> float:
    """fusion = one kernel: reads its operands, writes its outputs."""
    opb = 0.0
    for o in info.operands:
        src = comp.ops.get(o)
        if src is not None:
            opb += _nbytes(src.out_shapes)
    return opb + _nbytes(info.out_shapes)


_PARAM_NUM = re.compile(r"parameter\((\d+)\)")


def _fusion_bytes(info: OpInfo, comp: Computation,
                  ccomp: Computation | None) -> float:
    """HBM traffic of one fused kernel, recognizing the two indexed-access
    patterns that dominate scan-over-layers programs:

      * a fusion parameter consumed ONLY by dynamic-slice/gather reads just
        the produced slice, not the whole buffer (remat-stack reads);
      * a fusion containing dynamic-update-slice writes the update region in
        place — the big aliased buffer is neither fully read nor fully
        rewritten (remat-stack writes, KV-cache appends).
    """
    if ccomp is None:
        return _op_bytes_fusion(info, comp)
    out_b = _nbytes(info.out_shapes)
    # param index -> op, consumer map
    params: dict[int, OpInfo] = {}
    consumers: dict[str, list[OpInfo]] = defaultdict(list)
    dus_update_bytes = 0.0
    has_dus = False
    for on in ccomp.order:
        op = ccomp.ops[on]
        if op.kind == "parameter":
            pm = _PARAM_NUM.search(op.line)
            if pm:
                params[int(pm.group(1))] = op
        for o in op.operands:
            consumers[o].append(op)
        if op.kind == "dynamic-update-slice":
            has_dus = True
            if len(op.operands) >= 2:
                upd = ccomp.ops.get(op.operands[1])
                if upd is not None:
                    dus_update_bytes += _nbytes(upd.out_shapes)

    def effective(cons, depth=0):
        """Chase consumers through convert/bitcast/copy: CPU legalization
        wraps bf16 dot/DUS operands in f32 converts that do not exist on the
        TPU target (the MXU consumes bf16 natively) — the *indexed-access*
        structure is what matters for HBM traffic."""
        out = []
        for c in cons:
            if c.kind in ("convert", "bitcast", "copy") and depth < 4:
                nxt = consumers.get(c.name, [])
                out += effective(nxt, depth + 1) if nxt else [c]
            else:
                out.append(c)
        return out

    # elements (not bytes) compare across dtypes (converts change byte size)
    out_elems_each = [math.prod(sh) for _, sh in info.out_shapes]

    total = 0.0
    inplace_bytes = 0.0
    for idx, p_op in params.items():
        p_bytes = _nbytes(p_op.out_shapes)
        p_elems = _nelems(p_op.out_shapes)
        cons = effective(consumers.get(p_op.name, []))
        if cons and all(c.kind in ("dynamic-slice", "gather") for c in cons):
            total += sum(_nbytes(c.out_shapes) for c in cons)
        elif (has_dus and p_elems
              and any(abs(p_elems - oe) < 1e-6 for oe in out_elems_each)
              and any(c.kind == "dynamic-update-slice" for c in cons)):
            # in-place update of an aliased big buffer (possibly one element
            # of a tuple output): write only the update region
            inplace_bytes += p_bytes
        else:
            total += p_bytes
    if inplace_bytes:
        total += 2 * dus_update_bytes           # read+write update regions
        total += max(0.0, out_b - inplace_bytes)  # non-aliased outputs
    else:
        total += out_b
    return total


def analyze_hlo(text: str) -> HloCost:
    return HloCostModel(text).cost()
