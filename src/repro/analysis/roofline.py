"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (DESIGN.md §8):

  compute    = HLO_FLOPs / (chips * peak_FLOPs)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = sum over collectives of wire_bytes / (link_bw * links)

cost_analysis() supplies FLOPs/bytes; collective bytes are parsed out of the
HLO text (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), with per-op wire-byte formulas using the replica-group
size parsed from the op.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per direction), 4 linksimplied by the 2D torus but collectives on one
mesh axis use 2 (bidirectional ring); we use 2 links for axis collectives.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link
LINKS_PER_AXIS = 2           # bidirectional ring on a torus axis

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 0.5, "u4": 0.5, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"(\w[\w\.\-]*)\s*=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(shape_str: str) -> float:
    """'bf16[2048,7168]' -> bytes."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:                              # iota format [num_groups, group_size]
        return max(1, int(m.group(2)))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip()]))
    return 1


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)
    out_bytes: dict = field(default_factory=dict)
    wire_bytes: float = 0.0            # per-chip bytes that cross ICI

    def add(self, kind: str, nbytes: float, group: int):
        self.counts[kind] = self.counts.get(kind, 0) + 1
        self.out_bytes[kind] = self.out_bytes.get(kind, 0.0) + nbytes
        if group <= 1:
            return
        g = group
        if kind == "all-gather":
            # each chip receives (g-1)/g of the output
            self.wire_bytes += nbytes * (g - 1) / g
        elif kind == "reduce-scatter":
            self.wire_bytes += nbytes * (g - 1) / g
        elif kind == "all-reduce":
            # ring: 2(g-1)/g x buffer
            self.wire_bytes += 2 * nbytes * (g - 1) / g
        elif kind == "all-to-all":
            self.wire_bytes += nbytes * (g - 1) / g
        elif kind == "collective-permute":
            self.wire_bytes += nbytes


def parse_collectives(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        out_shape = m.group(2) or m.group(3)
        kind = m.group(4)
        nbytes = _shape_bytes(out_shape)
        st.add(kind, nbytes, _group_size(line))
    return st


@dataclass
class SimpleColl:
    counts: dict = field(default_factory=dict)
    out_bytes: dict = field(default_factory=dict)
    wire_bytes: float = 0.0


@dataclass
class Roofline:
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll: CollectiveStats | SimpleColl
    model_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / self.chips / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / self.chips / HBM_BW

    @property
    def t_collective(self) -> float:
        # coll.wire_bytes comes from the per-device partitioned module, so it
        # is already bytes-through-this-chip's-links; no /chips here.
        return self.coll.wire_bytes / (ICI_BW * LINKS_PER_AXIS)

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_frac(self) -> float:
        """Fraction of the hardware roofline achieved if the step runs at the
        max of the three terms: useful_FLOPs / (chips*peak) / t_bound."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t == 0:
            return 0.0
        return (self.model_flops / self.chips / PEAK_FLOPS) / t

    def as_dict(self) -> dict:
        return {
            "chips": self.chips,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
            "collective_counts": self.coll.counts,
            "collective_out_bytes": self.coll.out_bytes,
            "collective_wire_bytes_per_chip_total": self.coll.wire_bytes,
        }


# ---------------------------------------------------------------------------
# MODEL_FLOPS: 6*N*D for training (N params, D tokens); 2*N*D forward-only.
# MoE: active params only.
# ---------------------------------------------------------------------------

def active_params(cfg, params_total: int) -> int:
    if not cfg.n_experts:
        return params_total
    # subtract inactive experts: (E - top_k)/E of routed-expert weights
    per_layer_routed = 3 * cfg.d_model * cfg.d_ff * cfg.n_experts
    n_moe_layers = cfg.n_layers // cfg.moe_every
    routed = per_layer_routed * n_moe_layers
    inactive = routed * (cfg.n_experts - cfg.moe_top_k) / cfg.n_experts
    return int(params_total - inactive)


def model_flops(cfg, params_total: int, tokens: int, kind: str) -> float:
    n_active = active_params(cfg, params_total)
    if kind == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens      # prefill / decode forward
