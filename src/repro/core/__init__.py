"""TinyLFU core: the paper's contribution (sketch + admission + W-TinyLFU)
plus the host cache-policy zoo it is evaluated against."""
from .sketch import (FrequencySketch, ShardedFrequencySketch, SketchConfig,
                     ExactHistogram, default_sketch)
from .tinylfu import TinyLFUAdmission, tinylfu_cache
from .wtinylfu import WTinyLFU, AdaptiveWTinyLFU
from .policies import (
    Cache, Eviction, LRUEviction, FIFOEviction, RandomEviction, LFUEviction,
    SLRUEviction, ReplacementPolicy, ARC, LIRS, TwoQ, WLFU, PLFU,
    SetAssocS3FIFO, SetAssocARC, SetAssocLFU,
)
from .simulate import run_trace, run_matrix, SimResult, save_results, \
    load_results, theoretical_max_hit_ratio

__all__ = [
    "FrequencySketch", "ShardedFrequencySketch", "SketchConfig",
    "ExactHistogram", "default_sketch",
    "TinyLFUAdmission", "tinylfu_cache", "WTinyLFU", "AdaptiveWTinyLFU",
    "Cache", "Eviction", "LRUEviction", "FIFOEviction", "RandomEviction",
    "LFUEviction", "SLRUEviction", "ReplacementPolicy", "ARC", "LIRS", "TwoQ",
    "WLFU", "PLFU", "SetAssocS3FIFO", "SetAssocARC", "SetAssocLFU",
    "run_trace", "run_matrix", "SimResult", "save_results", "load_results",
    "theoretical_max_hit_ratio",
]
