"""Trace-driven cache simulation harness (host side).

Drives any object exposing ``access(key) -> bool`` over an integer-key trace
and reports hit ratios.  This is the engine behind every paper-figure
benchmark (benchmarks/bench_*.py) and the serving prefix-pool experiments.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field, asdict
from typing import Callable, Iterable

import numpy as np


@dataclass
class SimResult:
    policy: str
    cache_size: int
    trace: str
    accesses: int
    hits: int
    hit_ratio: float
    wall_s: float
    extra: dict = field(default_factory=dict)


def run_trace(cache, trace: np.ndarray, warmup: int = 0,
              trace_name: str = "?") -> SimResult:
    """Simulate; ``warmup`` initial accesses update state but don't count.

    ``trace_name`` labels the result so single-trace callers don't produce
    ``trace="?"`` rows (run_matrix overwrites it with its own key).
    """
    t0 = time.perf_counter()
    access = cache.access
    hits = 0
    n = len(trace)
    keys = trace.tolist()                 # python ints: ~2x faster inner loop
    for i in range(warmup):
        access(keys[i])
    counted = n - warmup
    for i in range(warmup, n):
        if access(keys[i]):
            hits += 1
    wall = time.perf_counter() - t0
    name = getattr(cache, "name", type(cache).__name__)
    if hasattr(cache, "ev"):              # Cache driver: name from parts
        adm = "tinylfu+" if cache.admission is not None else ""
        name = adm + cache.ev.name
    return SimResult(policy=name, cache_size=cache.capacity, trace=trace_name,
                     accesses=counted, hits=hits,
                     hit_ratio=hits / max(1, counted), wall_s=wall)


def run_matrix(policy_factories: dict[str, Callable[[int], object]],
               traces: dict[str, np.ndarray],
               cache_sizes: Iterable[int],
               warmup_frac: float = 0.0,
               verbose: bool = True) -> list[SimResult]:
    """Cartesian sweep: policies × traces × sizes."""
    results = []
    for tname, tr in traces.items():
        warm = int(len(tr) * warmup_frac)
        for size in cache_sizes:
            for pname, factory in policy_factories.items():
                cache = factory(size)
                r = run_trace(cache, tr, warmup=warm)
                r.policy = pname
                r.trace = tname
                results.append(r)
                if verbose:
                    print(f"  {tname:>14s} C={size:<7d} {pname:<16s} "
                          f"hit={r.hit_ratio:.4f}  ({r.wall_s:.1f}s)",
                          flush=True)
    return results


def save_results(results: list[SimResult], path: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump([asdict(r) for r in results], f, indent=1)


def load_results(path: str) -> list[SimResult]:
    with open(path) as f:
        return [SimResult(**d) for d in json.load(f)]


def theoretical_max_hit_ratio(probs: np.ndarray, length: int | None = None) -> float:
    """Paper §5.2: for a static distribution the best possible hit ratio is
    bounded by sum(max(0, f_i - 1)) / sum(f_i) over expected counts f_i = p_i*N
    (the first access to each item is always a miss)."""
    n = length if length is not None else int(round(1.0 / probs.min()))
    counts = probs * n
    return float(np.maximum(0.0, counts - 1.0).sum() / counts.sum())
