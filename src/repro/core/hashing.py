"""Hash mixers used by every sketch in the system.

Two families, bit-for-bit independent but statistically equivalent:

* ``numpy`` vectorized uint64 splitmix64 — host-side (trace simulation, the
  serving scheduler's admission batches are precomputed with these).
* 32-bit-lane mixers (``mix32``) expressed in jnp — TPU has no native 64-bit
  integer multiply, so the device kernels mix two uint32 lanes (``lo``/``hi``)
  with a Murmur3/prospector-style finalizer.  See DESIGN.md §2.
"""
from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# 64-bit splitmix — vectorized numpy (host side)
# ---------------------------------------------------------------------------

_SM64_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_SM64_M1 = np.uint64(0xBF58476D1CE4E5B9)
_SM64_M2 = np.uint64(0x94D049BB133111EB)


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer. x: uint64 ndarray -> uint64 ndarray."""
    x = (x + _SM64_GAMMA).astype(np.uint64)
    x = (x ^ (x >> np.uint64(30))) * _SM64_M1
    x = (x ^ (x >> np.uint64(27))) * _SM64_M2
    x = x ^ (x >> np.uint64(31))
    return x


def probe_indices(keys: np.ndarray, num_probes: int, width: int,
                  seed: int = 0) -> np.ndarray:
    """(N,) uint64 keys -> (N, num_probes) int64 indices in [0, width).

    Each probe uses an independent seed offset so the probes behave like
    independent hash functions (required by both CM-sketch rows and Bloom
    filter probes).  ``width`` need not be a power of two (we take a modulo
    after full 64-bit mixing; bias is negligible for width << 2**64).
    """
    keys = np.asarray(keys, dtype=np.uint64)
    seeds = (np.arange(1, num_probes + 1, dtype=np.uint64)
             * np.uint64(0xC2B2AE3D27D4EB4F)) + np.uint64(seed)
    # (N, 1) + (P,) broadcast -> (N, P)
    mixed = splitmix64(keys[:, None] + seeds[None, :])
    return (mixed % np.uint64(width)).astype(np.int64)


# ---------------------------------------------------------------------------
# 32-bit lane mixers (shared constants with the jnp/Pallas code paths)
# ---------------------------------------------------------------------------

MIX32_M1 = 0x7FEB352D
MIX32_M2 = 0x846CA68B
PROBE_SALTS = (0x9E3779B9, 0x85EBCA6B, 0xC2B2AE35, 0x27D4EB2F,
               0x165667B1, 0xD3A2646C, 0xFD7046C5, 0xB55A4F09)

# set-index salts for the set-associative cache tables (distinct from every
# probe/doorkeeper salt so set placement is uncorrelated with sketch probes)
WSET_SALT = 0x1B873593          # window table set hash
MSET_SALT = 0xCC9E2D51          # main (SLRU) table: first-choice set hash
MSET2_SALT = 0x38495AB5         # main table: second-choice set hash

# sketch-shard salt (StepSpec.shards): key -> owning sketch shard.  Distinct
# from every probe/doorkeeper/set salt so shard membership is uncorrelated
# with both probe positions and cache-set placement.
SHARD_SALT = 0x52DCE729
# host-side seed for the splitmix64 shard hash (ShardedFrequencySketch);
# the host twin's hash family is independent of the device's by design
SHARD_SEED64 = 0xA24BAED4963EE407


def mix32_np(x: np.ndarray) -> np.ndarray:
    """Reference (numpy) implementation of the 32-bit mixer used on device."""
    x = np.asarray(x, dtype=np.uint32)
    x ^= x >> np.uint32(16)
    x = (x * np.uint32(MIX32_M1)).astype(np.uint32)
    x ^= x >> np.uint32(15)
    x = (x * np.uint32(MIX32_M2)).astype(np.uint32)
    x ^= x >> np.uint32(16)
    return x


def probe_indices32_np(lo: np.ndarray, hi: np.ndarray, num_probes: int,
                       width: int) -> np.ndarray:
    """Reference for the device-side probe schedule (width must be pow2)."""
    assert width & (width - 1) == 0, "device sketch width must be a power of 2"
    lo = np.asarray(lo, dtype=np.uint32)
    hi = np.asarray(hi, dtype=np.uint32)
    out = np.empty(lo.shape + (num_probes,), dtype=np.int64)
    for p in range(num_probes):
        salt = np.uint32(PROBE_SALTS[p % len(PROBE_SALTS)] + 0x9E3779B9 * (p // len(PROBE_SALTS)))
        h = mix32_np(lo + salt) ^ mix32_np(hi ^ np.uint32(0x85EBCA6B) ^ salt)
        out[..., p] = (h & np.uint32(width - 1)).astype(np.int64)
    return out


def dk_probe_index_np(lo: np.ndarray, hi: np.ndarray, p: int,
                      dk_bits: int) -> np.ndarray:
    """Reference for the device doorkeeper-probe schedule
    (kernels/sketch_common.dk_probe_index), bit-for-bit.

    The host ``SetAssocARC`` twin replays the device's B1/B2 ghost-Bloom
    arithmetic with these bit positions, which is what makes its hit
    sequence exact-by-construction rather than collision-free-only.
    """
    assert dk_bits & (dk_bits - 1) == 0, "dk_bits must be a power of 2"
    lo = np.asarray(lo, dtype=np.uint32)
    hi = np.asarray(hi, dtype=np.uint32)
    salt = np.uint32(((PROBE_SALTS[p % len(PROBE_SALTS)] ^ 0xDEADBEEF)
                      + 0x9E3779B9 * (p // len(PROBE_SALTS))) & 0xFFFFFFFF)
    h = mix32_np(lo + salt) ^ mix32_np(hi ^ np.uint32(0x85EBCA6B) ^ salt)
    return (h & np.uint32(dk_bits - 1)).astype(np.int64)


def key_to_lanes(keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """uint64 keys -> (lo, hi) uint32 lane pair."""
    keys = np.asarray(keys, dtype=np.uint64)
    lo = (keys & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (keys >> np.uint64(32)).astype(np.uint32)
    return lo, hi


def set_index32_np(keys: np.ndarray, n_sets: int, salt: int) -> np.ndarray:
    """Set index of each key in an ``n_sets``-way-partitioned table (pow2).

    Bit-for-bit the device's set hash (kernels/sketch_common.set_index): the
    host twin ``SetAssociativeSLRU`` and the device tables place every key in
    the same set, which is what makes hit-sequence parity testable.
    """
    assert n_sets & (n_sets - 1) == 0, "set count must be a power of 2"
    lo, hi = key_to_lanes(keys)
    s = np.uint32(salt)
    h = mix32_np(lo + s) ^ mix32_np(hi ^ np.uint32(0x85EBCA6B) ^ s)
    return (h & np.uint32(n_sets - 1)).astype(np.int64)


def shard_index32_np(keys: np.ndarray, shards: int) -> np.ndarray:
    """Owning sketch shard of each key (``shards`` pow2).

    Bit-for-bit the device's shard hash (kernels/sketch_common.shard_index):
    diagnostics and tests can reconstruct the device's key->shard partition
    on the host.  (The host twin ``ShardedFrequencySketch`` uses its own
    splitmix64 shard hash — hash families never line up across the engines.)
    """
    return set_index32_np(keys, shards, SHARD_SALT)


# ---------------------------------------------------------------------------
# sketch-shard geometry (StepSpec.shards / ShardedFrequencySketch)
# ---------------------------------------------------------------------------

def shard_geometry(width: int, dk_bits: int, shards: int) -> tuple[int, int]:
    """(width_shard, dk_bits_shard) for a sketch partitioned into ``shards``.

    Each shard owns a contiguous ``width/shards``-counter slice of every row
    (and a ``dk_bits/shards`` slice of the doorkeeper): a key's probes are
    confined to its owning shard's slice, so per-access updates touch only
    that shard.  Constraints: ``shards`` pow2; per-shard width a pow2
    multiple of 8 (packed-counter word alignment); per-shard doorkeeper at
    least one 32-bit word.
    """
    assert shards >= 1 and shards & (shards - 1) == 0, \
        f"shards {shards} must be a power of two"
    assert width % (shards * 8) == 0, \
        f"width {width} must be a multiple of 8*shards ({shards * 8})"
    if dk_bits:
        assert dk_bits % (shards * 32) == 0, \
            f"dk_bits {dk_bits} must be a multiple of 32*shards ({shards * 32})"
    return width // shards, dk_bits // shards


# ---------------------------------------------------------------------------
# set-associative geometry (shared by host twin and device init)
# ---------------------------------------------------------------------------

def _pow2floor(x: int) -> int:
    return 1 << (max(1, int(x)).bit_length() - 1)


def assoc_geometry(capacity: int, assoc: int) -> tuple[int, int]:
    """(n_sets, ways) hosting ``capacity`` entries at >= ``assoc`` ways/set.

    The set count rounds DOWN to a power of two so the static ways per set
    land in [assoc, 2*assoc): rounding the set count up instead would leave
    sets *narrower* than requested after the capacity is distributed, which
    measurably hurts hit ratio on skewed traces.  Tiny capacities collapse
    to one set (exact LRU/SLRU semantics).
    """
    assert capacity >= 1 and assoc >= 1
    if capacity <= assoc:
        return 1, capacity
    n = max(1, _pow2floor(capacity // assoc))
    return n, -(-capacity // n)                      # ways = ceil(cap/sets)


def slots_for(capacity: int, ways: int) -> int:
    """Table slots for ``capacity`` entries at a FIXED static ``ways``:
    pow2 set count, smallest with sets*ways >= capacity (vmapped sweeps pad
    every grid member to the shared ways of the largest configuration)."""
    need = -(-capacity // ways)                      # ceil
    return (1 << max(0, need - 1).bit_length()) * ways


def set_ways(capacity: int, n_sets: int) -> list[int]:
    """Usable ways per set expressing ``capacity`` exactly over ``n_sets``.

    The first ``capacity % n_sets`` sets get one extra way — this is the
    padding rule the device tables bake in at init time, so vmapped sweeps
    can express any capacity below the static slot count.  A capacity below
    the set count leaves the excess sets with zero usable ways (vmapped
    sweeps whose shared geometry dwarfs a grid member, or window tables
    whose pow2 set count rounds above a tiny window_cap): an access hashing
    to a zero-way window set bypasses the window and goes straight to main
    admission — identically on the device kernel and the host twin.
    """
    assert capacity >= 1
    base, rem = divmod(capacity, n_sets)
    return [base + (1 if s < rem else 0) for s in range(n_sets)]
