"""Shared host/device parameters of the runtime-adaptive window climber.

The device climber (`core.device_simulate._climb_step`, jnp running inside
the compiled epoch scan) and its host twin (`core.wtinylfu.AdaptiveWTinyLFU`,
plain-python ints) must agree bit-for-bit on every derived constant and every
integer update, or the hit-sequence parity tests cannot hold.  This module is
the single source of truth for the parameter resolution and the climb
arithmetic; it imports nothing heavy so the host-only policy path stays free
of jax.

Resolved climb vector (`resolve_climb`; see docs/API.md for the ClimbSpec
view — indices are what `climb_update` and the device `_climb_step` share):

    [0] delta0       initial / restart quota step (auto: wmax/16)
    [1] wmin         smallest window quota the climb may set (>= 1)
    [2] wmax         largest quota (auto: the adaptive table headroom)
    [3] tol          noise hysteresis band on epoch-hit deltas
                     (auto: epoch_len/256 ~= 0.4% hit-rate)
    [4] restart      |ehits - EWMA| beyond which a phase shift is assumed
                     and the step re-expands (auto: epoch_len/16 ~= 6%)
    [5] warm_epochs  epochs that only seed the baselines (default 3)

All arithmetic is int32-safe (magnitudes stay far below 2^31) and uses
python floor division, which matches ``jnp.int32`` ``//`` (both floor).
"""
from __future__ import annotations


def window_cap_max(capacity: int, window_cap: int,
                   window_max_frac: float) -> int:
    """Largest window quota the adaptive tables are sized for."""
    return max(window_cap,
               min(capacity - 1, int(round(capacity * window_max_frac))))


def resolve_climb(epoch_len: int, delta0: int, wmin: int, wmax: int,
                  tol: int, restart: int, warm_epochs: int,
                  cap_wmax: int) -> list[int]:
    """[delta0, wmin, wmax, tol, restart, warm_epochs] with zero fields
    auto-sized: delta0 = wmax/16, tol = epoch_len/256 (~0.4% hit-rate noise
    band), restart = epoch_len/16 (~6% hit-rate swing)."""
    wmax = min(wmax, cap_wmax) if wmax else cap_wmax
    d0 = delta0 or max(1, wmax // 16)
    tol = tol or max(1, epoch_len // 256)
    restart = restart or max(tol + 1, epoch_len // 16)
    return [d0, max(1, wmin), max(1, wmax), tol, restart,
            max(1, warm_epochs)]


def climb_update(climb: list[int], ehits: int, prev: int, dirn: int,
                 delta: int, ewma: int, trend: int, k: int, quota: int):
    """Pure-int twin of the device hill-climb update (one epoch boundary).

    Returns (new_quota, prev, dirn, delta, ewma, trend, k).  See
    ``core.device_simulate._climb_step`` for the rationale of each rule;
    the two implementations must stay line-for-line parallel.
    """
    d0, wmin, wmax, tol, restart, warm_epochs = climb
    diff = ehits - prev
    adiff = diff - trend
    improved = adiff > tol
    regressed = adiff < -tol
    trend_n = 0 if prev < 0 else trend + (diff - trend) // 4
    dirn_n = -dirn if regressed else dirn
    if regressed:
        delta_n = max(delta // 2, 1)
    elif improved:
        delta_n = delta
    else:
        delta_n = max((delta * 3) // 4, 1)
    shift = abs(ehits - ewma) > restart
    span4 = max(d0, (wmax - wmin) // 4)
    if shift:
        delta_n = min(max(delta_n, d0) * 2, span4) if improved else d0
    warm = k < warm_epochs
    ewma = ehits if (warm or prev < 0) else ewma + (ehits - ewma) // 4
    if not warm:
        dirn, delta, trend = dirn_n, delta_n, trend_n
    else:
        trend = 0 if prev < 0 else diff
    move = improved or regressed or shift
    step = 0 if (warm or not move) else dirn * delta
    nq = min(max(quota + step, wmin), wmax)
    if nq <= wmin:
        dirn = 1
    elif nq >= wmax:
        dirn = -1
    return nq, ehits, dirn, delta, ewma, trend, k + 1


def window_set_ways(quota: int, n_sets: int, load) -> list[int]:
    """Usable window ways per set for a runtime ``quota`` (ISSUE 5).

    ``quota >= n_sets`` keeps the exact uniform rule the static padding
    bakes in (``core.hashing.set_ways``: base everywhere, the first
    ``quota % n_sets`` sets one extra way) — a quota pinned at the
    configured split therefore still reproduces the static path
    bit-for-bit.  Below ``n_sets`` the uniform rule hands the few usable
    ways to a FIXED prefix of sets regardless of traffic, starving hot
    sets under skewed key->set load; instead the quota's ways go to the
    ``quota`` most-loaded sets of the last epoch (``load`` = per-set
    window-access counts, ties broken by set index — a stable argsort on
    descending load, matching the device's jnp twin in
    ``kernels.sketch_step._rebalance_set`` bit-for-bit).
    """
    quota, n_sets = int(quota), int(n_sets)
    if quota >= n_sets:
        base, rem = divmod(quota, n_sets)
        return [base + (1 if s < rem else 0) for s in range(n_sets)]
    order = sorted(range(n_sets), key=lambda s: (-int(load[s]), s))
    ways = [0] * n_sets
    for s in order[:quota]:
        ways[s] = 1
    return ways
