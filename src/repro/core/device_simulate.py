"""Device-resident W-TinyLFU trace simulation engine.

The host engine (`simulate.run_trace`) walks a Python per-access loop at
~µs/access; the paper's hit-ratio curves (§5, Figs 6-22) need millions of
accesses × dozens of (policy, size, window) configurations, which makes the
host loop wall-clock prohibitive at production scale.  This module runs the
*entire* trace on the accelerator instead:

* the fused step (kernels/sketch_step.py) advances sketch + window-LRU +
  SLRU-main through a chunk of accesses in one VMEM-resident launch;
* `jax.lax.scan` chains chunks so a whole trace is one compiled program —
  hit counts come back as a single scalar, keys stream device-side;
* `simulate_sweep` vmaps the scan over a *grid* of configurations
  (cache sizes × window fractions × seed traces), turning a `run_matrix`
  Cartesian experiment into one compiled program.

Backends (`backend=` argument):

* ``"jit"``     — the pure-jnp twin (`step_ref`) under `jax.jit`.  This is the
                  fast path on CPU and the only path `vmap` currently takes.
* ``"pallas"``  — the fused Pallas kernel, `interpret=True` off-TPU.  Same
                  bits, real VMEM residency + buffer donation on TPU.

Sizing mirrors the host `WTinyLFU` defaults exactly (window 1%, SLRU 80/20,
W = sample_factor·C, cap = W/C with the doorkeeper absorbing one count), so
host and device hit ratios are directly comparable: the only difference is
the hash family (64-bit splitmix on host vs 32-bit-lane mixers on device),
which perturbs hit ratios by well under ±0.005 on the golden traces
(tests/test_device_simulate.py pins this).

Keys are int64/uint64 host arrays; they are split once into (lo, hi) 32-bit
lanes on the way in (TPU has no 64-bit integer multiply — DESIGN.md §2).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.sketch_step import (StepSpec, make_step_params,
                                       init_step_state, step_ref, step_pallas,
                                       R_HITS)
from repro.kernels.sketch_common import keys_to_lanes
from .hashing import assoc_geometry, slots_for
from .sketch import _pow2ceil
from .simulate import SimResult


@dataclass(frozen=True)
class DeviceWTinyLFU:
    """One simulated W-TinyLFU configuration (host-side description).

    ``assoc=None`` uses the exact flat tables (global LRU/SLRU, O(capacity)
    per access); ``assoc=W`` uses W-way set-associative tables (per-set
    LRU/SLRU, O(W) per access — the production-scale path).
    ``counter_bits=8`` doubles the sketch footprint but lifts the counter cap
    from 15 to 255, so ``sample_factor`` above 16 no longer needs the host
    engine.
    """
    capacity: int
    window_frac: float = 0.01
    sample_factor: int = 8
    protected_frac: float = 0.8
    counters_per_item: float = 1.0
    rows: int = 4
    doorkeeper: bool = True
    dk_bits_per_item: float = 4.0
    assoc: int | None = None
    counter_bits: int = 4

    @property
    def window_cap(self) -> int:
        return max(1, int(round(self.capacity * self.window_frac)))

    @property
    def main_cap(self) -> int:
        return max(1, self.capacity - self.window_cap)

    @property
    def prot_cap(self) -> int:
        return max(1, int(self.main_cap * self.protected_frac))

    @property
    def sample_size(self) -> int:
        return self.sample_factor * self.capacity

    @property
    def cap(self) -> int:
        cmax = (1 << self.counter_bits) - 1
        return min(cmax, max(1, self.sample_factor
                             - (1 if self.doorkeeper else 0)))

    @property
    def width(self) -> int:
        w = _pow2ceil(int(max(1.0, self.counters_per_item * self.sample_size
                              / self.rows)))
        return max(8, w)

    @property
    def dk_bits(self) -> int:
        if not self.doorkeeper:
            return 0
        return max(32, _pow2ceil(int(self.sample_size
                                     * self.dk_bits_per_item)))

    @property
    def ways(self) -> int | None:
        """Static gather width in set mode: >= assoc, from the main table's
        geometry (the window shares it so both tables use one block shape)."""
        if self.assoc is None:
            return None
        return assoc_geometry(self.main_cap, self.assoc)[1]

    def _table_slots(self, cap: int, ways: int | None = None) -> int:
        """Static slots to host ``cap`` entries: the capacity itself (flat),
        or pow2 sets × ways (set-associative) with the excess marked padding
        at init.  ``ways`` overrides for vmapped sweeps sharing the largest
        configuration's block shape."""
        if self.assoc is None:
            return cap
        return slots_for(cap, ways or self.ways)

    def spec(self, window_slots: int | None = None,
             main_slots: int | None = None,
             ways: int | None = None) -> StepSpec:
        """Static geometry; slots may be padded up for vmapped sweeps."""
        return StepSpec(
            width=self.width, rows=self.rows, dk_bits=self.dk_bits,
            window_slots=window_slots or self._table_slots(self.window_cap),
            main_slots=main_slots or self._table_slots(self.main_cap),
            assoc=(ways or self.ways) if self.assoc is not None else None,
            counter_bits=self.counter_bits)

    def params(self, warmup: int = 0) -> jnp.ndarray:
        return make_step_params(self.window_cap, self.main_cap, self.prot_cap,
                                self.sample_size, self.cap, warmup,
                                counter_bits=self.counter_bits)


def _trace_lanes(trace: np.ndarray):
    lo, hi = keys_to_lanes(np.asarray(trace).astype(np.uint64))
    return lo.astype(jnp.int32), hi.astype(jnp.int32)


# ---------------------------------------------------------------------------
# single-trace simulation
# ---------------------------------------------------------------------------

# module-level jit wrappers/caches: jax's trace cache is keyed on the
# wrapper object, so per-call jax.jit(...) would retrace and recompile the
# whole scan every invocation
_jit_step = jax.jit(step_ref, static_argnums=(0,))
_pallas_cache: dict = {}
_vmap_cache: dict = {}


def _run_jit(spec: StepSpec, params, state, lo, hi):
    return _jit_step(spec, params, state, lo, hi)


def _pallas_runner(spec: StepSpec, interpret: bool):
    key = (spec, interpret)
    if key not in _pallas_cache:
        @jax.jit
        def run(params, state, los, his, nvalid):
            def body(st, x):
                clo, chi, nv = x
                st, hits = step_pallas(spec, params, st, clo, chi, nv,
                                       interpret=interpret)
                return st, hits
            return jax.lax.scan(body, state, (los, his, nvalid))
        _pallas_cache[key] = run
    return _pallas_cache[key]


def _run_pallas(spec: StepSpec, params, state, lo, hi, chunk: int,
                interpret: bool):
    n = lo.shape[0]
    pad = (-n) % chunk
    if pad:
        z = jnp.zeros((pad,), lo.dtype)
        lo = jnp.concatenate([lo, z])
        hi = jnp.concatenate([hi, z])
    nchunks = lo.shape[0] // chunk
    los = lo.reshape(nchunks, chunk)
    his = hi.reshape(nchunks, chunk)
    nvalid = jnp.minimum(
        jnp.maximum(n - jnp.arange(nchunks, dtype=jnp.int32) * chunk, 0),
        chunk)
    state, hits = _pallas_runner(spec, interpret)(params, state, los, his,
                                                  nvalid)
    return state, hits.reshape(-1)[:n]


def simulate_trace(trace: np.ndarray, capacity: int, *,
                   window_frac: float = 0.01, sample_factor: int = 8,
                   warmup: int = 0, backend: str = "jit", chunk: int = 512,
                   interpret: bool | None = None, trace_name: str = "?",
                   return_state: bool = False, **cfg_kw) -> SimResult:
    """Device twin of ``simulate.run_trace(WTinyLFU(capacity), trace)``.

    ``backend="jit"`` runs the scan twin; ``backend="pallas"`` launches the
    fused kernel per chunk (interpret mode anywhere off-TPU).  ``warmup``
    accesses update state but are not counted, exactly like ``run_trace``.
    ``assoc=W`` (via cfg_kw) selects the W-way set-associative tables —
    O(W) per access instead of O(capacity), hit ratios within ±0.01 of the
    exact path; ``counter_bits=8`` enables sample factors above 16.
    """
    cfg = DeviceWTinyLFU(capacity, window_frac=window_frac,
                         sample_factor=sample_factor, **cfg_kw)
    spec = cfg.spec()
    params = cfg.params(warmup=warmup)
    state = init_step_state(spec, cfg.window_cap, cfg.main_cap)
    lo, hi = _trace_lanes(trace)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    t0 = time.perf_counter()
    if backend == "jit":
        state, hits = _run_jit(spec, params, state, lo, hi)
    elif backend == "pallas":
        state, hits = _run_pallas(spec, params, state, lo, hi, chunk,
                                  interpret)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    regs = np.asarray(state["regs"])
    wall = time.perf_counter() - t0

    counted = len(trace) - warmup
    res = SimResult(policy="w-tinylfu(device)", cache_size=capacity,
                    trace=trace_name, accesses=counted, hits=int(regs[R_HITS]),
                    hit_ratio=int(regs[R_HITS]) / max(1, counted),
                    wall_s=wall,
                    extra={"backend": backend, "window_frac": window_frac,
                           "assoc": cfg.assoc,
                           "device": jax.default_backend()})
    if return_state:
        return res, state, hits
    return res


# ---------------------------------------------------------------------------
# vmapped multi-configuration sweeps: one compiled program per grid
# ---------------------------------------------------------------------------

def simulate_sweep(trace: np.ndarray, capacities, *, window_fracs=(0.01,),
                   sample_factor: int = 8, warmup: int = 0,
                   trace_name: str = "?", verbose: bool = False,
                   mode: str = "auto", **cfg_kw) -> list[SimResult]:
    """Cartesian (capacity × window_frac) sweep as one compiled program.

    All configurations share the static geometry of the *largest* one (table
    slots are padded up; smaller capacities mark the excess slots as padding),
    so ONE compiled step program serves the whole grid; the sketch of a
    smaller configuration is sized for the largest sample — its estimates are
    slightly *more* accurate than a per-size host sketch, which is within the
    golden tolerance.

    ``mode``: ``"vmap"`` runs the whole grid as a single vmapped scan (the
    shape intended for accelerators — grid points ride the vector lanes; all
    configs share the largest config's sketch geometry); ``"sequential"``
    runs one compiled single-config scan per grid point with each config's
    own host-matched sketch sizing (faster on CPU, where XLA's batching
    rules serialize the lanes anyway, and directly comparable to per-size
    host results); ``"auto"`` picks vmap on TPU and sequential elsewhere.

    ``trace`` may be ``(N,)`` (shared by all configs) or ``(G, N)`` (one
    trace per grid point, e.g. seed sweeps).
    """
    grid = [DeviceWTinyLFU(C, window_frac=wf, sample_factor=sample_factor,
                           **cfg_kw)
            for C in capacities for wf in window_fracs]
    gridlab = [(C, wf) for C in capacities for wf in window_fracs]
    if mode == "auto":
        mode = "vmap" if jax.default_backend() == "tpu" else "sequential"

    trace = np.asarray(trace)
    shared_trace = trace.ndim == 1
    if not shared_trace and trace.shape[0] != len(grid):
        raise ValueError(f"trace grid dim {trace.shape[0]} != "
                         f"{len(grid)} configurations")
    n_per = trace.shape[-1]

    t0 = time.perf_counter()
    if mode == "vmap":
        # one program for the whole grid: shared (largest) static geometry,
        # per-config capacities traced, excess slots marked as padding
        big = max(grid, key=lambda c: c.capacity)
        # set mode: the whole grid shares the largest config's block shape
        # (ways).  A member whose main_cap falls below the shared MAIN set
        # count would leave most of its sets zero-way — keys could never
        # enter its main table and its hit ratio would silently collapse —
        # so such grids are rejected toward sequential mode.  (Zero-way
        # WINDOW sets are fine: those accesses bypass to main admission.)
        mslots = max(c._table_slots(c.main_cap, big.ways) for c in grid)
        if big.assoc is not None:
            msets = mslots // big.ways
            for c in grid:
                if c.main_cap < msets:
                    raise ValueError(
                        f"vmap assoc sweep: main_cap {c.main_cap} < shared "
                        f"{msets} sets (capacity {c.capacity} vs "
                        f"{big.capacity}); run mode='sequential'")
        spec = big.spec(
            window_slots=max(c._table_slots(c.window_cap, big.ways)
                             for c in grid),
            main_slots=mslots, ways=big.ways)
        pstack = jnp.stack([c.params(warmup=warmup) for c in grid])
        sstack = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[init_step_state(spec, c.window_cap, c.main_cap) for c in grid])
        if shared_trace:
            lo, hi = _trace_lanes(trace)
            in_axes = (0, 0, None, None)
        else:
            lanes = [_trace_lanes(t) for t in trace]
            lo = jnp.stack([l for l, _ in lanes])
            hi = jnp.stack([h for _, h in lanes])
            in_axes = (0, 0, 0, 0)
        key = (spec, in_axes)
        if key not in _vmap_cache:
            _vmap_cache[key] = jax.jit(jax.vmap(
                lambda p, s, l, h: step_ref(spec, p, s, l, h),
                in_axes=in_axes))
        out_states, _ = _vmap_cache[key](pstack, sstack, lo, hi)
        regs = np.asarray(out_states["regs"])
    elif mode == "sequential":
        # per-config tight specs: sketches sized exactly like the host's
        # per-capacity sizing, one compile per distinct geometry
        if shared_trace:
            lanes = [_trace_lanes(trace)] * len(grid)
        else:
            lanes = [_trace_lanes(t) for t in trace]
        outs = []
        for c, (l, h) in zip(grid, lanes):
            spec = c.spec()
            st = init_step_state(spec, c.window_cap, c.main_cap)
            outs.append(_jit_step(spec, c.params(warmup=warmup), st,
                                  l, h)[0]["regs"])
        regs = np.stack([np.asarray(r) for r in outs])
    else:
        raise ValueError(f"unknown mode {mode!r}")
    wall = time.perf_counter() - t0

    counted = n_per - warmup
    out = []
    for g, (C, wf) in enumerate(gridlab):
        hits = int(regs[g, R_HITS])
        out.append(SimResult(
            policy="w-tinylfu(device)", cache_size=C, trace=trace_name,
            accesses=counted, hits=hits, hit_ratio=hits / max(1, counted),
            # per-row amortized wall so accesses/wall_s is per-config and
            # comparable to host rows; the grid's total is in grid_wall_s
            wall_s=wall / len(grid),
            extra={"backend": f"jit+{mode}", "window_frac": wf,
                   "grid": len(grid), "grid_wall_s": wall,
                   "assoc": grid[g].assoc,
                   "device": jax.default_backend()}))
        if verbose:
            print(f"  {trace_name:>12s} C={C:<7d} wf={wf:<5.2f} "
                  f"hit={out[-1].hit_ratio:.4f}  (grid of {len(grid)}, "
                  f"{wall:.1f}s total)", flush=True)
    return out
