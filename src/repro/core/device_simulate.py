"""Device-resident W-TinyLFU trace simulation engine.

The host engine (`simulate.run_trace`) walks a Python per-access loop at
~µs/access; the paper's hit-ratio curves (§5, Figs 6-22) need millions of
accesses × dozens of (policy, size, window) configurations, which makes the
host loop wall-clock prohibitive at production scale.  This module runs the
*entire* trace on the accelerator instead:

* the fused step (kernels/sketch_step.py) advances sketch + window-LRU +
  SLRU-main through a chunk of accesses in one VMEM-resident launch;
* `jax.lax.scan` chains chunks so a whole trace is one compiled program —
  hit counts come back as a single scalar, keys stream device-side;
* `simulate_sweep` vmaps the scan over a *grid* of configurations
  (cache sizes × window fractions × seed traces), turning a `run_matrix`
  Cartesian experiment into one compiled program.

Backends (`backend=` argument):

* ``"jit"``     — the pure-jnp twin (`step_ref`) under `jax.jit`.  This is the
                  fast path on CPU and the only path `vmap` currently takes.
* ``"pallas"``  — the fused Pallas kernel, `interpret=True` off-TPU.  Same
                  bits, real VMEM residency + buffer donation on TPU.

Sizing mirrors the host `WTinyLFU` defaults exactly (window 1%, SLRU 80/20,
W = sample_factor·C, cap = W/C with the doorkeeper absorbing one count), so
host and device hit ratios are directly comparable: the only difference is
the hash family (64-bit splitmix on host vs 32-bit-lane mixers on device),
which perturbs hit ratios by well under ±0.005 on the golden traces
(tests/test_device_simulate.py pins this).

Keys are int64/uint64 host arrays; they are split once into (lo, hi) 32-bit
lanes on the way in (TPU has no 64-bit integer multiply — DESIGN.md §2).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.sketch_step import (StepSpec, MESH_AXIS, make_step_params,
                                       init_step_state, step_ref, step_pallas,
                                       rebalance, _state_keys,
                                       R_HITS, R_WQUOTA, R_EHITS)
from repro.kernels.sketch_common import keys_to_lanes, POLICIES
from repro.kernels.sketch_merge import merge_halve, merge_halve_mesh
from . import adaptive
from .hashing import assoc_geometry, slots_for
from .sketch import _pow2ceil
from .simulate import SimResult


@dataclass(frozen=True)
class DeviceWTinyLFU:
    """One simulated W-TinyLFU configuration (host-side description).

    ``assoc=None`` uses the exact flat tables (global LRU/SLRU, O(capacity)
    per access); ``assoc=W`` uses W-way set-associative tables (per-set
    LRU/SLRU, O(W) per access — the production-scale path).
    ``counter_bits=8`` doubles the sketch footprint but lifts the counter cap
    from 15 to 255, so ``sample_factor`` above 16 no longer needs the host
    engine.

    ``shards=S`` (pow2 > 1) partitions the frequency sketch into S
    device-resident shards: per-access writes touch only the owning shard's
    delta slice and a fused ``merge_halve`` folds the deltas into the global
    estimate every ``merge_every`` accesses — inside the compiled program,
    no host sync (kernels/sketch_merge.py).  ``merge_every=0`` auto-sizes to
    ``min(4096, sample_size)`` so the deferred §3.3 aging stays within one
    reset period of the per-access schedule.

    ``mesh=`` (a 1-D ``("shard",)`` mesh from
    ``distributed.mesh.make_shard_mesh``) executes the sharded run over
    MULTIPLE devices: the delta halves become shard-major arrays
    partitioned along the mesh axis (block placement — device ``d`` owns
    shards ``[d*S/D, (d+1)*S/D)``, matching
    ``distributed.mesh.shard_placement``), the global halves and cache
    tables are replicated, and the per-access path exchanges NOTHING —
    all cross-device traffic is per-epoch-chunk or rarer, selected by
    ``mesh_exchange`` (it used to be one 2-int ``psum`` per access, a 62x
    overhead on the forced-2-device bench):

    * ``"chunk"`` (default, exact): one all-gather of the delta blocks on
      entering the compiled program composes the single-device
      [global || delta] layout on every device, each device then replays
      the identical epoch-chunked single-device program (step scan +
      ``merge_halve`` fold, which keeps the deltas self-contained), and
      the local delta blocks are sliced back out at exit.  Bit-identical
      to the single-device sharded run — same hit sequence, same final
      sketch state (tests/test_distributed.py pins this over forced host
      devices).
    * ``"stale"`` (speculative): per-access delta writes stay
      device-local and admission estimates read only the replicated
      global halves — stale by at most one merge epoch — so the one
      collective is the per-epoch ``merge_halve_mesh`` all-gather fold
      that reconciles the deltas.  Lands in the goldens-±0.01 tier of the
      exactness ladder, with the host twin
      ``WTinyLFU(stale_admission=True)``.

    Requires ``shards % n_devices == 0`` and ``backend="jit"``.

    ``integrity=True`` (requires ``shards > 1``) arms the self-healing
    integrity fold: per-shard checksums over the global sketch halves are
    verified and refreshed at every merge boundary, and a mismatched
    (corrupted) shard is quarantined — its slices zeroed, its counts
    re-learned by the §3.3 aging within a few sample periods
    (kernels/sketch_merge.py).

    ``run()`` is the general entry point — it adds epoch-boundary
    checkpointing (``checkpoint_dir=``/``checkpoint_every=``) on top of
    what ``simulate_trace`` does; :func:`resume_trace` restores the latest
    checkpoint and continues bit-identically.
    """
    capacity: int
    window_frac: float = 0.01
    sample_factor: int = 8
    protected_frac: float = 0.8
    counters_per_item: float = 1.0
    rows: int = 4
    doorkeeper: bool = True
    dk_bits_per_item: float = 4.0
    assoc: int | None = None
    counter_bits: int = 4
    adaptive: bool = False        # runtime hill-climbed window quota
    window_max_frac: float = 0.5  # adaptive: table headroom for the climb
    shards: int = 1               # sketch shards; >1 = delta/global split
    merge_every: int = 0          # sharded merge cadence; 0 = auto
    mesh: object = None           # ("shard",) mesh; None = single device
    mesh_exchange: str = "chunk"  # mesh cadence: "chunk" exact | "stale"
    integrity: bool = False       # checksum + shard-quarantine merge fold
    streams: int = 1              # lane-batched tenant caches per program
    policy: str = "wtinylfu"      # device policy panel: s3fifo | arc | lfu

    def __post_init__(self):
        # eager validation (ISSUE 7): bad values used to surface as XLA
        # shape errors (or assertion tracebacks) from deep inside the
        # compile path — fail at construction with actionable messages
        # instead.  simulate_sweep builds one DeviceWTinyLFU per grid
        # point, so sweeps inherit every check.
        if self.capacity < 1:
            raise ValueError(f"capacity {self.capacity} must be >= 1")
        if not 0.0 < self.window_frac < 1.0:
            raise ValueError(f"window_frac {self.window_frac} must be in "
                             "(0, 1) — it is the window's share of capacity")
        if not 0.0 < self.protected_frac < 1.0:
            raise ValueError(f"protected_frac {self.protected_frac} must be "
                             "in (0, 1)")
        if self.sample_factor < 1:
            raise ValueError(f"sample_factor {self.sample_factor} must be "
                             ">= 1 (W = sample_factor * capacity)")
        if self.counter_bits not in (4, 8):
            raise ValueError(f"counter_bits {self.counter_bits} must be 4 "
                             "(paper §3.4.1 nibbles) or 8 (byte counters)")
        if self.rows < 1:
            raise ValueError(f"rows {self.rows} must be >= 1")
        if self.assoc is not None and self.assoc < 1:
            raise ValueError(f"assoc {self.assoc} must be >= 1 ways (or "
                             "None for the flat exact tables)")
        if self.shards < 1 or (self.shards & (self.shards - 1)):
            raise ValueError(f"shards {self.shards} must be a power of two "
                             "(shard membership is a masked hash)")
        if self.merge_every < 0:
            raise ValueError(f"merge_every {self.merge_every} must be >= 0 "
                             "(0 = auto min(4096, sample_size))")
        if self.mesh_exchange not in ("chunk", "stale"):
            raise ValueError(f"mesh_exchange {self.mesh_exchange!r} must be "
                             "'chunk' or 'stale'")
        if self.integrity and self.shards <= 1:
            raise ValueError("integrity=True requires shards > 1: the "
                             "checksums cover the per-shard global sketch "
                             "halves, which only exist in sharded mode")
        if self.streams < 1:
            raise ValueError(f"streams {self.streams} must be >= 1 (the "
                             "number of lane-batched tenant caches; 1 = "
                             "the unbatched single-stream engine)")
        if self.streams > 1 and self.mesh is not None:
            raise ValueError(
                f"streams {self.streams} cannot combine with mesh=: lanes "
                "batch WHOLE per-tenant engines while the mesh partitions "
                "ONE engine's sketch across devices — shard tenants over "
                "meshes at the process level instead")
        if self.policy not in POLICIES:
            raise ValueError(f"policy {self.policy!r} must be one of "
                             f"{POLICIES}")
        if self.policy != "wtinylfu":
            if self.assoc is None:
                raise ValueError(
                    f"policy {self.policy!r} requires assoc= (the "
                    "competitor panel reuses the set-associative table "
                    "machinery; the flat exact tables are W-TinyLFU-only)")
            if self.shards > 1 or self.mesh is not None:
                raise ValueError(
                    f"policy {self.policy!r} cannot combine with shards/"
                    "mesh: the sharded sketch split serves the TinyLFU "
                    "admission filter — competitors run single-sketch")
            if self.adaptive:
                raise ValueError(
                    f"policy {self.policy!r} cannot combine with "
                    "adaptive=True: the hill-climbed quota rebalances the "
                    "W-TinyLFU window/main split (arc adapts its own "
                    "target p as runtime state instead)")
            if self.integrity:
                raise ValueError(
                    f"policy {self.policy!r} cannot combine with "
                    "integrity=True (it requires shards > 1)")
        if self.policy == "arc" and not self.doorkeeper:
            raise ValueError(
                "policy 'arc' requires doorkeeper=True: the B1/B2 ghost "
                "lists are Bloom halves addressed by the doorkeeper probe "
                "schedule, so dk_bits must be sized (> 0)")

    @property
    def window_cap(self) -> int:
        # arc/lfu run main-table-only: the window table stays allocated at
        # its 1-entry minimum and the kernels never touch it
        if self.policy in ("arc", "lfu"):
            return 1
        return max(1, int(round(self.capacity * self.window_frac)))

    @property
    def main_cap(self) -> int:
        # arc/lfu: the main table IS the cache (no window share)
        if self.policy in ("arc", "lfu"):
            return max(1, self.capacity)
        return max(1, self.capacity - self.window_cap)

    @property
    def window_cap_max(self) -> int:
        """Largest quota the adaptive tables can host (static headroom)."""
        if not self.adaptive:
            return self.window_cap
        return adaptive.window_cap_max(self.capacity, self.window_cap,
                                       self.window_max_frac)

    @property
    def main_cap_max(self) -> int:
        """Largest main capacity (window quota at its minimum of 1)."""
        return max(1, self.capacity - 1)

    @property
    def prot_cap(self) -> int:
        return max(1, int(self.main_cap * self.protected_frac))

    @property
    def sample_size(self) -> int:
        return self.sample_factor * self.capacity

    @property
    def cap(self) -> int:
        cmax = (1 << self.counter_bits) - 1
        return min(cmax, max(1, self.sample_factor
                             - (1 if self.doorkeeper else 0)))

    @property
    def width(self) -> int:
        w = _pow2ceil(int(max(1.0, self.counters_per_item * self.sample_size
                              / self.rows)))
        # sharded: each shard needs at least one packed word per row
        return max(8 * self.shards, w)

    @property
    def dk_bits(self) -> int:
        if not self.doorkeeper:
            return 0
        # sharded: each shard needs at least one 32-bit doorkeeper word
        return max(32 * self.shards, _pow2ceil(int(self.sample_size
                                                   * self.dk_bits_per_item)))

    @property
    def merge_epoch(self) -> int:
        """Resolved sharded merge cadence (accesses between merge_halve
        folds).  ``merge_every=0`` auto-sizes to ``min(4096, sample_size)``:
        never defer the §3.3 aging past one reset period, and never merge
        less often than the adaptive default epoch."""
        return self.merge_every or max(1, min(4096, self.sample_size))

    @property
    def ways(self) -> int | None:
        """Static gather width in set mode: >= assoc, from the main table's
        geometry (the window shares it so both tables use one block shape).
        Adaptive sizing uses the LARGEST main capacity the climb can reach."""
        if self.assoc is None:
            return None
        return assoc_geometry(self.main_cap_max if self.adaptive
                              else self.main_cap, self.assoc)[1]

    def _table_slots(self, cap: int, ways: int | None = None) -> int:
        """Static slots to host ``cap`` entries: the capacity itself (flat),
        or pow2 sets × ways (set-associative) with the excess marked padding
        at init.  ``ways`` overrides for vmapped sweeps sharing the largest
        configuration's block shape."""
        if self.assoc is None:
            return cap
        return slots_for(cap, ways or self.ways)

    def spec(self, window_slots: int | None = None,
             main_slots: int | None = None,
             ways: int | None = None) -> StepSpec:
        """Static geometry; slots may be padded up for vmapped sweeps.
        Adaptive mode sizes both tables for the climb's full quota range
        (window up to ``window_max_frac``, main up to capacity - 1)."""
        wsize = self.window_cap_max if self.adaptive else self.window_cap
        msize = self.main_cap_max if self.adaptive else self.main_cap
        return StepSpec(
            width=self.width, rows=self.rows, dk_bits=self.dk_bits,
            window_slots=window_slots or self._table_slots(wsize),
            main_slots=main_slots or self._table_slots(msize),
            assoc=(ways or self.ways) if self.assoc is not None else None,
            counter_bits=self.counter_bits, adaptive=self.adaptive,
            shards=self.shards, mesh_devices=self.mesh_devices,
            # normalized so single-device specs share one compile cache key
            mesh_exchange=self.mesh_exchange if self.mesh is not None
            else "chunk", integrity=self.integrity, streams=self.streams,
            policy=self.policy)

    @property
    def mesh_devices(self) -> int:
        """Devices of the ``("shard",)`` mesh (0 = single-device layout)."""
        if self.mesh_exchange not in ("chunk", "stale"):
            raise ValueError(f"mesh_exchange {self.mesh_exchange!r} must be "
                             "'chunk' or 'stale'")
        if self.mesh is None:
            if self.mesh_exchange != "chunk":
                raise ValueError("mesh_exchange='stale' requires mesh= (a "
                                 "('shard',) mesh from "
                                 "distributed.mesh.make_shard_mesh)")
            return 0
        if tuple(self.mesh.axis_names) != ("shard",):
            raise ValueError(f"mesh axes {self.mesh.axis_names} != "
                             "('shard',) — build it with "
                             "distributed.mesh.make_shard_mesh")
        n = int(self.mesh.devices.size)
        if self.shards <= 1:
            raise ValueError("mesh execution requires shards > 1")
        if self.shards % n:
            raise ValueError(f"shards {self.shards} must be a multiple of "
                             f"the mesh size {n} (block placement)")
        return n

    def params(self, warmup: int = 0) -> jnp.ndarray:
        return make_step_params(self.window_cap, self.main_cap, self.prot_cap,
                                self.sample_size, self.cap, warmup,
                                counter_bits=self.counter_bits)

    def run(self, trace, *, warmup: int = 0, backend: str = "jit",
            chunk: int = 512, interpret: bool | None = None,
            trace_name: str = "?", climb: "ClimbSpec | None" = None,
            checkpoint_dir: str | None = None, checkpoint_every: int = 0,
            return_state: bool = False, on_checkpoint=None,
            fault_hook=None):
        """Simulate ``trace`` with optional epoch-boundary checkpointing.

        Without ``checkpoint_dir`` this is ``simulate_trace`` for this
        configuration (one compiled program over the whole trace).  With
        it, the trace is segmented at merge-epoch boundaries — every chunk
        boundary is already a clean state handoff, so segmented execution
        is bit-identical to the single-program run — and the full engine
        state tree (sketch halves, cache tables, climb registers, hit
        prefix, trace cursor) is snapshotted via
        ``checkpoint.store.AsyncCheckpointer`` after each segment.
        :func:`resume_trace` restores the latest complete checkpoint and
        continues the run, reproducing the uninterrupted hit sequence and
        final sketch words exactly.

        ``checkpoint_every`` (accesses) must be a positive multiple of the
        run's epoch — ``climb.epoch_len`` (adaptive), ``merge_epoch``
        (sharded), anything (unsharded static) — 0 auto-sizes to roughly
        32k accesses rounded to whole epochs.  Checkpointing requires
        ``backend="jit"`` (the segmented scan is the jit scan).

        ``on_checkpoint(cursor)`` fires after each snapshot is queued (the
        fault-injection harness prints its kill markers from it);
        ``fault_hook(cursor, state) -> state | None`` runs between
        segments on the canonical single-device state layout and may
        return a mutated state — the injection point for corruption
        experiments (``core.faults``).
        """
        return _run_checkpointed(
            self, trace, warmup=warmup, backend=backend, chunk=chunk,
            interpret=interpret, trace_name=trace_name, climb=climb,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            return_state=return_state, on_checkpoint=on_checkpoint,
            fault_hook=fault_hook)


def _trace_lanes(trace: np.ndarray):
    lo, hi = keys_to_lanes(np.asarray(trace).astype(np.uint64))
    return lo.astype(jnp.int32), hi.astype(jnp.int32)


def _check_trace_streams(cfg: "DeviceWTinyLFU", trace: np.ndarray):
    """Eager trace-shape vs ``streams`` validation (PR 7 style): a mismatch
    must raise a ValueError naming the field, not a compiled-shape error
    from deep inside the vmapped scan."""
    trace = np.asarray(trace)
    if cfg.streams > 1:
        if trace.ndim != 2 or trace.shape[0] != cfg.streams:
            raise ValueError(
                f"streams {cfg.streams} expects a (B, T) = ({cfg.streams}, "
                f"T) trace — one key row per tenant lane; got trace shape "
                f"{tuple(trace.shape)}")
    elif trace.ndim != 1:
        raise ValueError(
            f"trace shape {tuple(trace.shape)} carries a lane axis but "
            "streams is 1 (the unbatched engine, bit-identical to a 1-D "
            f"run) — construct DeviceWTinyLFU(streams={trace.shape[0]}) "
            "to batch tenant lanes, or pass a 1-D trace")


# ---------------------------------------------------------------------------
# single-trace simulation
# ---------------------------------------------------------------------------

# module-level jit wrappers/caches: jax's trace cache is keyed on the
# wrapper object, so per-call jax.jit(...) would retrace and recompile the
# whole scan every invocation.  The dict memos are bounded like _mesh_cache
# (PR 6): a geometry sweep mints a fresh spec per grid point and every
# entry pins a compiled executable, so unbounded memos grow without limit
_jit_step = jax.jit(step_ref, static_argnums=(0,))
_pallas_cache: dict = {}
_vmap_cache: dict = {}
_STEP_CACHE_LIMIT = 32


def _run_jit(spec: StepSpec, params, state, lo, hi):
    return _jit_step(spec, params, state, lo, hi)


def _chunk_lanes(x, nc: int, L: int):
    """(..., nc*L) -> scan-major (nc, ..., L): the chunk axis leads (scan
    iterates over it) and the lane axis, if any, rides along so each scan
    step sees per-lane (B, L) key rows."""
    if x.ndim == 1:
        return x.reshape(nc, L)
    return x.reshape(x.shape[0], nc, L).swapaxes(0, 1)


def _pallas_runner(spec: StepSpec, interpret: bool):
    key = (spec, interpret)
    if key not in _pallas_cache:
        if len(_pallas_cache) >= _STEP_CACHE_LIMIT:
            _pallas_cache.clear()
        @jax.jit
        def run(params, state, los, his, nvalid):
            def body(st, x):
                clo, chi, nv = x
                st, hits = step_pallas(spec, params, st, clo, chi, nv,
                                       interpret=interpret)
                return st, hits
            return jax.lax.scan(body, state, (los, his, nvalid))
        _pallas_cache[key] = run
    return _pallas_cache[key]


def _run_pallas(spec: StepSpec, params, state, lo, hi, chunk: int,
                interpret: bool):
    n = lo.shape[-1]
    pad = (-n) % chunk
    if pad:
        z = jnp.zeros(lo.shape[:-1] + (pad,), lo.dtype)
        lo = jnp.concatenate([lo, z], axis=-1)
        hi = jnp.concatenate([hi, z], axis=-1)
    nchunks = lo.shape[-1] // chunk
    los = _chunk_lanes(lo, nchunks, chunk)
    his = _chunk_lanes(hi, nchunks, chunk)
    # lanes share the chunking (one (B, T) trace, one T), so nvalid stays a
    # per-chunk scalar that every lane's masked tail consumes identically
    nvalid = jnp.minimum(
        jnp.maximum(n - jnp.arange(nchunks, dtype=jnp.int32) * chunk, 0),
        chunk)
    state, hits = _pallas_runner(spec, interpret)(params, state, los, his,
                                                  nvalid)
    if spec.streams > 1:                     # (nc, B, chunk) -> (B, T)
        return state, hits.swapaxes(0, 1).reshape(spec.streams, -1)[:, :n]
    return state, hits.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# sharded sketches: epoch-chunked scan + in-program merge_halve
# ---------------------------------------------------------------------------

_sharded_cache: dict = {}
_mesh_cache: dict = {}
# compiled mesh runners are keyed on (spec, mesh, adaptive); a geometry sweep
# mints a fresh spec per grid point, and each entry pins a compiled
# multi-device executable — bound the memo like the host set-index memos
_MESH_CACHE_LIMIT = 32


def _mesh_state_specs(spec: StepSpec):
    """shard_map in/out partition specs for the mesh-layout state pytree:
    the shard-major delta arrays ride the ("shard",) axis, everything else
    (global sketch halves, cache tables, registers) is replicated."""
    from jax.sharding import PartitionSpec as P
    return {k: (P("shard") if k in ("dcounters", "ddoorkeeper") else P())
            for k in _state_keys(spec)}


def _from_mesh_state(spec: StepSpec, state: dict) -> dict:
    """Mesh-layout state -> the single-device [global || delta] layout, so
    callers (and the parity tests) compare final sketch words directly."""
    out = {k: v for k, v in state.items()
           if k not in ("dcounters", "ddoorkeeper")}
    delta = state["dcounters"].transpose(1, 0, 2).reshape(spec.counter_words)
    out["counters"] = jnp.concatenate([state["counters"], delta])
    ddk = (state["ddoorkeeper"].reshape(spec.dk_words) if spec.dk_bits
           else jnp.zeros_like(state["doorkeeper"]))
    out["doorkeeper"] = jnp.concatenate([state["doorkeeper"], ddk])
    return out


def _to_mesh_state(spec: StepSpec, state: dict) -> dict:
    """Inverse of :func:`_from_mesh_state`: the canonical single-device
    [global || delta] layout -> the mesh (shard-major delta) layout.  This
    is the elastic-restore path — checkpoints always store the canonical
    layout, so a snapshot taken on ANY mesh size (including a plain
    single-device run) re-shards onto any other mesh whose size divides
    ``spec.shards``."""
    H, HD = spec.counter_words, spec.dk_words
    out = {k: v for k, v in state.items()
           if k not in ("counters", "doorkeeper")}
    out["counters"] = state["counters"][:H]
    out["doorkeeper"] = state["doorkeeper"][:HD]
    out["dcounters"] = state["counters"][H:].reshape(
        spec.rows, spec.shards, spec.wps_shard).transpose(1, 0, 2)
    out["ddoorkeeper"] = (
        state["doorkeeper"][HD:].reshape(spec.shards, spec.dkw_shard)
        if spec.dk_bits
        else jnp.zeros((spec.shards, spec.dkw_shard), jnp.int32))
    return out


def _gather_delta_state(spec: StepSpec, state: dict) -> dict:
    """Inside the shard_map body: all-gather the device-local delta blocks
    and compose the single-device [global || delta] layout on EVERY device
    — the one collective of the exact ``mesh_exchange="chunk"`` mode, paid
    once on entering the compiled program (the epoch fold keeps the
    replicated replica self-contained from then on)."""
    cd = jax.lax.all_gather(state["dcounters"], MESH_AXIS, axis=0, tiled=True)
    delta = cd.transpose(1, 0, 2).reshape(spec.counter_words)
    if spec.dk_bits:
        dd = jax.lax.all_gather(state["ddoorkeeper"], MESH_AXIS,
                                axis=0, tiled=True)
        ddk = dd.reshape(spec.dk_words)
    else:
        ddk = jnp.zeros_like(state["doorkeeper"])
    out = {k: v for k, v in state.items()
           if k not in ("dcounters", "ddoorkeeper")}
    out["counters"] = jnp.concatenate([state["counters"], delta])
    out["doorkeeper"] = jnp.concatenate([state["doorkeeper"], ddk])
    return out


def _split_delta_state(spec: StepSpec, state: dict, state0: dict) -> dict:
    """Inverse of :func:`_gather_delta_state` on exiting the program: slice
    this device's block of the (replicated) delta half back out so the
    returned pytree matches the mesh-layout partition specs.  ``state0`` is
    the device-local input state (for the dk_bits=0 placeholder, whose
    (local_shards, 1) block never reshapes from the flat layout)."""
    H, HD = spec.counter_words, spec.dk_words
    L = spec.local_shards
    base = jax.lax.axis_index(MESH_AXIS).astype(jnp.int32) * L
    delta = state["counters"][H:].reshape(
        spec.rows, spec.shards, spec.wps_shard).transpose(1, 0, 2)
    out = {k: v for k, v in state.items()
           if k not in ("counters", "doorkeeper")}
    out["counters"] = state["counters"][:H]
    out["doorkeeper"] = state["doorkeeper"][:HD]
    out["dcounters"] = jax.lax.dynamic_slice(
        delta, (base, jnp.int32(0), jnp.int32(0)),
        (L, spec.rows, spec.wps_shard))
    if spec.dk_bits:
        ddk = state["doorkeeper"][HD:].reshape(spec.shards, spec.dkw_shard)
        out["ddoorkeeper"] = jax.lax.dynamic_slice(
            ddk, (base, jnp.int32(0)), (L, spec.dkw_shard))
    else:
        out["ddoorkeeper"] = state0["ddoorkeeper"]
    return out


def _mesh_runner(spec: StepSpec, mesh, adaptive: bool):
    """One compiled multi-device program: a shard_map over the ("shard",)
    mesh whose body is the epoch-chunked scan — full (unmasked) merge
    epochs inside the scan, the (< merge_every) tail as a plain step after
    it, exactly like the single-device jit backend.  NO per-access
    collective in either exchange mode (``StepSpec.mesh_exchange``):

    * ``"chunk"``: :func:`_gather_delta_state` on entry, then every device
      replays the identical single-device program (``mesh_devices=0``
      spec) over its replicated [global || delta] replica — step scan +
      ``merge_halve`` fold, zero collectives — and
      :func:`_split_delta_state` restores the mesh layout on exit.
      Bit-identical to the single-device sharded run by construction.
    * ``"stale"``: the mesh layout is kept throughout — per-access delta
      writes stay device-local, estimates read the (<= one epoch stale)
      replicated global halves only, and the per-epoch
      ``merge_halve_mesh`` all-gather fold is the one collective.

    Every device computes identical replicated verdicts over the
    replicated cache tables; only its local delta blocks differ."""
    key = (spec, mesh, adaptive)
    if key not in _mesh_cache:
        if len(_mesh_cache) >= _MESH_CACHE_LIMIT:
            _mesh_cache.clear()
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        sspec = _mesh_state_specs(spec)
        chunked = spec.mesh_exchange == "chunk"
        # chunk mode replays the single-device program — same geometry,
        # single-device state layout — inside the shard_map body
        lspec = replace(spec, mesh_devices=0) if chunked else spec

        def enter(state):
            return _gather_delta_state(spec, state) if chunked else state

        def leave(st, state0):
            return _split_delta_state(spec, st, state0) if chunked else st

        def fold(params, st):
            return (merge_halve(lspec, params, st) if chunked
                    else merge_halve_mesh(spec, params, st))

        if not adaptive:
            def fn(params, state, los, his, tlo, thi):
                st0 = enter(state)

                def body(s, x):
                    clo, chi = x
                    s, hits = step_ref(lspec, params, s, clo, chi)
                    return fold(params, s), hits
                st, hits = jax.lax.scan(body, st0, (los, his))
                st, tail = step_ref(lspec, params, st, tlo, thi)
                return leave(st, state), jnp.concatenate(
                    [hits.reshape(-1), tail])

            _mesh_cache[key] = jax.jit(shard_map(
                fn, mesh=mesh, in_specs=(P(), sspec, P(), P(), P(), P()),
                out_specs=(sspec, P()), check_rep=False))
        else:
            def fn(params, state, los, his, tlo, thi, climb, carry0):
                st0 = enter(state)

                def body(carry, x):
                    clo, chi = x
                    s = carry[0]
                    s, hits = step_ref(lspec, params, s, clo, chi)
                    ehits = s["regs"][R_EHITS]
                    quota = s["regs"][R_WQUOTA]
                    # merge rides the climb epochs: fold first, then climb
                    # + rebalance — same order as the single-device runner
                    sm = fold(params, s)
                    carry = _climb_step(params, lspec, (sm,) + carry[1:],
                                        ehits, climb)
                    return carry, (hits, ehits, quota)

                init = (st0, carry0[0], carry0[1], carry0[2],
                        carry0[3], carry0[4], carry0[5])
                (st, *regs), (hits, ehits, quotas) = jax.lax.scan(
                    body, init, (los, his))
                st, tail = step_ref(lspec, params, st, tlo, thi)
                return (leave(st, state),
                        jnp.concatenate([hits.reshape(-1), tail]),
                        ehits, quotas, jnp.stack(regs))

            _mesh_cache[key] = jax.jit(shard_map(
                fn, mesh=mesh,
                in_specs=(P(), sspec, P(), P(), P(), P(), P(), P()),
                out_specs=(sspec, P(), P(), P(), P()), check_rep=False))
    return _mesh_cache[key]


def _pad_epochs(lo, hi, n: int, E: int):
    """Pad the trace to whole epochs; returns (los, his, nvalid) chunked.
    Lane-batched traces (leading (B,) axis) pad/chunk along the access
    axis; nvalid stays per-epoch scalar — lanes share the chunking."""
    pad = (-n) % E
    if pad:
        z = jnp.zeros(lo.shape[:-1] + (pad,), lo.dtype)
        lo = jnp.concatenate([lo, z], axis=-1)
        hi = jnp.concatenate([hi, z], axis=-1)
    ne = lo.shape[-1] // E
    nvalid = jnp.minimum(
        jnp.maximum(n - jnp.arange(ne, dtype=jnp.int32) * E, 0), E)
    return _chunk_lanes(lo, ne, E), _chunk_lanes(hi, ne, E), nvalid


def _sharded_runner(spec: StepSpec, backend: str, interpret: bool):
    """One compiled program: scan over merge epochs, each epoch = fused step
    over its chunk + merge_halve fold.  No host sync anywhere inside the
    trace — the sharded twin of ``_adaptive_runner`` without the climb."""
    key = (spec, backend, interpret)
    if key not in _sharded_cache:
        if len(_sharded_cache) >= _STEP_CACHE_LIMIT:
            _sharded_cache.clear()
        @jax.jit
        def run(params, state, los, his, nvalid):
            def body(st, x):
                clo, chi, nv = x
                if backend == "pallas":
                    st, hits = step_pallas(spec, params, st, clo, chi, nv,
                                           interpret=interpret)
                else:
                    st, hits = step_ref(spec, params, st, clo, chi)
                # a partial (padded tail) epoch does not merge — the jit
                # backend runs the tail outside the scan without a merge,
                # and the two must agree on the final state.  The gate
                # touches ONLY the sketch arrays the fold modifies: a
                # whole-state tree_map would copy the cache tables every
                # epoch, which at large capacities dwarfs the per-access
                # work and sinks the flatness arm (measured 4x at C=65536)
                merged = merge_halve(spec, params, st)
                full = nv >= jnp.int32(clo.shape[-1])
                gated = ("counters", "doorkeeper", "regs") + \
                    (("csum",) if spec.integrity else ())
                st = {**st, **{k: jnp.where(full, merged[k], st[k])
                               for k in gated}}
                return st, hits
            return jax.lax.scan(body, state, (los, his, nvalid))
        _sharded_cache[key] = run
    return _sharded_cache[key]


def _run_sharded(spec: StepSpec, params, state, lo, hi, merge_every: int,
                 backend: str, interpret: bool, mesh=None):
    """Merge-epoch-chunked sharded simulation; returns (state, hits).

    The jit backend scans whole epochs (each followed by the merge_halve
    fold) and runs the (< merge_every) tail as one extra dispatch without a
    final merge; the pallas backend folds the tail into a masked final
    epoch whose merge is skipped.  Both emit identical per-access hit flags
    and final state — and both match the host twin, which merges after
    every ``merge_every``-th access and never on a partial tail.

    ``mesh`` selects the multi-device shard_map runner — exact
    ("chunk") or speculative stale-global ("stale") exchange per
    ``spec.mesh_exchange``, both collective-free on the per-access path;
    it chunks the trace exactly like the jit backend (whole epochs in the
    scan, tail outside without a merge), so chunk mode's hits and final
    state are bit-identical to both single-device backends.

    ``spec.streams > 1``: lo/hi are (B, T) lane traces; epochs chunk along
    the access axis and hits come back (B, T) — lanes never interact, the
    per-lane fold is the vmapped single-stream ``merge_halve``.
    """
    n = lo.shape[-1]
    E = int(merge_every)
    if mesh is not None:
        ne = n // E
        nfull = ne * E
        state, hits = _mesh_runner(spec, mesh, False)(
            params, state, lo[:nfull].reshape(ne, E),
            hi[:nfull].reshape(ne, E), lo[nfull:], hi[nfull:])
        return state, hits
    if backend == "pallas":
        los, his, nvalid = _pad_epochs(lo, hi, n, E)
        state, hits = _sharded_runner(spec, backend, interpret)(
            params, state, los, his, nvalid)
        if spec.streams > 1:                 # (ne, B, E) -> (B, T)
            return state, hits.swapaxes(0, 1).reshape(spec.streams, -1)[:, :n]
        return state, hits.reshape(-1)[:n]
    ne = n // E
    nfull = ne * E
    B = spec.streams
    hits_parts = []
    if ne:
        state, hits = _sharded_runner(spec, backend, interpret)(
            params, state, _chunk_lanes(lo[..., :nfull], ne, E),
            _chunk_lanes(hi[..., :nfull], ne, E),
            jnp.full((ne,), E, jnp.int32))
        hits_parts.append(hits.swapaxes(0, 1).reshape(B, nfull)
                          if B > 1 else hits.reshape(-1))
    if n - nfull:
        state, tail = _jit_step(spec, params, state, lo[..., nfull:],
                                hi[..., nfull:])
        hits_parts.append(tail)
    if not hits_parts:                       # zero-length trace
        hits_parts.append(jnp.zeros((B, 0) if B > 1 else (0,), jnp.int32))
    hits = jnp.concatenate(hits_parts, axis=-1) if len(hits_parts) > 1 \
        else hits_parts[0]
    return state, hits


# ---------------------------------------------------------------------------
# adaptive window sizing: epoch-chunked scan + in-program hill-climb
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ClimbSpec:
    """Hill-climber hyperparameters (resolved against a configuration).

    Every ``epoch_len`` accesses the compiled program compares the epoch's
    hit count with the previous epoch's: within ``tol`` counts as
    improvement (noise hysteresis) and keeps climbing in the same
    direction; a regression reverses direction and halves the step (floor
    1), so the quota converges toward the local optimum with decaying
    oscillation.  A swing larger than ``restart`` (either sign — the
    workload changed) re-expands the step to ``delta0`` so the climber can
    cross the quota range quickly after a phase shift.  The quota is
    clamped to [wmin, wmax].

    Field reference (zero fields auto-size — core/adaptive.py; rendered in
    docs/API.md):

    ``epoch_len`` (default 4096)
        Accesses per climb epoch.  Climb + rebalance (and, with
        ``shards>1``, the merge_halve fold) run at each epoch boundary
        inside the compiled program; partial tail epochs never climb.
    ``delta0`` (default 0 = auto ``wmax/16``)
        Initial quota step, and the step the phase-shift restart re-arms.
    ``wmin`` (default 1)
        Smallest quota the climb may set.
    ``wmax`` (default 0 = auto)
        Largest quota; auto = the adaptive table headroom
        (``window_max_frac`` of capacity — the static table sizing).
    ``tol`` (default 0 = auto ``epoch_len/256``)
        Noise hysteresis band (~0.4% hit-rate): epoch-hit deltas within
        ±tol are a plateau (hold position, decay the step).
    ``restart`` (default 0 = auto ``epoch_len/16``)
        Disruption threshold (~6% hit-rate swing vs the EWMA baseline);
        while tripped, improving moves double the step (capped at a
        quarter of the quota range).
    ``warm_epochs`` (default 3)
        Epochs that only seed the baselines — the fill-up transient
        swamps every signal.
    """
    epoch_len: int = 4096
    delta0: int = 0
    wmin: int = 1
    wmax: int = 0
    tol: int = 0
    restart: int = 0
    warm_epochs: int = 3

    def resolve(self, cfg: "DeviceWTinyLFU") -> np.ndarray:
        return np.asarray(
            adaptive.resolve_climb(self.epoch_len, self.delta0, self.wmin,
                                   self.wmax, self.tol, self.restart,
                                   self.warm_epochs, cfg.window_cap_max),
            np.int32)


def _climb_step(params, spec, carry, ehits, climb):
    """One hill-climb update + rebalance (pure jnp, runs between epochs).

    Three-way comparison against the previous epoch: a real improvement
    (> tol) keeps direction and step; a real regression (< -tol) reverses
    and halves the step; the noise plateau in between keeps direction but
    decays the step 3/4 so a flat hit-ratio landscape freezes the quota
    instead of letting it drift.  A swing beyond ``restart`` (the workload
    changed) re-expands the step to delta0.  The first epoch only seeds the
    baseline — the cache is still warming, and climbing on the fill-up
    transient launches the quota far from any optimum.

    ``spec.streams > 1``: every climber register is per-lane (the carry
    scalars become (B,) rows of the (6, B) carry matrix) and the update
    vmaps over lanes, so B tenants hill-climb independently inside one
    program.  ``climb`` may be shared (6,) or per-lane (B, 6) — the latter
    is how ``simulate_sweep(mode="vmap", adaptive=True)`` runs climber
    hyperparameter grids as lanes.
    """
    if spec.streams > 1:
        lspec = replace(spec, streams=1)
        cvec = jnp.asarray(climb)

        def one(p, cv, st, prev, dirn, delta, ewma, trend, k, eh):
            return _climb_step(p, lspec,
                               (st, prev, dirn, delta, ewma, trend, k),
                               eh, cv)
        return jax.vmap(one, in_axes=(0 if params.ndim == 2 else None,
                                      0 if cvec.ndim == 2 else None)
                        + (0,) * 8)(params, cvec, *carry, ehits)
    st, prev, dirn, delta, ewma, trend, k = carry
    quota = st["regs"][R_WQUOTA]
    diff = ehits - prev
    # trend correction: judge a move against the background drift (EWMA of
    # recent diffs), not against zero — a cache still warming up improves
    # every epoch no matter what the quota does, and crediting that drift
    # to the last move rides the quota far from any optimum
    adiff = diff - trend
    improved = adiff > climb[3]
    regressed = adiff < -climb[3]
    trend_n = jnp.where(prev < 0, 0, trend + (diff - trend) // 4)
    dirn_n = jnp.where(regressed, -dirn, dirn)
    delta_n = jnp.where(regressed, jnp.maximum(delta // 2, 1),
                        jnp.where(improved, delta,
                                  jnp.maximum((delta * 3) // 4, 1)))
    # disruption restart: while the epoch hit count sits far from its
    # recent average (phase shift, or mid-recovery after one) the step must
    # stay wide — consecutive-epoch diffs alone go quiet as soon as the
    # collapse settles, long before the quota has crossed back to useful
    # territory, and a decayed step would crawl there at +-1 per epoch.
    # While the disruption lasts, improving moves double the step (capped
    # at a quarter of the quota range) so the recovery crosses the range in
    # a handful of epochs; non-improving ones reset it to delta0
    shift = jnp.abs(ehits - ewma) > climb[4]
    span4 = jnp.maximum(climb[0], (climb[2] - climb[1]) // 4)
    delta_n = jnp.where(
        shift,
        jnp.where(improved,
                  jnp.minimum(jnp.maximum(delta_n, climb[0]) * 2, span4),
                  climb[0]),
        delta_n)
    # warm epochs: the fill-up transient swamps every signal (its epoch
    # diffs trip even the disruption detector) — hold the quota and step,
    # and let the baselines FOLLOW the transient (ewma = ehits, trend =
    # diff) so the handoff into live climbing starts from honest levels
    # instead of a lagging average that reads as a disruption
    warm = k < climb[5]
    ewma = jnp.where(warm | (prev < 0), ehits,
                     ewma + (ehits - ewma) // 4)
    dirn = jnp.where(warm, dirn, dirn_n)
    delta = jnp.where(warm, delta, delta_n)
    trend = jnp.where(warm, jnp.where(prev < 0, 0, diff), trend_n)
    # a plateau decays the step but does NOT move: drifting at the decaying
    # step across a shallow landscape accumulates several delta0 of
    # displacement before freezing.  Disruptions always move — during a
    # recovery the trend estimate absorbs the climb's own gains, and
    # holding still there would stall the recovery mid-range.
    move = improved | regressed | shift
    step = jnp.where(warm | ~move, 0, dirn * delta)
    nq = jnp.clip(quota + step, climb[1], climb[2])
    # clamp escape: pinned at a range end with a flat (possibly uniformly
    # terrible) hit landscape there is no regression signal to reverse on —
    # point the next step back into the range
    dirn = jnp.where(nq <= climb[1], 1,
                     jnp.where(nq >= climb[2], -1, dirn))
    st = rebalance(spec, params, st, nq)
    return st, ehits, dirn, delta, ewma, trend, k + 1


_adaptive_cache: dict = {}


def _adaptive_runner(spec: StepSpec, backend: str, interpret: bool):
    """One compiled program: scan over epochs, each epoch = fused step over
    its chunk + climb + rebalance.  No host sync anywhere inside the trace."""
    key = (spec, backend, interpret)
    if key not in _adaptive_cache:
        if len(_adaptive_cache) >= _STEP_CACHE_LIMIT:
            _adaptive_cache.clear()
        @jax.jit
        def run(params, state, los, his, nvalid, climb, carry0):
            def body(carry, x):
                clo, chi, nv = x
                st = carry[0]
                if backend == "pallas":
                    st, hits = step_pallas(spec, params, st, clo, chi, nv,
                                           interpret=interpret)
                else:
                    st, hits = step_ref(spec, params, st, clo, chi)
                # [..., R] keeps the epoch registers per-lane under streams
                # (regs is (B, NREGS) there, (NREGS,) unbatched)
                ehits = st["regs"][..., R_EHITS]
                quota = st["regs"][..., R_WQUOTA]
                # sharded + adaptive: the merge_halve fold rides the climb
                # epochs (merge first, then climb + rebalance — the host
                # twin AdaptiveWTinyLFU merges at the same point); the
                # `full` gate below skips both on a padded partial tail
                stm = merge_halve(spec, params, st) if spec.shards > 1 else st
                climbed = _climb_step(params, spec, (stm,) + carry[1:],
                                      ehits, climb)
                # a partial (padded tail) epoch must not climb: its truncated
                # hit count reads as a phase shift, and the jit backend —
                # which runs the tail outside the scan — would disagree on
                # final quota and state
                full = nv >= jnp.int32(clo.shape[-1])
                carry = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(full, a, b), climbed,
                    (st,) + carry[1:])
                return carry, (hits, ehits, quota)

            # the climber's scalar registers enter/leave as a (6,) int32
            # vector [prev, dirn, delta, ewma, trend, k] so a checkpointed
            # run can hand them across segment boundaries bit-exactly
            init = (state, carry0[0], carry0[1], carry0[2],
                    carry0[3], carry0[4], carry0[5])
            (st, *regs), (hits, ehits, quotas) = jax.lax.scan(
                body, init, (los, his, nvalid))
            return st, hits, ehits, quotas, jnp.stack(regs)
        _adaptive_cache[key] = run
    return _adaptive_cache[key]


def _climb_carry0(cvec) -> jnp.ndarray:
    """Fresh-run climber registers: [prev=-1, dirn=1, delta=delta0,
    ewma=-1, trend=0, k=0] — the pre-ISSUE-7 scan init, as a vector."""
    return jnp.stack([jnp.int32(-1), jnp.int32(1),
                      jnp.asarray(cvec[0], jnp.int32), jnp.int32(-1),
                      jnp.int32(0), jnp.int32(0)])


def _run_adaptive(cfg: "DeviceWTinyLFU", spec: StepSpec, params, state,
                  lo, hi, climb: ClimbSpec, backend: str, interpret: bool,
                  mesh=None, carry=None):
    """Epoch-chunked adaptive simulation; returns (state, hits, trajectory,
    carry) where ``carry`` is the (6,) int32 climber-register vector.

    The jit backend scans whole epochs and runs the (< epoch_len) tail as
    one extra dispatch without a final climb; the pallas backend folds the
    tail into a masked final epoch whose climb is skipped.  Both emit
    identical per-access hit flags, final quota, and trajectory (full
    epochs only).  ``mesh`` selects the multi-device shard_map runner
    (whole epochs in the scan, tail outside without a climb, like jit) —
    the merge fold rides the climb epochs.

    ``carry=None`` starts a fresh climb; a checkpointed run passes the
    previous segment's carry so that splitting the trace at epoch
    boundaries reproduces the single-program run bit-for-bit.

    ``spec.streams > 1``: lo/hi are (B, T) lane traces, the carry is the
    (6, B) per-lane climber-register matrix, and the trajectory rows are
    per-lane ``(ne, B)`` — B independent hill-climbs in one program.
    """
    n = lo.shape[-1]
    E = int(climb.epoch_len)
    cvec = jnp.asarray(climb.resolve(cfg))
    if carry is None:
        carry = _climb_carry0(cvec)
        if spec.streams > 1:
            carry = jnp.repeat(carry[:, None], spec.streams, axis=1)
    if mesh is not None:
        ne = n // E
        nfull = ne * E
        state, hits, ehits, quotas, carry = _mesh_runner(spec, mesh, True)(
            params, state, lo[:nfull].reshape(ne, E),
            hi[:nfull].reshape(ne, E), lo[nfull:], hi[nfull:], cvec, carry)
        traj = (ehits, quotas) if ne else (None, None)
        return state, hits, traj, carry
    B = spec.streams
    if backend == "pallas":
        los, his, nvalid = _pad_epochs(lo, hi, n, E)
        state, hits, ehits, quotas, carry = _adaptive_runner(
            spec, backend, interpret)(params, state, los, his, nvalid, cvec,
                                      carry)
        nfull = n // E                   # drop the partial tail's row so the
        traj = (ehits[:nfull], quotas[:nfull]) if nfull else (None, None)
        hits = (hits.swapaxes(0, 1).reshape(B, -1)[:, :n] if B > 1
                else hits.reshape(-1)[:n])
        return state, hits, traj, carry  # traj matches jit
    ne = n // E
    nfull = ne * E
    hits_parts = []
    ehits = quotas = None
    if ne:
        state, hits, ehits, quotas, carry = _adaptive_runner(
            spec, backend, interpret)(params, state,
                                      _chunk_lanes(lo[..., :nfull], ne, E),
                                      _chunk_lanes(hi[..., :nfull], ne, E),
                                      jnp.full((ne,), E, jnp.int32), cvec,
                                      carry)
        hits_parts.append(hits.swapaxes(0, 1).reshape(B, nfull)
                          if B > 1 else hits.reshape(-1))
    if n - nfull:
        state, tail = _jit_step(spec, params, state, lo[..., nfull:],
                                hi[..., nfull:])
        hits_parts.append(tail)
    if not hits_parts:                       # zero-length trace
        hits_parts.append(jnp.zeros((B, 0) if B > 1 else (0,), jnp.int32))
    hits = jnp.concatenate(hits_parts, axis=-1) if len(hits_parts) > 1 \
        else hits_parts[0]
    return state, hits, (ehits, quotas), carry


def _policy_label(cfg: "DeviceWTinyLFU", adaptive: bool) -> str:
    """SimResult.policy label.  The W-TinyLFU spelling predates the policy
    panel and is pinned by downstream plot/golden tooling, so it is kept
    verbatim; competitors label as ``"<policy>(device)"``."""
    base = ("w-tinylfu(device)" if cfg.policy == "wtinylfu"
            else f"{cfg.policy}(device)")
    return base + ("+climb" if adaptive else "")


def _row_extra(cfg: "DeviceWTinyLFU", climb: "ClimbSpec | None",
               adaptive: bool) -> dict:
    """Config-knob rows shared by every ``SimResult.extra`` the engine
    emits — ``simulate_trace``, ``run()``, and each ``simulate_sweep`` row
    build on this one dict so the row schema cannot drift (sweep rows used
    to silently omit ``streams``/``integrity``/``merge_every``).  Knobs at
    their defaults stay absent so pre-existing row shapes are unchanged."""
    extra = {}
    if cfg.policy != "wtinylfu":
        extra["policy"] = cfg.policy
    if cfg.mesh is not None:
        extra["mesh_devices"] = cfg.mesh_devices
        extra["mesh_exchange"] = cfg.mesh_exchange
    if cfg.shards > 1:
        extra["shards"] = cfg.shards
        # adaptive+sharded: the fold rides the climb epochs, not merge_epoch
        extra["merge_every"] = (climb.epoch_len if adaptive and climb
                                else cfg.merge_epoch)
    if cfg.integrity:
        extra["integrity"] = True
    if cfg.streams > 1:
        extra["streams"] = cfg.streams
    return extra


def simulate_trace(trace: np.ndarray, capacity: int, *,
                   window_frac: float = 0.01, sample_factor: int = 8,
                   warmup: int = 0, backend: str = "jit", chunk: int = 512,
                   interpret: bool | None = None, trace_name: str = "?",
                   return_state: bool = False, adaptive: bool = False,
                   climb: ClimbSpec | None = None, **cfg_kw) -> SimResult:
    """Device twin of ``simulate.run_trace(WTinyLFU(capacity), trace)``.

    ``backend="jit"`` runs the scan twin; ``backend="pallas"`` launches the
    fused kernel per chunk (interpret mode anywhere off-TPU).  ``warmup``
    accesses update state but are not counted, exactly like ``run_trace``.
    ``assoc=W`` (via cfg_kw) selects the W-way set-associative tables —
    O(W) per access instead of O(capacity), hit ratios within ±0.01 of the
    exact path; ``counter_bits=8`` enables sample factors above 16.

    ``adaptive=True`` makes the window/main split runtime device state: an
    epoch-based hill-climber (``climb``, default :class:`ClimbSpec`) adjusts
    the window quota between epochs inside the same compiled program, and
    the per-epoch (quota, hits) trajectory is returned in
    ``extra["trajectory"]``.  ``window_frac`` seeds the initial quota.

    ``shards=S`` (via cfg_kw) runs the sharded frequency sketch: the trace
    is chunked into merge epochs (``merge_every`` accesses, 0 = auto) and a
    fused ``merge_halve`` folds the shard deltas into the global estimate
    at every boundary — combined with ``adaptive=True`` the fold rides the
    climb epochs instead.
    """
    cfg = DeviceWTinyLFU(capacity, window_frac=window_frac,
                         sample_factor=sample_factor, adaptive=adaptive,
                         **cfg_kw)
    trace = np.asarray(trace)
    _check_trace_streams(cfg, trace)
    spec = cfg.spec()
    params = cfg.params(warmup=warmup)
    state = init_step_state(spec, cfg.window_cap, cfg.main_cap)
    lo, hi = _trace_lanes(trace)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    climb = climb or ClimbSpec()

    if cfg.mesh is not None and backend != "jit":
        raise ValueError("mesh execution runs the jit scan under shard_map: "
                         "use backend='jit'")
    t0 = time.perf_counter()
    trajectory = None
    if adaptive:
        if backend not in ("jit", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        state, hits, (ehits, quotas), _ = _run_adaptive(
            cfg, spec, params, state, lo, hi, climb, backend, interpret,
            mesh=cfg.mesh)
        if ehits is not None:
            trajectory = {"epoch_len": climb.epoch_len,
                          "epoch_hits": np.asarray(ehits).tolist(),
                          "quota": np.asarray(quotas).tolist()}
    elif cfg.shards > 1:
        if backend not in ("jit", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        state, hits = _run_sharded(spec, params, state, lo, hi,
                                   cfg.merge_epoch, backend, interpret,
                                   mesh=cfg.mesh)
    elif backend == "jit":
        state, hits = _run_jit(spec, params, state, lo, hi)
    elif backend == "pallas":
        state, hits = _run_pallas(spec, params, state, lo, hi, chunk,
                                  interpret)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    if cfg.mesh is not None:
        # hand back the single-device [global || delta] layout so callers
        # compare final sketch state across placements directly
        state = _from_mesh_state(spec, state)
    regs = np.asarray(state["regs"])
    wall = time.perf_counter() - t0

    # warmup applies per lane (each tenant's own R_T register counts it)
    counted = (trace.shape[-1] - warmup) * cfg.streams
    extra = {"backend": backend, "window_frac": window_frac,
             "assoc": cfg.assoc, "device": jax.default_backend(),
             **_row_extra(cfg, climb, adaptive)}
    if adaptive:
        extra["adaptive"] = True
        extra["final_quota"] = ([int(q) for q in regs[:, R_WQUOTA]]
                                if cfg.streams > 1 else int(regs[R_WQUOTA]))
        if trajectory is not None:
            extra["trajectory"] = trajectory
    if cfg.streams > 1:
        # aggregate hits in the SimResult; per-lane breakdown in extra
        # (trajectory rows are already per-lane (ne, B) lists)
        extra["lane_hits"] = [int(h) for h in regs[:, R_HITS]]
        n_hits = int(regs[:, R_HITS].sum())
    else:
        n_hits = int(regs[R_HITS])
    res = SimResult(policy=_policy_label(cfg, adaptive),
                    cache_size=capacity,
                    trace=trace_name, accesses=counted, hits=n_hits,
                    hit_ratio=n_hits / max(1, counted),
                    wall_s=wall, extra=extra)
    if return_state:
        return res, state, hits
    return res


# ---------------------------------------------------------------------------
# fault-tolerant execution: epoch-boundary checkpoint / resume (ISSUE 7)
# ---------------------------------------------------------------------------

def _ckpt_epoch(cfg: "DeviceWTinyLFU", climb: ClimbSpec) -> int:
    """The run's state-handoff granularity in accesses.

    Adaptive runs climb (and, sharded, merge) every ``climb.epoch_len``;
    sharded static runs merge every ``merge_epoch``; a plain scan has no
    boundary constraint at all — any split is a clean handoff — so its
    epoch only sets the auto checkpoint cadence."""
    if cfg.adaptive:
        return int(climb.epoch_len)
    if cfg.shards > 1:
        return int(cfg.merge_epoch)
    return max(1, min(4096, cfg.sample_size))


def _resolve_every(cfg: "DeviceWTinyLFU", climb: ClimbSpec,
                   checkpoint_every: int) -> int:
    """Validated checkpoint cadence in accesses (0 = auto ~32k, rounded to
    whole epochs).  Epoch-chunked runs (adaptive / sharded) may only hand
    state off at epoch boundaries, so their cadence must be a multiple of
    the epoch — anything else could not reproduce the uninterrupted run."""
    E = _ckpt_epoch(cfg, climb)
    if checkpoint_every == 0:
        return E * max(1, 32768 // E)
    ce = int(checkpoint_every)
    chunked = cfg.adaptive or cfg.shards > 1
    if ce < 1 or (chunked and ce % E):
        kind = ("climb.epoch_len" if cfg.adaptive else
                "the resolved merge_epoch")
        raise ValueError(
            f"checkpoint_every {checkpoint_every} must be a positive "
            f"multiple of the run's epoch ({kind} = {E}): the engine "
            "hands state off only at epoch boundaries, so any other "
            "cadence cannot resume bit-identically")
    return ce


def _config_meta(cfg: "DeviceWTinyLFU", climb: ClimbSpec, warmup: int,
                 n: int) -> dict:
    """JSON-safe fingerprint of the logical run configuration, stored in
    every checkpoint's manifest and verified by :func:`resume_trace`.

    The mesh itself is deliberately ABSENT: placement is not part of the
    logical configuration, which is exactly what makes elastic restore
    (checkpoint on 2 devices, resume on 1, or vice versa) legal."""
    meta = {f: getattr(cfg, f) for f in (
        "capacity", "window_frac", "sample_factor", "protected_frac",
        "counters_per_item", "rows", "doorkeeper", "dk_bits_per_item",
        "assoc", "counter_bits", "adaptive", "window_max_frac", "shards",
        "merge_every", "integrity")}
    meta["mesh_exchange"] = (cfg.mesh_exchange if cfg.mesh is not None
                            else "chunk")
    if cfg.streams > 1:          # absent at 1 so pre-streams manifests match
        meta["streams"] = cfg.streams
    if cfg.policy != "wtinylfu":  # absent at default so old manifests match
        meta["policy"] = cfg.policy
    if cfg.adaptive:
        meta["climb"] = [int(x) for x in climb.resolve(cfg)]
    meta["warmup"] = int(warmup)
    meta["trace_len"] = int(n)
    return meta


def _segment(cfg: "DeviceWTinyLFU", spec: StepSpec, params, state, lo, hi,
             climb: ClimbSpec, carry, backend: str, chunk: int,
             interpret: bool):
    """One contiguous trace slice through the right runner; returns
    (state, hits, (ehits, quotas), carry)."""
    if cfg.adaptive:
        return _run_adaptive(cfg, spec, params, state, lo, hi, climb,
                             backend, interpret, mesh=cfg.mesh, carry=carry)
    if cfg.shards > 1:
        state, hits = _run_sharded(spec, params, state, lo, hi,
                                   cfg.merge_epoch, backend, interpret,
                                   mesh=cfg.mesh)
    elif backend == "jit":
        state, hits = _run_jit(spec, params, state, lo, hi)
    else:
        state, hits = _run_pallas(spec, params, state, lo, hi, chunk,
                                  interpret)
    return state, hits, (None, None), carry


def _run_checkpointed(cfg: "DeviceWTinyLFU", trace, *, warmup=0,
                      backend="jit", chunk=512, interpret=None,
                      trace_name="?", climb=None, checkpoint_dir=None,
                      checkpoint_every=0, return_state=False,
                      on_checkpoint=None, fault_hook=None,
                      _start=0, _state=None, _carry=None,
                      _hits_prefix=None, _traj_prefix=None):
    """Segmented engine driver behind :meth:`DeviceWTinyLFU.run` and
    :func:`resume_trace` (the leading-underscore kwargs are the resume
    handoff).  Every segment boundary is an epoch boundary, i.e. a clean
    state handoff, so the concatenated segments reproduce the
    single-program run bit-for-bit — hit sequence, climb trajectory, and
    final sketch words."""
    from repro.checkpoint.store import AsyncCheckpointer
    climb = climb or ClimbSpec()
    spec = cfg.spec()
    params = cfg.params(warmup=warmup)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if backend not in ("jit", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")
    if cfg.mesh is not None and backend != "jit":
        raise ValueError("mesh execution runs the jit scan under shard_map: "
                         "use backend='jit'")
    segmenting = checkpoint_dir is not None or fault_hook is not None
    if segmenting and backend != "jit":
        raise ValueError("checkpointing / fault injection segment the jit "
                         "scan: use backend='jit'")
    if segmenting and cfg.streams > 1:
        raise ValueError(
            f"streams {cfg.streams} does not combine with checkpoint_dir/"
            "fault_hook: the checkpoint tree and fault surface are the "
            "single-tenant state layout — run per-tenant streams=1 runs "
            "for fault-tolerant execution")
    _check_trace_streams(cfg, trace)
    lo, hi = _trace_lanes(trace)
    every = (_resolve_every(cfg, climb, checkpoint_every) if segmenting
             else None)
    n = lo.shape[-1]
    state = (_state if _state is not None
             else init_step_state(spec, cfg.window_cap, cfg.main_cap))
    carry = _carry
    ck = (AsyncCheckpointer(checkpoint_dir) if checkpoint_dir is not None
          else None)
    meta = _config_meta(cfg, climb, warmup, n)

    t0 = time.perf_counter()
    hits_parts = ([] if _hits_prefix is None
                  else [jnp.asarray(_hits_prefix)])
    ehits_parts, quota_parts = [], []
    if _traj_prefix is not None:
        ehits_parts.append(jnp.asarray(_traj_prefix[0]))
        quota_parts.append(jnp.asarray(_traj_prefix[1]))

    i = _start
    while True:
        j = n if every is None else min(n, i + every)
        if j > i:
            state, hits, (eh, qu), carry = _segment(
                cfg, spec, params, state, lo[..., i:j], hi[..., i:j],
                climb, carry, backend, chunk, interpret)
            hits_parts.append(hits)
            if eh is not None:
                ehits_parts.append(eh)
                quota_parts.append(qu)
        i = j
        if ck is not None:
            canon = (_from_mesh_state(spec, state) if cfg.mesh is not None
                     else state)
            tree = {"state": canon,
                    "carry": (carry if carry is not None
                              else jnp.zeros((6,), jnp.int32)),
                    "hits": (jnp.concatenate(hits_parts) if hits_parts
                             else jnp.zeros((0,), jnp.int32))}
            if cfg.adaptive:
                z = jnp.zeros((0,), jnp.int32)
                tree["ehits"] = (jnp.concatenate(ehits_parts)
                                 if ehits_parts else z)
                tree["quotas"] = (jnp.concatenate(quota_parts)
                                  if quota_parts else z)
            ck.save(int(i), tree, extra_meta={**meta, "cursor": int(i)})
            if on_checkpoint is not None:
                on_checkpoint(int(i))
        if i >= n:
            break
        if fault_hook is not None:
            # faults inject at the clean boundary, on the canonical layout
            # — the checkpoint just written holds the PRE-fault state
            canon = (_from_mesh_state(spec, state) if cfg.mesh is not None
                     else state)
            mutated = fault_hook(int(i), canon)
            if mutated is not None:
                state = (_to_mesh_state(spec, mutated)
                         if cfg.mesh is not None else mutated)
    if ck is not None:
        ck.wait()

    if cfg.mesh is not None:
        state = _from_mesh_state(spec, state)
    hits = (jnp.concatenate(hits_parts) if len(hits_parts) != 1
            else hits_parts[0]) if hits_parts else jnp.zeros((0,), jnp.int32)
    regs = np.asarray(state["regs"])
    wall = time.perf_counter() - t0

    counted = (n - warmup) * cfg.streams
    extra = {"backend": backend, "window_frac": cfg.window_frac,
             "assoc": cfg.assoc, "device": jax.default_backend(),
             **_row_extra(cfg, climb, cfg.adaptive)}
    if cfg.streams > 1:
        extra["lane_hits"] = [int(h) for h in regs[:, R_HITS]]
        n_hits = int(regs[:, R_HITS].sum())
    else:
        n_hits = int(regs[R_HITS])
    if cfg.adaptive:
        extra["adaptive"] = True
        extra["final_quota"] = ([int(q) for q in regs[:, R_WQUOTA]]
                                if cfg.streams > 1 else int(regs[R_WQUOTA]))
        if ehits_parts:
            ehits = np.asarray(jnp.concatenate(ehits_parts))
            quotas = np.asarray(jnp.concatenate(quota_parts))
            extra["trajectory"] = {"epoch_len": climb.epoch_len,
                                   "epoch_hits": ehits.tolist(),
                                   "quota": quotas.tolist()}
    if checkpoint_dir is not None:
        extra["checkpoint_every"] = every
    if _start:
        extra["resumed_at"] = int(_start)
    res = SimResult(policy=_policy_label(cfg, cfg.adaptive),
                    cache_size=cfg.capacity, trace=trace_name,
                    accesses=counted, hits=n_hits,
                    hit_ratio=n_hits / max(1, counted),
                    wall_s=wall, extra=extra)
    if return_state:
        return res, state, hits
    return res


def resume_trace(trace, cfg: DeviceWTinyLFU, *, checkpoint_dir: str,
                 warmup: int = 0, backend: str = "jit", chunk: int = 512,
                 interpret: bool | None = None, trace_name: str = "?",
                 climb: ClimbSpec | None = None, checkpoint_every: int = 0,
                 return_state: bool = False, on_checkpoint=None,
                 fault_hook=None):
    """Restore the latest complete checkpoint in ``checkpoint_dir`` and
    finish the run; bit-identical to the uninterrupted
    ``cfg.run(trace, checkpoint_dir=...)`` (hit sequence, trajectory, final
    sketch words).

    Checkpoints store the CANONICAL single-device state layout, so restore
    is elastic: a snapshot written by a 2-device mesh run resumes on a
    single device (or any mesh whose size divides ``cfg.shards``) — the
    delta blocks re-shard through ``checkpoint.store.restore_checkpoint``
    + ``distributed.mesh.mesh_state_shardings``.  With no checkpoint yet
    (killed before the first snapshot), the resume IS a fresh run.  A
    checkpoint written under a different logical configuration (any
    ``DeviceWTinyLFU`` field, climb vector, warmup, or trace length) is
    rejected with ``ValueError`` rather than silently continued.
    """
    from repro.checkpoint.store import (latest_step, load_meta,
                                        restore_checkpoint)
    climb = climb or ClimbSpec()
    common = dict(warmup=warmup, backend=backend, chunk=chunk,
                  interpret=interpret, trace_name=trace_name, climb=climb,
                  checkpoint_dir=checkpoint_dir,
                  checkpoint_every=checkpoint_every,
                  return_state=return_state, on_checkpoint=on_checkpoint,
                  fault_hook=fault_hook)
    step = latest_step(checkpoint_dir)
    if step is None:
        out = _run_checkpointed(cfg, trace, **common)
        (out[0] if return_state else out).extra["resumed_at"] = 0
        return out
    meta = dict(load_meta(checkpoint_dir, step))
    cursor = int(meta.pop("cursor", step))
    expect = _config_meta(cfg, climb, warmup, len(trace))
    if meta != expect:
        diffs = sorted(k for k in set(meta) | set(expect)
                       if meta.get(k) != expect.get(k))
        raise ValueError(
            f"checkpoint {checkpoint_dir!r} step {step} was saved under a "
            f"different configuration (differing fields: {diffs}) — resume "
            "with the original DeviceWTinyLFU / climb / warmup / trace")
    spec = cfg.spec()
    cspec = replace(spec, mesh_devices=0) if cfg.mesh is not None else spec
    template = {"state": init_step_state(cspec, cfg.window_cap,
                                         cfg.main_cap),
                "carry": jnp.zeros((6,), jnp.int32),
                "hits": jnp.zeros((cursor,), jnp.int32)}
    if cfg.adaptive:
        ne = cursor // int(climb.epoch_len)
        template["ehits"] = jnp.zeros((ne,), jnp.int32)
        template["quotas"] = jnp.zeros((ne,), jnp.int32)
    tree = restore_checkpoint(checkpoint_dir, step, template)
    state = tree["state"]
    if cfg.mesh is not None:
        from repro.distributed.mesh import mesh_state_shardings
        state = _to_mesh_state(spec, state)
        sh = mesh_state_shardings(cfg.mesh, state.keys())
        state = {k: jax.device_put(v, sh[k]) for k, v in state.items()}
    return _run_checkpointed(
        cfg, trace, _start=cursor, _state=state,
        _carry=(tree["carry"] if cfg.adaptive else None),
        _hits_prefix=tree["hits"],
        _traj_prefix=((tree["ehits"], tree["quotas"]) if cfg.adaptive
                      else None),
        **common)


# ---------------------------------------------------------------------------
# vmapped multi-configuration sweeps: one compiled program per grid
# ---------------------------------------------------------------------------

def simulate_sweep(trace: np.ndarray, capacities, *, window_fracs=(0.01,),
                   sample_factor: int = 8, warmup: int = 0,
                   trace_name: str = "?", verbose: bool = False,
                   mode: str = "auto", adaptive: bool = False,
                   climb: ClimbSpec | None = None,
                   policies=("wtinylfu",), **cfg_kw) -> list[SimResult]:
    """Cartesian (capacity × window_frac × policy) sweep.

    All configurations share the static geometry of the *largest* one (table
    slots are padded up; smaller capacities mark the excess slots as padding),
    so ONE compiled step program serves the whole grid; the sketch of a
    smaller configuration is sized for the largest sample — its estimates are
    slightly *more* accurate than a per-size host sketch, which is within the
    golden tolerance.

    ``mode``: ``"vmap"`` runs the whole grid as a single vmapped scan (the
    shape intended for accelerators — grid points ride the vector lanes; all
    configs share the largest config's sketch geometry); ``"sequential"``
    runs one compiled single-config scan per grid point with each config's
    own host-matched sketch sizing (faster on CPU, where XLA's batching
    rules serialize the lanes anyway, and directly comparable to per-size
    host results); ``"auto"`` picks vmap on TPU and sequential elsewhere.

    ``trace`` may be ``(N,)`` (shared by all configs) or ``(G, N)`` (one
    trace per grid point, e.g. seed sweeps).

    ``adaptive=True`` runs the in-program hill-climber per grid point
    (``window_fracs`` seed the initial quotas).  ``mode="sequential"``
    runs one epoch-chunked compiled program per config;
    ``mode="vmap"`` runs the whole grid as tenant LANES of ONE
    ``streams=len(grid)`` compiled program (``StepSpec.streams``) —
    per-lane quota and climber registers keep every grid point's history
    independent, bit-identical to the sequential runs.  The lanes share
    one static geometry, so vmapped adaptive grids may vary
    ``window_fracs`` and climb hyperparameters but not capacity/sizing.
    ``climb`` may be one ``ClimbSpec`` for the whole grid or a sequence of
    ``len(grid)`` specs (uniform ``epoch_len`` — the lanes climb in
    lockstep), which is how climber hyperparameter grids sweep as lanes.

    ``policies=`` adds the device policy-panel axis (kernels
    ``StepSpec.policy``: ``"wtinylfu" | "s3fifo" | "arc" | "lfu"``) to the
    grid.  Policy dispatch is *static* — each policy traces a different
    step program — so multi-policy grids run ``mode="sequential"``; a grid
    restricted to one policy may still vmap.  Competitor policies require
    ``assoc=`` (see :class:`DeviceWTinyLFU`).
    """
    policies = tuple(policies)
    grid = [DeviceWTinyLFU(C, window_frac=wf, sample_factor=sample_factor,
                           adaptive=adaptive, policy=pol, **cfg_kw)
            for C in capacities for wf in window_fracs for pol in policies]
    gridlab = [(C, wf) for C in capacities for wf in window_fracs
               for pol in policies]
    if len(set(policies)) > 1 and mode == "vmap":
        raise ValueError(
            "policy grids run one compiled step program per policy (the "
            "dispatch is static, traced into the program): use "
            "mode='sequential'")
    if len(set(policies)) > 1 and mode == "auto":
        mode = "sequential"
    sharded = any(c.shards > 1 for c in grid)
    meshed = any(c.mesh is not None for c in grid)
    if meshed:
        for c in grid:
            c.mesh_devices    # eager: reject bad mesh/shards combos up front
    if mode == "auto":
        # sharded/meshed grids can't share geometry (merge epochs need the
        # epoch-chunked runner; mesh runs need the shard_map runner), and
        # adaptive grids usually sweep capacities (distinct geometries), so
        # auto resolves to the always-valid mode even on accelerators;
        # adaptive same-geometry grids opt into lanes with mode="vmap"
        mode = "sequential" if (adaptive or sharded or meshed) else (
            "vmap" if jax.default_backend() == "tpu" else "sequential")
    if adaptive:
        climb = climb or ClimbSpec()
        climbs = (list(climb) if isinstance(climb, (list, tuple))
                  else [climb] * len(grid))
        if len(climbs) != len(grid):
            raise ValueError(f"climb sequence length {len(climbs)} != "
                             f"{len(grid)} grid configurations")
    if meshed and mode == "vmap":
        raise ValueError("mesh sweeps run per-config shard_map programs "
                         "(the vmapped scan would silently run the "
                         "single-device path): use mode='sequential'")
    if sharded and mode == "vmap":
        raise ValueError("sharded sweeps run per-config epoch-chunked "
                         "programs: use mode='sequential'")

    trace = np.asarray(trace)
    shared_trace = trace.ndim == 1
    if not shared_trace and trace.shape[0] != len(grid):
        raise ValueError(f"trace grid dim {trace.shape[0]} != "
                         f"{len(grid)} configurations")
    n_per = trace.shape[-1]

    t0 = time.perf_counter()
    if mode == "vmap" and adaptive:
        # the long-standing vmapped-adaptive-sweeps item: the grid's
        # climbers become tenant LANES of one streams=G compiled program
        # (StepSpec.streams) — per-lane quota and climber registers keep
        # every grid point's history independent, so the results are
        # bit-identical to the sequential per-config runs
        # (tests/test_streams.py pins it).  Lanes advance one shared
        # program, so the grid must agree on the static geometry —
        # capacity/sizing sweeps change it and stay sequential.
        specs = {c.spec() for c in grid}
        if len(specs) != 1:
            raise ValueError(
                "adaptive vmap sweeps run the grid as lanes of ONE "
                "compiled program, which needs one shared static geometry; "
                f"this grid has {len(specs)} distinct geometries "
                "(capacities or sizing differ) — sweep window_fracs or "
                "climb hyperparameters, or use mode='sequential'")
        G = len(grid)
        lspec = specs.pop()
        spec = replace(lspec, streams=G)
        epochs = {int(cl.epoch_len) for cl in climbs}
        if len(epochs) != 1:
            raise ValueError(
                "adaptive vmap sweeps climb in lockstep, so climb.epoch_len "
                f"must be uniform across the grid (got {sorted(epochs)}) — "
                "use mode='sequential' for mixed epoch lengths")
        E = epochs.pop()
        pstack = jnp.stack([c.params(warmup=warmup) for c in grid])
        sstack = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[init_step_state(lspec, c.window_cap, c.main_cap)
              for c in grid])
        cstack = jnp.stack([jnp.asarray(cl.resolve(c))
                            for cl, c in zip(climbs, grid)])
        carry = jnp.stack([_climb_carry0(cv) for cv in cstack], axis=1)
        if shared_trace:
            l1, h1 = _trace_lanes(trace)
            lo = jnp.broadcast_to(l1, (G, n_per))
            hi = jnp.broadcast_to(h1, (G, n_per))
        else:
            lanes = [_trace_lanes(t) for t in trace]
            lo = jnp.stack([l for l, _ in lanes])
            hi = jnp.stack([h for _, h in lanes])
        ne = n_per // E
        nfull = ne * E
        st = sstack
        if ne:
            st, _, _, _, carry = _adaptive_runner(spec, "jit", False)(
                pstack, st, _chunk_lanes(lo[:, :nfull], ne, E),
                _chunk_lanes(hi[:, :nfull], ne, E),
                jnp.full((ne,), E, jnp.int32), cstack, carry)
        if n_per - nfull:       # the (< epoch) tail steps but never climbs
            st, _ = _jit_step(spec, pstack, st, lo[:, nfull:], hi[:, nfull:])
        regs = np.asarray(st["regs"])
    elif mode == "vmap":
        # one program for the whole grid: shared (largest) static geometry,
        # per-config capacities traced, excess slots marked as padding
        big = max(grid, key=lambda c: c.capacity)
        # set mode: the whole grid shares the largest config's block shape
        # (ways).  A member whose main_cap falls below the shared MAIN set
        # count would leave most of its sets zero-way — keys could never
        # enter its main table and its hit ratio would silently collapse —
        # so such grids are rejected toward sequential mode.  (Zero-way
        # WINDOW sets are fine: those accesses bypass to main admission.)
        mslots = max(c._table_slots(c.main_cap, big.ways) for c in grid)
        if big.assoc is not None:
            msets = mslots // big.ways
            for c in grid:
                if c.main_cap < msets:
                    raise ValueError(
                        f"vmap assoc sweep: main_cap {c.main_cap} < shared "
                        f"{msets} sets (capacity {c.capacity} vs "
                        f"{big.capacity}); run mode='sequential'")
        spec = big.spec(
            window_slots=max(c._table_slots(c.window_cap, big.ways)
                             for c in grid),
            main_slots=mslots, ways=big.ways)
        pstack = jnp.stack([c.params(warmup=warmup) for c in grid])
        sstack = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[init_step_state(spec, c.window_cap, c.main_cap) for c in grid])
        if shared_trace:
            lo, hi = _trace_lanes(trace)
            in_axes = (0, 0, None, None)
        else:
            lanes = [_trace_lanes(t) for t in trace]
            lo = jnp.stack([l for l, _ in lanes])
            hi = jnp.stack([h for _, h in lanes])
            in_axes = (0, 0, 0, 0)
        key = (spec, in_axes)
        if key not in _vmap_cache:
            if len(_vmap_cache) >= _STEP_CACHE_LIMIT:
                _vmap_cache.clear()
            _vmap_cache[key] = jax.jit(jax.vmap(
                lambda p, s, l, h: step_ref(spec, p, s, l, h),
                in_axes=in_axes))
        out_states, _ = _vmap_cache[key](pstack, sstack, lo, hi)
        regs = np.asarray(out_states["regs"])
    elif mode == "sequential":
        # per-config tight specs: sketches sized exactly like the host's
        # per-capacity sizing, one compile per distinct geometry
        if shared_trace:
            lanes = [_trace_lanes(trace)] * len(grid)
        else:
            lanes = [_trace_lanes(t) for t in trace]
        outs = []
        for gi, (c, (l, h)) in enumerate(zip(grid, lanes)):
            spec = c.spec()
            st = init_step_state(spec, c.window_cap, c.main_cap)
            if adaptive:
                st, _, _, _ = _run_adaptive(c, spec, c.params(warmup=warmup),
                                            st, l, h, climbs[gi], "jit",
                                            False, mesh=c.mesh)
                outs.append(st["regs"])
            elif c.shards > 1:
                st, _ = _run_sharded(spec, c.params(warmup=warmup), st,
                                     l, h, c.merge_epoch, "jit", False,
                                     mesh=c.mesh)
                outs.append(st["regs"])
            else:
                outs.append(_jit_step(spec, c.params(warmup=warmup), st,
                                      l, h)[0]["regs"])
        regs = np.stack([np.asarray(r) for r in outs])
    else:
        raise ValueError(f"unknown mode {mode!r}")
    wall = time.perf_counter() - t0

    counted = n_per - warmup
    out = []
    for g, (C, wf) in enumerate(gridlab):
        hits = int(regs[g, R_HITS])
        # _row_extra keeps sweep rows schema-identical to simulate_trace
        # rows (sweep rows used to omit streams/integrity/merge_every)
        extra = {"backend": f"jit+{mode}", "window_frac": wf,
                 "grid": len(grid), "grid_wall_s": wall,
                 "assoc": grid[g].assoc,
                 "device": jax.default_backend(),
                 **_row_extra(grid[g], climbs[g] if adaptive else None,
                              adaptive)}
        if adaptive:
            extra["adaptive"] = True
            extra["final_quota"] = int(regs[g, R_WQUOTA])
        out.append(SimResult(
            policy=_policy_label(grid[g], adaptive),
            cache_size=C, trace=trace_name,
            accesses=counted, hits=hits, hit_ratio=hits / max(1, counted),
            # per-row amortized wall so accesses/wall_s is per-config and
            # comparable to host rows; the grid's total is in grid_wall_s
            wall_s=wall / len(grid), extra=extra))
        if verbose:
            print(f"  {trace_name:>12s} C={C:<7d} wf={wf:<5.2f} "
                  f"hit={out[-1].hit_ratio:.4f}  (grid of {len(grid)}, "
                  f"{wall:.1f}s total)", flush=True)
    return out
