"""Host-side cache eviction/replacement policies (paper §2.1 & §5 baselines).

Two interfaces:

* ``Eviction`` — pluggable eviction primitive (contains/on_hit/add/remove/
  peek_victim).  These compose with an admission policy via ``Cache`` — this
  is exactly Figure 1 of the paper (eviction picks the victim, admission
  decides the swap).  LRU, Random, FIFO, LFU (in-memory, O(1)), SLRU.
* ``ReplacementPolicy`` — self-contained ``access(key)->hit`` policies that
  entangle admission+eviction themselves and therefore cannot host TinyLFU:
  ARC, LIRS, 2Q, WLFU (exact windowed LFU), PLFU (perfect LFU).
"""
from __future__ import annotations

from collections import OrderedDict, deque, Counter
import random
from typing import Optional

import numpy as np

from .hashing import (assoc_geometry, dk_probe_index_np, set_ways,
                      set_index32_np, slots_for, MSET_SALT, MSET2_SALT,
                      WSET_SALT)
from .sketch import default_sketch


# ===========================================================================
# Pluggable evictions
# ===========================================================================

class Eviction:
    name = "base"

    def __init__(self, capacity: int):
        assert capacity > 0
        self.capacity = capacity

    def __contains__(self, key) -> bool: raise NotImplementedError
    def __len__(self) -> int: raise NotImplementedError
    def on_hit(self, key) -> None: raise NotImplementedError
    def add(self, key) -> None: raise NotImplementedError
    def remove(self, key) -> None: raise NotImplementedError
    def peek_victim(self): raise NotImplementedError
    def keys(self): raise NotImplementedError


class LRUEviction(Eviction):
    name = "lru"

    def __init__(self, capacity: int):
        super().__init__(capacity)
        self.od: OrderedDict = OrderedDict()

    def __contains__(self, key): return key in self.od
    def __len__(self): return len(self.od)

    def on_hit(self, key): self.od.move_to_end(key)
    def add(self, key): self.od[key] = None
    def remove(self, key): del self.od[key]
    def peek_victim(self): return next(iter(self.od))
    def keys(self): return self.od.keys()


class FIFOEviction(Eviction):
    name = "fifo"

    def __init__(self, capacity: int):
        super().__init__(capacity)
        self.od: OrderedDict = OrderedDict()

    def __contains__(self, key): return key in self.od
    def __len__(self): return len(self.od)
    def on_hit(self, key): pass
    def add(self, key): self.od[key] = None
    def remove(self, key): del self.od[key]
    def peek_victim(self): return next(iter(self.od))
    def keys(self): return self.od.keys()


class RandomEviction(Eviction):
    name = "random"

    def __init__(self, capacity: int, seed: int = 0):
        super().__init__(capacity)
        self.rng = random.Random(seed)
        self.items: list = []
        self.pos: dict = {}

    def __contains__(self, key): return key in self.pos
    def __len__(self): return len(self.items)
    def on_hit(self, key): pass

    def add(self, key):
        self.pos[key] = len(self.items)
        self.items.append(key)

    def remove(self, key):
        i = self.pos.pop(key)
        last = self.items.pop()
        if last != key:
            self.items[i] = last
            self.pos[last] = i

    def peek_victim(self):
        # fresh draw per access: a sticky victim with a maxed-out counter
        # would freeze the cache behind an unbeatable incumbent
        return self.items[self.rng.randrange(len(self.items))]

    def keys(self): return list(self.items)


class LFUEviction(Eviction):
    """In-memory LFU with O(1) ops (freq-bucket linked structure) + the §3.6
    synchronization hook: ``halve_all()`` is called by TinyLFU's reset so the
    cache's counters age together with the sketch."""
    name = "lfu"

    def __init__(self, capacity: int):
        super().__init__(capacity)
        self.freq: dict = {}
        self.buckets: dict[int, OrderedDict] = {}
        self.minf = 0

    def __contains__(self, key): return key in self.freq
    def __len__(self): return len(self.freq)

    def _bump(self, key, newf):
        oldf = self.freq.get(key)
        if oldf is not None:
            b = self.buckets[oldf]
            del b[key]
            if not b:
                del self.buckets[oldf]
                if self.minf == oldf:
                    self.minf = newf if oldf != newf else self.minf
        self.freq[key] = newf
        self.buckets.setdefault(newf, OrderedDict())[key] = None
        if newf < self.minf or len(self.freq) == 1:
            self.minf = newf

    def on_hit(self, key): self._bump(key, self.freq[key] + 1)
    def add(self, key): self._bump(key, 1)

    def remove(self, key):
        f = self.freq.pop(key)
        b = self.buckets[f]
        del b[key]
        if not b:
            del self.buckets[f]
            if self.minf == f and self.freq:
                self.minf = min(self.buckets)   # rare; amortized fine
        if not self.freq:
            self.minf = 0

    def peek_victim(self):
        while self.minf not in self.buckets:
            self.minf = min(self.buckets)
        return next(iter(self.buckets[self.minf]))

    def keys(self): return self.freq.keys()

    def halve_all(self):
        items = [(k, f // 2) for k, f in self.freq.items()]
        self.freq.clear(); self.buckets.clear()
        for k, f in items:
            f = max(f, 1)
            self.freq[k] = f
            self.buckets.setdefault(f, OrderedDict())[k] = None
        self.minf = min(self.buckets) if self.buckets else 0


class SLRUEviction(Eviction):
    """Segmented LRU (§2.1): probation (A1) + protected (A2).  New items ->
    probation; hit in probation -> promote to protected; protected overflow
    demotes its LRU victim back to probation.  Victim = probation LRU."""
    name = "slru"

    def __init__(self, capacity: int, protected_frac: float = 0.8):
        super().__init__(capacity)
        self.prot_cap = max(1, int(capacity * protected_frac))
        self.probation: OrderedDict = OrderedDict()
        self.protected: OrderedDict = OrderedDict()

    def __contains__(self, key):
        return key in self.probation or key in self.protected

    def __len__(self): return len(self.probation) + len(self.protected)

    def on_hit(self, key):
        if key in self.protected:
            self.protected.move_to_end(key)
            return
        del self.probation[key]
        self.protected[key] = None
        if len(self.protected) > self.prot_cap:      # demote protected LRU
            demoted, _ = self.protected.popitem(last=False)
            self.probation[demoted] = None

    def add(self, key): self.probation[key] = None

    def remove(self, key):
        if key in self.probation: del self.probation[key]
        else: del self.protected[key]

    def peek_victim(self):
        if self.probation:
            return next(iter(self.probation))
        return next(iter(self.protected))

    def keys(self):
        return list(self.probation.keys()) + list(self.protected.keys())


class SetAssociativeSLRU(Eviction):
    """Host twin of the device set-associative SLRU main table
    (kernels/sketch_step.py ``_one_access_set``).

    Layout and semantics mirror the device exactly: pow2 sets of ``ways``
    (>= ``assoc``) slots sized by ``core.hashing.assoc_geometry``/``set_ways``,
    power-of-two-choices placement (``MSET_SALT``/``MSET2_SALT`` 32-bit-lane
    set hashes — a key resides in exactly one of its two choice sets),
    per-set protected budget ``max(1, usable * prot_cap // capacity)``, and
    victim priority empty < probation LRU < protected LRU across the key's
    two sets with the first-choice set winning ties.  Stamps are
    caller-provided monotone access indices so ``WTinyLFU(assoc=...)``
    reproduces the device engine's per-access hit sequence bit-for-bit
    (tests pin this with collision-free sketches).
    """
    name = "slru-assoc"

    def __init__(self, capacity: int, assoc: int = 8,
                 protected_frac: float = 0.8):
        super().__init__(capacity)
        self.n_sets, self.ways = assoc_geometry(capacity, assoc)
        self.usable = set_ways(capacity, self.n_sets)
        self.prot_cap = max(1, int(capacity * protected_frac))
        # per set: key -> [protected: bool, stamp: int]
        self.slots: list[dict] = [dict() for _ in range(self.n_sets)]
        self.home: dict = {}              # key -> resident set index
        self._memo: dict = {}             # key -> (choice set 1, choice set 2)
        self.t = 0                        # auto-stamp for standalone use

    def _stamp(self, stamp: Optional[int]) -> int:
        if stamp is None:
            stamp = self.t
            self.t += 1
        return stamp

    _MEMO_LIMIT = 2_000_000           # hash memo safety valve (scan traces)

    def sets_of(self, key) -> tuple[int, int]:
        p = self._memo.get(key)
        if p is None:
            k = np.asarray([key], np.uint64)
            p = (int(set_index32_np(k, self.n_sets, MSET_SALT)[0]),
                 int(set_index32_np(k, self.n_sets, MSET2_SALT)[0]))
            if len(self._memo) >= self._MEMO_LIMIT:
                self._memo.clear()
            self._memo[key] = p
        return p

    def __contains__(self, key): return key in self.home
    def __len__(self): return len(self.home)
    def keys(self): return list(self.home)

    def _prot_budget(self, s: int) -> int:
        return max(1, self.usable[s] * self.prot_cap // max(1, self.capacity))

    def on_hit(self, key, stamp: Optional[int] = None) -> None:
        """Promote-or-refresh to protected MRU; overflow demotes the set's
        protected LRU back to probation MRU (device step 3b)."""
        stamp = self._stamp(stamp)
        s = self.home[key]
        st = self.slots[s]
        st[key] = [True, stamp]
        if sum(1 for p, _ in st.values() if p) > self._prot_budget(s):
            demote = min((k for k, (p, _) in st.items() if p),
                         key=lambda k: st[k][1])
            st[demote] = [False, stamp]

    def victim_for(self, key) -> tuple[int, object]:
        """Where an insert of ``key`` would land: ``(set, None)`` for a free
        way, else ``(set, victim_key)`` — the weakest of the two choice
        sets' records (device step 5's argmin over the 2*ways concat)."""
        s1, s2 = self.sets_of(key)
        for s in (s1, s2):
            if len(self.slots[s]) < self.usable[s]:
                return s, None
        best = None
        for s in (s1, s2):
            for k, (p, stmp) in self.slots[s].items():
                if best is None or (p, stmp) < best[:2]:
                    best = (p, stmp, s, k)
        return best[2], best[3]

    def insert(self, key, set_index: int, stamp: Optional[int] = None) -> None:
        """Place ``key`` in ``set_index`` as probation MRU (admitted or
        free-way insert; the set comes from :meth:`victim_for`)."""
        self.slots[set_index][key] = [False, self._stamp(stamp)]
        self.home[key] = set_index

    def remove(self, key) -> None:
        del self.slots[self.home.pop(key)][key]

    # -- Eviction-interface conveniences for standalone composition ----------
    def add(self, key) -> None:
        s, victim = self.victim_for(key)
        if victim is not None:
            self.remove(victim)
        self.insert(key, s)

    def peek_victim(self):
        """Globally weakest record (O(capacity) — diagnostics only; the
        device-faithful query is the per-key :meth:`victim_for`)."""
        best = None
        for st in self.slots:
            for k, (p, stmp) in st.items():
                if best is None or (p, stmp) < best[:2]:
                    best = (p, stmp, k)
        return best[2] if best else None


# ===========================================================================
# Cache driver: eviction ∘ admission   (paper Fig. 1)
# ===========================================================================

class Cache:
    """``access(key) -> hit`` driver wiring an Eviction to an optional
    admission policy object exposing record(key) and admit(cand, victim)."""

    def __init__(self, eviction: Eviction, admission=None):
        self.ev = eviction
        self.admission = admission
        self.hits = 0
        self.misses = 0

    @property
    def capacity(self): return self.ev.capacity

    def access(self, key) -> bool:
        adm = self.admission
        if adm is not None:
            adm.record(key)
        if key in self.ev:
            self.ev.on_hit(key)
            self.hits += 1
            return True
        self.misses += 1
        if len(self.ev) < self.ev.capacity:
            self.ev.add(key)
            return False
        victim = self.ev.peek_victim()
        if adm is None or adm.admit(key, victim):
            self.ev.remove(victim)
            self.ev.add(key)
        return False

    @property
    def hit_ratio(self):
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


# ===========================================================================
# Self-contained replacement policies
# ===========================================================================

class ReplacementPolicy:
    name = "base"

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.hits = 0
        self.misses = 0

    def access(self, key) -> bool:
        hit = self._access(key)
        if hit: self.hits += 1
        else: self.misses += 1
        return hit

    @property
    def hit_ratio(self):
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class PLFU(ReplacementPolicy):
    """Perfect LFU: unbounded global histogram; cache holds argmax-C keys.
    Implemented incrementally: on access, bump global count; admit iff count
    exceeds the cache's current minimum (classic PLFU behaviour)."""
    name = "plfu"

    def __init__(self, capacity: int):
        super().__init__(capacity)
        self.counts: Counter = Counter()
        self.lfu = LFUEviction(capacity)    # reuse bucket structure, freq=global count

    def _access(self, key) -> bool:
        self.counts[key] += 1
        c = self.counts[key]
        if key in self.lfu:
            self.lfu._bump(key, c)
            return True
        if len(self.lfu) < self.capacity:
            self.lfu._bump(key, c)
            return False
        victim = self.lfu.peek_victim()
        if c > self.lfu.freq[victim]:
            self.lfu.remove(victim)
            self.lfu._bump(key, c)
        return False


class WLFU(ReplacementPolicy):
    """Window LFU [38]: exact frequency over the last W requests; both the
    eviction and the admission compare exact window counts."""
    name = "wlfu"

    def __init__(self, capacity: int, window: int):
        super().__init__(capacity)
        self.window = window
        self.win: deque = deque()
        self.wcount: Counter = Counter()
        self.lfu = LFUEviction(capacity)

    def _record(self, key):
        self.win.append(key)
        self.wcount[key] += 1
        if len(self.win) > self.window:
            old = self.win.popleft()
            self.wcount[old] -= 1
            if self.wcount[old] <= 0:
                del self.wcount[old]
            if old in self.lfu:
                self.lfu._bump(old, max(1, self.wcount[old]))

    def _access(self, key) -> bool:
        self._record(key)
        c = max(1, self.wcount[key])
        if key in self.lfu:
            self.lfu._bump(key, c)
            return True
        if len(self.lfu) < self.capacity:
            self.lfu._bump(key, c)
            return False
        victim = self.lfu.peek_victim()
        if c > self.lfu.freq[victim]:
            self.lfu.remove(victim)
            self.lfu._bump(key, c)
        return False


class TwoQ(ReplacementPolicy):
    """2Q [37]: A1in FIFO (25%), A1out ghost FIFO (50% of capacity, keys
    only), Am LRU (75%)."""
    name = "2q"

    def __init__(self, capacity: int, kin: float = 0.25, kout: float = 0.5):
        super().__init__(capacity)
        self.kin_cap = max(1, int(capacity * kin))
        self.am_cap = max(1, capacity - self.kin_cap)
        self.kout_cap = max(1, int(capacity * kout))
        self.a1in: OrderedDict = OrderedDict()
        self.a1out: OrderedDict = OrderedDict()
        self.am: OrderedDict = OrderedDict()

    def _access(self, key) -> bool:
        if key in self.am:
            self.am.move_to_end(key)
            return True
        if key in self.a1in:                 # stays in A1in until FIFO-evicted
            return True
        if key in self.a1out:                # ghost hit -> promote to Am
            del self.a1out[key]
            self.am[key] = None
            if len(self.am) > self.am_cap:
                self.am.popitem(last=False)
            return False
        self.a1in[key] = None
        if len(self.a1in) > self.kin_cap:
            old, _ = self.a1in.popitem(last=False)
            self.a1out[old] = None
            if len(self.a1out) > self.kout_cap:
                self.a1out.popitem(last=False)
        return False


class ARC(ReplacementPolicy):
    """ARC [44]: T1/T2 resident, B1/B2 ghosts, adaptive target p."""
    name = "arc"

    def __init__(self, capacity: int):
        super().__init__(capacity)
        self.p = 0
        self.t1: OrderedDict = OrderedDict()
        self.t2: OrderedDict = OrderedDict()
        self.b1: OrderedDict = OrderedDict()
        self.b2: OrderedDict = OrderedDict()

    def _replace(self, in_b2: bool):
        if self.t1 and (len(self.t1) > self.p or (in_b2 and len(self.t1) == self.p)):
            old, _ = self.t1.popitem(last=False)
            self.b1[old] = None
        else:
            old, _ = self.t2.popitem(last=False)
            self.b2[old] = None

    def _access(self, key) -> bool:
        c = self.capacity
        if key in self.t1:
            del self.t1[key]
            self.t2[key] = None
            return True
        if key in self.t2:
            self.t2.move_to_end(key)
            return True
        if key in self.b1:
            self.p = min(c, self.p + max(1, len(self.b2) // max(1, len(self.b1))))
            self._replace(False)
            del self.b1[key]
            self.t2[key] = None
            return False
        if key in self.b2:
            self.p = max(0, self.p - max(1, len(self.b1) // max(1, len(self.b2))))
            self._replace(True)
            del self.b2[key]
            self.t2[key] = None
            return False
        # brand-new key
        if len(self.t1) + len(self.b1) == c:
            if len(self.t1) < c:
                self.b1.popitem(last=False)
                self._replace(False)
            else:
                self.t1.popitem(last=False)
        elif len(self.t1) + len(self.b1) < c:
            total = len(self.t1) + len(self.t2) + len(self.b1) + len(self.b2)
            if total >= c:
                if total == 2 * c:
                    self.b2.popitem(last=False)
                self._replace(False)
        self.t1[key] = None
        return False


class LIRS(ReplacementPolicy):
    """LIRS [36].  Stack S tracks recency of LIR + recently-seen HIR (resident
    and non-resident); queue Q holds resident HIR blocks.  ~1% HIR budget."""
    name = "lirs"

    LIR, HIR_RES, HIR_NONRES = 0, 1, 2

    def __init__(self, capacity: int, hir_frac: float = 0.01,
                 max_nonres_factor: float = 3.0):
        super().__init__(capacity)
        self.lhirs = max(1, int(capacity * hir_frac))
        self.llirs = max(1, capacity - self.lhirs)
        self.s: OrderedDict = OrderedDict()   # key -> state (front=LRU end=MRU)
        self.q: OrderedDict = OrderedDict()   # resident HIR
        self.lir_count = 0
        self.state: dict = {}                  # key -> state for residents+ghosts
        self.max_nonres = int(max_nonres_factor * capacity)
        self.nonres: OrderedDict = OrderedDict()  # ghost order (oldest first)

    def _prune(self):
        # Bottom of S must be LIR.
        while self.s:
            k = next(iter(self.s))
            if self.state.get(k) == self.LIR:
                break
            del self.s[k]
            if self.state.get(k) == self.HIR_NONRES:
                del self.state[k]              # fully forgotten
                self.nonres.pop(k, None)

    def _bound_nonres(self):
        while len(self.nonres) > self.max_nonres:
            k, _ = self.nonres.popitem(last=False)
            if self.state.get(k) == self.HIR_NONRES:
                del self.state[k]
                self.s.pop(k, None)
        self._prune()

    def _evict_hir_resident(self):
        k, _ = self.q.popitem(last=False)
        if k in self.s:
            self.state[k] = self.HIR_NONRES
            self.nonres[k] = None
        else:
            del self.state[k]

    def _demote_lir_bottom(self):
        k = next(iter(self.s))
        del self.s[k]
        self.state[k] = self.HIR_RES
        self.q[k] = None
        self.lir_count -= 1
        self._prune()

    def _access(self, key) -> bool:
        st = self.state.get(key)
        if st == self.LIR:
            was_bottom = next(iter(self.s)) == key
            self.s.move_to_end(key)
            if was_bottom:
                self._prune()
            return True
        if st == self.HIR_RES:
            in_s = key in self.s
            if in_s:
                self.s.move_to_end(key)
                self.state[key] = self.LIR
                self.lir_count += 1
                del self.q[key]
                if self.lir_count > self.llirs:
                    self._demote_lir_bottom()
            else:
                self.s[key] = None
                self.q.move_to_end(key)
            return True
        # miss (new or non-resident HIR ghost)
        if self.lir_count < self.llirs and st is None and not self.q:
            self.state[key] = self.LIR
            self.s[key] = None
            self.lir_count += 1
            return False
        if len(self.q) + self.lir_count >= self.capacity:
            if self.q:
                self._evict_hir_resident()
            else:
                self._demote_lir_bottom()
                self._evict_hir_resident()
        if st == self.HIR_NONRES and key in self.s:   # ghost hit -> LIR
            self.nonres.pop(key, None)
            self.s.move_to_end(key)
            self.state[key] = self.LIR
            self.lir_count += 1
            if self.lir_count > self.llirs:
                self._demote_lir_bottom()
        else:
            self.nonres.pop(key, None)
            self.state[key] = self.HIR_RES
            self.s[key] = None
            self.q[key] = None
        self._bound_nonres()
        return False


# ===========================================================================
# Device-policy host twins (kernels/sketch_step.py StepSpec.policy panel)
# ===========================================================================

class _SetAssocTable:
    """Shared set-associative main-table bookkeeping for the device-policy
    host twins: pow2 sets sized by ``assoc_geometry``/``set_ways``,
    power-of-two-choices placement (``MSET_SALT``/``MSET2_SALT``), per-set
    ``key -> [flag, stamp]`` records.  Free-way preference follows the
    device victim argmin exactly: an empty slot (-1 meta) beats every
    resident, and the first-choice set's empties order before the
    second's in the (2*ways,) concat."""

    _MEMO_LIMIT = 2_000_000           # hash memo safety valve (scan traces)

    def __init__(self, capacity: int, assoc: int):
        self.capacity = capacity
        self.n_sets, self.ways = assoc_geometry(capacity, assoc)
        self.usable = set_ways(capacity, self.n_sets)
        self.slots: list[dict] = [dict() for _ in range(self.n_sets)]
        self.home: dict = {}              # key -> resident set index
        self._memo: dict = {}

    def sets_of(self, key) -> tuple[int, int]:
        p = self._memo.get(key)
        if p is None:
            k = np.asarray([key], np.uint64)
            p = (int(set_index32_np(k, self.n_sets, MSET_SALT)[0]),
                 int(set_index32_np(k, self.n_sets, MSET2_SALT)[0]))
            if len(self._memo) >= self._MEMO_LIMIT:
                self._memo.clear()
            self._memo[key] = p
        return p

    def __contains__(self, key): return key in self.home
    def __len__(self): return len(self.home)

    def free_set(self, key):
        """First choice set with a free usable way, or None (device: the
        empty-slot -1 wins the victim argmin, first half first)."""
        s1, s2 = self.sets_of(key)
        for s in (s1, s2):
            if len(self.slots[s]) < self.usable[s]:
                return s
        return None

    def insert(self, key, s: int, flag: bool, stamp: int) -> None:
        self.slots[s][key] = [flag, stamp]
        self.home[key] = s

    def remove(self, key) -> None:
        del self.slots[self.home.pop(key)][key]

    def residents(self, key):
        """(set, key, flag, stamp) over the key's two choice sets, first
        choice first — deduplicated when the choices alias (device masks
        the duplicate second half out of the victim scan)."""
        s1, s2 = self.sets_of(key)
        out = [(s1, k, f, st) for k, (f, st) in self.slots[s1].items()]
        if s2 != s1:
            out += [(s2, k, f, st) for k, (f, st) in self.slots[s2].items()]
        return out


class _GhostBloom:
    """Bit-for-bit replay of one half of the device ``"ghost"`` buffer: a
    ``dk_bits``-bit Bloom filter addressed by the doorkeeper probe schedule
    (``core.hashing.dk_probe_index_np``), cleared wholesale when it has
    absorbed ``clear_at`` inserts (the device's saturation clear)."""

    def __init__(self, dk_bits: int, dk_probes: int, clear_at: int):
        self.dk_bits = dk_bits
        self.dk_probes = dk_probes
        self.clear_at = clear_at
        self.words = np.zeros(max(1, dk_bits // 32), np.int64)
        self.count = 0
        self._memo: dict = {}

    def _bits(self, key):
        b = self._memo.get(key)
        if b is None:
            lo = np.asarray([key & 0xFFFFFFFF], np.uint32)
            hi = np.asarray([(key >> 32) & 0xFFFFFFFF], np.uint32)
            b = tuple(int(dk_probe_index_np(lo, hi, p, self.dk_bits)[0])
                      for p in range(self.dk_probes))
            if len(self._memo) >= _SetAssocTable._MEMO_LIMIT:
                self._memo.clear()
            self._memo[key] = b
        return b

    def __contains__(self, key) -> bool:
        return all((int(self.words[b >> 5]) >> (b & 31)) & 1
                   for b in self._bits(key))

    def add(self, key) -> None:
        if self.count >= self.clear_at:
            self.words[:] = 0
            self.count = 0
        for b in self._bits(key):
            self.words[b >> 5] |= np.int64(1 << (b & 31))
        self.count += 1


class SetAssocS3FIFO(ReplacementPolicy):
    """Host twin of the device ``policy="s3fifo"`` step
    (kernels/sketch_step.py ``_one_access_set_s3fifo``).

    S3-FIFO on the shared set-associative machinery: a small per-set FIFO
    (the device window table — hits do NOT refresh, order is insert
    order), a CLOCK-marked main FIFO (a hit sets the accessed flag and
    keeps the insert stamp; the victim scan prefers empty < unmarked
    FIFO-oldest < marked FIFO-oldest across the key's two choice sets),
    and the frequency sketch as the one-hit-wonder filter: a candidate
    displaced from the small FIFO enters main only when its estimate is
    >= 2, with no free-slot override.  With collision-free sketches the
    per-access hit sequence equals the device program's bit-for-bit."""
    name = "s3fifo-assoc"

    def __init__(self, capacity: int, window_frac: float = 0.1,
                 assoc: int = 8, sample_factor: int = 8, seed: int = 0,
                 counters_per_item: float = 1.0, doorkeeper: bool = True):
        super().__init__(capacity)
        self.window_cap = max(1, int(round(capacity * window_frac)))
        self.main_cap = max(1, capacity - self.window_cap)
        self.main = _SetAssocTable(self.main_cap, assoc)
        ways = self.main.ways
        self._n_wsets = slots_for(self.window_cap, ways) // ways
        self._wusable = set_ways(self.window_cap, self._n_wsets)
        self._wsets = [OrderedDict() for _ in range(self._n_wsets)]
        self._wset_memo: dict = {}
        self._t = 0
        self.sketch = default_sketch(capacity, sample_factor=sample_factor,
                                     seed=seed,
                                     counters_per_item=counters_per_item,
                                     doorkeeper=doorkeeper)

    def _wset_of(self, key) -> int:
        s = self._wset_memo.get(key)
        if s is None:
            s = int(set_index32_np(np.asarray([key], np.uint64),
                                   self._n_wsets, WSET_SALT)[0])
            if len(self._wset_memo) >= _SetAssocTable._MEMO_LIMIT:
                self._wset_memo.clear()
            self._wset_memo[key] = s
        return s

    def _access(self, key) -> bool:
        t = self._t
        self._t += 1
        self.sketch.add(key)
        ws = self._wset_of(key)
        wset = self._wsets[ws]
        if key in wset:                    # small-FIFO hit: NO refresh
            return True
        if key in self.main:               # main hit: set the CLOCK mark
            s = self.main.home[key]
            self.main.slots[s][key][0] = True
            return True
        # miss: small-FIFO insert; overflow displaces the oldest toward main
        wset[key] = None
        if len(wset) > self._wusable[ws]:
            cand, _ = wset.popitem(last=False)
            if self.sketch.estimate(cand) >= 2:     # one-hit-wonder filter
                s = self.main.free_set(cand)
                if s is not None:
                    self.main.insert(cand, s, False, t)
                else:
                    best = None
                    for s_, k, f, st in self.main.residents(cand):
                        if best is None or (f, st) < best[:2]:
                            best = (f, st, s_, k)
                    if best is not None:
                        self.main.remove(best[3])
                        self.main.insert(cand, best[2], False, t)
        return False


class SetAssocARC(ReplacementPolicy):
    """Host twin of the device ``policy="arc"`` step
    (kernels/sketch_step.py ``_one_access_set_arc``).

    The seed :class:`ARC` is the algorithmic reference; this twin replays
    the device's *approximations* of it exactly: T1/T2 share the
    set-associative main table (flag = "in T2"), the adaptive target ``p``
    moves by +-1 per ghost hit (clamped to [0, capacity]), and the B1/B2
    ghost lists are Bloom halves replayed bit-for-bit through the device
    doorkeeper probe schedule — membership is approximate, removal is the
    wholesale saturation clear.  Because the Bloom arithmetic is replayed
    exactly (``dk_probe_index_np``), the hit sequence matches the device
    program exact-by-construction at ANY ``dk_bits`` — no collision-free
    assumption needed (ARC never consults the frequency sketch)."""
    name = "arc-assoc"

    def __init__(self, capacity: int, assoc: int = 8,
                 dk_bits: int | None = None, dk_probes: int = 3):
        super().__init__(capacity)
        if dk_bits is None:
            dk_bits = max(32, 1 << max(0, (32 * capacity - 1).bit_length()))
        self.main = _SetAssocTable(capacity, assoc)
        self.p = 0
        self.t1count = 0
        self.b1 = _GhostBloom(dk_bits, dk_probes, capacity)
        self.b2 = _GhostBloom(dk_bits, dk_probes, capacity)
        self._t = 0

    def _access(self, key) -> bool:
        t = self._t
        self._t += 1
        main = self.main
        if key in main:                    # hit: promote to T2, refresh
            rec = main.slots[main.home[key]][key]
            if not rec[0]:
                self.t1count -= 1          # T1 hit leaves T1
            rec[0] = True
            rec[1] = t
            return True
        # miss: ghost-driven +-1 adaptation (B1 beats B2 when both match)
        gb1 = key in self.b1
        gb2 = key in self.b2
        if gb1:
            self.p = min(self.main.capacity, self.p + 1)
        elif gb2:
            self.p = max(0, self.p - 1)
        in_t2 = gb1 or gb2                 # ghost-remembered -> T2
        s = main.free_set(key)
        if s is None:
            prefer_t1 = (self.t1count > self.p
                         or ((gb2 and not gb1) and self.t1count == self.p))
            best = None
            for s_, k, f, st in main.residents(key):
                okey = (f if prefer_t1 else not f, st)
                if best is None or okey < best[0]:
                    best = (okey, s_, k, f)
            if best is None:               # degenerate zero-way sets
                return False
            _, s, vic, vic_t2 = best
            main.remove(vic)
            if vic_t2:
                self.b2.add(vic)
            else:
                self.b1.add(vic)
                self.t1count -= 1
        main.insert(key, s, in_t2, t)
        if not in_t2:
            self.t1count += 1
        return False


class SetAssocLFU(ReplacementPolicy):
    """Host twin of the device ``policy="lfu"`` step
    (kernels/sketch_step.py ``_one_access_set_lfu``).

    Heap-free sketch-LFU: no window, no admission filter; the victim is
    the resident with the smallest sketch estimate across the key's two
    choice sets (stamps break frequency ties toward the LRU record), and
    a hit refreshes the stamp only.  With collision-free sketches the
    per-access hit sequence equals the device program's bit-for-bit."""
    name = "lfu-assoc"

    def __init__(self, capacity: int, assoc: int = 8, sample_factor: int = 8,
                 seed: int = 0, counters_per_item: float = 1.0,
                 doorkeeper: bool = True):
        super().__init__(capacity)
        self.main = _SetAssocTable(capacity, assoc)
        self._t = 0
        self.sketch = default_sketch(capacity, sample_factor=sample_factor,
                                     seed=seed,
                                     counters_per_item=counters_per_item,
                                     doorkeeper=doorkeeper)

    def _access(self, key) -> bool:
        t = self._t
        self._t += 1
        self.sketch.add(key)
        main = self.main
        if key in main:
            main.slots[main.home[key]][key][1] = t      # stamp refresh only
            return True
        s = main.free_set(key)
        if s is None:
            best = None
            for s_, k, _f, st in main.residents(key):
                okey = (self.sketch.estimate(k), st)
                if best is None or okey < best[0]:
                    best = (okey, s_, k)
            if best is None:               # degenerate zero-way sets
                return False
            _, s, vic = best
            main.remove(vic)
        main.insert(key, s, False, t)
        return False
