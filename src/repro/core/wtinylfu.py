"""W-TinyLFU (paper §4, Fig 5): LRU window cache (no admission) in front of an
SLRU main cache guarded by TinyLFU admission.

Flow per access:
  * hit in window or main -> hit (window hit refreshes window LRU; main hit
    follows SLRU promotion).
  * miss -> insert into window.  If the window overflows, its LRU victim asks
    for admission into the main cache; on rejection the window victim is
    dropped (it *is* W-TinyLFU's victim), on admission the main cache's SLRU
    victim is dropped instead.

Caffeine 2.0 defaults: window = 1% of total capacity, main = 99% with an
80/20 protected/probation SLRU split.

``assoc=W`` switches both tables to the set-associative layout — a host twin
of the device engine's O(ways) tables (kernels/sketch_step.py): the window
becomes per-set LRU and the main cache a ``SetAssociativeSLRU``
(power-of-two-choices placement, per-set protected budgets).  With
collision-free sketches the assoc host and device engines produce identical
per-access hit sequences (tests/test_sketch_step.py pins this).

``shards=S`` swaps the sketch for the sharded twin
(``core.sketch.ShardedFrequencySketch``): writes accumulate in shard-local
deltas, reads compose global+delta, and every ``merge_every`` accesses the
merge_halve fold runs — mirroring the device engine's ``StepSpec.shards``
mode, whose per-access hit sequence it reproduces bit-for-bit under
collision-free sketches (reset timing included: §3.3 aging is deferred to
the merge boundaries on both sides).

``stale_admission=True`` (sharded only) makes admission estimates read the
merged global sketch ONLY — stale by at most one merge epoch — the host
twin of the device mesh runner's speculative ``mesh_exchange="stale"``
mode, whose per-access path is collective-free.  Under collision-free
sketches its hit sequence matches the stale-mode mesh run bit-for-bit.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

from .adaptive import window_cap_max, resolve_climb, climb_update
from .hashing import slots_for, set_ways, set_index32_np, WSET_SALT
from .policies import SLRUEviction, SetAssociativeSLRU, ReplacementPolicy
from .sketch import default_sketch
from .tinylfu import TinyLFUAdmission


class WTinyLFU(ReplacementPolicy):
    name = "w-tinylfu"

    def __init__(self, capacity: int, window_frac: float = 0.01,
                 sample_factor: int = 8, protected_frac: float = 0.8,
                 seed: int = 0, counters_per_item: float = 1.0,
                 doorkeeper: bool = True, assoc: int | None = None,
                 shards: int = 1, merge_every: int = 0,
                 stale_admission: bool = False):
        super().__init__(capacity)
        self.window_cap = max(1, int(round(capacity * window_frac)))
        self.main_cap = max(1, capacity - self.window_cap)
        self.assoc = assoc
        # sharded sketch twin (device StepSpec.shards): writes accumulate in
        # shard deltas and every ``merge_every`` accesses the merge_halve
        # fold runs — mirroring the device's epoch-boundary fused op,
        # including the 0 = auto cadence (min(4096, sample_size), i.e.
        # DeviceWTinyLFU.merge_epoch — aging never defers past one reset
        # period)
        self.shards = shards
        self.merge_every = merge_every or max(
            1, min(4096, sample_factor * capacity))
        self._nacc = 0
        if assoc is None:
            self.window: OrderedDict = OrderedDict()
            self.main = SLRUEviction(self.main_cap,
                                     protected_frac=protected_frac)
        else:
            self.main = SetAssociativeSLRU(self.main_cap, assoc=assoc,
                                           protected_frac=protected_frac)
            # the window shares the main table's static ways (one block
            # shape on device); per-set LRU over pow2 window sets
            ways = self.main.ways
            self._n_wsets = slots_for(self.window_cap, ways) // ways
            self._wusable = set_ways(self.window_cap, self._n_wsets)
            self._wsets = [OrderedDict() for _ in range(self._n_wsets)]
            self._wset_memo: dict = {}
            self._t = 0                    # device-matching LRU stamp
        sketch = default_sketch(capacity, sample_factor=sample_factor,
                                seed=seed, counters_per_item=counters_per_item,
                                doorkeeper=doorkeeper, shards=shards,
                                stale_estimates=stale_admission)
        self.admission = TinyLFUAdmission(sketch)

    def __contains__(self, key):
        if self.assoc is None:
            return key in self.window or key in self.main
        return (key in self._wsets[self._wset_of(key)]
                or key in self.main)

    _WSET_MEMO_LIMIT = 2_000_000      # hash memo safety valve (scan traces)

    def _wset_of(self, key) -> int:
        s = self._wset_memo.get(key)
        if s is None:
            s = int(set_index32_np(np.asarray([key], np.uint64),
                                   self._n_wsets, WSET_SALT)[0])
            if len(self._wset_memo) >= self._WSET_MEMO_LIMIT:
                self._wset_memo.clear()
            self._wset_memo[key] = s
        return s

    def _access(self, key) -> bool:
        hit = (self._access_assoc(key) if self.assoc is not None
               else self._access_flat(key))
        if self.shards > 1:
            # device parity: the merge_halve fold runs after every
            # merge_every-th access completes, never on a partial tail
            self._nacc += 1
            if self._nacc % self.merge_every == 0:
                self.admission.sketch.merge_halve()
        return hit

    def _access_flat(self, key) -> bool:
        self.admission.record(key)
        if key in self.window:
            self.window.move_to_end(key)
            return True
        if key in self.main:
            self.main.on_hit(key)
            return True
        # miss: admit to window unconditionally
        self.window[key] = None
        if len(self.window) > self.window_cap:
            cand, _ = self.window.popitem(last=False)
            if len(self.main) < self.main.capacity:
                self.main.add(cand)
            else:
                victim = self.main.peek_victim()
                if self.admission.admit(cand, victim):
                    self.main.remove(victim)
                    self.main.add(cand)
        return False

    def _access_assoc(self, key) -> bool:
        """Set-associative twin of the device `_one_access_set` step."""
        t = self._t
        self._t += 1
        self.admission.record(key)
        wset = self._wsets[self._wset_of(key)]
        if key in wset:
            wset.move_to_end(key)          # refresh = stamp t (order only)
            return True
        if key in self.main:
            self.main.on_hit(key, t)
            return True
        # miss: insert into the key's window set; per-set LRU overflow
        # displaces a candidate toward the main table
        wset[key] = None
        if len(wset) > self._wusable[self._wset_of(key)]:
            cand, _ = wset.popitem(last=False)
            vset, victim = self.main.victim_for(cand)
            if victim is None:             # free way in a choice set
                self.main.insert(cand, vset, t)
            elif self.admission.admit(cand, victim):
                self.main.remove(victim)
                self.main.insert(cand, vset, t)
        return False


class AdaptiveWTinyLFU(ReplacementPolicy):
    """Runtime-adaptive W-TinyLFU: the window/main split is mutable state
    driven by an epoch-based hill-climber — the host twin of the device
    engine's ``adaptive=True`` mode (kernels/sketch_step.py runtime quota +
    core/device_simulate.py climb), exact flat-table layout.

    Every decision mirrors the device step bit-for-bit: stamps derive from
    the global access index (window ``2t`` / main ``2t+1`` so migration can
    never collide two entries), SLRU priority is the packed-meta order
    (probation stamp < protected stamp), the runtime protected budget is
    ``max(1, mcap_rt * prot_cap // main_cap)``, misses insert into the
    window gated by the runtime quota, and every ``epoch_len`` accesses the
    shared integer climb rule (``core.adaptive.climb_update``) moves the
    quota and the rebalance migrates displaced window records into main's
    free room (stamps preserved) or evicts main's weakest beyond its new
    budget.  With collision-free sketches on both sides the per-access hit
    sequence equals the device climber's exactly (tests pin this).
    """
    name = "w-tinylfu-adaptive"

    def __init__(self, capacity: int, window_frac: float = 0.01,
                 sample_factor: int = 8, protected_frac: float = 0.8,
                 seed: int = 0, counters_per_item: float = 1.0,
                 doorkeeper: bool = True, window_max_frac: float = 0.5,
                 epoch_len: int = 4096, delta0: int = 0, wmin: int = 1,
                 wmax: int = 0, tol: int = 0, restart: int = 0,
                 warm_epochs: int = 3, shards: int = 1,
                 stale_admission: bool = False):
        super().__init__(capacity)
        self.shards = shards          # sharded sketch: merge rides the epochs
        self.window_cap0 = max(1, int(round(capacity * window_frac)))
        self.main_cap0 = max(1, capacity - self.window_cap0)
        self.total = self.window_cap0 + self.main_cap0
        self.prot_cap0 = max(1, int(self.main_cap0 * protected_frac))
        self.quota = self.window_cap0
        self.epoch_len = epoch_len
        self.climb = resolve_climb(
            epoch_len, delta0, wmin, wmax, tol, restart, warm_epochs,
            window_cap_max(capacity, self.window_cap0, window_max_frac))
        # window: key -> stamp; main: key -> [protected, stamp]
        self._window: dict = {}
        self._main: dict = {}
        self._pcount = 0
        self._t = 0
        # climber carry (mirrors the device scan carry)
        self._prev, self._dirn, self._delta = -1, 1, self.climb[0]
        self._ewma, self._trend, self._k = -1, 0, 0
        self._ehits = 0
        self._eacc = 0
        self.quota_trajectory: list[int] = []
        sketch = default_sketch(capacity, sample_factor=sample_factor,
                                seed=seed, counters_per_item=counters_per_item,
                                doorkeeper=doorkeeper, shards=shards,
                                stale_estimates=stale_admission)
        self.admission = TinyLFUAdmission(sketch)

    def __contains__(self, key):
        return key in self._window or key in self._main

    def _access(self, key) -> bool:
        t = self._t
        self._t += 1
        # stamps are globally unique across tables (window even, main odd)
        # so rebalance migration can never collide two entries on one stamp
        # — the device kernel uses the same mapping (see _one_access_flat)
        wst, mst = 2 * t, 2 * t + 1
        self.admission.record(key)
        mcap_rt = self.total - self.quota
        prot_rt = max(1, mcap_rt * self.prot_cap0 // max(1, self.main_cap0))
        hit = True
        if key in self._window:
            self._window[key] = wst
        elif key in self._main:
            e = self._main[key]
            if not e[0]:
                self._pcount += 1
            e[0], e[1] = True, mst
            if self._pcount > prot_rt:
                # demote the protected LRU back to probation MRU
                kd = min((k for k, v in self._main.items() if v[0]),
                         key=lambda k: self._main[k][1])
                self._main[kd] = [False, mst]
                self._pcount -= 1
        else:
            hit = False
            if len(self._window) >= self.quota:
                cand = min(self._window, key=self._window.get)
                del self._window[cand]
                self._window[key] = wst
                if len(self._main) < mcap_rt:
                    self._main[cand] = [False, mst]
                else:
                    victim = min(self._main, key=lambda k: tuple(self._main[k]))
                    if self.admission.admit(cand, victim):
                        self._pcount -= self._main.pop(victim)[0]
                        self._main[cand] = [False, mst]
            else:
                self._window[key] = wst
        self._ehits += hit
        self._eacc += 1
        if self._eacc == self.epoch_len:
            self._epoch_boundary()
        return hit

    def _epoch_boundary(self):
        # sharded: the merge_halve fold rides the climb epochs, before the
        # climb + rebalance — same order as the device scan body
        if self.shards > 1:
            self.admission.sketch.merge_halve()
        # record the quota that was IN EFFECT for the finished epoch (the
        # device scan emits the same pre-climb value next to epoch_hits)
        self.quota_trajectory.append(self.quota)
        nq, self._prev, self._dirn, self._delta, self._ewma, self._trend, \
            self._k = climb_update(self.climb, self._ehits, self._prev,
                                   self._dirn, self._delta, self._ewma,
                                   self._trend, self._k, self.quota)
        self._rebalance(nq)
        self._ehits = 0
        self._eacc = 0

    def _rebalance(self, nq: int):
        """Host mirror of the device epoch rebalance (_rebalance_flat)."""
        mcap_new = self.total - nq
        n_wev = max(0, len(self._window) - nq)
        if n_wev:
            victims = sorted(self._window, key=self._window.get)[:n_wev]
            room = max(0, mcap_new - len(self._main))
            for kx in sorted(victims, key=self._window.get,
                             reverse=True)[:room]:
                self._main[kx] = [False, self._window[kx]]
            for kx in victims:
                del self._window[kx]
        n_mev = max(0, len(self._main) - mcap_new)
        if n_mev:
            for kx in sorted(self._main,
                             key=lambda k: tuple(self._main[k]))[:n_mev]:
                self._pcount -= self._main.pop(kx)[0]
        self.quota = nq
