"""W-TinyLFU (paper §4, Fig 5): LRU window cache (no admission) in front of an
SLRU main cache guarded by TinyLFU admission.

Flow per access:
  * hit in window or main -> hit (window hit refreshes window LRU; main hit
    follows SLRU promotion).
  * miss -> insert into window.  If the window overflows, its LRU victim asks
    for admission into the main cache; on rejection the window victim is
    dropped (it *is* W-TinyLFU's victim), on admission the main cache's SLRU
    victim is dropped instead.

Caffeine 2.0 defaults: window = 1% of total capacity, main = 99% with an
80/20 protected/probation SLRU split.
"""
from __future__ import annotations

from collections import OrderedDict

from .policies import SLRUEviction, ReplacementPolicy
from .sketch import default_sketch
from .tinylfu import TinyLFUAdmission


class WTinyLFU(ReplacementPolicy):
    name = "w-tinylfu"

    def __init__(self, capacity: int, window_frac: float = 0.01,
                 sample_factor: int = 8, protected_frac: float = 0.8,
                 seed: int = 0, counters_per_item: float = 1.0,
                 doorkeeper: bool = True):
        super().__init__(capacity)
        self.window_cap = max(1, int(round(capacity * window_frac)))
        self.main_cap = max(1, capacity - self.window_cap)
        self.window: OrderedDict = OrderedDict()
        self.main = SLRUEviction(self.main_cap, protected_frac=protected_frac)
        sketch = default_sketch(capacity, sample_factor=sample_factor,
                                seed=seed, counters_per_item=counters_per_item,
                                doorkeeper=doorkeeper)
        self.admission = TinyLFUAdmission(sketch)

    def __contains__(self, key):
        return key in self.window or key in self.main

    def _access(self, key) -> bool:
        self.admission.record(key)
        if key in self.window:
            self.window.move_to_end(key)
            return True
        if key in self.main:
            self.main.on_hit(key)
            return True
        # miss: admit to window unconditionally
        self.window[key] = None
        if len(self.window) > self.window_cap:
            cand, _ = self.window.popitem(last=False)
            if len(self.main) < self.main.capacity:
                self.main.add(cand)
            else:
                victim = self.main.peek_victim()
                if self.admission.admit(cand, victim):
                    self.main.remove(victim)
                    self.main.add(cand)
        return False
