"""W-TinyLFU (paper §4, Fig 5): LRU window cache (no admission) in front of an
SLRU main cache guarded by TinyLFU admission.

Flow per access:
  * hit in window or main -> hit (window hit refreshes window LRU; main hit
    follows SLRU promotion).
  * miss -> insert into window.  If the window overflows, its LRU victim asks
    for admission into the main cache; on rejection the window victim is
    dropped (it *is* W-TinyLFU's victim), on admission the main cache's SLRU
    victim is dropped instead.

Caffeine 2.0 defaults: window = 1% of total capacity, main = 99% with an
80/20 protected/probation SLRU split.

``assoc=W`` switches both tables to the set-associative layout — a host twin
of the device engine's O(ways) tables (kernels/sketch_step.py): the window
becomes per-set LRU and the main cache a ``SetAssociativeSLRU``
(power-of-two-choices placement, per-set protected budgets).  With
collision-free sketches the assoc host and device engines produce identical
per-access hit sequences (tests/test_sketch_step.py pins this).
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

from .hashing import slots_for, set_ways, set_index32_np, WSET_SALT
from .policies import SLRUEviction, SetAssociativeSLRU, ReplacementPolicy
from .sketch import default_sketch
from .tinylfu import TinyLFUAdmission


class WTinyLFU(ReplacementPolicy):
    name = "w-tinylfu"

    def __init__(self, capacity: int, window_frac: float = 0.01,
                 sample_factor: int = 8, protected_frac: float = 0.8,
                 seed: int = 0, counters_per_item: float = 1.0,
                 doorkeeper: bool = True, assoc: int | None = None):
        super().__init__(capacity)
        self.window_cap = max(1, int(round(capacity * window_frac)))
        self.main_cap = max(1, capacity - self.window_cap)
        self.assoc = assoc
        if assoc is None:
            self.window: OrderedDict = OrderedDict()
            self.main = SLRUEviction(self.main_cap,
                                     protected_frac=protected_frac)
        else:
            self.main = SetAssociativeSLRU(self.main_cap, assoc=assoc,
                                           protected_frac=protected_frac)
            # the window shares the main table's static ways (one block
            # shape on device); per-set LRU over pow2 window sets
            ways = self.main.ways
            self._n_wsets = slots_for(self.window_cap, ways) // ways
            self._wusable = set_ways(self.window_cap, self._n_wsets)
            self._wsets = [OrderedDict() for _ in range(self._n_wsets)]
            self._wset_memo: dict = {}
            self._t = 0                    # device-matching LRU stamp
        sketch = default_sketch(capacity, sample_factor=sample_factor,
                                seed=seed, counters_per_item=counters_per_item,
                                doorkeeper=doorkeeper)
        self.admission = TinyLFUAdmission(sketch)

    def __contains__(self, key):
        if self.assoc is None:
            return key in self.window or key in self.main
        return (key in self._wsets[self._wset_of(key)]
                or key in self.main)

    _WSET_MEMO_LIMIT = 2_000_000      # hash memo safety valve (scan traces)

    def _wset_of(self, key) -> int:
        s = self._wset_memo.get(key)
        if s is None:
            s = int(set_index32_np(np.asarray([key], np.uint64),
                                   self._n_wsets, WSET_SALT)[0])
            if len(self._wset_memo) >= self._WSET_MEMO_LIMIT:
                self._wset_memo.clear()
            self._wset_memo[key] = s
        return s

    def _access(self, key) -> bool:
        if self.assoc is not None:
            return self._access_assoc(key)
        self.admission.record(key)
        if key in self.window:
            self.window.move_to_end(key)
            return True
        if key in self.main:
            self.main.on_hit(key)
            return True
        # miss: admit to window unconditionally
        self.window[key] = None
        if len(self.window) > self.window_cap:
            cand, _ = self.window.popitem(last=False)
            if len(self.main) < self.main.capacity:
                self.main.add(cand)
            else:
                victim = self.main.peek_victim()
                if self.admission.admit(cand, victim):
                    self.main.remove(victim)
                    self.main.add(cand)
        return False

    def _access_assoc(self, key) -> bool:
        """Set-associative twin of the device `_one_access_set` step."""
        t = self._t
        self._t += 1
        self.admission.record(key)
        wset = self._wsets[self._wset_of(key)]
        if key in wset:
            wset.move_to_end(key)          # refresh = stamp t (order only)
            return True
        if key in self.main:
            self.main.on_hit(key, t)
            return True
        # miss: insert into the key's window set; per-set LRU overflow
        # displaces a candidate toward the main table
        wset[key] = None
        if len(wset) > self._wusable[self._wset_of(key)]:
            cand, _ = wset.popitem(last=False)
            vset, victim = self.main.victim_for(cand)
            if victim is None:             # free way in a choice set
                self.main.insert(cand, vset, t)
            elif self.admission.admit(cand, victim):
                self.main.remove(victim)
                self.main.insert(cand, vset, t)
        return False
