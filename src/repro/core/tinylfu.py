"""TinyLFU admission policy (paper §3) — host-side object composing with any
``Eviction`` through ``core.policies.Cache``."""
from __future__ import annotations

from typing import Callable, Optional

from .sketch import FrequencySketch, default_sketch


class TinyLFUAdmission:
    """record() on every access; admit(candidate, victim) compares frequency
    estimates — the newcomer must be strictly more frequent to displace the
    victim (ties keep the incumbent, which resists one-hit-wonder pollution).

    ``on_reset`` supports §3.6: an LFU eviction synchronizes its internal
    counters with the sketch's halving.
    """

    def __init__(self, sketch: FrequencySketch,
                 on_reset: Optional[Callable[[], None]] = None):
        self.sketch = sketch
        self.on_reset = on_reset
        self._seen_resets = sketch.resets
        self.admitted = 0
        self.rejected = 0

    def record(self, key) -> None:
        self.sketch.add(key)
        if self.sketch.resets != self._seen_resets:
            self._seen_resets = self.sketch.resets
            if self.on_reset is not None:
                self.on_reset()

    def admit(self, candidate, victim) -> bool:
        ok = self.sketch.estimate(candidate) > self.sketch.estimate(victim)
        if ok: self.admitted += 1
        else: self.rejected += 1
        return ok


class SketchLFUEviction:
    """LFU eviction ordered by the TinyLFU sketch's estimates (§3.6: the LFU
    cache is synchronized with the sketch — counters age via the same reset).
    Items are (re)prioritized with the sketch estimate on insert and on hit,
    so the victim is the cached item the *sketch* believes least frequent."""
    name = "lfu"

    def __init__(self, capacity: int, sketch: FrequencySketch):
        from .policies import LFUEviction
        self._lfu = LFUEviction(capacity)
        self.sketch = sketch
        self.capacity = capacity

    def __contains__(self, key): return key in self._lfu
    def __len__(self): return len(self._lfu)
    def keys(self): return self._lfu.keys()
    def remove(self, key): self._lfu.remove(key)
    def peek_victim(self): return self._lfu.peek_victim()

    def _estimate(self, key) -> int:
        return max(1, self.sketch.estimate(key))

    def on_hit(self, key): self._lfu._bump(key, self._estimate(key))
    def add(self, key): self._lfu._bump(key, self._estimate(key))

    def halve_all(self):
        self._lfu.halve_all()


def tinylfu_cache(capacity: int, eviction: str = "lru", sample_factor: int = 8,
                  seed: int = 0, counters_per_item: float = 2.0,
                  doorkeeper: bool = True):
    """Factory for the paper's augmented caches: T-LRU / T-Random / T-LFU /
    T-FIFO / T-SLRU."""
    from . import policies as P

    sketch = default_sketch(capacity, sample_factor=sample_factor, seed=seed,
                            counters_per_item=counters_per_item,
                            doorkeeper=doorkeeper)
    ev: P.Eviction
    if eviction == "lru":
        ev = P.LRUEviction(capacity)
    elif eviction == "random":
        ev = P.RandomEviction(capacity, seed=seed)
    elif eviction == "fifo":
        ev = P.FIFOEviction(capacity)
    elif eviction == "slru":
        ev = P.SLRUEviction(capacity)
    elif eviction == "lfu":
        ev = SketchLFUEviction(capacity, sketch)
    else:
        raise ValueError(f"unknown eviction {eviction!r}")
    adm = TinyLFUAdmission(
        sketch, on_reset=(ev.halve_all if eviction == "lfu" else None))
    return P.Cache(ev, adm)
