"""Fault-injection harness for the device engine (ISSUE 7).

Three fault families, matched to the recovery mechanisms they exercise:

* **process death** — :func:`run_to_kill` launches a checkpointing run as a
  subprocess and SIGKILLs it after it reports k checkpoints; the test then
  calls ``core.device_simulate.resume_trace`` in-parent and pins the resumed
  run bit-identical to an uninterrupted one.  Checkpoints are written
  atomically (``checkpoint.store``: tmp + fsync + rename), so a kill at any
  instant leaves at most a torn ``.tmp`` that ``latest_step`` ignores.
* **lost delta** — :func:`drop_shard_delta` zeroes one shard's delta slices,
  modelling a device that missed an epoch's exchange in
  ``mesh_exchange="stale"`` mode.  CM-sketch counts are a sampled estimate;
  dropping one shard-epoch of increments degrades the estimate, it does not
  corrupt it, so hit ratio stays within goldens tolerance.
* **corrupted words** — :func:`flip_words` XOR-flips bits in a state buffer.
  Flips in the global sketch halves are caught by the per-shard checksums
  (``StepSpec.integrity``) and the shard is quarantined at the next merge
  boundary; flips in cache-table words exercise crash-free degradation.

All mutators are pure: they take the CANONICAL (single-device) state layout
that ``DeviceWTinyLFU.run(..., fault_hook=...)`` passes and return a new
dict, leaving the input untouched.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import time
from typing import Optional

import numpy as np

from repro.kernels.sketch_step import StepSpec


def run_to_kill(script: str, *, marker: str = "CKPT", kills: int = 2,
                timeout: float = 600.0, env: Optional[dict] = None,
                python: Optional[str] = None):
    """Run ``script`` (python source) as a subprocess and SIGKILL it after
    it has printed ``marker`` ``kills`` times on stdout.

    The script is expected to print one marker line per completed
    checkpoint (``on_checkpoint=lambda c: print("CKPT", c, flush=True)``),
    so the kill lands mid-run with at least one durable checkpoint behind
    it.  Returns ``(markers_seen, returncode)``; a SIGKILLed child reports
    ``-signal.SIGKILL``.  If the script finishes before ``kills`` markers
    appear the (successful) return code is surfaced so the test can fail
    with the real exit status instead of hanging.
    """
    proc = subprocess.Popen(
        [python or sys.executable, "-c", script],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env={**os.environ, **(env or {})})
    seen = 0
    deadline = time.monotonic() + timeout
    try:
        for line in proc.stdout:
            if time.monotonic() > deadline:
                raise TimeoutError(f"run_to_kill: no {kills} markers within "
                                   f"{timeout}s; output so far: {line!r}")
            if line.startswith(marker):
                seen += 1
                if seen >= kills:
                    proc.kill()
                    break
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()
    return seen, proc.returncode


def flip_words(state: dict, key: str, flips) -> dict:
    """XOR single bits into ``state[key]`` (canonical layout).

    ``flips``: iterable of ``(flat_index, bit)`` pairs, bit in [0, 32).
    Returns a new state dict (numpy copy for the mutated buffer).
    """
    arr = np.array(state[key], copy=True)
    flat = arr.reshape(-1)
    view = flat.view(np.uint32)
    for idx, bit in flips:
        view[idx] ^= np.uint32(1) << np.uint32(bit)
    return {**state, key: arr}


def drop_shard_delta(spec: StepSpec, state: dict, shard: int,
                     half: str = "delta") -> dict:
    """Zero shard ``shard``'s counter- and doorkeeper slices in a
    canonical-layout sharded state.

    ``half="delta"`` models one device's epoch of increments lost before
    the merge fold (meaningful only on MID-epoch state — at boundaries the
    fold has just cleared the deltas).  ``half="global"`` models the
    strictly-worse loss of the shard's whole accumulated estimate — a
    device that missed every past exchange — which is what the
    boundary-time ``fault_hook`` injects for the stale-exchange drills.
    ``half="both"`` combines them.
    """
    assert spec.shards > 1 and 0 <= shard < spec.shards
    assert half in ("delta", "global", "both")
    H, wps = spec.counter_words, spec.wps_shard
    halves = (0, 1) if half == "both" else ((1,) if half == "delta" else (0,))
    c = np.array(state["counters"], copy=True)
    for h in halves:
        c[h * H:(h + 1) * H].reshape(
            spec.rows, spec.shards, wps)[:, shard, :] = 0
    out = {**state, "counters": c}
    if spec.dk_bits:
        HD = spec.dk_words
        dk = np.array(state["doorkeeper"], copy=True)
        for h in halves:
            dk[h * HD:(h + 1) * HD].reshape(
                spec.shards, spec.dkw_shard)[shard, :] = 0
        out["doorkeeper"] = dk
    return out
