"""Host-side TinyLFU frequency sketch (paper §3).

``FrequencySketch`` = Minimal-Increment (conservative update) counting
structure + Doorkeeper Bloom filter + reset/aging, exactly the paper's
architecture:

* counting layout is configurable between the paper's prototype (Counting
  Bloom Filter: one table, k probes) and Caffeine's CM-sketch (d rows, one
  probe each).  Both use conservative update.
* counters saturate at ``cap`` = W/C (the paper's "small counters", §3.4.1).
* after ``sample_size`` (W) additions, every counter is halved and the
  doorkeeper is cleared (§3.3 reset; §3.4.2 doorkeeper reset).

This is the oracle for the Pallas kernels (see kernels/ref.py for the
functional-jnp twin), and the engine used by the trace simulators.

Performance: the hot path is pure Python (no per-access numpy calls) with
memoized probe indices — ~2-4 µs/access, fast enough for the multi-million
access paper benchmarks.  Default sizing follows the paper's accuracy knee
(Fig 22): ≥ ~1.25 bytes of metadata per sample element.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

_MASK64 = (1 << 64) - 1
_SM64_GAMMA = 0x9E3779B97F4A7C15
_SM64_M1 = 0xBF58476D1CE4E5B9
_SM64_M2 = 0x94D049BB133111EB
_SEED_STEP = 0xC2B2AE3D27D4EB4F


def _splitmix64_py(x: int) -> int:
    x = (x + _SM64_GAMMA) & _MASK64
    x = ((x ^ (x >> 30)) * _SM64_M1) & _MASK64
    x = ((x ^ (x >> 27)) * _SM64_M2) & _MASK64
    return x ^ (x >> 31)


def _pow2ceil(x: int) -> int:
    return 1 << max(0, (int(x) - 1)).bit_length()


@dataclass
class SketchConfig:
    sample_size: int                      # W — reset period
    counters: int                         # total number of counters (all rows)
    rows: int = 4                         # d rows (CM layout); 1 => CBF layout
    probes_per_row: int = 1               # CBF layout: rows=1, probes=k
    cap: int = 15                         # small-counter saturation (W/C)
    doorkeeper_bits: int = 0              # 0 disables the doorkeeper
    doorkeeper_probes: int = 3
    conservative: bool = True             # minimal-increment update
    seed: int = 0

    @property
    def width(self) -> int:               # counters per row
        return max(1, self.counters // self.rows)

    def meta_bits(self) -> int:
        """Total metadata footprint in bits (for Fig 4 style accounting)."""
        bits_per_counter = max(1, int(self.cap).bit_length())
        return self.rows * self.width * bits_per_counter + self.doorkeeper_bits


class FrequencySketch:
    """TinyLFU histogram: estimate()/add()/reset(), paper §3."""

    _MEMO_LIMIT = 2_000_000               # probe memo safety valve (scan traces)

    def __init__(self, cfg: SketchConfig):
        self.cfg = cfg
        n_probes = cfg.rows * cfg.probes_per_row
        # flat table, row-major; probes carry precomputed row offsets
        self.table = [0] * (cfg.rows * cfg.width)
        self.dk = bytearray(cfg.doorkeeper_bits) if cfg.doorkeeper_bits else None
        self.size = 0                      # additions since last reset
        self.resets = 0
        self._memo: dict = {}
        self._dk_memo: dict = {}
        w = cfg.width
        if cfg.rows == 1:
            self._row_off = [0] * n_probes
        else:
            self._row_off = [r * w for r in range(cfg.rows)
                             for _ in range(cfg.probes_per_row)]
        self._probe_seeds = [((i + 1) * _SEED_STEP + cfg.seed) & _MASK64
                             for i in range(n_probes)]
        self._dk_seeds = [((i + 1) * _SEED_STEP + (cfg.seed ^ 0x5A5A)) & _MASK64
                          for i in range(cfg.doorkeeper_probes)]

    # -- hashing (memoized pure python) ---------------------------------------
    def _probes(self, key: int):
        p = self._memo.get(key)
        if p is None:
            w = self.cfg.width
            p = tuple(off + _splitmix64_py((key + s) & _MASK64) % w
                      for off, s in zip(self._row_off, self._probe_seeds))
            if len(self._memo) >= self._MEMO_LIMIT:
                self._memo.clear()
            self._memo[key] = p
        return p

    def _dk_probes(self, key: int):
        p = self._dk_memo.get(key)
        if p is None:
            nb = self.cfg.doorkeeper_bits
            p = tuple(_splitmix64_py((key + s) & _MASK64) % nb
                      for s in self._dk_seeds)
            if len(self._dk_memo) >= self._MEMO_LIMIT:
                self._dk_memo.clear()
            self._dk_memo[key] = p
        return p

    # -- doorkeeper ------------------------------------------------------------
    def _dk_contains(self, key: int) -> bool:
        dk = self.dk
        for i in self._dk_probes(key):
            if not dk[i]:
                return False
        return True

    def _dk_put(self, key: int) -> bool:
        """Insert; returns True if the key was already present."""
        dk = self.dk
        present = True
        for i in self._dk_probes(key):
            if not dk[i]:
                present = False
                dk[i] = 1
        return present

    # -- main structure ---------------------------------------------------------
    def _table_estimate(self, key: int) -> int:
        t = self.table
        return min(t[i] for i in self._probes(key))

    def _table_add(self, key: int) -> None:
        t = self.table
        idx = self._probes(key)
        vals = [t[i] for i in idx]
        m = min(vals)
        if m >= self.cfg.cap:
            return
        if self.cfg.conservative:
            m1 = m + 1
            for i, v in zip(idx, vals):    # minimal increment: bump only minima
                if v == m:
                    t[i] = m1
        else:
            cap = self.cfg.cap
            for i, v in zip(idx, vals):
                if v < cap:
                    t[i] = v + 1

    # -- public api (paper semantics) --------------------------------------------
    def estimate(self, key: int) -> int:
        est = self._table_estimate(key)
        if self.dk is not None and self._dk_contains(key):
            est += 1
        return est

    def add(self, key: int) -> None:
        if self.dk is not None:
            if self._dk_put(key):
                self._table_add(key)       # repeat visitor: count in main
            # else: first timer absorbed by the doorkeeper (1-bit counter)
        else:
            self._table_add(key)
        self.size += 1
        if self.size >= self.cfg.sample_size:
            self.reset()

    def reset(self) -> None:
        """Paper §3.3: halve all counters (integer division), clear doorkeeper,
        halve the sample counter."""
        self.table = [v >> 1 for v in self.table]
        if self.dk is not None:
            for i in range(len(self.dk)):
                self.dk[i] = 0
        self.size //= 2
        self.resets += 1

    # numpy view for tests / kernels parity checks
    def table_array(self) -> np.ndarray:
        return np.asarray(self.table, dtype=np.int64).reshape(
            self.cfg.rows, self.cfg.width)


class ShardedFrequencySketch:
    """Sharded TinyLFU histogram — host twin of the device engine's
    ``StepSpec.shards`` mode (kernels/sketch_step.py + sketch_merge.py).

    The counting address space is partitioned into ``shards`` slices: a key
    owns one shard (splitmix64 shard hash) and all of its probes are
    confined to that shard's ``width/shards``-counter (and
    ``doorkeeper_bits/shards``-bit) slice.  Writes accumulate in shard-local
    *delta* structures; reads compose the merged *global* estimate with the
    delta; :meth:`merge_halve` — called by the owning policy every merge
    epoch, mirroring the device's fused epoch-boundary fold — adds the
    deltas into the global (CM-sketch linear merge, saturating at ``cap``)
    and applies the paper's §3.3 aging as many halvings as the accumulated
    sample size demands.  Between merges the combined global+delta evolves
    exactly like an unsharded :class:`FrequencySketch`; only the reset
    timing differs (deferred to merge boundaries), which is what the device
    parity tests pin.

    Unlike :class:`FrequencySketch`, :meth:`add` never resets on its own —
    aging belongs to :meth:`merge_halve`.

    ``stale_estimates=True`` makes :meth:`estimate` read ONLY the merged
    global structures (stale by at most one merge epoch), ignoring the
    un-merged deltas — the host twin of the device mesh runner's
    speculative ``mesh_exchange="stale"`` admission
    (``kernels.sketch_step._estimate_pair_stale``), whose per-access path
    is collective-free because estimates never touch another device's
    delta.  :meth:`add` still writes the deltas and reads global+delta for
    the conservative-update minimum, exactly like the device's
    ``_sketch_add_mesh``.
    """

    _MEMO_LIMIT = 2_000_000               # probe memo safety valve

    def __init__(self, cfg: SketchConfig, shards: int,
                 stale_estimates: bool = False):
        assert shards >= 2 and shards & (shards - 1) == 0, \
            f"shards {shards} must be a power of two >= 2"
        assert cfg.width % shards == 0, \
            f"width {cfg.width} must be a multiple of shards ({shards})"
        if cfg.doorkeeper_bits:
            assert cfg.doorkeeper_bits % shards == 0
        assert cfg.conservative, "sharded sketch is conservative-update only"
        self.cfg = cfg
        self.shards = shards
        self.stale_estimates = stale_estimates
        self.width_shard = cfg.width // shards
        self.dk_bits_shard = cfg.doorkeeper_bits // shards
        n_probes = cfg.rows * cfg.probes_per_row
        self.gtable = [0] * (cfg.rows * cfg.width)    # merged global
        self.dtable = [0] * (cfg.rows * cfg.width)    # shard-local deltas
        if cfg.doorkeeper_bits:
            self.gdk = bytearray(cfg.doorkeeper_bits)
            self.ddk = bytearray(cfg.doorkeeper_bits)
        else:
            self.gdk = self.ddk = None
        self.size = 0                      # additions since last §3.3 reset
        self.resets = 0
        self.merges = 0
        self._memo: dict = {}
        self._dk_memo: dict = {}
        w = cfg.width
        if cfg.rows == 1:
            self._row_off = [0] * n_probes
        else:
            self._row_off = [r * w for r in range(cfg.rows)
                             for _ in range(cfg.probes_per_row)]
        self._probe_seeds = [((i + 1) * _SEED_STEP + cfg.seed) & _MASK64
                             for i in range(n_probes)]
        self._dk_seeds = [((i + 1) * _SEED_STEP + (cfg.seed ^ 0x5A5A))
                          & _MASK64 for i in range(cfg.doorkeeper_probes)]

    # -- hashing (memoized; probes confined to the owning shard's slice) -----
    def _shard_of(self, key: int) -> int:
        from .hashing import SHARD_SEED64
        return _splitmix64_py((key + SHARD_SEED64) & _MASK64) % self.shards

    def _probes(self, key: int):
        p = self._memo.get(key)
        if p is None:
            base = self._shard_of(key) * self.width_shard
            ws = self.width_shard
            p = tuple(off + base + _splitmix64_py((key + s) & _MASK64) % ws
                      for off, s in zip(self._row_off, self._probe_seeds))
            if len(self._memo) >= self._MEMO_LIMIT:
                self._memo.clear()
            self._memo[key] = p
        return p

    def _dk_probes(self, key: int):
        p = self._dk_memo.get(key)
        if p is None:
            base = self._shard_of(key) * self.dk_bits_shard
            nb = self.dk_bits_shard
            p = tuple(base + _splitmix64_py((key + s) & _MASK64) % nb
                      for s in self._dk_seeds)
            if len(self._dk_memo) >= self._MEMO_LIMIT:
                self._dk_memo.clear()
            self._dk_memo[key] = p
        return p

    # -- public api (FrequencySketch-compatible, minus the auto reset) -------
    def add(self, key: int) -> None:
        if self.gdk is not None:
            present = True
            gdk, ddk = self.gdk, self.ddk
            for i in self._dk_probes(key):
                if not (gdk[i] or ddk[i]):
                    present = False
                    ddk[i] = 1
            if not present:                # first timer: doorkeeper absorbs
                self.size += 1
                return
        g, d = self.gtable, self.dtable
        idx = self._probes(key)
        vals = [g[i] + d[i] for i in idx]
        m = min(vals)
        if m < self.cfg.cap:               # combined count caps like the
            for i, v in zip(idx, vals):    # unsharded sketch; bump the delta
                if v == m:
                    d[i] += 1
        self.size += 1

    def estimate(self, key: int) -> int:
        g, d = self.gtable, self.dtable
        if self.stale_estimates:           # global-only: <= one epoch stale
            est = min(g[i] for i in self._probes(key))
            if self.gdk is not None:
                gdk = self.gdk
                if all(gdk[i] for i in self._dk_probes(key)):
                    est += 1
            return est
        est = min(g[i] + d[i] for i in self._probes(key))
        if self.gdk is not None:
            gdk, ddk = self.gdk, self.ddk
            if all(gdk[i] or ddk[i] for i in self._dk_probes(key)):
                est += 1
        return est

    def merge_halve(self) -> None:
        """Fold the shard deltas into the global estimate (saturating CM
        merge) and apply the deferred §3.3 aging — the host mirror of
        ``kernels.sketch_merge.merge_halve``, bit-for-bit including the
        merge-first halve-second order and the multi-halving catch-up."""
        cap = self.cfg.cap
        self.gtable = [min(g + d, cap)
                       for g, d in zip(self.gtable, self.dtable)]
        self.dtable = [0] * len(self.dtable)
        if self.gdk is not None:
            gdk, ddk = self.gdk, self.ddk
            for i in range(len(gdk)):
                if ddk[i]:
                    gdk[i] = 1
            self.ddk = bytearray(len(ddk))
        k = 0
        while self.cfg.sample_size > 0 and self.size >= self.cfg.sample_size:
            self.size //= 2
            k += 1
        if k:
            self.gtable = [v >> k for v in self.gtable]
            if self.gdk is not None:
                self.gdk = bytearray(len(self.gdk))
            self.resets += k
        self.merges += 1

    # numpy view (merged global + delta) for tests / parity checks
    def table_array(self) -> np.ndarray:
        merged = [g + d for g, d in zip(self.gtable, self.dtable)]
        return np.asarray(merged, dtype=np.int64).reshape(
            self.cfg.rows, self.cfg.width)


class ExactHistogram:
    """Accurate TinyLFU: per-key exact counters (hash table), same reset
    semantics.  ``integer_division=False`` gives the floating-point reset used
    to isolate the truncation error in Fig 22."""

    def __init__(self, sample_size: int, cap: float | None = None,
                 integer_division: bool = True):
        self.sample_size = sample_size
        self.cap = cap
        self.integer_division = integer_division
        self.counts: dict[int, float] = {}
        self.size = 0
        self.resets = 0

    def estimate(self, key: int) -> float:
        return self.counts.get(key, 0)

    def add(self, key: int) -> None:
        c = self.counts.get(key, 0) + 1
        if self.cap is None or c <= self.cap:
            self.counts[key] = c
        self.size += 1
        if self.size >= self.sample_size:
            self.reset()

    def reset(self) -> None:
        if self.integer_division:
            self.counts = {k: v // 2 for k, v in self.counts.items() if v >= 2}
        else:
            self.counts = {k: v / 2 for k, v in self.counts.items()}
        self.size //= 2
        self.resets += 1


def default_sketch(cache_size: int, sample_factor: int = 8,
                   counters_per_item: float = 2.0, rows: int = 4,
                   doorkeeper: bool = True, dk_bits_per_item: float = 4.0,
                   seed: int = 0, shards: int = 1,
                   stale_estimates: bool = False):
    """Sizing rule used throughout the benchmarks.

    Defaults land at ~1.5 bytes of metadata per sample element (4-bit main
    counters x2/elem + 4 doorkeeper bits/elem), just above the paper's Fig 22
    accuracy knee (~1.25 B/elem), so the approximate sketch matches the exact
    histogram's hit ratio.  cap = W/C with the doorkeeper absorbing one count.

    ``shards > 1`` returns the sharded twin (:class:`ShardedFrequencySketch`,
    same total footprint, shard-partitioned): the owning policy must then
    drive :meth:`~ShardedFrequencySketch.merge_halve` every merge epoch.
    ``stale_estimates=True`` (sharded only) selects the global-only reads
    of the speculative stale-global admission mode.
    """
    sample = sample_factor * cache_size
    cap = max(1, sample_factor - (1 if doorkeeper else 0))
    counters = rows * _pow2ceil(max(1.0, counters_per_item * sample / rows))
    width = max(shards, counters // rows)
    dk_bits = 0
    if doorkeeper:
        dk_bits = max(32 * shards, _pow2ceil(sample * dk_bits_per_item))
    cfg = SketchConfig(
        sample_size=sample,
        counters=rows * width,
        rows=rows,
        cap=cap,
        doorkeeper_bits=dk_bits,
        seed=seed,
    )
    if shards > 1:
        return ShardedFrequencySketch(cfg, shards,
                                      stale_estimates=stale_estimates)
    if stale_estimates:
        raise ValueError("stale_estimates requires shards > 1 (an unsharded "
                         "sketch has no delta to be stale against)")
    return FrequencySketch(cfg)
