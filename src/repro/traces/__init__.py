from .synthetic import (
    zipf_trace, zipf_probs, youtube_dynamic_trace, wiki_drift_trace,
    spc1_like_trace, oltp_like_trace, glimpse_trace, multi_tenant_prompt_trace,
    fickle_churn_trace, phase_shift_trace, tenant_lanes_trace, panel_traces,
)

__all__ = [
    "zipf_trace", "zipf_probs", "youtube_dynamic_trace", "wiki_drift_trace",
    "spc1_like_trace", "oltp_like_trace", "glimpse_trace",
    "multi_tenant_prompt_trace", "fickle_churn_trace",
    "phase_shift_trace", "tenant_lanes_trace", "panel_traces",
]
