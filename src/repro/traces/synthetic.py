"""Synthetic trace generators matching the structural families of the paper's
workloads (§5.1).  The container is offline, so the real traces (Wikipedia,
UMass F1/F2/WS*, ARC's DS1/S3/P8/P12/OLTP/SPC1, LIRS' Glimpse) are modeled by
generators parameterized from the published descriptions:

* **zipf**        — static Zipf(α) over n items (paper's synthetic workloads).
* **youtube**     — weekly re-drawn Zipf-like popularity with item churn [12].
* **wiki-drift**  — Zipf with slowly wandering rank permutation [55].
* **spc1-like**   — long ascending sequential scans + random zipf hot set [44].
* **oltp-like**   — ascending log-append stream (mostly once-accessed) mixed
                    with zipf random page reads; "sparse bursts" [44]/§4.
* **glimpse**     — large loop (> cache) + random accesses [36].
* **multi-tenant prompts** — our serving workload: prefix-block access stream
  from T tenants with zipf tenant popularity and per-tenant shared prefixes.

All generators are deterministic given ``seed`` and return int64 key arrays.
"""
from __future__ import annotations

import numpy as np


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
def zipf_probs(n_items: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, n_items + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    return w / w.sum()


def _sample_from_probs(probs: np.ndarray, length: int,
                       rng: np.random.Generator) -> np.ndarray:
    cdf = np.cumsum(probs)
    cdf[-1] = 1.0
    u = rng.random(length)
    return np.searchsorted(cdf, u, side="right").astype(np.int64)


def zipf_trace(length: int, n_items: int = 1_000_000, alpha: float = 0.9,
               seed: int = 0) -> np.ndarray:
    """Static Zipf trace; ranks are shuffled into arbitrary key ids so rank
    order is not correlated with key order."""
    rng = _rng(seed)
    ranks = _sample_from_probs(zipf_probs(n_items, alpha), length, rng)
    perm = rng.permutation(n_items).astype(np.int64)
    return perm[ranks]


# ---------------------------------------------------------------------------
def youtube_dynamic_trace(length: int, weeks: int = 21,
                          items_per_week: int = 8000, alpha: float = 0.9,
                          churn: float = 0.4, seed: int = 0) -> np.ndarray:
    """Weekly popularity snapshots (paper §5.2 [12]): every week, a fraction
    ``churn`` of the active set is replaced by brand-new videos and ranks are
    re-drawn; accesses within a week are i.i.d. from that week's Zipf."""
    rng = _rng(seed)
    per_week = length // weeks
    probs = zipf_probs(items_per_week, alpha)
    active = np.arange(items_per_week, dtype=np.int64)
    next_id = items_per_week
    out = np.empty(weeks * per_week, dtype=np.int64)
    for w in range(weeks):
        if w > 0:
            n_new = int(items_per_week * churn)
            repl = rng.choice(items_per_week, size=n_new, replace=False)
            active = active.copy()
            active[repl] = np.arange(next_id, next_id + n_new)
            next_id += n_new
            rng.shuffle(active)          # fresh rank assignment each week
        idx = _sample_from_probs(probs, per_week, rng)
        out[w * per_week:(w + 1) * per_week] = active[idx]
    return out


# ---------------------------------------------------------------------------
def wiki_drift_trace(length: int, n_items: int = 400_000, alpha: float = 0.9,
                     drift_every: int = 20_000, drift_frac: float = 0.02,
                     seed: int = 0) -> np.ndarray:
    """Gradually changing Zipf (paper's Wikipedia trace behaviour): every
    ``drift_every`` accesses, ``drift_frac`` of items swap ranks."""
    rng = _rng(seed)
    probs = zipf_probs(n_items, alpha)
    perm = rng.permutation(n_items).astype(np.int64)
    out = np.empty(length, dtype=np.int64)
    pos = 0
    n_swap = max(2, int(n_items * drift_frac))
    while pos < length:
        chunk = min(drift_every, length - pos)
        idx = _sample_from_probs(probs, chunk, rng)
        out[pos:pos + chunk] = perm[idx]
        pos += chunk
        a = rng.choice(n_items, size=n_swap, replace=False)
        b = rng.choice(n_items, size=n_swap, replace=False)
        perm[a], perm[b] = perm[b].copy(), perm[a].copy()
    return out


# ---------------------------------------------------------------------------
def spc1_like_trace(length: int, n_random: int = 200_000, alpha: float = 1.0,
                    scan_frac: float = 0.55, mean_scan: int = 400,
                    scan_space: int = 4_000_000, seed: int = 0) -> np.ndarray:
    """SPC1-like [44]: interleave long ascending sequential scans over a huge
    address space (cache-polluting, never re-used) with zipf random I/O over a
    hot region.  Scan keys are offset above the random region."""
    rng = _rng(seed)
    probs = zipf_probs(n_random, alpha)
    out = np.empty(length, dtype=np.int64)
    pos = 0
    scan_ptr = 0
    while pos < length:
        if rng.random() < scan_frac:
            slen = min(int(rng.exponential(mean_scan)) + 16, length - pos)
            start = scan_ptr
            scan_ptr = (scan_ptr + slen) % scan_space
            seq = (np.arange(start, start + slen) % scan_space) + n_random
            out[pos:pos + slen] = seq
            pos += slen
        else:
            rlen = min(int(rng.exponential(mean_scan * 0.6)) + 8, length - pos)
            out[pos:pos + rlen] = _sample_from_probs(probs, rlen, rng)
            pos += rlen
    return out


# ---------------------------------------------------------------------------
def oltp_like_trace(length: int, n_pages: int = 100_000, alpha: float = 0.8,
                    log_frac: float = 0.6, burst: int = 4,
                    seed: int = 0) -> np.ndarray:
    """OLTP-like [44] (§5.1): "ascending lists of sequential block accesses
    sprinkled with a few random accesses" — a transaction log appends to
    ever-increasing block ids (each touched a handful of times in a short
    burst, then never again = the paper's 'sparse bursts'), plus zipf reads
    over the database pages."""
    rng = _rng(seed)
    probs = zipf_probs(n_pages, alpha)
    out = np.empty(length, dtype=np.int64)
    pos = 0
    log_ptr = 0
    while pos < length:
        if rng.random() < log_frac:
            # short ascending burst re-touching the current tail of the log
            blen = min(int(rng.integers(2, burst * 2)), length - pos)
            base = log_ptr
            log_ptr += max(1, blen // burst)
            seq = base + (np.arange(blen) % burst)
            out[pos:pos + blen] = seq + n_pages
            pos += blen
        else:
            rlen = min(int(rng.integers(1, 8)), length - pos)
            out[pos:pos + rlen] = _sample_from_probs(probs, rlen, rng)
            pos += rlen
    return out


# ---------------------------------------------------------------------------
def glimpse_trace(length: int, loop_items: int = 5000, n_random: int = 50_000,
                  alpha: float = 0.9, loop_frac: float = 0.65,
                  seed: int = 0) -> np.ndarray:
    """Glimpse [36]: an underlying loop over more items than the cache holds
    (LRU's pathological case) mixed with other accesses."""
    rng = _rng(seed)
    probs = zipf_probs(n_random, alpha)
    out = np.empty(length, dtype=np.int64)
    pos = 0
    lp = 0
    while pos < length:
        if rng.random() < loop_frac:
            slen = min(int(rng.integers(200, 2000)), length - pos)
            seq = (lp + np.arange(slen)) % loop_items
            lp = (lp + slen) % loop_items
            out[pos:pos + slen] = seq + n_random
            pos += slen
        else:
            rlen = min(int(rng.integers(50, 500)), length - pos)
            out[pos:pos + rlen] = _sample_from_probs(probs, rlen, rng)
            pos += rlen
    return out


# ---------------------------------------------------------------------------
def fickle_churn_trace(length: int, n_hot: int = 2000, alpha: float = 1.0,
                       hot_frac: float = 0.7, seed: int = 0) -> np.ndarray:
    """Adversarial frequency-skewed trace for window-size adaptation: a
    stable Zipf hot set interleaved with a stream of one-hit wonders (§2.3's
    "fickle" churn — every churn key is seen exactly once and never again).

    The best static window is the tiny default (~1%): every window slot
    beyond it just parks one-hit wonders that TinyLFU would have filtered,
    displacing hot-set capacity.  An adaptive window must climb DOWN (or
    stay down) on this trace.
    """
    rng = _rng(seed)
    hot = _sample_from_probs(zipf_probs(n_hot, alpha), length, rng)
    is_hot = rng.random(length) < hot_frac
    n_cold = int((~is_hot).sum())
    # one-hit wonders: fresh ids above the hot range, each seen once
    cold = n_hot + np.arange(n_cold, dtype=np.int64)
    out = np.empty(length, dtype=np.int64)
    out[is_hot] = hot[is_hot]
    out[~is_hot] = cold
    return out


# ---------------------------------------------------------------------------
def phase_shift_trace(length: int, n_hot: int = 2000, alpha: float = 0.9,
                      working_set: int = 1200, advance: float = 0.25,
                      seed: int = 0) -> np.ndarray:
    """Adversarial phase-shift trace: a stationary Zipf first half (small
    window + TinyLFU admission is near-optimal), then an abrupt switch to a
    pure recency pattern — accesses drawn uniformly from a working set of
    ``working_set`` keys that slides forward by ``advance`` keys per access
    over a fresh id range, so frequency counts never accumulate and LRU-like
    behaviour (a LARGE window) is the only way to hit.

    A static window loses one phase or the other; the paper's fixed 1%
    split loses the whole second half.  These are the two traces the
    runtime-adaptive engine must win on (ISSUE 3 acceptance).
    """
    rng = _rng(seed)
    h1 = length // 2
    first = _sample_from_probs(zipf_probs(n_hot, alpha), h1, rng)
    base = n_hot + (np.arange(length - h1) * advance).astype(np.int64)
    second = base + rng.integers(0, working_set, size=length - h1)
    return np.concatenate([first, second.astype(np.int64)])


# ---------------------------------------------------------------------------
def tenant_lanes_trace(streams: int, length: int, n_items: int = 10_000,
                       alpha: float = 0.9, tenant_alpha: float = 1.0,
                       drift_every: int = 0, seed: int = 0) -> np.ndarray:
    """Multi-tenant lane trace for the batched engine
    (``DeviceWTinyLFU(streams=B)``): a ``(streams, length)`` int64 key
    matrix, row b = tenant b's private access stream.

    Zipf-over-tenants × per-tenant Zipf keys: tenant popularity
    ``Zipf(tenant_alpha)`` over the lanes sets each tenant's working-set
    size — the rank-r tenant draws from a ``Zipf(alpha)`` over
    ``n_items / r^tenant_alpha`` keys (floor 64), so hot tenants
    concentrate reuse on small hot sets while tail tenants sprawl — the
    Zipf-of-Zipfs shape multi-tenant skew comparisons care about
    (arXiv:2503.02504).  Key ids are offset per lane into disjoint ranges
    (tenants never share keys, matching per-tenant isolated caches).

    ``drift_every > 0`` re-draws each lane's rank→key permutation every
    ``drift_every`` accesses with a per-lane PHASE OFFSET of
    ``b * drift_every / streams`` accesses, so tenant phase changes are
    staggered across lanes instead of synchronized — the worst case for
    any cross-tenant resource adaptation, and the pattern that makes
    per-lane climb trajectories genuinely diverge.
    """
    if streams < 1:
        raise ValueError(f"streams {streams} must be >= 1")
    rng = _rng(seed)
    tenant_rank = rng.permutation(streams) + 1        # rank 1 = hottest
    out = np.empty((streams, length), dtype=np.int64)
    for b in range(streams):
        nb = max(64, int(n_items / tenant_rank[b] ** tenant_alpha))
        probs = zipf_probs(nb, alpha)
        ranks = _sample_from_probs(probs, length, rng)
        perm = rng.permutation(nb).astype(np.int64)
        if drift_every and drift_every > 0:
            phase = (b * drift_every) // streams
            pos = 0
            while pos < length:
                nxt = min(length, pos + (drift_every - (pos + phase)
                                         % drift_every))
                out[b, pos:nxt] = perm[ranks[pos:nxt]]
                perm = rng.permutation(nb).astype(np.int64)
                pos = nxt
        else:
            out[b] = perm[ranks]
        out[b] += b * (n_items + 64)                  # disjoint id ranges
    return out


# ---------------------------------------------------------------------------
def multi_tenant_prompt_trace(n_requests: int, n_tenants: int = 200,
                              tenant_alpha: float = 1.0,
                              prefix_blocks_mean: int = 24,
                              suffix_blocks_mean: int = 6,
                              block_reuse_alpha: float = 0.8,
                              seed: int = 0) -> np.ndarray:
    """Serving workload: each request touches its tenant's shared prefix
    blocks (ids stable per tenant) then some per-request suffix blocks (mostly
    unique, occasionally re-used within the tenant).  Emits the block-id
    access stream seen by the prefix cache."""
    rng = _rng(seed)
    tprobs = zipf_probs(n_tenants, tenant_alpha)
    tenant_prefix_len = rng.poisson(prefix_blocks_mean, n_tenants) + 4
    # globally unique block id ranges per tenant
    prefix_base = np.concatenate([[0], np.cumsum(tenant_prefix_len)])[:-1]
    suffix_base = int(prefix_base[-1] + tenant_prefix_len[-1])
    next_suffix = suffix_base
    chunks = []
    tenants = _sample_from_probs(tprobs, n_requests, rng)
    for t in tenants:
        plen = tenant_prefix_len[t]
        chunks.append(prefix_base[t] + np.arange(plen))
        slen = rng.poisson(suffix_blocks_mean) + 1
        chunks.append(np.arange(next_suffix, next_suffix + slen))
        next_suffix += slen
    return np.concatenate(chunks).astype(np.int64)


# ---------------------------------------------------------------------------
def panel_traces(length: int = 60_000, seed: int = 0) -> dict:
    """Named trace families for the device policy panel (``StepSpec.policy``:
    W-TinyLFU vs S3-FIFO / ARC / sketch-LFU), each built to separate the
    policies along one axis:

    * ``"zipf"``     — stationary frequency skew: the TinyLFU-style
      admission filters (wtinylfu, lfu, s3fifo's one-hit-wonder gate)
      should lead; pure recency trails.
    * ``"scan-hot"`` — a one-pass sequential scan followed by a Zipf
      hotspot: scan resistance.  Admission-filtered policies and ARC's
      T1/T2 split keep the scan out of the hot working set.
    * ``"churn"``    — a stable hot set diluted by one-hit wonders
      (``fickle_churn_trace``): the workload S3-FIFO's quick-demotion
      small queue and the doorkeeper were designed for.
    * ``"loop"``     — a cyclic scan over a loop slightly larger than
      typical cache sizes plus uniform noise (``glimpse_trace``): the
      classic LRU-adversarial pattern; frequency retention wins.

    Returns ``{name: (length,) int64 trace}``; deterministic in ``seed``.
    The cross-policy golden tests (tests/test_policy_panel.py) pin hit
    ratios on these families.
    """
    half = length // 2
    scan = np.arange(1 << 20, (1 << 20) + half, dtype=np.int64)
    hot = _sample_from_probs(zipf_probs(2_000, 1.0), length - half,
                             _rng(seed + 1))
    return {
        "zipf": zipf_trace(length, n_items=length, alpha=0.9, seed=seed),
        "scan-hot": np.concatenate([scan, hot]),
        "churn": fickle_churn_trace(length, seed=seed),
        "loop": glimpse_trace(length, seed=seed),
    }
