"""Epoch-boundary fold for sharded frequency sketches (StepSpec.shards).

Sharded mode splits the TinyLFU sketch into S shard-partitioned structures:
``counters``/``doorkeeper`` carry [merged global || shard delta] halves in
one buffer; per-access writes land in the owning shard's slice of the delta
half while reads compose the global half with the delta.  This module is the
other half of the contract: :func:`merge_halve` runs at epoch boundaries —
inside the
same compiled program as the step scan and (in adaptive mode) right next to
``kernels.sketch_step.rebalance``, no host sync — and

1. **merges**: folds every shard's delta into the read-optimized global
   estimate.  CM-sketch counts are linearly mergeable (the property
   Lightweight Robust Size-Aware Cache Management leans on for its
   multi-sketch variants), so the fold is a per-field SATURATING add
   (``sketch_common.merge_words`` — no borrow may leak into a neighbouring
   packed counter) plus a bitwise OR of the doorkeeper deltas;
2. **halves**: applies the paper's §3.3 aging — deferred from the per-access
   path, which in sharded mode never resets — as many times as the
   accumulated sample size demands (an epoch longer than the sample period W
   owes more than one halving; ``k`` halvings of packed fields are ``k``
   passes of ``halve_words``, i.e. field >> k), clearing the doorkeeper
   exactly like the unsharded reset;
3. **clears** the deltas, so the next epoch accumulates from zero.

The §3.3 divide-by-2 commutes with the merge in exact arithmetic (half of a
sum is the sum of halves); in integer arithmetic the fold runs merge-first,
halve-second, which tests/test_sketch_merge.py pins together with the
saturation and no-borrow-leak invariants at both counter widths.

On the future multi-device placement (``distributed.mesh.shard_placement``)
each device owns one shard's delta slice and the merge is the once-per-epoch
all-gather that refreshes every device's replica of the global estimate —
the per-access path stays free of cross-device traffic.
"""
from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp

from .sketch_common import checksum_words, halve_words, merge_words
from .sketch_step import StepSpec, MESH_AXIS, P_SAMPLE, R_SIZE


def shard_checksums(spec: StepSpec, counters_global: jnp.ndarray,
                    dk_global: jnp.ndarray) -> jnp.ndarray:
    """(shards,) int32 checksums over each shard's global sketch slices.

    The global halves are read-only between merge boundaries (per-access
    writes land only in the delta halves), so a checksum computed at one
    fold is verifiable at the next: any bit flipped in a shard's global
    counter slice or doorkeeper slice in between changes its checksum
    (:func:`repro.kernels.sketch_common.checksum_words` uses odd positional
    weights).  Shard s owns words ``r*words_per_row + s*wps_shard + w`` of
    the counter image and words ``[s*dkw_shard, (s+1)*dkw_shard)`` of the
    doorkeeper; both slices concatenate into one per-shard lane so a single
    vectorized checksum covers them.
    """
    S = spec.shards
    c = counters_global.reshape(spec.rows, S, spec.wps_shard)
    per_shard = c.transpose(1, 0, 2).reshape(S, -1)
    if spec.dk_bits:
        d = dk_global.reshape(S, spec.dkw_shard)
        per_shard = jnp.concatenate([per_shard, d], axis=-1)
    return checksum_words(per_shard)


def merge_halve(spec: StepSpec, params: jnp.ndarray, state: dict) -> dict:
    """Fold shard deltas into the global sketch and apply the deferred §3.3
    aging; returns the new state (deltas cleared).

    Pure jnp, O(width) once per epoch — amortized over the epoch it leaves
    the per-access cost untouched (the same contract as ``rebalance``).
    The number of halvings is data-dependent (``size`` may have crossed the
    sample period W several times within one epoch), so it runs as a tiny
    ``while_loop`` on the scalar followed by a ``fori_loop`` of full-array
    halving passes — zero iterations on the epochs where no reset is due.
    """
    assert spec.shards > 1, "merge_halve requires StepSpec.shards > 1"
    if spec.streams > 1:
        # lane-batched tenants (StepSpec.streams): vmap the single-stream
        # fold over the leading lane axis of every state leaf.  Per-lane
        # ``size`` registers give per-lane halving counts, so the deferred
        # §3.3 aging batches into a masked while-loop — once per epoch over
        # the small per-tenant buffers, not on the per-access path.
        lspec = replace(spec, streams=1)
        pax = 0 if params.ndim == 2 else None
        return jax.vmap(lambda p, s: merge_halve(lspec, p, s),
                        in_axes=(pax, 0))(params, state)
    H, HD = spec.counter_words, spec.dk_words
    gc, dc = state["counters"][:H], state["counters"][H:]
    gdk, ddk = state["doorkeeper"][:HD], state["doorkeeper"][HD:]

    if spec.integrity:
        # verify-then-quarantine (ISSUE 7): the stored per-shard checksums
        # were computed over these global halves at the previous fold, and
        # nothing legal wrote them since.  A mismatched shard is corrupt —
        # zero BOTH its global and delta slices (the delta cannot be
        # checksummed: it mutates every access, so it gets no benefit of
        # the doubt) and let the §3.3 aging re-learn its counts.
        S, wps = spec.shards, spec.wps_shard
        ok = shard_checksums(spec, gc, gdk) == state["csum"][:S]
        okc = ok[None, :, None]
        gc = jnp.where(okc, gc.reshape(spec.rows, S, wps), 0).reshape(H)
        dc = jnp.where(okc, dc.reshape(spec.rows, S, wps), 0).reshape(H)
        if spec.dk_bits:
            okd = ok[:, None]
            dkw = spec.dkw_shard
            gdk = jnp.where(okd, gdk.reshape(S, dkw), 0).reshape(HD)
            ddk = jnp.where(okd, ddk.reshape(S, dkw), 0).reshape(HD)

    g = merge_words(gc, dc, spec.counter_bits)
    dk = gdk | ddk

    size = state["regs"][R_SIZE]
    W = params[P_SAMPLE]

    def more(c):
        return (W > 0) & (c[0] >= W)

    def halve_size(c):
        return c[0] // 2, c[1] + 1

    size, k = jax.lax.while_loop(more, halve_size, (size, jnp.int32(0)))
    g = jax.lax.fori_loop(
        0, k, lambda i, x: halve_words(x, spec.counter_bits), g)
    dk = jnp.where(k > 0, jnp.zeros_like(dk), dk)

    regs = state["regs"].at[R_SIZE].set(size)
    out = {**state,
           "counters": jnp.concatenate([g, jnp.zeros_like(g)]),
           "doorkeeper": jnp.concatenate([dk, jnp.zeros_like(dk)]),
           "regs": regs}
    if spec.integrity:
        # refresh the checksums over the NEW global halves (they stay
        # read-only until the next fold) and count quarantined shards
        csum = state["csum"].at[:spec.shards].set(
            shard_checksums(spec, g, dk))
        out["csum"] = csum.at[spec.shards].add(
            jnp.sum((~ok).astype(jnp.int32)))
    return out


def merge_halve_mesh(spec: StepSpec, params: jnp.ndarray,
                     state: dict) -> dict:
    """Multi-device :func:`merge_halve`: the once-per-epoch all-gather.

    Runs inside the shard_map body of the mesh runner
    (``core.device_simulate._mesh_runner`` with
    ``mesh_exchange="stale"`` — the ONLY collective of that mode, and of
    the whole mesh run): each device all-gathers the other devices'
    shard-major delta blocks (``dcounters``/``ddoorkeeper``, the ONLY
    sharded state), reorders them into the single-device delta-half
    layout, and then applies the exact single-device fold — saturating
    merge into the replicated global halves, deferred halvings, doorkeeper
    OR/clear — so every device ends the epoch holding an identical
    refreshed global replica and zeroed local deltas.  O(width) exchanged
    once per epoch; the per-access path exchanges nothing (stale-global
    estimates reconcile here).  The exact ``mesh_exchange="chunk"`` mode
    does not use this fold at all — it replays the single-device
    :func:`merge_halve` on its replicated [global || delta] replica.
    """
    assert spec.mesh_devices, "merge_halve_mesh requires StepSpec.mesh_devices"
    cd = jax.lax.all_gather(state["dcounters"], MESH_AXIS,
                            axis=0, tiled=True)          # (S, rows, wps)
    dd = jax.lax.all_gather(state["ddoorkeeper"], MESH_AXIS,
                            axis=0, tiled=True)          # (S, dkw_shard)
    # shard-major -> the delta-half flat layout (row-major with per-shard
    # slices inside each row: r*words_per_row + s*wps_shard + w), then the
    # fold IS the single-device merge_halve on the reassembled [global ||
    # delta] buffers — exact by construction, one copy of the §3.3 aging
    delta = cd.transpose(1, 0, 2).reshape(spec.counter_words)
    ddk = (dd.reshape(spec.dk_words) if spec.dk_bits
           else jnp.zeros_like(state["doorkeeper"]))
    folded = merge_halve(spec, params, {
        **state,
        "counters": jnp.concatenate([state["counters"], delta]),
        "doorkeeper": jnp.concatenate([state["doorkeeper"], ddk]),
    })
    H, HD = spec.counter_words, spec.dk_words
    return {**folded, "counters": folded["counters"][:H],
            "doorkeeper": folded["doorkeeper"][:HD],
            "dcounters": jnp.zeros_like(state["dcounters"]),
            "ddoorkeeper": jnp.zeros_like(state["ddoorkeeper"])}
