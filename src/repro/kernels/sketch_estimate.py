"""Pallas TPU kernel: batched TinyLFU frequency estimation.

Adapted for the TPU memory hierarchy (DESIGN.md §2): the whole sketch
(packed 4-bit counters + doorkeeper bitset, ≲1 MiB) is pinned in VMEM for the
duration of a batch — the TPU analogue of the paper's "fits in a single
memory page".  Per-key gathers are vectorized as one-hot matmuls on the MXU:
an int32 word is gathered exactly by splitting it into two 16-bit halves
(each < 2^24, exact in fp32), gathering both with a (B × W) one-hot × (W,)
word-vector product, and recombining.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .sketch_common import (DeviceSketchConfig, probe_index, dk_probe_index,
                            nibble_get)


def _onehot_gather_words(words_row: jnp.ndarray, w_idx: jnp.ndarray) -> jnp.ndarray:
    """Exact int32 gather words_row[w_idx] via two fp32 MXU matmuls.

    words_row: (W,) int32; w_idx: (B,) int32 -> (B,) int32.
    """
    W = words_row.shape[0]
    B = w_idx.shape[0]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (B, W), 1)
              == w_idx[:, None]).astype(jnp.float32)
    lo16 = (words_row & jnp.int32(0xFFFF)).astype(jnp.float32)
    hi16 = ((words_row >> 16) & jnp.int32(0xFFFF)).astype(jnp.float32)
    g_lo = jnp.dot(onehot, lo16, preferred_element_type=jnp.float32)
    g_hi = jnp.dot(onehot, hi16, preferred_element_type=jnp.float32)
    return g_lo.astype(jnp.int32) | (g_hi.astype(jnp.int32) << 16)


def vectorized_estimate(cfg: DeviceSketchConfig, counters: jnp.ndarray,
                        dk: jnp.ndarray, lo: jnp.ndarray,
                        hi: jnp.ndarray) -> jnp.ndarray:
    """(B,) int32 estimates; pure jnp so it runs inside kernel bodies."""
    est = jnp.full(lo.shape, 15, jnp.int32)
    for r in range(cfg.rows):
        idx = probe_index(lo, hi, r, cfg.width)
        word = _onehot_gather_words(counters[r], idx >> 3)
        est = jnp.minimum(est, nibble_get(word, idx & 7))
    if cfg.dk_bits:
        dk_flat = dk.reshape(-1)
        ok = jnp.ones(lo.shape, jnp.bool_)
        for p in range(cfg.dk_probes):
            bit = dk_probe_index(lo, hi, p, cfg.dk_bits)
            word = _onehot_gather_words(dk_flat, bit >> 5)
            ok &= ((word >> (bit & 31)) & 1).astype(jnp.bool_)
        est = est + ok.astype(jnp.int32)
    return est


def _estimate_kernel(cfg: DeviceSketchConfig, counters_ref, dk_ref, lo_ref,
                     hi_ref, out_ref):
    out_ref[...] = vectorized_estimate(
        cfg, counters_ref[...], dk_ref[...], lo_ref[...], hi_ref[...])


def estimate_pallas(cfg: DeviceSketchConfig, state: dict, lo: jnp.ndarray,
                    hi: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """Batched estimate.  B should be a multiple of 128 (ops.py pads)."""
    (b,) = lo.shape
    kernel = functools.partial(_estimate_kernel, cfg)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),   # counters: whole table
            pl.BlockSpec(memory_space=pltpu.VMEM),   # doorkeeper
            pl.BlockSpec(memory_space=pltpu.VMEM),   # lo
            pl.BlockSpec(memory_space=pltpu.VMEM),   # hi
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        interpret=interpret,
    )(state["counters"], state["doorkeeper"], lo.astype(jnp.uint32),
      hi.astype(jnp.uint32))
