"""Pallas TPU kernels for the TinyLFU sketch hot path + jnp oracles.

Layout (per the kernel deliverable spec):
  sketch_estimate.py / sketch_update.py / sketch_reset.py / admission.py —
      pl.pallas_call kernels with explicit BlockSpec/memory-space placement
  sketch_step.py — fused W-TinyLFU simulation step: doorkeeper insert +
      conservative add + candidate/victim estimate + admission verdict +
      window/SLRU table update in ONE VMEM-resident launch per trace chunk
      (the engine behind core/device_simulate.py)
  ops.py — jit'd public wrappers (+ DeviceTinyLFU facade)
  ref.py — pure-jnp oracles, bit-exact ground truth for the kernels
"""
from .sketch_common import (DeviceSketchConfig, init_state, keys_to_lanes,
                            merge_words)
from .ops import estimate, add, reset, admit, make_config, DeviceTinyLFU
from .sketch_step import (StepSpec, make_step_params, init_step_state,
                          step_ref, step_pallas)
from .sketch_merge import merge_halve

__all__ = ["DeviceSketchConfig", "init_state", "keys_to_lanes", "estimate",
           "add", "reset", "admit", "make_config", "DeviceTinyLFU",
           "StepSpec", "make_step_params", "init_step_state", "step_ref",
           "step_pallas", "merge_words", "merge_halve"]
