"""Pallas TPU kernels for the TinyLFU sketch hot path + jnp oracles.

Layout (per the kernel deliverable spec):
  sketch_estimate.py / sketch_update.py / sketch_reset.py / admission.py —
      pl.pallas_call kernels with explicit BlockSpec/memory-space placement
  ops.py — jit'd public wrappers (+ DeviceTinyLFU facade)
  ref.py — pure-jnp oracles, bit-exact ground truth for the kernels
"""
from .sketch_common import DeviceSketchConfig, init_state, keys_to_lanes
from .ops import estimate, add, reset, admit, make_config, DeviceTinyLFU

__all__ = ["DeviceSketchConfig", "init_state", "keys_to_lanes", "estimate",
           "add", "reset", "admit", "make_config", "DeviceTinyLFU"]
