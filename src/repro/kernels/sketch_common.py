"""Shared device-sketch configuration, state, and 32-bit-lane hashing.

The device sketch is the TPU-resident twin of ``core.sketch.FrequencySketch``:
4-bit counters packed 8-per-int32 word (paper §3.4.1 small counters), a
doorkeeper bitset packed 32-per-int32 (§3.4.2), and the reset/aging rule
(§3.3).  Keys arrive as (lo, hi) uint32 lane pairs — TPU has no 64-bit int
multiply, so hashing runs the 32-bit prospector mixer per lane (DESIGN.md §2).

Everything here is plain jnp (usable both inside Pallas kernel bodies and in
the pure-jnp oracles in ref.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import (MIX32_M1, MIX32_M2, PROBE_SALTS,
                                WSET_SALT, MSET_SALT, SHARD_SALT)

DK_SALT_XOR = 0xDEADBEEF        # doorkeeper probes use salted variants
HI_MIX_XOR = 0x85EBCA6B

# device-resident replacement/admission policies (StepSpec.policy).  The
# set-associative table machinery (packed records, per-set gather+reduce,
# _lset/_ldus write discipline) is policy-agnostic; the enum selects which
# admission/victim rules the fused step applies on top of it:
#   "wtinylfu" — LRU window -> TinyLFU-gated SLRU main (the default; every
#                other mode — flat, adaptive, sharded, mesh — requires it)
#   "s3fifo"   — small FIFO (window table) -> CLOCK-marked main FIFO,
#                one-hit-wonder filter from the frequency sketch
#   "arc"      — T1/T2 in the main table, runtime target p in a register,
#                B1/B2 ghosts as Bloom halves of a dedicated "ghost" buffer
#   "lfu"      — heap-free sketch-LFU: min-frequency victim straight from
#                the per-set reduce, no window, always admit
POLICIES = ("wtinylfu", "s3fifo", "arc", "lfu")


def _pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


@dataclass(frozen=True)
class DeviceSketchConfig:
    width: int                    # counters per row (power of two)
    rows: int = 4
    cap: int = 15                 # <= 15 (4-bit nibbles)
    dk_bits: int = 0              # doorkeeper bits (power of two); 0 = off
    dk_probes: int = 3
    sample_size: int = 0          # W; 0 = never reset automatically

    def __post_init__(self):
        assert _pow2(self.width) and self.width % 8 == 0
        assert 1 <= self.cap <= 15
        assert self.dk_bits == 0 or (_pow2(self.dk_bits) and self.dk_bits >= 32)
        assert self.rows <= len(PROBE_SALTS)

    @property
    def words_per_row(self) -> int:
        return self.width // 8

    @property
    def dk_words(self) -> int:
        return max(1, self.dk_bits // 32)


def init_state(cfg: DeviceSketchConfig) -> dict:
    """Functional sketch state (a pytree of device arrays)."""
    return {
        "counters": jnp.zeros((cfg.rows, cfg.words_per_row), jnp.int32),
        "doorkeeper": jnp.zeros((1, cfg.dk_words), jnp.int32),
        "size": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# hashing (jnp; identical math to core.hashing.probe_indices32_np)
# ---------------------------------------------------------------------------

def mix32(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(MIX32_M1)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(MIX32_M2)
    x = x ^ (x >> jnp.uint32(16))
    return x


def probe_index(lo: jnp.ndarray, hi: jnp.ndarray, p: int,
                width: int) -> jnp.ndarray:
    """Index of probe ``p`` into a row of ``width`` (pow2) counters."""
    salt = jnp.uint32(PROBE_SALTS[p % len(PROBE_SALTS)]
                      + 0x9E3779B9 * (p // len(PROBE_SALTS)))
    h = mix32(lo.astype(jnp.uint32) + salt) ^ \
        mix32(hi.astype(jnp.uint32) ^ jnp.uint32(HI_MIX_XOR) ^ salt)
    return (h & jnp.uint32(width - 1)).astype(jnp.int32)


def dk_probe_index(lo: jnp.ndarray, hi: jnp.ndarray, p: int,
                   dk_bits: int) -> jnp.ndarray:
    salt = jnp.uint32((PROBE_SALTS[p % len(PROBE_SALTS)] ^ DK_SALT_XOR)
                      + 0x9E3779B9 * (p // len(PROBE_SALTS)))
    h = mix32(lo.astype(jnp.uint32) + salt) ^ \
        mix32(hi.astype(jnp.uint32) ^ jnp.uint32(HI_MIX_XOR) ^ salt)
    return (h & jnp.uint32(dk_bits - 1)).astype(jnp.int32)


def set_index(lo: jnp.ndarray, hi: jnp.ndarray, n_sets: int,
              salt: int) -> jnp.ndarray:
    """Set index for the set-associative cache tables (n_sets pow2).

    jnp twin of ``core.hashing.set_index32_np`` — the host ``SetAssociative*``
    policies and the device tables must map every key to the same set.
    """
    s = jnp.uint32(salt)
    h = mix32(lo.astype(jnp.uint32) + s) ^ \
        mix32(hi.astype(jnp.uint32) ^ jnp.uint32(HI_MIX_XOR) ^ s)
    return (h & jnp.uint32(n_sets - 1)).astype(jnp.int32)


def shard_index(lo: jnp.ndarray, hi: jnp.ndarray,
                shards: int) -> jnp.ndarray:
    """Owning sketch shard of a key (``shards`` pow2; StepSpec.shards).

    jnp twin of ``core.hashing.shard_index32_np``.  Uses ``SHARD_SALT`` —
    independent of every probe/doorkeeper/cache-set salt, so shard
    membership is uncorrelated with probe positions and set placement.
    """
    s = jnp.uint32(SHARD_SALT)
    h = mix32(lo.astype(jnp.uint32) + s) ^ \
        mix32(hi.astype(jnp.uint32) ^ jnp.uint32(HI_MIX_XOR) ^ s)
    return (h & jnp.uint32(shards - 1)).astype(jnp.int32)


# -- nibble helpers (int32-safe: masks clear any sign-extension bits) --------

def nibble_get(word: jnp.ndarray, nib: jnp.ndarray) -> jnp.ndarray:
    """Extract 4-bit counter ``nib`` (0..7) from an int32 word."""
    return (word >> (nib * 4)) & jnp.int32(0xF)


def nibble_inc(word: jnp.ndarray, nib: jnp.ndarray) -> jnp.ndarray:
    """Increment 4-bit counter ``nib`` (caller guarantees value < 15)."""
    return word + (jnp.int32(1) << (nib * 4))


def halve_words(words: jnp.ndarray, counter_bits: int = 4) -> jnp.ndarray:
    """Per-field halving of packed counters: the paper's reset as one VPU op.
    (x >> 1) masked clears both cross-field borrow bits and the sign
    extension (0x77777777 for 4-bit nibbles, 0x7F7F7F7F for 8-bit bytes)."""
    mask = 0x77777777 if counter_bits == 4 else 0x7F7F7F7F
    return (words >> 1) & jnp.int32(mask)


def merge_words(a: jnp.ndarray, b: jnp.ndarray,
                counter_bits: int = 4) -> jnp.ndarray:
    """Per-field SATURATING add of packed counter words: CM-sketch linear
    merge (counts add) with every field pinned at the counter maximum.

    A plain word-wise ``a + b`` would carry a field that overflows into its
    neighbouring packed counter, silently corrupting another key's count —
    the merge splits even/odd fields into separate lanes so each sum gets a
    spare high bit, then saturates any field that overflowed:

        4-bit: even nibbles masked 0x0F0F0F0F sum to <= 30 inside their
        byte; bit 4 of the byte flags >= 16, and ``flag * 0xF`` builds the
        saturation value without cross-byte carries (bytes are 0 or 1).
        8-bit: same scheme over 0x00FF00FF halfword lanes, flag bit 8.

    Shard folds (kernels/sketch_merge.merge_halve) rely on this: the
    engine's own invariant keeps global+delta <= cap so the saturation is
    never hit there, but merging independently-built sketches (multi-device
    aggregation) must not borrow across fields.
    """
    assert counter_bits in (4, 8)
    if counter_bits == 4:
        lane_mask, flag_shift, flag_mask, fmax = 0x0F0F0F0F, 4, 0x01010101, 0xF
    else:
        lane_mask, flag_shift, flag_mask, fmax = 0x00FF00FF, 8, 0x00010001, 0xFF
    lane_mask = jnp.int32(lane_mask)
    flag_mask = jnp.int32(flag_mask)

    def lane_sum(x, y):
        s = (x & lane_mask) + (y & lane_mask)
        over = (s >> flag_shift) & flag_mask          # 1 per overflowed field
        return (s | over * jnp.int32(fmax)) & lane_mask

    even = lane_sum(a, b)
    odd = lane_sum(a >> counter_bits, b >> counter_bits)
    return even | (odd << counter_bits)


def checksum_words(words: jnp.ndarray) -> jnp.ndarray:
    """Position-weighted wrap-sum checksum of an int32 buffer.

    ``sum(x[i] * w[i]) mod 2^32`` with ``w[i] = (i * 2654435761) | 1`` —
    every weight is odd, so for any position ``2^b * w[i] != 0 (mod 2^32)``
    for ``b < 32``: flipping any single bit of any word changes the
    checksum.  Position-dependent weights additionally catch swapped words
    (a plain sum would not).  Reduces over the LAST axis, so a
    ``(shards, n)`` view yields per-shard checksums in one call.  Pure VPU
    arithmetic: usable inside compiled folds (kernels/sketch_merge) at a
    cost far below the merge itself.
    """
    n = words.shape[-1]
    w = (jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(2654435761)) \
        | jnp.uint32(1)
    return jnp.sum(words.astype(jnp.uint32) * w, axis=-1).astype(jnp.int32)


def bit_get(words: jnp.ndarray, bit: jnp.ndarray) -> jnp.ndarray:
    """Read bit ``bit`` from a packed int32 bitset (flat indexing)."""
    word = words.reshape(-1)[bit >> 5]
    return (word >> (bit & 31)) & jnp.int32(1)


def keys_to_lanes(keys: np.ndarray | jnp.ndarray):
    """uint64 numpy keys -> (lo, hi) uint32 jnp arrays (host-side helper)."""
    keys = np.asarray(keys, dtype=np.uint64)
    lo = jnp.asarray((keys & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    hi = jnp.asarray((keys >> np.uint64(32)).astype(np.uint32))
    return lo, hi
