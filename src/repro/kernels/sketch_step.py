"""Fused device-resident W-TinyLFU simulation step (paper §4, Fig 5).

One launch advances an entire *chunk* of the access trace through the full
W-TinyLFU decision pipeline while every byte of policy state stays
VMEM-resident:

    per access:  doorkeeper insert  +  conservative-update add  (+ §3.3 reset)
                 -> window-LRU / SLRU-main lookup
                 -> on window overflow: candidate & victim frequency estimate
                 -> admission verdict + table update

This replaces the three separate HBM round-trips per decision (sketch_update
-> sketch_estimate -> admission) that made trace simulation launch-bound.

Two table layouts share the step, selected by ``StepSpec.assoc``:

**Flat (assoc=None, the exact path)** — cache tables are fixed-capacity
packed int32 arrays.  Each slot's (valid, segment, LRU-stamp) state is packed
into ONE int32 ``meta``:

      -1              empty slot
      t               probation entry, last-stamped at access t
      2^30 | t        protected entry, last-stamped at access t
      2^31-1          sweep padding (permanently unusable slot)

so a single ``argmin(meta)`` is simultaneously the free-slot finder and
the exact SLRU victim priority (empty < probation LRU < protected LRU),
and a single ``argmin`` over the window's meta is free-slot-else-LRU.
Exact global LRU — but every lookup/victim search is O(capacity), so
per-access cost grows linearly with cache size.

**Set-associative (assoc=W, the O(ways) path)** — each table is
``n_sets × assoc`` rows of one packed int32 record
``[lo, hi, meta, (mset1, mset2,) idx[rows], dkb[dkp]]``; a key hashes to a set
(``sketch_common.set_index``) and every lookup, free-slot search, SLRU
victim priority, and protected-overflow demotion is a contiguous
``dynamic_slice`` gather + reduce over ``assoc`` records — O(ways) per
access, independent of capacity.  LRU and the SLRU segmentation become
*per-set* (hardware-cache / Caffeine-style): the protected budget of a set
is ``max(1, usable_ways * prot_cap // main_cap)``.  Semantics shift from
exact global LRU to per-set LRU, so the contract vs the host exact policy
is hit-ratio tolerance (±0.01 on the golden traces) instead of bitwise
parity; ``step_ref``/``step_pallas`` remain bit-for-bit identical to each
other, and a single-set geometry (n_sets == 1) reproduces the flat path's
hit sequence exactly.

* LRU order is the monotone access index ``t``; each access stamps at most
  one entry per segment (per set), so stamps are unique and ``argmin``
  reproduces the host OrderedDict order exactly.
* hashing is hoisted out of the sequential loop entirely: probe rows,
  doorkeeper bit positions, and both set indices are precomputed vectorized
  over the whole chunk (they do not depend on state) and *stored in the
  tables* next to the key lanes, so estimates of resident candidates/victims
  need no re-hashing, and a displaced window entry carries its own main-table
  set index with it.

Sketch counters are ``counter_bits`` ∈ {4, 8} wide (8 or 4 per int32 word):
4-bit is the paper's §3.4.1 layout (cap ≤ 15, sample_factor ≤ 16); 8-bit
doubles the sketch footprint but lifts the cap to 255 so large
``sample_factor`` configurations no longer need the host engine.

**Sharded sketches (``StepSpec.shards = S``)** — for capacities whose
counters outgrow one core's VMEM, the sketch address space partitions into S
shards: a key's probes are confined to its owning shard's ``width/S``-counter
(and ``dk_bits/S``-doorkeeper-bit) slice, ``counters``/``doorkeeper`` carry
[merged global || shard delta] halves in one buffer, per-access writes land
in the owning shard's slice of the delta half, reads compose global + delta,
and the §3.3 reset moves from the per-access path to the epoch-boundary
``kernels.sketch_merge.merge_halve`` fold (saturating CM-sketch merge +
deferred halving, inside the same compiled program).
``shards=1`` (the default) compiles the identical program — all shard logic
sits under static Python branches, same pattern as ``assoc=None`` /
``adaptive=False``.

Semantics contract (tests/test_sketch_step.py, tests/test_device_simulate.py):

* ``step_ref`` (pure-jnp `lax.scan`) and ``step_pallas`` (fused kernel) are
  bit-for-bit identical, including reset boundaries that straddle chunks —
  in both layouts.
* The sketch substate evolves exactly like ``ref.add_ref`` (no reset) and the
  host ``FrequencySketch`` up to the 32-bit-lane hash family.
* With a collision-free sketch, the per-access hit sequence is bit-for-bit
  the host ``WTinyLFU``'s (flat), resp. the host set-associative twin's
  (``core.policies.SetAssociativeSLRU`` via ``WTinyLFU(assoc=...)``).

Static geometry lives in ``StepSpec``; per-config scalars that may vary
across a vmapped sweep (protected capacity, sample size W, counter cap,
warmup) are a traced int32 ``params`` vector, so one compiled program sweeps
a Cartesian grid of configurations (core/device_simulate.py).  Window/main
capacities below the static slot counts are expressed at init time by marking
the excess slots as padding (init_step_state); in set mode the padding is
distributed over the sets by ``core.hashing.set_ways``; a grid member far
below the shared geometry may leave some sets empty, and keys hashing there
bypass that table (inserts are gated on non-padding slots).

Keys: 64-bit keys arrive as (lo, hi) int32 bit-pattern lanes.  The single
key value 2^64-1 (lanes == -1) is reserved as the padding-slot sentinel and
must not appear in traces.

Aliasing: ``step_pallas`` donates every state buffer (input_output_aliases),
so between chunks the state never round-trips through fresh HBM allocations.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.hashing import (WSET_SALT, MSET_SALT, MSET2_SALT, set_ways,
                                shard_geometry)
from .sketch_common import (POLICIES, probe_index, dk_probe_index, set_index,
                            shard_index, halve_words)

# python ints (not jnp scalars): jnp scalars at module scope would be closed
# over as captured constants, which pallas kernels reject
_I32_MAX = 2**31 - 1          # padding-slot meta: never free, never a victim
_PROT = 1 << 30               # meta bit 30: protected segment
_EMPTY = -1                   # meta of an empty (usable) slot

# params vector layout (traced per-config scalars; see make_step_params)
P_WINDOW_CAP = 0              # informational (capacities are baked at init)
P_MAIN_CAP = 1
P_PROT_CAP = 2
P_SAMPLE = 3                  # W; 0 disables the automatic reset
P_CAP = 4                     # counter saturation (< 2**counter_bits)
P_WARMUP = 5                  # accesses before hits start counting
NPARAMS = 8

# regs vector layout (mutable int32 scalar state)
R_SIZE = 0                    # sketch additions since last reset
R_PCOUNT = 1                  # protected entries within main (flat path only)
R_T = 2                       # global access index == LRU stamp
R_HITS = 3                    # counted hits (post warmup)
# adaptive-mode registers (zero / inert when StepSpec.adaptive is False)
R_WQUOTA = 4                  # runtime window capacity (hill-climbed)
R_WCOUNT = 5                  # resident window entries (flat adaptive only)
R_MCOUNT = 6                  # resident main entries (flat adaptive only)
R_EHITS = 7                   # hits this epoch (reset by rebalance)
NREGS = 8

# packed set-associative record columns (window carries two extra lanes: the
# resident key's two candidate main-table set indices, so a displaced
# candidate needs no re-hash to find its victim sets)
WT_LO, WT_HI, WT_META, WT_MSET, WT_MSET2 = 0, 1, 2, 3, 4
MT_LO, MT_HI, MT_META = 0, 1, 2

# mesh axis name of the multi-device sharded-sketch run (StepSpec.mesh_devices
# > 0 — the step then executes inside a shard_map over
# distributed.mesh.make_shard_mesh and the delta halves are device-local)
MESH_AXIS = "shard"


def _pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


@dataclass(frozen=True)
class StepSpec:
    """Static geometry of one simulated W-TinyLFU instance.

    Every field is compile-time static: two ``StepSpec`` values that differ
    in any field compile (and cache) separate programs.  Per-config scalars
    that may vary across a vmapped sweep live in the traced ``params``
    vector instead (:func:`make_step_params`).

    Field reference (see docs/API.md for the rendered version):

    ``width``
        Sketch counters per row.  Power of two, multiple of 8 (counters are
        packed 8- or 4-per-int32 word).  With ``shards=S`` also a multiple
        of ``8*S`` — each shard owns a contiguous ``width/S`` slice.
    ``rows`` (default 4)
        CM-sketch depth: independent probe rows, estimate = min over rows.
        At most ``len(PROBE_SALTS)`` (8).
    ``dk_bits`` (default 0)
        Doorkeeper Bloom-filter bits (paper §3.4.2).  0 disables the
        doorkeeper; otherwise a power of two >= 32 (packed 32-per-int32;
        with ``shards=S``: a multiple of ``32*S``).
    ``dk_probes`` (default 3)
        Bloom probes per doorkeeper insert/query.
    ``window_slots`` / ``main_slots`` (default 1)
        Static table sizes; must be >= any window/main capacity the params
        configure (excess slots become init-time padding, or runtime
        headroom when ``adaptive``).  In set mode each must be
        ``assoc * pow2`` (sets x ways).
    ``assoc`` (default None)
        None = flat exact tables (global LRU/SLRU, O(capacity) per access).
        W = W-way set-associative layout, O(ways) per access.  Interaction:
        vmapped sweeps share one static geometry — every grid member must
        keep ``main_cap >= shared main set count`` (enforced by
        ``simulate_sweep``) or its main table would be unreachable.
    ``counter_bits`` (default 4)
        Packed sketch counter width: 4 (cap <= 15, the paper's §3.4.1
        layout) or 8 (cap <= 255, doubles the sketch footprint, lifts the
        ``sample_factor > 16`` host-engine limitation).
    ``adaptive`` (default False)
        Runtime window quota in ``regs[R_WQUOTA]`` hill-climbed at epoch
        boundaries (``core.device_simulate.ClimbSpec``).  False compiles
        the identical program as before the feature existed.  Interaction:
        adaptive sweeps are sequential-mode only (quota histories diverge,
        defeating vmap's shared geometry).
    ``shards`` (default 1)
        Frequency-sketch shards (pow2).  ``S > 1`` partitions the sketch
        address space: a key's probes are confined to its owning shard's
        ``width/S``-counter (and ``dk_bits/S``-bit) slice, the sketch
        buffers carry [merged global || shard delta] halves, per-access
        writes land in the owning shard's slice of the delta half, reads
        compose global + delta, and the §3.3 reset moves from the
        per-access path to the epoch-boundary
        :func:`repro.kernels.sketch_merge.merge_halve` fold.  ``shards=1``
        compiles the identical program (all shard logic is under static
        Python branches).  Interaction: sharded runs are epoch-chunked
        (``merge_every``) and sequential-sweep only, like ``adaptive``.
    ``mesh_devices`` (default 0)
        Multi-device sharded execution (``core.device_simulate``
        ``DeviceWTinyLFU(mesh=)``): the step runs inside a ``shard_map``
        over a 1-D ``("shard",)`` mesh of that many devices
        (``distributed.mesh.make_shard_mesh``), the sketch delta halves
        live as shard-major arrays partitioned along the mesh axis
        (``dcounters``/``ddoorkeeper`` state keys — per-access writes are
        device-local), the global halves stay replicated, and the
        per-access path exchanges NOTHING: all cross-device traffic is
        per-epoch-chunk (``mesh_exchange``).  Requires
        ``shards % mesh_devices == 0`` (block placement: device ``d``
        owns shards ``[d*S/D, (d+1)*S/D)``, matching
        ``distributed.mesh.shard_placement``).  0 = single-device layout.
    ``mesh_exchange`` (default "chunk")
        Cross-device exchange cadence of the mesh run (inert at
        ``mesh_devices=0``).  ``"chunk"`` — exact chunked exchange: the
        runner all-gathers the shard deltas ONCE per run, every device
        replays each merge epoch as the literal (replicated) single-device
        sharded program, and re-splits its local delta block at the end;
        bit-identical to the single-device sharded run.  ``"stale"`` —
        speculative stale-global admission: per-access estimates read only
        the replicated global halves (:func:`_estimate_pair_stale` — stale
        by at most one merge epoch, zero per-access collectives) and
        reconcile at the once-per-epoch
        :func:`repro.kernels.sketch_merge.merge_halve_mesh` all-gather;
        hit ratios land in the goldens-±0.01 tier (host twin:
        ``core.sketch.ShardedFrequencySketch(stale_estimates=True)``).
    ``streams`` (default 1)
        Lane-batched multi-tenant execution: ``B > 1`` advances B
        INDEPENDENT cache instances in lockstep inside one compiled scan.
        Every mutable state leaf gains a leading lane axis ``(B, …)``, key
        lanes arrive as ``(B, T)``, and the step dispatches through
        ``jax.vmap`` of the ``streams=1`` program — with the per-access
        single-slot writes re-expressed as fused masked selects
        (:data:`_LANE_TRACE`), because vmapping a per-lane-indexed
        ``dynamic_update_slice`` would lower to one XLA-CPU scatter per
        write site (~7µs FIXED cost each, regardless of operand size —
        measured to cap lane scaling at ~2x).  ``streams=1`` never takes
        the dispatch and compiles the byte-identical unbatched program.
        Interaction: incompatible with ``mesh_devices`` (the lanes would
        vmap over the mesh axis the shard_map already owns); the pallas
        backend batches through pallas' own vmap rule.
    ``policy`` (default "wtinylfu")
        Admission/victim rules applied on top of the policy-agnostic
        set-associative machinery (:data:`repro.kernels.sketch_common.
        POLICIES`).  ``"wtinylfu"`` is the full engine and the only value
        the flat/adaptive/sharded/mesh/integrity modes accept; the
        competitor policies (``"s3fifo"``, ``"arc"``, ``"lfu"``) require
        ``assoc`` and run inside the same fused scan — same packed
        records, per-set gather+reduce, write discipline, and ``streams``
        lane batching.  ``"arc"`` additionally requires ``dk_bits > 0``
        (its B1/B2 ghost lists are Bloom filters addressed by the
        doorkeeper probe schedule, stored in a dedicated ``"ghost"``
        state buffer).  ``policy="wtinylfu"`` compiles the byte-identical
        program to a spec without the field (tests/test_policy_panel.py
        pins the lowered HLO).
    ``integrity`` (default False)
        Self-healing sketch integrity (requires ``shards > 1``).  Adds a
        ``"csum"`` state vector of ``shards + 1`` int32 words: per-shard
        :func:`repro.kernels.sketch_common.checksum_words` checksums over
        the global sketch halves (which are read-only between merge
        boundaries — per-access writes land only in the delta halves),
        plus a cumulative quarantined-shard counter in the last word.  The
        epoch-boundary :func:`repro.kernels.sketch_merge.merge_halve` fold
        verifies each shard's checksum before merging; a mismatched shard
        is QUARANTINED — its global and delta slices are zeroed — and the
        paper's §3.3 aging re-learns its counts within a few sample
        periods.  False compiles the identical program.
    """
    width: int                    # sketch counters per row (pow2, mult of 8)
    rows: int = 4
    dk_bits: int = 0              # doorkeeper bits (pow2 >= 32); 0 = off
    dk_probes: int = 3
    window_slots: int = 1         # window table size (>= any window_cap used)
    main_slots: int = 1           # main table size (>= any main_cap used)
    assoc: int | None = None      # ways per set; None = flat exact tables
    counter_bits: int = 4         # sketch counter width: 4 (cap 15) or 8 (255)
    adaptive: bool = False        # runtime window quota (regs[R_WQUOTA])
    shards: int = 1               # sketch shards (pow2); >1 = delta/global
    mesh_devices: int = 0         # shard_map devices; 0 = single-device
    mesh_exchange: str = "chunk"  # mesh cadence: "chunk" exact | "stale"
    integrity: bool = False       # per-shard checksums + quarantine fold
    streams: int = 1              # lane-batched tenant instances (B >= 1)
    policy: str = "wtinylfu"      # admission/victim rules (POLICIES enum)

    def __post_init__(self):
        assert self.policy in POLICIES, (
            f"policy {self.policy!r} must be one of {POLICIES}")
        if self.policy != "wtinylfu":
            assert self.assoc is not None, (
                f"policy {self.policy!r} runs on the set-associative "
                "machinery only (assoc=W); the flat exact tables are "
                "W-TinyLFU-specific")
            assert self.shards == 1 and self.mesh_devices == 0, (
                f"policy {self.policy!r} does not support sketch sharding "
                "or mesh execution (competitor policies exist for "
                "apples-to-apples sweeps, not production scale-out)")
            assert not self.adaptive and not self.integrity, (
                f"policy {self.policy!r} cannot combine with adaptive/"
                "integrity (both are W-TinyLFU-engine features)")
        if self.policy == "arc":
            assert self.dk_bits > 0, (
                "policy='arc' needs dk_bits > 0: its B1/B2 ghost lists "
                "are Bloom filters addressed by the doorkeeper probe "
                "schedule")
        assert self.streams >= 1, "streams must be >= 1"
        if self.streams > 1:
            assert self.mesh_devices == 0, (
                "streams (lane-batched tenants) cannot combine with "
                "mesh_devices (the lanes would vmap over the mesh axis "
                "the shard_map already owns)")
        if self.integrity:
            assert self.shards > 1, (
                "integrity checksums cover the per-shard global sketch "
                "halves, which only exist at shards > 1")
        assert self.mesh_exchange in ("chunk", "stale"), (
            f"mesh_exchange {self.mesh_exchange!r} must be 'chunk' (exact "
            "chunked exchange) or 'stale' (speculative stale-global "
            "admission)")
        if self.mesh_devices:
            assert self.shards > 1, "mesh execution requires shards > 1"
            assert self.shards % self.mesh_devices == 0, (
                f"shards {self.shards} must be a multiple of mesh_devices "
                f"{self.mesh_devices} (block placement)")
        assert _pow2(self.width) and self.width % 8 == 0
        assert self.counter_bits in (4, 8)
        assert self.dk_bits == 0 or (_pow2(self.dk_bits) and self.dk_bits >= 32)
        assert self.window_slots >= 1 and self.main_slots >= 1
        # validates shards pow2 + per-shard word alignment
        shard_geometry(self.width, self.dk_bits, self.shards)
        if self.assoc is not None:
            assert self.assoc >= 1
            assert self.window_slots % self.assoc == 0 and \
                _pow2(self.window_slots // self.assoc), \
                "window_slots must be assoc * pow2-sets"
            assert self.main_slots % self.assoc == 0 and \
                _pow2(self.main_slots // self.assoc), \
                "main_slots must be assoc * pow2-sets"

    @property
    def counters_per_word(self) -> int:
        return 32 // self.counter_bits

    @property
    def words_per_row(self) -> int:
        return self.width // self.counters_per_word

    @property
    def counter_cap_max(self) -> int:
        return (1 << self.counter_bits) - 1

    @property
    def dk_words(self) -> int:
        return max(1, self.dk_bits // 32)

    @property
    def width_shard(self) -> int:     # counters per row owned by one shard
        return self.width // self.shards

    @property
    def dk_bits_shard(self) -> int:   # doorkeeper bits owned by one shard
        return self.dk_bits // self.shards

    @property
    def counter_words(self) -> int:   # words in the global counter image
        return self.rows * self.words_per_row

    @property
    def sketch_halves(self) -> int:   # sharded: [global || delta] halves
        return 2 if self.shards > 1 else 1

    @property
    def local_shards(self) -> int:    # shards owned by one mesh device
        return self.shards // max(1, self.mesh_devices)

    @property
    def wps_shard(self) -> int:       # counter words per row per shard
        return self.words_per_row // self.shards

    @property
    def dkw_shard(self) -> int:       # doorkeeper words per shard
        return max(1, self.dk_words // self.shards)

    @property
    def dkp(self) -> int:         # stored doorkeeper probes per table entry
        return self.dk_probes if self.dk_bits else 1

    @property
    def window_sets(self) -> int:
        return self.window_slots // self.assoc

    @property
    def main_sets(self) -> int:
        return self.main_slots // self.assoc

    @property
    def wcols(self) -> int:       # packed window record width (set mode)
        return 5 + self.rows + self.dkp

    @property
    def mcols(self) -> int:       # packed main record width (set mode)
        return 3 + self.rows + self.dkp


def make_step_params(window_cap: int, main_cap: int, prot_cap: int,
                     sample_size: int, cap: int, warmup: int = 0,
                     counter_bits: int = 4) -> jnp.ndarray:
    """Pack per-config scalars into the traced (NPARAMS,) int32 vector.

    ``counter_bits`` must match the ``StepSpec`` these params will run
    against: a cap above the counter mask would make the minimal-increment
    bump fire on saturated counters and carry into the NEIGHBORING packed
    counter, silently corrupting another key's estimate.
    """
    assert 1 <= cap <= (1 << counter_bits) - 1, (
        f"cap {cap} does not fit {counter_bits}-bit counters")
    p = [int(window_cap), int(main_cap), int(prot_cap), int(sample_size),
         int(cap), int(warmup)] + [0] * (NPARAMS - 6)
    return jnp.asarray(p, jnp.int32)


def _state_keys(spec: StepSpec) -> tuple[str, ...]:
    # sharded mode keeps the same keys: "counters"/"doorkeeper" simply carry
    # TWO halves — [merged global || shard-partitioned delta].  One buffer
    # (not separate delta arrays) so the per-access DUS write chain has the
    # exact shape XLA CPU already updates in place on the unsharded path;
    # separate delta buffers measured 4 full copies per access at big widths.
    # Mesh mode is the exception: "counters"/"doorkeeper" hold ONLY the
    # replicated global halves and the deltas live in shard-major
    # "dcounters"/"ddoorkeeper" arrays partitioned along the mesh axis.
    mesh = ("dcounters", "ddoorkeeper") if spec.mesh_devices else ()
    load = (("wsl", "wuw") if spec.adaptive and spec.assoc is not None
            else ())
    csum = ("csum",) if spec.integrity else ()
    # ARC's B1/B2 ghost Blooms: one buffer of 2*dk_words int32 words
    # (B1 = [0, dk_words), B2 = [dk_words, 2*dk_words))
    ghost = ("ghost",) if spec.policy == "arc" else ()
    if spec.assoc is None:
        return ("counters", "doorkeeper", *mesh, "wlo", "whi", "wmeta",
                "widx", "wdkb", "mlo", "mhi", "mmeta", "midx", "mdkb",
                *csum, "regs")
    return ("counters", "doorkeeper", *mesh, "wtab", "mtab", *ghost, *load,
            *csum, "regs")


def init_step_state(spec: StepSpec, window_cap: int | None = None,
                    main_cap: int | None = None) -> dict:
    """Zeroed simulation state (a pytree of int32 device arrays).

    ``window_cap``/``main_cap`` below the static slot counts mark the excess
    slots as permanent padding — this is how one static ``StepSpec`` hosts a
    vmapped sweep over different cache sizes.  In set mode the padding is
    distributed over the sets (``core.hashing.set_ways``): the first
    ``cap % n_sets`` sets keep one extra usable way; capacities below the
    set count leave the excess sets empty (keys hashing there bypass that
    table — a documented vmapped-sweep approximation).

    ``spec.adaptive`` flips the capacity mechanism from init-time padding to
    runtime state: every slot is usable at the static level, ``window_cap``
    seeds the ``regs[R_WQUOTA]`` register (the hill-climbed runtime window
    quota), and the per-access step derives both tables' effective
    capacities from the registers instead of from padding (flat: resident
    counts gate inserts; set: per-set usable-way masks).
    """
    if spec.streams > 1:
        # every lane starts from the identical zeroed instance; per-lane
        # capacities (vmapped sweeps) stack per-config states instead
        base = init_step_state(replace(spec, streams=1), window_cap,
                               main_cap)
        return jax.tree_util.tree_map(
            lambda v: jnp.repeat(v[None], spec.streams, axis=0), base)
    wcap = spec.window_slots if window_cap is None else int(window_cap)
    mcap = spec.main_slots if main_cap is None else int(main_cap)
    assert 1 <= wcap <= spec.window_slots and 1 <= mcap <= spec.main_slots

    regs = jnp.zeros((NREGS,), jnp.int32)
    if spec.adaptive:
        regs = regs.at[R_WQUOTA].set(wcap)
    # sharded (sketch_halves == 2): the arrays carry [global || delta]
    # halves in ONE buffer — shard s owns words [s*words/S, (s+1)*words/S)
    # of every row slice in the delta half, and per-access writes land only
    # there (probe indices are shard-confined).  Mesh mode splits the delta
    # out into shard-major arrays (axis 0 = shard) so a NamedSharding /
    # shard_map along ("shard",) makes per-access delta writes device-local.
    if spec.mesh_devices:
        common = {
            "counters": jnp.zeros((spec.counter_words,), jnp.int32),
            "doorkeeper": jnp.zeros((spec.dk_words,), jnp.int32),
            "dcounters": jnp.zeros(
                (spec.shards, spec.rows, spec.wps_shard), jnp.int32),
            "ddoorkeeper": jnp.zeros((spec.shards, spec.dkw_shard),
                                     jnp.int32),
            "regs": regs,
        }
    else:
        common = {
            "counters": jnp.zeros((spec.sketch_halves * spec.counter_words,),
                                  jnp.int32),
            "doorkeeper": jnp.zeros((spec.sketch_halves * spec.dk_words,),
                                    jnp.int32),
            "regs": regs,
        }
    if spec.integrity:
        # [0:S] per-shard checksums of the global sketch halves, [S] the
        # cumulative quarantined-shard count.  Zeros are the correct seed:
        # checksum_words of all-zero buffers is 0.
        common["csum"] = jnp.zeros((spec.shards + 1,), jnp.int32)
    if spec.policy == "arc":
        # B1/B2 ghost Blooms (dk_bits each), empty at init
        common["ghost"] = jnp.zeros((2 * spec.dk_words,), jnp.int32)
    if spec.adaptive and spec.assoc is not None:
        # load-aware window quota distribution state (ISSUE 5): per-set
        # window access counts this epoch + the current usable-way vector
        # (seeded with the uniform set_ways rule, which the per-access path
        # used to compute arithmetically)
        nws = spec.window_slots // spec.assoc
        common["wsl"] = jnp.zeros((nws,), jnp.int32)
        common["wuw"] = jnp.asarray(set_ways(wcap, nws), jnp.int32)
    if spec.adaptive:
        # no init-time padding: capacities live in regs/params at runtime
        wcap = spec.window_slots
        mcap = spec.main_slots

    if spec.assoc is None:
        def table(slots, cap):
            pad = jnp.arange(slots) >= cap
            return {
                # all non-resident slots hold the sentinel key (lanes -1) so
                # no real key — including key 0 — can match an unoccupied slot
                "lo": jnp.full((slots,), -1, jnp.int32),
                "hi": jnp.full((slots,), -1, jnp.int32),
                "meta": jnp.where(pad, _I32_MAX, _EMPTY).astype(jnp.int32),
                "idx": jnp.zeros((slots, spec.rows), jnp.int32),
                "dkb": jnp.zeros((slots, spec.dkp), jnp.int32),
            }

        w, m = table(spec.window_slots, wcap), table(spec.main_slots, mcap)
        return {**common,
                "wlo": w["lo"], "whi": w["hi"], "wmeta": w["meta"],
                "widx": w["idx"], "wdkb": w["dkb"],
                "mlo": m["lo"], "mhi": m["hi"], "mmeta": m["meta"],
                "midx": m["idx"], "mdkb": m["dkb"]}

    def set_table(slots, cap, ncols, meta_col):
        n_sets = slots // spec.assoc
        ways = np.asarray(set_ways(cap, n_sets))
        way_of = np.arange(slots) % spec.assoc
        pad = way_of >= ways[np.arange(slots) // spec.assoc]
        tab = np.zeros((slots, ncols), np.int32)
        tab[:, 0] = -1
        tab[:, 1] = -1
        tab[:, meta_col] = np.where(pad, _I32_MAX, _EMPTY)
        return jnp.asarray(tab)

    return {**common,
            "wtab": set_table(spec.window_slots, wcap, spec.wcols, WT_META),
            "mtab": set_table(spec.main_slots, mcap, spec.mcols, MT_META)}


# ---------------------------------------------------------------------------
# probe precomputation — vectorized over the chunk, outside the scan
# ---------------------------------------------------------------------------

def precompute_probes(spec: StepSpec, lo: jnp.ndarray, hi: jnp.ndarray):
    """(B,) key lanes -> ((B, rows) probes, (B, dkp) doorkeeper bits,
    (B,) window set, (B, 2) main set choices).

    Pure functions of the key, hoisted out of the sequential loop and stored
    alongside resident entries so the loop body never hashes.  Set indices
    are zeros in flat mode.  Each key gets TWO candidate main sets
    (power-of-two-choices placement): the resident copy lives in exactly one,
    lookups probe both, and the insert victim is the weakest of both sets'
    2*ways records.

    ``spec.shards > 1`` confines every probe to the key's owning shard:
    probe = shard * width_shard + (hash & (width_shard - 1)), and likewise
    for doorkeeper bits — so the per-access sketch update touches only the
    owning shard's slice of the delta arrays.  At shards=1 the expressions
    reduce to the unsharded ones bit-for-bit.
    """
    if spec.shards > 1:
        ks = shard_index(lo, hi, spec.shards)
        idx = jnp.stack([ks * spec.width_shard
                         + probe_index(lo, hi, r, spec.width_shard)
                         for r in range(spec.rows)], axis=-1)
        if spec.dk_bits:
            dkb = jnp.stack([ks * spec.dk_bits_shard
                             + dk_probe_index(lo, hi, p, spec.dk_bits_shard)
                             for p in range(spec.dk_probes)], axis=-1)
        else:
            dkb = jnp.zeros(lo.shape + (1,), jnp.int32)
    else:
        idx = jnp.stack([probe_index(lo, hi, r, spec.width)
                         for r in range(spec.rows)], axis=-1)
        if spec.dk_bits:
            dkb = jnp.stack([dk_probe_index(lo, hi, p, spec.dk_bits)
                             for p in range(spec.dk_probes)], axis=-1)
        else:
            dkb = jnp.zeros(lo.shape + (1,), jnp.int32)
    if spec.assoc is not None:
        wset = set_index(lo, hi, spec.window_sets, WSET_SALT)
        mset = jnp.stack([set_index(lo, hi, spec.main_sets, MSET_SALT),
                          set_index(lo, hi, spec.main_sets, MSET2_SALT)],
                         axis=-1)
    else:
        wset = jnp.zeros(lo.shape, jnp.int32)
        mset = jnp.zeros(lo.shape + (2,), jnp.int32)
    return idx, dkb, wset, mset


# ---------------------------------------------------------------------------
# functional single-access step — the one source of truth for both backends
# ---------------------------------------------------------------------------

def _row_offsets(spec: StepSpec) -> jnp.ndarray:
    return (jnp.arange(spec.rows, dtype=jnp.int32) * spec.words_per_row)


def _ds_gather(arr: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """(k,) positions -> (k,) values as UNROLLED 1-element dynamic slices.

    The sharded path reads the doubled [global || delta] sketch buffers;
    above ~256KB of operand XLA CPU's parallel task partitioner starts
    multithreading the k-element gather fusions (outer_dimension_partitions
    on a 3..8-element output), putting a thread-pool dispatch on every
    access — measured 3-5x at width 2^17.  Scalar dynamic slices are
    costed by the slice, not the operand, and a 1-element output cannot be
    partitioned.

    Lane mode (:data:`_LANE_TRACE`): one fused fancy-indexing gather — the
    unrolled scalar slices would batch into k separate gather ops, and the
    per-tenant buffers of a lane-batched run sit far below the partitioner
    cliff the unrolling works around.  (A one-hot select-and-sum
    contraction was tried instead and measured ~25% SLOWER at B=64: the
    reduce roots fragment the fused where-chains.)
    """
    if _LANE_TRACE[0]:
        return arr[idx]
    return jnp.concatenate([jax.lax.dynamic_slice(arr, (idx[i],), (1,))
                            for i in range(idx.shape[0])])


# operand bytes beyond which the unsharded sketch reads switch from fused
# fancy-indexing gathers to the unrolled-scalar-slice discipline: the
# partitioner cliff lands at ~512KB single-half buffers (width 2^18 at the
# default geometry — ROADMAP "XLA-CPU cost-model cliffs"), while BELOW it
# the fused gathers are measurably cheaper (~1.4x at C=512; the same
# size-dependent trade as the flat path's fused masked reset).  The sharded
# branches stay unconditionally unrolled — their doubled buffers cliff a
# tier earlier and PR 4 measured them there.
_PARTITION_CLIFF_BYTES = 1 << 19


def _big_operand(nwords: int) -> bool:
    return nwords * 4 >= _PARTITION_CLIFF_BYTES


# ---------------------------------------------------------------------------
# lane-batched write discipline (StepSpec.streams > 1)
# ---------------------------------------------------------------------------
# Trace-time flag: True only while the streams dispatcher (_step_lanes) is
# vmapping the streams=1 program over the lane axis.  Under vmap, every
# single-slot write whose index is traced PER LANE (argmin/argmax results,
# hashed probe words) would batch from dynamic_update_slice into an XLA
# scatter — and on XLA CPU each scatter op carries a ~7µs FIXED dispatch
# cost regardless of operand size, which caps lane scaling at ~2x (measured;
# the scatter "unique_indices" hints make it WORSE).  The helpers below emit
# today's exact .at[]/DUS expressions when the flag is off — so the
# streams=1 trace stays byte-identical — and fused masked selects when it is
# on: chained one-hot `where` passes over the same buffer fuse into ~one
# elementwise pass (cost ∝ bytes, no per-op penalty), which is what makes
# thousands of small tenant caches per step pay off.  Out-of-bounds
# semantics differ (.at clamps, the mask drops) but every wrapped index is
# an argmin/argmax/hash result, provably in bounds.  The flag is consulted
# at TRACE time only; cache safety follows from the jit key: traces with the
# flag on are only ever produced under a spec whose ``streams`` differs.
_LANE_TRACE = [False]


def _barrier(x):
    """``optimization_barrier`` — identity under lanes: the barrier is an
    XLA-CPU scheduling hint for the in-place DUS discipline (which the lane
    form replaces with fused selects) and it has no vmap batching rule."""
    if _LANE_TRACE[0]:
        return x
    return jax.lax.optimization_barrier(x)


def _lset(arr, j, v, pred=None):
    """``arr.at[j].set(where(pred, v, arr[j]))`` — scatter-free under lanes.

    The predicate folds INTO the one-hot mask in lane mode (one fused
    select, NO ``arr[j]`` gather): the gathers the unbatched expression
    embeds would otherwise break the fused where-chain into separate
    full-buffer passes, which measured ~100 unfused (B, N) sweeps per step.
    """
    if not _LANE_TRACE[0]:
        if pred is None:
            return arr.at[j].set(v)
        return arr.at[j].set(jnp.where(pred, v, arr[j]))
    iota = jnp.arange(arr.shape[0], dtype=jnp.int32)
    m = (iota == j) if pred is None else ((iota == j) & pred)
    return jnp.where(m, v, arr)


def _lset_row(arr, j, row, pred=None):
    """``arr.at[j].set(where(pred, row, arr[j]))`` (2-D arr, row write)."""
    if not _LANE_TRACE[0]:
        if pred is None:
            return arr.at[j].set(row)
        return arr.at[j].set(jnp.where(pred, row, arr[j]))
    iota = jnp.arange(arr.shape[0], dtype=jnp.int32)
    m = (iota == j) if pred is None else ((iota == j) & pred)
    return jnp.where(m[:, None], row[None, :], arr)


def _lset_col(arr, col, v):
    """``arr.at[:, col].set(v)`` (STATIC col) — scatter-free variant."""
    if not _LANE_TRACE[0]:
        return arr.at[:, col].set(v)
    iota = jnp.arange(arr.shape[1], dtype=jnp.int32)
    return jnp.where(iota[None, :] == col, v[:, None], arr)


def _ldus1(arr, upd, j):
    """``dynamic_update_slice(arr, upd, (j,))`` with a (1,) update."""
    if not _LANE_TRACE[0]:
        return jax.lax.dynamic_update_slice(arr, upd, (j,))
    iota = jnp.arange(arr.shape[0], dtype=jnp.int32)
    return jnp.where(iota == j, upd[0], arr)


def _ldus_block(tab, blk, s, A):
    """``dynamic_update_slice(tab, blk, (s*A, 0))`` — whole-set block write.

    Takes the SET index ``s`` (not the row offset): the lane form exploits
    the set alignment to reshape ``tab`` to (n_sets, A, cols) and select the
    target set with a one-hot broadcast — a generic batched block update
    (take_along_axis) would instead materialize full-table gathers.
    """
    if not _LANE_TRACE[0]:
        return jax.lax.dynamic_update_slice(tab, blk, (s * A, 0))
    n_sets = tab.shape[0] // A
    t3 = tab.reshape(n_sets, A, tab.shape[1])
    iota = jnp.arange(n_sets, dtype=jnp.int32)
    t3 = jnp.where((iota == s)[:, None, None], blk[None, :, :], t3)
    return t3.reshape(tab.shape)


def _counter_vals(spec: StepSpec, words: jnp.ndarray,
                  idx: jnp.ndarray) -> jnp.ndarray:
    """counter_bits-wide counter values at probe positions idx (…, rows)."""
    sub = idx & (spec.counters_per_word - 1)
    return ((words >> (sub * spec.counter_bits))
            & jnp.int32(spec.counter_cap_max))


def _word_of(spec: StepSpec, idx: jnp.ndarray) -> jnp.ndarray:
    return idx >> (3 if spec.counter_bits == 4 else 2)


def _sketch_add(spec: StepSpec, params, counters, dk, size, kidx, kdkb,
                *, use_cond: bool = False):
    """FrequencySketch.add(): doorkeeper gate -> minimal increment -> reset.

    ``kidx`` (rows,) precomputed probe indices; ``kdkb`` (dkp,) doorkeeper
    bit positions.  Row gathers/scatters are one vectorized op each.

    ``use_cond`` runs the §3.3 reset as a ``lax.cond`` so the O(width)
    halving pass executes only on the accesses where it actually fires
    (the set-associative path needs this for capacity-independent access
    cost); the flat path keeps the fused masked ``where`` which measured
    faster at its small sizes.

    Sharded mode (``spec.shards > 1``): ``counters``/``dk`` carry
    [global || delta] halves in one buffer.  Only the delta half is
    written (probe indices confine the writes to the owning shard's
    slice); a counter's effective value is global+delta and a doorkeeper
    bit is global|delta, so between merges the combined structure evolves
    exactly like the unsharded sketch.  The §3.3 reset is SKIPPED here —
    it moves to the epoch-boundary ``merge_halve`` fold.  (One buffer, not
    separate delta arrays: the single-buffer DUS chain is the shape XLA
    CPU's copy elision already handles in place on the unsharded path —
    separate delta buffers measured 4 full-array copies per access.)

    Mesh mode (``spec.mesh_devices > 0``): dispatched to
    :func:`_sketch_add_mesh` — ``counters``/``dk`` arrive as
    (global, local-delta) tuples inside a shard_map body.
    """
    if spec.mesh_devices:
        return _sketch_add_mesh(spec, params, counters, dk, size, kidx, kdkb)
    # single-word writes are dynamic_update_slice, NOT scatter (.at[].set):
    # XLA CPU updates a loop-carried buffer in place for DUS but lowers the
    # equivalent scatter to a full-array copy, which would put an O(width)
    # copy on every access and sink the capacity-independent set path
    if spec.dk_bits:
        # host _dk_put semantics (a later probe of the same access observes
        # bits set by an earlier one), restructured as ONE gather + straight-
        # line writes: intra-access carry is resolved in-register via pairwise
        # probe comparisons, and duplicate-word writes carry identical merged
        # values.  Interleaving reads between the writes defeats XLA CPU's
        # in-place analysis and costs a full dk copy per read.
        np_ = spec.dk_probes
        w_idx = kdkb >> 5
        bpos = kdkb & 31
        if spec.shards > 1:
            dw_idx = spec.dk_words + w_idx             # delta half (written)
            # barrier: materialize BOTH gathers before any write fusion —
            # a dynamic-slice read fused INTO a later DUS write re-reads
            # the original buffer mid-chain, keeping it live and costing
            # two full copies per access
            words, gwords = _barrier(
                (_ds_gather(dk, dw_idx), _ds_gather(dk, w_idx)))
            eff_words = words | gwords                 # | global half (read)
            # the global-half gather feeds only the LATER counter writes
            # (via the gate), not the dk writes below — anchor it into the
            # first dk write or the scheduler may run it after the write
            # and copy the whole doorkeeper every access (see _sched_dep)
            zdk = _sched_dep(eff_words)
        else:
            dw_idx = w_idx
            if _big_operand(spec.dk_words):
                # unrolled scalar-slice gather + barrier, same discipline
                # as the sharded branch: the fused (dkp,)-element gather is
                # costed by its OPERAND and the parallel task partitioner
                # multithreads it past the cliff — a thread-pool dispatch
                # per access
                words = _barrier(_ds_gather(dk, w_idx))
            else:
                words = dk[w_idx]                      # (dkp,) one gather
            eff_words = words
            zdk = None
        pre = (eff_words >> bpos) & 1
        present = jnp.int32(1)
        for i in range(np_):
            eff = pre[i]
            for j in range(i):                         # set by earlier probe?
                eff = eff | (kdkb[j] == kdkb[i]).astype(jnp.int32)
            present &= eff
        bitm = jnp.int32(1) << bpos
        for i in range(np_):
            merged = words[i] | bitm[i]
            if i == 0 and zdk is not None:
                merged = merged | zdk                  # always 0; see above
            for j in range(np_):
                if j != i:                             # same-word probes merge
                    merged = merged | jnp.where(w_idx[j] == w_idx[i],
                                                bitm[j], 0)
            dk = _ldus1(dk, merged[None], dw_idx[i])
        gate = present.astype(jnp.bool_)   # repeat visitor -> main table
    else:
        gate = jnp.bool_(True)

    flat = _row_offsets(spec) + _word_of(spec, kidx)   # (rows,) word positions
    if spec.shards > 1:
        dflat = spec.counter_words + flat              # delta half (written)
        # barrier: same read-materialization discipline as the doorkeeper
        words, gw = _barrier(
            (_ds_gather(counters, dflat), _ds_gather(counters, flat)))
        # conservative update judges the COMBINED count; the bump lands in
        # the delta field.  bump only fires while the combined min < cap,
        # so every field keeps global+delta <= cap (no overflow, and the
        # merge fold never actually saturates in-engine).  The min runs as
        # an unrolled minimum chain, not a reduce: XLA CPU's parallel task
        # partitioner multithreads small reduce fusions whose fused gathers
        # touch big operands, costing a thread dispatch per access
        vals = (_counter_vals(spec, words, kidx)
                + _counter_vals(spec, gw, kidx))
        m = vals[0]
        for r in range(1, spec.rows):
            m = jnp.minimum(m, vals[r])
    else:
        dflat = flat
        if _big_operand(spec.counter_words):
            # unrolled scalar-slice gather + unrolled minimum chain (not a
            # reduce): the same in-place discipline the sharded path needed
            # — a fused (rows,)-gather over a >= 2^18-counter buffer gets
            # multithreaded by the parallel task partitioner, putting a
            # thread-pool dispatch on every access
            words = _barrier(_ds_gather(counters, flat))
            vals = _counter_vals(spec, words, kidx)
            m = vals[0]
            for r in range(1, spec.rows):
                m = jnp.minimum(m, vals[r])
        else:
            words = counters[flat]
            vals = _counter_vals(spec, words, kidx)
            m = vals.min()
    bump = gate & (m < params[P_CAP])
    sub = kidx & (spec.counters_per_word - 1)
    new = jnp.where(bump & (vals == m),
                    words + (jnp.int32(1) << (sub * spec.counter_bits)), words)
    for r in range(spec.rows):         # rows write disjoint word segments
        counters = _ldus1(counters, new[r][None], dflat[r])

    size = size + 1
    if spec.shards > 1:
        # sharded: aging is deferred to the epoch-boundary merge_halve fold
        # (kernels/sketch_merge.py) — the per-access path never resets
        return counters, dk, size
    do_reset = (params[P_SAMPLE] > 0) & (size >= params[P_SAMPLE])
    # lanes: the dynamic-trip-count word loops would batch into a masked
    # while over PER-LANE trip counts with one scatter per word — the fused
    # masked pass (identical arithmetic) is the scatter-free form, and the
    # small per-tenant sketches of a lane-batched run sit well below the
    # size where the masked pass was ever a problem
    if use_cond and not _LANE_TRACE[0]:
        # dynamic-trip-count word loops: 0 iterations on the (vast majority
        # of) accesses where no reset fires, in-place single-word updates
        # when it does.  Neither lax.cond (copies its big operands on every
        # call) nor a masked where (a full O(width) pass every access) keeps
        # the set path's per-access cost capacity-independent on XLA CPU.
        def halve_one(i, c):
            w = jax.lax.dynamic_slice(c, (i,), (1,))
            return jax.lax.dynamic_update_slice(
                c, halve_words(w, spec.counter_bits), (i,))

        def zero_one(i, d):
            return jax.lax.dynamic_update_slice(
                d, jnp.zeros((1,), jnp.int32), (i,))

        counters = jax.lax.fori_loop(
            0, jnp.where(do_reset, counters.shape[0], 0), halve_one, counters)
        dk = jax.lax.fori_loop(
            0, jnp.where(do_reset, dk.shape[0], 0), zero_one, dk)
        size = jnp.where(do_reset, size // 2, size)
    else:
        # select, not lax.cond: XLA CPU cond copies its operand buffers every
        # step, which costs more than the fused masked pass it would skip at
        # the flat path's small sketch sizes
        counters = jnp.where(do_reset,
                             halve_words(counters, spec.counter_bits),
                             counters)
        dk = jnp.where(do_reset, jnp.zeros_like(dk), dk)
        size = jnp.where(do_reset, size // 2, size)
    return counters, dk, size


def _sketch_add_mesh(spec: StepSpec, params, counters, dk, size, kidx, kdkb):
    """Multi-device twin of the sharded ``_sketch_add`` branch (runs inside
    a ``shard_map`` body over :data:`MESH_AXIS`).

    ``counters`` is a (global ``(counter_words,)``, local delta
    ``(local_shards, rows, wps_shard)``) pair; ``dk`` likewise with the
    local doorkeeper deltas ``(local_shards, dkw_shard)``.  Every device
    runs the identical replicated computation over the replicated global
    halves and cache tables, but a key's delta slice is resident on exactly
    one device (block placement: device ``d`` owns shards
    ``[d*L, (d+1)*L)``), so the masked delta writes are device-local and
    the sketch add needs NO cross-device exchange: the doorkeeper gate and
    the conservative-update bump are consumed only by the owner's writes —
    a non-owner computes don't-care values there and writes nothing.
    Arithmetic is field-for-field the single-device sharded branch, so the
    combined [global || all-gathered deltas] state evolves bit-identically.
    """
    cg, cd = counters
    dkg, dd = dk
    L = spec.local_shards
    ks = kidx[0] // spec.width_shard             # owning shard (rows agree)
    base = jax.lax.axis_index(MESH_AXIS).astype(jnp.int32) * L
    local = (ks >= base) & (ks < base + L)
    lks = jnp.clip(ks - base, 0, L - 1)
    cdf = cd.reshape(-1)
    ddf = dd.reshape(-1)

    if spec.dk_bits:
        np_ = spec.dk_probes
        w_idx = kdkb >> 5                        # global-half word positions
        bpos = kdkb & 31
        # local delta word: shard-major (local shard, word-within-shard)
        ldw = lks * spec.dkw_shard + ((kdkb - ks * spec.dk_bits_shard) >> 5)
        words, gwords = jax.lax.optimization_barrier(
            (_ds_gather(ddf, ldw), _ds_gather(dkg, w_idx)))
        # owner composes delta|global exactly like the single-device branch;
        # a non-owner's `present` is a don't-care (bump writes are masked)
        eff_words = jnp.where(local, words, 0) | gwords
        pre = (eff_words >> bpos) & 1
        present = jnp.int32(1)
        for i in range(np_):
            eff = pre[i]
            for j in range(i):                   # set by an earlier probe?
                eff = eff | (kdkb[j] == kdkb[i]).astype(jnp.int32)
            present &= eff
        bitm = jnp.int32(1) << bpos
        for i in range(np_):
            merged = words[i] | bitm[i]
            for j in range(np_):
                if j != i:                       # same-word probes merge
                    merged = merged | jnp.where(w_idx[j] == w_idx[i],
                                                bitm[j], 0)
            ddf = jax.lax.dynamic_update_slice(
                ddf, jnp.where(local, merged, words[i])[None], (ldw[i],))
        gate = present.astype(jnp.bool_)
    else:
        gate = jnp.bool_(True)

    flat = _row_offsets(spec) + _word_of(spec, kidx)      # global positions
    h = kidx - ks * spec.width_shard             # per-shard probe offsets
    dflat = ((lks * spec.rows + jnp.arange(spec.rows, dtype=jnp.int32))
             * spec.wps_shard + _word_of(spec, h))
    words, gw = jax.lax.optimization_barrier(
        (_ds_gather(cdf, dflat), _ds_gather(cg, flat)))
    vals = (jnp.where(local, _counter_vals(spec, words, kidx), 0)
            + _counter_vals(spec, gw, kidx))
    m = vals[0]
    for r in range(1, spec.rows):
        m = jnp.minimum(m, vals[r])
    bump = gate & (m < params[P_CAP])
    sub = kidx & (spec.counters_per_word - 1)
    new = jnp.where(bump & (vals == m),
                    words + (jnp.int32(1) << (sub * spec.counter_bits)), words)
    for r in range(spec.rows):
        cdf = jax.lax.dynamic_update_slice(
            cdf, jnp.where(local, new[r], words[r])[None], (dflat[r],))
    # aging is deferred to the epoch-boundary all-gather merge_halve fold
    return ((cg, cdf.reshape(cd.shape)), (dkg, ddf.reshape(dd.shape)),
            size + 1)


def _estimate_pair_stale(spec: StepSpec, counters, dk, idx2, dkb2):
    """Mesh twin of the sharded ``_estimate_pair`` branch — speculative
    stale-global admission (``mesh_exchange="stale"``), ZERO cross-device
    exchange.

    Estimates read ONLY the replicated global halves: every device computes
    the identical (replicated) verdict locally, so the cache tables never
    diverge and the per-access path stays collective-free.  The local delta
    — even on the device that owns the entry's shard — is deliberately
    ignored: composing it would make the owner's verdict differ from the
    other devices' and fork the replicated tables.  The estimate is
    therefore stale by at most one merge epoch; the once-per-epoch
    :func:`repro.kernels.sketch_merge.merge_halve_mesh` all-gather
    reconciles it, bounding the hit-ratio deviation to the goldens-±0.01
    tier (tests/test_distributed.py pins this, next to the bit-exact host
    twin ``core.sketch.ShardedFrequencySketch(stale_estimates=True)``).

    This replaced the original per-access 2-int ``psum`` (one collective
    per access — measured 62.8x the single-device sharded cost on the
    forced-2-device bench); the exact path is now the "chunk" mode, which
    never calls the mesh estimator at all.
    """
    cg, _cd = counters
    dkg, _dd = dk
    flat2 = _row_offsets(spec)[None, :] + _word_of(spec, idx2)
    gw = _ds_gather(cg, flat2.reshape(-1)).reshape(2, spec.rows)
    vals = _counter_vals(spec, gw, idx2)
    est = vals[:, 0]
    for r in range(1, spec.rows):
        est = jnp.minimum(est, vals[:, r])
    if spec.dk_bits:
        bb = (dkb2 >> 5).reshape(-1)
        gbits = _ds_gather(dkg, bb).reshape(2, spec.dkp)
        bits = (gbits >> (dkb2 & 31)) & 1
        ok = bits[:, 0]
        for p in range(1, bits.shape[1]):
            ok = ok & bits[:, p]
        est = est + ok
    return est


def _estimate_pair(spec: StepSpec, counters, dk, idx2, dkb2):
    """TinyLFU estimates for two resident entries from their stored probes.

    idx2: (2, rows); dkb2: (2, dkp) -> (2,) int32 estimates.

    Sharded mode: an estimate composes the global half + the delta half of
    the split buffers (each entry's stored probes already point into its
    owning shard's slice).  The row min / doorkeeper all run as unrolled
    chains instead of reduces — XLA CPU's parallel task partitioner
    multithreads reduce fusions whose fused gathers touch the doubled
    buffers, costing a thread-pool dispatch per access (measured 5x).

    The unsharded branch switches to the same discipline (unrolled
    scalar-slice gathers + unrolled reduce chains) once its buffers reach
    ``_big_operand`` (~512KB, width >= 2^18 at default geometry — ROADMAP
    "XLA-CPU cost-model cliffs"); below that the fused gathers are cheaper
    and every pre-cliff program stays byte-identical to the PR 4 one.

    Mesh mode dispatches to :func:`_estimate_pair_stale` — stale-global
    admission, the only per-access estimator that ever runs inside a
    shard_map body (``mesh_exchange="chunk"`` replays the single-device
    program with ``mesh_devices=0``, so it takes the sharded branch here).
    """
    if spec.mesh_devices:
        return _estimate_pair_stale(spec, counters, dk, idx2, dkb2)
    flat2 = _row_offsets(spec)[None, :] + _word_of(spec, idx2)
    ff = flat2.reshape(-1)
    k = ff.shape[0]
    if spec.shards > 1:
        gw = _ds_gather(counters, ff).reshape(2, k // 2)
        dw = _ds_gather(counters, spec.counter_words + ff).reshape(2, k // 2)
        vals = (_counter_vals(spec, gw, idx2)
                + _counter_vals(spec, dw, idx2))
        est = vals[:, 0]
        for r in range(1, spec.rows):
            est = jnp.minimum(est, vals[:, r])
    elif _big_operand(spec.counter_words):
        gw = _ds_gather(counters, ff).reshape(2, k // 2)
        vals = _counter_vals(spec, gw, idx2)
        est = vals[:, 0]
        for r in range(1, spec.rows):
            est = jnp.minimum(est, vals[:, r])
    else:
        vals = _counter_vals(spec, counters[flat2], idx2)
        est = vals.min(axis=-1)
    if spec.dk_bits:
        bb = (dkb2 >> 5).reshape(-1)
        kb = bb.shape[0]
        if spec.shards > 1 or _big_operand(spec.dk_words):
            if spec.shards > 1:
                w2 = (_ds_gather(dk, bb)
                      | _ds_gather(dk, spec.dk_words + bb)).reshape(2,
                                                                    kb // 2)
            else:
                w2 = _ds_gather(dk, bb).reshape(2, kb // 2)
            bits = (w2 >> (dkb2 & 31)) & 1
            ok = bits[:, 0]
            for p in range(1, bits.shape[1]):
                ok = ok & bits[:, p]
            est = est + ok
        else:
            w2 = dk[dkb2 >> 5]
            ok = (((w2 >> (dkb2 & 31)) & 1) == 1).all(axis=-1)
            est = est + ok.astype(jnp.int32)
    return est


def _estimate_block(spec: StepSpec, counters, dk, idxs, dkbs):
    """TinyLFU estimates for K records from their stored probes.

    idxs: (K, rows); dkbs: (K, dkp) -> (K,) int32 estimates.  K-record
    generalization of :func:`_estimate_pair` for the competitor policies
    (the ``"lfu"`` victim scan estimates every record of both choice sets;
    ``"s3fifo"`` estimates the displaced candidate alone).  Competitors
    run unsharded and mesh-free by construction (StepSpec asserts), so
    only the two unsharded disciplines exist: fused fancy-indexing
    gathers below the ``_big_operand`` cliff, unrolled scalar slices +
    unrolled reduce chains past it (same rationale as ``_estimate_pair``).
    """
    k = idxs.shape[0]
    flat = _row_offsets(spec)[None, :] + _word_of(spec, idxs)
    if _big_operand(spec.counter_words):
        gw = _ds_gather(counters, flat.reshape(-1)).reshape(k, spec.rows)
        vals = _counter_vals(spec, gw, idxs)
        est = vals[:, 0]
        for r in range(1, spec.rows):
            est = jnp.minimum(est, vals[:, r])
    else:
        vals = _counter_vals(spec, counters[flat], idxs)
        est = vals.min(axis=-1)
    if spec.dk_bits:
        if _big_operand(spec.dk_words):
            w2 = _ds_gather(dk, (dkbs >> 5).reshape(-1)).reshape(k, spec.dkp)
            bits = (w2 >> (dkbs & 31)) & 1
            ok = bits[:, 0]
            for p in range(1, bits.shape[1]):
                ok = ok & bits[:, p]
            est = est + ok
        else:
            w2 = dk[dkbs >> 5]
            ok = (((w2 >> (dkbs & 31)) & 1) == 1).all(axis=-1)
            est = est + ok.astype(jnp.int32)
    return est


def _one_access_flat(spec: StepSpec, params: jnp.ndarray, state: dict,
                     klo, khi, kidx, kdkb):
    """Advance the full W-TinyLFU state by one access (exact flat tables).

    ``spec.adaptive`` swaps the capacity mechanism: instead of init-time
    padding, the window quota lives in ``regs[R_WQUOTA]`` and resident
    counts (``R_WCOUNT``/``R_MCOUNT``) gate inserts — at quota the argmin
    hides empty slots so the LRU/SLRU victim is displaced exactly as if the
    table were that size.  All adaptive logic is under a static Python
    branch, so ``adaptive=False`` compiles to the identical program.
    """
    regs = state["regs"]
    t = regs[R_T]

    # -- 1. admission.record(key): sketch add + automatic §3.3 reset ---------
    # (sharded: the add writes the delta half only; aging waits for the
    # epoch-boundary merge_halve fold; mesh: global/local-delta pairs)
    if spec.mesh_devices:
        cin = (state["counters"], state["dcounters"])
        din = (state["doorkeeper"], state["ddoorkeeper"])
    else:
        cin, din = state["counters"], state["doorkeeper"]
    counters, dk, size = _sketch_add(spec, params, cin, din, regs[R_SIZE],
                                     kidx, kdkb)

    wlo, whi, wmeta = state["wlo"], state["whi"], state["wmeta"]
    widx, wdkb = state["widx"], state["wdkb"]
    mlo, mhi, mmeta = state["mlo"], state["mhi"], state["mmeta"]
    midx, mdkb = state["midx"], state["mdkb"]

    if spec.adaptive:
        wquota = regs[R_WQUOTA]
        wcount = regs[R_WCOUNT]
        mcount = regs[R_MCOUNT]
        # total capacity is split at runtime: main gets what the window
        # quota leaves; the protected budget keeps the static FRACTION
        # (prot_cap/main_cap scales with the runtime main capacity, and
        # equals params[P_PROT_CAP] exactly when the quota sits at its
        # configured split — the pinned-quota differential tests rely on it)
        mcap_rt = params[P_WINDOW_CAP] + params[P_MAIN_CAP] - wquota
        prot_rt = jnp.maximum(1, mcap_rt * params[P_PROT_CAP]
                              // jnp.maximum(1, params[P_MAIN_CAP]))
        # adaptive stamps are globally unique ACROSS tables (window even,
        # main odd): one access can stamp both tables (window insert +
        # candidate admit), and the rebalance later migrates window records
        # into main — colliding stamps there would leave victim selection
        # to slot-index tie-breaks no host twin can mirror.  Within a
        # table the 2t/2t+1 mapping preserves every ordering, so a pinned
        # quota still reproduces the static path's hit sequence exactly.
        wst = t + t
        mst = t + t + 1
    else:
        prot_rt = params[P_PROT_CAP]
        wst = t
        mst = t

    # -- 2. lookups (meta >= 0 <=> resident; padding slots hold sentinel key)
    eqw = (wlo == klo) & (whi == khi)
    eqm = (mlo == klo) & (mhi == khi)
    jw = jnp.argmax(eqw)
    jm = jnp.argmax(eqm)
    if _LANE_TRACE[0]:
        # a key occupies at most one slot per table (inserts fire only on
        # miss), so the gather-at-argmax hit test collapses to a reduction
        # over the already-materialized equality mask — each scalar gather
        # op in the batched program breaks the fused elementwise chain and
        # its fixed dispatch cost dominates the small-tenant lane step
        hit_w = jnp.any(eqw & (wmeta >= 0))
        hit_m = jnp.any(eqm & (mmeta >= 0))
        promote = hit_m & jnp.any(eqm & (mmeta >= 0) & (mmeta < _PROT))
    else:
        hit_w = (wlo[jw] == klo) & (whi[jw] == khi) & (wmeta[jw] >= 0)
        hit_m = (mlo[jm] == klo) & (mhi[jm] == khi) & (mmeta[jm] >= 0)
        promote = hit_m & (mmeta[jm] < _PROT)
    hit = hit_w | hit_m

    # -- 3a. window hit: refresh LRU stamp -----------------------------------
    wmeta = _lset(wmeta, jw, wst, hit_w)

    # -- 3b. main hit: SLRU promote-or-refresh -> protected MRU --------------
    mmeta = _lset(mmeta, jm, _PROT | mst, hit_m)
    pcount = regs[R_PCOUNT] + promote.astype(jnp.int32)
    # protected overflow -> demote its LRU entry back to probation MRU.
    # Adaptive: a rebalance can shrink the runtime budget below the resident
    # protected count, so the drain is gated on a main hit (one demotion per
    # promote-or-refresh, like the host twin) — draining on every access
    # would stamp a demotion at t in the same access that inserts a
    # window-displaced candidate at t, breaking stamp uniqueness.  In the
    # static path over implies a promote just happened (the budget is
    # constant), so the gate is vacuous there and the branch keeps the
    # compiled program identical.
    if spec.adaptive:
        over = hit_m & (pcount > prot_rt)
    else:
        over = pcount > prot_rt
    kd = jnp.argmin(jnp.where(mmeta >= _PROT, mmeta, _I32_MAX))
    mmeta = _lset(mmeta, kd, mst, over)
    pcount = pcount - over.astype(jnp.int32)

    # -- 4. miss: insert into window; LRU overflow asks admission ------------
    miss = ~hit
    # argmin(wmeta): empty (-1) before LRU stamps; padding (+MAX) never picked
    if spec.adaptive:
        # at quota, hide the (statically unpadded) empty slots so the argmin
        # lands on the LRU resident — the runtime equivalent of padding
        at_wcap = wcount >= wquota
        ws = jnp.argmin(jnp.where(at_wcap & (wmeta == _EMPTY), _I32_MAX,
                                  wmeta))
    else:
        ws = jnp.argmin(wmeta)
    if _LANE_TRACE[0] and not spec.adaptive:
        # ws == argmin(wmeta), so the gathered value IS the min — the
        # reduction reuses the argmin's input and saves a gather op
        wsmeta = wmeta.min()
    else:
        wsmeta = wmeta[ws]
    push = miss & (wsmeta >= 0)                 # evicting a resident entry
    if spec.adaptive:                           # R_WCOUNT bookkeeping
        w_filled = miss & (wsmeta == _EMPTY)
    cand_lo, cand_hi = wlo[ws], whi[ws]
    cand_idx, cand_dkb = widx[ws], wdkb[ws]
    wlo = _lset(wlo, ws, klo, miss)
    whi = _lset(whi, ws, khi, miss)
    wmeta = _lset(wmeta, ws, wst, miss)
    widx = _lset_row(widx, ws, kidx, miss)
    wdkb = _lset_row(wdkb, ws, kdkb, miss)

    # single argmin = free slot < probation LRU < protected LRU (exact SLRU
    # victim priority); padding (+MAX) is unreachable
    if spec.adaptive:
        at_mcap = mcount >= mcap_rt
        tslot = jnp.argmin(jnp.where(at_mcap & (mmeta == _EMPTY), _I32_MAX,
                                     mmeta))
    else:
        tslot = jnp.argmin(mmeta)
    if _LANE_TRACE[0] and not spec.adaptive:
        vmeta = mmeta.min()                     # == mmeta[argmin(mmeta)]
    else:
        vmeta = mmeta[tslot]
    m_free = vmeta < 0
    # fused TinyLFU verdict from stored probes (post-record sketch state)
    est = _estimate_pair(spec, counters, dk,
                         jnp.stack([cand_idx, midx[tslot]]),
                         jnp.stack([cand_dkb, mdkb[tslot]]))
    admit = est[0] > est[1]
    do_ins = push & (m_free | admit)
    mlo = _lset(mlo, tslot, cand_lo, do_ins)
    mhi = _lset(mhi, tslot, cand_hi, do_ins)
    mmeta = _lset(mmeta, tslot, mst, do_ins)
    midx = _lset_row(midx, tslot, cand_idx, do_ins)
    mdkb = _lset_row(mdkb, tslot, cand_dkb, do_ins)
    pcount = pcount - (do_ins & (vmeta >= _PROT)).astype(jnp.int32)

    # -- 5. bookkeeping ------------------------------------------------------
    counted = (hit & (t >= params[P_WARMUP])).astype(jnp.int32)
    if spec.adaptive:
        regs = jnp.stack([size, pcount, t + 1, regs[R_HITS] + counted,
                          wquota,
                          wcount + w_filled.astype(jnp.int32),
                          mcount + (do_ins & m_free).astype(jnp.int32),
                          regs[R_EHITS] + hit.astype(jnp.int32)])
    else:
        regs = jnp.stack([size, pcount, t + 1, regs[R_HITS] + counted,
                          regs[4], regs[5], regs[6], regs[7]])
    if spec.mesh_devices:
        (cg, cd), (dkg, dd) = counters, dk
        sketch = {"counters": cg, "doorkeeper": dkg,
                  "dcounters": cd, "ddoorkeeper": dd}
    else:
        sketch = {"counters": counters, "doorkeeper": dk}
    # {**state, ...} first: access-invariant keys (e.g. the "csum" integrity
    # vector, touched only by the epoch-boundary merge fold) ride through the
    # scan carry unchanged
    new_state = {**state, **sketch,
                 "wlo": wlo, "whi": whi, "wmeta": wmeta,
                 "widx": widx, "wdkb": wdkb,
                 "mlo": mlo, "mhi": mhi, "mmeta": mmeta,
                 "midx": midx, "mdkb": mdkb, "regs": regs}
    return new_state, hit.astype(jnp.int32)


def _sched_dep(x: jnp.ndarray) -> jnp.ndarray:
    """A data-dependent int32 scalar that is always 0 but opaque to XLA.

    (d >> 31) & (~d >> 31) is zero for every d, yet XLA's simplifier cannot
    prove it.  OR-ing this into the FIRST dynamic_update_slice of a
    loop-carried table forces every computation that read the pre-write
    table to transitively feed that write, so the scheduler runs all reads
    first and the write happens in place.  Without it, XLA CPU may schedule
    an independent read-fusion (e.g. a lookup reduce consumed only by a
    later write) after the first write and must then copy the WHOLE table
    every access — turning the O(ways) step back into O(capacity).
    """
    d = x.reshape(-1)[0]
    return (d >> 31) & ((~d) >> 31)


def _one_access_set(spec: StepSpec, params: jnp.ndarray, state: dict,
                    klo, khi, kidx, kdkb, kwset, kmset):
    """One access against W-way set-associative tables: every table touch is
    a contiguous (assoc, cols) gather + reduce — O(ways), capacity-free.

    The main table uses power-of-two-choices placement: a key may reside in
    either of its two hashed sets (lookups probe both); a displaced window
    candidate is admitted against the weakest of its two sets' 2*ways
    records, which both balances set load and doubles the victim pool —
    together this recovers most of the exact global-SLRU hit ratio.

    Dataflow discipline: ALL gathers read the pre-access tables up front;
    aliasing between the key's sets and the candidate's sets is composed
    with selects; the writes go last, with :func:`_sched_dep` anchoring
    every read before the first write so the tables update in place.
    """
    A = spec.assoc
    rows, dkp = spec.rows, spec.dkp
    regs = state["regs"]
    t = regs[R_T]

    # -- 1. admission.record(key): sketch add + amortized in-place reset -----
    # (sharded: the add writes the delta half only; no per-access reset —
    # aging happens in the epoch-boundary merge_halve fold; mesh:
    # global/local-delta pairs)
    if spec.mesh_devices:
        cin = (state["counters"], state["dcounters"])
        din = (state["doorkeeper"], state["ddoorkeeper"])
    else:
        cin, din = state["counters"], state["doorkeeper"]
    counters, dk, size = _sketch_add(spec, params, cin, din, regs[R_SIZE],
                                     kidx, kdkb, use_cond=True)

    wtab, mtab = state["wtab"], state["mtab"]
    km1, km2 = kmset[0], kmset[1]
    same_km = km2 == km1

    if spec.adaptive:
        # runtime window quota: per-set usable ways come from the ``wuw``
        # state vector, refreshed by each epoch rebalance — uniform
        # (core.hashing.set_ways: first quota % n_sets sets keep one extra
        # way) while quota >= n_sets, load-aware below it (the quota's ways
        # go to the sets with the highest window traffic last epoch —
        # core.adaptive.window_set_ways), so small quotas no longer starve
        # hot sets.  Ways at or beyond a set's usable count READ as padding
        # (_I32_MAX) for every decision; the epoch rebalance keeps them
        # EMPTY in storage, so the write-back restores _EMPTY bit-exactly.
        wquota = regs[R_WQUOTA]
        mcap_rt = params[P_WINDOW_CAP] + params[P_MAIN_CAP] - wquota
        nws, nms = spec.window_sets, spec.main_sets
        way_ids = jnp.arange(A, dtype=jnp.int32)
        wuw = state["wuw"]

        def w_usable(s):
            return jax.lax.dynamic_slice(wuw, (s,), (1,))[0]

        def m_usable(s):
            return mcap_rt // nms + (s < mcap_rt % nms).astype(jnp.int32)

        def mask_ways(blk, u, col):
            return _lset_col(blk, col,
                             jnp.where(way_ids >= u, _I32_MAX, blk[:, col]))

        def unmask_ways(blk, u, col):
            return _lset_col(blk, col,
                             jnp.where(way_ids >= u, _EMPTY, blk[:, col]))
        # globally unique stamps across tables (window even, main odd):
        # see _one_access_flat — the rebalance migrates window records
        # into main, where a stamp collision would leave victim
        # selection to way-index tie-breaks
        wst = t + t
        mst = t + t + 1
    else:
        def mask_ways(blk, u, col):
            return blk

        def unmask_ways(blk, u, col):
            return blk

        def w_usable(s):
            return None

        def m_usable(s):
            return None
        wst = t
        mst = t

    # -- 2. lookups: the key's window set and both main choice sets ----------
    wblk = mask_ways(
        jax.lax.dynamic_slice(wtab, (kwset * A, 0), (A, spec.wcols)),
        w_usable(kwset), WT_META)
    wmeta = wblk[:, WT_META]
    match_w = (wblk[:, WT_LO] == klo) & (wblk[:, WT_HI] == khi) & (wmeta >= 0)
    hit_w = match_w.any()
    jw = jnp.argmax(match_w)

    mblk1 = mask_ways(
        jax.lax.dynamic_slice(mtab, (km1 * A, 0), (A, spec.mcols)),
        m_usable(km1), MT_META)
    mblk2 = mask_ways(
        jax.lax.dynamic_slice(mtab, (km2 * A, 0), (A, spec.mcols)),
        m_usable(km2), MT_META)

    def match_in(blk):
        return ((blk[:, MT_LO] == klo) & (blk[:, MT_HI] == khi)
                & (blk[:, MT_META] >= 0))

    match1 = match_in(mblk1)
    match2 = match_in(mblk2) & ~same_km     # aliased choices: count set1 only
    hit1 = match1.any()
    hit2 = match2.any()
    hit_m = hit1 | hit2
    hit = hit_w | hit_m

    # -- 3a. window hit/miss: refresh stamp, insert on miss (not yet written)
    wmeta = _lset(wmeta, jw, wst, hit_w)
    miss = ~hit
    ws = jnp.argmin(wmeta)
    newrow = jnp.concatenate(
        [jnp.stack([klo, khi, wst, km1, km2]), kidx, kdkb]).astype(jnp.int32)
    # padding (+MAX) can only win the argmin in a zero-way set (vmapped
    # sweeps far below the shared geometry, or degenerate tiny windows):
    # such an access bypasses the window — the incoming key itself becomes
    # the admission candidate, exactly like the host twin's insert-then-
    # immediately-displace
    w_ok = wmeta[ws] != _I32_MAX
    push = miss & ((wmeta[ws] >= 0) | ~w_ok)
    cand = jnp.where(w_ok, wblk[ws], newrow)    # full packed record
    wblk = _lset_col(wblk, WT_META, wmeta)
    wblk = _lset_row(wblk, ws, newrow, miss & w_ok)

    # -- 3b. main hit: SLRU promote-or-refresh within the RESIDENT set -------
    def hit_update(blk, match, hit_half):
        meta = blk[:, MT_META]
        j = jnp.argmax(match)
        meta = _lset(meta, j, _PROT | mst, hit_half)
        # the set's protected budget scales its usable ways by the global
        # protected fraction; counting resident protected beats carrying a
        # per-set register (padding meta +MAX excluded: stamps < 2^31-1)
        usable = (meta != _I32_MAX).sum()
        nprot = ((meta >= _PROT) & (meta != _I32_MAX)).sum()
        cap = jnp.maximum(1, usable * params[P_PROT_CAP]
                          // jnp.maximum(1, params[P_MAIN_CAP]))
        over = hit_half & (nprot > cap)
        kd = jnp.argmin(jnp.where(meta >= _PROT, meta, _I32_MAX))
        meta = _lset(meta, kd, mst, over)
        return _lset_col(blk, MT_META, meta)

    mblk1u = hit_update(mblk1, match1, hit1)
    mblk2u = hit_update(mblk2, match2, hit2)
    m2eff = jnp.where(same_km, mblk1u, mblk2u)  # aliased sets follow set1

    # -- 4. admission: candidate vs the weakest of its 2*ways records --------
    # the candidate's choice sets were stored at its window insert; they are
    # gathered from the PRE-access table, then the hit updates above are
    # replayed onto them wherever the sets alias
    c1, c2 = cand[WT_MSET], cand[WT_MSET2]
    same_c = c2 == c1

    def fixup(cb, c):
        return jnp.where(c == km2, m2eff, jnp.where(c == km1, mblk1u, cb))

    cb1 = fixup(mask_ways(
        jax.lax.dynamic_slice(mtab, (c1 * A, 0), (A, spec.mcols)),
        m_usable(c1), MT_META), c1)
    cb2 = fixup(mask_ways(
        jax.lax.dynamic_slice(mtab, (c2 * A, 0), (A, spec.mcols)),
        m_usable(c2), MT_META), c2)
    cblk = jnp.concatenate([cb1, cb2], axis=0)          # (2A, cols)
    # argmin = empty < probation LRU < protected LRU across both sets;
    # ties pick the first half, so aliased choice sets stay consistent
    tslot = jnp.argmin(cblk[:, MT_META])
    vic = cblk[tslot]
    m_free = vic[MT_META] < 0
    est = _estimate_pair(
        spec, counters, dk,
        jnp.stack([cand[5:5 + rows], vic[3:3 + rows]]),
        jnp.stack([cand[5 + rows:5 + rows + dkp], vic[3 + rows:3 + rows + dkp]]))
    admit = est[0] > est[1]
    # all-padding candidate sets (see w_ok above) never accept an insert
    do_ins = push & (vic[MT_META] != _I32_MAX) & (m_free | admit)
    candrow = jnp.concatenate(
        [jnp.stack([cand[WT_LO], cand[WT_HI], mst]),
         cand[5:5 + rows], cand[5 + rows:5 + rows + dkp]]).astype(jnp.int32)
    in1 = do_ins & (tslot < A)
    in2 = do_ins & (tslot >= A)
    j1 = jnp.minimum(tslot, A - 1)
    j2 = jnp.clip(tslot - A, 0, A - 1)
    cb1u = _lset_row(cb1, j1, candrow, in1)
    cb2u = _lset_row(cb2, j2, candrow, in2)
    cb2u = jnp.where(same_c, cb1u, cb2u)

    # -- 5. writes last; later writes win where the four sets alias ----------
    # (adaptive: masked ways are restored to EMPTY before the write — the
    # decisions above never touched them, and storage must stay quota-free)
    mblk1u = unmask_ways(mblk1u, m_usable(km1), MT_META)
    m2eff = unmask_ways(m2eff, m_usable(km2), MT_META)
    cb1u = unmask_ways(cb1u, m_usable(c1), MT_META)
    cb2u = unmask_ways(cb2u, m_usable(c2), MT_META)
    wblk = unmask_ways(wblk, w_usable(kwset), WT_META)
    zm = _sched_dep(mblk2u) | _sched_dep(cb1u) | _sched_dep(cb2u)
    mtab = _ldus_block(mtab, mblk1u | zm, km1, A)
    mtab = _ldus_block(mtab, m2eff, km2, A)
    mtab = _ldus_block(mtab, cb1u, c1, A)
    mtab = _ldus_block(mtab, cb2u, c2, A)
    zw = _sched_dep(cb1u) | _sched_dep(cb2u)    # cand-derived: covers reads
    wtab = _ldus_block(wtab, wblk | zw, kwset, A)

    # -- 6. bookkeeping (R_PCOUNT is unused: protected counts are per-set) ---
    counted = (hit & (t >= params[P_WARMUP])).astype(jnp.int32)
    if spec.adaptive:
        # per-set window-traffic telemetry feeding the next rebalance's
        # load-aware quota distribution (single-word DUS, O(1) per access)
        wsl = state["wsl"]
        lcur = jax.lax.dynamic_slice(wsl, (kwset,), (1,))
        wsl = _ldus1(wsl, lcur + 1, kwset)
        regs = jnp.stack([size, regs[R_PCOUNT], t + 1, regs[R_HITS] + counted,
                          wquota, regs[5], regs[6],
                          regs[R_EHITS] + hit.astype(jnp.int32)])
    else:
        regs = jnp.stack([size, regs[R_PCOUNT], t + 1, regs[R_HITS] + counted,
                          regs[4], regs[5], regs[6], regs[7]])
    if spec.mesh_devices:
        (cg, cd), (dkg, dd) = counters, dk
        sketch = {"counters": cg, "doorkeeper": dkg,
                  "dcounters": cd, "ddoorkeeper": dd}
    else:
        sketch = {"counters": counters, "doorkeeper": dk}
    new_state = {**state, **sketch, "wtab": wtab, "mtab": mtab, "regs": regs}
    if spec.adaptive:
        new_state["wsl"] = wsl
        new_state["wuw"] = wuw
    return new_state, hit.astype(jnp.int32)


def _one_access_set_s3fifo(spec: StepSpec, params: jnp.ndarray, state: dict,
                           klo, khi, kidx, kdkb, kwset, kmset):
    """One access under the ``"s3fifo"`` competitor policy.

    S3-FIFO (SNIPPETS.md / CacheKit competitor set) on the shared
    set-associative machinery: the window table is the *small* FIFO
    (insert-stamp order, NO stamp refresh on hit — a window hit leaves the
    table untouched), the main table is the CLOCK-marked *main* FIFO (a
    hit ORs ``_PROT`` into the meta as the accessed bit, keeping the
    insert stamp, so the victim argmin is empty < unmarked-oldest <
    marked-oldest), and the one-hit-wonder filter is the frequency sketch
    itself: a candidate displaced from the small FIFO enters main only if
    its estimate is >= 2 (with the doorkeeper on, exactly "seen more than
    once"), with NO free-slot override — one-hit wonders never enter main.
    S3-FIFO's ghost queue is approximated by that sketch memory rather
    than tracked exactly (documented in ARCHITECTURE.md).
    """
    A = spec.assoc
    rows, dkp = spec.rows, spec.dkp
    regs = state["regs"]
    t = regs[R_T]

    counters, dk, size = _sketch_add(spec, params, state["counters"],
                                     state["doorkeeper"], regs[R_SIZE],
                                     kidx, kdkb, use_cond=True)

    wtab, mtab = state["wtab"], state["mtab"]
    km1, km2 = kmset[0], kmset[1]
    same_km = km2 == km1

    # -- lookups: small-FIFO set and both main choice sets -------------------
    wblk = jax.lax.dynamic_slice(wtab, (kwset * A, 0), (A, spec.wcols))
    wmeta = wblk[:, WT_META]
    match_w = (wblk[:, WT_LO] == klo) & (wblk[:, WT_HI] == khi) & (wmeta >= 0)
    hit_w = match_w.any()

    mblk1 = jax.lax.dynamic_slice(mtab, (km1 * A, 0), (A, spec.mcols))
    mblk2 = jax.lax.dynamic_slice(mtab, (km2 * A, 0), (A, spec.mcols))

    def match_in(blk):
        return ((blk[:, MT_LO] == klo) & (blk[:, MT_HI] == khi)
                & (blk[:, MT_META] >= 0))

    match1 = match_in(mblk1)
    match2 = match_in(mblk2) & ~same_km     # aliased choices: count set1 only
    hit = hit_w | match1.any() | match2.any()

    # -- small-FIFO miss insert (hit: NO write — FIFO order is insert order) -
    miss = ~hit
    ws = jnp.argmin(wmeta)                  # oldest insert stamp (or empty)
    newrow = jnp.concatenate(
        [jnp.stack([klo, khi, t, km1, km2]), kidx, kdkb]).astype(jnp.int32)
    w_ok = wmeta[ws] != _I32_MAX            # zero-way window set: bypass
    push = miss & ((wmeta[ws] >= 0) | ~w_ok)
    cand = jnp.where(w_ok, wblk[ws], newrow)
    wblk = _lset_row(wblk, ws, newrow, miss & w_ok)

    # -- main hit: set the CLOCK accessed bit, keep the insert stamp ---------
    def mark(blk, match):
        meta = blk[:, MT_META]
        return _lset_col(blk, MT_META,
                         jnp.where(match, meta | _PROT, meta))

    mblk1u = mark(mblk1, match1)
    mblk2u = mark(mblk2, match2)
    m2eff = jnp.where(same_km, mblk1u, mblk2u)

    # -- admission: sketch-filtered FIFO insert over the candidate's sets ----
    c1, c2 = cand[WT_MSET], cand[WT_MSET2]
    same_c = c2 == c1

    def fixup(cb, c):
        return jnp.where(c == km2, m2eff, jnp.where(c == km1, mblk1u, cb))

    cb1 = fixup(jax.lax.dynamic_slice(mtab, (c1 * A, 0), (A, spec.mcols)), c1)
    cb2 = fixup(jax.lax.dynamic_slice(mtab, (c2 * A, 0), (A, spec.mcols)), c2)
    cblk = jnp.concatenate([cb1, cb2], axis=0)          # (2A, cols)
    tslot = jnp.argmin(cblk[:, MT_META])    # empty < unmarked < marked FIFO
    vic = cblk[tslot]
    est = _estimate_block(spec, counters, dk,
                          cand[5:5 + rows][None, :],
                          cand[5 + rows:5 + rows + dkp][None, :])
    admit = est[0] >= 2                     # one-hit-wonder filter, strict
    do_ins = push & (vic[MT_META] != _I32_MAX) & admit
    candrow = jnp.concatenate(
        [jnp.stack([cand[WT_LO], cand[WT_HI], t]),
         cand[5:5 + rows], cand[5 + rows:5 + rows + dkp]]).astype(jnp.int32)
    in1 = do_ins & (tslot < A)
    in2 = do_ins & (tslot >= A)
    j1 = jnp.minimum(tslot, A - 1)
    j2 = jnp.clip(tslot - A, 0, A - 1)
    cb1u = _lset_row(cb1, j1, candrow, in1)
    cb2u = _lset_row(cb2, j2, candrow, in2)
    cb2u = jnp.where(same_c, cb1u, cb2u)

    # -- writes last (same aliasing/scheduling discipline as wtinylfu) -------
    zm = _sched_dep(mblk2u) | _sched_dep(cb1u) | _sched_dep(cb2u)
    mtab = _ldus_block(mtab, mblk1u | zm, km1, A)
    mtab = _ldus_block(mtab, m2eff, km2, A)
    mtab = _ldus_block(mtab, cb1u, c1, A)
    mtab = _ldus_block(mtab, cb2u, c2, A)
    zw = _sched_dep(cb1u) | _sched_dep(cb2u)
    wtab = _ldus_block(wtab, wblk | zw, kwset, A)

    counted = (hit & (t >= params[P_WARMUP])).astype(jnp.int32)
    regs = jnp.stack([size, regs[R_PCOUNT], t + 1, regs[R_HITS] + counted,
                      regs[4], regs[5], regs[6], regs[7]])
    new_state = {**state, "counters": counters, "doorkeeper": dk,
                 "wtab": wtab, "mtab": mtab, "regs": regs}
    return new_state, hit.astype(jnp.int32)


def _one_access_set_arc(spec: StepSpec, params: jnp.ndarray, state: dict,
                        klo, khi, kidx, kdkb, kwset, kmset):
    """One access under the ``"arc"`` competitor policy.

    ARC (seed ``core.policies.ARC`` is the reference twin) on the shared
    main table: T1 (recency, probation meta) and T2 (frequency,
    ``_PROT``-tagged meta) share the set-associative table; the adaptive
    target ``p`` lives in the ``R_WQUOTA`` register exactly like the
    adaptive window quota does.  The B1/B2 ghost lists are Bloom halves
    of the dedicated ``"ghost"`` state buffer (``dk_words`` words each,
    addressed by the key's stored doorkeeper probes): membership is
    approximate, removal is wholesale — when a half has absorbed
    ``P_MAIN_CAP`` evictions it is cleared and restarted (the clear is a
    where-gated fori-loop of single-word updates, O(1) amortized — same
    pattern as the §3.3 sketch reset).  The frequency sketch itself is
    NOT consulted (no ``_sketch_add``): ARC is a sketch-free policy and
    rides through with counters/doorkeeper untouched.  The window table
    is bypassed entirely (window_cap collapses to its 1-slot minimum).
    Register map: p -> R_WQUOTA, |T1| -> R_WCOUNT, B1/B2 insert counts ->
    R_MCOUNT / R_EHITS.
    """
    A = spec.assoc
    rows, dkp = spec.rows, spec.dkp
    regs = state["regs"]
    t = regs[R_T]
    p = regs[R_WQUOTA]
    t1count = regs[R_WCOUNT]
    gb1count = regs[R_MCOUNT]
    gb2count = regs[R_EHITS]
    ghost = state["ghost"]
    mtab = state["mtab"]
    km1, km2 = kmset[0], kmset[1]
    same_km = km2 == km1
    mst = t

    # -- lookups (all reads first: choice sets + both ghost Bloom halves) ----
    mblk1 = jax.lax.dynamic_slice(mtab, (km1 * A, 0), (A, spec.mcols))
    mblk2 = jax.lax.dynamic_slice(mtab, (km2 * A, 0), (A, spec.mcols))

    def match_in(blk):
        return ((blk[:, MT_LO] == klo) & (blk[:, MT_HI] == khi)
                & (blk[:, MT_META] >= 0))

    match1 = match_in(mblk1)
    match2 = match_in(mblk2) & ~same_km
    hit = match1.any() | match2.any()
    hit_t1 = ((match1 & (mblk1[:, MT_META] < _PROT)).any()
              | (match2 & (mblk2[:, MT_META] < _PROT)).any())

    gpos = kdkb >> 5
    gbit = kdkb & 31
    if _big_operand(2 * spec.dk_words):
        w1 = _ds_gather(ghost, gpos)
        w2 = _ds_gather(ghost, spec.dk_words + gpos)
    else:
        w1 = ghost[gpos]
        w2 = ghost[spec.dk_words + gpos]
    gb1 = (((w1 >> gbit) & 1) == 1).all()
    gb2 = (((w2 >> gbit) & 1) == 1).all()

    # -- hit: promote to T2 MRU (both lists; a T1 hit shrinks |T1|) ----------
    def promote(blk, match):
        meta = blk[:, MT_META]
        return _lset_col(blk, MT_META,
                         jnp.where(match, _PROT | mst, meta))

    mblk1u = promote(mblk1, match1)
    mblk2u = promote(mblk2, match2)
    m2eff = jnp.where(same_km, mblk1u, mblk2u)

    # -- miss: ghost-driven delta=1 adaptation of the target p ---------------
    miss = ~hit
    in_b1 = miss & gb1
    in_b2 = miss & gb2 & ~gb1
    p_new = jnp.where(in_b1, jnp.minimum(params[P_MAIN_CAP], p + 1),
                      jnp.where(in_b2, jnp.maximum(0, p - 1), p))

    # -- REPLACE: prefer the T1 LRU while |T1| exceeds p (seed-ARC tiebreak:
    # a B2 ghost hit also evicts from T1 at |T1| == p); XOR-flipping _PROT
    # into the order key swaps which list the shared argmin prefers, and
    # degrades gracefully to the other list when the preferred one has no
    # record in these two sets
    cblk = jnp.concatenate([mblk1u, m2eff], axis=0)     # (2A, cols)
    meta_c = cblk[:, MT_META]
    prefer_t1 = (t1count > p_new) | (in_b2 & (t1count == p_new))
    flip = jnp.where(prefer_t1, 0, _PROT)
    okey = jnp.where(meta_c == _I32_MAX, _I32_MAX,
                     jnp.where(meta_c < 0, -1, meta_c ^ flip))
    tslot = jnp.argmin(okey)
    vic = cblk[tslot]
    m_free = vic[MT_META] < 0
    do_ins = miss & (okey[tslot] != _I32_MAX)           # always admit
    evict = do_ins & ~m_free
    vic_was_t1 = evict & (vic[MT_META] < _PROT)

    # -- ghost maintenance: evicted key's stored dk probes enter B1/B2 -------
    goff = jnp.where(vic_was_t1, 0, spec.dk_words)
    vdkb = vic[3 + rows:3 + rows + dkp]
    vpos = goff + (vdkb >> 5)
    vbit = jnp.int32(1) << (vdkb & 31)
    gw = _ds_gather(ghost, vpos)            # pre-write read (see below)
    clr1 = vic_was_t1 & (gb1count >= params[P_MAIN_CAP])
    clr2 = evict & ~vic_was_t1 & (gb2count >= params[P_MAIN_CAP])
    clr = clr1 | clr2
    # anchor every ghost read before the first ghost write (in-place DUS
    # discipline — the query gathers feed only p_new/regs otherwise)
    zg = _sched_dep(w1) | _sched_dep(w2) | _sched_dep(gw)
    if not _LANE_TRACE[0]:
        # saturation clear: where-gated trip count, 0 iterations on the
        # (vast majority of) accesses where no clear fires — the same
        # O(1)-amortized pattern as the use_cond sketch reset
        def zero_one_g(i, g):
            return jax.lax.dynamic_update_slice(
                g, jnp.zeros((1,), jnp.int32) | zg, (goff + i,))

        ghost = jax.lax.fori_loop(
            0, jnp.where(clr, spec.dk_words, 0), zero_one_g, ghost)
    else:
        giota = jnp.arange(2 * spec.dk_words, dtype=jnp.int32)
        inhalf = jnp.where(vic_was_t1, giota < spec.dk_words,
                           giota >= spec.dk_words)
        ghost = jnp.where(clr & inhalf, 0, ghost)
    # bit inserts: same-word probes merge in-register (see _sketch_add);
    # a cleared half contributes zeros regardless of the pre-clear read
    base = jnp.where(clr, 0, gw)
    for i in range(dkp):
        merged = base[i] | vbit[i]
        if i == 0:
            merged = merged | zg
        for j in range(dkp):
            if j != i:
                merged = merged | jnp.where(vpos[j] == vpos[i], vbit[j], 0)
        ghost = _ldus1(ghost, jnp.where(evict, merged, gw[i])[None], vpos[i])
    gb1c = jnp.where(clr1, 0, gb1count) + vic_was_t1.astype(jnp.int32)
    gb2c = (jnp.where(clr2, 0, gb2count)
            + (evict & ~vic_was_t1).astype(jnp.int32))

    # -- insert: ghost-remembered keys go to T2, fresh keys to T1 MRU --------
    meta0 = jnp.where(gb1 | gb2, _PROT | mst, mst)
    candrow = jnp.concatenate(
        [jnp.stack([klo, khi, meta0]), kidx, kdkb]).astype(jnp.int32)
    in1 = do_ins & (tslot < A)
    in2 = do_ins & (tslot >= A)
    j1 = jnp.minimum(tslot, A - 1)
    j2 = jnp.clip(tslot - A, 0, A - 1)
    mb1f = _lset_row(mblk1u, j1, candrow, in1)
    mb2f = _lset_row(m2eff, j2, candrow, in2)
    mb2f = jnp.where(same_km, mb1f, mb2f)
    t1c = (t1count - hit_t1.astype(jnp.int32)
           - vic_was_t1.astype(jnp.int32)
           + (do_ins & (meta0 < _PROT)).astype(jnp.int32))

    # -- writes last ---------------------------------------------------------
    zm = _sched_dep(mb2f)
    mtab = _ldus_block(mtab, mb1f | zm, km1, A)
    mtab = _ldus_block(mtab, mb2f, km2, A)

    counted = (hit & (t >= params[P_WARMUP])).astype(jnp.int32)
    regs = jnp.stack([regs[R_SIZE], regs[R_PCOUNT], t + 1,
                      regs[R_HITS] + counted, p_new, t1c, gb1c, gb2c])
    new_state = {**state, "mtab": mtab, "ghost": ghost, "regs": regs}
    return new_state, hit.astype(jnp.int32)


def _one_access_set_lfu(spec: StepSpec, params: jnp.ndarray, state: dict,
                        klo, khi, kidx, kdkb, kwset, kmset):
    """One access under the ``"lfu"`` competitor policy.

    Heap-free sketch-LFU (Shah/Mitra/Matani's O(1) LFU, mapped onto the
    packed-record layout): there is no frequency heap at all — every
    record's stored sketch probes make the per-set gather+reduce itself
    the min-frequency scan, O(ways) per access like everything else.  No
    window (window_cap collapses to its 1-slot minimum), no admission
    filter (always admit: plain LFU has no ghost/doorkeeper gate), victim
    = the resident with the smallest sketch estimate across the key's two
    choice sets, stamps breaking frequency ties toward the LRU record.
    A hit refreshes the stamp (probation meta only — no ``_PROT`` tier).
    """
    A = spec.assoc
    rows, dkp = spec.rows, spec.dkp
    regs = state["regs"]
    t = regs[R_T]
    mst = t

    counters, dk, size = _sketch_add(spec, params, state["counters"],
                                     state["doorkeeper"], regs[R_SIZE],
                                     kidx, kdkb, use_cond=True)

    mtab = state["mtab"]
    km1, km2 = kmset[0], kmset[1]
    same_km = km2 == km1

    mblk1 = jax.lax.dynamic_slice(mtab, (km1 * A, 0), (A, spec.mcols))
    mblk2 = jax.lax.dynamic_slice(mtab, (km2 * A, 0), (A, spec.mcols))

    def match_in(blk):
        return ((blk[:, MT_LO] == klo) & (blk[:, MT_HI] == khi)
                & (blk[:, MT_META] >= 0))

    match1 = match_in(mblk1)
    match2 = match_in(mblk2) & ~same_km
    hit = match1.any() | match2.any()

    def refresh(blk, match):
        meta = blk[:, MT_META]
        return _lset_col(blk, MT_META, jnp.where(match, mst, meta))

    mblk1u = refresh(mblk1, match1)
    mblk2u = refresh(mblk2, match2)
    m2eff = jnp.where(same_km, mblk1u, mblk2u)

    # -- victim: min sketch estimate over both sets, stamp-LRU tiebreak ------
    cblk = jnp.concatenate([mblk1u, m2eff], axis=0)     # (2A, cols)
    meta_c = cblk[:, MT_META]
    est = _estimate_block(spec, counters, dk,
                          cblk[:, 3:3 + rows],
                          cblk[:, 3 + rows:3 + rows + dkp])
    # aliased choice sets: the second half duplicates the first — mask it
    # out of the victim scan so the insert lands once
    half2 = jnp.arange(2 * A, dtype=jnp.int32) >= A
    pad = (meta_c == _I32_MAX) | (same_km & half2)
    okey1 = jnp.where(pad, _I32_MAX, jnp.where(meta_c < 0, -1, est))
    mmin = jnp.min(okey1)
    okey2 = jnp.where(okey1 == mmin, meta_c, _I32_MAX)  # LRU among freq ties
    tslot = jnp.argmin(okey2)
    miss = ~hit
    do_ins = miss & (okey1[tslot] != _I32_MAX)          # always admit
    candrow = jnp.concatenate(
        [jnp.stack([klo, khi, mst]), kidx, kdkb]).astype(jnp.int32)
    in1 = do_ins & (tslot < A)
    in2 = do_ins & (tslot >= A)
    j1 = jnp.minimum(tslot, A - 1)
    j2 = jnp.clip(tslot - A, 0, A - 1)
    mb1f = _lset_row(mblk1u, j1, candrow, in1)
    mb2f = _lset_row(m2eff, j2, candrow, in2)
    mb2f = jnp.where(same_km, mb1f, mb2f)

    zm = _sched_dep(mb2f)
    mtab = _ldus_block(mtab, mb1f | zm, km1, A)
    mtab = _ldus_block(mtab, mb2f, km2, A)

    counted = (hit & (t >= params[P_WARMUP])).astype(jnp.int32)
    regs = jnp.stack([size, regs[R_PCOUNT], t + 1, regs[R_HITS] + counted,
                      regs[4], regs[5], regs[6], regs[7]])
    new_state = {**state, "counters": counters, "doorkeeper": dk,
                 "mtab": mtab, "regs": regs}
    return new_state, hit.astype(jnp.int32)


def _one_access(spec: StepSpec, params: jnp.ndarray, state: dict,
                klo, khi, kidx, kdkb, kwset, kmset):
    """Advance the cache state by one access; returns (state, hit).

    Dispatch is static (Python, at trace time): ``spec.assoc is None``
    takes the flat exact path, otherwise ``spec.policy`` selects which
    admission/victim rules run on the set-associative machinery.  The
    default ``"wtinylfu"`` path is byte-for-byte the pre-panel program
    (tests/test_policy_panel.py pins its lowered HLO).
    """
    if spec.assoc is None:
        return _one_access_flat(spec, params, state, klo, khi, kidx, kdkb)
    if spec.policy == "s3fifo":
        return _one_access_set_s3fifo(spec, params, state, klo, khi, kidx,
                                      kdkb, kwset, kmset)
    if spec.policy == "arc":
        return _one_access_set_arc(spec, params, state, klo, khi, kidx,
                                   kdkb, kwset, kmset)
    if spec.policy == "lfu":
        return _one_access_set_lfu(spec, params, state, klo, khi, kidx,
                                   kdkb, kwset, kmset)
    return _one_access_set(spec, params, state, klo, khi, kidx, kdkb,
                           kwset, kmset)


# ---------------------------------------------------------------------------
# epoch-boundary rebalance: move the runtime window/main boundary
# ---------------------------------------------------------------------------

def _rebalance_flat(spec: StepSpec, params, state, nq):
    regs = state["regs"]
    wlo, whi, wmeta = state["wlo"], state["whi"], state["wmeta"]
    mlo, mhi, mmeta = state["mlo"], state["mhi"], state["mmeta"]
    wcount, mcount = regs[R_WCOUNT], regs[R_MCOUNT]
    pcount = regs[R_PCOUNT]
    total = params[P_WINDOW_CAP] + params[P_MAIN_CAP]
    mcap_new = total - nq

    # -- window shrink: evict the LRU residents beyond the new quota ---------
    res_w = (wmeta >= 0) & (wmeta < _I32_MAX)
    n_wev = jnp.maximum(0, wcount - nq)
    ranks = jnp.argsort(jnp.argsort(jnp.where(res_w, wmeta, _I32_MAX)))
    evict = res_w & (ranks < n_wev)
    # ... migrating the strongest (most recent) of them into main's free
    # room, probation, original stamp (stamps are globally unique so SLRU
    # order is preserved); the weakest beyond the room are dropped
    room = jnp.maximum(0, mcap_new - mcount)
    dranks = jnp.argsort(jnp.argsort(jnp.where(evict, -wmeta, _I32_MAX)))
    mig = evict & (dranks < room)
    free_order = jnp.argsort(mmeta != _EMPTY)    # stable: empty slots first
    tgt = jnp.where(mig, free_order[dranks], spec.main_slots)  # OOB -> drop
    mlo = mlo.at[tgt].set(wlo, mode="drop")
    mhi = mhi.at[tgt].set(whi, mode="drop")
    mmeta = mmeta.at[tgt].set(wmeta, mode="drop")
    midx = state["midx"].at[tgt].set(state["widx"], mode="drop")
    mdkb = state["mdkb"].at[tgt].set(state["wdkb"], mode="drop")
    wlo = jnp.where(evict, -1, wlo)
    whi = jnp.where(evict, -1, whi)
    wmeta = jnp.where(evict, _EMPTY, wmeta)
    wcount = wcount - n_wev
    mcount = mcount + mig.sum()

    # -- window grow: evict main's weakest beyond the shrunken budget --------
    # (mutually exclusive with the migration above: only one side shrinks)
    res_m = (mmeta >= 0) & (mmeta < _I32_MAX)
    n_mev = jnp.maximum(0, mcount - mcap_new)
    ranks_m = jnp.argsort(jnp.argsort(jnp.where(res_m, mmeta, _I32_MAX)))
    evict_m = res_m & (ranks_m < n_mev)
    pcount = pcount - (evict_m & (mmeta >= _PROT)).sum()
    mlo = jnp.where(evict_m, -1, mlo)
    mhi = jnp.where(evict_m, -1, mhi)
    mmeta = jnp.where(evict_m, _EMPTY, mmeta)
    mcount = mcount - n_mev

    regs = jnp.stack([regs[R_SIZE], pcount, regs[R_T], regs[R_HITS],
                      nq, wcount, mcount, jnp.int32(0)])
    return {**state, "wlo": wlo, "whi": whi, "wmeta": wmeta,
            "midx": midx, "mdkb": mdkb,
            "mlo": mlo, "mhi": mhi, "mmeta": mmeta, "regs": regs}


def _rebalance_set(spec: StepSpec, params, state, nq):
    A = spec.assoc
    regs = state["regs"]
    wtab, mtab = state["wtab"], state["mtab"]
    total = params[P_WINDOW_CAP] + params[P_MAIN_CAP]
    mcap_new = total - nq
    nws, nms = spec.window_sets, spec.main_sets
    way = jnp.arange(A, dtype=jnp.int32)

    def compact(tab, n_sets, ncols, meta_col, usable):
        """Per-set: sort records strongest-first, keep the first ``usable``,
        blank the rest; returns (new tab3d, sorted tab3d, evicted mask)."""
        t3 = tab.reshape(n_sets, A, ncols)
        meta = t3[:, :, meta_col]
        order = jnp.argsort(-meta, axis=1)       # residents first, empty last
        t3s = jnp.take_along_axis(t3, order[:, :, None], axis=1)
        keep = way[None, :] < usable[:, None]
        metas = t3s[:, :, meta_col]
        evict = (metas >= 0) & (metas < _I32_MAX) & ~keep
        blank = jnp.zeros((ncols,), jnp.int32).at[0].set(-1).at[1].set(-1) \
            .at[meta_col].set(_EMPTY)
        t3n = jnp.where(keep[:, :, None], t3s, blank[None, None, :])
        return t3n, t3s, evict

    # window quota distribution (jnp twin of core.adaptive.window_set_ways):
    # uniform while nq >= nws (bit-identical to the static set_ways padding,
    # preserving pinned-quota == static); below nws the ways go to the nq
    # most-loaded sets of the finished epoch (state["wsl"] telemetry) so a
    # small quota cannot starve hot sets under skewed key->set load.  The
    # argsort is stable, so ties break by set index like the host rule.
    load = state["wsl"]
    uniform = nq // nws + (jnp.arange(nws, dtype=jnp.int32) < nq % nws)
    order = jnp.argsort(-load)                   # hottest first, stable
    ranks = jnp.zeros((nws,), jnp.int32).at[order].set(
        jnp.arange(nws, dtype=jnp.int32))
    uw = jnp.where(nq < nws, (ranks < nq).astype(jnp.int32), uniform)
    um = mcap_new // nms + (jnp.arange(nms, dtype=jnp.int32) < mcap_new % nms)
    w3n, w3s, w_evict = compact(wtab, nws, spec.wcols, WT_META, uw)
    m3n, _, _ = compact(mtab, nms, spec.mcols, MT_META, um)
    wtab = w3n.reshape(-1, spec.wcols)
    mtab = m3n.reshape(-1, spec.mcols)

    # -- migrate displaced window records into a free usable way of their
    # stored first-choice main set (sequential: targets collide; the traced
    # trip count is the number of evictions, ~delta per epoch)
    ev_flat = w_evict.reshape(-1)
    recs = w3s.reshape(-1, spec.wcols)
    ev_order = jnp.argsort(~ev_flat)             # stable: evicted first

    def body(i, mtab_c):
        rec = recs[ev_order[i]]
        s = rec[WT_MSET]
        blk = jax.lax.dynamic_slice(mtab_c, (s * A, 0), (A, spec.mcols))
        meta = blk[:, MT_META]
        u = mcap_new // nms + (s < mcap_new % nms).astype(jnp.int32)
        free = (meta == _EMPTY) & (way < u)
        j = jnp.argmax(free)
        mainrow = jnp.concatenate([rec[:WT_META + 1], rec[WT_MSET2 + 1:]])
        row = jnp.where(free.any(), mainrow, blk[j])
        return jax.lax.dynamic_update_slice(
            mtab_c, blk.at[j].set(row), (s * A, 0))

    mtab = jax.lax.fori_loop(0, ev_flat.sum(), body, mtab)

    regs = jnp.stack([regs[R_SIZE], regs[R_PCOUNT], regs[R_T], regs[R_HITS],
                      nq, regs[R_WCOUNT], regs[R_MCOUNT], jnp.int32(0)])
    return {**state, "wtab": wtab, "mtab": mtab, "regs": regs,
            "wsl": jnp.zeros_like(load), "wuw": uw}


def rebalance(spec: StepSpec, params: jnp.ndarray, state: dict,
              new_quota) -> dict:
    """Move the runtime window/main boundary to ``new_quota`` (adaptive mode).

    Runs between epochs inside the compiled program (no host sync): clamps
    the quota to the geometry, evicts/compacts each table down to its new
    budget, migrates displaced window records into main's free room
    (probation, stamps preserved), and resets the per-epoch telemetry
    register ``R_EHITS``.  O(slots·log) once per epoch — amortized over the
    epoch it leaves the per-access cost untouched.  A rebalance to the
    current quota only compacts (hit-sequence no-op), which is what makes
    the pinned-quota differential tests possible.
    """
    assert spec.adaptive, "rebalance requires StepSpec.adaptive"
    total = params[P_WINDOW_CAP] + params[P_MAIN_CAP]
    nq = jnp.clip(jnp.asarray(new_quota, jnp.int32),
                  jnp.maximum(1, total - spec.main_slots),
                  jnp.minimum(spec.window_slots, total - 1))
    if spec.assoc is None:
        return _rebalance_flat(spec, params, state, nq)
    return _rebalance_set(spec, params, state, nq)


# ---------------------------------------------------------------------------
# reference backend: lax.scan over the chunk (jit twin of the fused kernel)
# ---------------------------------------------------------------------------

def _step_lanes(fn, spec: StepSpec, params, state, lo, hi, n_valid,
                lane_trace: bool = True, **kw):
    """Dispatch a ``streams=B`` step: vmap the ``streams=1`` program.

    ``params`` may be shared ``(NPARAMS,)`` or per-lane ``(B, NPARAMS)``
    (vmapped sweeps); all state leaves and key lanes carry a leading lane
    axis.  ``n_valid`` may be shared (scalar) or per-lane ``(B,)``.  While
    the vmapped trace runs, :data:`_LANE_TRACE` re-expresses every
    per-lane-indexed single-slot write as a fused masked select (see the
    flag's comment) — the pallas path skips the flag (``lane_trace=False``):
    pallas' own vmap rule batches the kernel by a grid dimension, inside
    which the indices stay unbatched.
    """
    B = spec.streams
    if lo.ndim != 2 or lo.shape[0] != B:
        raise ValueError(
            f"streams={B} expects (B, T) key lanes; got trace shape "
            f"{tuple(lo.shape)} — one row per tenant lane")
    lspec = replace(spec, streams=1)
    axes = [0 if params.ndim == 2 else None, 0, 0, 0]
    args = [params, state, lo, hi]
    if n_valid is not None:
        nv = jnp.asarray(n_valid, jnp.int32)
        axes.append(0 if nv.ndim else None)
        args.append(nv)

        def run(p, s, l, h, n):
            return fn(lspec, p, s, l, h, n, **kw)
    else:
        def run(p, s, l, h):
            return fn(lspec, p, s, l, h, **kw)
    prev = _LANE_TRACE[0]
    _LANE_TRACE[0] = lane_trace
    try:
        return jax.vmap(run, in_axes=tuple(axes))(*args)
    finally:
        _LANE_TRACE[0] = prev


def step_ref(spec: StepSpec, params: jnp.ndarray, state: dict,
             lo: jnp.ndarray, hi: jnp.ndarray,
             n_valid: jnp.ndarray | int | None = None,
             *, unroll: int | None = None):
    """Sequentially simulate ``lo/hi`` accesses; returns (state, hit_flags).

    ``n_valid`` masks padded tails: accesses at positions >= n_valid leave the
    state untouched and report hit=0.  Bit-for-bit identical to step_pallas.

    ``unroll=None`` picks per layout: 4 for the flat path (hides scalar
    latency between its big reductions), 1 for the set path (unrolling
    defeats XLA CPU's in-place buffer reuse across the chained single-word
    updates, reintroducing O(state) copies per access).

    ``spec.streams = B > 1`` expects ``(B, T)`` key lanes and lane-axis
    state and runs all B tenant lanes in one vmapped scan (unroll forced to
    1: the lane axis already fills the vector units).
    """
    if spec.streams > 1:
        return _step_lanes(step_ref, spec, params, state, lo, hi, n_valid,
                           unroll=1 if unroll is None else unroll)
    if unroll is None:
        unroll = 4 if spec.assoc is None else 1
    (b,) = lo.shape
    lo = lo.astype(jnp.int32)
    hi = hi.astype(jnp.int32)
    kidx, kdkb, kwset, kmset = precompute_probes(spec, lo, hi)

    if n_valid is None:
        # fast path: no tail masking, no per-step state merge
        def body(carry, x):
            klo, khi, ki, kd, kw, km = x
            return _one_access(spec, params, carry, klo, khi, ki, kd, kw, km)

        return jax.lax.scan(body, state, (lo, hi, kidx, kdkb, kwset, kmset),
                            unroll=unroll)

    n_valid = jnp.asarray(n_valid, jnp.int32)

    def body(carry, x):
        klo, khi, ki, kd, kw, km, i = x
        new, hit = _one_access(spec, params, carry, klo, khi, ki, kd, kw, km)
        active = i < n_valid
        merged = jax.tree_util.tree_map(
            lambda n, o: jnp.where(active, n, o), new, carry)
        return merged, jnp.where(active, hit, 0)

    xs = (lo, hi, kidx, kdkb, kwset, kmset, jnp.arange(b, dtype=jnp.int32))
    return jax.lax.scan(body, state, xs, unroll=unroll)


# ---------------------------------------------------------------------------
# fused Pallas kernel: whole chunk, state pinned in VMEM, buffers donated
# ---------------------------------------------------------------------------

# number of streamed (non-state) VMEM inputs: lo, hi, kidx, kdkb, kwset, kmset
_N_STREAM = 6


def _step_kernel(spec: StepSpec, lo_ref, hi_ref, kidx_ref, kdkb_ref,
                 kwset_ref, kmset_ref, scal_ref, *refs):
    keys = _state_keys(spec)
    n_state = len(keys)
    in_refs = refs[:n_state]
    out_refs = refs[n_state:2 * n_state]
    hits_ref = refs[2 * n_state]

    params = jnp.stack([scal_ref[i] for i in range(NPARAMS)])
    n_valid = scal_ref[NPARAMS]
    lo = lo_ref[...]
    hi = hi_ref[...]
    kidx = kidx_ref[...]
    kdkb = kdkb_ref[...]
    kwset = kwset_ref[...]
    kmset = kmset_ref[...]
    state0 = tuple(r[...] for r in in_refs)
    hits0 = jnp.zeros(lo.shape, jnp.int32)

    def body(i, carry):
        state_t, hits = carry
        state = dict(zip(keys, state_t))
        new, hit = _one_access(spec, params, state, lo[i], hi[i],
                               kidx[i], kdkb[i], kwset[i], kmset[i])
        return (tuple(new[k] for k in keys),
                hits.at[i].set(hit))

    state_t, hits = jax.lax.fori_loop(0, n_valid, body, (state0, hits0))
    for r, v in zip(out_refs, state_t):
        r[...] = v
    hits_ref[...] = hits


def step_pallas(spec: StepSpec, params: jnp.ndarray, state: dict,
                lo: jnp.ndarray, hi: jnp.ndarray,
                n_valid: jnp.ndarray | int | None = None,
                *, interpret: bool = True):
    """Fused chunk step: one launch, state VMEM-resident and donated.

    Same signature/semantics as :func:`step_ref`.  Probes and set indices are
    precomputed vectorized outside the kernel (they are pure functions of the
    keys) and streamed in with the key lanes.  ``spec.streams > 1`` batches
    through pallas' vmap rule (a fresh grid dimension; the kernel body stays
    unbatched, so the lane-write discipline is not needed).
    """
    if spec.streams > 1:
        return _step_lanes(step_pallas, spec, params, state, lo, hi,
                           n_valid, lane_trace=False, interpret=interpret)
    (b,) = lo.shape
    n_valid = b if n_valid is None else n_valid
    lo = lo.astype(jnp.int32)
    hi = hi.astype(jnp.int32)
    kidx, kdkb, kwset, kmset = precompute_probes(spec, lo, hi)
    scal = jnp.concatenate([
        params.astype(jnp.int32),
        jnp.asarray(n_valid, jnp.int32).reshape(1)])
    kernel = functools.partial(_step_kernel, spec)
    keys = _state_keys(spec)
    n_state = len(keys)
    state_vals = [state[k] for k in keys]
    outs = pl.pallas_call(
        kernel,
        out_shape=tuple(
            [jax.ShapeDtypeStruct(v.shape, v.dtype) for v in state_vals]
            + [jax.ShapeDtypeStruct((b,), jnp.int32)]),
        in_specs=(
            [pl.BlockSpec(memory_space=pltpu.VMEM)] * _N_STREAM
            + [pl.BlockSpec(memory_space=pltpu.SMEM)]     # packed scalars
            + [pl.BlockSpec(memory_space=pltpu.VMEM)] * n_state),
        out_specs=tuple([pl.BlockSpec(memory_space=pltpu.VMEM)]
                        * (n_state + 1)),
        # donate every state buffer: input i+_N_STREAM+1 -> output i
        input_output_aliases={i + _N_STREAM + 1: i for i in range(n_state)},
        interpret=interpret,
    )(lo, hi, kidx, kdkb, kwset, kmset, scal, *state_vals)
    new_state = dict(zip(keys, outs[:n_state]))
    return new_state, outs[n_state]
