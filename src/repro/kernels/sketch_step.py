"""Fused device-resident W-TinyLFU simulation step (paper §4, Fig 5).

One launch advances an entire *chunk* of the access trace through the full
W-TinyLFU decision pipeline while every byte of policy state stays
VMEM-resident:

    per access:  doorkeeper insert  +  conservative-update add  (+ §3.3 reset)
                 -> window-LRU / SLRU-main lookup
                 -> on window overflow: candidate & victim frequency estimate
                 -> admission verdict + table update

This replaces the three separate HBM round-trips per decision (sketch_update
-> sketch_estimate -> admission) that made trace simulation launch-bound.

Data layout — engineered so the sequential per-access body is a handful of
tiny fused ops instead of O(capacity) masked rebuilds:

* cache tables are fixed-capacity packed int32 arrays.  Each slot's
  (valid, segment, LRU-stamp) state is packed into ONE int32 ``meta``:

      -1              empty slot
      t               probation entry, last-stamped at access t
      2^30 | t        protected entry, last-stamped at access t
      2^31-1          sweep padding (permanently unusable slot)

  so a single ``argmin(meta)`` is simultaneously the free-slot finder and
  the exact SLRU victim priority (empty < probation LRU < protected LRU),
  and a single ``argmin`` over the window's meta is free-slot-else-LRU.
* LRU order is the monotone access index ``t``; each access stamps at most
  one entry per segment, so stamps are unique and ``argmin`` reproduces the
  host OrderedDict order (core/policies.py:SLRUEviction) exactly.
* hashing is hoisted out of the sequential loop entirely: probe rows and
  doorkeeper bit positions are precomputed vectorized over the whole chunk
  (they do not depend on state) and *stored in the tables* next to the key
  lanes, so estimates of resident candidates/victims need no re-hashing.

Semantics contract (tests/test_sketch_step.py, tests/test_device_simulate.py):

* ``step_ref`` (pure-jnp `lax.scan`) and ``step_pallas`` (fused kernel) are
  bit-for-bit identical, including reset boundaries that straddle chunks.
* The sketch substate evolves exactly like ``ref.add_ref`` (no reset) and the
  host ``FrequencySketch`` up to the 32-bit-lane hash family.
* With a collision-free sketch, the per-access hit sequence is bit-for-bit
  the host ``WTinyLFU``'s.

Static geometry lives in ``StepSpec``; per-config scalars that may vary
across a vmapped sweep (protected capacity, sample size W, counter cap,
warmup) are a traced int32 ``params`` vector, so one compiled program sweeps
a Cartesian grid of configurations (core/device_simulate.py).  Window/main
capacities below the static slot counts are expressed at init time by marking
the excess slots as padding (init_step_state).

Keys: 64-bit keys arrive as (lo, hi) int32 bit-pattern lanes.  The single
key value 2^64-1 (lanes == -1) is reserved as the padding-slot sentinel and
must not appear in traces.

Aliasing: ``step_pallas`` donates every state buffer (input_output_aliases),
so between chunks the state never round-trips through fresh HBM allocations.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .sketch_common import probe_index, dk_probe_index, halve_words

# python ints (not jnp scalars): jnp scalars at module scope would be closed
# over as captured constants, which pallas kernels reject
_I32_MAX = 2**31 - 1          # padding-slot meta: never free, never a victim
_PROT = 1 << 30               # meta bit 30: protected segment
_EMPTY = -1                   # meta of an empty (usable) slot

# params vector layout (traced per-config scalars; see make_step_params)
P_WINDOW_CAP = 0              # informational (capacities are baked at init)
P_MAIN_CAP = 1
P_PROT_CAP = 2
P_SAMPLE = 3                  # W; 0 disables the automatic reset
P_CAP = 4                     # counter saturation (<= 15, 4-bit nibbles)
P_WARMUP = 5                  # accesses before hits start counting
NPARAMS = 8

# regs vector layout (mutable int32 scalar state)
R_SIZE = 0                    # sketch additions since last reset
R_PCOUNT = 1                  # protected entries within main
R_T = 2                       # global access index == LRU stamp
R_HITS = 3                    # counted hits (post warmup)
NREGS = 8


def _pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


@dataclass(frozen=True)
class StepSpec:
    """Static geometry of one simulated W-TinyLFU instance."""
    width: int                    # sketch counters per row (pow2, mult of 8)
    rows: int = 4
    dk_bits: int = 0              # doorkeeper bits (pow2 >= 32); 0 = off
    dk_probes: int = 3
    window_slots: int = 1         # window table size (>= any window_cap used)
    main_slots: int = 1           # main table size (>= any main_cap used)

    def __post_init__(self):
        assert _pow2(self.width) and self.width % 8 == 0
        assert self.dk_bits == 0 or (_pow2(self.dk_bits) and self.dk_bits >= 32)
        assert self.window_slots >= 1 and self.main_slots >= 1

    @property
    def words_per_row(self) -> int:
        return self.width // 8

    @property
    def dk_words(self) -> int:
        return max(1, self.dk_bits // 32)

    @property
    def dkp(self) -> int:         # stored doorkeeper probes per table entry
        return self.dk_probes if self.dk_bits else 1


def make_step_params(window_cap: int, main_cap: int, prot_cap: int,
                     sample_size: int, cap: int, warmup: int = 0) -> jnp.ndarray:
    """Pack per-config scalars into the traced (NPARAMS,) int32 vector."""
    assert 1 <= cap <= 15
    p = [int(window_cap), int(main_cap), int(prot_cap), int(sample_size),
         int(cap), int(warmup)] + [0] * (NPARAMS - 6)
    return jnp.asarray(p, jnp.int32)


def init_step_state(spec: StepSpec, window_cap: int | None = None,
                    main_cap: int | None = None) -> dict:
    """Zeroed simulation state (a pytree of int32 device arrays).

    ``window_cap``/``main_cap`` below the static slot counts mark the excess
    slots as permanent padding — this is how one static ``StepSpec`` hosts a
    vmapped sweep over different cache sizes.
    """
    wcap = spec.window_slots if window_cap is None else int(window_cap)
    mcap = spec.main_slots if main_cap is None else int(main_cap)
    assert 1 <= wcap <= spec.window_slots and 1 <= mcap <= spec.main_slots

    def table(slots, cap):
        pad = jnp.arange(slots) >= cap
        return {
            # all non-resident slots hold the sentinel key (lanes -1) so no
            # real key — including key 0 — can match an unoccupied slot
            "lo": jnp.full((slots,), -1, jnp.int32),
            "hi": jnp.full((slots,), -1, jnp.int32),
            "meta": jnp.where(pad, _I32_MAX, _EMPTY).astype(jnp.int32),
            "idx": jnp.zeros((slots, spec.rows), jnp.int32),
            "dkb": jnp.zeros((slots, spec.dkp), jnp.int32),
        }

    w, m = table(spec.window_slots, wcap), table(spec.main_slots, mcap)
    return {
        "counters": jnp.zeros((spec.rows * spec.words_per_row,), jnp.int32),
        "doorkeeper": jnp.zeros((spec.dk_words,), jnp.int32),
        "wlo": w["lo"], "whi": w["hi"], "wmeta": w["meta"],
        "widx": w["idx"], "wdkb": w["dkb"],
        "mlo": m["lo"], "mhi": m["hi"], "mmeta": m["meta"],
        "midx": m["idx"], "mdkb": m["dkb"],
        "regs": jnp.zeros((NREGS,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# probe precomputation — vectorized over the chunk, outside the scan
# ---------------------------------------------------------------------------

def precompute_probes(spec: StepSpec, lo: jnp.ndarray, hi: jnp.ndarray):
    """(B,) key lanes -> ((B, rows) table probes, (B, dkp) doorkeeper bits).

    Pure functions of the key, hoisted out of the sequential loop and stored
    alongside resident entries so the loop body never hashes.
    """
    idx = jnp.stack([probe_index(lo, hi, r, spec.width)
                     for r in range(spec.rows)], axis=-1)
    if spec.dk_bits:
        dkb = jnp.stack([dk_probe_index(lo, hi, p, spec.dk_bits)
                         for p in range(spec.dk_probes)], axis=-1)
    else:
        dkb = jnp.zeros(lo.shape + (1,), jnp.int32)
    return idx, dkb


# ---------------------------------------------------------------------------
# functional single-access step — the one source of truth for both backends
# ---------------------------------------------------------------------------

def _row_offsets(spec: StepSpec) -> jnp.ndarray:
    return (jnp.arange(spec.rows, dtype=jnp.int32) * spec.words_per_row)


def _nibble_vals(words: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """4-bit counter values at probe positions idx (…, rows)."""
    return (words >> ((idx & 7) * 4)) & jnp.int32(0xF)


def _sketch_add(spec: StepSpec, params, counters, dk, size, kidx, kdkb):
    """FrequencySketch.add(): doorkeeper gate -> minimal increment -> reset.

    ``kidx`` (rows,) precomputed probe indices; ``kdkb`` (dkp,) doorkeeper
    bit positions.  Row gathers/scatters are one vectorized op each.
    """
    if spec.dk_bits:
        # sequential probe insert (host _dk_put semantics: a later probe of
        # the same access observes bits set by an earlier one)
        present = jnp.int32(1)
        for p in range(spec.dk_probes):
            bit = kdkb[p]
            word = dk[bit >> 5]
            present &= (word >> (bit & 31)) & 1
            dk = dk.at[bit >> 5].set(word | (jnp.int32(1) << (bit & 31)))
        gate = present.astype(jnp.bool_)   # repeat visitor -> main table
    else:
        gate = jnp.bool_(True)

    flat = _row_offsets(spec) + (kidx >> 3)        # (rows,) word positions
    words = counters[flat]
    vals = _nibble_vals(words, kidx)
    m = vals.min()
    bump = gate & (m < params[P_CAP])
    new = jnp.where(bump & (vals == m),
                    words + (jnp.int32(1) << ((kidx & 7) * 4)), words)
    counters = counters.at[flat].set(new)

    size = size + 1
    do_reset = (params[P_SAMPLE] > 0) & (size >= params[P_SAMPLE])
    # select, not lax.cond: XLA CPU cond copies its operand buffers every
    # step, which costs more than the fused masked pass it would skip
    counters = jnp.where(do_reset, halve_words(counters), counters)
    dk = jnp.where(do_reset, jnp.zeros_like(dk), dk)
    size = jnp.where(do_reset, size // 2, size)
    return counters, dk, size


def _estimate_pair(spec: StepSpec, counters, dk, idx2, dkb2):
    """TinyLFU estimates for two resident entries from their stored probes.

    idx2: (2, rows); dkb2: (2, dkp) -> (2,) int32 estimates.
    """
    words = counters[_row_offsets(spec)[None, :] + (idx2 >> 3)]
    est = _nibble_vals(words, idx2).min(axis=-1)
    if spec.dk_bits:
        w2 = dk[dkb2 >> 5]
        ok = (((w2 >> (dkb2 & 31)) & 1) == 1).all(axis=-1)
        est = est + ok.astype(jnp.int32)
    return est


def _one_access(spec: StepSpec, params: jnp.ndarray, state: dict,
                klo, khi, kidx, kdkb):
    """Advance the full W-TinyLFU state by one access; returns (state, hit)."""
    regs = state["regs"]
    t = regs[R_T]

    # -- 1. admission.record(key): sketch add + automatic §3.3 reset ---------
    counters, dk, size = _sketch_add(spec, params, state["counters"],
                                     state["doorkeeper"], regs[R_SIZE],
                                     kidx, kdkb)

    wlo, whi, wmeta = state["wlo"], state["whi"], state["wmeta"]
    widx, wdkb = state["widx"], state["wdkb"]
    mlo, mhi, mmeta = state["mlo"], state["mhi"], state["mmeta"]
    midx, mdkb = state["midx"], state["mdkb"]

    # -- 2. lookups (meta >= 0 <=> resident; padding slots hold sentinel key)
    jw = jnp.argmax((wlo == klo) & (whi == khi))
    hit_w = (wlo[jw] == klo) & (whi[jw] == khi) & (wmeta[jw] >= 0)
    jm = jnp.argmax((mlo == klo) & (mhi == khi))
    hit_m = (mlo[jm] == klo) & (mhi[jm] == khi) & (mmeta[jm] >= 0)
    hit = hit_w | hit_m

    # -- 3a. window hit: refresh LRU stamp -----------------------------------
    wmeta = wmeta.at[jw].set(jnp.where(hit_w, t, wmeta[jw]))

    # -- 3b. main hit: SLRU promote-or-refresh -> protected MRU --------------
    promote = hit_m & (mmeta[jm] < _PROT)
    mmeta = mmeta.at[jm].set(jnp.where(hit_m, _PROT | t, mmeta[jm]))
    pcount = regs[R_PCOUNT] + promote.astype(jnp.int32)
    # protected overflow -> demote its LRU entry back to probation MRU
    over = pcount > params[P_PROT_CAP]
    kd = jnp.argmin(jnp.where(mmeta >= _PROT, mmeta, _I32_MAX))
    mmeta = mmeta.at[kd].set(jnp.where(over, t, mmeta[kd]))
    pcount = pcount - over.astype(jnp.int32)

    # -- 4. miss: insert into window; LRU overflow asks admission ------------
    miss = ~hit
    # argmin(wmeta): empty (-1) before LRU stamps; padding (+MAX) never picked
    ws = jnp.argmin(wmeta)
    push = miss & (wmeta[ws] >= 0)              # evicting a resident entry
    cand_lo, cand_hi = wlo[ws], whi[ws]
    cand_idx, cand_dkb = widx[ws], wdkb[ws]
    wlo = wlo.at[ws].set(jnp.where(miss, klo, wlo[ws]))
    whi = whi.at[ws].set(jnp.where(miss, khi, whi[ws]))
    wmeta = wmeta.at[ws].set(jnp.where(miss, t, wmeta[ws]))
    widx = widx.at[ws].set(jnp.where(miss, kidx, widx[ws]))
    wdkb = wdkb.at[ws].set(jnp.where(miss, kdkb, wdkb[ws]))

    # single argmin = free slot < probation LRU < protected LRU (exact SLRU
    # victim priority); padding (+MAX) is unreachable
    tslot = jnp.argmin(mmeta)
    vmeta = mmeta[tslot]
    m_free = vmeta < 0
    # fused TinyLFU verdict from stored probes (post-record sketch state)
    est = _estimate_pair(spec, counters, dk,
                         jnp.stack([cand_idx, midx[tslot]]),
                         jnp.stack([cand_dkb, mdkb[tslot]]))
    admit = est[0] > est[1]
    do_ins = push & (m_free | admit)
    mlo = mlo.at[tslot].set(jnp.where(do_ins, cand_lo, mlo[tslot]))
    mhi = mhi.at[tslot].set(jnp.where(do_ins, cand_hi, mhi[tslot]))
    mmeta = mmeta.at[tslot].set(jnp.where(do_ins, t, mmeta[tslot]))
    midx = midx.at[tslot].set(jnp.where(do_ins, cand_idx, midx[tslot]))
    mdkb = mdkb.at[tslot].set(jnp.where(do_ins, cand_dkb, mdkb[tslot]))
    pcount = pcount - (do_ins & (vmeta >= _PROT)).astype(jnp.int32)

    # -- 5. bookkeeping ------------------------------------------------------
    counted = (hit & (t >= params[P_WARMUP])).astype(jnp.int32)
    regs = jnp.stack([size, pcount, t + 1, regs[R_HITS] + counted,
                      regs[4], regs[5], regs[6], regs[7]])
    new_state = {"counters": counters, "doorkeeper": dk,
                 "wlo": wlo, "whi": whi, "wmeta": wmeta,
                 "widx": widx, "wdkb": wdkb,
                 "mlo": mlo, "mhi": mhi, "mmeta": mmeta,
                 "midx": midx, "mdkb": mdkb, "regs": regs}
    return new_state, hit.astype(jnp.int32)


# ---------------------------------------------------------------------------
# reference backend: lax.scan over the chunk (jit twin of the fused kernel)
# ---------------------------------------------------------------------------

def step_ref(spec: StepSpec, params: jnp.ndarray, state: dict,
             lo: jnp.ndarray, hi: jnp.ndarray,
             n_valid: jnp.ndarray | int | None = None, *, unroll: int = 4):
    """Sequentially simulate ``lo/hi`` accesses; returns (state, hit_flags).

    ``n_valid`` masks padded tails: accesses at positions >= n_valid leave the
    state untouched and report hit=0.  Bit-for-bit identical to step_pallas.
    """
    (b,) = lo.shape
    lo = lo.astype(jnp.int32)
    hi = hi.astype(jnp.int32)
    kidx, kdkb = precompute_probes(spec, lo, hi)

    if n_valid is None:
        # fast path: no tail masking, no per-step state merge
        def body(carry, x):
            klo, khi, ki, kd = x
            return _one_access(spec, params, carry, klo, khi, ki, kd)

        return jax.lax.scan(body, state, (lo, hi, kidx, kdkb), unroll=unroll)

    n_valid = jnp.asarray(n_valid, jnp.int32)

    def body(carry, x):
        klo, khi, ki, kd, i = x
        new, hit = _one_access(spec, params, carry, klo, khi, ki, kd)
        active = i < n_valid
        merged = jax.tree_util.tree_map(
            lambda n, o: jnp.where(active, n, o), new, carry)
        return merged, jnp.where(active, hit, 0)

    xs = (lo, hi, kidx, kdkb, jnp.arange(b, dtype=jnp.int32))
    return jax.lax.scan(body, state, xs, unroll=unroll)


# ---------------------------------------------------------------------------
# fused Pallas kernel: whole chunk, state pinned in VMEM, buffers donated
# ---------------------------------------------------------------------------

_STATE_KEYS = ("counters", "doorkeeper", "wlo", "whi", "wmeta", "widx",
               "wdkb", "mlo", "mhi", "mmeta", "midx", "mdkb", "regs")


def _step_kernel(spec: StepSpec, lo_ref, hi_ref, kidx_ref, kdkb_ref,
                 scal_ref, *refs):
    n_state = len(_STATE_KEYS)
    in_refs = refs[:n_state]
    out_refs = refs[n_state:2 * n_state]
    hits_ref = refs[2 * n_state]

    params = jnp.stack([scal_ref[i] for i in range(NPARAMS)])
    n_valid = scal_ref[NPARAMS]
    lo = lo_ref[...]
    hi = hi_ref[...]
    kidx = kidx_ref[...]
    kdkb = kdkb_ref[...]
    state0 = tuple(r[...] for r in in_refs)
    hits0 = jnp.zeros(lo.shape, jnp.int32)

    def body(i, carry):
        state_t, hits = carry
        state = dict(zip(_STATE_KEYS, state_t))
        new, hit = _one_access(spec, params, state, lo[i], hi[i],
                               kidx[i], kdkb[i])
        return (tuple(new[k] for k in _STATE_KEYS),
                hits.at[i].set(hit))

    state_t, hits = jax.lax.fori_loop(0, n_valid, body, (state0, hits0))
    for r, v in zip(out_refs, state_t):
        r[...] = v
    hits_ref[...] = hits


def step_pallas(spec: StepSpec, params: jnp.ndarray, state: dict,
                lo: jnp.ndarray, hi: jnp.ndarray,
                n_valid: jnp.ndarray | int | None = None,
                *, interpret: bool = True):
    """Fused chunk step: one launch, state VMEM-resident and donated.

    Same signature/semantics as :func:`step_ref`.  Probes are precomputed
    vectorized outside the kernel (they are pure functions of the keys) and
    streamed in with the key lanes.
    """
    (b,) = lo.shape
    n_valid = b if n_valid is None else n_valid
    lo = lo.astype(jnp.int32)
    hi = hi.astype(jnp.int32)
    kidx, kdkb = precompute_probes(spec, lo, hi)
    scal = jnp.concatenate([
        params.astype(jnp.int32),
        jnp.asarray(n_valid, jnp.int32).reshape(1)])
    kernel = functools.partial(_step_kernel, spec)
    n_state = len(_STATE_KEYS)
    state_vals = [state[k] for k in _STATE_KEYS]
    outs = pl.pallas_call(
        kernel,
        out_shape=tuple(
            [jax.ShapeDtypeStruct(v.shape, v.dtype) for v in state_vals]
            + [jax.ShapeDtypeStruct((b,), jnp.int32)]),
        in_specs=(
            [pl.BlockSpec(memory_space=pltpu.VMEM)] * 4   # lo, hi, kidx, kdkb
            + [pl.BlockSpec(memory_space=pltpu.SMEM)]     # packed scalars
            + [pl.BlockSpec(memory_space=pltpu.VMEM)] * n_state),
        out_specs=tuple([pl.BlockSpec(memory_space=pltpu.VMEM)]
                        * (n_state + 1)),
        # donate every state buffer: input i+5 -> output i
        input_output_aliases={i + 5: i for i in range(n_state)},
        interpret=interpret,
    )(lo, hi, kidx, kdkb, scal, *state_vals)
    new_state = dict(zip(_STATE_KEYS, outs[:n_state]))
    return new_state, outs[n_state]
