"""Jitted public wrappers around the sketch kernels.

* pads batches to lane multiples,
* selects Pallas (TPU) vs interpret-mode Pallas vs the pure-jnp oracle,
* composes `add` with the automatic reset (paper §3.3: reset once the sample
  counter reaches W).

`DeviceTinyLFU` is the stateful convenience facade used by the serving
scheduler (serve/prefix_cache.py).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .sketch_common import DeviceSketchConfig, init_state, keys_to_lanes
from .sketch_estimate import estimate_pallas
from .sketch_update import add_pallas
from .sketch_reset import reset_pallas
from .admission import admit_pallas

LANE = 128


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_lanes(x: jnp.ndarray, mult: int = LANE) -> jnp.ndarray:
    b = x.shape[0]
    pad = (-b) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x


# ---------------------------------------------------------------------------
# functional ops (jit-friendly; cfg static)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0, 4))
def estimate(cfg: DeviceSketchConfig, state: dict, lo: jnp.ndarray,
             hi: jnp.ndarray, use_pallas: bool = True) -> jnp.ndarray:
    b = lo.shape[0]
    if not use_pallas:
        return ref.estimate_ref(cfg, state, lo, hi)
    out = estimate_pallas(cfg, state, _pad_lanes(lo), _pad_lanes(hi),
                          interpret=_default_interpret())
    return out[:b]


@functools.partial(jax.jit, static_argnums=(0, 4))
def add(cfg: DeviceSketchConfig, state: dict, lo: jnp.ndarray,
        hi: jnp.ndarray, use_pallas: bool = True) -> dict:
    """Batch add + automatic reset when the sample counter crosses W."""
    b = lo.shape[0]
    if use_pallas:
        new = add_pallas(cfg, state, _pad_lanes(lo), _pad_lanes(hi),
                         n_valid=b, interpret=_default_interpret())
    else:
        new = ref.add_ref(cfg, state, lo, hi)
    if cfg.sample_size:
        def do_reset(s):
            if use_pallas:
                return reset_pallas(cfg, s, interpret=_default_interpret())
            return ref.reset_ref(cfg, s)
        new = jax.lax.cond(new["size"] >= cfg.sample_size, do_reset,
                           lambda s: s, new)
    return new


@functools.partial(jax.jit, static_argnums=(0,))
def reset(cfg: DeviceSketchConfig, state: dict) -> dict:
    return reset_pallas(cfg, state, interpret=_default_interpret())


@functools.partial(jax.jit, static_argnums=(0, 6))
def admit(cfg: DeviceSketchConfig, state: dict, cand_lo, cand_hi,
          victim_lo, victim_hi, use_pallas: bool = True) -> jnp.ndarray:
    b = cand_lo.shape[0]
    if not use_pallas:
        return ref.admission_ref(cfg, state, cand_lo, cand_hi,
                                 victim_lo, victim_hi)
    out = admit_pallas(cfg, state, _pad_lanes(cand_lo), _pad_lanes(cand_hi),
                       _pad_lanes(victim_lo), _pad_lanes(victim_hi),
                       interpret=_default_interpret())
    return out[:b]


# ---------------------------------------------------------------------------
# stateful facade
# ---------------------------------------------------------------------------

def _pow2ceil(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


def make_config(num_blocks: int, sample_factor: int = 8,
                counters_per_item: float = 2.0, rows: int = 4,
                dk_bits_per_item: float = 4.0) -> DeviceSketchConfig:
    """Same sizing rule as core.sketch.default_sketch (≈1.5 B/sample elem)."""
    sample = sample_factor * num_blocks
    width = _pow2ceil(max(8, counters_per_item * sample / rows))
    width = max(width, 8)
    return DeviceSketchConfig(
        width=width, rows=rows, cap=min(15, max(1, sample_factor - 1)),
        dk_bits=max(32, _pow2ceil(sample * dk_bits_per_item)),
        sample_size=sample)


class DeviceTinyLFU:
    """Stateful TinyLFU over device arrays (serving-side admission).

    Keys are uint64 (block hashes); batches are converted to 32-bit lanes on
    the way in.  All methods are O(batch) with the sketch resident on device.
    """

    def __init__(self, num_blocks: int, sample_factor: int = 8,
                 use_pallas: bool = True, **kw):
        self.cfg = make_config(num_blocks, sample_factor=sample_factor, **kw)
        self.state = init_state(self.cfg)
        self.use_pallas = use_pallas

    def record(self, keys: np.ndarray) -> None:
        if len(keys) == 0:
            return
        lo, hi = keys_to_lanes(keys)
        self.state = add(self.cfg, self.state, lo, hi, self.use_pallas)

    def estimate(self, keys: np.ndarray) -> np.ndarray:
        if len(keys) == 0:
            return np.zeros(0, np.int32)
        lo, hi = keys_to_lanes(keys)
        return np.asarray(estimate(self.cfg, self.state, lo, hi,
                                   self.use_pallas))

    def admit(self, cands: np.ndarray, victims: np.ndarray) -> np.ndarray:
        if len(cands) == 0:
            return np.zeros(0, bool)
        clo, chi = keys_to_lanes(cands)
        vlo, vhi = keys_to_lanes(victims)
        return np.asarray(admit(self.cfg, self.state, clo, chi, vlo, vhi,
                                self.use_pallas))
