"""Pure-jnp oracles for every Pallas sketch kernel.

These are the semantic ground truth: the kernels in sketch_*.py / admission.py
must match them bit-for-bit (tests/test_kernels.py sweeps shapes & dtypes).
They are also directly usable — `jax.jit`-able, differentiable-free integer
code — wherever interpret-mode Pallas would be slower (CPU serving path).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .sketch_common import (DeviceSketchConfig, probe_index, dk_probe_index,
                            nibble_get, nibble_inc, halve_words)


# ---------------------------------------------------------------------------
# estimate
# ---------------------------------------------------------------------------

def _dk_contains(cfg: DeviceSketchConfig, dk: jnp.ndarray, lo, hi):
    """(B,) bool: all doorkeeper probe bits set."""
    flat = dk.reshape(-1)
    ok = jnp.ones(lo.shape, jnp.bool_)
    for p in range(cfg.dk_probes):
        bit = dk_probe_index(lo, hi, p, cfg.dk_bits)
        word = flat[bit >> 5]
        ok &= ((word >> (bit & 31)) & 1).astype(jnp.bool_)
    return ok


def _table_estimate(cfg: DeviceSketchConfig, counters: jnp.ndarray, lo, hi):
    """(B,) int32 min over rows of the 4-bit counters."""
    est = jnp.full(lo.shape, 15, jnp.int32)
    for r in range(cfg.rows):
        idx = probe_index(lo, hi, r, cfg.width)
        word = counters[r, idx >> 3]
        est = jnp.minimum(est, nibble_get(word, idx & 7))
    return est


def estimate_ref(cfg: DeviceSketchConfig, state: dict, lo: jnp.ndarray,
                 hi: jnp.ndarray) -> jnp.ndarray:
    """Paper §3.4.2 estimate: main-table min + 1 if the doorkeeper knows you."""
    est = _table_estimate(cfg, state["counters"], lo, hi)
    if cfg.dk_bits:
        est = est + _dk_contains(cfg, state["doorkeeper"], lo, hi).astype(jnp.int32)
    return est


# ---------------------------------------------------------------------------
# add (conservative update, sequential over the batch)
# ---------------------------------------------------------------------------

def add_ref(cfg: DeviceSketchConfig, state: dict, lo: jnp.ndarray,
            hi: jnp.ndarray) -> dict:
    """Sequential minimal-increment adds; later batch elements observe earlier
    updates (same order semantics as the host sketch and the Pallas kernel).
    Does NOT trigger reset — compose via ops.add_and_maybe_reset."""

    def one(carry, key):
        counters, dk = carry
        klo, khi = key

        def main_add(counters):
            idx = []
            vals = []
            for r in range(cfg.rows):
                i = probe_index(klo, khi, r, cfg.width)
                idx.append(i)
                vals.append(nibble_get(counters[r, i >> 3], i & 7))
            vals = jnp.stack(vals)
            m = vals.min()

            def bump(counters):
                new = counters
                for r in range(cfg.rows):
                    i = idx[r]
                    word = new[r, i >> 3]
                    word = jnp.where(vals[r] == m, nibble_inc(word, i & 7), word)
                    new = new.at[r, i >> 3].set(word)
                return new

            return jax.lax.cond(m < cfg.cap, bump, lambda c: c, counters)

        if cfg.dk_bits:
            flat = dk.reshape(-1)
            present = jnp.bool_(True)
            new_flat = flat
            for p in range(cfg.dk_probes):
                bit = dk_probe_index(klo, khi, p, cfg.dk_bits)
                word = new_flat[bit >> 5]
                present &= ((word >> (bit & 31)) & 1).astype(jnp.bool_)
                new_flat = new_flat.at[bit >> 5].set(word | (jnp.int32(1) << (bit & 31)))
            # repeat visitor -> main table; first-timer -> doorkeeper only
            counters = jax.lax.cond(present, main_add, lambda c: c, counters)
            dk = new_flat.reshape(dk.shape)
        else:
            counters = main_add(counters)
        return (counters, dk), None

    (counters, dk), _ = jax.lax.scan(
        one, (state["counters"], state["doorkeeper"]),
        (lo.astype(jnp.uint32), hi.astype(jnp.uint32)))
    return {"counters": counters, "doorkeeper": dk,
            "size": state["size"] + lo.shape[0]}


# ---------------------------------------------------------------------------
# reset
# ---------------------------------------------------------------------------

def reset_ref(cfg: DeviceSketchConfig, state: dict) -> dict:
    return {
        "counters": halve_words(state["counters"]),
        "doorkeeper": jnp.zeros_like(state["doorkeeper"]),
        "size": state["size"] // 2,
    }


# ---------------------------------------------------------------------------
# fused admission (paper Fig 1 decision, batched)
# ---------------------------------------------------------------------------

def admission_ref(cfg: DeviceSketchConfig, state: dict,
                  cand_lo, cand_hi, victim_lo, victim_hi) -> jnp.ndarray:
    """(B,) bool: admit candidate i over victim i (strictly greater freq)."""
    ce = estimate_ref(cfg, state, cand_lo, cand_hi)
    ve = estimate_ref(cfg, state, victim_lo, victim_hi)
    return ce > ve
