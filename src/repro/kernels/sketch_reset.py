"""Pallas TPU kernel: the paper's reset (§3.3) as one vectorized VPU pass.

Per-nibble halving of the packed counters — ``(x >> 1) & 0x77777777`` — maps
the paper's "shift registers in hardware" observation directly onto TPU VPU
lanes; the doorkeeper is zeroed in the same launch.  Tiled over counter rows
with an explicit BlockSpec grid (the one kernel here whose working set could
exceed VMEM for very large samples).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .sketch_common import DeviceSketchConfig, halve_words


def _reset_kernel(counters_ref, dk_ref, counters_out, dk_out):
    r = pl.program_id(0)
    counters_out[...] = halve_words(counters_ref[...])

    @pl.when(r == 0)
    def _():
        dk_out[...] = jnp.zeros_like(dk_ref[...])


def reset_pallas(cfg: DeviceSketchConfig, state: dict,
                 *, interpret: bool = True) -> dict:
    rows, w8 = state["counters"].shape
    counters, dk = pl.pallas_call(
        _reset_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((rows, w8), jnp.int32),
            jax.ShapeDtypeStruct(state["doorkeeper"].shape, jnp.int32),
        ),
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, w8), lambda r: (r, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec(state["doorkeeper"].shape, lambda r: (0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=(
            pl.BlockSpec((1, w8), lambda r: (r, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec(state["doorkeeper"].shape, lambda r: (0, 0),
                         memory_space=pltpu.VMEM),
        ),
        interpret=interpret,
    )(state["counters"], state["doorkeeper"])
    return {"counters": counters, "doorkeeper": dk, "size": state["size"] // 2}
