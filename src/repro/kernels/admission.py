"""Pallas TPU kernel: fused batched admission decision (paper Fig 1).

One launch answers B independent "admit candidate_i over victim_i?" queries:
both estimates (main table min + doorkeeper bonus) and the comparison are
fused so the sketch is read from VMEM once per batch.  This is the kernel the
serving scheduler calls every tick for prefix-block retention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .sketch_common import DeviceSketchConfig
from .sketch_estimate import vectorized_estimate


def _admission_kernel(cfg: DeviceSketchConfig, counters_ref, dk_ref,
                      clo_ref, chi_ref, vlo_ref, vhi_ref, out_ref):
    counters = counters_ref[...]
    dk = dk_ref[...]
    ce = vectorized_estimate(cfg, counters, dk, clo_ref[...], chi_ref[...])
    ve = vectorized_estimate(cfg, counters, dk, vlo_ref[...], vhi_ref[...])
    out_ref[...] = (ce > ve).astype(jnp.int32)


def admit_pallas(cfg: DeviceSketchConfig, state: dict, cand_lo, cand_hi,
                 victim_lo, victim_hi, *, interpret: bool = True):
    (b,) = cand_lo.shape
    kernel = functools.partial(_admission_kernel, cfg)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)] * 6,
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        interpret=interpret,
    )(state["counters"], state["doorkeeper"],
      cand_lo.astype(jnp.uint32), cand_hi.astype(jnp.uint32),
      victim_lo.astype(jnp.uint32), victim_hi.astype(jnp.uint32))
    return out.astype(jnp.bool_)
