"""Pallas TPU kernel: sequential conservative-update (minimal increment) adds.

The paper's Add is order-dependent (later keys see earlier increments), so the
batch is processed by a ``fori_loop`` with scalar VMEM loads/stores while the
sketch stays VMEM-resident — one HBM round-trip per *batch* instead of per
*decision*.  This preserves the exact sequential semantics of the host sketch
(core/sketch.py) and of the jnp oracle (ref.py `add_ref`), which the tests
check bit-for-bit.

Input/output aliasing donates the counter and doorkeeper buffers, so the
update is in-place in HBM between batches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .sketch_common import (DeviceSketchConfig, probe_index, dk_probe_index,
                            nibble_get, nibble_inc)


def _update_kernel(cfg: DeviceSketchConfig, lo_ref, hi_ref, nvalid_ref,
                   counters_in, dk_in, counters_out, dk_out):
    # aliased buffers: materialize input -> output once, then mutate out_refs
    counters_out[...] = counters_in[...]
    dk_out[...] = dk_in[...]
    n = nvalid_ref[0]

    def body(i, _):
        klo = lo_ref[i]
        khi = hi_ref[i]

        # ---- doorkeeper: membership test + insert (always) ----------------
        if cfg.dk_bits:
            present = jnp.int32(1)
            for p in range(cfg.dk_probes):
                bit = dk_probe_index(klo, khi, p, cfg.dk_bits)
                w = dk_out[0, bit >> 5]
                present &= (w >> (bit & 31)) & 1
                dk_out[0, bit >> 5] = w | (jnp.int32(1) << (bit & 31))
            gate = present.astype(jnp.bool_)   # repeat visitor -> main table
        else:
            gate = jnp.bool_(True)

        # ---- main table: minimal increment ---------------------------------
        idxs, vals = [], []
        for r in range(cfg.rows):
            idx = probe_index(klo, khi, r, cfg.width)
            word = counters_out[r, idx >> 3]
            idxs.append(idx)
            vals.append(nibble_get(word, idx & 7))
        m = jnp.minimum(jnp.minimum(vals[0], vals[-1]),
                        functools.reduce(jnp.minimum, vals))
        bump = gate & (m < cfg.cap)
        for r in range(cfg.rows):
            idx = idxs[r]
            word = counters_out[r, idx >> 3]
            new = jnp.where(bump & (vals[r] == m),
                            nibble_inc(word, idx & 7), word)
            counters_out[r, idx >> 3] = new
        return 0

    jax.lax.fori_loop(0, n, body, 0)


def add_pallas(cfg: DeviceSketchConfig, state: dict, lo: jnp.ndarray,
               hi: jnp.ndarray, n_valid: jnp.ndarray | int | None = None,
               *, interpret: bool = True) -> dict:
    """Sequential batch add; ``n_valid`` allows padded batches (padding keys
    beyond n_valid are ignored)."""
    (b,) = lo.shape
    if n_valid is None:
        n_valid = b
    nvalid = jnp.asarray([n_valid], jnp.int32)
    kernel = functools.partial(_update_kernel, cfg)
    counters, dk = pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct(state["counters"].shape, jnp.int32),
            jax.ShapeDtypeStruct(state["doorkeeper"].shape, jnp.int32),
        ),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),   # lo
            pl.BlockSpec(memory_space=pltpu.VMEM),   # hi
            pl.BlockSpec(memory_space=pltpu.SMEM),   # n_valid scalar
            pl.BlockSpec(memory_space=pltpu.VMEM),   # counters (aliased)
            pl.BlockSpec(memory_space=pltpu.VMEM),   # doorkeeper (aliased)
        ],
        out_specs=(
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ),
        input_output_aliases={3: 0, 4: 1},
        interpret=interpret,
    )(lo.astype(jnp.uint32), hi.astype(jnp.uint32), nvalid,
      state["counters"], state["doorkeeper"])
    size = state["size"] + jnp.asarray(n_valid, jnp.int32)
    return {"counters": counters, "doorkeeper": dk, "size": size}
