"""Pallas TPU flash attention (beyond-paper optimization, §Perf).

The roofline analysis shows every train/prefill cell memory-bound on the
unfused jnp attention: (q_block x kv_block) fp32 score/prob tiles round-trip
HBM between the two matmuls — arithmetic intensity ~ D/4 ≈ 32 flops/byte vs
the ~240 a v5e needs.  This kernel keeps the whole online-softmax tile chain
in VMEM: per (batch*head, q_block) grid cell it loops over kv blocks with the
running (m, l, acc) in VMEM scratch, so HBM traffic collapses to one pass
over Q, K, V plus one O write — intensity ~ q_block ≈ 512.

Causal tiles after the diagonal are skipped with @pl.when (grid-level
predication).  Validated against models/layers.flash_attention (the jnp
oracle) in interpret mode; see tests/test_flash_kernel.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  kv_block: int, q_block: int, causal: bool, scale: float,
                  nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: tiles entirely above the diagonal contribute nothing
    needed = (not causal) or (ki * kv_block < (qi + 1) * q_block)

    @pl.when(needed)
    def _tile():
        q = q_ref[0]                          # (q_block, D)
        k = k_ref[0]                          # (kv_block, D)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (qb, kb)
        if causal:
            q_pos = qi * q_block + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, kv_block), 0)
            k_pos = ki * kv_block + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, kv_block), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_tpu(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, q_block: int = 512,
                        kv_block: int = 512,
                        interpret: bool = True) -> jnp.ndarray:
    """q,k,v (B, S, H, D) with equal head counts (GQA callers repeat KV).
    Returns (B, S, H, D).  S must be a multiple of the block sizes."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    assert Sq % q_block == 0 and Skv % kv_block == 0
    nq, nk = Sq // q_block, Skv // kv_block
    scale = float(1.0 / np.sqrt(D))

    # (B*H, S, D) layout: one grid cell per (bh, q_block); kv loop innermost
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * H, Skv, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * H, Skv, D)

    kernel = functools.partial(_flash_kernel, kv_block=kv_block,
                               q_block=q_block, causal=causal, scale=scale,
                               nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, D), lambda bh, qi, ki: (bh, qi, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, kv_block, D), lambda bh, qi, ki: (bh, ki, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, kv_block, D), lambda bh, qi, ki: (bh, ki, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, q_block, D),
                               lambda bh, qi, ki: (bh, qi, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),      # running max
            pltpu.VMEM((q_block, 1), jnp.float32),      # running denom
            pltpu.VMEM((q_block, D), jnp.float32),      # running acc
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


def attention_hbm_bytes(B, S, H, D, *, dtype_bytes=2, causal=True) -> float:
    """Modeled HBM traffic of this kernel (for roofline kernel-crediting):
    read Q once; read K,V once per q-row pass (here: per q block loop —
    K/V re-read per q block); write O once."""
    q_o = 2 * B * S * H * D * dtype_bytes
    kv_passes = (S // 512)                     # one K+V read per q block
    kv = 2 * B * S * H * D * dtype_bytes * kv_passes
    if causal:
        kv *= 0.5
    return q_o + kv
