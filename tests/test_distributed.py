"""Distribution-layer tests.

The production-mesh machinery (16x16 / 2x16x16) is proven here on a tiny
in-test mesh: sharded lowering succeeds, FSDP+TP specs resolve for every
arch's param tree, collectives appear in the compiled module, and the HLO
cost parser stays exact on a hand-checkable program.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import build_model
from repro.optim import make_optimizer, wsd
from repro.train import make_train_state, build_train_step
from repro.distributed.mesh import make_debug_mesh
from repro.distributed.shardings import ShardingPolicy
from repro.analysis.hlo_cost import analyze_hlo

mesh = make_debug_mesh((2, 4), ("data", "model"))
arch = "%(arch)s"
cfg = get_config(arch, smoke=True).replace(
    n_heads=8, n_kv_heads=4, d_ff=256, vocab_size=512)
if cfg.family == "xlstm":
    cfg = cfg.replace(n_heads=4, n_kv_heads=4, d_ff=0)
model = build_model(cfg)
policy = ShardingPolicy(mesh, fsdp=True)
opt = make_optimizer("adamw", wsd(1e-3, 5, 50, 20))
state_shapes = jax.eval_shape(lambda k: make_train_state(model, opt, k),
                              jax.ShapeDtypeStruct((2,), jnp.uint32))
step = build_train_step(model, opt, policy=policy, loss_chunk=16)
batch = {"tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32)}
if cfg.n_vis_tokens:
    batch["vision_embeds"] = jax.ShapeDtypeStruct(
        (4, cfg.n_vis_tokens, cfg.d_model), jnp.bfloat16)
if cfg.n_codebooks:
    batch["tokens"] = jax.ShapeDtypeStruct((4, 64, cfg.n_codebooks),
                                           jnp.int32)
in_sh = (policy.shardings(state_shapes), policy.batch_specs(batch))
compiled = jax.jit(step, in_shardings=in_sh,
                   donate_argnums=(0,)).lower(state_shapes, batch).compile()
cost = analyze_hlo(compiled.as_text())
assert cost.flops > 0
n_coll = sum(cost.coll_counts.values())
assert n_coll > 0, "sharded train step must contain collectives"
print("OK", arch, int(cost.flops), int(n_coll))
"""


@pytest.mark.parametrize("arch", ["qwen3-4b", "llama4-scout-17b-a16e",
                                  "zamba2-1.2b", "xlstm-1.3b"])
def test_sharded_train_step_lowering(arch):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT % {"arch": arch}],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


class TestShardingRules:
    def test_param_specs_cover_all_archs(self):
        """Every leaf of every arch's param tree gets a consistent spec."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec
        from repro.configs import get_config, list_archs
        from repro.models import build_model
        from repro.distributed.shardings import ShardingPolicy

        class FakeMesh:
            axis_names = ("data", "model")
        pol = ShardingPolicy.__new__(ShardingPolicy)
        pol.mesh = FakeMesh()
        pol.fsdp = True
        pol.__post_init__()
        for arch in list_archs():
            cfg = get_config(arch)     # FULL config (no allocation)
            model = build_model(cfg)
            shapes = jax.eval_shape(lambda k: model.init(k),
                                    jax.ShapeDtypeStruct((2,), jnp.uint32))
            specs = pol.tree_specs(shapes)
            flat_sh, _ = jax.tree_util.tree_flatten(shapes)
            flat_sp, _ = jax.tree_util.tree_flatten(
                specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
            assert len(flat_sh) == len(flat_sp)
            for leaf, spec in zip(flat_sh, flat_sp):
                assert len(spec) <= leaf.ndim, (arch, leaf.shape, spec)
                # 'model'-sharded dims of weight matrices must divide 16
                for dim, name in zip(leaf.shape, list(spec) + [None] * 9):
                    if name == "model":
                        assert dim % 16 == 0 or dim >= 16, (arch, leaf.shape,
                                                            spec)

    def test_hlo_cost_parser_exact_on_scan_matmul(self):
        import jax
        import jax.numpy as jnp
        from repro.analysis.hlo_cost import analyze_hlo

        W = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

        def f(x, ws):
            def body(x, w):
                return jnp.tanh(x @ w), None
            return jax.lax.scan(body, x, ws)[0].sum()

        compiled = jax.jit(f).lower(x, W).compile()
        cost = analyze_hlo(compiled.as_text())
        expected = 7 * 2 * 8 * 64 * 64            # dots only
        assert abs(cost.flops - expected) / expected < 0.05


def test_sketch_shard_placement_block():
    """Sketch-shard placement map (ISSUE 5): every shard maps to a device,
    BLOCK placement when shards exceed the device count (device ``d`` owns
    the contiguous shards ``[d*S/D, (d+1)*S/D)`` — exactly how
    NamedSharding/shard_map split axis 0 of the shard-major delta arrays
    over ``make_shard_mesh``), and the 1-D shard mesh size is the largest
    divisor of the shard count that the available devices can host."""
    import jax
    from repro.distributed.mesh import shard_placement, make_shard_mesh

    devs = jax.devices()
    pl = shard_placement(8)
    assert len(pl) == 8
    assert all(d in devs for d in pl)
    # block: co-located shards are CONSECUTIVE, and the run of shards on
    # one device never interleaves with another device's
    per = 8 // len({id(d) for d in pl})
    for s in range(8):
        assert pl[s] == pl[(s // per) * per]
    mesh = make_shard_mesh(4)
    assert mesh.axis_names == ("shard",)
    assert 4 % mesh.devices.size == 0
    assert mesh.devices.size <= min(4, len(devs))


def test_shard_placement_matches_mesh_n4_d2():
    """ISSUE 5 regression: with n_shards=4 over n_devices=2 the placement
    map and the mesh partitioning used to disagree (round-robin
    [d0,d1,d0,d1] vs the mesh's contiguous [d0,d0,d1,d1] block split).
    They must describe the same placement — shards 0,1 on the first mesh
    device, shards 2,3 on the second."""
    from repro.distributed.mesh import shard_placement, _shard_mesh_size

    d0, d1 = object(), object()
    pl = shard_placement(4, [d0, d1])
    assert pl == [d0, d0, d1, d1]
    # and a device count that does NOT divide the shard count falls back
    # to the largest divisor instead of producing an uneven split
    assert _shard_mesh_size(4, 3) == 2
    pl3 = shard_placement(4, [d0, d1, object()])
    assert pl3 == [d0, d0, d1, d1]
    # one device: everything co-located (the single-host special case)
    assert shard_placement(4, [d0]) == [d0] * 4


# ---------------------------------------------------------------------------
# multi-device sharded-sketch execution (ISSUE 5 tentpole): the mesh run
# over 2 forced host devices must be bit-identical to the single-device
# sharded run — hit sequence, final sketch state, and (adaptive) the full
# quota trajectory — for shards in {2,4}, flat and assoc layouts.
# ---------------------------------------------------------------------------

MESH_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np
import jax
from repro.core.device_simulate import simulate_trace, ClimbSpec
from repro.distributed.mesh import make_shard_mesh, shard_placement
from repro.traces import zipf_trace, phase_shift_trace

assert len(jax.devices()) == 2
tr = zipf_trace(8000, n_items=600, alpha=0.9, seed=3)


def parity(trace, C, **kw):
    mesh = make_shard_mesh(kw["shards"])
    assert mesh.devices.size == 2
    # the placement map and the mesh describe the same block placement
    per = kw["shards"] // 2
    pl = shard_placement(kw["shards"])
    assert all(pl[s] == mesh.devices.flat[s // per]
               for s in range(kw["shards"]))
    rs, ss, hs = simulate_trace(trace, C, return_state=True, **kw)
    rm, sm, hm = simulate_trace(trace, C, mesh=mesh, return_state=True, **kw)
    assert rm.extra["mesh_devices"] == 2
    np.testing.assert_array_equal(np.asarray(hs), np.asarray(hm))
    for k in ss:
        np.testing.assert_array_equal(np.asarray(ss[k]), np.asarray(sm[k]),
                                      err_msg=k)
    return rs, rm


for shards in (2, 4):
    parity(tr, 200, shards=shards, merge_every=512)            # flat tables
    parity(tr, 400, shards=shards, merge_every=512, assoc=8)   # set-assoc
print("OK parity flat+assoc")

# adaptive: full stack (runtime quota + sharded sketch + mesh), trajectory
tp = phase_shift_trace(8000, n_hot=300, working_set=80, advance=0.05, seed=2)
for shards in (2, 4):
    ra, rm = parity(tp, 200, shards=shards, adaptive=True, assoc=8,
                    climb=ClimbSpec(epoch_len=512))
    assert ra.extra["trajectory"] == rm.extra["trajectory"]
    assert ra.extra["final_quota"] == rm.extra["final_quota"]
print("OK parity adaptive")
"""

MESH_GOLDEN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np
import jax
from repro.core.device_simulate import simulate_trace
from repro.distributed.mesh import make_shard_mesh
from repro.traces import zipf_trace
from repro.traces.synthetic import zipf_probs, _sample_from_probs

assert len(jax.devices()) == 2
mesh = make_shard_mesh(2)
# the PR 1 golden pins (tests/test_device_simulate.py), tolerance widened
# to +-0.01 for the sharded+mesh path (deferred-reset timing shifts the
# estimates slightly; observed deltas are well under 0.005)
z = zipf_trace(60_000, n_items=50_000, alpha=0.9, seed=7)
r = simulate_trace(z, 200, warmup=10_000, shards=2, mesh=mesh)
assert abs(r.hit_ratio - 0.3498) < 0.01, r.hit_ratio
rng = np.random.default_rng(13)
s = np.concatenate([np.arange(100_000, 125_000, dtype=np.int64),
                    _sample_from_probs(zipf_probs(2_000, 1.0), 35_000,
                                       rng).astype(np.int64)])
r2 = simulate_trace(s, 400, warmup=5_000, shards=2, mesh=mesh)
assert abs(r2.hit_ratio - 0.4837) < 0.01, r2.hit_ratio
print("OK goldens", round(r.hit_ratio, 4), round(r2.hit_ratio, 4))
"""


MESH_EDGE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np
import jax
from repro.core.device_simulate import simulate_trace
from repro.distributed.mesh import make_shard_mesh
from repro.traces import zipf_trace

assert len(jax.devices()) == 2
mesh = make_shard_mesh(2)
tr = zipf_trace(1000, n_items=150, alpha=0.9, seed=11)


def parity(trace, C, **kw):
    rs, ss, hs = simulate_trace(trace, C, return_state=True, **kw)
    rm, sm, hm = simulate_trace(trace, C, mesh=mesh, return_state=True, **kw)
    assert rm.extra["mesh_exchange"] == "chunk"
    np.testing.assert_array_equal(np.asarray(hs), np.asarray(hm))
    for k in ss:
        np.testing.assert_array_equal(np.asarray(ss[k]), np.asarray(sm[k]),
                                      err_msg=k)


# merge_every larger than the whole trace: zero full epochs, tail-only run
parity(tr, 100, shards=2, merge_every=4096)
# trace shorter than one auto epoch (merge_epoch = min(4096, 8*100) = 800)
parity(tr[:200], 100, shards=2)
# partial final epoch: 1000 = 3 full epochs of 256 + a 232-access tail
parity(tr, 100, shards=2, merge_every=256)
# exact multiple: 1000 = 4 * 250, no tail — every epoch merges
parity(tr, 100, shards=2, merge_every=250)
print("OK edges")
"""

MESH_STALE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np
import jax
from repro.core.device_simulate import simulate_trace
from repro.core.wtinylfu import WTinyLFU
from repro.distributed.mesh import make_shard_mesh
from repro.traces import zipf_trace
from repro.traces.synthetic import zipf_probs, _sample_from_probs

assert len(jax.devices()) == 2
mesh = make_shard_mesh(2)

# host-twin bitwise ladder: collision-free sketches on both sides remove
# the hash family from the equation, so the stale-mode mesh run must
# reproduce WTinyLFU(stale_admission=True) per-access hits EXACTLY (the
# stale twin of test_sketch_step.test_host_oracle_hit_sequence_bitwise)
C = 60
tr = zipf_trace(5000, n_items=300, alpha=0.9, seed=5)
kw = dict(window_frac=0.01, sample_factor=8, doorkeeper=False,
          counters_per_item=550.0)
_, _, hm = simulate_trace(tr, C, shards=2, merge_every=512, mesh=mesh,
                          mesh_exchange="stale", return_state=True, **kw)
host = WTinyLFU(C, shards=2, merge_every=512, stale_admission=True, **kw)
host_hits = np.array([host.access(int(k)) for k in tr], np.int32)
np.testing.assert_array_equal(np.asarray(hm), host_hits)
print("OK stale host twin")

# PR-1 goldens: the speculative mode lands in the +-0.01 tier, and its
# deviation from the exact chunked mode is pinned inside it too.  The
# staleness error scales with the merge epoch (estimates lag by <= one
# epoch): the stationary zipf trace sits in the tier at the auto cadence
# (min(4096, 8*200) = 1600), while the scan->hotspot phase transition
# needs a tighter cadence (512) — at the auto 3200 the stale estimates
# lag the hotspot onset far enough to drift ~0.03 below the golden,
# which is exactly the epoch-length/accuracy dial the docs describe
z = zipf_trace(60_000, n_items=50_000, alpha=0.9, seed=7)
rx = simulate_trace(z, 200, warmup=10_000, shards=2, mesh=mesh)
rs = simulate_trace(z, 200, warmup=10_000, shards=2, mesh=mesh,
                    mesh_exchange="stale")
assert rs.extra["mesh_exchange"] == "stale"
assert abs(rs.hit_ratio - 0.3498) < 0.01, rs.hit_ratio
assert abs(rs.hit_ratio - rx.hit_ratio) < 0.01, (rs.hit_ratio, rx.hit_ratio)
rng = np.random.default_rng(13)
s = np.concatenate([np.arange(100_000, 125_000, dtype=np.int64),
                    _sample_from_probs(zipf_probs(2_000, 1.0), 35_000,
                                       rng).astype(np.int64)])
rx2 = simulate_trace(s, 400, warmup=5_000, shards=2, mesh=mesh,
                     merge_every=512)
rs2 = simulate_trace(s, 400, warmup=5_000, shards=2, mesh=mesh,
                     merge_every=512, mesh_exchange="stale")
assert abs(rs2.hit_ratio - 0.4837) < 0.01, rs2.hit_ratio
assert abs(rs2.hit_ratio - rx2.hit_ratio) < 0.01, (rs2.hit_ratio,
                                                   rx2.hit_ratio)
print("OK stale goldens", round(rs.hit_ratio, 4), round(rs2.hit_ratio, 4))
"""


def _run_forced_device_script(script, timeout=900):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_mesh_sharded_parity_two_devices():
    out = _run_forced_device_script(MESH_PARITY_SCRIPT)
    assert "OK parity flat+assoc" in out
    assert "OK parity adaptive" in out


def test_mesh_sharded_goldens_two_devices():
    out = _run_forced_device_script(MESH_GOLDEN_SCRIPT)
    assert "OK goldens" in out


def test_mesh_tail_edge_cases_two_devices():
    """Mesh tail/edge coverage (ISSUE 6): merge_every larger than the
    trace, a trace shorter than one auto epoch, a partial final epoch, and
    an exact epoch multiple — each bit-identical (chunk mode) to the
    single-device sharded run."""
    out = _run_forced_device_script(MESH_EDGE_SCRIPT)
    assert "OK edges" in out


def test_mesh_stale_exchange_two_devices():
    """Speculative stale-global admission (mesh_exchange="stale"): host
    twin bit-identical under collision-free sketches, PR-1 goldens within
    ±0.01, deviation from the exact chunked mode pinned."""
    out = _run_forced_device_script(MESH_STALE_SCRIPT)
    assert "OK stale host twin" in out
    assert "OK stale goldens" in out


def test_simulate_sweep_mesh_guards():
    """simulate_sweep must resolve/reject cfg.mesh explicitly: vmap mode
    raises (instead of silently running the single-device path), auto
    forces sequential, and invalid mesh/shards combos fail eagerly."""
    import pytest
    from repro.core.device_simulate import (simulate_sweep, simulate_trace,
                                            DeviceWTinyLFU)
    from repro.distributed.mesh import make_shard_mesh

    tr = np.arange(600, dtype=np.int64) % 80
    mesh = make_shard_mesh(2)      # single-CI-device: a size-1 shard mesh
    with pytest.raises(ValueError, match="mesh sweeps"):
        simulate_sweep(tr, [50], mode="vmap", shards=2, mesh=mesh)
    # eager validation: a meshed grid with shards=1 fails before any run
    with pytest.raises(ValueError, match="shards > 1"):
        simulate_sweep(tr, [50], shards=1, mesh=mesh)
    # auto resolves to sequential and runs the shard_map path
    out = simulate_sweep(tr, [50], shards=2, mesh=mesh, merge_every=256)
    assert out[0].extra["backend"] == "jit+sequential"
    assert out[0].extra["mesh_devices"] >= 1
    assert out[0].extra["mesh_exchange"] == "chunk"
    # ... matching the single-config mesh run exactly
    r = simulate_trace(tr, 50, shards=2, mesh=mesh, merge_every=256)
    assert out[0].hit_ratio == r.hit_ratio
    # mesh_exchange validation lives on the config, pre-compile
    with pytest.raises(ValueError, match="mesh_exchange"):
        DeviceWTinyLFU(50, shards=2, mesh_exchange="bogus").spec()
    with pytest.raises(ValueError, match="requires mesh"):
        DeviceWTinyLFU(50, shards=2, mesh_exchange="stale").spec()
