"""Distribution-layer tests.

The production-mesh machinery (16x16 / 2x16x16) is proven here on a tiny
in-test mesh: sharded lowering succeeds, FSDP+TP specs resolve for every
arch's param tree, collectives appear in the compiled module, and the HLO
cost parser stays exact on a hand-checkable program.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import build_model
from repro.optim import make_optimizer, wsd
from repro.train import make_train_state, build_train_step
from repro.distributed.mesh import make_debug_mesh
from repro.distributed.shardings import ShardingPolicy
from repro.analysis.hlo_cost import analyze_hlo

mesh = make_debug_mesh((2, 4), ("data", "model"))
arch = "%(arch)s"
cfg = get_config(arch, smoke=True).replace(
    n_heads=8, n_kv_heads=4, d_ff=256, vocab_size=512)
if cfg.family == "xlstm":
    cfg = cfg.replace(n_heads=4, n_kv_heads=4, d_ff=0)
model = build_model(cfg)
policy = ShardingPolicy(mesh, fsdp=True)
opt = make_optimizer("adamw", wsd(1e-3, 5, 50, 20))
state_shapes = jax.eval_shape(lambda k: make_train_state(model, opt, k),
                              jax.ShapeDtypeStruct((2,), jnp.uint32))
step = build_train_step(model, opt, policy=policy, loss_chunk=16)
batch = {"tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32)}
if cfg.n_vis_tokens:
    batch["vision_embeds"] = jax.ShapeDtypeStruct(
        (4, cfg.n_vis_tokens, cfg.d_model), jnp.bfloat16)
if cfg.n_codebooks:
    batch["tokens"] = jax.ShapeDtypeStruct((4, 64, cfg.n_codebooks),
                                           jnp.int32)
in_sh = (policy.shardings(state_shapes), policy.batch_specs(batch))
compiled = jax.jit(step, in_shardings=in_sh,
                   donate_argnums=(0,)).lower(state_shapes, batch).compile()
cost = analyze_hlo(compiled.as_text())
assert cost.flops > 0
n_coll = sum(cost.coll_counts.values())
assert n_coll > 0, "sharded train step must contain collectives"
print("OK", arch, int(cost.flops), int(n_coll))
"""


@pytest.mark.parametrize("arch", ["qwen3-4b", "llama4-scout-17b-a16e",
                                  "zamba2-1.2b", "xlstm-1.3b"])
def test_sharded_train_step_lowering(arch):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT % {"arch": arch}],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


class TestShardingRules:
    def test_param_specs_cover_all_archs(self):
        """Every leaf of every arch's param tree gets a consistent spec."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec
        from repro.configs import get_config, list_archs
        from repro.models import build_model
        from repro.distributed.shardings import ShardingPolicy

        class FakeMesh:
            axis_names = ("data", "model")
        pol = ShardingPolicy.__new__(ShardingPolicy)
        pol.mesh = FakeMesh()
        pol.fsdp = True
        pol.__post_init__()
        for arch in list_archs():
            cfg = get_config(arch)     # FULL config (no allocation)
            model = build_model(cfg)
            shapes = jax.eval_shape(lambda k: model.init(k),
                                    jax.ShapeDtypeStruct((2,), jnp.uint32))
            specs = pol.tree_specs(shapes)
            flat_sh, _ = jax.tree_util.tree_flatten(shapes)
            flat_sp, _ = jax.tree_util.tree_flatten(
                specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
            assert len(flat_sh) == len(flat_sp)
            for leaf, spec in zip(flat_sh, flat_sp):
                assert len(spec) <= leaf.ndim, (arch, leaf.shape, spec)
                # 'model'-sharded dims of weight matrices must divide 16
                for dim, name in zip(leaf.shape, list(spec) + [None] * 9):
                    if name == "model":
                        assert dim % 16 == 0 or dim >= 16, (arch, leaf.shape,
                                                            spec)

    def test_hlo_cost_parser_exact_on_scan_matmul(self):
        import jax
        import jax.numpy as jnp
        from repro.analysis.hlo_cost import analyze_hlo

        W = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

        def f(x, ws):
            def body(x, w):
                return jnp.tanh(x @ w), None
            return jax.lax.scan(body, x, ws)[0].sum()

        compiled = jax.jit(f).lower(x, W).compile()
        cost = analyze_hlo(compiled.as_text())
        expected = 7 * 2 * 8 * 64 * 64            # dots only
        assert abs(cost.flops - expected) / expected < 0.05


def test_sketch_shard_placement_round_robin():
    """Sketch-shard placement map (ISSUE 4): every shard maps to a device,
    round-robin when shards exceed the device count, and the 1-D shard mesh
    is bounded by the available devices."""
    import jax
    from repro.distributed.mesh import shard_placement, make_shard_mesh

    devs = jax.devices()
    pl = shard_placement(8)
    assert len(pl) == 8
    assert all(d in devs for d in pl)
    # round-robin: shard s and shard s+len(devs) share a device
    for s in range(8 - len(devs)):
        assert pl[s] == pl[s + len(devs)]
    mesh = make_shard_mesh(4)
    assert mesh.axis_names == ("shard",)
    assert mesh.devices.size == min(4, len(devs))
