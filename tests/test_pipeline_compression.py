"""Pipeline parallelism + gradient compression tests (multi-host-device
subprocesses: XLA device count must be set before jax import)."""
import os
import subprocess
import sys

import pytest

ENV = dict(os.environ,
           PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(script: str, timeout=600):
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=ENV, timeout=timeout)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-2500:])
    return r.stdout


PIPELINE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply

mesh = jax.make_mesh((4,), ("stage",), devices=jax.devices()[:4])
L, B, Dm = 8, 8, 16
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (L, Dm, Dm)) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (B, Dm))

def block(w, h):
    return jnp.tanh(h @ w)

# sequential reference
ref = x
for i in range(L):
    ref = block(ws[i], ref)

for n_micro in (2, 4):
    got = pipeline_apply(mesh, "stage", block, ws, x, n_micro=n_micro)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
print("PIPELINE-OK")
"""


COMPRESSION = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.distributed.compression import compressed_allreduce_int8

mesh = jax.make_mesh((8,), ("data",), devices=jax.devices()[:8])
G = 8
x = jax.random.normal(jax.random.PRNGKey(0), (G, 64, 32))

def f(xs, err):
    m, e = compressed_allreduce_int8(xs[0], "data", err[0])
    return m[None], e[None]

fn = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                       out_specs=(P("data"), P("data")), check_rep=False))
err0 = jnp.zeros_like(x)
mean, err = fn(x, err0)
true_mean = x.mean(0)
# every shard holds the same (approximate) mean
got = np.asarray(mean)
for g in range(G):
    rel = np.abs(got[g] - np.asarray(true_mean)).max() / (np.abs(np.asarray(true_mean)).max() + 1e-9)
    assert rel < 0.05, rel

# error feedback: accumulated mean over many steps converges to true mean
acc_c = np.zeros((64, 32)); acc_t = np.zeros((64, 32))
err = err0
for step in range(30):
    mean, err = fn(x, err)
    acc_c += np.asarray(mean[0]); acc_t += np.asarray(true_mean)
rel = np.abs(acc_c - acc_t).max() / np.abs(acc_t).max()
assert rel < 0.01, f"error feedback failed to cancel bias: {rel}"
print("COMPRESSION-OK")
"""


def test_pipeline_matches_sequential():
    assert "PIPELINE-OK" in _run(PIPELINE)


def test_compressed_allreduce_with_error_feedback():
    assert "COMPRESSION-OK" in _run(COMPRESSION)
