"""Differential tests for the fused W-TinyLFU step (kernels/sketch_step.py).

Three independent oracles pin the kernel's semantics:

1. the pure-jnp scan twin (`step_ref`) — `step_pallas` must match it
   bit-for-bit: state arrays AND per-access hit flags, across chunk splits,
   padded tails, and reset boundaries that straddle chunks;
2. the existing jnp sketch oracle (`kernels/ref.py`) — the sketch substate
   (counters + doorkeeper) after a step equals `add_ref` over the same keys,
   and estimates derived from the step state equal `estimate_ref`;
3. the host implementation (`core.wtinylfu.WTinyLFU` +
   `FrequencySketch`/`TinyLFUAdmission`) — with collision-free sketches on
   both sides the hash family cannot matter, and the device per-access hit
   sequence must equal the host's bit-for-bit (window LRU + SLRU promotion /
   demotion + admission verdicts + reset timing all agree exactly).
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.wtinylfu import WTinyLFU
from repro.kernels import ref
from repro.kernels.sketch_common import DeviceSketchConfig, keys_to_lanes
from repro.kernels.sketch_step import (StepSpec, make_step_params,
                                       init_step_state, step_ref, step_pallas,
                                       R_SIZE, R_HITS, R_T)


def lanes(keys):
    lo, hi = keys_to_lanes(np.asarray(keys, np.uint64))
    return lo.astype(jnp.int32), hi.astype(jnp.int32)


def run_ref(spec, params, keys, state=None):
    lo, hi = lanes(keys)
    state = init_step_state(spec) if state is None else state
    return step_ref(spec, params, state, lo, hi)


def run_pallas_chunks(spec, params, keys, chunk):
    state = init_step_state(spec)
    hits = []
    keys = np.asarray(keys, np.uint64)
    for s in range(0, len(keys), chunk):
        part = keys[s:s + chunk]
        pad = chunk - len(part)
        lo, hi = lanes(np.concatenate([part, np.zeros(pad, np.uint64)]))
        state, h = step_pallas(spec, params, state, lo, hi,
                               n_valid=len(part))
        hits.append(np.asarray(h)[:len(part)])
    return state, np.concatenate(hits)


def assert_state_equal(a, b):
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=f"state[{k}] differs")


SPECS = [
    # (spec, params) sweeping rows / width / doorkeeper / cap
    (StepSpec(width=256, rows=4, dk_bits=1024, window_slots=2, main_slots=60),
     make_step_params(2, 60, 48, 500, 7, 0)),
    (StepSpec(width=1024, rows=2, dk_bits=0, window_slots=5, main_slots=45),
     make_step_params(5, 45, 36, 400, 15, 0)),
    (StepSpec(width=512, rows=1, dk_bits=2048, window_slots=1, main_slots=30),
     make_step_params(1, 30, 24, 0, 3, 0)),     # sample=0: never reset
    (StepSpec(width=2048, rows=5, dk_bits=4096, window_slots=10,
              main_slots=90),
     make_step_params(10, 90, 72, 1000, 1, 0)),  # cap=1: instant saturation
]


@pytest.mark.parametrize("spec,params", SPECS)
@pytest.mark.parametrize("chunk", [128, 500])
def test_pallas_matches_ref_bitwise(spec, params, chunk):
    """Fused kernel == scan twin: state and hit flags, across chunk splits
    and padded tails (1500 accesses is not a multiple of either chunk)."""
    rng = np.random.default_rng(spec.width + chunk)
    keys = rng.integers(0, 500, size=1500, dtype=np.uint64)
    s_ref, h_ref = run_ref(spec, params, keys)
    s_pal, h_pal = run_pallas_chunks(spec, params, keys, chunk)
    assert_state_equal(s_ref, s_pal)
    np.testing.assert_array_equal(np.asarray(h_ref), h_pal)


def test_reset_straddles_chunk_boundary():
    """W=700 with 500-element chunks: the §3.3 reset fires mid-chunk-2 and
    must land identically whether the stream is chunked or not."""
    spec = StepSpec(width=256, rows=4, dk_bits=1024, window_slots=2,
                    main_slots=40)
    params = make_step_params(2, 40, 32, 700, 7, 0)
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 300, size=1200, dtype=np.uint64)
    s_ref, _ = run_ref(spec, params, keys)
    s_pal, _ = run_pallas_chunks(spec, params, keys, 500)
    assert_state_equal(s_ref, s_pal)
    # the reset actually happened: 1200 adds, W=700 -> size = 1200 - 700/2*?
    size = int(np.asarray(s_ref["regs"])[R_SIZE])
    assert size < 1200 and int(np.asarray(s_ref["regs"])[R_T]) == 1200


def test_sketch_substate_matches_add_ref():
    """The per-access sketch add inside the fused step is bit-for-bit the
    existing jnp oracle's sequential add (no reset, cap matched)."""
    spec = StepSpec(width=512, rows=4, dk_bits=2048, window_slots=4,
                    main_slots=50)
    params = make_step_params(4, 50, 40, 0, 15, 0)
    cfg = DeviceSketchConfig(width=512, rows=4, cap=15, dk_bits=2048,
                             sample_size=0)
    rng = np.random.default_rng(11)
    keys = rng.integers(0, 10_000, size=800, dtype=np.uint64)
    keys = np.concatenate([keys, keys[:200]])            # in-batch duplicates
    s_step, _ = run_ref(spec, params, keys)
    lo, hi = keys_to_lanes(keys)
    s_ora = ref.add_ref(cfg, {
        "counters": jnp.zeros((4, 512 // 8), jnp.int32),
        "doorkeeper": jnp.zeros((1, 2048 // 32), jnp.int32),
        "size": jnp.zeros((), jnp.int32)}, lo, hi)
    np.testing.assert_array_equal(
        np.asarray(s_step["counters"]).reshape(4, 512 // 8),
        np.asarray(s_ora["counters"]))
    np.testing.assert_array_equal(
        np.asarray(s_step["doorkeeper"]).reshape(-1),
        np.asarray(s_ora["doorkeeper"]).reshape(-1))


def test_cap_saturation_hot_key():
    """Adversarial stream: one key hammered past cap; counters must pin at
    cap and the estimate (via estimate_ref on the step's sketch state) at
    cap + doorkeeper bonus."""
    spec = StepSpec(width=256, rows=4, dk_bits=1024, window_slots=1,
                    main_slots=10)
    params = make_step_params(1, 10, 8, 0, 7, 0)
    keys = np.full(100, 42, np.uint64)
    s, hits = run_ref(spec, params, keys)
    cfg = DeviceSketchConfig(width=256, rows=4, cap=7, dk_bits=1024,
                             sample_size=0)
    est = ref.estimate_ref(cfg, {
        "counters": jnp.asarray(np.asarray(s["counters"]).reshape(4, 32)),
        "doorkeeper": jnp.asarray(
            np.asarray(s["doorkeeper"]).reshape(1, -1)),
        "size": jnp.zeros((), jnp.int32)}, *lanes(keys[:1]))
    assert int(est[0]) == 8          # cap 7 + doorkeeper bonus
    # first access misses, the other 99 hit the window
    assert int(np.asarray(hits).sum()) == 99


def test_padded_tail_is_inert():
    """n_valid masking: padded accesses change nothing, for both backends."""
    spec, params = SPECS[0]
    rng = np.random.default_rng(8)
    keys = rng.integers(0, 200, size=300, dtype=np.uint64)
    s_short, h_short = run_ref(spec, params, keys)
    padded = np.concatenate([keys, np.zeros(100, np.uint64)])
    lo, hi = lanes(padded)
    s_pad, h_pad = step_ref(spec, params, init_step_state(spec), lo, hi,
                            n_valid=300)
    assert_state_equal(s_short, s_pad)
    np.testing.assert_array_equal(np.asarray(h_short),
                                  np.asarray(h_pad)[:300])
    assert int(np.asarray(h_pad)[300:].sum()) == 0


def test_padded_slots_match_tight_spec():
    """A spec with more static slots than the configured capacities behaves
    bit-for-bit like the tight spec (vmapped-sweep padding is inert)."""
    tight = StepSpec(width=256, rows=4, dk_bits=1024, window_slots=2,
                     main_slots=40)
    padded = StepSpec(width=256, rows=4, dk_bits=1024, window_slots=8,
                      main_slots=128)
    params = make_step_params(2, 40, 32, 500, 7, 0)
    rng = np.random.default_rng(21)
    keys = rng.integers(0, 400, size=2000, dtype=np.uint64)
    lo, hi = lanes(keys)
    _, h_tight = step_ref(tight, params, init_step_state(tight), lo, hi)
    _, h_pad = step_ref(padded, params,
                        init_step_state(padded, window_cap=2, main_cap=40),
                        lo, hi)
    np.testing.assert_array_equal(np.asarray(h_tight), np.asarray(h_pad))


def test_host_oracle_hit_sequence_bitwise():
    """Collision-free sketches on both sides remove the hash family from the
    equation: the fused step must reproduce the host WTinyLFU per-access hit
    sequence exactly — window LRU, SLRU promotion/demotion, admission
    verdicts, and reset timing all agree."""
    from repro.traces import zipf_trace
    C = 60
    spec = StepSpec(width=1 << 16, rows=4, dk_bits=0, window_slots=1,
                    main_slots=C - 1)
    params = make_step_params(1, C - 1, int((C - 1) * 0.8), 8 * C, 8, 0)
    tr = zipf_trace(5000, n_items=300, alpha=0.9, seed=5)
    _, hits = run_ref(spec, params, tr.astype(np.uint64))
    host = WTinyLFU(C, window_frac=0.01, sample_factor=8, doorkeeper=False,
                    counters_per_item=550.0)
    host_hits = np.array([host.access(int(k)) for k in tr], np.int32)
    np.testing.assert_array_equal(np.asarray(hits), host_hits)


def test_hits_register_counts_post_warmup():
    spec, _ = SPECS[0]
    params = make_step_params(2, 60, 48, 500, 7, 100)    # warmup=100
    rng = np.random.default_rng(4)
    keys = rng.integers(0, 50, size=400, dtype=np.uint64)
    s, hits = run_ref(spec, params, keys)
    counted = int(np.asarray(hits)[100:].sum())
    assert int(np.asarray(s["regs"])[R_HITS]) == counted


# ===========================================================================
# set-associative tables (StepSpec.assoc) and 8-bit counters
# ===========================================================================

ASSOC_SPECS = [
    # 8 sets x 8 ways, doorkeeper on, reset W=700 (straddles 500-chunks)
    (StepSpec(width=256, rows=4, dk_bits=1024, window_slots=8, main_slots=64,
              assoc=8),
     make_step_params(4, 48, 38, 700, 7, 0)),
    # 16 ways, no doorkeeper
    (StepSpec(width=512, rows=2, dk_bits=0, window_slots=16, main_slots=64,
              assoc=16),
     make_step_params(6, 60, 48, 500, 15, 0)),
    # 8-bit counters: cap 100 > the 4-bit maximum of 15
    (StepSpec(width=256, rows=4, dk_bits=1024, window_slots=4, main_slots=32,
              assoc=4, counter_bits=8),
     make_step_params(3, 30, 24, 400, 100, 0, counter_bits=8)),
]


@pytest.mark.parametrize("spec,params", ASSOC_SPECS)
def test_assoc_pallas_matches_ref_bitwise(spec, params):
    """Set-associative fused kernel == scan twin: state and hit flags across
    chunk splits, padded tails, and resets that straddle chunks."""
    rng = np.random.default_rng(spec.assoc + spec.counter_bits)
    keys = rng.integers(0, 400, size=1300, dtype=np.uint64)
    s_ref, h_ref = run_ref(spec, params, keys)
    s_pal, h_pal = run_pallas_chunks(spec, params, keys, 500)
    assert_state_equal(s_ref, s_pal)
    np.testing.assert_array_equal(np.asarray(h_ref), h_pal)


def test_assoc_single_set_matches_flat_bitwise():
    """A one-set geometry degenerates to exact global LRU/SLRU: its hit
    sequence equals the flat path's bit-for-bit (differential proof that
    the per-set SLRU promote/demote/victim logic mirrors the exact one)."""
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 300, size=3000, dtype=np.uint64)
    flat = StepSpec(width=256, rows=4, dk_bits=1024, window_slots=2,
                    main_slots=40)
    params = make_step_params(2, 40, 32, 500, 7, 0)
    _, h_flat = run_ref(flat, params, keys)
    one_set = StepSpec(width=256, rows=4, dk_bits=1024, window_slots=64,
                       main_slots=64, assoc=64)
    lo, hi = lanes(keys)
    _, h_set = step_ref(one_set, params,
                        init_step_state(one_set, window_cap=2, main_cap=40),
                        lo, hi)
    np.testing.assert_array_equal(np.asarray(h_flat), np.asarray(h_set))


def test_assoc_host_twin_hit_sequence_bitwise():
    """Collision-free sketches on both sides: the set-associative device
    engine reproduces the host ``WTinyLFU(assoc=...)`` /
    ``SetAssociativeSLRU`` per-access hit sequence exactly — set placement,
    per-set window LRU, two-choice victim search, per-set protected
    budgets, admission verdicts, and reset timing all agree."""
    from repro.traces import zipf_trace
    from repro.core.hashing import assoc_geometry, slots_for
    C, assoc = 60, 8
    main_cap, window_cap = C - 1, 1
    n_sets, ways = assoc_geometry(main_cap, assoc)
    spec = StepSpec(width=1 << 16, rows=4, dk_bits=0,
                    window_slots=slots_for(window_cap, ways),
                    main_slots=n_sets * ways, assoc=ways)
    params = make_step_params(window_cap, main_cap, int(main_cap * 0.8),
                              8 * C, 8, 0)
    tr = zipf_trace(5000, n_items=300, alpha=0.9, seed=5)
    lo, hi = lanes(tr.astype(np.uint64))
    _, hits = step_ref(spec, params,
                       init_step_state(spec, window_cap, main_cap), lo, hi)
    host = WTinyLFU(C, window_frac=0.01, sample_factor=8, doorkeeper=False,
                    counters_per_item=550.0, assoc=assoc)
    host_hits = np.array([host.access(int(k)) for k in tr], np.int32)
    np.testing.assert_array_equal(np.asarray(hits), host_hits)


def test_assoc_zero_way_window_sets_bypass_to_admission():
    """Degenerate geometry (window set count > window_cap leaves zero-way
    sets): keys hashing there bypass the window straight to main admission,
    identically on host and device (regression: the device used to drop
    them, breaking hit-sequence parity)."""
    from repro.traces import zipf_trace
    C, assoc = 69, 1
    window_cap = max(1, int(round(C * 0.0725)))     # 5 < 8 window sets
    main_cap = C - window_cap
    host = WTinyLFU(C, window_frac=0.0725, sample_factor=8, doorkeeper=False,
                    counters_per_item=550.0, assoc=assoc)
    assert 0 in host._wusable                       # geometry hits the case
    spec = StepSpec(width=1 << 16, rows=4, dk_bits=0,
                    window_slots=host._n_wsets, main_slots=main_cap,
                    assoc=host.main.ways)
    params = make_step_params(window_cap, main_cap, int(main_cap * 0.8),
                              8 * C, 8, 0)
    tr = zipf_trace(4000, n_items=250, alpha=0.9, seed=5)
    lo, hi = lanes(tr.astype(np.uint64))
    _, hits = step_ref(spec, params,
                       init_step_state(spec, window_cap, main_cap), lo, hi)
    host_hits = np.array([host.access(int(k)) for k in tr], np.int32)
    np.testing.assert_array_equal(np.asarray(hits), host_hits)


def test_counter8_reset_halving_straddles_chunks():
    """§3.3 reset at counter_bits=8 (4 counters/word): the halving of
    near-cap (255) byte counters fires mid-chunk-2 under 500-element chunks
    and must land bit-for-bit with the unchunked scan — values above 127
    exercise the 8-bit borrow/sign masking in halve_words (a wrong mask
    leaks the high bit into the neighbouring byte)."""
    from repro.kernels.sketch_step import _estimate_pair, precompute_probes
    spec = StepSpec(width=256, rows=4, dk_bits=0, window_slots=2,
                    main_slots=20, counter_bits=8)
    params = make_step_params(2, 20, 16, 900, 255, 0, counter_bits=8)
    rng = np.random.default_rng(5)
    keys = np.concatenate([
        np.full(300, 7, np.uint64),          # pins key 7's counters at 255
        np.full(300, 9, np.uint64),
        rng.integers(0, 50, size=600, dtype=np.uint64),
    ])                                       # reset at add 900 = mid chunk 2
    s_ref, h_ref = run_ref(spec, params, keys)
    s_pal, h_pal = run_pallas_chunks(spec, params, keys, 500)
    assert_state_equal(s_ref, s_pal)
    np.testing.assert_array_equal(np.asarray(h_ref), h_pal)

    def estimate(state, key):
        lo, hi = lanes(np.asarray([key], np.uint64))
        kidx, kdkb, _, _ = precompute_probes(spec, lo, hi)
        return int(_estimate_pair(spec, state["counters"],
                                  state["doorkeeper"],
                                  jnp.stack([kidx[0], kidx[0]]),
                                  jnp.stack([kdkb[0], kdkb[0]]))[0])

    s_pre, _ = run_ref(spec, params, keys[:899])
    assert estimate(s_pre, 7) == 255         # saturated before the reset
    s_post, _ = run_ref(spec, params, keys[:900])
    assert estimate(s_post, 7) == 127        # halved exactly, no borrow leak
    assert int(np.asarray(s_post["regs"])[R_SIZE]) == 450


# ===========================================================================
# sharded sketches (StepSpec.shards)
# ===========================================================================

def test_shards1_is_the_identical_program():
    """shards=1 (the default) must compile the identical program — the
    exactness-ladder pin, now enforced through the central fingerprint
    registry (R7, repro.analysis.program_lint)."""
    from repro.analysis.program_lint import assert_identical_program
    assert_identical_program("shards1")
    # ... and the sharded program is genuinely different: the sketch
    # buffers double into [global || delta] halves
    base = StepSpec(width=256, rows=4, dk_bits=1024, window_slots=8,
                    main_slots=64, assoc=8)
    sharded = StepSpec(width=256, rows=4, dk_bits=1024, window_slots=8,
                      main_slots=64, assoc=8, shards=2)
    st = init_step_state(sharded)
    assert st["counters"].shape[0] == 2 * init_step_state(base)["counters"].shape[0]
    assert st["doorkeeper"].shape[0] == 2 * init_step_state(base)["doorkeeper"].shape[0]


def test_big_operand_unrolled_branch_matches_fused_bitwise(monkeypatch):
    """ISSUE 5: past ``_big_operand`` the unsharded sketch reads switch to
    the unrolled-scalar-slice discipline; every regular test runs at
    pre-cliff widths where the fused path compiles byte-identically, so
    force the threshold to 0 and pin the unrolled branches bitwise against
    the fused ones (hits AND final state, both layouts) — otherwise an
    indexing bug there would surface only as silent hit-ratio drift in the
    benchmark."""
    from repro.kernels import sketch_step

    rng = np.random.default_rng(4)
    keys = rng.integers(0, 400, size=2500, dtype=np.uint64)
    lo, hi = lanes(keys)
    for spec, params in [
            (StepSpec(width=256, rows=4, dk_bits=1024, window_slots=2,
                      main_slots=40),
             make_step_params(2, 40, 32, 500, 7, 0)),
            (StepSpec(width=256, rows=4, dk_bits=1024, window_slots=8,
                      main_slots=64, assoc=8),
             make_step_params(4, 48, 38, 700, 7, 0))]:
        st_f, h_f = step_ref(spec, params, init_step_state(spec), lo, hi)
        # step_ref is un-jitted here, so it re-traces under the patched
        # threshold (no compile cache keyed on spec can serve the fused
        # build)
        monkeypatch.setattr(sketch_step, "_PARTITION_CLIFF_BYTES", 0)
        st_u, h_u = step_ref(spec, params, init_step_state(spec), lo, hi)
        monkeypatch.undo()
        np.testing.assert_array_equal(np.asarray(h_f), np.asarray(h_u))
        for k in st_f:
            np.testing.assert_array_equal(np.asarray(st_f[k]),
                                          np.asarray(st_u[k]), err_msg=k)


SHARDED_SPECS = [
    # flat, doorkeeper on, 4 shards
    (StepSpec(width=256, rows=4, dk_bits=1024, window_slots=2, main_slots=60,
              shards=4),
     make_step_params(2, 60, 48, 500, 7, 0)),
    # set-associative, 2 shards
    (StepSpec(width=256, rows=4, dk_bits=1024, window_slots=8, main_slots=64,
              assoc=8, shards=2),
     make_step_params(4, 48, 38, 700, 7, 0)),
    # 8-bit counters, no doorkeeper, 8 shards
    (StepSpec(width=512, rows=2, dk_bits=0, window_slots=4, main_slots=32,
              assoc=4, counter_bits=8, shards=8),
     make_step_params(3, 30, 24, 400, 100, 0, counter_bits=8)),
]


@pytest.mark.parametrize("spec,params", SHARDED_SPECS)
def test_sharded_pallas_matches_ref_bitwise(spec, params):
    """Sharded fused kernel == scan twin: the delta arrays ride the same
    donated-state path, across chunk splits and padded tails."""
    rng = np.random.default_rng(spec.shards)
    keys = rng.integers(0, 400, size=1300, dtype=np.uint64)
    s_ref, h_ref = run_ref(spec, params, keys)
    s_pal, h_pal = run_pallas_chunks(spec, params, keys, 500)
    assert_state_equal(s_ref, s_pal)
    np.testing.assert_array_equal(np.asarray(h_ref), h_pal)


@pytest.mark.parametrize("assoc", [None, 8])
def test_sharded_host_twin_hit_sequence_bitwise(assoc):
    """Collision-free sketches on both sides: the sharded device engine —
    driven through epoch-chunked merges like the production runner —
    reproduces the host ``WTinyLFU(shards=...)`` per-access hit sequence
    exactly, deferred §3.3 reset timing included."""
    from repro.traces import zipf_trace
    from repro.core.device_simulate import simulate_trace
    C, E = 60, 700
    tr = zipf_trace(5000, n_items=300, alpha=0.9, seed=5)
    _, _, hits = simulate_trace(
        tr, C, shards=4, merge_every=E, assoc=assoc, doorkeeper=False,
        counters_per_item=550.0, return_state=True)
    host = WTinyLFU(C, window_frac=0.01, sample_factor=8, doorkeeper=False,
                    counters_per_item=550.0, assoc=assoc, shards=4,
                    merge_every=E)
    host_hits = np.array([host.access(int(k)) for k in tr], np.int32)
    np.testing.assert_array_equal(np.asarray(hits), host_hits)


def test_counter8_counts_past_nibble_cap():
    """8-bit packed counters keep counting where 4-bit nibbles saturate:
    a key hammered 100x under cap=100 reaches estimate 100."""
    from repro.kernels.sketch_step import (_estimate_pair, precompute_probes)
    spec = StepSpec(width=256, rows=4, dk_bits=0, window_slots=1,
                    main_slots=10, counter_bits=8)
    params = make_step_params(1, 10, 8, 0, 100, 0, counter_bits=8)
    keys = np.full(100, 42, np.uint64)
    s, hits = run_ref(spec, params, keys)
    lo, hi = lanes(keys[:1])
    kidx, kdkb, _, _ = precompute_probes(spec, lo, hi)
    est = _estimate_pair(spec, s["counters"], s["doorkeeper"],
                         jnp.stack([kidx[0], kidx[0]]),
                         jnp.stack([kdkb[0], kdkb[0]]))
    assert int(est[0]) == 100
    assert int(np.asarray(hits).sum()) == 99     # window of 1 holds the key
