"""Property tests for the merge fold and the integrity checksum (ISSUE 7).

Runs under the optional-`hypothesis` shim (tests/_hypothesis_compat.py):
with the real library installed these fuzz and shrink; in the minimal CI
image they run the same bodies over fixed seeded examples.

Pinned properties:

* ``merge_words`` equals the unpacked per-field reference
  ``min(a + b, fmax)`` on arbitrary field patterns — and saturation never
  leaks into a neighbouring packed lane;
* the merge is commutative and associative (fold order across shards is
  arbitrary), witnessed directly on the words and via checksum equality —
  the admission path may fold shard deltas in any order;
* ``halve_words`` is the per-field ``>> 1`` at both counter widths;
* ``checksum_words`` detects every single bit flip and every swap of two
  unequal words (the two corruptions the quarantine path is built for),
  and checksumming is layout-stable: the per-shard fold in
  ``shard_checksums`` equals checksumming each shard's slice directly.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.sketch_common import (checksum_words, halve_words,
                                         merge_words)
from repro.kernels.sketch_merge import shard_checksums
from repro.kernels.sketch_step import StepSpec


def _pack(fields: np.ndarray, bits: int) -> np.ndarray:
    n = 32 // bits
    w = np.zeros(fields.shape[0], np.int64)
    for i in range(n):
        w |= fields[:, i].astype(np.int64) << (i * bits)
    return w.astype(np.uint32).view(np.int32)


def _unpack(words: np.ndarray, bits: int) -> np.ndarray:
    n = 32 // bits
    u = np.asarray(words).view(np.uint32).astype(np.int64)
    return np.stack([(u >> (i * bits)) & ((1 << bits) - 1)
                     for i in range(n)], axis=-1)


def _fields(rng_seed: int, bits: int, n_words: int) -> np.ndarray:
    fmax = (1 << bits) - 1
    rng = np.random.default_rng(rng_seed)
    return rng.integers(0, fmax + 1, size=(n_words, 32 // bits))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([4, 8]),
       n=st.integers(1, 64))
def test_merge_matches_unpacked_reference(seed, bits, n):
    fmax = (1 << bits) - 1
    fa, fb = _fields(seed, bits, n), _fields(seed + 1, bits, n)
    got = _unpack(np.asarray(
        merge_words(jnp.asarray(_pack(fa, bits)),
                    jnp.asarray(_pack(fb, bits)), bits)), bits)
    np.testing.assert_array_equal(got, np.minimum(fa + fb, fmax))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([4, 8]),
       lane=st.integers(0, 3))
def test_saturation_never_leaks_across_lanes(seed, bits, lane):
    """Saturate one lane everywhere; every OTHER lane must read exactly the
    reference sum — a borrow leak would off-by-one a neighbour."""
    fmax = (1 << bits) - 1
    lanes = 32 // bits
    lane = lane % lanes
    fa, fb = _fields(seed, bits, 32), _fields(seed + 1, bits, 32)
    fa[:, lane] = fmax
    fb[:, lane] = fmax
    got = _unpack(np.asarray(
        merge_words(jnp.asarray(_pack(fa, bits)),
                    jnp.asarray(_pack(fb, bits)), bits)), bits)
    assert (got[:, lane] == fmax).all()
    others = [i for i in range(lanes) if i != lane]
    np.testing.assert_array_equal(
        got[:, others], np.minimum(fa + fb, fmax)[:, others])


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([4, 8]))
def test_merge_commutative_associative(seed, bits):
    """Shard deltas may fold in any order: a+b == b+a and
    (a+b)+c == a+(b+c), asserted on the words AND via the checksum (equal
    words <=> equal checksums is how the integrity path observes state)."""
    a, b, c = (jnp.asarray(_pack(_fields(seed + i, bits, 48), bits))
               for i in range(3))
    ab, ba = merge_words(a, b, bits), merge_words(b, a, bits)
    np.testing.assert_array_equal(np.asarray(ab), np.asarray(ba))
    lhs = merge_words(ab, c, bits)
    rhs = merge_words(a, merge_words(b, c, bits), bits)
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))
    assert int(checksum_words(lhs)) == int(checksum_words(rhs))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([4, 8]),
       n=st.integers(1, 64))
def test_halve_is_per_field_shift(seed, bits, n):
    f = _fields(seed, bits, n)
    got = _unpack(np.asarray(halve_words(jnp.asarray(_pack(f, bits)), bits)),
                  bits)
    np.testing.assert_array_equal(got, f >> 1)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), idx=st.integers(0, 10**9),
       bit=st.integers(0, 31))
def test_checksum_detects_single_bit_flip(seed, idx, bit):
    rng = np.random.default_rng(seed)
    x = rng.integers(-2**31, 2**31, size=64, dtype=np.int64).astype(np.int32)
    y = x.copy()
    i = idx % x.size
    y.view(np.uint32)[i] ^= np.uint32(1) << np.uint32(bit)
    assert int(checksum_words(jnp.asarray(x))) != \
        int(checksum_words(jnp.asarray(y)))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), i=st.integers(0, 10**9),
       j=st.integers(0, 10**9))
def test_checksum_detects_word_swap(seed, i, j):
    """Position weighting: transposing two UNEQUAL words changes the sum
    (a plain wrap-sum would not notice)."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-2**31, 2**31, size=64, dtype=np.int64).astype(np.int32)
    i, j = i % x.size, j % x.size
    if i == j or x[i] == x[j]:
        return
    y = x.copy()
    y[i], y[j] = x[j], x[i]
    assert int(checksum_words(jnp.asarray(x))) != \
        int(checksum_words(jnp.asarray(y)))


@pytest.mark.parametrize("dk_bits", [0, 1 << 10])
def test_shard_checksums_match_direct_slices(dk_bits):
    """The vectorized per-shard fold equals checksumming each shard's
    (counter-slice ‖ doorkeeper-slice) lane by hand — and mutating ONE
    shard's slice changes exactly that shard's checksum."""
    spec = StepSpec(width=1 << 10, rows=4, dk_bits=dk_bits, window_slots=2,
                    main_slots=16, shards=4)
    rng = np.random.default_rng(5)
    gc = rng.integers(-2**31, 2**31, size=spec.counter_words,
                      dtype=np.int64).astype(np.int32)
    gdk = rng.integers(-2**31, 2**31, size=spec.dk_words,
                       dtype=np.int64).astype(np.int32)
    got = np.asarray(shard_checksums(spec, jnp.asarray(gc),
                                     jnp.asarray(gdk)))
    for s in range(spec.shards):
        lane = gc.reshape(spec.rows, spec.shards,
                          spec.wps_shard)[:, s, :].reshape(-1)
        if spec.dk_bits:
            lane = np.concatenate(
                [lane, gdk.reshape(spec.shards, spec.dkw_shard)[s]])
        assert int(checksum_words(jnp.asarray(lane))) == int(got[s])
    bad = gc.copy()
    bad[spec.wps_shard] ^= 1               # row 0, shard 1, word 0
    got2 = np.asarray(shard_checksums(spec, jnp.asarray(bad),
                                      jnp.asarray(gdk)))
    assert got2[1] != got[1]
    others = [0, 2, 3]
    np.testing.assert_array_equal(got2[others], got[others])
