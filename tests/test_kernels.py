"""Per-kernel allclose tests: Pallas (interpret=True) vs the pure-jnp oracle
(ref.py) vs an independent Python mirror of the device semantics.

Sweeps shapes (width, rows, dk sizes, batch), and hypothesis-generated key
streams.  Everything is integer so comparisons are exact (assert_array_equal).
"""
import numpy as np
import pytest
import jax.numpy as jnp
from _hypothesis_compat import given, settings, strategies as st

from repro.core.hashing import probe_indices32_np, key_to_lanes, mix32_np
from repro.kernels import (DeviceSketchConfig, init_state, keys_to_lanes,
                           make_config, DeviceTinyLFU)
from repro.kernels import ops, ref
from repro.kernels.sketch_common import (probe_index, dk_probe_index,
                                         halve_words, DK_SALT_XOR, HI_MIX_XOR)


# ---------------------------------------------------------------------------
# independent python mirror of the device sketch (no jax)
# ---------------------------------------------------------------------------

class PyMirror:
    def __init__(self, cfg: DeviceSketchConfig):
        self.cfg = cfg
        self.table = np.zeros((cfg.rows, cfg.width), np.int64)
        self.dk = np.zeros(cfg.dk_bits, bool)
        self.size = 0

    def _probes(self, key):
        lo, hi = key_to_lanes(np.asarray([key], np.uint64))
        return probe_indices32_np(lo, hi, self.cfg.rows, self.cfg.width)[0]

    def _dk_probes(self, key):
        # mirror dk_probe_index: salt = (PROBE_SALTS[p] ^ DK_SALT_XOR) + ...
        from repro.core.hashing import PROBE_SALTS
        lo, hi = key_to_lanes(np.asarray([key], np.uint64))
        out = []
        for p in range(self.cfg.dk_probes):
            salt = np.uint32((PROBE_SALTS[p] ^ DK_SALT_XOR) & 0xFFFFFFFF)
            h = mix32_np(lo + salt) ^ mix32_np(hi ^ np.uint32(HI_MIX_XOR) ^ salt)
            out.append(int(h[0]) & (self.cfg.dk_bits - 1))
        return out

    def estimate(self, key):
        idx = self._probes(key)
        est = min(int(self.table[r, idx[r]]) for r in range(self.cfg.rows))
        if self.cfg.dk_bits and all(self.dk[b] for b in self._dk_probes(key)):
            est += 1
        return est

    def add(self, key):
        gate = True
        if self.cfg.dk_bits:
            bits = self._dk_probes(key)
            gate = all(self.dk[b] for b in bits)
            for b in bits:
                self.dk[b] = True
        if gate:
            idx = self._probes(key)
            vals = [int(self.table[r, idx[r]]) for r in range(self.cfg.rows)]
            m = min(vals)
            if m < self.cfg.cap:
                for r in range(self.cfg.rows):
                    if vals[r] == m:
                        self.table[r, idx[r]] = m + 1
        self.size += 1

    def reset(self):
        self.table >>= 1
        self.dk[:] = False
        self.size //= 2


def unpack_counters(cfg, counters):
    """(rows, width//8) packed int32 -> (rows, width) nibble values."""
    w = np.asarray(counters)
    out = np.zeros((cfg.rows, cfg.width), np.int64)
    for nib in range(8):
        out[:, nib::8] = (w >> (4 * nib)) & 0xF
    return out


CFGS = [
    DeviceSketchConfig(width=256, rows=4, cap=15, dk_bits=1024, sample_size=0),
    DeviceSketchConfig(width=1024, rows=4, cap=7, dk_bits=4096, sample_size=0),
    DeviceSketchConfig(width=512, rows=2, cap=15, dk_bits=0, sample_size=0),
    DeviceSketchConfig(width=2048, rows=1, cap=3, dk_bits=2048, sample_size=0),
]


@pytest.mark.parametrize("cfg", CFGS)
@pytest.mark.parametrize("batch", [1, 7, 128, 300])
def test_add_estimate_pallas_vs_ref(cfg, batch):
    rng = np.random.default_rng(hash((cfg.width, batch)) % 2**32)
    keys = rng.integers(0, 1 << 63, size=batch, dtype=np.uint64)
    lo, hi = keys_to_lanes(keys)
    s0 = init_state(cfg)
    s_pal = ops.add(cfg, s0, lo, hi, True)
    s_ref = ops.add(cfg, s0, lo, hi, False)
    np.testing.assert_array_equal(s_pal["counters"], s_ref["counters"])
    np.testing.assert_array_equal(s_pal["doorkeeper"], s_ref["doorkeeper"])
    q = rng.integers(0, 1 << 63, size=64, dtype=np.uint64)
    qlo, qhi = keys_to_lanes(q)
    np.testing.assert_array_equal(
        ops.estimate(cfg, s_pal, qlo, qhi, True),
        ops.estimate(cfg, s_ref, qlo, qhi, False))


@pytest.mark.parametrize("cfg", CFGS[:2])
def test_pallas_vs_python_mirror(cfg):
    """Kernel semantics == independent python implementation, per key."""
    rng = np.random.default_rng(5)
    keys = rng.integers(0, 1 << 40, size=200, dtype=np.uint64)
    keys = np.concatenate([keys, keys[:100], keys[:50]])   # repeats
    mir = PyMirror(cfg)
    for k in keys:
        mir.add(int(k))
    lo, hi = keys_to_lanes(keys)
    st_ = ops.add(cfg, init_state(cfg), lo, hi, True)
    np.testing.assert_array_equal(
        unpack_counters(cfg, st_["counters"]), mir.table)
    q = np.unique(keys)[:80]
    est_dev = ops.estimate(cfg, st_, *keys_to_lanes(q), True)
    est_py = np.array([mir.estimate(int(k)) for k in q])
    np.testing.assert_array_equal(np.asarray(est_dev), est_py)


def test_reset_halves_and_clears():
    cfg = CFGS[0]
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 1 << 63, size=500, dtype=np.uint64)
    s = ops.add(cfg, init_state(cfg), *keys_to_lanes(keys), True)
    before = unpack_counters(cfg, s["counters"])
    s2 = ops.reset(cfg, s)
    after = unpack_counters(cfg, s2["counters"])
    np.testing.assert_array_equal(after, before // 2)
    assert int(np.asarray(s2["doorkeeper"]).sum()) == 0
    assert int(s2["size"]) == int(s["size"]) // 2


def test_auto_reset_on_sample_boundary():
    cfg = DeviceSketchConfig(width=256, rows=4, cap=15, dk_bits=1024,
                             sample_size=256)
    keys = np.arange(300, dtype=np.uint64)
    s = ops.add(cfg, init_state(cfg), *keys_to_lanes(keys), True)
    assert int(s["size"]) == 150          # (300) -> reset -> 150
    assert int(np.asarray(s["doorkeeper"]).sum()) == 0


def test_cap_saturation():
    cfg = DeviceSketchConfig(width=256, rows=4, cap=7, dk_bits=0,
                             sample_size=0)
    keys = np.full(50, 123456, np.uint64)
    s = ops.add(cfg, init_state(cfg), *keys_to_lanes(keys), True)
    est = ops.estimate(cfg, s, *keys_to_lanes(keys[:1]), True)
    assert int(est[0]) == 7


def test_sequential_order_dependence():
    """Conservative update is order-sensitive; kernel must process the batch
    in order (same result as two sequential half-batches)."""
    cfg = CFGS[0]
    rng = np.random.default_rng(2)
    keys = rng.integers(0, 1 << 30, size=120, dtype=np.uint64)
    s_once = ops.add(cfg, init_state(cfg), *keys_to_lanes(keys), True)
    s_two = ops.add(cfg, init_state(cfg), *keys_to_lanes(keys[:60]), True)
    s_two = ops.add(cfg, s_two, *keys_to_lanes(keys[60:]), True)
    np.testing.assert_array_equal(s_once["counters"], s_two["counters"])


def test_admission_fused_vs_two_estimates():
    cfg = CFGS[1]
    rng = np.random.default_rng(3)
    hist = rng.integers(0, 1 << 20, size=1000, dtype=np.uint64)
    s = ops.add(cfg, init_state(cfg), *keys_to_lanes(hist), True)
    cand = rng.integers(0, 1 << 20, size=64, dtype=np.uint64)
    vict = rng.integers(0, 1 << 20, size=64, dtype=np.uint64)
    fused = ops.admit(cfg, s, *keys_to_lanes(cand), *keys_to_lanes(vict), True)
    ce = np.asarray(ops.estimate(cfg, s, *keys_to_lanes(cand), True))
    ve = np.asarray(ops.estimate(cfg, s, *keys_to_lanes(vict), True))
    np.testing.assert_array_equal(np.asarray(fused), ce > ve)
    # and fused pallas == fused ref
    fused_ref = ops.admit(cfg, s, *keys_to_lanes(cand), *keys_to_lanes(vict),
                          False)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(fused_ref))


def test_padding_is_inert():
    """ops.add pads the batch to 128 lanes; padding must not change state."""
    cfg = CFGS[0]
    keys = np.array([11, 22, 33], np.uint64)       # batch of 3 -> padded 128
    s = ops.add(cfg, init_state(cfg), *keys_to_lanes(keys), True)
    mir = PyMirror(cfg)
    for k in keys:
        mir.add(int(k))
    np.testing.assert_array_equal(unpack_counters(cfg, s["counters"]),
                                  mir.table)


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=10_000), min_size=1,
                max_size=200))
def test_property_estimate_lower_bounds_true_count(keys):
    """With no reset and huge cap, sketch estimate >= true count (CM property
    survives the doorkeeper: first occurrence absorbed, +1 returned)."""
    cfg = DeviceSketchConfig(width=4096, rows=4, cap=15, dk_bits=1 << 14,
                             sample_size=0)
    karr = np.asarray(keys, np.uint64)
    s = ops.add(cfg, init_state(cfg), *keys_to_lanes(karr), True)
    uniq, counts = np.unique(karr, return_counts=True)
    est = np.asarray(ops.estimate(cfg, s, *keys_to_lanes(uniq), True))
    # doorkeeper absorbs the 1st occurrence (no false negatives -> +1 back);
    # the main table never undercounts; counters cap at 15:
    #   est >= min(true_count, cap + 1)
    assert (est >= np.minimum(counts, cfg.cap + 1)).all()


def test_device_facade_end_to_end():
    t = DeviceTinyLFU(num_blocks=128, sample_factor=8, use_pallas=True)
    rng = np.random.default_rng(0)
    hot = rng.integers(0, 100, size=2000, dtype=np.uint64)
    t.record(hot)
    cold = np.arange(10_000, 10_064, dtype=np.uint64)
    hot_q = np.arange(0, 64, dtype=np.uint64)
    admits = t.admit(cold, hot_q)          # cold candidates vs hot victims
    assert admits.sum() <= 3               # cold should almost never win
    admits2 = t.admit(hot_q, cold)         # hot candidates vs cold victims
    assert admits2.sum() >= 60
