"""Golden hit-ratio regressions for the device simulation engine.

Pins host (`WTinyLFU` + `run_trace`) and device (`device_simulate`) hit
ratios on two small fixed-seed traces so future refactors cannot silently
change admission behavior.  Host and device use different hash families
(64-bit splitmix vs 32-bit-lane mixers), so agreement is statistical — the
golden tolerance is the acceptance band (±0.005), far above observed deltas
(~2e-4) but far below any behavioral regression (getting window LRU, SLRU
promotion, admission, or reset wrong moves these ratios by >0.01).
"""
import numpy as np
import pytest

from repro.core import WTinyLFU, run_trace
from repro.core.device_simulate import (DeviceWTinyLFU, simulate_trace,
                                        simulate_sweep)
from repro.traces import zipf_trace
from repro.traces.synthetic import zipf_probs, _sample_from_probs

TOL = 0.005

# pinned goldens (trace construction below must not change)
GOLDEN_ZIPF_HOST = 0.3496
GOLDEN_ZIPF_DEVICE = 0.3498
GOLDEN_SCANHOT_HOST = 0.4834
GOLDEN_SCANHOT_DEVICE = 0.4837


def golden_zipf_trace():
    return zipf_trace(60_000, n_items=50_000, alpha=0.9, seed=7)


def scan_then_hotspot_trace():
    """25k one-shot sequential scan (LRU poison) then a 35k Zipf(1.0)
    hotspot over 2k items — the workload family admission exists for."""
    rng = np.random.default_rng(13)
    scan = np.arange(100_000, 125_000, dtype=np.int64)
    hot = _sample_from_probs(zipf_probs(2_000, 1.0), 35_000,
                             rng).astype(np.int64)
    return np.concatenate([scan, hot])


class TestGoldenZipf:
    C, WARMUP = 200, 10_000

    def test_host_matches_golden(self):
        r = run_trace(WTinyLFU(self.C, sample_factor=8), golden_zipf_trace(),
                      warmup=self.WARMUP, trace_name="golden-zipf")
        assert abs(r.hit_ratio - GOLDEN_ZIPF_HOST) < TOL

    def test_device_matches_golden_and_host(self):
        tr = golden_zipf_trace()
        d = simulate_trace(tr, self.C, warmup=self.WARMUP,
                           trace_name="golden-zipf")
        h = run_trace(WTinyLFU(self.C, sample_factor=8), tr,
                      warmup=self.WARMUP)
        assert abs(d.hit_ratio - GOLDEN_ZIPF_DEVICE) < TOL
        assert abs(d.hit_ratio - h.hit_ratio) < TOL      # acceptance band
        assert d.trace == "golden-zipf"
        assert d.accesses == len(tr) - self.WARMUP


class TestGoldenScanHotspot:
    C, WARMUP = 400, 5_000

    def test_host_and_device_match_golden(self):
        tr = scan_then_hotspot_trace()
        h = run_trace(WTinyLFU(self.C, sample_factor=8), tr,
                      warmup=self.WARMUP)
        d = simulate_trace(tr, self.C, warmup=self.WARMUP)
        assert abs(h.hit_ratio - GOLDEN_SCANHOT_HOST) < TOL
        assert abs(d.hit_ratio - GOLDEN_SCANHOT_DEVICE) < TOL
        assert abs(d.hit_ratio - h.hit_ratio) < TOL


def test_pallas_backend_matches_jit():
    """Interpret-mode fused kernel == jit scan twin on a short prefix."""
    tr = golden_zipf_trace()[:3000]
    j = simulate_trace(tr, 100, backend="jit")
    p = simulate_trace(tr, 100, backend="pallas", chunk=512)
    assert p.hits == j.hits and p.accesses == j.accesses


def test_sweep_matches_single_runs():
    """Sequential sweeps use per-config host-matched sketch sizing, so each
    grid point is bit-identical to its standalone simulate_trace run."""
    tr = golden_zipf_trace()[:8000]
    rows = simulate_sweep(tr, [100], window_fracs=[0.01, 0.2], warmup=1000,
                          mode="sequential")
    for row in rows:
        single = simulate_trace(tr, 100,
                                window_frac=row.extra["window_frac"],
                                warmup=1000)
        assert row.hits == single.hits
        assert row.extra["grid"] == 2


def test_sweep_vmap_matches_sequential():
    """The vmapped one-program grid (accelerator shape) reproduces the
    sequential sweep exactly when the grid shares one capacity (identical
    geometry => bit-identical); padding slots from the shared spec are
    inert."""
    tr = golden_zipf_trace()[:3000]
    seq = simulate_sweep(tr, [100], window_fracs=[0.01, 0.2], warmup=500,
                         mode="sequential")
    vm = simulate_sweep(tr, [100], window_fracs=[0.01, 0.2], warmup=500,
                        mode="vmap")
    assert [r.hits for r in vm] == [r.hits for r in seq]


def test_sweep_per_config_traces():
    """(G, N) trace batches: one trace per grid point (seed sweeps)."""
    tr = np.stack([zipf_trace(4000, n_items=3000, alpha=0.9, seed=s)
                   for s in (1, 2)])
    rows = simulate_sweep(tr, [100], window_fracs=[0.01, 0.2], warmup=500)
    assert len(rows) == 2
    assert all(0.0 < r.hit_ratio < 1.0 for r in rows)
    assert rows[0].hits != rows[1].hits          # different traces


def test_sizing_mirrors_host_defaults():
    """DeviceWTinyLFU reproduces the host WTinyLFU/default_sketch sizing."""
    cfg = DeviceWTinyLFU(1000)
    host = WTinyLFU(1000, sample_factor=8)
    assert cfg.window_cap == host.window_cap
    assert cfg.main_cap == host.main_cap
    assert cfg.prot_cap == host.main.prot_cap
    sk = host.admission.sketch.cfg
    assert cfg.sample_size == sk.sample_size
    assert cfg.cap == sk.cap
    assert cfg.width == sk.width
    assert cfg.dk_bits == sk.doorkeeper_bits


def test_run_trace_trace_name_label():
    """Satellite fix: run_trace labels single-trace results."""
    tr = golden_zipf_trace()[:2000]
    r = run_trace(WTinyLFU(50, sample_factor=8), tr, trace_name="mytrace")
    assert r.trace == "mytrace"
    r2 = run_trace(WTinyLFU(50, sample_factor=8), tr)
    assert r2.trace == "?"


# ===========================================================================
# set-associative engine (assoc=) and 8-bit counters
# ===========================================================================

ASSOC_TOL = 0.01


class TestGoldenAssoc:
    """Per-set LRU is an approximation of exact global LRU, so the golden
    contract for the set-associative engine is hit-ratio tolerance (±0.01 vs
    the exact host W-TinyLFU) instead of the flat path's bitwise parity.
    Capacities are production-shaped (the engine's target regime); at very
    small C with few ways the approximation costs more (documented in
    README) and the exact assoc=None path is the right tool."""

    def test_zipf_assoc_within_tolerance(self):
        tr = golden_zipf_trace()
        h = run_trace(WTinyLFU(1000, sample_factor=8), tr, warmup=10_000)
        for a in (4, 8, 16):
            d = simulate_trace(tr, 1000, warmup=10_000, assoc=a)
            assert abs(d.hit_ratio - h.hit_ratio) < ASSOC_TOL, (a, d.hit_ratio)
            assert d.extra["assoc"] == a

    def test_scanhot_assoc_within_tolerance(self):
        tr = scan_then_hotspot_trace()
        h = run_trace(WTinyLFU(400, sample_factor=8), tr, warmup=5_000)
        for a in (4, 8, 16):
            d = simulate_trace(tr, 400, warmup=5_000, assoc=a)
            assert abs(d.hit_ratio - h.hit_ratio) < ASSOC_TOL, (a, d.hit_ratio)


def test_assoc_sweep_matches_single_runs():
    """Sequential sweeps with assoc use per-config tight geometry: each grid
    point is bit-identical to its standalone simulate_trace run."""
    tr = golden_zipf_trace()[:8000]
    rows = simulate_sweep(tr, [100], window_fracs=[0.01, 0.2], warmup=1000,
                          mode="sequential", assoc=8)
    for row in rows:
        single = simulate_trace(tr, 100, window_frac=row.extra["window_frac"],
                                warmup=1000, assoc=8)
        assert row.hits == single.hits
        assert row.extra["assoc"] == 8


def test_sweep_reports_amortized_wall():
    """Satellite fix: each SimResult row carries the per-row amortized wall
    (so accesses/wall_s is per-config) and the grid total in extra."""
    tr = golden_zipf_trace()[:4000]
    rows = simulate_sweep(tr, [100], window_fracs=[0.01, 0.2], warmup=500,
                          mode="sequential")
    assert len(rows) == 2
    for r in rows:
        assert r.extra["grid"] == 2
        assert r.extra["grid_wall_s"] == pytest.approx(rows[0].extra["grid_wall_s"])
        assert r.wall_s == pytest.approx(r.extra["grid_wall_s"] / 2)


# ===========================================================================
# sharded sketches (shards=) — ISSUE 4
# ===========================================================================


class TestGoldenSharded:
    """ISSUE 4 acceptance: sharded sketches at shards ∈ {2, 4} stay within
    ±0.01 of the exact host W-TinyLFU on both golden traces.  The sharded
    engine differs from exact in three bounded ways: the 32-bit hash
    family, the shard-partitioned probe space (same expected collision
    rate), and §3.3 aging deferred to merge boundaries (at most one reset
    period late by the merge_epoch auto-sizing).  Observed deltas are
    ~0.005 — the band would catch any behavioral regression."""
    C, WARMUP = 200, 10_000

    def test_zipf_sharded_within_tolerance(self):
        tr = golden_zipf_trace()
        h = run_trace(WTinyLFU(self.C, sample_factor=8), tr,
                      warmup=self.WARMUP)
        for s in (2, 4):
            d = simulate_trace(tr, self.C, warmup=self.WARMUP, shards=s)
            assert abs(d.hit_ratio - h.hit_ratio) < ASSOC_TOL, (s, d.hit_ratio)
            assert d.extra["shards"] == s
            # auto cadence: never defer aging past one reset period
            assert d.extra["merge_every"] == min(4096, 8 * self.C)

    def test_scanhot_sharded_assoc_within_tolerance(self):
        """Production shape: sharded sketch + set-associative tables."""
        tr = scan_then_hotspot_trace()
        h = run_trace(WTinyLFU(400, sample_factor=8), tr, warmup=5_000)
        for s in (2, 4):
            d = simulate_trace(tr, 400, warmup=5_000, shards=s, assoc=8)
            assert abs(d.hit_ratio - h.hit_ratio) < ASSOC_TOL, (s, d.hit_ratio)


def test_sharded_pallas_backend_matches_jit():
    """Merge-epoch-chunked fused kernel == jit scan, partial tail included
    (3000 accesses is not a multiple of the 1600-access auto cadence)."""
    tr = golden_zipf_trace()[:3000]
    j = simulate_trace(tr, 200, backend="jit", shards=4)
    p = simulate_trace(tr, 200, backend="pallas", shards=4)
    assert p.hits == j.hits and p.accesses == j.accesses


def test_sharded_sweep_matches_single_runs():
    """Sequential sharded sweeps run the same epoch-chunked program per grid
    point: each row is bit-identical to its standalone simulate_trace."""
    tr = golden_zipf_trace()[:8000]
    rows = simulate_sweep(tr, [100], window_fracs=[0.01, 0.2], warmup=1000,
                          mode="sequential", shards=2)
    for row in rows:
        single = simulate_trace(tr, 100, window_frac=row.extra["window_frac"],
                                warmup=1000, shards=2)
        assert row.hits == single.hits
        assert row.extra["shards"] == 2
    # vmapped grids cannot host the epoch-chunked merge: clear error, and
    # mode="auto" resolves to sequential on every backend
    with pytest.raises(ValueError):
        simulate_sweep(tr, [100], shards=2, mode="vmap")
    auto = simulate_sweep(tr[:4000], [100], shards=2, mode="auto")
    assert auto[0].extra["backend"] == "jit+sequential"


def test_sharded_degenerate_short_traces():
    """Traces shorter than one merge epoch (or empty) run without a merge
    and without crashing."""
    short = golden_zipf_trace()[:500]
    r = simulate_trace(short, 50, shards=2)
    assert 0.0 <= r.hit_ratio <= 1.0
    empty = simulate_trace(np.array([], np.int64), 50, shards=2)
    assert empty.hits == 0


def test_counter8_matches_host_large_sample_factor():
    """Satellite: counter_bits=8 lifts the 4-bit cap (15) so sample_factor >
    16 no longer needs the host engine; device cap matches the host's."""
    from repro.core.device_simulate import DeviceWTinyLFU
    cfg = DeviceWTinyLFU(200, sample_factor=32, counter_bits=8)
    assert cfg.cap == 31                       # host: max(1, 32 - 1)
    assert DeviceWTinyLFU(200, sample_factor=32).cap == 15   # 4-bit clamp
    tr = golden_zipf_trace()[:20_000]
    h = run_trace(WTinyLFU(200, sample_factor=32), tr, warmup=4_000)
    d = simulate_trace(tr, 200, warmup=4_000, sample_factor=32,
                       counter_bits=8)
    assert abs(d.hit_ratio - h.hit_ratio) < TOL
