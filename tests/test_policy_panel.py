"""Cross-policy exactness tier for the device policy panel (ISSUE 9).

The fused step's admission/victim rules are now an enum
(``StepSpec.policy``: wtinylfu | s3fifo | arc | lfu) dispatched statically
over the shared set-associative machinery.  This tier pins the panel four
ways:

1. **Exactness** — each competitor's device hit sequence equals its host
   twin (``core.policies.SetAssoc*``) bit-for-bit: s3fifo/lfu under
   collision-free sketches (huge width, doorkeeper off, so both hash
   families degenerate to exact counts), arc exact-by-construction at any
   ``dk_bits`` (the twin replays the device's ghost-Bloom arithmetic
   through ``dk_probe_index_np``).
2. **Program pin** — ``policy="wtinylfu"`` lowers the byte-identical HLO
   as a spec that never mentions policy (the same exactness-ladder pin as
   shards=1/adaptive=False): the panel refactor cannot perturb the default
   engine.
3. **Goldens** — per-policy hit ratios on the golden zipf and
   scan-then-hotspot traces, pinned to ±0.01.
4. **Ordering** — W-TinyLFU >= every competitor on the golden Zipf trace
   at the paper's sizing (the panel exists to make this claim testable).

Plus the satellite regressions: ``simulate_sweep`` row-schema round-trip
(rows used to omit ``streams``/``integrity``/``merge_every``) and
policy-parameterized property tests (capacity bound, hits never evict,
lane isolation) under the optional-hypothesis shim.
"""
import numpy as np
import jax
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.core.device_simulate import (DeviceWTinyLFU, _row_extra,
                                        simulate_trace, simulate_sweep)
from repro.core.policies import SetAssocARC, SetAssocLFU, SetAssocS3FIFO
from repro.kernels.sketch_common import POLICIES
from repro.kernels.sketch_step import (StepSpec, _EMPTY, _I32_MAX, MT_LO,
                                       MT_HI, MT_META, WT_META,
                                       init_step_state, step_ref)
from repro.traces import panel_traces, zipf_trace
from repro.traces.synthetic import zipf_probs, _sample_from_probs

COMPETITORS = ("s3fifo", "arc", "lfu")

# ---------------------------------------------------------------------------
# pinned goldens (trace construction + configs below must not change).
# Measured on the jit scan; the tolerance is the cross-refactor acceptance
# band, an order of magnitude above float/jitter (the runs are integer-
# deterministic) and far below any behavioral regression.
# ---------------------------------------------------------------------------
GOLDEN_TOL = 0.01
# golden zipf (C=200, warmup=10k, assoc=8, sample_factor=8)
GOLDEN_ZIPF = {"wtinylfu": 0.3407, "s3fifo": 0.3470,
               "arc": 0.3517, "lfu": 0.2699}
# scan-then-hotspot (C=400, warmup=5k, assoc=8, sample_factor=8)
GOLDEN_SCANHOT = {"wtinylfu": 0.4800, "s3fifo": 0.4790,
                  "arc": 0.4786, "lfu": 0.4650}


def _wf(policy: str) -> float:
    """Per-policy window_frac: s3fifo gets the S3-FIFO paper's 10% small
    queue; arc/lfu ignore the knob (window pinned to its 1-slot minimum)."""
    return 0.1 if policy == "s3fifo" else 0.01


def golden_zipf_trace():
    return zipf_trace(60_000, n_items=50_000, alpha=0.9, seed=7)


def scan_then_hotspot_trace():
    rng = np.random.default_rng(13)
    scan = np.arange(100_000, 125_000, dtype=np.int64)
    hot = _sample_from_probs(zipf_probs(2_000, 1.0), 35_000,
                             rng).astype(np.int64)
    return np.concatenate([scan, hot])


# ===========================================================================
# 1. device == host-twin hit sequence, bit for bit
# ===========================================================================

def _device_hits(cfg: DeviceWTinyLFU, trace: np.ndarray) -> np.ndarray:
    _, _, hits = simulate_trace(trace, cfg.capacity, return_state=True,
                                **{f: getattr(cfg, f) for f in
                                   ("window_frac", "sample_factor",
                                    "counters_per_item", "doorkeeper",
                                    "dk_bits_per_item", "assoc", "policy")})
    return np.asarray(hits)


class TestDeviceTwinParity:
    """Per-access hit-sequence parity on a 5k-access zipf trace whose
    working set churns a C=60 cache hard (every structural rule — FIFO
    order, CLOCK marks, ghost adaptation, min-frequency victims — is
    exercised thousands of times; one divergent access fails the test)."""

    C = 60
    TRACE = zipf_trace(5_000, n_items=600, alpha=0.9, seed=11)

    # collision-free sketch recipe shared by the sketch-consulting twins:
    # ~550 counters/item makes both hash families exact counters, and
    # doorkeeper=False removes the only cross-family +1 disagreement
    FREE = dict(sample_factor=8, counters_per_item=550.0, doorkeeper=False)

    def _twin_hits(self, twin) -> np.ndarray:
        return np.array([twin.access(int(k)) for k in self.TRACE], np.int32)

    def test_s3fifo_bit_for_bit(self):
        cfg = DeviceWTinyLFU(self.C, assoc=8, policy="s3fifo",
                             window_frac=0.1, **self.FREE)
        twin = SetAssocS3FIFO(self.C, window_frac=0.1, assoc=8, **self.FREE)
        dev = _device_hits(cfg, self.TRACE)
        assert np.array_equal(dev, self._twin_hits(twin))

    def test_lfu_bit_for_bit(self):
        cfg = DeviceWTinyLFU(self.C, assoc=8, policy="lfu", **self.FREE)
        twin = SetAssocLFU(self.C, assoc=8, **self.FREE)
        dev = _device_hits(cfg, self.TRACE)
        assert np.array_equal(dev, self._twin_hits(twin))

    def test_arc_bit_for_bit_at_realistic_dk_bits(self):
        """ARC parity needs NO collision-free assumption: the twin replays
        the device Bloom-ghost arithmetic, so even a deliberately tiny
        (collision-heavy) filter must agree bit-for-bit."""
        cfg = DeviceWTinyLFU(self.C, assoc=8, policy="arc")
        twin = SetAssocARC(self.C, assoc=8, dk_bits=cfg.dk_bits, dk_probes=3)
        dev = _device_hits(cfg, self.TRACE)
        assert np.array_equal(dev, self._twin_hits(twin))

    def test_arc_bit_for_bit_at_tiny_dk_bits(self):
        spec_bits = 256                    # ~4 bits/ghost: heavy aliasing
        cfg = DeviceWTinyLFU(self.C, assoc=8, policy="arc",
                             dk_bits_per_item=spec_bits / (8 * self.C))
        assert cfg.dk_bits == spec_bits
        twin = SetAssocARC(self.C, assoc=8, dk_bits=spec_bits, dk_probes=3)
        dev = _device_hits(cfg, self.TRACE)
        assert np.array_equal(dev, self._twin_hits(twin))


# ===========================================================================
# 2. policy="wtinylfu" compiles the byte-identical program
# ===========================================================================

def test_wtinylfu_policy_is_the_identical_program():
    """The panel dispatch is static: the default policy must lower to the
    byte-identical HLO as a spec that predates the enum — the exactness
    ladder's 'the refactor cannot have perturbed the default engine'
    guarantee, enforced through the central fingerprint registry (R7)."""
    from repro.analysis.program_lint import assert_identical_program
    assert_identical_program("policy-default")


def test_competitor_specs_validate_eagerly():
    for pol in COMPETITORS:
        with pytest.raises(ValueError):
            DeviceWTinyLFU(100, policy=pol)            # needs assoc
    with pytest.raises(ValueError):
        DeviceWTinyLFU(100, policy="arc", assoc=8, doorkeeper=False)
    with pytest.raises(ValueError):
        DeviceWTinyLFU(100, policy="s3fifo", assoc=8, shards=2)
    with pytest.raises(ValueError):
        DeviceWTinyLFU(100, policy="lfu", assoc=8, adaptive=True)
    with pytest.raises(ValueError):
        DeviceWTinyLFU(100, policy="bogus")
    with pytest.raises(AssertionError):
        StepSpec(width=256, rows=4, dk_bits=0, window_slots=8,
                 main_slots=64, assoc=8, policy="arc")  # arc needs dk_bits


# ===========================================================================
# 3 + 4. golden hit ratios, and W-TinyLFU wins the golden Zipf
# ===========================================================================

class TestGoldenPanel:
    def _panel(self, trace, C, warmup, **kw):
        return {pol: simulate_trace(trace, C, assoc=8, policy=pol,
                                    window_frac=_wf(pol), warmup=warmup,
                                    **kw).hit_ratio
                for pol in POLICIES}

    def test_golden_zipf_panel(self):
        got = self._panel(golden_zipf_trace(), 200, 10_000)
        for pol, want in GOLDEN_ZIPF.items():
            assert abs(got[pol] - want) < GOLDEN_TOL, (pol, got[pol], want)

    def test_golden_scanhot_panel(self):
        got = self._panel(scan_then_hotspot_trace(), 400, 5_000)
        for pol, want in GOLDEN_SCANHOT.items():
            assert abs(got[pol] - want) < GOLDEN_TOL, (pol, got[pol], want)

    def test_wtinylfu_beats_every_competitor_on_golden_zipf(self):
        """The paper's claim, now falsifiable in-repo: at the paper's
        sketch sizing (sample_factor=16 needs byte counters — the 4-bit
        cap at sf=8 blunts W-TinyLFU's frequency resolution more than its
        competitors') W-TinyLFU's hit ratio is >= every panel policy on
        the golden Zipf trace."""
        got = self._panel(golden_zipf_trace(), 1000, 10_000,
                          sample_factor=16, counter_bits=8)
        for pol in COMPETITORS:
            assert got["wtinylfu"] >= got[pol], (pol, got)


def test_panel_traces_families():
    fams = panel_traces(length=4_000, seed=3)
    assert set(fams) == {"zipf", "scan-hot", "churn", "loop"}
    for name, tr in fams.items():
        assert tr.dtype == np.int64 and len(tr) == 4_000, name
    # deterministic in seed
    again = panel_traces(length=4_000, seed=3)
    assert all(np.array_equal(fams[k], again[k]) for k in fams)


# ===========================================================================
# satellite: sweep row schema round-trips every config knob
# ===========================================================================

class TestSweepRowSchema:
    TR = zipf_trace(3_000, n_items=2_000, alpha=0.9, seed=5)

    def test_policy_axis_rows(self):
        rows = simulate_sweep(self.TR, [64], policies=POLICIES, assoc=8,
                              window_fracs=(0.1,))
        assert [r.policy for r in rows] == \
            ["w-tinylfu(device)", "s3fifo(device)", "arc(device)",
             "lfu(device)"]
        for r in rows[1:]:
            assert r.extra["policy"] == r.policy.split("(")[0]
        assert "policy" not in rows[0].extra      # default stays absent
        # per-policy sweep rows == the per-policy single runs, exactly
        for r in rows:
            pol = r.extra.get("policy", "wtinylfu")
            single = simulate_trace(self.TR, 64, assoc=8, policy=pol,
                                    window_frac=0.1)
            assert r.hits == single.hits, pol

    def test_multi_policy_grid_rejects_vmap(self):
        with pytest.raises(ValueError):
            simulate_sweep(self.TR, [64], policies=("wtinylfu", "lfu"),
                           assoc=8, mode="vmap")

    def test_sequential_rows_carry_shards_merge_integrity(self):
        """The row-schema bug this satellite fixes: sequential-mode sweep
        rows silently omitted the shards/merge_every/integrity (and
        streams) knobs that simulate_trace rows carry — a sweep row must
        round-trip every config knob that shaped it."""
        rows = simulate_sweep(self.TR, [64], shards=2, merge_every=512,
                              integrity=True, mode="sequential")
        single = simulate_trace(self.TR, 64, shards=2, merge_every=512,
                                integrity=True)
        for r in rows:
            assert r.extra["shards"] == 2
            assert r.extra["merge_every"] == 512
            assert r.extra["integrity"] is True
        knobs = ("policy", "shards", "merge_every", "integrity", "streams")
        assert {k: rows[0].extra.get(k) for k in knobs} == \
            {k: single.extra.get(k) for k in knobs}

    def test_row_extra_covers_every_knob(self):
        assert _row_extra(DeviceWTinyLFU(64), None, False) == {}
        e = _row_extra(DeviceWTinyLFU(64, shards=2, integrity=True,
                                      streams=3, merge_every=128),
                       None, False)
        assert e == {"shards": 2, "merge_every": 128, "integrity": True,
                     "streams": 3}
        e = _row_extra(DeviceWTinyLFU(64, assoc=8, policy="arc"), None,
                       False)
        assert e == {"policy": "arc"}


# ===========================================================================
# satellite: policy-parameterized property tests (hypothesis shim)
# ===========================================================================

def _prop_cfg(policy: str) -> DeviceWTinyLFU:
    return DeviceWTinyLFU(24, assoc=4, policy=policy,
                          window_frac=_wf(policy), sample_factor=8)


def _resident_counts(spec, cfg, state):
    """(window, main) resident record counts from the table meta columns."""
    wtab = np.asarray(state["wtab"]).reshape(-1, spec.wcols)
    mtab = np.asarray(state["mtab"]).reshape(-1, spec.mcols)
    res = []
    for tab, col in ((wtab, WT_META), (mtab, MT_META)):
        meta = tab[:, col]
        res.append(int(((meta != _I32_MAX) & (meta != _EMPTY)).sum()))
    return tuple(res)


@settings(max_examples=4, deadline=None)
@given(pol=st.sampled_from(POLICIES), seed=st.integers(0, 2**31 - 1))
def test_resident_count_never_exceeds_capacity(pol, seed):
    cfg = _prop_cfg(pol)
    rng = np.random.default_rng(seed)
    tr = rng.integers(0, 300, size=600).astype(np.int64)
    _, state, _ = simulate_trace(tr, cfg.capacity, return_state=True,
                                 assoc=cfg.assoc, policy=pol,
                                 window_frac=_wf(pol))
    w, m = _resident_counts(cfg.spec(), cfg, state)
    assert w <= cfg.window_cap
    assert m <= cfg.main_cap
    assert w + m <= cfg.capacity + (1 if pol in ("arc", "lfu") else 0)


@settings(max_examples=3, deadline=None)
@given(pol=st.sampled_from(POLICIES), seed=st.integers(0, 2**31 - 1))
def test_hit_never_changes_resident_set(pol, seed):
    """A hit must not evict: stepping one access at a time, the resident
    key set after any hit equals the set before it (refreshes/mark bits
    may change; membership may not)."""
    cfg = _prop_cfg(pol)
    spec = cfg.spec()
    params = cfg.params()
    state = init_step_state(spec, cfg.window_cap, cfg.main_cap)
    step = jax.jit(step_ref, static_argnums=0)
    rng = np.random.default_rng(seed)
    tr = rng.zipf(1.4, size=250).astype(np.int64) % 200

    def resident_keys(st_):
        out = set()
        for tab, cols in ((np.asarray(st_["wtab"]).reshape(-1, spec.wcols),
                           (0, 1, WT_META)),
                          (np.asarray(st_["mtab"]).reshape(-1, spec.mcols),
                           (MT_LO, MT_HI, MT_META))):
            lo_c, hi_c, meta_c = cols
            ok = (tab[:, meta_c] != _I32_MAX) & (tab[:, meta_c] != _EMPTY)
            for row in tab[ok]:
                out.add((np.uint32(row[lo_c]).item(),
                         np.uint32(row[hi_c]).item()))
        return out

    lo = np.asarray(tr & 0xFFFFFFFF, np.uint32)
    hi = np.asarray(tr >> 32, np.uint32)
    import jax.numpy as jnp
    for i in range(len(tr)):
        before = resident_keys(state)
        state, hit = step(spec, params, state,
                          jnp.asarray(lo[i:i + 1]), jnp.asarray(hi[i:i + 1]))
        if int(np.asarray(hit)[0]):
            assert resident_keys(state) == before, (pol, i)


@settings(max_examples=3, deadline=None)
@given(pol=st.sampled_from(POLICIES), seed=st.integers(0, 2**31 - 1))
def test_poisoned_lane_cannot_perturb_neighbor(pol, seed):
    """streams=2 lane isolation across the policy panel: lane 1 replaying
    adversarial churn (every key unique — pure pollution) must leave lane
    0's hit count identical to the streams=1 run of the same trace."""
    cfg = _prop_cfg(pol)
    rng = np.random.default_rng(seed)
    good = rng.zipf(1.3, size=500).astype(np.int64) % 300
    poison = (10**9 + np.arange(500)).astype(np.int64)
    solo = simulate_trace(good, cfg.capacity, assoc=cfg.assoc, policy=pol,
                          window_frac=_wf(pol))
    duo = simulate_trace(np.stack([good, poison]), cfg.capacity,
                         assoc=cfg.assoc, policy=pol,
                         window_frac=_wf(pol), streams=2)
    assert duo.extra["lane_hits"][0] == solo.hits, pol
