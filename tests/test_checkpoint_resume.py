"""Epoch-boundary checkpoint/resume for the device engine (ISSUE 7).

The contract under test: ``DeviceWTinyLFU.run(..., checkpoint_dir=)``
snapshots the full engine state at merge-epoch boundaries, and
``resume_trace`` restores the latest complete checkpoint and continues —
with the resumed run BIT-IDENTICAL to an uninterrupted one (per-access hit
sequence, every state buffer, and in adaptive mode the quota trajectory).
Segmented execution itself must be invisible: a checkpointed run equals the
single-scan ``simulate_trace`` bitwise.  The multi-device variants (resume
onto the same mesh, elastic 2->1 restore) run under forced host devices in
a subprocess, following tests/test_distributed.py.
"""
import os
import re
import shutil

import numpy as np
import pytest

from repro.core.device_simulate import (DeviceWTinyLFU, ClimbSpec,
                                        simulate_trace, resume_trace)
from repro.checkpoint.store import latest_step
from repro.traces import zipf_trace

from test_distributed import _run_forced_device_script


def _steps(d):
    return sorted(int(m.group(1)) for x in os.listdir(d)
                  if (m := re.match(r"step_(\d+)$", x)))


def _prune_to_first(d):
    """Delete all but the EARLIEST checkpoint, so resume has real work."""
    steps = _steps(d)
    assert len(steps) >= 2, f"need an intermediate checkpoint, got {steps}"
    for s in steps[1:]:
        shutil.rmtree(os.path.join(d, f"step_{s:010d}"))
    return steps[0]


def _assert_same(res_a, st_a, h_a, res_b, st_b, h_b, adaptive):
    np.testing.assert_array_equal(np.asarray(h_a), np.asarray(h_b))
    assert set(st_a) == set(st_b)
    for k in st_a:
        np.testing.assert_array_equal(np.asarray(st_a[k]),
                                      np.asarray(st_b[k]), err_msg=k)
    assert res_a.hits == res_b.hits
    assert res_a.hit_ratio == res_b.hit_ratio
    if adaptive:
        assert res_a.extra["trajectory"] == res_b.extra["trajectory"]
        assert res_a.extra["final_quota"] == res_b.extra["final_quota"]


CASES = [
    # (label, cfg-kwargs, adaptive, checkpoint_every)
    ("flat-static", dict(), False, 9000),
    ("flat-sharded", dict(shards=4, merge_every=512), False, 512 * 8),
    ("assoc-static", dict(assoc=8, shards=4, merge_every=512), False,
     512 * 8),
    ("flat-adaptive", dict(), True, 1024 * 4),
    ("assoc-adaptive", dict(assoc=8, shards=4, merge_every=512), True,
     1024 * 4),
]


@pytest.mark.parametrize("label,kw,adaptive,every",
                         CASES, ids=[c[0] for c in CASES])
def test_checkpoint_resume_bitwise(label, kw, adaptive, every, tmp_path):
    tr = zipf_trace(12_000, n_items=2_000, alpha=0.9, seed=4)
    climb = ClimbSpec(epoch_len=1024) if adaptive else None
    res0, st0, h0 = simulate_trace(tr, 300, warmup=1_000, adaptive=adaptive,
                                   climb=climb, return_state=True, **kw)
    cfg = DeviceWTinyLFU(300, adaptive=adaptive, **kw)
    d = str(tmp_path / "ck")
    # 1. the checkpointing (segmented) run equals the single-scan run
    res1, st1, h1 = cfg.run(tr, warmup=1_000, climb=climb, checkpoint_dir=d,
                            checkpoint_every=every, return_state=True)
    _assert_same(res0, st0, h0, res1, st1, h1, adaptive)
    assert res1.extra["checkpoint_every"] > 0
    # 2. resume from an INTERMEDIATE checkpoint (later ones deleted, so the
    #    restored cursor is mid-trace) — still bit-identical
    cursor = _prune_to_first(d)
    assert 0 < cursor < len(tr)
    res2, st2, h2 = resume_trace(tr, cfg, checkpoint_dir=d, warmup=1_000,
                                 climb=climb, checkpoint_every=every,
                                 return_state=True)
    assert res2.extra["resumed_at"] == cursor
    _assert_same(res0, st0, h0, res2, st2, h2, adaptive)


def test_resume_from_empty_dir_runs_fresh(tmp_path):
    tr = zipf_trace(4_000, n_items=600, alpha=0.9, seed=9)
    cfg = DeviceWTinyLFU(150)
    d = str(tmp_path / "none")
    res0 = simulate_trace(tr, 150, warmup=500)
    res1 = resume_trace(tr, cfg, checkpoint_dir=d, warmup=500,
                        checkpoint_every=3000)
    assert res1.extra["resumed_at"] == 0
    assert res1.hits == res0.hits
    assert latest_step(d) is not None          # and it checkpointed


def test_config_fingerprint_mismatch_rejected(tmp_path):
    tr = zipf_trace(4_000, n_items=600, alpha=0.9, seed=9)
    d = str(tmp_path / "ck")
    DeviceWTinyLFU(150).run(tr, warmup=500, checkpoint_dir=d,
                            checkpoint_every=3000)
    wrong = DeviceWTinyLFU(200)                # different capacity
    with pytest.raises(ValueError, match="capacity"):
        resume_trace(tr, wrong, checkpoint_dir=d, warmup=500)
    with pytest.raises(ValueError, match="warmup"):
        resume_trace(tr, DeviceWTinyLFU(150), checkpoint_dir=d, warmup=999)


def test_checkpoint_cadence_validation(tmp_path):
    tr = zipf_trace(2_000, n_items=300, alpha=0.9, seed=1)
    cfg = DeviceWTinyLFU(100, shards=4, merge_every=512)
    with pytest.raises(ValueError, match="checkpoint_every"):
        cfg.run(tr, checkpoint_dir=str(tmp_path / "x"), checkpoint_every=100)


def test_eager_config_validation():
    with pytest.raises(ValueError, match="power of two"):
        DeviceWTinyLFU(100, shards=3)
    with pytest.raises(ValueError, match="capacity"):
        DeviceWTinyLFU(0)
    with pytest.raises(ValueError, match="window_frac"):
        DeviceWTinyLFU(100, window_frac=1.5)
    with pytest.raises(ValueError, match="counter_bits"):
        DeviceWTinyLFU(100, counter_bits=5)
    with pytest.raises(ValueError, match="merge_every"):
        DeviceWTinyLFU(100, shards=2, merge_every=-1)
    with pytest.raises(ValueError, match="integrity"):
        DeviceWTinyLFU(100, integrity=True)


def test_integrity_checksums_are_invisible_when_clean(tmp_path):
    """With no corruption the integrity machinery must not change a single
    admission decision: same hits, same sketch words, and the quarantine
    counter stays zero across the whole run."""
    tr = zipf_trace(10_000, n_items=1_500, alpha=0.9, seed=6)
    kw = dict(shards=4, merge_every=512)
    res0, st0, h0 = simulate_trace(tr, 300, warmup=1_000, return_state=True,
                                   **kw)
    res1, st1, h1 = simulate_trace(tr, 300, warmup=1_000, return_state=True,
                                   integrity=True, **kw)
    np.testing.assert_array_equal(np.asarray(h0), np.asarray(h1))
    for k in st0:
        np.testing.assert_array_equal(np.asarray(st0[k]),
                                      np.asarray(st1[k]), err_msg=k)
    assert int(np.asarray(st1["csum"])[-1]) == 0
    # and it checkpoints/resumes like any other state key
    cfg = DeviceWTinyLFU(300, integrity=True, **kw)
    d = str(tmp_path / "ck")
    res2, st2, h2 = cfg.run(tr, warmup=1_000, checkpoint_dir=d,
                            checkpoint_every=512 * 8, return_state=True)
    _prune_to_first(d)
    res3, st3, h3 = resume_trace(tr, cfg, checkpoint_dir=d, warmup=1_000,
                                 checkpoint_every=512 * 8, return_state=True)
    _assert_same(res2, st2, h2, res3, st3, h3, False)


# ---------------------------------------------------------------------------
# multi-device: resume onto the same 2-device mesh (chunk + stale modes) and
# ELASTIC restore — a checkpoint written by a 2-device mesh run resumed on a
# single device.  Checkpoints store the canonical single-device layout, so
# elastic restore is just the ordinary resume path plus a device_put.
# ---------------------------------------------------------------------------

MESH_RESUME_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import re, shutil
import numpy as np
import jax
from repro.core.device_simulate import (DeviceWTinyLFU, ClimbSpec,
                                        simulate_trace, resume_trace)
from repro.distributed.mesh import make_shard_mesh
from repro.traces import zipf_trace

assert len(jax.devices()) == 2
mesh = make_shard_mesh(4, require=2)
tr = zipf_trace(10_000, n_items=1_500, alpha=0.9, seed=3)


def prune_to_first(d):
    steps = sorted(int(m.group(1)) for x in os.listdir(d)
                   if (m := re.match(r"step_(\d+)$", x)))
    assert len(steps) >= 2, steps
    for s in steps[1:]:
        shutil.rmtree(os.path.join(d, f"step_{s:010d}"))


def same(h0, st0, h3, st3):
    np.testing.assert_array_equal(np.asarray(h0), np.asarray(h3))
    for k in st0:
        np.testing.assert_array_equal(np.asarray(st0[k]),
                                      np.asarray(st3[k]), err_msg=k)


for exch in ("chunk", "stale"):
    for adaptive in (False, True):
        cl = ClimbSpec(epoch_len=1024) if adaptive else None
        kw = dict(shards=4, merge_every=512, mesh_exchange=exch)
        res0, st0, h0 = simulate_trace(tr, 300, warmup=1_000, mesh=mesh,
                                       adaptive=adaptive, climb=cl,
                                       return_state=True, **kw)
        cfg = DeviceWTinyLFU(300, mesh=mesh, adaptive=adaptive, **kw)
        d = f"/tmp/ckpt_mesh_{exch}_{adaptive}"
        shutil.rmtree(d, ignore_errors=True)
        every = (1024 if adaptive else 512) * 4
        res1, st1, h1 = cfg.run(tr, warmup=1_000, climb=cl,
                                checkpoint_dir=d, checkpoint_every=every,
                                return_state=True)
        same(h0, st0, h1, st1)
        prune_to_first(d)
        # resume ON the mesh
        res2, st2, h2 = resume_trace(tr, cfg, checkpoint_dir=d,
                                     warmup=1_000, climb=cl,
                                     checkpoint_every=every,
                                     return_state=True)
        same(h0, st0, h2, st2)
        # ELASTIC: the same (pruned-again) checkpoint on ONE device — exact
        # for chunk mode (its mesh run is bit-identical to single-device)
        if exch == "chunk":
            prune_to_first(d)      # the resume re-wrote the later steps
            cfg1 = DeviceWTinyLFU(300, adaptive=adaptive, shards=4,
                                  merge_every=512)
            res3, st3, h3 = resume_trace(tr, cfg1, checkpoint_dir=d,
                                         warmup=1_000, climb=cl,
                                         checkpoint_every=every,
                                         return_state=True)
            same(h0, st0, h3, st3)
        shutil.rmtree(d, ignore_errors=True)
        print(f"OK mesh resume {exch} adaptive={adaptive}")
print("OK all mesh resume")
"""


def test_mesh_checkpoint_resume_and_elastic_two_devices():
    out = _run_forced_device_script(MESH_RESUME_SCRIPT)
    assert "OK all mesh resume" in out
