"""Unit + property tests for the TinyLFU frequency sketch (paper §3)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.sketch import (FrequencySketch, SketchConfig, ExactHistogram,
                               default_sketch)


def make_sketch(sample=1 << 20, counters=4096, rows=4, cap=1 << 30,
                dk_bits=0, conservative=True, seed=0):
    return FrequencySketch(SketchConfig(
        sample_size=sample, counters=counters, rows=rows, cap=cap,
        doorkeeper_bits=dk_bits, conservative=conservative, seed=seed))


class TestSketchBasics:
    def test_empty_estimates_zero(self):
        s = make_sketch()
        assert s.estimate(42) == 0

    def test_single_add(self):
        s = make_sketch()
        s.add(42)
        assert s.estimate(42) >= 1

    def test_monotone_in_adds(self):
        s = make_sketch()
        prev = 0
        for _ in range(10):
            s.add(7)
            est = s.estimate(7)
            assert est >= prev
            prev = est
        assert s.estimate(7) == 10  # no collisions possible w/ single key

    def test_cap_saturates(self):
        s = make_sketch(cap=7)
        for _ in range(100):
            s.add(3)
        assert s.estimate(3) == 7

    def test_reset_halves(self):
        s = make_sketch()
        for _ in range(9):
            s.add(5)
        s.reset()
        assert s.estimate(5) == 4      # 9 // 2
        assert s.resets == 1

    def test_reset_triggers_at_sample_size(self):
        s = make_sketch(sample=10)
        for i in range(10):
            s.add(i % 3)
        assert s.resets == 1
        assert s.size == 5             # halved sample counter

    def test_cbf_layout(self):
        # rows=1 with k probes into a single table = paper's CBF prototype
        s = FrequencySketch(SketchConfig(sample_size=1 << 20, counters=4096,
                                         rows=1, probes_per_row=4,
                                         cap=1 << 30))
        for _ in range(5):
            s.add(99)
        assert s.estimate(99) == 5


class TestOverestimateProperty:
    """CM/CBF sketches never undercount (without reset/cap/doorkeeper)."""

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.integers(min_value=0, max_value=200), min_size=1,
                    max_size=500))
    def test_estimate_geq_true(self, keys):
        s = make_sketch(counters=1024)
        true = {}
        for k in keys:
            s.add(k)
            true[k] = true.get(k, 0) + 1
        for k, c in true.items():
            assert s.estimate(k) >= c

    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.integers(min_value=0, max_value=100), min_size=50,
                    max_size=500), st.integers(min_value=0, max_value=3))
    def test_conservative_leq_plain(self, keys, seed):
        """Minimal increment estimates <= plain CBF estimates, pointwise."""
        cu = make_sketch(counters=256, conservative=True, seed=seed)
        pl = make_sketch(counters=256, conservative=False, seed=seed)
        for k in keys:
            cu.add(k)
            pl.add(k)
        for k in set(keys):
            assert cu._table_estimate(k) <= pl._table_estimate(k)


class TestDoorkeeper:
    def test_first_timer_stays_out_of_main(self):
        s = make_sketch(dk_bits=1 << 16)
        s.add(1234)
        assert s._table_estimate(1234) == 0    # absorbed by doorkeeper
        assert s.estimate(1234) == 1           # but estimate includes it

    def test_second_timer_reaches_main(self):
        s = make_sketch(dk_bits=1 << 16)
        s.add(1234)
        s.add(1234)
        assert s._table_estimate(1234) >= 1
        assert s.estimate(1234) >= 2

    def test_reset_clears_doorkeeper(self):
        s = make_sketch(sample=4, dk_bits=1 << 16)
        for i in range(4):
            s.add(i)           # 4 adds -> reset
        assert not any(s.dk)

    @settings(max_examples=20, deadline=None)
    @given(st.sets(st.integers(min_value=0, max_value=10_000), min_size=1,
                   max_size=200))
    def test_no_false_negatives(self, keys):
        s = make_sketch(dk_bits=1 << 16)
        for k in keys:
            s.add(k)
        for k in keys:
            assert s.estimate(k) >= 1


class TestExactHistogram:
    def test_truncation_error_bounded(self):
        """Integer vs float reset differ by < 1 after any number of resets
        (paper §3.3.2: worst-case truncation error converges to 1)."""
        hi = ExactHistogram(sample_size=1 << 30)
        hf = ExactHistogram(sample_size=1 << 30, integer_division=False)
        rng = np.random.default_rng(0)
        keys = rng.integers(0, 50, size=2000)
        for i, k in enumerate(map(int, keys)):
            hi.add(k)
            hf.add(k)
            if i % 300 == 299:
                hi.reset()
                hf.reset()
        for k in set(map(int, keys)):
            assert abs(hi.estimate(k) - hf.estimate(k)) < 1.0 + 1e-9

    def test_convergence_lemma(self):
        """Lemma 3.2: E(h_i) -> f_i * W regardless of initial error."""
        W = 1000
        h = ExactHistogram(sample_size=W, integer_division=False)
        h.counts[7] = 500.0                 # absurd initial error
        rng = np.random.default_rng(1)
        # key 7 has frequency 0.2
        for _ in range(30 * W):
            h.add(7 if rng.random() < 0.2 else int(rng.integers(10, 10_000)))
        assert abs(h.estimate(7) - 0.2 * W) < 0.15 * W


def test_default_sketch_sizing():
    s = default_sketch(1000, sample_factor=8)
    assert s.cfg.sample_size == 8000
    assert s.cfg.cap == 7                   # W/C with doorkeeper absorbing 1
    # ~1.25+ bytes per sample element (paper Fig 22 accuracy knee)
    assert s.cfg.meta_bits() / s.cfg.sample_size >= 10


def test_meta_bits_accounting():
    cfg = SketchConfig(sample_size=9000, counters=8192, rows=4, cap=7,
                       doorkeeper_bits=8192)
    assert cfg.meta_bits() == 8192 * 3 + 8192
