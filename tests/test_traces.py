"""Synthetic trace generator sanity checks."""
import numpy as np
import pytest

from repro.traces import (zipf_trace, zipf_probs, youtube_dynamic_trace,
                          wiki_drift_trace, spc1_like_trace, oltp_like_trace,
                          glimpse_trace, multi_tenant_prompt_trace,
                          fickle_churn_trace, phase_shift_trace)


@pytest.mark.parametrize("gen", [
    lambda n: zipf_trace(n, n_items=10_000, alpha=0.9, seed=1),
    lambda n: youtube_dynamic_trace(n, weeks=5, items_per_week=500, seed=1),
    lambda n: wiki_drift_trace(n, n_items=5000, drift_every=1000, seed=1),
    lambda n: spc1_like_trace(n, n_random=2000, seed=1),
    lambda n: oltp_like_trace(n, n_pages=2000, seed=1),
    lambda n: glimpse_trace(n, loop_items=500, n_random=2000, seed=1),
    lambda n: fickle_churn_trace(n, n_hot=1000, seed=1),
    lambda n: phase_shift_trace(n, n_hot=1000, working_set=400, seed=1),
])
def test_generators_basic(gen):
    tr = gen(20_000)
    assert len(tr) == 20_000 and tr.dtype == np.int64 and (tr >= 0).all()
    # deterministic
    np.testing.assert_array_equal(tr, gen(20_000))


def test_zipf_is_skewed():
    tr = zipf_trace(50_000, n_items=100_000, alpha=0.9, seed=2)
    _, counts = np.unique(tr, return_counts=True)
    top = np.sort(counts)[::-1]
    assert top[:100].sum() > 0.15 * len(tr)     # head carries real mass


def test_zipf_probs_normalized():
    p = zipf_probs(1000, 0.9)
    assert abs(p.sum() - 1.0) < 1e-9 and (np.diff(p) <= 0).all()


def test_oltp_has_ascending_log():
    tr = oltp_like_trace(20_000, n_pages=1000, seed=3)
    log = tr[tr >= 1000]                        # log region keys
    # ascending trend: later log accesses have larger ids on average
    a, b = log[: len(log) // 2], log[len(log) // 2:]
    assert b.mean() > a.mean()


def test_fickle_churn_one_hit_wonders():
    """Every churn key appears exactly once; the hot set repeats."""
    tr = fickle_churn_trace(30_000, n_hot=1000, seed=2)
    cold = tr[tr >= 1000]
    _, counts = np.unique(cold, return_counts=True)
    assert (counts == 1).all()                  # true one-hit wonders
    assert 0.2 < len(cold) / len(tr) < 0.4      # ~30% churn share
    hot = tr[tr < 1000]
    _, hcounts = np.unique(hot, return_counts=True)
    assert hcounts.max() > 50                   # zipf head repeats heavily


def test_phase_shift_two_phases():
    """First half: stationary zipf over the hot range.  Second half: keys
    from a sliding working set over a fresh id range (recency-only)."""
    tr = phase_shift_trace(40_000, n_hot=1000, working_set=400, seed=2)
    first, second = tr[:20_000], tr[20_000:]
    assert (first < 1000).all()
    assert (second >= 1000).all()
    # the working set slides: late keys sit above early keys
    assert second[-1000:].mean() > second[:1000].mean() + 1000


def test_multi_tenant_prefix_shared():
    tr = multi_tenant_prompt_trace(200, n_tenants=10, seed=4)
    _, counts = np.unique(tr, return_counts=True)
    assert (counts > 5).any()                   # shared prefix blocks re-hit
