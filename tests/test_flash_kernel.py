"""Pallas flash-attention kernel vs the jnp online-softmax oracle."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_tpu
from repro.models.layers import flash_attention


def _rand(shape, key, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) \
        .astype(dtype)


@pytest.mark.parametrize("B,S,H,D,qb,kb", [
    (2, 256, 4, 64, 64, 64),
    (1, 512, 2, 128, 128, 64),
    (2, 128, 8, 32, 32, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_matches_reference(B, S, H, D, qb, kb, causal):
    q = _rand((B, S, H, D), 0)
    k = _rand((B, S, H, D), 1)
    v = _rand((B, S, H, D), 2)
    got = flash_attention_tpu(q, k, v, causal=causal, q_block=qb,
                              kv_block=kb, interpret=True)
    want = flash_attention(q, k, v, causal=causal, q_block=qb, kv_block=kb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    q = _rand((1, 128, 2, 64), 3, dtype)
    k = _rand((1, 128, 2, 64), 4, dtype)
    v = _rand((1, 128, 2, 64), 5, dtype)
    got = flash_attention_tpu(q, k, v, q_block=64, kv_block=64)
    want = flash_attention(q, k, v, q_block=64, kv_block=64)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
    assert got.dtype == dtype


def test_exact_softmax_vs_naive():
    """Both implementations vs the unblocked softmax ground truth."""
    B, S, H, D = 1, 128, 2, 32
    q, k, v = (_rand((B, S, H, D), i) for i in (6, 7, 8))
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(D)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    got = flash_attention_tpu(q, k, v, q_block=32, kv_block=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
