"""Sketch mergeability invariants (ISSUE 4): the properties that make
per-shard counting sound.

CM-sketch counts are linearly mergeable — counts add — and the paper's §3.3
aging (divide-by-2) commutes with the merge in exact arithmetic.  These
tests pin what the implementation guarantees at both counter widths:

* ``merge_words`` is a per-field SATURATING add: fields pin at the counter
  maximum and no overflow may borrow into the neighbouring packed counter
  ("no borrow leak across shard folds");
* merge-then-halve equals halve-then-merge exactly whenever the integer
  arithmetic allows it (even fields, no saturation), and never diverges by
  more than the floor-division ulp otherwise;
* ``merge_halve`` applies the deferred §3.3 reset bit-for-bit like the
  per-access reset would have (saturated counters halve with no borrow
  leak; an epoch owing several resets catches up with k halvings);
* merged shard estimates equal a single unsharded sketch's estimates under
  collision-free hashing — on the host twin and differentially on the
  device engine (the sharded step with aging disabled reproduces the
  unsharded step's hit sequence bit-for-bit).
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.sketch import (FrequencySketch, ShardedFrequencySketch,
                               SketchConfig)
from repro.kernels.sketch_common import halve_words, merge_words, keys_to_lanes
from repro.kernels.sketch_step import (StepSpec, make_step_params,
                                       init_step_state, step_ref, R_SIZE)
from repro.kernels.sketch_merge import merge_halve


def _pack(fields: np.ndarray, bits: int) -> np.ndarray:
    """(W, fields_per_word) int fields -> (W,) packed int32 words."""
    n = 32 // bits
    w = np.zeros(fields.shape[0], np.int64)
    for i in range(n):
        w |= fields[:, i].astype(np.int64) << (i * bits)
    return w.astype(np.uint32).view(np.int32)


def _unpack(words: np.ndarray, bits: int) -> np.ndarray:
    n = 32 // bits
    u = np.asarray(words).view(np.uint32).astype(np.int64)
    return np.stack([(u >> (i * bits)) & ((1 << bits) - 1)
                     for i in range(n)], axis=-1)


@pytest.mark.parametrize("bits", [4, 8])
def test_merge_words_is_per_field_saturating_add(bits):
    fmax = (1 << bits) - 1
    n = 32 // bits
    rng = np.random.default_rng(bits)
    fa = rng.integers(0, fmax + 1, size=(256, n))
    fb = rng.integers(0, fmax + 1, size=(256, n))
    got = np.asarray(merge_words(jnp.asarray(_pack(fa, bits)),
                                 jnp.asarray(_pack(fb, bits)), bits))
    np.testing.assert_array_equal(got, _pack(np.minimum(fa + fb, fmax), bits))


@pytest.mark.parametrize("bits", [4, 8])
def test_merge_words_saturation_no_borrow_leak(bits):
    """Adversarial layout: saturating fields alternate with zero fields — a
    carry-leaking merge would deposit a 1 in the zero neighbours."""
    fmax = (1 << bits) - 1
    n = 32 // bits
    fields = np.zeros((8, n), np.int64)
    fields[:, ::2] = fmax                       # 15,0,15,0,... / 255,0,...
    w = jnp.asarray(_pack(fields, bits))
    got = _unpack(np.asarray(merge_words(w, w, bits)), bits)
    assert (got[:, ::2] == fmax).all()          # saturated, not wrapped
    assert (got[:, 1::2] == 0).all()            # neighbours untouched


@pytest.mark.parametrize("bits", [4, 8])
def test_merge_commutes_with_halve(bits):
    """§3.3 aging commutes with the merge: exactly on even unsaturated
    fields, and within the floor-division ulp (<= 1) in general."""
    fmax = (1 << bits) - 1
    n = 32 // bits
    rng = np.random.default_rng(7 + bits)
    # even fields whose sums stay below saturation: exact commutation
    fa = 2 * rng.integers(0, fmax // 4, size=(128, n))
    fb = 2 * rng.integers(0, fmax // 4, size=(128, n))
    a, b = jnp.asarray(_pack(fa, bits)), jnp.asarray(_pack(fb, bits))
    mh = halve_words(merge_words(a, b, bits), bits)
    hm = merge_words(halve_words(a, bits), halve_words(b, bits), bits)
    np.testing.assert_array_equal(np.asarray(mh), np.asarray(hm))
    # arbitrary parity, sums below saturation: the two orders differ by at
    # most the floor-division ulp.  (Saturation breaks commutation — which
    # is exactly why the engine always merges FIRST and halves second.)
    fa = rng.integers(0, fmax // 2 + 1, size=(128, n))
    fb = rng.integers(0, fmax // 2, size=(128, n))
    a, b = jnp.asarray(_pack(fa, bits)), jnp.asarray(_pack(fb, bits))
    mh = _unpack(np.asarray(halve_words(merge_words(a, b, bits), bits)), bits)
    hm = _unpack(np.asarray(merge_words(halve_words(a, bits),
                                        halve_words(b, bits), bits)), bits)
    assert np.abs(mh - hm).max() <= 1


@pytest.mark.parametrize("bits,cap", [(4, 15), (8, 255)])
def test_merge_halve_saturated_reset_no_borrow_leak(bits, cap):
    """In-engine §3.3 catch-up: a key hammered to a saturated counter, then
    a merge_halve with the sample size crossed — the global must read
    cap//2 exactly (15->7 / 255->127), with no borrow leaking from the
    halving of the packed neighbours, and the deltas must clear."""
    spec = StepSpec(width=64, rows=4, dk_bits=0, window_slots=1,
                    main_slots=8, counter_bits=bits, shards=2)
    params = make_step_params(1, 8, 6, 0, cap, 0, counter_bits=bits)
    keys = np.full(cap + 50, 42, np.uint64)     # saturate key 42
    lo, hi = keys_to_lanes(keys)
    st, _ = step_ref(spec, params, init_step_state(spec),
                     lo.astype(jnp.int32), hi.astype(jnp.int32))
    from repro.kernels.sketch_step import _estimate_pair, precompute_probes
    kidx, kdkb, _, _ = precompute_probes(spec, lo[:1].astype(jnp.int32),
                                         hi[:1].astype(jnp.int32))
    pair = (jnp.stack([kidx[0], kidx[0]]), jnp.stack([kdkb[0], kdkb[0]]))
    est = _estimate_pair(spec, st["counters"], st["doorkeeper"], *pair)
    assert int(est[0]) == cap                    # saturated before the fold
    # sample crossed once: W = half the adds -> exactly one halving
    params_w = make_step_params(1, 8, 6, (cap + 50) // 2 + 1, cap, 0,
                                counter_bits=bits)
    st2 = merge_halve(spec, params_w, st)
    est2 = _estimate_pair(spec, st2["counters"], st2["doorkeeper"], *pair)
    assert int(est2[0]) == cap // 2              # halved exactly
    # the delta halves are cleared by the fold
    H = spec.counter_words
    assert int(np.abs(np.asarray(st2["counters"])[H:]).sum()) == 0
    assert int(np.abs(np.asarray(st2["doorkeeper"])[spec.dk_words:]).sum()) == 0


def test_merge_halve_multi_reset_catchup():
    """An epoch that crossed the sample period k times owes k halvings:
    4000 adds at W=1000 leave size 500 (4000 -> 2000 -> 1000 -> 500) and
    fields shifted by 3."""
    spec = StepSpec(width=64, rows=4, dk_bits=0, window_slots=1,
                    main_slots=8, shards=2)
    params = make_step_params(1, 8, 6, 1000, 15, 0)
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 200, size=4000, dtype=np.uint64)
    lo, hi = keys_to_lanes(keys)
    st, _ = step_ref(spec, params, init_step_state(spec),
                     lo.astype(jnp.int32), hi.astype(jnp.int32))
    assert int(st["regs"][R_SIZE]) == 4000       # sharded: no inline reset
    H = spec.counter_words
    pre = _unpack(np.asarray(merge_words(st["counters"][:H],
                                         st["counters"][H:], 4)), 4)
    st2 = merge_halve(spec, params, st)
    assert int(st2["regs"][R_SIZE]) == 500
    np.testing.assert_array_equal(
        _unpack(np.asarray(st2["counters"])[:H], 4), pre >> 3)


def test_merged_shard_estimates_equal_single_sketch():
    """Host twin: under collision-free hashing (huge width) the sharded
    sketch's post-merge estimates equal a single unsharded sketch's — both
    are the true capped counts, shard partitioning invisible."""
    # sample_size far beyond the adds: aging never fires on either side
    # (FrequencySketch resets when size >= sample_size, so 0 would reset
    # every add — the never-reset convention is sample-huge on the host)
    cfg = SketchConfig(sample_size=10**9, counters=4 * (1 << 16), rows=4,
                       cap=15, doorkeeper_bits=1 << 14)
    single = FrequencySketch(cfg)
    sharded = ShardedFrequencySketch(cfg, shards=4)
    rng = np.random.default_rng(11)
    keys = rng.integers(0, 400, size=6000)
    for k in keys:
        single.add(int(k))
        sharded.add(int(k))
    sharded.merge_halve()
    for k in np.unique(keys):
        assert sharded.estimate(int(k)) == single.estimate(int(k))
    assert sharded.estimate(10**9) == single.estimate(10**9) == 0


def test_sharded_sketch_merge_halve_matches_frequency_sketch_reset():
    """When every per-access reset point lands on a merge boundary (W=1000,
    cadence 500: the first reset fires at add 1000 and the post-reset size
    W/2 re-crosses W exactly one cadence later) the sharded host sketch
    ages exactly like FrequencySketch.reset(): same reset count and same
    estimates after the same adds (collision-free so the hash family
    cannot matter)."""
    W, E = 1000, 500
    cfg = SketchConfig(sample_size=W, counters=4 * (1 << 16), rows=4,
                       cap=15, doorkeeper_bits=1 << 14)
    single = FrequencySketch(cfg)
    sharded = ShardedFrequencySketch(cfg, shards=2)
    rng = np.random.default_rng(2)
    keys = rng.integers(0, 120, size=2 * W)
    for i, k in enumerate(keys):
        single.add(int(k))                       # auto-resets at W
        sharded.add(int(k))
        if (i + 1) % E == 0:
            sharded.merge_halve()                # deferred reset, same point
    assert sharded.resets == single.resets == 3
    assert sharded.size == single.size == W // 2
    for k in np.unique(keys):
        assert sharded.estimate(int(k)) == single.estimate(int(k))


def test_stale_estimates_read_global_only():
    """``stale_estimates=True`` (the host twin of the mesh runner's
    speculative ``mesh_exchange="stale"`` admission): estimate() reads ONLY
    the merged global structures — zero before the first merge, converging
    to the fresh estimate at every merge boundary — while add() keeps the
    exact global+delta conservative update, so the sketch STATE evolves
    identically to the fresh-estimate twin."""
    cfg = SketchConfig(sample_size=10**9, counters=4 * (1 << 16), rows=4,
                       cap=15, doorkeeper_bits=1 << 14)
    fresh = ShardedFrequencySketch(cfg, shards=4)
    stale = ShardedFrequencySketch(cfg, shards=4, stale_estimates=True)
    rng = np.random.default_rng(21)
    keys = rng.integers(0, 300, size=4000)
    for k in keys:
        fresh.add(int(k))
        stale.add(int(k))
    # pre-merge: the un-merged deltas are invisible to the stale reader
    assert all(stale.estimate(int(k)) == 0 for k in np.unique(keys))
    assert any(fresh.estimate(int(k)) > 0 for k in np.unique(keys))
    # ... but the tables themselves are identical (adds are exact)
    assert stale.gtable == fresh.gtable and stale.dtable == fresh.dtable
    assert bytes(stale.gdk) == bytes(fresh.gdk)
    assert bytes(stale.ddk) == bytes(fresh.ddk)
    fresh.merge_halve()
    stale.merge_halve()
    # post-merge: deltas folded in, the two readers agree again
    for k in np.unique(keys):
        assert stale.estimate(int(k)) == fresh.estimate(int(k))
    # unsharded sketches have no delta to be stale against
    from repro.core.sketch import default_sketch
    with pytest.raises(ValueError, match="stale_estimates"):
        default_sketch(100, stale_estimates=True)
    assert default_sketch(100, shards=2,
                          stale_estimates=True).stale_estimates


@pytest.mark.parametrize("assoc", [None, 8])
def test_sharded_no_aging_matches_unsharded_bitwise(assoc):
    """Device differential: with aging disabled (sample=0) the merge fold
    is invisible to estimates (global+delta is invariant) and under
    collision-free hashing the sharded step reproduces the unsharded hit
    sequence bit-for-bit — shard partitioning changes nothing but the
    collision structure."""
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 300, size=4000, dtype=np.uint64)
    lo, hi = keys_to_lanes(keys)
    lo, hi = lo.astype(jnp.int32), hi.astype(jnp.int32)
    kw = dict(width=1 << 16, rows=4, dk_bits=1 << 14)
    if assoc is None:
        base = dict(window_slots=2, main_slots=40)
    else:
        base = dict(window_slots=8, main_slots=64, assoc=8)
    params = make_step_params(2, 40, 32, 0, 7, 0)
    u = StepSpec(**kw, **base)
    s = StepSpec(**kw, **base, shards=4)
    _, hu = step_ref(u, params, init_step_state(u, 2, 40), lo, hi)
    st, hs = step_ref(s, params, init_step_state(s, 2, 40), lo, hi)
    np.testing.assert_array_equal(np.asarray(hu), np.asarray(hs))
    # ... and a mid-stream merge fold is a hit-sequence no-op
    st, hA = step_ref(s, params, init_step_state(s, 2, 40), lo[:2000],
                      hi[:2000])
    st = merge_halve(s, params, st)
    _, hB = step_ref(s, params, st, lo[2000:], hi[2000:])
    np.testing.assert_array_equal(
        np.asarray(hu),
        np.concatenate([np.asarray(hA), np.asarray(hB)]))
