"""Docs subsystem checks (ISSUE 4): the reference checker works and the
repo's own docs pass it."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

import check_docs  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_repo_docs_have_no_stale_references():
    assert check_docs.main(["--root", REPO]) == 0


def test_checker_catches_stale_path_and_symbol(tmp_path):
    docs = tmp_path / "docs"
    docs.mkdir()
    (docs / "ok.md").write_text(
        "see `docs/ok.md` and `repro.kernels.sketch_merge.merge_halve`\n")
    # stale refs: a deleted file and a renamed symbol
    (docs / "stale.md").write_text(
        "see `kernels/nonexistent_module.py` and "
        "`repro.kernels.sketch_merge.merge_halve_gone`\n")
    # resolve symbols against the real source tree
    src = tmp_path / "src"
    src.symlink_to(os.path.join(REPO, "src"))
    failures = check_docs.check_file(str(docs / "stale.md"), str(tmp_path))
    assert len(failures) == 2
    assert any("nonexistent_module" in f for f in failures)
    assert any("merge_halve_gone" in f for f in failures)
    assert check_docs.check_file(str(docs / "ok.md"), str(tmp_path)) == []
    assert check_docs.main(["--root", str(tmp_path)]) == 1


def test_checker_ignores_commands_and_prose():
    refs = list(check_docs._iter_refs(
        "run `python -m pytest -x -q` on `docs/*.md` then `foo_bar` "
        "and `StepSpec.shards`"))
    assert all(not check_docs._PATHLIKE.match(r) for r in refs)
    assert all(not check_docs._DOTTED.match(r) for r in refs)


def test_bench_field_contract_on_real_repo():
    assert check_docs.check_bench_fields(REPO) == []


def _bench_fixture(tmp_path, *, doc_fields, snap_keys, gate_src=""):
    (tmp_path / "docs").mkdir()
    rows = "\n".join(f"| `{f}` | meaning |" for f in doc_fields)
    (tmp_path / "docs" / "BENCHMARKS.md").write_text(
        "## `BENCH_device.json` fields\n\n| field | meaning |\n|---|---|\n"
        + rows + "\n")
    (tmp_path / "BENCH_device.json").write_text(
        json.dumps({k: 1.0 for k in snap_keys}))
    if gate_src:
        (tmp_path / "benchmarks").mkdir()
        (tmp_path / "benchmarks" / "check_bench.py").write_text(gate_src)
    return str(tmp_path)


def test_stale_documented_bench_field_fails(tmp_path):
    root = _bench_fixture(tmp_path,
                          doc_fields=["acc_per_s", "renamed_field"],
                          snap_keys=["acc_per_s"])
    failures = check_docs.check_bench_fields(root)
    assert any("renamed_field" in f and "BENCHMARKS.md" in f
               for f in failures)


def test_undocumented_snapshot_field_fails(tmp_path):
    root = _bench_fixture(tmp_path, doc_fields=["acc_per_s"],
                          snap_keys=["acc_per_s", "sneaky_new_field"])
    failures = check_docs.check_bench_fields(root)
    assert any("sneaky_new_field" in f and "undocumented" in f
               for f in failures)


def test_gate_reading_stale_field_fails(tmp_path):
    gate = ("def check(fresh):\n"
            "    ok = fresh.get('acc_per_s')\n"
            "    gone = fresh.get('field_deleted_from_snapshot')\n"
            "    for pol in ('a', 'b'):\n"
            "        fresh.get(f'missing_prefix_{pol}')\n")
    root = _bench_fixture(tmp_path, doc_fields=["acc_per_s"],
                          snap_keys=["acc_per_s"], gate_src=gate)
    failures = check_docs.check_bench_fields(root)
    assert any("field_deleted_from_snapshot" in f for f in failures)
    assert any("missing_prefix_{}" in f for f in failures)


def test_gate_fstring_template_matches_wildcard(tmp_path):
    gate = ("def check(fresh):\n"
            "    for pol in ('x',):\n"
            "        fresh.get(f'policy_acc_{pol}')\n")
    root = _bench_fixture(tmp_path, doc_fields=["policy_acc_x"],
                          snap_keys=["policy_acc_x"], gate_src=gate)
    assert check_docs.check_bench_fields(root) == []
