"""Docs subsystem checks (ISSUE 4): the reference checker works and the
repo's own docs pass it."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

import check_docs  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_repo_docs_have_no_stale_references():
    assert check_docs.main(["--root", REPO]) == 0


def test_checker_catches_stale_path_and_symbol(tmp_path):
    docs = tmp_path / "docs"
    docs.mkdir()
    (docs / "ok.md").write_text(
        "see `docs/ok.md` and `repro.kernels.sketch_merge.merge_halve`\n")
    # stale refs: a deleted file and a renamed symbol
    (docs / "stale.md").write_text(
        "see `kernels/nonexistent_module.py` and "
        "`repro.kernels.sketch_merge.merge_halve_gone`\n")
    # resolve symbols against the real source tree
    src = tmp_path / "src"
    src.symlink_to(os.path.join(REPO, "src"))
    failures = check_docs.check_file(str(docs / "stale.md"), str(tmp_path))
    assert len(failures) == 2
    assert any("nonexistent_module" in f for f in failures)
    assert any("merge_halve_gone" in f for f in failures)
    assert check_docs.check_file(str(docs / "ok.md"), str(tmp_path)) == []
    assert check_docs.main(["--root", str(tmp_path)]) == 1


def test_checker_ignores_commands_and_prose():
    refs = list(check_docs._iter_refs(
        "run `python -m pytest -x -q` on `docs/*.md` then `foo_bar` "
        "and `StepSpec.shards`"))
    assert all(not check_docs._PATHLIKE.match(r) for r in refs)
    assert all(not check_docs._DOTTED.match(r) for r in refs)
