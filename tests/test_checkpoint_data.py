"""Fault-tolerance tests: checkpoint atomicity/roundtrip/async, resumable
data pipeline determinism, W-TinyLFU shard cache, end-to-end resume."""
import json
import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.store import (save_checkpoint, restore_checkpoint,
                                    latest_step, load_meta, prune_old,
                                    AsyncCheckpointer)
from repro.data.pipeline import (ShardSpec, SyntheticShardStore,
                                 CachedShardReader, TokenPipeline)


@pytest.fixture
def tmpdir(tmp_path):
    return str(tmp_path)


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32),
                       "step": jnp.asarray(7, jnp.int32)},
            "scalar": 3}


class TestCheckpoint:
    def test_roundtrip(self, tmpdir):
        t = _tree()
        save_checkpoint(tmpdir, 5, t)
        assert latest_step(tmpdir) == 5
        got = restore_checkpoint(tmpdir, 5, jax.eval_shape(lambda: t))
        np.testing.assert_array_equal(got["a"], t["a"])
        np.testing.assert_array_equal(got["nested"]["b"], t["nested"]["b"])
        assert got["scalar"] == 3

    def test_atomic_no_partial(self, tmpdir):
        save_checkpoint(tmpdir, 1, _tree())
        # a leftover .tmp dir must never be visible as a step
        os.makedirs(os.path.join(tmpdir, "step_0000000009.tmp"))
        assert latest_step(tmpdir) == 1

    def test_prune(self, tmpdir):
        for s in [1, 2, 3, 4, 5]:
            save_checkpoint(tmpdir, s, {"x": jnp.zeros(2)})
        prune_old(tmpdir, keep=2)
        assert latest_step(tmpdir) == 5
        assert len([d for d in os.listdir(tmpdir)
                    if d.startswith("step_")]) == 2

    def test_async_checkpointer(self, tmpdir):
        ck = AsyncCheckpointer(tmpdir, keep=2)
        ck.save(1, _tree())
        ck.save(2, _tree())          # waits for 1, then writes 2
        ck.wait()
        assert latest_step(tmpdir) == 2

    def test_missing_leaf_errors(self, tmpdir):
        save_checkpoint(tmpdir, 1, {"x": jnp.zeros(2)})
        with pytest.raises(KeyError):
            restore_checkpoint(tmpdir, 1, {"x": jnp.zeros(2),
                                           "y": jnp.zeros(3)})

    def test_shape_mismatch_errors(self, tmpdir):
        save_checkpoint(tmpdir, 1, {"x": jnp.zeros(2)})
        with pytest.raises(ValueError):
            restore_checkpoint(tmpdir, 1, {"x": jnp.zeros(3)})

    def test_torn_newer_step_is_invisible(self, tmpdir):
        """A kill mid-write of a LATER step leaves only its .tmp dir (the
        rename never happened): latest_step must keep serving the older
        complete checkpoint, and restore from it must work even with the
        torn partial sitting beside it (the ISSUE 7 SIGKILL contract)."""
        t = _tree()
        save_checkpoint(tmpdir, 3, t)
        torn = os.path.join(tmpdir, "step_0000000007.tmp")
        os.makedirs(torn)
        with open(os.path.join(torn, "manifest.json"), "w") as f:
            f.write('{"step": 7')              # truncated mid-write
        assert latest_step(tmpdir) == 3
        got = restore_checkpoint(tmpdir, 3, jax.eval_shape(lambda: t))
        np.testing.assert_array_equal(got["a"], t["a"])

    def test_extra_meta_roundtrip(self, tmpdir):
        meta = {"cursor": 4096, "capacity": 200, "climb": [1, 2, 3],
                "mesh_exchange": "chunk"}
        save_checkpoint(tmpdir, 2, _tree(), extra_meta=meta)
        assert load_meta(tmpdir, 2) == meta
        # a checkpoint saved without extra_meta reads back an empty dict
        save_checkpoint(tmpdir, 4, _tree())
        assert load_meta(tmpdir, 4) == {}
        # async path carries the meta through the background writer
        ck = AsyncCheckpointer(tmpdir)
        ck.save(6, _tree(), extra_meta={"cursor": 6})
        ck.wait()
        assert load_meta(tmpdir, 6) == {"cursor": 6}

    def test_async_overlapping_saves_serialize(self, tmpdir):
        """Back-to-back async saves must serialize (save() joins the
        pending writer first) and each snapshot must be taken at CALL time:
        mutating the source array after save() cannot leak into the
        checkpoint (the write thread works from the host copy)."""
        ck = AsyncCheckpointer(tmpdir, keep=10)
        src = np.arange(8, dtype=np.int32)
        for s in range(1, 6):
            ck.save(s, {"x": src}, extra_meta={"cursor": s})
            src += 100                      # mutate AFTER the snapshot
        ck.wait()
        assert ck.last_saved == 5
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmpdir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        assert steps == [1, 2, 3, 4, 5]
        for s in steps:
            got = restore_checkpoint(tmpdir, s, {"x": np.zeros(8, np.int32)})
            np.testing.assert_array_equal(
                np.asarray(got["x"]),
                np.arange(8, dtype=np.int32) + 100 * (s - 1))
            assert load_meta(tmpdir, s)["cursor"] == s

    def test_async_error_surfaces_on_wait(self, tmpdir):
        # a regular file where the checkpoint dir should be: the background
        # writer fails, wait() re-raises (works even when running as root,
        # unlike permission-bit tricks)
        path = os.path.join(tmpdir, "f")
        with open(path, "w") as fh:
            fh.write("not a directory")
        ck = AsyncCheckpointer(path)
        ck.save(1, {"x": jnp.zeros(2)})
        with pytest.raises(OSError):
            ck.wait()


class TestDataPipeline:
    def _pipe(self, seed=0):
        spec = ShardSpec(n_shards=32, tokens_per_shard=2048, vocab_size=1000,
                         seed=seed)
        return TokenPipeline(
            CachedShardReader(SyntheticShardStore(spec), capacity_shards=6),
            seq_len=64, global_batch=4, seed=seed)

    def test_deterministic_stream(self):
        p1, p2 = self._pipe(), self._pipe()
        for _ in range(5):
            b1, b2 = p1.next_batch(), p2.next_batch()
            np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_resume_replays_identically(self):
        ref = self._pipe()
        batches = [ref.next_batch()["tokens"] for _ in range(8)]
        fresh = self._pipe()
        for _ in range(3):
            fresh.next_batch()
        st = fresh.state_dict()
        resumed = self._pipe()
        resumed.load_state_dict(st)
        for i in range(3, 8):
            np.testing.assert_array_equal(resumed.next_batch()["tokens"],
                                          batches[i])

    def test_shard_cache_effective(self):
        p = self._pipe()
        for _ in range(40):
            p.next_batch()
        st = p.cache_stats
        assert st["shard_cache_hit_ratio"] > 0.3   # zipf-skewed shards
        assert st["cold_fetches"] < 40 * 4          # far fewer than accesses


class TestEndToEndResume:
    def test_interrupted_equals_continuous(self, tmpdir):
        from repro.train.driver import train
        a, b = os.path.join(tmpdir, "a"), os.path.join(tmpdir, "b")
        cont = train("chatglm3-6b", steps=6, out_dir=a, global_batch=4,
                     seq_len=32, ckpt_every=3)
        train("chatglm3-6b", steps=3, out_dir=b, global_batch=4,
              seq_len=32, ckpt_every=3)
        resumed = train("chatglm3-6b", steps=6, out_dir=b, global_batch=4,
                        seq_len=32, ckpt_every=3)
        assert abs(cont["loss"] - resumed["loss"]) < 1e-4
