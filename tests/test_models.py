"""Per-architecture smoke tests (reduced same-family configs): forward/train
shapes + finiteness, prefill+decode vs full-forward consistency, and a few
steps of real optimization per family."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model
from repro.optim import make_optimizer, wsd, cosine
from repro.train import make_train_state, build_train_step


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    tok_shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    batch = {"tokens": jnp.array(rng.integers(0, cfg.vocab_size, tok_shape),
                                 jnp.int32)}
    if cfg.n_vis_tokens:
        batch["vision_embeds"] = jnp.array(
            rng.normal(size=(B, cfg.n_vis_tokens, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", list_archs())
class TestArchSmoke:
    def test_forward_shapes_finite(self, arch):
        cfg = get_config(arch, smoke=True)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        B, S = 2, 32
        batch = make_batch(cfg, B, S)
        h, aux = m.hidden_train(params, batch)
        S_out = S + (cfg.n_vis_tokens or 0)
        assert h.shape == (B, S_out, cfg.d_model)
        logits = m.lm_head(params, h)
        if cfg.n_codebooks:
            assert logits.shape == (B, S_out, cfg.n_codebooks, cfg.vocab_size)
        else:
            assert logits.shape == (B, S_out, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        assert bool(jnp.isfinite(jnp.asarray(aux)))

    def test_decode_matches_forward(self, arch):
        cfg = get_config(arch, smoke=True)
        if cfg.n_experts:
            cfg = cfg.replace(capacity_factor=8.0)  # no drops => exact parity
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        B, S = 2, 31                                 # odd: exercises padding
        batch = make_batch(cfg, B, S)
        h, _ = m.hidden_train(params, batch)
        full = m.lm_head(params, h)
        cache = m.init_cache(B, 64)
        pre = {"tokens": batch["tokens"][:, :S - 1],
               "vision_embeds": batch.get("vision_embeds")}
        cache, _ = m.prefill(params, pre, cache)
        dec, cache = m.decode(params, batch["tokens"][:, S - 1:S], cache)
        ref, got = full[:, -1], dec[:, 0]
        rel = float(jnp.max(jnp.abs(ref - got))
                    / (jnp.max(jnp.abs(ref)) + 1e-9))
        assert rel < 0.05, f"decode/train mismatch {rel}"
        assert int(cache["pos"][0]) == S + (cfg.n_vis_tokens or 0)

    def test_train_step_reduces_loss(self, arch):
        cfg = get_config(arch, smoke=True)
        m = build_model(cfg)
        opt = make_optimizer("adamw", wsd(1e-3, 5, 100, 50))
        state = make_train_state(m, opt, jax.random.PRNGKey(0))
        step = jax.jit(build_train_step(m, opt, loss_chunk=16))
        batch = make_batch(cfg, 4, 32)
        losses = []
        for _ in range(6):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert all(map(math.isfinite, losses))
        assert losses[-1] < losses[0], f"no learning: {losses}"
        # initial loss should be ~ln(V) for a fresh model
        assert abs(losses[0] - math.log(cfg.vocab_size)) < 1.5


class TestTrainMachinery:
    def test_microbatch_equivalence(self):
        """Gradient accumulation over k microbatches == single big batch
        (compared at the gradient level; AdamW's normalized update would
        amplify bf16 noise on near-zero grads)."""
        from repro.train import build_loss_fn
        cfg = get_config("qwen3_4b", smoke=True)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg, 4, 32)
        loss_fn = build_loss_fn(m, loss_chunk=16)
        grad_fn = jax.jit(jax.grad(lambda p, b: loss_fn(p, b)[0]))
        g_full = grad_fn(params, batch)
        halves = [jax.tree_util.tree_map(lambda x: x[:2], batch),
                  jax.tree_util.tree_map(lambda x: x[2:], batch)]
        g_acc = jax.tree_util.tree_map(
            lambda a, b: (a + b) / 2, grad_fn(params, halves[0]),
            grad_fn(params, halves[1]))
        rel = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))
                               / (jnp.max(jnp.abs(a)) + 1e-8)),
            g_full, g_acc)
        assert max(jax.tree_util.tree_leaves(rel)) < 0.05

    def test_adafactor_trains(self):
        cfg = get_config("minicpm_2b", smoke=True)
        m = build_model(cfg)
        opt = make_optimizer("adafactor", cosine(3e-3, 5, 200))
        state = make_train_state(m, opt, jax.random.PRNGKey(1))
        step = jax.jit(build_train_step(m, opt, loss_chunk=16))
        batch = make_batch(cfg, 4, 32)
        losses = []
        for _ in range(6):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert all(map(math.isfinite, losses)) and losses[-1] < losses[0]

    def test_adafactor_state_is_factored(self):
        cfg = get_config("qwen3_4b", smoke=True)
        m = build_model(cfg)
        opt = make_optimizer("adafactor", cosine(1e-3, 5, 200))
        params = m.init(jax.random.PRNGKey(0))
        st = opt.init(params)
        n_param = sum(np.prod(p.shape) for p in
                      jax.tree_util.tree_leaves(params))
        n_state = sum(np.prod(p.shape) for p in
                      jax.tree_util.tree_leaves(st))
        assert n_state < 0.2 * n_param     # factored: O(n+m) per matrix

    def test_wsd_schedule_shape(self):
        from repro.optim import wsd
        f = wsd(1.0, warmup=10, stable=100, decay=100, floor_frac=0.1)
        assert float(f(0)) < 0.2
        assert abs(float(f(50)) - 1.0) < 1e-6
        assert abs(float(f(110)) - 1.0) < 1e-6
        assert float(f(210)) <= 0.11

    def test_moe_capacity_drops_are_bounded(self):
        """With cf=1.0 and adversarial routing, output != input everywhere
        but loss remains finite (dropped tokens pass residual through)."""
        cfg = get_config("llama4_scout_17b_a16e", smoke=True).replace(
            capacity_factor=0.5)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        h, aux = m.hidden_train(params, make_batch(cfg, 2, 32))
        assert bool(jnp.isfinite(h).all())

    def test_long_seq_padding_families(self):
        """SSM/xlstm chunk padding: odd sequence lengths work and match the
        even-length prefix."""
        for arch in ["zamba2_1p2b", "xlstm_1p3b"]:
            cfg = get_config(arch, smoke=True)
            m = build_model(cfg)
            params = m.init(jax.random.PRNGKey(0))
            b32 = make_batch(cfg, 2, 32, seed=3)
            b27 = {"tokens": b32["tokens"][:, :27]}
            h32, _ = m.hidden_train(params, b32)
            h27, _ = m.hidden_train(params, b27)
            rel = float(jnp.max(jnp.abs(h32[:, :27].astype(jnp.float32)
                                        - h27.astype(jnp.float32))))
            assert rel < 0.05, f"{arch} causality broken by padding: {rel}"
