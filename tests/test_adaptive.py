"""Runtime-adaptive window sizing (ISSUE 3): differential + golden tests.

Four layers of proof, mirroring the PR 1/2 test strategy:

1. **Static preservation** — with ``adaptive=True`` but the quota pinned at
   the configured split, both layouts reproduce the ``adaptive=False`` hit
   sequence bit-for-bit (the runtime-quota machinery is a no-op exactly
   when it should be), and a mid-trace rebalance to the current quota
   (compaction only) changes nothing.
2. **Backend parity** — the adaptive epoch program produces identical hit
   flags under the jit scan and the fused Pallas kernel.
3. **Host twin parity** — ``AdaptiveWTinyLFU`` (plain-python ints) and the
   device climber agree on the per-access hit sequence bit-for-bit under
   collision-free sketches, with the climb active (same shared integer
   climb rule: core/adaptive.py).
4. **Adaptivity goldens** — on the two adversarial traces
   (traces/synthetic.py fickle-churn and phase-shift) the climbing engine
   lands within 0.01 of the best static window from the ISSUE's
   {1,5,10,20,40}% sweep — same adaptive config on both traces — and the
   static-vs-host hit ratios are pinned so the generators cannot drift.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import WTinyLFU, AdaptiveWTinyLFU, run_trace
from repro.core.device_simulate import (simulate_trace, simulate_sweep,
                                        ClimbSpec)
from repro.kernels.sketch_common import keys_to_lanes
from repro.kernels.sketch_step import (StepSpec, make_step_params,
                                       init_step_state, step_ref, rebalance,
                                       R_WQUOTA, R_WCOUNT, R_MCOUNT)
from repro.traces import fickle_churn_trace, phase_shift_trace, zipf_trace


def lanes(keys):
    lo, hi = keys_to_lanes(np.asarray(keys, np.uint64))
    return lo.astype(jnp.int32), hi.astype(jnp.int32)


# ===========================================================================
# 1. static preservation: pinned quota == adaptive=False, bit for bit
# ===========================================================================

def test_pinned_quota_matches_static_flat():
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 300, size=3000, dtype=np.uint64)
    lo, hi = lanes(keys)
    flat = StepSpec(width=256, rows=4, dk_bits=1024, window_slots=2,
                    main_slots=40)
    params = make_step_params(2, 40, 32, 500, 7, 0)
    _, h_static = step_ref(flat, params, init_step_state(flat), lo, hi)
    for wslots, mslots in [(2, 40), (16, 128)]:   # exact and padded-up tables
        ad = StepSpec(width=256, rows=4, dk_bits=1024, window_slots=wslots,
                      main_slots=mslots, adaptive=True)
        _, h_ad = step_ref(ad, params, init_step_state(ad, window_cap=2),
                           lo, hi)
        np.testing.assert_array_equal(np.asarray(h_static), np.asarray(h_ad))


def test_pinned_quota_matches_static_assoc():
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 300, size=3000, dtype=np.uint64)
    lo, hi = lanes(keys)
    spec = StepSpec(width=256, rows=4, dk_bits=1024, window_slots=8,
                    main_slots=64, assoc=8)
    params = make_step_params(4, 48, 38, 700, 7, 0)
    _, h_static = step_ref(spec, params,
                           init_step_state(spec, window_cap=4, main_cap=48),
                           lo, hi)
    ad = StepSpec(width=256, rows=4, dk_bits=1024, window_slots=8,
                  main_slots=64, assoc=8, adaptive=True)
    _, h_ad = step_ref(ad, params, init_step_state(ad, window_cap=4), lo, hi)
    np.testing.assert_array_equal(np.asarray(h_static), np.asarray(h_ad))


@pytest.mark.parametrize("assoc", [None, 8])
def test_rebalance_to_same_quota_is_hit_noop(assoc):
    """A mid-trace rebalance at the current quota only compacts storage —
    the subsequent hit sequence is unchanged."""
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 300, size=3000, dtype=np.uint64)
    lo, hi = lanes(keys)
    kw = dict(width=256, rows=4, dk_bits=1024, adaptive=True)
    if assoc is None:
        spec = StepSpec(window_slots=8, main_slots=64, **kw)
        params = make_step_params(4, 48, 38, 500, 7, 0)
    else:
        spec = StepSpec(window_slots=8, main_slots=64, assoc=assoc, **kw)
        params = make_step_params(4, 48, 38, 700, 7, 0)
    st = init_step_state(spec, window_cap=4)
    _, h_plain = step_ref(spec, params, init_step_state(spec, window_cap=4),
                          lo, hi)
    n = 1500
    st, hA = step_ref(spec, params, st, lo[:n], hi[:n])
    st = rebalance(spec, params, st, st["regs"][R_WQUOTA])
    st, hB = step_ref(spec, params, st, lo[n:], hi[n:])
    np.testing.assert_array_equal(
        np.asarray(h_plain),
        np.concatenate([np.asarray(hA), np.asarray(hB)]))


def test_rebalance_set_invariants_across_quota_moves():
    """Drive the assoc tables through grow/shrink rebalances: residents must
    only occupy ways below each set's usable count (no ghosts that masked
    lookups could never evict), window residency must respect the quota,
    and no key may be resident in both tables."""
    rng = np.random.default_rng(9)
    keys = rng.integers(0, 600, size=6000, dtype=np.uint64)
    lo, hi = lanes(keys)
    spec = StepSpec(width=256, rows=4, dk_bits=1024, window_slots=32,
                    main_slots=64, assoc=8, adaptive=True)
    params = make_step_params(4, 48, 38, 700, 7, 0)
    st = init_step_state(spec, window_cap=4)
    total = 4 + 48

    def check(st, quota):
        # window usable ways come from the load-aware wuw state vector
        # (ISSUE 5); the main side keeps the uniform rule
        for tab_key, n_sets, usable in [
                ("wtab", 4, np.asarray(st["wuw"])),
                ("mtab", 8, np.array([(total - quota) // 8
                                      + (s < (total - quota) % 8)
                                      for s in range(8)]))]:
            tab = np.asarray(st[tab_key])
            A = spec.assoc
            meta = tab[:, 2].reshape(n_sets, A)
            res = meta >= 0
            beyond = res & (np.arange(A)[None, :] >= usable[:, None])
            assert not beyond.any(), (tab_key, quota)
        wres = np.asarray(st["wtab"])[:, 2] >= 0
        assert wres.sum() <= quota
        wkeys = {(r[0], r[1]) for r in np.asarray(st["wtab"]) if r[2] >= 0}
        mkeys = {(r[0], r[1]) for r in np.asarray(st["mtab"]) if r[2] >= 0}
        assert not (wkeys & mkeys)

    from repro.core.adaptive import window_set_ways
    for i, nq in enumerate([12, 3, 26, 1, 9]):
        s0, s1 = i * 1000, (i + 1) * 1000
        st, _ = step_ref(spec, params, st, lo[s0:s1], hi[s0:s1])
        load = np.asarray(st["wsl"])
        assert load.sum() == 1000            # every access counts its set
        st = rebalance(spec, params, st, nq)
        assert int(np.asarray(st["regs"])[R_WQUOTA]) == nq
        # the device's jnp distribution == the shared host rule, and the
        # usable-way budget always sums to the quota
        np.testing.assert_array_equal(np.asarray(st["wuw"]),
                                      window_set_ways(nq, 4, load))
        assert np.asarray(st["wuw"]).sum() == nq
        assert int(np.asarray(st["wsl"]).sum()) == 0     # telemetry reset
        check(st, nq)


def test_small_quota_load_aware_ways_follow_hot_sets():
    """ISSUE 5 satellite: at quotas below the window set count the old
    uniform rule handed the few usable ways to a FIXED prefix of sets, so
    keys hashing to any other set could never use the window.  The
    load-aware distribution must move the ways to the sets actually
    carrying traffic — and recover the window hits on a skewed trace whose
    hot sets are exactly the ones the uniform rule starved."""
    from repro.core.adaptive import window_set_ways
    from repro.core.hashing import set_index32_np, WSET_SALT

    nws = 4
    spec = StepSpec(width=256, rows=4, dk_bits=1024, window_slots=32,
                    main_slots=64, assoc=8, adaptive=True)
    params = make_step_params(2, 50, 40, 700, 7, 0)

    # bucket candidate keys by their window set; hot sets are 2 and 3 —
    # precisely the sets the uniform rule gives ZERO ways at quota 2
    pool = np.arange(1, 40_000, dtype=np.uint64)
    wset = set_index32_np(pool, nws, WSET_SALT)
    hot2 = pool[wset == 2]
    hot3 = pool[wset == 3]
    assert len(hot2) > 700 and len(hot3) > 700

    # churny bursts: a FRESH key per burst, 3 back-to-back accesses, hot
    # sets alternating — window-friendly (2 hits/burst with an MRU way in
    # the set), admission-hostile (every key is new, so a starved window
    # yields almost nothing)
    def burst_trace(n_bursts):
        ks = np.empty((n_bursts, 3), np.uint64)
        for b in range(n_bursts):
            src = hot2 if b % 2 == 0 else hot3
            ks[b, :] = src[b // 2 % len(src)]
        return ks.reshape(-1)

    tr = burst_trace(1600)                     # 4800 accesses
    lo, hi = lanes(tr)

    st = init_step_state(spec, window_cap=2)
    st, _ = step_ref(spec, params, st, lo[:1200], hi[:1200])
    load = np.asarray(st["wsl"])
    assert load[2] + load[3] == 1200           # the skew is real
    st = rebalance(spec, params, st, 2)
    wuw = np.asarray(st["wuw"])
    np.testing.assert_array_equal(wuw, [0, 0, 1, 1])   # ways follow load
    np.testing.assert_array_equal(wuw, window_set_ways(2, nws, load))
    _, h_aware = step_ref(spec, params, st, lo[1200:], hi[1200:])

    # the static path bakes the uniform [1, 1, 0, 0] padding at init — its
    # window never sees the hot sets and the tail hits collapse
    stat = StepSpec(width=256, rows=4, dk_bits=1024, window_slots=32,
                    main_slots=64, assoc=8)
    ss = init_step_state(stat, window_cap=2, main_cap=50)
    ss, _ = step_ref(stat, params, ss, lo[:1200], hi[:1200])
    _, h_starved = step_ref(stat, params, ss, lo[1200:], hi[1200:])

    aware = int(np.asarray(h_aware).sum())
    starved = int(np.asarray(h_starved).sum())
    # ~2 hits per 3-access burst once the ways sit in the hot sets
    assert aware > 0.5 * (len(tr) - 1200), (aware, starved)
    assert aware > starved + 0.3 * (len(tr) - 1200), (aware, starved)


def test_rebalance_moves_quota_and_counts_stay_consistent():
    """Grow then shrink the flat window across epoch boundaries: the
    resident-count registers must track the tables exactly and migration
    must not lose more records than the shrink demands."""
    rng = np.random.default_rng(2)
    keys = rng.integers(0, 400, size=2000, dtype=np.uint64)
    lo, hi = lanes(keys)
    spec = StepSpec(width=256, rows=4, dk_bits=1024, window_slots=16,
                    main_slots=128, adaptive=True)
    params = make_step_params(2, 40, 32, 500, 7, 0)
    st = init_step_state(spec, window_cap=2)
    st, _ = step_ref(spec, params, st, lo[:1000], hi[:1000])
    st = rebalance(spec, params, st, 10)          # grow window 2 -> 10
    st, _ = step_ref(spec, params, st, lo[1000:], hi[1000:])
    st = rebalance(spec, params, st, 3)           # shrink 10 -> 3 (migration)
    regs = np.asarray(st["regs"])
    wmeta = np.asarray(st["wmeta"])
    mmeta = np.asarray(st["mmeta"])
    assert regs[R_WQUOTA] == 3
    assert (wmeta >= 0).sum() == regs[R_WCOUNT] <= 3
    assert ((mmeta >= 0) & (mmeta < 2**31 - 1)).sum() == regs[R_MCOUNT] <= 40


# ===========================================================================
# 2. backend parity: fused pallas kernel == jit scan, climb active
# ===========================================================================

@pytest.mark.parametrize("assoc", [None, 4])
def test_adaptive_pallas_matches_jit(assoc):
    """A phase-shift trace keeps the quota mid-range (not parked at a
    clamp), and 9000 accesses leave a partial tail epoch under 2048 — the
    pallas backend must not climb on the padded tail (regression: it used
    to, so final_quota and trajectory disagreed with jit whenever the trace
    length was not a multiple of epoch_len)."""
    tr = phase_shift_trace(9000, n_hot=800, working_set=200, advance=0.1,
                           seed=7)
    kw = dict(adaptive=True, assoc=assoc, climb=ClimbSpec(epoch_len=2048))
    j = simulate_trace(tr, 100, backend="jit", **kw)
    p = simulate_trace(tr, 100, backend="pallas", **kw)
    assert p.hits == j.hits
    assert p.extra["final_quota"] == j.extra["final_quota"]
    assert p.extra["trajectory"] == j.extra["trajectory"]
    assert len(j.extra["trajectory"]["quota"]) == 4     # full epochs only


# ===========================================================================
# 3. host twin parity: AdaptiveWTinyLFU == device climber, bit for bit
# ===========================================================================

@pytest.mark.parametrize("tname,trace", [
    ("zipf", zipf_trace(6000, n_items=300, alpha=0.9, seed=5)),
    ("phase", phase_shift_trace(6000, n_hot=300, working_set=80,
                                advance=0.05, seed=2)),
])
def test_host_twin_hit_sequence_bitwise(tname, trace):
    """Collision-free sketches on both sides: per-access hit sequence AND
    the full quota trajectory of the climb agree exactly."""
    C = 60
    kw = dict(window_frac=0.05, sample_factor=8)
    res, _, hits = simulate_trace(
        trace, C, adaptive=True, doorkeeper=False, counters_per_item=550.0,
        climb=ClimbSpec(epoch_len=500), return_state=True, **kw)
    host = AdaptiveWTinyLFU(C, doorkeeper=False, counters_per_item=550.0,
                            epoch_len=500, **kw)
    host_hits = np.array([host.access(int(k)) for k in trace], np.int32)
    np.testing.assert_array_equal(np.asarray(hits), host_hits)
    assert res.extra["trajectory"]["quota"] == host.quota_trajectory
    assert res.extra["final_quota"] == host.quota


def test_sharded_adaptive_host_twin_bitwise():
    """ISSUE 4: the sharded sketch composes with the adaptive climber — the
    merge_halve fold rides the climb epochs (merge first, then climb +
    rebalance) on both engines, and with collision-free sketches the hit
    sequence AND quota trajectory agree exactly."""
    C = 60
    trace = phase_shift_trace(6000, n_hot=300, working_set=80, advance=0.05,
                              seed=2)
    kw = dict(window_frac=0.05, sample_factor=8)
    res, _, hits = simulate_trace(
        trace, C, adaptive=True, shards=4, doorkeeper=False,
        counters_per_item=550.0, climb=ClimbSpec(epoch_len=500),
        return_state=True, **kw)
    host = AdaptiveWTinyLFU(C, doorkeeper=False, counters_per_item=550.0,
                            epoch_len=500, shards=4, **kw)
    host_hits = np.array([host.access(int(k)) for k in trace], np.int32)
    np.testing.assert_array_equal(np.asarray(hits), host_hits)
    assert res.extra["trajectory"]["quota"] == host.quota_trajectory
    assert res.extra["shards"] == 4


def test_prot_budget_shrink_parity_bitwise():
    """A window grow shrinks the runtime protected budget below the
    resident protected count; the lazy per-main-hit drain must demote
    identically on host and device (regression: the device used to drain
    on every access, diverging from the twin and breaking the stamp
    uniqueness the rebalance relies on)."""
    C = 40
    fill = zipf_trace(3000, n_items=60, alpha=0.9, seed=4)
    tail = zipf_trace(2000, n_items=80, alpha=0.8, seed=9)
    spec = StepSpec(width=1 << 16, rows=4, dk_bits=0, window_slots=20,
                    main_slots=39, adaptive=True)
    params = make_step_params(2, 38, 30, 8 * C, 8, 0)
    st = init_step_state(spec, window_cap=2)
    lo, hi = lanes(fill.astype(np.uint64))
    st, dA = step_ref(spec, params, st, lo, hi)
    st = rebalance(spec, params, st, 18)       # mcap 22 -> prot_rt 17 < 30
    lo, hi = lanes(tail.astype(np.uint64))
    st, dB = step_ref(spec, params, st, lo, hi)

    host = AdaptiveWTinyLFU(C, window_frac=0.05, sample_factor=8,
                            doorkeeper=False, counters_per_item=550.0,
                            epoch_len=10**9)   # boundaries driven manually
    hA = np.array([host.access(int(k)) for k in fill], np.int32)
    assert host._pcount > 17                   # the shrink actually bites
    host._rebalance(18)
    hB = np.array([host.access(int(k)) for k in tail], np.int32)
    np.testing.assert_array_equal(np.asarray(dA), hA)
    np.testing.assert_array_equal(np.asarray(dB), hB)


# ===========================================================================
# 4. adaptivity goldens on the adversarial traces
# ===========================================================================

TOL = 0.005

# pinned goldens (trace construction below must not change)
GOLDEN_FICKLE_HOST = 0.5482
GOLDEN_FICKLE_DEVICE = 0.5475
GOLDEN_PHASE_HOST = 0.4061
GOLDEN_PHASE_DEVICE = 0.4086


class TestGoldenAdversarial:
    """Host/device pins for the two new trace generators (static 1%
    window).  The phase-shift pin doubles as the motivation number: the
    static window's 0.41 is what adaptivity exists to beat."""
    C, WARMUP, N = 500, 5_000, 60_000

    def test_fickle_churn_pins(self):
        tr = fickle_churn_trace(self.N, seed=3)
        h = run_trace(WTinyLFU(self.C, sample_factor=8), tr,
                      warmup=self.WARMUP)
        d = simulate_trace(tr, self.C, warmup=self.WARMUP)
        assert abs(h.hit_ratio - GOLDEN_FICKLE_HOST) < TOL
        assert abs(d.hit_ratio - GOLDEN_FICKLE_DEVICE) < TOL

    def test_phase_shift_pins_and_adaptive_win(self):
        tr = phase_shift_trace(self.N, seed=3)
        h = run_trace(WTinyLFU(self.C, sample_factor=8), tr,
                      warmup=self.WARMUP)
        d = simulate_trace(tr, self.C, warmup=self.WARMUP)
        assert abs(h.hit_ratio - GOLDEN_PHASE_HOST) < TOL
        assert abs(d.hit_ratio - GOLDEN_PHASE_DEVICE) < TOL
        # the static 1% window loses the whole second half; the climber
        # must recover a large chunk of it
        a = simulate_trace(tr, self.C, warmup=self.WARMUP, adaptive=True,
                           assoc=8, climb=ClimbSpec(epoch_len=2048))
        assert a.hit_ratio > d.hit_ratio + 0.03
        assert a.extra["final_quota"] > self.C * 0.1


ACCEPT_GAP = 0.01
STATIC_WFS = [0.01, 0.05, 0.10, 0.20, 0.40]


@pytest.mark.parametrize("gen", [fickle_churn_trace, phase_shift_trace])
def test_adaptive_within_001_of_best_static(gen):
    """ISSUE 3 acceptance: one adaptive config (the defaults), both
    adversarial traces, hit ratio within 0.01 of the best static window
    from the {1,5,10,20,40}% sweep (production set-associative path)."""
    C = 800
    tr = gen(120_000, seed=3)
    rows = simulate_sweep(tr, [C], window_fracs=STATIC_WFS,
                          mode="sequential", assoc=8)
    best = max(r.hit_ratio for r in rows)
    a = simulate_trace(tr, C, adaptive=True, assoc=8, climb=ClimbSpec())
    assert a.hit_ratio > best - ACCEPT_GAP, (
        f"adaptive {a.hit_ratio:.4f} vs best static {best:.4f}, "
        f"trajectory {a.extra['trajectory']['quota']}")


def test_adaptive_sweep_rows_report_quota():
    tr = zipf_trace(12_000, n_items=5000, alpha=0.9, seed=1)
    rows = simulate_sweep(tr, [100], window_fracs=[0.01, 0.2],
                          adaptive=True, climb=ClimbSpec(epoch_len=2048),
                          mode="sequential")
    assert len(rows) == 2
    for r in rows:
        assert r.extra["adaptive"] is True
        assert 1 <= r.extra["final_quota"] <= 50
        assert r.policy.endswith("+climb")
    with pytest.raises(ValueError):
        simulate_sweep(tr, [100], adaptive=True, mode="vmap")
    # mode="auto" must resolve to sequential for adaptive grids on EVERY
    # backend (regression: on TPU auto picked vmap and then rejected it)
    auto = simulate_sweep(tr[:5000], [100], adaptive=True,
                          climb=ClimbSpec(epoch_len=2048), mode="auto")
    assert auto[0].extra["backend"] == "jit+sequential"


def test_adaptive_degenerate_short_traces():
    """Traces shorter than one epoch (or empty) run without climbing and
    without crashing, like the static path."""
    short = zipf_trace(1000, n_items=500, alpha=0.9, seed=2)
    r = simulate_trace(short, 50, adaptive=True, climb=ClimbSpec())
    assert 0.0 <= r.hit_ratio <= 1.0
    assert "trajectory" not in r.extra       # no full epoch -> no climb
    empty = simulate_trace(np.array([], np.int64), 50, adaptive=True,
                           climb=ClimbSpec())
    assert empty.hits == 0
