"""End-to-end system behaviour: train -> checkpoint -> serve the trained
params with prefix caching; optimizer/loss properties (hypothesis)."""
import math
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.models import build_model
from repro.optim import make_optimizer, wsd, clip_by_global_norm, global_norm
from repro.train import make_train_state, build_train_step, \
    chunked_cross_entropy
from repro.serve import ServeEngine


class TestEndToEnd:
    def test_train_then_serve_roundtrip(self, tmp_path):
        """The full life of a model: train on a tiny corpus, checkpoint,
        restore into a serving engine, generate with prefix reuse."""
        from repro.checkpoint.store import save_checkpoint, restore_checkpoint
        cfg = get_config("chatglm3-6b", smoke=True)
        m = build_model(cfg)
        opt = make_optimizer("adamw", wsd(2e-3, 3, 60, 20))
        state = make_train_state(m, opt, jax.random.PRNGKey(0))
        step = jax.jit(build_train_step(m, opt, loss_chunk=16))
        rng = np.random.default_rng(0)
        # tiny synthetic corpus with a repeated "system prompt" prefix
        prefix = rng.integers(0, cfg.vocab_size, 16)
        losses = []
        for i in range(10):
            suffix = rng.integers(0, cfg.vocab_size, (4, 16))
            toks = np.concatenate(
                [np.tile(prefix, (4, 1)), suffix], axis=1)
            state, metrics = step(state, {"tokens": jnp.asarray(toks,
                                                                jnp.int32)})
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0]

        save_checkpoint(str(tmp_path), int(state.step), state.params)
        params = restore_checkpoint(str(tmp_path), int(state.step),
                                    jax.eval_shape(lambda: state.params))
        eng = ServeEngine(m, params, max_batch=2, max_len=96, block_size=8,
                          pool_slots=16)
        p1 = list(prefix) + list(rng.integers(0, cfg.vocab_size, 9))
        p2 = list(prefix) + list(rng.integers(0, cfg.vocab_size, 9))
        eng.submit(p1, 4)
        out1 = eng.run()            # wave 1 populates the prefix pool
        eng.submit(p2, 4)
        out2 = eng.run()            # wave 2 reuses the shared prefix
        assert len(out1) == 1 and len(out2) == 1
        assert eng.stats["block_hits"] >= 2   # shared prefix reused

    def test_engine_under_pool_pressure(self):
        """Pool smaller than the working set: no leaks, accounting holds."""
        cfg = get_config("qwen3-4b", smoke=True)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        eng = ServeEngine(m, params, max_batch=2, max_len=96, block_size=8,
                          pool_slots=4, prefix_policy="tinylfu")
        rng = np.random.default_rng(1)
        shared = list(rng.integers(0, cfg.vocab_size, 16))
        for i in range(6):
            eng.submit(shared + list(rng.integers(0, cfg.vocab_size, 9)), 2)
        out = eng.run()
        assert len(out) == 6
        assert eng.pool.used <= 4
        assert eng.pool.used == len(eng.prefix_cache)


class TestOptimizerProperties:
    @settings(max_examples=20, deadline=None)
    @given(st.floats(min_value=0.1, max_value=100.0))
    def test_clip_never_exceeds(self, max_norm):
        g = {"a": jnp.asarray([3.0, -4.0]), "b": jnp.asarray([[12.0]])}
        clipped, norm = clip_by_global_norm(g, max_norm)
        assert float(global_norm(clipped)) <= max_norm * 1.001 + 1e-6

    def test_adamw_step_bounded(self):
        """Adam updates are bounded by ~lr regardless of gradient scale."""
        opt = make_optimizer("adamw", lambda s: 0.1, weight_decay=0.0,
                             max_grad_norm=1e9)
        p = {"w": jnp.ones((4,))}
        st_ = opt.init(p)
        for scale in [1e-6, 1.0, 1e6]:
            g = {"w": jnp.full((4,), scale)}
            newp, _, _ = opt.apply(p, g, st_)
            delta = float(jnp.max(jnp.abs(newp["w"] - p["w"])))
            assert delta < 0.5          # lr / sqrt(bias-corr) bound


class TestLossProperties:
    def test_chunked_xent_matches_direct(self):
        """Chunked (scan+checkpoint) loss == direct full-logit xent."""
        cfg = get_config("qwen3-4b", smoke=True)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(2)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 33)),
                           jnp.int32)
        h, _ = m.hidden_train(params, {"tokens": toks})
        loss, _ = chunked_cross_entropy(params, h, toks, cfg, chunk=8)
        # direct reference
        logits = m.lm_head(params, h)[:, :-1]
        lab = toks[:, 1:]
        lse = jax.nn.logsumexp(logits, -1)
        true = jnp.take_along_axis(logits, lab[..., None], -1)[..., 0]
        ref = jnp.mean(lse - true)
        assert abs(float(loss) - float(ref)) < 1e-3

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=2, max_value=40))
    def test_chunked_xent_any_length(self, T):
        cfg = get_config("musicgen_medium", smoke=True)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(T)
        toks = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (2, T, cfg.n_codebooks)),
            jnp.int32)
        h, _ = m.hidden_train(params, {"tokens": toks})
        loss, metr = chunked_cross_entropy(params, h, toks, cfg, chunk=16)
        assert math.isfinite(float(loss))
        assert int(metr["tokens"]) == 2 * (T - 1)
