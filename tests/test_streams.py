"""Lane isolation and parity for the multi-stream batched engine
(``StepSpec.streams`` / ``DeviceWTinyLFU(streams=B)``).

The contract under test: a ``streams=B`` run advances B independent tenant
caches in ONE compiled program and is bit-identical, lane by lane, to B
separate single-stream runs — same per-access hit flags, same final
registers, same adaptive quota trajectories.  ``streams=1`` is the
unbatched engine itself (same spec value, same compiled program).  The
batched program must also stay scatter-free: per-access scatters cost a
fixed ~µs each on CPU and would sink the dispatch-amortization win the
lane axis exists for (benchmarks/bench_device.py section 9 measures it).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.device_simulate import (DeviceWTinyLFU, ClimbSpec,
                                        simulate_trace, simulate_sweep)
from repro.kernels.sketch_step import init_step_state, step_ref
from repro.traces.synthetic import tenant_lanes_trace, fickle_churn_trace

B, C, T = 3, 64, 3000
CL = ClimbSpec(epoch_len=512)


def lanes_trace(seed=0):
    return tenant_lanes_trace(B, T, n_items=5000, alpha=1.1, seed=seed)


def run_lanes(traces, **kw):
    return simulate_trace(traces, C, streams=traces.shape[0],
                          return_state=True, **kw)


def run_solo(traces, **kw):
    return [simulate_trace(traces[b], C, return_state=True, **kw)
            for b in range(traces.shape[0])]


def assert_lane_parity(traces, **kw):
    res, state, hits = run_lanes(traces, **kw)
    solos = run_solo(traces, **kw)
    for b, (rs, ss, sh) in enumerate(solos):
        np.testing.assert_array_equal(np.asarray(hits[b]), np.asarray(sh),
                                      err_msg=f"lane {b} hit sequence")
        for k in ss:
            np.testing.assert_array_equal(
                np.asarray(state[k][b]), np.asarray(ss[k]),
                err_msg=f"lane {b} state[{k!r}]")
    assert res.hits == sum(rs.hits for rs, _, _ in solos)
    assert res.extra["lane_hits"] == [rs.hits for rs, _, _ in solos]
    return res, solos


def test_lane_parity_flat():
    assert_lane_parity(lanes_trace())


def test_lane_parity_assoc():
    assert_lane_parity(lanes_trace(1), assoc=4)


def test_lane_parity_sharded():
    assert_lane_parity(lanes_trace(2), shards=4, merge_every=512)


def test_lane_parity_sharded_integrity():
    assert_lane_parity(lanes_trace(3), shards=4, merge_every=512,
                       integrity=True)


def test_lane_parity_pallas():
    # pallas batches through its own vmap rule (grid dimension), not the
    # lane-write discipline — still bit-identical per lane
    assert_lane_parity(lanes_trace(4), backend="pallas", chunk=512)


def test_lane_parity_adaptive_with_quota_trajectories():
    res, solos = assert_lane_parity(lanes_trace(5), adaptive=True, climb=CL)
    quotas = np.asarray(res.extra["trajectory"]["quota"])   # (ne, B)
    ehits = np.asarray(res.extra["trajectory"]["epoch_hits"])
    for b, (rs, _, _) in enumerate(solos):
        assert quotas[:, b].tolist() == rs.extra["trajectory"]["quota"]
        assert ehits[:, b].tolist() == rs.extra["trajectory"]["epoch_hits"]
        assert res.extra["final_quota"][b] == rs.extra["final_quota"]


def test_adversarial_lane_cannot_perturb_neighbor():
    """Lane 0 streams an adversarial all-once churn (sketch poison, window
    thrash); lane 1's hit sequence must equal its solo run bit-for-bit."""
    benign = lanes_trace(6)
    adversarial = fickle_churn_trace(T, n_hot=8, hot_frac=0.02,
                                     seed=9).astype(np.int64)
    traces = np.stack([adversarial, benign[1], benign[2]])
    _, _, hits = run_lanes(traces)
    for b in (1, 2):
        _, _, sh = simulate_trace(traces[b], C, return_state=True)
        np.testing.assert_array_equal(np.asarray(hits[b]), np.asarray(sh),
                                      err_msg=f"lane {b} perturbed by "
                                      "adversarial lane 0")


def test_streams1_bit_identical_to_unbatched():
    tr = lanes_trace(7)[0]
    r1, s1, h1 = simulate_trace(tr, C, streams=1, return_state=True)
    r0, s0, h0 = simulate_trace(tr, C, return_state=True)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h0))
    for k in s0:
        np.testing.assert_array_equal(np.asarray(s1[k]), np.asarray(s0[k]))
    # same spec value -> literally the same compiled program (cache key)
    assert DeviceWTinyLFU(C, streams=1).spec() == DeviceWTinyLFU(C).spec()
    # ... and the byte-identity pin, through the central registry (R7)
    from repro.analysis.program_lint import assert_identical_program
    assert_identical_program("streams1")


def test_lane_program_is_scatter_free():
    """The batched step must not lower to scatter ops: each one costs
    fixed ~µs dispatch on CPU, which is exactly the overhead the lane
    batching amortizes away (lane writes are fused one-hot selects).
    Enforced by lint rule R1, which also catches the expanded-scatter
    form (a known-trip per-index write loop) the old substring check
    missed."""
    from repro.analysis.program_lint import LintBounds, lint_hlo
    spec = DeviceWTinyLFU(C, streams=B).spec()
    state = init_step_state(spec, DeviceWTinyLFU(C).window_cap,
                            DeviceWTinyLFU(C).main_cap)
    lo = jnp.zeros((B, 64), jnp.int32)
    params = DeviceWTinyLFU(C, streams=B).params()
    hlo = jax.jit(step_ref, static_argnums=(0,)).lower(
        spec, params, state, lo, lo).compile().as_text()
    violations = lint_hlo(hlo, LintBounds(access_trips=(64,)),
                          config="lane-program")
    assert not violations, [str(v) for v in violations]


def test_vmapped_adaptive_sweep_matches_sequential():
    """The acceptance criterion: an adaptive grid runs as lanes via
    simulate_sweep(mode="vmap") where it previously raised ValueError."""
    tr = lanes_trace(8)[0]
    wfs = (0.02, 0.1, 0.3)
    rv = simulate_sweep(tr, [C], window_fracs=wfs, mode="vmap",
                        adaptive=True, climb=CL)
    rs = simulate_sweep(tr, [C], window_fracs=wfs, mode="sequential",
                        adaptive=True, climb=CL)
    assert [r.hits for r in rv] == [r.hits for r in rs]
    assert ([r.extra["final_quota"] for r in rv]
            == [r.extra["final_quota"] for r in rs])


def test_climb_hyperparameter_grid_as_lanes():
    tr = lanes_trace(9)[0]
    climbs = [ClimbSpec(epoch_len=512, delta0=d, warm_epochs=w)
              for d, w in ((1, 1), (3, 2), (8, 3))]
    rv = simulate_sweep(tr, [C], window_fracs=(0.1,) * 3, mode="vmap",
                        adaptive=True, climb=climbs)
    rs = simulate_sweep(tr, [C], window_fracs=(0.1,) * 3, mode="sequential",
                        adaptive=True, climb=climbs)
    assert [r.hits for r in rv] == [r.hits for r in rs]
    assert ([r.extra["final_quota"] for r in rv]
            == [r.extra["final_quota"] for r in rs])


def test_adaptive_vmap_sweep_rejects_mixed_geometry():
    with pytest.raises(ValueError, match="shared static geometry"):
        simulate_sweep(lanes_trace(10)[0], [32, 64], mode="vmap",
                       adaptive=True, climb=CL)


def test_adaptive_vmap_sweep_rejects_mixed_epochs():
    climbs = [ClimbSpec(epoch_len=512), ClimbSpec(epoch_len=1024)]
    with pytest.raises(ValueError, match="epoch_len must be uniform"):
        simulate_sweep(lanes_trace(11)[0], [C], window_fracs=(0.05, 0.2),
                       mode="vmap", adaptive=True, climb=climbs)


def test_validation_names_the_field():
    tr = lanes_trace(12)
    with pytest.raises(ValueError, match="streams 0"):
        DeviceWTinyLFU(C, streams=0)
    with pytest.raises(ValueError, match="streams 2 cannot combine"):
        DeviceWTinyLFU(C, streams=2, shards=4, mesh=object())
    with pytest.raises(ValueError, match=r"streams 3 expects a \(B, T\)"):
        simulate_trace(tr[0], C, streams=B)
    with pytest.raises(ValueError, match=r"streams 2 expects a \(B, T\)"):
        simulate_trace(tr, C, streams=2)
    with pytest.raises(ValueError, match="streams is 1"):
        simulate_trace(tr, C)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        DeviceWTinyLFU(C, streams=B).run(tr, checkpoint_dir="/tmp/nope")
    with pytest.raises(ValueError, match="fault_hook"):
        DeviceWTinyLFU(C, streams=B).run(tr, fault_hook=lambda c, s: None)


def test_init_state_lane_axis():
    spec = DeviceWTinyLFU(C, streams=B).spec()
    base = init_step_state(DeviceWTinyLFU(C).spec())
    state = init_step_state(spec)
    for k, v in base.items():
        assert state[k].shape == (B,) + v.shape
        for b in range(B):
            np.testing.assert_array_equal(np.asarray(state[k][b]),
                                          np.asarray(v))


def test_tenant_lanes_trace_shape_and_isolation():
    tr = tenant_lanes_trace(4, 500, n_items=200, seed=3)
    assert tr.shape == (4, 500) and tr.dtype == np.int64
    # deterministic given seed; lanes occupy disjoint key ranges
    np.testing.assert_array_equal(
        tr, tenant_lanes_trace(4, 500, n_items=200, seed=3))
    sets = [set(row.tolist()) for row in tr]
    for a in range(4):
        for b in range(a + 1, 4):
            assert not (sets[a] & sets[b]), (a, b)
    # staggered drift changes the stream but stays per-lane disjoint
    td = tenant_lanes_trace(4, 500, n_items=200, drift_every=128, seed=3)
    assert td.shape == (4, 500)
    assert any(not np.array_equal(td[b], tr[b]) for b in range(4))
