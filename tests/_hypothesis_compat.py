"""Optional-`hypothesis` shim for the test suite.

The tier-1 suite must collect and pass in containers where `hypothesis` is
not installed (CI's minimal image bakes in only the jax toolchain).  When the
real library is available we re-export it untouched — property tests get full
shrinking/fuzzing.  Otherwise we fall back to a tiny deterministic
re-implementation of the small strategy surface these tests use
(`integers`, `floats`, `lists`, `sets`): `@given` draws a fixed number of
seeded pseudo-random examples per strategy and runs the test once per example.

The fallback is intentionally NOT a fuzzer — it is a fixed-example harness
that keeps the same test bodies executable, so the assertions still run on a
spread of representative inputs (including the min/max-size boundaries).
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import random
    import zlib

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5          # examples drawn per @given when shimmed
    _FALLBACK_SEED = 0x71BF        # fixed: runs are reproducible

    class _Strategy:
        """A deterministic example generator: draw(rng) -> value."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _strategies:
        """Fallback for `hypothesis.strategies` (only what the suite uses)."""

        @staticmethod
        def integers(min_value=0, max_value=1 << 16):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

        @staticmethod
        def sets(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                out = set()
                for _ in range(20 * max(1, n)):     # bounded retry on dupes
                    if len(out) >= n:
                        break
                    out.add(elements.draw(rng))
                return out
            return _Strategy(draw)

    strategies = _strategies()

    def settings(*_a, **_kw):
        """No-op decorator mirroring hypothesis.settings(...)."""
        def deco(fn):
            return fn
        return deco

    def given(*strats, **kw_strats):
        """Run the test body over a fixed set of deterministically drawn
        examples.  Supports the positional/keyword strategy forms used here.
        Works both for plain functions and methods (extra leading args are
        passed through)."""
        def deco(fn):
            seed = _FALLBACK_SEED ^ zlib.crc32(fn.__qualname__.encode())

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(seed)
                for _ in range(_FALLBACK_EXAMPLES):
                    ex_args = tuple(s.draw(rng) for s in strats)
                    ex_kw = {k: s.draw(rng) for k, s in kw_strats.items()}
                    fn(*args, *ex_args, **{**kwargs, **ex_kw})
            # pytest follows __wrapped__ to the original signature and would
            # then demand fixtures named after the strategy parameters; hide it
            # so the wrapper's (*args, **kwargs) signature is what's inspected.
            del wrapper.__wrapped__
            return wrapper
        return deco
