"""Serving-substrate tests: prefix cache semantics, TinyLFU admission under
pressure, engine determinism with reuse, device-sketch integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (ServeEngine, PrefixCache, PayloadPool, block_hashes)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen3_4b", smoke=True)
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


class TestBlockHashes:
    def test_chained(self):
        a = block_hashes([1, 2, 3, 4, 5, 6, 7, 8], 4)
        b = block_hashes([1, 2, 3, 4, 9, 9, 9, 9], 4)
        assert a[0] == b[0] and a[1] != b[1]

    def test_partial_block_ignored(self):
        assert len(block_hashes(list(range(10)), 4)) == 2

    def test_prefix_property(self):
        long = block_hashes(list(range(32)), 4)
        short = block_hashes(list(range(16)), 4)
        assert long[:4] == short


class TestPayloadPool:
    def test_store_load_free(self):
        tpl = {"a": jnp.zeros((2, 3)), "b": jnp.zeros((4,), jnp.int32)}
        pool = PayloadPool(tpl, 4)
        s1 = pool.store({"a": jnp.ones((2, 3)), "b": jnp.arange(4)})
        got = pool.load(s1)
        assert float(got["a"].sum()) == 6.0
        assert pool.used == 1
        pool.free(s1)
        assert pool.used == 0

    def test_exhaustion(self):
        pool = PayloadPool({"a": jnp.zeros(2)}, 2)
        assert pool.store({"a": jnp.ones(2)}) is not None
        assert pool.store({"a": jnp.ones(2)}) is not None
        assert pool.store({"a": jnp.ones(2)}) is None


class TestPrefixCachePolicy:
    def _fill(self, pc, pool, n, key_base=0):
        for i in range(n):
            s = pool.store({"x": jnp.ones(1)})
            for f in pc.insert(key_base + i, s):
                pool.free(f)

    def test_lru_no_admission(self):
        pool = PayloadPool({"x": jnp.zeros(1)}, 16)
        pc = PrefixCache(4, policy="lru")
        self._fill(pc, pool, 8)
        assert len(pc) == 4 and pool.used == 4

    def test_tinylfu_protects_hot_blocks(self):
        pool = PayloadPool({"x": jnp.zeros(1)}, 64)
        pc = PrefixCache(8, policy="tinylfu")
        hot = list(range(8))
        for _ in range(20):                     # build frequency
            pc.lookup(hot)
        self._fill(pc, pool, 8)                 # fill with hot keys
        assert len(pc) == 8
        # a cold scan must NOT displace the hot set
        for k in range(1000, 1032):
            s = pool.store({"x": jnp.ones(1)})
            for f in pc.insert(k, s):
                pool.free(f)
        survivors = sum(1 for k in hot if k in pc)
        assert survivors == 8
        assert pc.stats.rejected >= 30

    def test_wtinylfu_window_admits_bursts(self):
        pool = PayloadPool({"x": jnp.zeros(1)}, 256)
        pc = PrefixCache(100, policy="wtinylfu", window_frac=0.1)
        self._fill(pc, pool, 5, key_base=5000)
        # a brand-new burst key always lands in the window (no admission)
        s = pool.store({"x": jnp.ones(1)})
        freed = pc.insert(77, s)
        assert 77 in pc and not freed

    def test_pool_accounting_conserved(self):
        """Every stored slot is either cached or freed — never leaked."""
        rng = np.random.default_rng(0)
        pool = PayloadPool({"x": jnp.zeros(1)}, 32)
        pc = PrefixCache(16, policy="tinylfu")
        for i in range(200):
            k = int(rng.zipf(1.3)) % 64
            pc.lookup([k])
            if k not in pc:
                s = pool.store({"x": jnp.ones(1)})
                if s is None:
                    break
                for f in pc.insert(k, s):
                    pool.free(f)
            assert pool.used == len(pc)


class TestEngine:
    def test_generation_deterministic_under_reuse(self, qwen):
        m, params = qwen
        rng = np.random.default_rng(1)
        prompt = list(rng.integers(0, m.cfg.vocab_size, 33))
        eng = ServeEngine(m, params, max_batch=2, max_len=128, block_size=8,
                          pool_slots=16)
        eng.submit(prompt, 6)
        r1 = eng.run()
        eng.submit(prompt, 6)
        r2 = eng.run()                      # second pass reuses cached blocks
        assert r1[0] == r2[1]
        assert eng.stats["block_hits"] > 0

    def test_continuous_batching_many_requests(self, qwen):
        m, params = qwen
        rng = np.random.default_rng(2)
        shared = list(rng.integers(0, m.cfg.vocab_size, 16))
        eng = ServeEngine(m, params, max_batch=3, max_len=128, block_size=8,
                          pool_slots=32)
        n = 7
        for _ in range(n):
            eng.submit(shared + list(rng.integers(0, m.cfg.vocab_size, 5)), 3)
        out = eng.run()
        assert len(out) == n
        assert all(len(v) == 3 for v in out.values())
        assert eng.stats["reuse_frac"] > 0.2

    def test_device_sketch_admission_end_to_end(self, qwen):
        """Admission through the Pallas kernels (interpret mode)."""
        m, params = qwen
        rng = np.random.default_rng(3)
        eng = ServeEngine(m, params, max_batch=2, max_len=128, block_size=8,
                          pool_slots=6, prefix_policy="tinylfu",
                          device_sketch=True)
        shared = list(rng.integers(0, m.cfg.vocab_size, 16))
        for _ in range(4):
            eng.submit(shared + list(rng.integers(0, m.cfg.vocab_size, 9)), 2)
        out = eng.run()
        assert len(out) == 4
        s = eng.stats
        assert s["pool_used"] <= 6

    @pytest.mark.parametrize("arch", ["zamba2_1p2b", "xlstm_1p3b"])
    def test_ssm_snapshot_reuse(self, arch):
        cfg = get_config(arch, smoke=True)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(4)
        prompt = list(rng.integers(0, cfg.vocab_size, 33))
        eng = ServeEngine(m, params, max_batch=1, max_len=128, block_size=8,
                          pool_slots=16)
        eng.submit(prompt, 4)
        r1 = eng.run()
        eng.submit(prompt, 4)
        r2 = eng.run()
        assert r1[0] == r2[1]
        assert eng.stats["tokens_reused"] > 0
