"""Behavioural tests for eviction/replacement policies + TinyLFU admission."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (Cache, LRUEviction, FIFOEviction, RandomEviction,
                        LFUEviction, SLRUEviction, ARC, LIRS, TwoQ, WLFU,
                        PLFU, WTinyLFU, tinylfu_cache, run_trace)
from repro.traces import zipf_trace


class TestLRU:
    def test_basic_hit_miss(self):
        c = Cache(LRUEviction(2))
        assert not c.access(1)
        assert not c.access(2)
        assert c.access(1)          # hit
        assert not c.access(3)      # evicts 2 (LRU)
        assert not c.access(2)      # 2 was evicted
        assert c.access(3)

    def test_cyclic_worst_case(self):
        """LRU gets 0 hits on a loop one larger than the cache."""
        c = Cache(LRUEviction(3))
        for _ in range(10):
            for k in range(4):
                assert not c.access(k)

    def test_capacity_never_exceeded(self):
        c = Cache(LRUEviction(5))
        for k in range(100):
            c.access(k % 17)
            assert len(c.ev) <= 5


class TestLFU:
    def test_keeps_frequent(self):
        c = Cache(LFUEviction(2))
        for _ in range(5):
            c.access(1)
        c.access(2)
        c.access(3)                 # evicts 2 (freq 1, LRU tie-break)
        assert c.access(1)
        assert not c.access(2)

    def test_halve_all_preserves_order(self):
        ev = LFUEviction(4)
        for f, k in [(8, 1), (4, 2), (2, 3)]:
            ev.add(k)
            for _ in range(f - 1):
                ev.on_hit(k)
        ev.halve_all()
        assert ev.freq == {1: 4, 2: 2, 3: 1}
        assert ev.peek_victim() == 3


class TestSLRU:
    def test_promotion_and_demotion(self):
        ev = SLRUEviction(5, protected_frac=0.6)   # prot cap 3
        for k in [1, 2, 3, 4]:
            ev.add(k)
        ev.on_hit(1); ev.on_hit(2); ev.on_hit(3)   # promote 1,2,3
        assert set(ev.protected) == {1, 2, 3}
        ev.on_hit(4)                                # promote 4 -> demote 1
        assert 1 in ev.probation and 4 in ev.protected
        assert ev.peek_victim() == 1                # probation LRU


@pytest.mark.parametrize("factory", [
    lambda: Cache(LRUEviction(8)),
    lambda: Cache(FIFOEviction(8)),
    lambda: Cache(RandomEviction(8)),
    lambda: Cache(LFUEviction(8)),
    lambda: Cache(SLRUEviction(8)),
    lambda: ARC(8),
    lambda: LIRS(8),
    lambda: TwoQ(8),
    lambda: WLFU(8, window=64),
    lambda: PLFU(8),
    lambda: WTinyLFU(8),
    lambda: tinylfu_cache(8, "lru"),
])
class TestAllPolicies:
    def test_repeated_key_hits(self, factory):
        c = factory()
        c.access(1)
        for _ in range(20):
            assert c.access(1)

    def test_deterministic(self, factory):
        tr = zipf_trace(3000, n_items=500, alpha=0.8, seed=3)
        r1 = run_trace(factory(), tr)
        r2 = run_trace(factory(), tr)
        assert r1.hit_ratio == r2.hit_ratio


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=50), min_size=1,
                max_size=400),
       st.sampled_from(["arc", "lirs", "2q", "wtlfu", "tlru"]))
def test_resident_bounds_property(keys, which):
    """No policy ever holds more residents than its capacity."""
    cap = 8
    c = {"arc": lambda: ARC(cap), "lirs": lambda: LIRS(cap),
         "2q": lambda: TwoQ(cap), "wtlfu": lambda: WTinyLFU(cap),
         "tlru": lambda: tinylfu_cache(cap, "lru")}[which]()
    for k in keys:
        c.access(k)
        if which == "arc":
            assert len(c.t1) + len(c.t2) <= cap
        elif which == "lirs":
            assert c.lir_count + len(c.q) <= cap
        elif which == "2q":
            assert len(c.a1in) + len(c.am) <= cap + 1  # transient +1 by design
        elif which == "wtlfu":
            assert len(c.window) + len(c.main) <= cap + 1
        else:
            assert len(c.ev) <= cap


class TestTinyLFUAdmission:
    def test_improves_lru_on_zipf(self):
        """The paper's headline claim, in miniature."""
        tr = zipf_trace(120_000, n_items=100_000, alpha=0.9, seed=7)
        C = 500
        lru = run_trace(Cache(LRUEviction(C)), tr, warmup=30_000)
        tlru = run_trace(tinylfu_cache(C, "lru", sample_factor=16), tr,
                         warmup=30_000)
        assert tlru.hit_ratio > lru.hit_ratio + 0.02

    def test_wtinylfu_not_worse_than_lru(self):
        tr = zipf_trace(80_000, n_items=50_000, alpha=0.9, seed=9)
        C = 500
        lru = run_trace(Cache(LRUEviction(C)), tr, warmup=20_000)
        w = run_trace(WTinyLFU(C, sample_factor=16), tr, warmup=20_000)
        assert w.hit_ratio >= lru.hit_ratio

    def test_admission_rejects_one_hit_wonders(self):
        """Scan resistance: a cache full of popular items is not polluted by a
        one-pass scan."""
        c = tinylfu_cache(100, "lru", sample_factor=16)
        popular = list(range(100))
        for _ in range(30):
            for k in popular:
                c.access(k)
        before = set(c.ev.keys())
        for k in range(10_000, 11_000):     # scan of cold keys
            c.access(k)
        after = set(c.ev.keys())
        # almost all popular items survive the scan
        assert len(before & after) >= 95

    def test_sketch_lfu_sync_on_reset(self):
        c = tinylfu_cache(4, "lfu", sample_factor=2)  # tiny sample: resets often
        for i in range(64):
            c.access(i % 6)
        # reaching here without KeyError proves reset/halve_all stay in sync
        assert len(c.ev) <= 4


class TestSetAssociativeSLRU:
    """Host twin of the device set-associative main table — see
    kernels/sketch_step.py `_one_access_set` for the mirrored algorithm."""

    def _ev(self, capacity, assoc=8):
        from repro.core.policies import SetAssociativeSLRU
        return SetAssociativeSLRU(capacity, assoc=assoc)

    def test_capacity_and_per_set_budget_respected(self):
        ev = self._ev(64, assoc=8)
        for k in range(500):
            ev.add(k * 7919)
        assert len(ev) <= 64
        for s, st in enumerate(ev.slots):
            assert len(st) <= ev.usable[s]

    def test_resident_set_is_one_of_two_choices(self):
        ev = self._ev(64, assoc=8)
        for k in range(200):
            ev.add(k)
        for k in ev.keys():
            assert ev.home[k] in ev.sets_of(k)

    def test_single_set_victim_is_probation_lru(self):
        # capacity <= assoc collapses to one set: exact SLRU semantics
        ev = self._ev(4, assoc=8)
        assert ev.n_sets == 1
        for k in (1, 2, 3, 4):
            ev.add(k)
        ev.on_hit(2)                       # 2 -> protected
        s, victim = ev.victim_for(99)
        assert victim == 1                 # probation LRU, not protected 2

    def test_protected_overflow_demotes_lru(self):
        ev = self._ev(5, assoc=8)          # 1 set; prot budget = 4*5//5? ->
        budget = ev._prot_budget(0)        # max(1, 5*4//5) = 4
        for k in range(5):
            ev.add(k)
        for k in range(5):
            ev.on_hit(k)                   # 5 promotions: overflow demotes
        nprot = sum(1 for p, _ in ev.slots[0].values() if p)
        assert nprot == budget

    def test_free_way_prefers_first_choice_set(self):
        ev = self._ev(64, assoc=8)
        s, victim = ev.victim_for(12345)
        assert victim is None and s == ev.sets_of(12345)[0]


class TestWTinyLFUAssoc:
    def test_tracks_exact_policy(self):
        """The set-associative host twin stays close to exact W-TinyLFU."""
        tr = zipf_trace(20_000, n_items=5_000, alpha=0.9, seed=3)
        exact = run_trace(WTinyLFU(500, sample_factor=8), tr, warmup=4_000)
        approx = run_trace(WTinyLFU(500, sample_factor=8, assoc=8), tr,
                           warmup=4_000)
        assert abs(exact.hit_ratio - approx.hit_ratio) < 0.02

    def test_contains_and_capacity(self):
        w = WTinyLFU(64, sample_factor=8, assoc=8)
        for k in range(1000):
            w.access(k % 90)
        resident = sum(1 for k in range(90) if k in w)
        assert 0 < resident <= 64
