"""Behavioural tests for eviction/replacement policies + TinyLFU admission."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (Cache, LRUEviction, FIFOEviction, RandomEviction,
                        LFUEviction, SLRUEviction, ARC, LIRS, TwoQ, WLFU,
                        PLFU, WTinyLFU, tinylfu_cache, run_trace,
                        SetAssocS3FIFO, SetAssocARC, SetAssocLFU)
from repro.traces import zipf_trace


class TestLRU:
    def test_basic_hit_miss(self):
        c = Cache(LRUEviction(2))
        assert not c.access(1)
        assert not c.access(2)
        assert c.access(1)          # hit
        assert not c.access(3)      # evicts 2 (LRU)
        assert not c.access(2)      # 2 was evicted
        assert c.access(3)

    def test_cyclic_worst_case(self):
        """LRU gets 0 hits on a loop one larger than the cache."""
        c = Cache(LRUEviction(3))
        for _ in range(10):
            for k in range(4):
                assert not c.access(k)

    def test_capacity_never_exceeded(self):
        c = Cache(LRUEviction(5))
        for k in range(100):
            c.access(k % 17)
            assert len(c.ev) <= 5


class TestLFU:
    def test_keeps_frequent(self):
        c = Cache(LFUEviction(2))
        for _ in range(5):
            c.access(1)
        c.access(2)
        c.access(3)                 # evicts 2 (freq 1, LRU tie-break)
        assert c.access(1)
        assert not c.access(2)

    def test_halve_all_preserves_order(self):
        ev = LFUEviction(4)
        for f, k in [(8, 1), (4, 2), (2, 3)]:
            ev.add(k)
            for _ in range(f - 1):
                ev.on_hit(k)
        ev.halve_all()
        assert ev.freq == {1: 4, 2: 2, 3: 1}
        assert ev.peek_victim() == 3


class TestSLRU:
    def test_promotion_and_demotion(self):
        ev = SLRUEviction(5, protected_frac=0.6)   # prot cap 3
        for k in [1, 2, 3, 4]:
            ev.add(k)
        ev.on_hit(1); ev.on_hit(2); ev.on_hit(3)   # promote 1,2,3
        assert set(ev.protected) == {1, 2, 3}
        ev.on_hit(4)                                # promote 4 -> demote 1
        assert 1 in ev.probation and 4 in ev.protected
        assert ev.peek_victim() == 1                # probation LRU


@pytest.mark.parametrize("factory", [
    lambda: Cache(LRUEviction(8)),
    lambda: Cache(FIFOEviction(8)),
    lambda: Cache(RandomEviction(8)),
    lambda: Cache(LFUEviction(8)),
    lambda: Cache(SLRUEviction(8)),
    lambda: ARC(8),
    lambda: LIRS(8),
    lambda: TwoQ(8),
    lambda: WLFU(8, window=64),
    lambda: PLFU(8),
    lambda: WTinyLFU(8),
    lambda: tinylfu_cache(8, "lru"),
])
class TestAllPolicies:
    def test_repeated_key_hits(self, factory):
        c = factory()
        c.access(1)
        for _ in range(20):
            assert c.access(1)

    def test_deterministic(self, factory):
        tr = zipf_trace(3000, n_items=500, alpha=0.8, seed=3)
        r1 = run_trace(factory(), tr)
        r2 = run_trace(factory(), tr)
        assert r1.hit_ratio == r2.hit_ratio


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=50), min_size=1,
                max_size=400),
       st.sampled_from(["arc", "lirs", "2q", "wtlfu", "tlru"]))
def test_resident_bounds_property(keys, which):
    """No policy ever holds more residents than its capacity."""
    cap = 8
    c = {"arc": lambda: ARC(cap), "lirs": lambda: LIRS(cap),
         "2q": lambda: TwoQ(cap), "wtlfu": lambda: WTinyLFU(cap),
         "tlru": lambda: tinylfu_cache(cap, "lru")}[which]()
    for k in keys:
        c.access(k)
        if which == "arc":
            assert len(c.t1) + len(c.t2) <= cap
        elif which == "lirs":
            assert c.lir_count + len(c.q) <= cap
        elif which == "2q":
            assert len(c.a1in) + len(c.am) <= cap + 1  # transient +1 by design
        elif which == "wtlfu":
            assert len(c.window) + len(c.main) <= cap + 1
        else:
            assert len(c.ev) <= cap


class TestTinyLFUAdmission:
    def test_improves_lru_on_zipf(self):
        """The paper's headline claim, in miniature."""
        tr = zipf_trace(120_000, n_items=100_000, alpha=0.9, seed=7)
        C = 500
        lru = run_trace(Cache(LRUEviction(C)), tr, warmup=30_000)
        tlru = run_trace(tinylfu_cache(C, "lru", sample_factor=16), tr,
                         warmup=30_000)
        assert tlru.hit_ratio > lru.hit_ratio + 0.02

    def test_wtinylfu_not_worse_than_lru(self):
        tr = zipf_trace(80_000, n_items=50_000, alpha=0.9, seed=9)
        C = 500
        lru = run_trace(Cache(LRUEviction(C)), tr, warmup=20_000)
        w = run_trace(WTinyLFU(C, sample_factor=16), tr, warmup=20_000)
        assert w.hit_ratio >= lru.hit_ratio

    def test_admission_rejects_one_hit_wonders(self):
        """Scan resistance: a cache full of popular items is not polluted by a
        one-pass scan."""
        c = tinylfu_cache(100, "lru", sample_factor=16)
        popular = list(range(100))
        for _ in range(30):
            for k in popular:
                c.access(k)
        before = set(c.ev.keys())
        for k in range(10_000, 11_000):     # scan of cold keys
            c.access(k)
        after = set(c.ev.keys())
        # almost all popular items survive the scan
        assert len(before & after) >= 95

    def test_sketch_lfu_sync_on_reset(self):
        c = tinylfu_cache(4, "lfu", sample_factor=2)  # tiny sample: resets often
        for i in range(64):
            c.access(i % 6)
        # reaching here without KeyError proves reset/halve_all stay in sync
        assert len(c.ev) <= 4


class TestSetAssociativeSLRU:
    """Host twin of the device set-associative main table — see
    kernels/sketch_step.py `_one_access_set` for the mirrored algorithm."""

    def _ev(self, capacity, assoc=8):
        from repro.core.policies import SetAssociativeSLRU
        return SetAssociativeSLRU(capacity, assoc=assoc)

    def test_capacity_and_per_set_budget_respected(self):
        ev = self._ev(64, assoc=8)
        for k in range(500):
            ev.add(k * 7919)
        assert len(ev) <= 64
        for s, st in enumerate(ev.slots):
            assert len(st) <= ev.usable[s]

    def test_resident_set_is_one_of_two_choices(self):
        ev = self._ev(64, assoc=8)
        for k in range(200):
            ev.add(k)
        for k in ev.keys():
            assert ev.home[k] in ev.sets_of(k)

    def test_single_set_victim_is_probation_lru(self):
        # capacity <= assoc collapses to one set: exact SLRU semantics
        ev = self._ev(4, assoc=8)
        assert ev.n_sets == 1
        for k in (1, 2, 3, 4):
            ev.add(k)
        ev.on_hit(2)                       # 2 -> protected
        s, victim = ev.victim_for(99)
        assert victim == 1                 # probation LRU, not protected 2

    def test_protected_overflow_demotes_lru(self):
        ev = self._ev(5, assoc=8)          # 1 set; prot budget = 4*5//5? ->
        budget = ev._prot_budget(0)        # max(1, 5*4//5) = 4
        for k in range(5):
            ev.add(k)
        for k in range(5):
            ev.on_hit(k)                   # 5 promotions: overflow demotes
        nprot = sum(1 for p, _ in ev.slots[0].values() if p)
        assert nprot == budget

    def test_free_way_prefers_first_choice_set(self):
        ev = self._ev(64, assoc=8)
        s, victim = ev.victim_for(12345)
        assert victim is None and s == ev.sets_of(12345)[0]


class TestWTinyLFUAssoc:
    def test_tracks_exact_policy(self):
        """The set-associative host twin stays close to exact W-TinyLFU."""
        tr = zipf_trace(20_000, n_items=5_000, alpha=0.9, seed=3)
        exact = run_trace(WTinyLFU(500, sample_factor=8), tr, warmup=4_000)
        approx = run_trace(WTinyLFU(500, sample_factor=8, assoc=8), tr,
                           warmup=4_000)
        assert abs(exact.hit_ratio - approx.hit_ratio) < 0.02

    def test_contains_and_capacity(self):
        w = WTinyLFU(64, sample_factor=8, assoc=8)
        for k in range(1000):
            w.access(k % 90)
        resident = sum(1 for k in range(90) if k in w)
        assert 0 < resident <= 64


# ===========================================================================
# seed-policy behavioral debt (ISSUE 9): ARC / LIRS / TwoQ were exercised
# only through aggregate hit ratios — pin the *mechanisms* (ghost-hit
# promotion, the documented direction of ARC's p adaptation, and capacity
# invariants under churn) so a refactor cannot hollow them out silently.
# ===========================================================================

class TestARCBehavior:
    def _warm(self):
        """1,1,2,3,4,5: t2=[1], t1=[3,4,5], b1=[2] — one ghost, full cache."""
        a = ARC(4)
        for k in (1, 1, 2, 3, 4, 5):
            a.access(k)
        assert (list(a.t1), list(a.t2), list(a.b1)) == ([3, 4, 5], [1], [2])
        return a

    def test_b1_ghost_hit_raises_p_and_promotes_to_t2(self):
        a = self._warm()
        assert a.p == 0
        assert a.access(2) is False        # ghost hit is still a miss...
        assert a.p == 1                    # ...but p grows toward recency
        assert 2 in a.t2 and 2 not in a.b1  # and re-enters as frequent
        assert a.access(2) is True

    def test_b2_ghost_hit_lowers_p(self):
        a = self._warm()
        a.access(2)                        # b1 hit: p 0 -> 1
        a.access(3)                        # b1 hit: p 1 -> 2, evicts t2 LRU
        assert a.p == 2 and list(a.b2) == [1]
        assert a.access(1) is False        # b2 ghost hit
        assert a.p == 1                    # p shrinks toward frequency
        assert 1 in a.t2

    def test_t1_hit_promotes_to_t2(self):
        a = ARC(4)
        a.access(7)
        assert 7 in a.t1
        assert a.access(7) is True
        assert 7 in a.t2 and 7 not in a.t1

    def test_capacity_invariants_under_churn(self):
        """The paper's I1-I4 style bounds: residency <= c, |L1| <= c,
        |L1|+|L2| <= 2c, p in [0, c] — after EVERY access."""
        rng = np.random.default_rng(42)
        for c in (2, 5, 16):
            a = ARC(c)
            tr = rng.zipf(1.3, size=3_000).astype(int) % 120
            for k in tr:
                a.access(int(k))
                assert len(a.t1) + len(a.t2) <= c
                assert len(a.t1) + len(a.b1) <= c
                assert (len(a.t1) + len(a.t2)
                        + len(a.b1) + len(a.b2)) <= 2 * c
                assert 0 <= a.p <= c


class TestLIRSBehavior:
    def _warm(self):
        """C=5 (llirs=4, lhirs=1): 1..4 LIR, 5 resident-HIR, 6 evicts 5."""
        l = LIRS(5)
        for k in (1, 2, 3, 4, 5, 6):
            l.access(k)
        return l

    def test_ghost_hit_promotes_to_lir(self):
        l = self._warm()
        assert l.state[5] == l.HIR_NONRES and 5 in l.nonres
        assert l.access(5) is False        # non-resident: a real miss...
        assert l.state[5] == l.LIR         # ...promoted straight to LIR
        assert l.lir_count <= l.llirs      # a LIR bottom was demoted to fit

    def test_resident_hir_hit_promotes_when_in_stack(self):
        l = LIRS(5)
        for k in (1, 2, 3, 4):
            l.access(k)
        l.access(5)
        assert l.state[5] == l.HIR_RES and 5 in l.s
        assert l.access(5) is True         # resident hit
        assert l.state[5] == l.LIR and 5 not in l.q

    def test_capacity_invariants_under_churn(self):
        rng = np.random.default_rng(43)
        for c in (3, 5, 20):
            l = LIRS(c)
            tr = rng.zipf(1.3, size=3_000).astype(int) % 150
            for k in tr:
                l.access(int(k))
                assert l.lir_count + len(l.q) <= c      # residents
                assert l.lir_count <= l.llirs
                assert len(l.nonres) <= l.max_nonres    # bounded ghosts


class TestTwoQBehavior:
    def test_a1out_ghost_hit_promotes_to_am(self):
        q = TwoQ(8)                        # kin_cap=2, am_cap=6, kout_cap=4
        q.access(1)
        q.access(2)
        q.access(3)                        # A1in FIFO evicts 1 -> A1out
        assert 1 in q.a1out and 1 not in q.a1in
        assert q.access(1) is False        # ghost hit is a miss...
        assert 1 in q.am and 1 not in q.a1out  # ...promoted to Am
        assert q.access(1) is True

    def test_a1in_hit_does_not_refresh_fifo_order(self):
        q = TwoQ(8)
        q.access(1)
        q.access(2)
        assert q.access(1) is True         # hit in A1in...
        q.access(3)                        # ...but 1 still FIFO-oldest
        assert 1 in q.a1out

    def test_capacity_invariants_under_churn(self):
        rng = np.random.default_rng(44)
        for c in (4, 8, 24):
            q = TwoQ(c)
            tr = rng.zipf(1.3, size=3_000).astype(int) % 150
            for k in tr:
                q.access(int(k))
                assert len(q.a1in) <= q.kin_cap
                assert len(q.am) <= q.am_cap
                assert len(q.a1out) <= q.kout_cap       # ghost bound
                assert len(q.a1in) + len(q.am) <= c     # residents


class TestDevicePolicyTwins:
    """Smoke coverage for the SetAssoc* host twins themselves (the
    bit-for-bit device parity lives in tests/test_policy_panel.py)."""

    def test_s3fifo_small_queue_is_fifo_and_filter_gates_main(self):
        p = SetAssocS3FIFO(40, window_frac=0.1, assoc=8,
                           counters_per_item=550.0, doorkeeper=False)
        for k in range(4):
            p.access(k)                    # fill the 4-slot small FIFO
        assert p.access(0) is True         # small-queue hit, no refresh:
        p.access(10)                       # 0 is still FIFO-oldest, and
        assert 0 in p.main                 # seen twice -> passes the filter
        p.access(11)                       # displaces 1: a one-hit wonder,
        assert 1 not in p.main             # filtered away from main

    def test_twin_residency_bounds(self):
        rng = np.random.default_rng(45)
        tr = (rng.zipf(1.3, size=2_000).astype(int) % 200).tolist()
        for mk in (lambda: SetAssocS3FIFO(30, assoc=8),
                   lambda: SetAssocARC(30, assoc=8),
                   lambda: SetAssocLFU(30, assoc=8)):
            p = mk()
            for k in tr:
                p.access(k)
                assert len(p.main) <= p.main.capacity
            assert 0.0 < p.hit_ratio < 1.0

    def test_arc_twin_adapts_p(self):
        p = SetAssocARC(16, assoc=4, dk_bits=1 << 14)
        rng = np.random.default_rng(46)
        for k in rng.zipf(1.2, size=4_000).astype(int) % 64:
            p.access(int(k))
            assert 0 <= p.p <= p.main.capacity
