"""The linter lints itself: every rule R1-R7 must trip on its committed
bad program, and the real engine (a cheap slice of the config matrix)
must lint clean.  The full matrix runs in CI via tools/lint_programs.py;
here we pin the rule semantics so a refactor of program_lint.py cannot
silently stop detecting a regression class.
"""
import json

import pytest

from repro.analysis.lint_fixtures import FIXTURES
from repro.analysis.program_lint import (FINGERPRINT_CONTRACTS, LintBounds,
                                         MatrixEntry, _digest,
                                         check_fingerprints, default_matrix,
                                         env_key, lint_hlo, load_registry,
                                         run_matrix)


# ---------------------------------------------------------------------------
# R1-R6: each fixture must trip exactly its rule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_fixture_trips_its_rule(rule):
    text, bounds = FIXTURES[rule]()
    tripped = {v.rule for v in lint_hlo(text, bounds, config=f"bad-{rule}")}
    assert rule in tripped, (
        f"fixture for {rule} no longer detected; rules tripped: {tripped}")


def test_violation_reports_are_actionable():
    text, bounds = FIXTURES["R2"]()
    v = [x for x in lint_hlo(text, bounds, config="bad-R2")
         if x.rule == "R2"][0]
    assert "bad-R2" in str(v) and v.where    # config + HLO location
    d = v.to_dict()
    assert set(d) == {"rule", "config", "where", "message"}


def test_r0_flags_missing_access_scan():
    # a module with no while loop at all, but bounds that expect one
    text, _ = FIXTURES["R4"]()               # committed text, loop-free
    v = lint_hlo(text, LintBounds(access_trips=(96,)), config="no-scan")
    assert "R0" in {x.rule for x in v}


def test_unrolled_access_scan_is_still_recognized():
    # XLA unrolls the flat scan 4x (trips T/4); R0 must not fire, and an
    # absurd trip count must not be mistaken for the access loop
    text, bounds = FIXTURES["R3"]()
    n_trips = [t for _, _, t, _ in _whiles_of(text) if t is not None]
    assert n_trips, "fixture lost its known-trip while"
    ok = lint_hlo(text, LintBounds(access_trips=(4 * n_trips[0],)),
                  config="unrolled")
    assert "R0" not in {x.rule for x in ok}


def _whiles_of(text):
    from repro.analysis.hlo_cost import _split_computations
    from repro.analysis.program_lint import _find_whiles
    comps, _ = _split_computations(text)
    return _find_whiles(comps)


# ---------------------------------------------------------------------------
# R6 cadence: the same collective text judged under each contract
# ---------------------------------------------------------------------------

def test_r6_cadence_contracts():
    text, _ = FIXTURES["R6"]()               # all-reduce in the scan body
    in_loop = LintBounds(access_trips=(96,))
    # single-device: any collective is a violation
    assert "R6" in {v.rule for v in lint_hlo(text, in_loop)}
    # chunk: in-loop collective is the 62.8x bug
    assert "R6" in {v.rule for v in lint_hlo(
        text, LintBounds(access_trips=(96,), mesh_exchange="chunk"))}
    # stale: collective in the ACCESS body is still wrong...
    assert "R6" in {v.rule for v in lint_hlo(
        text, LintBounds(access_trips=(96,), mesh_exchange="stale"))}
    # ...but the same loop declared as a non-access (epoch) loop is the
    # legitimate per-epoch fold under the stale contract (R0 fires for
    # the absent access scan; R6 must not)
    stale_other = lint_hlo(
        text, LintBounds(access_trips=(7,), mesh_exchange="stale"))
    assert "R6" not in {v.rule for v in stale_other}


# ---------------------------------------------------------------------------
# green run: the real engine lints clean (cheap slice of the matrix; the
# full 15-entry matrix is the CI step)
# ---------------------------------------------------------------------------

def test_default_matrix_covers_every_axis():
    labels = [e.label for e in default_matrix()]
    for needle in ("flat-static", "assoc-static", "streams4", "policy-",
                   "shards4", "adaptive", "mesh-chunk", "mesh-stale",
                   "integrity", "donated"):
        assert any(needle in l for l in labels), needle


def test_engine_slice_lints_clean():
    matrix = [e for e in default_matrix()
              if e.label in ("flat-static", "assoc-static",
                             "assoc-donated")]
    violations, rows = run_matrix(matrix)
    assert not violations, [str(v) for v in violations]
    assert {r["label"]: r["status"] for r in rows} == {
        "flat-static": "ok", "assoc-static": "ok", "assoc-donated": "ok"}


def test_waived_rule_reports_but_does_not_fail():
    def build():
        return FIXTURES["R3"]()
    entry = MatrixEntry("waived-fixture", build,
                        waive={"R3": "test waiver"})
    violations, rows = run_matrix([entry])
    assert not violations                     # waived -> non-fatal
    (row,) = rows
    assert row["status"] == "waived"
    assert row["waived"] and row["waived"][0]["reason"] == "test waiver"


def test_skip_entry_reports_skipped():
    from repro.analysis.program_lint import SkipEntry

    def build():
        raise SkipEntry("needs hardware")
    violations, rows = run_matrix([MatrixEntry("skippy", build)])
    assert not violations
    assert rows[0]["status"] == "skipped" and "hardware" in rows[0]["reason"]


# ---------------------------------------------------------------------------
# R7: the fingerprint registry
# ---------------------------------------------------------------------------

def test_r7_update_then_check_roundtrip(tmp_path):
    reg = tmp_path / "fp.json"
    v, notes = check_fingerprints(update=True, registry_path=reg,
                                  contracts={"shards1": {"shards": 1}})
    assert not v and any("updated" in n for n in notes)
    v, notes = check_fingerprints(registry_path=reg,
                                  contracts={"shards1": {"shards": 1}})
    assert not v, [str(x) for x in v]


def test_r7_tampered_digest_is_a_violation(tmp_path):
    reg = tmp_path / "fp.json"
    check_fingerprints(update=True, registry_path=reg,
                       contracts={"shards1": {"shards": 1}})
    data = json.loads(reg.read_text())
    data[env_key()]["shards1"] = "0" * 64
    reg.write_text(json.dumps(data))
    v, _ = check_fingerprints(registry_path=reg,
                              contracts={"shards1": {"shards": 1}})
    assert any(x.rule == "R7" and "drifted" in x.message for x in v)


def test_r7_non_default_override_breaks_pair_equality(tmp_path):
    # {"assoc": 4} is NOT a spelled-out default -> different program ->
    # the pair-equality half of R7 must fire even with no registry
    v, _ = check_fingerprints(registry_path=tmp_path / "fp.json",
                              contracts={"bogus": {"assoc": 4}})
    assert any(x.rule == "R7" and x.config == "bogus" for x in v)


def test_r7_missing_env_is_note_not_violation(tmp_path):
    v, notes = check_fingerprints(registry_path=tmp_path / "absent.json",
                                  contracts={"shards1": {"shards": 1}})
    assert not v
    assert any("skipped" in n for n in notes)


def test_committed_registry_is_valid_json_with_all_contracts():
    reg = load_registry()
    assert reg, "fingerprints.json missing or empty"
    for env, digests in reg.items():
        assert "base" in digests
        for name in FINGERPRINT_CONTRACTS:
            assert name in digests, (env, name)
        for dg in digests.values():
            assert len(dg) == 64 and int(dg, 16) >= 0


def test_digest_is_sha256_of_text():
    import hashlib
    assert _digest("abc") == hashlib.sha256(b"abc").hexdigest()
