"""Fault-injection drills for the device engine (ISSUE 7, core.faults).

Three failure families, each pinned against the recovery mechanism that
owns it:

* corrupted sketch words — the integrity checksums catch the flip at the
  next merge boundary, quarantine the shard (zero + count), and the §3.3
  aging re-learns: the golden hit ratio holds within the ±0.01 tier;
* lost shard state — the stale-exchange loss model (a device that missed
  its delta exchanges, injected as the strictly-worse zeroing of the
  shard's accumulated global slice): graceful degradation, goldens hold;
* process death — SIGKILL mid-run; resume from the latest durable
  checkpoint is bit-identical to the uninterrupted run.  The two-device
  variant runs under FAULT_TIER=1 (CI's fault tier) because it needs two
  forced host devices and a kill+resume subprocess pair.
"""
import os
import signal

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import faults
from repro.core.device_simulate import (DeviceWTinyLFU, simulate_trace,
                                        resume_trace)
from repro.checkpoint.store import latest_step
from repro.kernels.sketch_common import keys_to_lanes
from repro.kernels.sketch_step import (StepSpec, make_step_params,
                                       init_step_state, step_ref)
from repro.kernels.sketch_merge import merge_halve
from repro.traces import zipf_trace

from test_distributed import _run_forced_device_script


def test_flip_words_flips_exact_bit():
    st = {"counters": jnp.arange(16, dtype=jnp.int32)}
    out = faults.flip_words(st, "counters", [(3, 7), (5, 31)])
    a, b = np.asarray(st["counters"]), np.asarray(out["counters"])
    diff = a.view(np.uint32) ^ b.view(np.uint32)
    assert diff[3] == np.uint32(1) << 7
    assert diff[5] == np.uint32(1) << 31
    assert (np.delete(diff, [3, 5]) == 0).all()
    assert int(a[3]) == 3                       # input untouched


def test_drop_shard_delta_mid_epoch_semantics():
    """On a mid-epoch state (no fold yet: deltas nonzero, globals zero)
    dropping shard 0's delta zeroes exactly that slice, and the subsequent
    fold produces a global that differs from the intact fold ONLY in shard
    0's slices — one device's lost increments never contaminate peers."""
    spec = StepSpec(width=1 << 10, rows=4, dk_bits=1 << 8, window_slots=2,
                    main_slots=16, shards=4)
    params = make_step_params(2, 16, 12, 0, 15, 3)
    rng = np.random.default_rng(8)
    keys = rng.integers(0, 500, size=800, dtype=np.uint64)
    lo, hi = keys_to_lanes(keys)
    st, _ = step_ref(spec, params, init_step_state(spec, 2, 16),
                     lo.astype(jnp.int32), hi.astype(jnp.int32))
    H, HD = spec.counter_words, spec.dk_words
    c0 = np.asarray(st["counters"])
    assert np.abs(c0[H:]).sum() > 0             # mid-epoch: deltas live
    dropped = faults.drop_shard_delta(spec, st, 0)
    cd = np.asarray(dropped["counters"])
    d3 = cd[H:].reshape(spec.rows, spec.shards, spec.wps_shard)
    assert (d3[:, 0, :] == 0).all()
    np.testing.assert_array_equal(               # other shards + globals
        d3[:, 1:, :],
        c0[H:].reshape(spec.rows, spec.shards, spec.wps_shard)[:, 1:, :])
    np.testing.assert_array_equal(cd[:H], c0[:H])
    gi = np.asarray(merge_halve(spec, params, st)["counters"])[:H]
    gd = np.asarray(merge_halve(spec, params, dropped)["counters"])[:H]
    gi3 = gi.reshape(spec.rows, spec.shards, spec.wps_shard)
    gd3 = gd.reshape(spec.rows, spec.shards, spec.wps_shard)
    assert (gd3[:, 0, :] == 0).all()            # shard 0 lost (global was 0)
    np.testing.assert_array_equal(gd3[:, 1:, :], gi3[:, 1:, :])
    # doorkeeper mirrors the counters
    dk = np.asarray(dropped["doorkeeper"])
    assert (dk[HD:].reshape(spec.shards, spec.dkw_shard)[0] == 0).all()


def test_cache_table_flip_degrades_gracefully():
    """A flipped word in the cache tables (bit-rot in the metadata, not the
    sketch) may evict at most the entries it garbles: the run completes and
    the hit ratio moves by at most noise."""
    tr = zipf_trace(10_000, n_items=1_500, alpha=0.9, seed=6)
    cfg = DeviceWTinyLFU(300, assoc=8)

    def hook(cursor, state):
        if cursor == 4096:
            state = faults.flip_words(state, "wtab", [(1, 4)])
            state = faults.flip_words(state, "mtab", [(7, 30)])
            return state
        return None

    res0 = simulate_trace(tr, 300, warmup=1_000, assoc=8)
    res1 = cfg.run(tr, warmup=1_000, fault_hook=hook, checkpoint_every=2_048)
    assert res1.accesses == res0.accesses
    assert abs(res1.hit_ratio - res0.hit_ratio) < 0.02


def test_checksum_quarantine_self_heals_golden():
    """The tentpole integrity drill on the PR-1 golden: a bit flipped in
    shard 1's read-only global slice is caught at the next merge boundary,
    the shard is quarantined (csum count 1), aging re-learns, and both the
    full-run hit ratio and the post-fault tail stay inside the golden
    ±0.01 tier."""
    tr = zipf_trace(60_000, n_items=50_000, alpha=0.9, seed=7)
    kw = dict(shards=2, merge_every=1600)
    cfg = DeviceWTinyLFU(200, integrity=True, **kw)
    spec = cfg.spec()

    def hook(cursor, state):
        if cursor == 12_800:                     # mid-run, one flip
            return faults.flip_words(state, "counters",
                                     [(spec.wps_shard, 2)])
        return None

    res0, _, h0 = simulate_trace(tr, 200, warmup=10_000, return_state=True,
                                 integrity=True, **kw)
    res1, st1, h1 = cfg.run(tr, warmup=10_000, fault_hook=hook,
                            checkpoint_every=3_200, return_state=True)
    assert int(np.asarray(st1["csum"])[-1]) == 1          # quarantined once
    assert abs(res1.hit_ratio - 0.3498) < 0.01, res1.hit_ratio
    tail0 = float(np.asarray(h0)[-20_000:].mean())
    tail1 = float(np.asarray(h1)[-20_000:].mean())
    assert abs(tail1 - tail0) < 0.01, (tail0, tail1)      # healed, bounded


def test_shard_global_loss_degrades_gracefully_golden():
    """Stale-exchange loss model: a device whose accumulated global slice
    vanishes twice mid-run (strictly worse than missing single delta
    exchanges).  The estimator is a sampled approximation — losing one
    shard's estimates degrades admission, it must not break it: golden
    ±0.01 holds with no integrity machinery at all."""
    tr = zipf_trace(60_000, n_items=50_000, alpha=0.9, seed=7)
    cfg = DeviceWTinyLFU(200, shards=2, merge_every=1600)
    spec = cfg.spec()

    def hook(cursor, state):
        if cursor in (19_200, 38_400):
            return faults.drop_shard_delta(spec, state, 0, half="global")
        return None

    res = cfg.run(tr, warmup=10_000, fault_hook=hook, checkpoint_every=3_200)
    assert abs(res.hit_ratio - 0.3498) < 0.01, res.hit_ratio


KILL_SCRIPT = r"""
import numpy as np
from repro.core.device_simulate import DeviceWTinyLFU
from repro.traces import zipf_trace

tr = zipf_trace(30_000, n_items=4_000, alpha=0.9, seed=12)
cfg = DeviceWTinyLFU(300)
cfg.run(tr, warmup=2_000, checkpoint_dir=%(dir)r, checkpoint_every=2_400,
        on_checkpoint=lambda c: print("CKPT", c, flush=True))
print("DONE", flush=True)
"""


def test_sigkill_resume_bit_identical(tmp_path):
    """SIGKILL a checkpointing run mid-trace; resume in-process from the
    latest durable checkpoint — hit sequence and final state bit-identical
    to the uninterrupted run (atomic saves mean a kill mid-write leaves a
    torn .tmp that latest_step ignores)."""
    d = str(tmp_path / "ck")
    env = {"PYTHONPATH": os.path.join(os.path.dirname(__file__), "..",
                                      "src"),
           "JAX_PLATFORMS": "cpu"}
    seen, rc = faults.run_to_kill(KILL_SCRIPT % {"dir": d}, kills=3,
                                  env=env)
    assert seen == 3
    assert rc == -signal.SIGKILL
    step = latest_step(d)
    assert step is not None and 0 < step < 30_000          # died mid-run
    tr = zipf_trace(30_000, n_items=4_000, alpha=0.9, seed=12)
    res0, st0, h0 = simulate_trace(tr, 300, warmup=2_000, return_state=True)
    cfg = DeviceWTinyLFU(300)
    res1, st1, h1 = resume_trace(tr, cfg, checkpoint_dir=d, warmup=2_000,
                                 checkpoint_every=2_400, return_state=True)
    assert res1.extra["resumed_at"] == step
    np.testing.assert_array_equal(np.asarray(h0), np.asarray(h1))
    for k in st0:
        np.testing.assert_array_equal(np.asarray(st0[k]),
                                      np.asarray(st1[k]), err_msg=k)


MESH_KILL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
from repro.core.device_simulate import DeviceWTinyLFU
from repro.distributed.mesh import make_shard_mesh
from repro.traces import zipf_trace

assert len(jax.devices()) == 2
tr = zipf_trace(30_000, n_items=4_000, alpha=0.9, seed=12)
cfg = DeviceWTinyLFU(300, shards=4, merge_every=512,
                     mesh=make_shard_mesh(4, require=2))
cfg.run(tr, warmup=2_000, checkpoint_dir=%(dir)r, checkpoint_every=2_048,
        on_checkpoint=lambda c: print("CKPT", c, flush=True))
print("DONE", flush=True)
"""

MESH_RESUME_VERIFY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np
import jax
from repro.core.device_simulate import (DeviceWTinyLFU, simulate_trace,
                                        resume_trace)
from repro.distributed.mesh import make_shard_mesh
from repro.traces import zipf_trace

assert len(jax.devices()) == 2
tr = zipf_trace(30_000, n_items=4_000, alpha=0.9, seed=12)
mesh = make_shard_mesh(4, require=2)
kw = dict(shards=4, merge_every=512)
res0, st0, h0 = simulate_trace(tr, 300, warmup=2_000, mesh=mesh,
                               return_state=True, **kw)
cfg = DeviceWTinyLFU(300, mesh=mesh, **kw)
res1, st1, h1 = resume_trace(tr, cfg, checkpoint_dir=%(dir)r,
                             warmup=2_000, checkpoint_every=2_048,
                             return_state=True)
assert res1.extra["resumed_at"] > 0
np.testing.assert_array_equal(np.asarray(h0), np.asarray(h1))
for k in st0:
    np.testing.assert_array_equal(np.asarray(st0[k]), np.asarray(st1[k]),
                                  err_msg=k)
print("OK mesh kill+resume", res1.extra["resumed_at"])
"""


@pytest.mark.skipif(not os.environ.get("FAULT_TIER"),
                    reason="fault tier only (FAULT_TIER=1): forced-2-device "
                           "kill+resume subprocess pair")
def test_kill_resume_two_devices(tmp_path):
    d = str(tmp_path / "ck")
    seen, rc = faults.run_to_kill(
        MESH_KILL_SCRIPT % {"dir": d}, kills=3,
        env={"PYTHONPATH": os.path.join(os.path.dirname(__file__), "..",
                                        "src"),
             "JAX_PLATFORMS": "cpu"})
    assert seen == 3
    assert rc == -signal.SIGKILL
    assert latest_step(d) is not None
    out = _run_forced_device_script(MESH_RESUME_VERIFY_SCRIPT % {"dir": d})
    assert "OK mesh kill+resume" in out
