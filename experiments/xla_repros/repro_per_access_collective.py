"""Collective-cadence repro: an ``all-reduce`` inside a scan body pays a
cross-device exchange per iteration.  On the forced-2-host-device CPU
stand-in this measured 62.8x vs the identical program exchanging state
only at program entry/exit (the bug class lint rule R6 now catches
statically; see ``docs/ARCHITECTURE.md``).

The committed HLO text in ``repro.analysis.lint_fixtures`` is the
structure itself (lowering it live needs a >= 2 device mesh); this
script verifies the linter still classifies it as the per-access-psum
pathology under the chunk-exchange cadence contract.  Exit 0 = repro
intact, 1 = the fixture stopped tripping (investigate before trusting
the R6 gate).
"""
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "..", "src"))

from repro.analysis.lint_fixtures import bad_r6_per_access_psum
from repro.analysis.program_lint import lint_hlo


def main() -> int:
    text, bounds = bad_r6_per_access_psum()
    violations = [v for v in lint_hlo(text, bounds, config="repro-r6")
                  if v.rule == "R6"]
    if not violations:
        print("R6 repro stopped tripping — the fixture or the linter "
              "changed; the 62.8x per-access-psum gate may be void")
        return 1
    print("R6 repro reproduces: collective inside the scan body —")
    for v in violations:
        print("  ", v)
    print("\nworkaround in this repo: per-chunk delta gather/split "
          "exchange (mesh_exchange=\"chunk\"), collectives at program "
          "entry/exit only; measured ~1x overhead vs single-device "
          "sharded, against 62.8x for the per-access psum")
    return 0


if __name__ == "__main__":
    sys.exit(main())
