"""XLA-CPU copy-insertion repro: a scan body that changes ONE word of a
table via a full-array masked ``where`` materializes a table-shaped
buffer every iteration — the optimized HLO carries a table-shaped copy /
non-DUS fusion output inside the while body, so the per-step cost is
O(table) instead of O(1).

This is the minimal form of the "chain-split allocation cliff" the
engine works around with single-word dynamic_update_slice chains (lint
rule R3, ``docs/ARCHITECTURE.md`` static-analysis section).  Exit 0 =
pathology present (repro reproduces), 1 = fixed upstream.
"""
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "..", "src"))

from repro.analysis.lint_fixtures import bad_r3_whole_table_copy
from repro.analysis.program_lint import lint_hlo


def main() -> int:
    text, bounds = bad_r3_whole_table_copy()
    violations = [v for v in lint_hlo(text, bounds, config="repro-r3")
                  if v.rule == "R3"]
    if not violations:
        print("R3 repro NO LONGER reproduces — XLA now keeps the masked "
              "where in place; revisit the single-word-DUS workaround")
        return 1
    print("R3 repro reproduces: table-shaped materialization per scan "
          "step in the optimized HLO —")
    for v in violations:
        print("  ", v)
    print("\nworkaround in this repo: single-word dynamic_update_slice "
          "chains + _sched_dep read-anchoring (kernels/sketch_step.py)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
