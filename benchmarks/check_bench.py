"""CI regression gate over the device-engine perf snapshot (ISSUE 3).

Compares a freshly measured ``BENCH_device.json`` against the committed
baseline and fails (exit 1) when the XLA-CPU in-place discipline looks
broken:

* ``assoc_flatness_512_to_65536 < threshold`` (default 0.9) — the set path's
  per-access cost is supposed to be capacity-free; an in-place-discipline
  regression (scatter writes, cond-copied operands, read-after-write
  scheduling) turns it O(capacity) and drops flatness to ~0.1.  Because LLC
  contention on shared runners also depresses large-C throughput (observed
  down to ~0.7 with *unchanged* code), a flatness miss alone is only a
  WARNING unless corroborated by ``assoc_speedup_vs_flat_8192 < 5`` — a
  real O(capacity) regression collapses that internal ratio to ~1 while
  machine noise leaves it >= 10.  ``--strict`` makes flatness alone fatal.
* ``sharded_flatness_512_to_65536 < threshold`` — the same tripwire for the
  sharded-sketch path (ISSUE 4): its per-access delta writes must stay
  capacity-free too.  Corroborated by
  ``sharded_overhead_vs_unsharded > 3`` (a real regression — e.g. the
  merge fold leaking into the per-access path, or delta copies — blows the
  overhead up; machine noise leaves it near ~1-2x).  Missing fields are
  tolerated (pre-ISSUE-4 snapshots).
* ``assoc_flatness_512_to_262144 < threshold`` — the UNSHARDED path at a
  2^19-counter sketch width, past the XLA-CPU gather-partitioning cliff
  the ISSUE 5 unrolled-gather fix removed; same corroboration as the
  65536 arm.  Missing in pre-ISSUE-5 snapshots.
* ``mesh_parity_ok`` false — the forced-2-host-device mesh run no longer
  reproduces the single-device sharded hit sequence bit-for-bit.  This is
  an exactness invariant, so it fails unconditionally (no noise model);
  the field is absent when the bench could not run the subprocess.
* ``mesh_overhead_vs_sharded > 3`` — the ISSUE 6 collective-cadence
  tripwire: the exact chunked-exchange mesh run exchanges state only on
  entering/leaving the compiled program, so its overhead vs the
  single-device sharded run sits near ~1x.  A per-access collective
  sneaking back into the step (the bug this gate was built after measured
  62.8x) scales the overhead with the epoch length, far past any machine
  noise — so a miss WARNS at > 3 and only fails when corroborated by
  ``> 10`` (or ``--strict``).  Missing in pre-ISSUE-6 snapshots.
* ``checkpoint_overhead_vs_plain`` (ISSUE 7) is RECORDED in the gate-OK
  line but never gated: the epoch-boundary checkpoint cost is dominated by
  CI-runner disk speed, which is not a property of this code.  The
  acceptance bar (<= 1.1x at the auto cadence) is checked by eye on the
  printed snapshot.
* ``streams_scaling_1_to_64 < 8`` — the ISSUE 8 dispatch-amortization
  tripwire: the B=64 lane-batched step must aggregate >= 8x the
  single-stream acc/s on the frozen small-tenant geometry.  A real
  regression (a scatter back in the lane program, a fusion-breaking
  gather, per-lane dispatch re-serialized) collapses the ratio toward
  ~1x; shared-runner noise moves it by tens of percent, not 3x — so a
  miss WARNS below 8 and only fails when corroborated by ``< 3`` (or
  ``--strict``).  Missing in pre-ISSUE-8 snapshots.
* ``policy_acc_per_s_{s3fifo,arc,lfu} < policy_acc_per_s_wtinylfu / 2`` —
  the ISSUE 9 policy-panel arm: the competitor policies share the fused
  per-access scan body and geometry with W-TinyLFU, so a > 2x throughput
  gap flags a fused-shape break in that policy's branch.  WARN-only (hit
  ratios are pinned by ``tests/test_policy_panel.py``; throughput parity
  is advisory on shared runners).  ARC's warning is currently expected:
  its per-access ghost-Bloom maintenance measures ~4.5x on XLA-CPU (see
  docs/BENCHMARKS.md arm 8).  Missing in pre-ISSUE-9 snapshots.
* set-assoc throughput more than ``--drop`` (default 30%) below the
  baseline snapshot — only enforced when both snapshots carry the same
  ``machine`` fingerprint: absolute acc/s is meaningless across machines.
  In practice this arm is for like-for-like comparisons (local dev loop,
  a future benchmark runner that commits its own snapshots); on GitHub CI
  the committed baseline comes from a different machine, the comparison is
  skipped with a NOTE, and the flatness+corroboration tripwire above is
  the active gate.

docs/BENCHMARKS.md documents every snapshot field, the gate arms, and the
baseline refresh procedure.

Usage (CI runs this right after ``benchmarks.run --only device``):

  python -m benchmarks.check_bench --baseline BENCH_baseline.json \
      [--fresh BENCH_device.json] [--threshold 0.9] [--drop 0.3] [--strict]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def check(fresh: dict, baseline: dict | None, *, threshold: float = 0.9,
          drop: float = 0.3, strict: bool = False) -> list[str]:
    """Returns the list of fatal failures (empty = gate passes)."""
    failures = []
    flat = fresh.get("assoc_flatness_512_to_65536")
    speedup = fresh.get("assoc_speedup_vs_flat_8192", 0.0)
    if flat is None:
        failures.append("snapshot missing assoc_flatness_512_to_65536")
    elif flat < threshold:
        msg = (f"flatness {flat} < {threshold} "
               f"(speedup vs flat engine: {speedup}x)")
        if strict or speedup < 5:
            failures.append("set path no longer capacity-free: " + msg)
        else:
            print(f"WARNING: {msg} — not corroborated by the speedup "
                  "indicator; attributing to machine noise", flush=True)

    # unsharded path at width 2^19 (ISSUE 5: the gather-partitioning cliff
    # fix) — same corroboration logic as the 65536 arm; missing in
    # pre-ISSUE-5 snapshots.  Own threshold: past _big_operand the sketch
    # reads run the unrolled-scalar-slice discipline, whose constant cost
    # puts the healthy ratio near ~0.75 (measured 0.76 vs 0.28 with the
    # cliff present), so the 0.9 default would warn on every healthy run.
    flat_xl = fresh.get("assoc_flatness_512_to_262144")
    xl_threshold = min(threshold, 0.6)
    if flat_xl is not None and flat_xl < xl_threshold:
        msg = (f"flatness 512->262144 {flat_xl} < {xl_threshold} "
               f"(speedup vs flat engine: {speedup}x)")
        if strict or speedup < 5:
            failures.append(
                "unsharded path hit the large-width gather cliff: " + msg)
        else:
            print(f"WARNING: {msg} — not corroborated by the speedup "
                  "indicator; attributing to machine noise", flush=True)

    sh_flat = fresh.get("sharded_flatness_512_to_65536")
    sh_over = fresh.get("sharded_overhead_vs_unsharded", 0.0)
    if sh_flat is not None and sh_flat < threshold:
        msg = (f"sharded flatness {sh_flat} < {threshold} "
               f"(overhead vs unsharded: {sh_over}x)")
        if strict or sh_over > 3:
            failures.append("sharded path no longer capacity-free: " + msg)
        else:
            print(f"WARNING: {msg} — not corroborated by the overhead "
                  "indicator; attributing to machine noise", flush=True)

    # multi-device mesh run (ISSUE 5): bit-identity to the single-device
    # sharded run is a hard invariant, not a throughput number — no noise
    # model applies.  Missing in pre-mesh snapshots (or when the bench
    # could not spawn the forced-2-device subprocess).
    if fresh.get("mesh_parity_ok") is False:
        failures.append(
            "mesh run diverged from the single-device sharded run "
            "(mesh_parity_ok false) — the multi-device exactness ladder "
            "is broken")

    # mesh collective cadence (ISSUE 6): the exact chunked exchange keeps
    # the per-access path collective-free, so overhead vs the single-device
    # sharded run stays near ~1x.  A real regression (a collective back in
    # the step scan) scales with the epoch length — the original per-access
    # psum measured 62.8x — so > 3 warns and > 10 (or --strict)
    # corroborates it into a failure; plain machine noise cannot push a
    # collective-free program past ~10x.
    m_over = fresh.get("mesh_overhead_vs_sharded")
    if m_over is not None and m_over > 3.0:
        msg = f"mesh chunked-exchange overhead {m_over}x > 3x vs sharded"
        if strict or m_over > 10.0:
            failures.append(
                "per-access mesh collective is back: " + msg)
        else:
            print(f"WARNING: {msg} — under the 10x corroboration bar; "
                  "attributing to machine noise", flush=True)

    # multi-stream lane batching (ISSUE 8): aggregate-throughput scaling at
    # B=64 vs single-stream on the frozen small-tenant geometry.  The lane
    # program is scatter-free fused selects by construction; losing that
    # (or re-serializing lane dispatch) collapses the ratio toward ~1x,
    # far below what machine noise can do to a within-process ratio.
    st_scale = fresh.get("streams_scaling_1_to_64")
    if st_scale is not None and st_scale < 8.0:
        msg = (f"streams B=64 aggregate scaling {st_scale}x < 8x over "
               "single-stream")
        if strict or st_scale < 3.0:
            failures.append(
                "lane batching no longer amortizes dispatch: " + msg)
        else:
            print(f"WARNING: {msg} — above the 3x corroboration floor; "
                  "attributing to machine noise", flush=True)

    # policy panel (ISSUE 9): the competitor policies share the fused
    # per-access scan body and geometry with W-TinyLFU, so their acc/s
    # should land within ~2x of the default policy.  A bigger gap means a
    # policy branch broke out of the fused shape (a scatter, a cond-copied
    # table, a widened operand) — but hit-ratio exactness is pinned by the
    # test tier, and throughput parity is aspirational on shared runners,
    # so this arm only ever WARNS.  Missing in pre-ISSUE-9 snapshots.
    pol_base = fresh.get("policy_acc_per_s_wtinylfu")
    if pol_base:
        for pol in ("s3fifo", "arc", "lfu"):
            pol_rate = fresh.get(f"policy_acc_per_s_{pol}")
            if pol_rate and pol_rate < pol_base / 2.0:
                print(f"WARNING: policy {pol!r} runs "
                      f"{pol_base / pol_rate:.1f}x slower than w-tinylfu "
                      "in the same geometry — check its branch for a "
                      "fused-shape break (warn-only arm)", flush=True)

    if baseline:
        same_machine = (baseline.get("machine") and
                        baseline.get("machine") == fresh.get("machine") and
                        baseline.get("device") == fresh.get("device"))
        if not same_machine:
            print("NOTE: baseline from a different machine "
                  f"({baseline.get('machine')!r} vs {fresh.get('machine')!r})"
                  " — skipping absolute-throughput comparison", flush=True)
        else:
            for key in ("assoc_acc_per_s_small_C", "assoc_acc_per_s_large_C"):
                base, cur = baseline.get(key), fresh.get(key)
                if base and cur and cur < base * (1.0 - drop):
                    failures.append(
                        f"{key} dropped {(1 - cur / base):.0%} "
                        f"({base} -> {cur}, limit {drop:.0%})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh",
                    default=os.path.join(_REPO_ROOT, "BENCH_device.json"))
    ap.add_argument("--baseline", default=None,
                    help="committed snapshot to compare against (optional)")
    ap.add_argument("--threshold", type=float, default=0.9)
    ap.add_argument("--drop", type=float, default=0.3)
    ap.add_argument("--strict", action="store_true",
                    help="flatness miss is fatal even without corroboration")
    args = ap.parse_args(argv)

    with open(args.fresh) as f:
        fresh = json.load(f)
    baseline = None
    if args.baseline:
        try:
            with open(args.baseline) as f:
                baseline = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"NOTE: baseline {args.baseline!r} unreadable ({e}); "
                  "skipping throughput comparison", flush=True)

    failures = check(fresh, baseline, threshold=args.threshold,
                     drop=args.drop, strict=args.strict)
    for msg in failures:
        print("FAIL:", msg, flush=True)
    if failures:
        print("see docs/BENCHMARKS.md for the gate arms, the noise model, "
              "and how to refresh the baseline snapshot", flush=True)
    else:
        print("bench gate OK:", json.dumps(
            {k: fresh.get(k) for k in ("assoc_flatness_512_to_65536",
                                       "assoc_flatness_512_to_262144",
                                       "assoc_speedup_vs_flat_8192",
                                       "adaptive_overhead_vs_static",
                                       "sharded_flatness_512_to_65536",
                                       "sharded_overhead_vs_unsharded",
                                       "mesh_overhead_vs_sharded",
                                       "mesh_stale_overhead_vs_sharded",
                                       "mesh_parity_ok",
                                       "checkpoint_overhead_vs_plain",
                                       "streams_acc_per_s_total",
                                       "streams_scaling_1_to_64",
                                       "policy_acc_per_s_s3fifo",
                                       "policy_acc_per_s_arc",
                                       "policy_acc_per_s_lfu")}),
            flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
