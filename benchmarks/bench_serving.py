"""Serving integration (ours): prefix-pool block hit-ratio under a
multi-tenant prompt workload — LRU vs TinyLFU vs W-TinyLFU retention, plus
the implied prefill-FLOP savings.  This is the paper's admission policy doing
its production job (DESIGN.md §2)."""
from __future__ import annotations

import numpy as np

from repro.serve.prefix_cache import PrefixCache
from repro.traces import multi_tenant_prompt_trace
from .common import save


def run(quick: bool = False):
    n_req = 1200 if quick else 6000
    stream = multi_tenant_prompt_trace(n_req, n_tenants=400,
                                       tenant_alpha=1.0, seed=81)
    rows = []
    for policy in ["lru", "tinylfu", "wtinylfu"]:
        for cap in ([2000] if quick else [1000, 2000, 4000]):
            pc = PrefixCache(cap, policy=policy, sample_factor=8)
            slot = 0
            # replay: requests touch their block chain; block-level admission
            i = 0
            req_sizes = []
            while i < len(stream):
                # requests are contiguous runs; reconstruct by prefix ids:
                # simpler: process in chunks of 32 blocks as pseudo-requests
                chunk = [int(x) for x in stream[i:i + 32]]
                i += 32
                hits = pc.lookup(chunk)
                for h in chunk[len(hits):]:
                    if h not in pc:
                        for freed in pc.insert(h, slot):
                            pass
                        slot += 1
            s = pc.stats
            rows.append({"trace": "multi-tenant", "policy": policy,
                         "cache_size": cap, "hit_ratio": s.hit_ratio,
                         "admitted": s.admitted, "rejected": s.rejected})
            print(f"  serving cap={cap:<6d} {policy:<10s} "
                  f"block-hit={s.hit_ratio:.4f}", flush=True)
    save(rows, "serving_prefix")
    return rows


if __name__ == "__main__":
    run()
