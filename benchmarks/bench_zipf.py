"""Figure 6: augmenting caches with TinyLFU under static Zipf 0.7 / 0.9.

Claims validated: (a) TinyLFU admission lifts LRU/Random close to windowed
LFU; (b) eviction choice barely matters once admission is on; (c) PLFU is the
static-optimal reference.  Static distributions are presented at a large
sample (sf=64, the paper's "highest hit ratio" presentation; sampling error
shrinks with W — §5.4)."""
from __future__ import annotations

from repro.traces import zipf_trace
from .common import policy_factories, sweep, device_rows, save


def run(quick: bool = False, device: bool = True):
    length = 300_000 if quick else 1_200_000
    sizes = [500, 2000] if quick else [250, 1000, 4000, 16000]
    rows = []
    pf = policy_factories(sample_factor=64)
    keep = ["LRU", "Random", "LFU(inmem)", "WLFU", "PLFU",
            "TLRU", "TRandom", "TLFU", "W-TinyLFU"]
    pols = {k: pf[k] for k in keep}
    for alpha in (0.7, 0.9):
        tr = zipf_trace(length, n_items=1_000_000, alpha=alpha, seed=11)
        rows += sweep(tr, sizes, pols, warmup_frac=0.4,
                      trace_name=f"zipf{alpha}")
        if device:
            # device twin of the W-TinyLFU curve as one compiled sweep.
            # sample_factor=8: device counters are 4-bit (§3.4.1), so the
            # host presentation's sf=64 cap does not fit a nibble.
            rows += device_rows(tr, sizes, warmup_frac=0.4,
                                trace_name=f"zipf{alpha}", sample_factor=8)
    save(rows, "fig6_zipf")
    return rows


if __name__ == "__main__":
    run()
