"""Benchmark orchestrator — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV per the harness contract and writes
full JSON rows to experiments/results/."""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale traces (default: quick CI sizes)")
    ap.add_argument("--only", type=str, default=None)
    args, _ = ap.parse_known_args()
    quick = not args.full

    from . import (bench_strawman, bench_zipf, bench_youtube, bench_wiki,
                   bench_traces, bench_window, bench_errors, bench_serving,
                   bench_sketch, bench_device)
    suites = {
        "fig4_strawman": bench_strawman.run,
        "fig6_zipf": bench_zipf.run,
        "fig7_youtube": bench_youtube.run,
        "fig8_wiki": bench_wiki.run,
        "fig9_20_traces": bench_traces.run,
        "fig21_window": bench_window.run,
        "fig22_errors": bench_errors.run,
        "serving_prefix": bench_serving.run,
        "sketch_micro": bench_sketch.run,
        "device_throughput": bench_device.run,
    }
    if args.only:
        suites = {k: v for k, v in suites.items() if args.only in k}
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        t0 = time.perf_counter()
        rows = fn(quick=quick)
        wall = time.perf_counter() - t0
        n = max(1, sum(r.get("accesses", 1) for r in rows))
        # derived: the headline number of each table
        derived = ""
        hits = [r["hit_ratio"] for r in rows if "hit_ratio" in r]
        if hits:
            derived = f"best_hit={max(hits):.4f}"
        elif rows and "reduction" in rows[0]:
            derived = f"reduction={rows[0]['reduction']:.1%}"
        elif rows and "us_per_op" in rows[0]:
            derived = f"host_us={rows[0]['us_per_op']:.2f}"
        print(f"{name},{wall / n * 1e6:.4f},{derived}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
