"""Figure 7: YouTube-like dynamic distribution (weekly drift + churn).

Claims: TinyLFU still helps under drift; slower change -> bigger benefit;
eviction choice matters MORE than in the static case (paper §5.2)."""
from __future__ import annotations

from repro.traces import youtube_dynamic_trace
from .common import policy_factories, sweep, save


def run(quick: bool = False):
    rows = []
    pf = policy_factories(sample_factor=9)
    keep = ["LRU", "Random", "LFU(inmem)", "WLFU", "TLRU", "TRandom",
            "TLFU", "W-TinyLFU"]
    pols = {k: pf[k] for k in keep}
    # (a) change-speed sweep at C=1000 (requests per week ~ change speed)
    length = 200_000 if quick else 800_000
    for per_week_factor, tag in [(0.3, "fast"), (1.0, "med"), (3.0, "slow")]:
        tr = youtube_dynamic_trace(int(length * per_week_factor), weeks=21,
                                   items_per_week=8000, churn=0.4, seed=21)
        rows += sweep(tr, [1000], pols, warmup_frac=0.1,
                      trace_name=f"yt-{tag}")
    # (b) cache-size sweep at trace speed
    tr = youtube_dynamic_trace(length, weeks=21, items_per_week=8000,
                               churn=0.4, seed=22)
    sizes = [500, 2000] if quick else [250, 1000, 4000]
    rows += sweep(tr, sizes, pols, warmup_frac=0.1, trace_name="yt-sizes")
    save(rows, "fig7_youtube")
    return rows


if __name__ == "__main__":
    run()
