"""Figure 4 (table): TinyLFU metadata vs the strawman (10 sliding sketches,
full-width counters, no doorkeeper/cap) for a 1k cache / 9k sample under
Zipf 0.9.  Claim: ~89%% metadata reduction."""
from __future__ import annotations

import numpy as np

from repro.core.sketch import FrequencySketch, SketchConfig, _pow2ceil
from repro.traces import zipf_trace
from .common import save


def run(quick: bool = False):
    C, W = 1000, 9000
    tr = zipf_trace(W, n_items=1_000_000, alpha=0.9, seed=71)
    uniq = len(np.unique(tr))
    counts = np.unique(tr, return_counts=True)[1]
    second_timers = int((counts >= 2).sum())

    # TinyLFU: doorkeeper 1 bit/unique + 3-bit counters for 2nd-timers (the
    # paper's Fig-4 accounting), bloom-sized at 1 counter per item
    tiny_bits = uniq * 1 + second_timers * 3
    tiny_avg = tiny_bits / uniq
    # Strawman: 10 sketches, counters must count to the window max -> 10 bits,
    # every unique item in every ~1/10 window slice allocated a counter
    straw_bits = uniq * 10
    straw_avg = 10.0
    rows = [{
        "table": "fig4", "unique_items": uniq,
        "second_timers": second_timers,
        "tinylfu_avg_bits": round(tiny_avg, 2),
        "strawman_avg_bits": straw_avg,
        "reduction": round(1 - tiny_bits / straw_bits, 3),
    }]
    print(f"  fig4: uniq={uniq} 2nd={second_timers} tiny={tiny_avg:.2f}b "
          f"straw={straw_avg:.0f}b reduction={rows[0]['reduction']:.1%}",
          flush=True)
    save(rows, "fig4_strawman")
    return rows


if __name__ == "__main__":
    run()
