"""Shared benchmark harness: policy factories, sweep runner, result I/O."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import (Cache, LRUEviction, RandomEviction, LFUEviction,
                        SLRUEviction, FIFOEviction, ARC, LIRS, TwoQ, WLFU,
                        PLFU, WTinyLFU, tinylfu_cache, run_trace, SimResult)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "results")


def policy_factories(sample_factor: int = 8, seed: int = 0):
    """name -> factory(capacity).  The paper's cast (§5.1 naming)."""
    sf = sample_factor
    return {
        "LRU": lambda C: Cache(LRUEviction(C)),
        "Random": lambda C: Cache(RandomEviction(C, seed=seed)),
        "FIFO": lambda C: Cache(FIFOEviction(C)),
        "LFU(inmem)": lambda C: Cache(LFUEviction(C)),
        "WLFU": lambda C: WLFU(C, window=sf * C),
        "PLFU": lambda C: PLFU(C),
        "2Q": lambda C: TwoQ(C),
        "ARC": lambda C: ARC(C),
        "LIRS": lambda C: LIRS(C),
        "TLRU": lambda C: tinylfu_cache(C, "lru", sample_factor=sf, seed=seed),
        "TRandom": lambda C: tinylfu_cache(C, "random", sample_factor=sf,
                                           seed=seed),
        "TLFU": lambda C: tinylfu_cache(C, "lfu", sample_factor=sf, seed=seed),
        "W-TinyLFU": lambda C: WTinyLFU(C, sample_factor=sf, seed=seed),
        "W-TinyLFU(20%)": lambda C: WTinyLFU(C, window_frac=0.20,
                                             sample_factor=sf, seed=seed),
    }


def sweep(trace: np.ndarray, cache_sizes, policies: dict, *,
          warmup_frac: float = 0.0, trace_name: str = "trace",
          verbose: bool = True) -> list[dict]:
    rows = []
    warm = int(len(trace) * warmup_frac)
    for C in cache_sizes:
        for name, factory in policies.items():
            t0 = time.perf_counter()
            r = run_trace(factory(C), trace, warmup=warm,
                          trace_name=trace_name)
            rows.append({
                "trace": trace_name, "policy": name, "cache_size": C,
                "hit_ratio": r.hit_ratio, "accesses": r.accesses,
                "wall_s": round(time.perf_counter() - t0, 2),
            })
            if verbose:
                print(f"  {trace_name:>12s} C={C:<6d} {name:<16s} "
                      f"hit={r.hit_ratio:.4f}", flush=True)
    return rows


def device_rows(trace: np.ndarray, cache_sizes, *, window_fracs=(0.01,),
                warmup_frac: float = 0.0, trace_name: str = "trace",
                sample_factor: int = 8, verbose: bool = True,
                **cfg_kw) -> list[dict]:
    """Device-engine twin of :func:`sweep` for the W-TinyLFU policy family.

    Runs the whole (cache_size × window_frac) grid through
    ``core.device_simulate.simulate_sweep`` — one compiled program instead of
    one Python loop per configuration — and returns rows in the same shape as
    ``sweep`` so results mix into the same JSON files.  The jax import is
    deferred so host-only benchmark runs never pay for it.
    """
    from repro.core.device_simulate import simulate_sweep

    warm = int(len(trace) * warmup_frac)
    results = simulate_sweep(trace, cache_sizes, window_fracs=window_fracs,
                             warmup=warm, trace_name=trace_name,
                             sample_factor=sample_factor, verbose=verbose,
                             **cfg_kw)
    rows = []
    for r in results:
        wf = r.extra["window_frac"]
        name = ("W-TinyLFU(dev)" if wf == 0.01
                else f"W-TinyLFU(dev,{wf:.0%})")
        rows.append({
            # SimResult.wall_s is already per-row amortized (the whole
            # grid's wall lives in extra["grid_wall_s"])
            "trace": trace_name, "policy": name, "cache_size": r.cache_size,
            "hit_ratio": r.hit_ratio, "accesses": r.accesses,
            "wall_s": round(r.wall_s, 2), "grid": r.extra["grid"],
            "grid_wall_s": round(r.extra["grid_wall_s"], 2),
            "backend": r.extra["backend"],
        })
    return rows


def save(rows, name: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


def load(name: str):
    with open(os.path.join(RESULTS_DIR, name + ".json")) as f:
        return json.load(f)
