"""Shared benchmark harness: policy factories, sweep runner, result I/O."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import (Cache, LRUEviction, RandomEviction, LFUEviction,
                        SLRUEviction, FIFOEviction, ARC, LIRS, TwoQ, WLFU,
                        PLFU, WTinyLFU, tinylfu_cache, run_trace, SimResult)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "results")


def policy_factories(sample_factor: int = 8, seed: int = 0):
    """name -> factory(capacity).  The paper's cast (§5.1 naming)."""
    sf = sample_factor
    return {
        "LRU": lambda C: Cache(LRUEviction(C)),
        "Random": lambda C: Cache(RandomEviction(C, seed=seed)),
        "FIFO": lambda C: Cache(FIFOEviction(C)),
        "LFU(inmem)": lambda C: Cache(LFUEviction(C)),
        "WLFU": lambda C: WLFU(C, window=sf * C),
        "PLFU": lambda C: PLFU(C),
        "2Q": lambda C: TwoQ(C),
        "ARC": lambda C: ARC(C),
        "LIRS": lambda C: LIRS(C),
        "TLRU": lambda C: tinylfu_cache(C, "lru", sample_factor=sf, seed=seed),
        "TRandom": lambda C: tinylfu_cache(C, "random", sample_factor=sf,
                                           seed=seed),
        "TLFU": lambda C: tinylfu_cache(C, "lfu", sample_factor=sf, seed=seed),
        "W-TinyLFU": lambda C: WTinyLFU(C, sample_factor=sf, seed=seed),
        "W-TinyLFU(20%)": lambda C: WTinyLFU(C, window_frac=0.20,
                                             sample_factor=sf, seed=seed),
    }


def sweep(trace: np.ndarray, cache_sizes, policies: dict, *,
          warmup_frac: float = 0.0, trace_name: str = "trace",
          verbose: bool = True) -> list[dict]:
    rows = []
    warm = int(len(trace) * warmup_frac)
    for C in cache_sizes:
        for name, factory in policies.items():
            t0 = time.perf_counter()
            r = run_trace(factory(C), trace, warmup=warm)
            rows.append({
                "trace": trace_name, "policy": name, "cache_size": C,
                "hit_ratio": r.hit_ratio, "accesses": r.accesses,
                "wall_s": round(time.perf_counter() - t0, 2),
            })
            if verbose:
                print(f"  {trace_name:>12s} C={C:<6d} {name:<16s} "
                      f"hit={r.hit_ratio:.4f}", flush=True)
    return rows


def save(rows, name: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


def load(name: str):
    with open(os.path.join(RESULTS_DIR, name + ".json")) as f:
        return json.load(f)
