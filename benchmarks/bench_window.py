"""Figure 21: window/main balance of W-TinyLFU on burst-heavy (OLTP-like)
traces. Claim: 20-40%% windows win on OLTP-family, 1%% elsewhere."""
from __future__ import annotations

from repro.core import WTinyLFU, run_trace
from repro.traces import oltp_like_trace, zipf_trace
from .common import save


def run(quick: bool = False):
    length = 200_000 if quick else 800_000
    rows = []
    for tname, tr, C in [
        ("oltp-like", oltp_like_trace(length, seed=51), 1000),
        ("zipf0.9", zipf_trace(length, n_items=400_000, alpha=0.9, seed=52),
         1000),
    ]:
        for wf in [0.01, 0.05, 0.1, 0.2, 0.3, 0.4, 0.6, 0.8]:
            r = run_trace(WTinyLFU(C, window_frac=wf, sample_factor=8), tr,
                          warmup=length // 5)
            rows.append({"trace": tname, "policy": f"W-TinyLFU({wf:.0%})",
                         "cache_size": C, "hit_ratio": r.hit_ratio,
                         "accesses": r.accesses, "wall_s": r.wall_s})
            print(f"  {tname:>10s} window={wf:.0%} hit={r.hit_ratio:.4f}",
                  flush=True)
    save(rows, "fig21_window")
    return rows


if __name__ == "__main__":
    run()
