"""Figure 21: window/main balance of W-TinyLFU on burst-heavy (OLTP-like)
traces. Claim: 20-40%% windows win on OLTP-family, 1%% elsewhere."""
from __future__ import annotations

from repro.core import WTinyLFU, run_trace
from repro.traces import oltp_like_trace, zipf_trace
from .common import device_rows, save

WINDOW_FRACS = [0.01, 0.05, 0.1, 0.2, 0.3, 0.4, 0.6, 0.8]


def run(quick: bool = False, device: bool = True):
    length = 200_000 if quick else 800_000
    rows = []
    for tname, tr, C in [
        ("oltp-like", oltp_like_trace(length, seed=51), 1000),
        ("zipf0.9", zipf_trace(length, n_items=400_000, alpha=0.9, seed=52),
         1000),
    ]:
        for wf in WINDOW_FRACS:
            r = run_trace(WTinyLFU(C, window_frac=wf, sample_factor=8), tr,
                          warmup=length // 5, trace_name=tname)
            rows.append({"trace": tname, "policy": f"W-TinyLFU({wf:.0%})",
                         "cache_size": C, "hit_ratio": r.hit_ratio,
                         "accesses": r.accesses, "wall_s": r.wall_s})
            print(f"  {tname:>10s} window={wf:.0%} hit={r.hit_ratio:.4f}",
                  flush=True)
        if device:
            # the whole window-fraction axis is one compiled device sweep
            rows += device_rows(tr, [C], window_fracs=WINDOW_FRACS,
                                warmup_frac=0.2, trace_name=tname)
    save(rows, "fig21_window")
    return rows


if __name__ == "__main__":
    run()
