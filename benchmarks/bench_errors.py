"""Figure 22: error decomposition for a 1k cache under Zipf 0.9 —
sampling error (sample size), truncation error (integer vs float reset), and
approximation error (sketch vs exact table) as a function of bytes/element."""
from __future__ import annotations

from repro.core import Cache, LRUEviction, run_trace, ExactHistogram
from repro.core.sketch import FrequencySketch, SketchConfig, _pow2ceil
from repro.core.tinylfu import TinyLFUAdmission
from repro.traces import zipf_trace
from .common import save


class _ExactAdmission:
    def __init__(self, sample, integer_division=True, cap=None):
        self.h = ExactHistogram(sample, cap=cap,
                                integer_division=integer_division)
    def record(self, k): self.h.add(k)
    def admit(self, cand, victim):
        return self.h.estimate(cand) > self.h.estimate(victim)


def _sketch_admission(sample, bytes_per_elem, dk_frac=0.33, seed=0):
    total_bits = int(8 * bytes_per_elem * sample)
    dk_bits = max(64, _pow2ceil(int(total_bits * dk_frac)))
    counters = max(32, _pow2ceil((total_bits - dk_bits) // 4))
    cfg = SketchConfig(sample_size=sample, counters=counters, rows=4,
                       cap=7, doorkeeper_bits=dk_bits, seed=seed)
    return TinyLFUAdmission(FrequencySketch(cfg))


def run(quick: bool = False, tiny: bool = False):
    """``tiny=True`` is the CI smoke configuration (ISSUE 7): a 30k trace
    over a 200-entry cache with one byte budget — seconds instead of
    minutes, enough to prove the figure still runs end to end and orders
    the error tiers (float-exact >= int-exact ~ best sketch)."""
    C = 200 if tiny else 1000
    length = 30_000 if tiny else (250_000 if quick else 1_000_000)
    tr = zipf_trace(length, n_items=1_000_000, alpha=0.9, seed=61)
    warm = length // 5
    rows = []

    def measure(name, adm_factory, sample):
        cache = Cache(LRUEviction(C), adm_factory())
        r = run_trace(cache, tr, warmup=warm)
        rows.append({"trace": "zipf0.9", "policy": name, "cache_size": C,
                     "sample": sample, "hit_ratio": r.hit_ratio,
                     "accesses": r.accesses, "wall_s": r.wall_s})
        print(f"  {name:<34s} hit={r.hit_ratio:.4f}", flush=True)

    for sample in ([9 * C] if (quick or tiny) else [9 * C, 17 * C]):
        # float-exact = sampling error only
        measure(f"exact-float(W={sample})",
                lambda s=sample: _ExactAdmission(s, integer_division=False),
                sample)
        # int-exact adds truncation error
        measure(f"exact-int(W={sample})",
                lambda s=sample: _ExactAdmission(s, integer_division=True),
                sample)
        # sketch adds approximation error, vs byte budget
        budgets = ([1.0] if tiny
                   else [0.5, 1.0, 1.5] if quick
                   else [0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 2.0])
        for b in budgets:
            measure(f"sketch(W={sample},B={b})",
                    lambda s=sample, bb=b: _sketch_admission(s, bb), sample)
    save(rows, "fig22_errors")
    return rows


if __name__ == "__main__":
    import sys
    run(quick=True, tiny="--tiny" in sys.argv)
