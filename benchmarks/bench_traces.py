"""Figures 9-20: trace-family comparisons vs state of the art (ARC, LIRS).

Families (synthetic generators matching the published structure, §5.1):
glimpse (loop), spc1-like (sequential scans + hot set), oltp-like (ascending
log w/ sparse bursts).  Claims: W-TinyLFU ties or beats ARC/LIRS everywhere;
raw TLRU underperforms on OLTP (admission starves bursts) and the window
fixes it (§4)."""
from __future__ import annotations

from repro.traces import glimpse_trace, spc1_like_trace, oltp_like_trace
from .common import policy_factories, sweep, save


def run(quick: bool = False):
    length = 250_000 if quick else 900_000
    pf = policy_factories(sample_factor=8)
    keep = ["LRU", "ARC", "LIRS", "2Q", "TLRU", "W-TinyLFU",
            "W-TinyLFU(20%)"]
    pols = {k: pf[k] for k in keep}
    rows = []
    traces = {
        "glimpse": glimpse_trace(length, loop_items=3000, seed=41),
        "spc1-like": spc1_like_trace(length, seed=42),
        "oltp-like": oltp_like_trace(length, seed=43),
    }
    sizes = {
        "glimpse": [500, 2000] if quick else [512, 1024, 2048, 4096],
        "spc1-like": [1000, 4000] if quick else [1024, 4096, 16384],
        "oltp-like": [500, 1000] if quick else [256, 1024, 4096],
    }
    for name, tr in traces.items():
        rows += sweep(tr, sizes[name], pols, warmup_frac=0.1,
                      trace_name=name)
    save(rows, "fig9_20_traces")
    return rows


if __name__ == "__main__":
    run()
