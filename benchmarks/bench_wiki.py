"""Figure 8: Wikipedia-like gradually-drifting Zipf. Claims: there is a
sample-size sweet spot — too-large W slows adaptation and can REDUCE hit
ratio (paper §5.2)."""
from __future__ import annotations

from repro.traces import wiki_drift_trace
from repro.core import tinylfu_cache, WTinyLFU, Cache, LRUEviction, run_trace
from .common import sweep, save, policy_factories


def run(quick: bool = False):
    length = 250_000 if quick else 1_000_000
    rows = []
    tr = wiki_drift_trace(length, n_items=400_000, alpha=0.9,
                          drift_every=20_000, drift_frac=0.02, seed=31)
    C = 1000
    # (a) sample-factor sweep for TLRU (the paper's ratio experiment)
    for sf in [2, 4, 8, 16, 32, 64]:
        r = run_trace(tinylfu_cache(C, "lru", sample_factor=sf), tr,
                      warmup=length // 5)
        rows.append({"trace": "wiki-drift", "policy": f"TLRU(sf={sf})",
                     "cache_size": C, "hit_ratio": r.hit_ratio,
                     "accesses": r.accesses, "wall_s": r.wall_s})
        print(f"  wiki sf={sf:<3d} hit={r.hit_ratio:.4f}", flush=True)
    # (b) cache-size sweep at the best ratio found
    best_sf = max((r for r in rows), key=lambda r: r["hit_ratio"])
    sf = int(best_sf["policy"].split("=")[1].rstrip(")"))
    pf = policy_factories(sample_factor=sf)
    keep = ["LRU", "WLFU", "TLRU", "W-TinyLFU", "ARC", "LIRS"]
    sizes = [500, 2000] if quick else [250, 1000, 4000]
    rows += sweep(tr, sizes, {k: pf[k] for k in keep}, warmup_frac=0.2,
                  trace_name="wiki-drift")
    save(rows, "fig8_wiki")
    return rows


if __name__ == "__main__":
    run()
