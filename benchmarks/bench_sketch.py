"""Sketch micro-benchmark: host sketch vs jnp oracle vs Pallas(interpret)
per-op latency, plus memory footprint per configuration."""
from __future__ import annotations

import time

import numpy as np

from repro.core.sketch import default_sketch
from repro.kernels import DeviceTinyLFU, make_config, init_state, keys_to_lanes
from repro.kernels import ops
from .common import save


def run(quick: bool = False):
    rows = []
    n = 2000 if quick else 20_000
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 1 << 60, size=n, dtype=np.uint64)

    # host sketch
    s = default_sketch(1000, sample_factor=8)
    t0 = time.perf_counter()
    for k in keys.tolist():
        s.add(k)
    host_us = (time.perf_counter() - t0) / n * 1e6
    rows.append({"impl": "host-python", "op": "add", "us_per_op": host_us,
                 "meta_bits": s.cfg.meta_bits()})

    # device (jnp oracle and pallas-interpret), batched
    for use_pallas, name in [(False, "jnp-oracle"), (True, "pallas-interp")]:
        cfg = make_config(1000, sample_factor=8)
        st = init_state(cfg)
        lo, hi = keys_to_lanes(keys[:1024])
        ops.add(cfg, st, lo, hi, use_pallas)            # compile
        t0 = time.perf_counter()
        reps = 3 if quick else 10
        for _ in range(reps):
            st = ops.add(cfg, st, lo, hi, use_pallas)
        st["counters"].block_until_ready()
        us = (time.perf_counter() - t0) / (reps * 1024) * 1e6
        rows.append({"impl": name, "op": "add_batch1024",
                     "us_per_op": us, "meta_bits": None})
        print(f"  sketch {name:<14s} {us:8.2f} us/op", flush=True)
    print(f"  sketch host-python    {host_us:8.2f} us/op", flush=True)
    save(rows, "sketch_micro")
    return rows


if __name__ == "__main__":
    run()
